"""The serving path (PR 10): fused one-dispatch tuning, cross-session
micro-batching with spy-asserted bitwise parity against the unbatched
path, the deadline-aware admission queue's typed errors and health
transitions, and per-session result isolation under concurrent load."""
import threading
import time

import numpy as np
import pytest

from repro.configs.neurovec import NeuroVecConfig
from repro.core.agents import AGENT_NAMES, make_agent
from repro.core.agents.brute import brute_force_labels
from repro.core.env import ActionSpace, CostModelEnv
from repro.models.compute import KernelSite
from repro.serving import (AgentBatch, DeadlineExceeded, FusedTuner,
                           QueueFull, Server, ServingConfig, ServingError,
                           bucket_size)
from repro.service import TuningService


def small_cfg() -> NeuroVecConfig:
    return NeuroVecConfig(
        bm_choices=(16, 32), bn_choices=(128,), bk_choices=(128,),
        bq_choices=(32, 64), bkv_choices=(128,), chunk_choices=(16, 32),
        train_batch=32, sgd_minibatch=16, ppo_epochs=2)


CFG = small_cfg()

SITES = [
    KernelSite(site="sv.mm0", kind="matmul", m=64, n=128, k=128),
    KernelSite(site="sv.mm1", kind="matmul", m=96, n=256, k=128),
    KernelSite(site="sv.attn", kind="attention", m=64, n=32, k=64,
               batch=2, causal=True),
    KernelSite(site="sv.scan", kind="chunk_scan", m=32, n=16, k=8,
               batch=2),
]


def _sites(tag: str, n: int = 3):
    """Distinct per-session site lists so cross-request mixing in the
    batcher would change results."""
    return [KernelSite(site=f"{tag}.mm{i}", kind="matmul",
                       m=32 * (i + 1), n=128, k=128) for i in range(n)]


# ---------------------------------------------------------------------------
# FusedTuner: one dispatch, argmin parity with the float64 reference
# ---------------------------------------------------------------------------

class TestFusedTuner:
    def test_actions_match_brute_force_float64_reference(self):
        """The float32 device grid must pick the same argmin as the
        float64 NumPy reference, per site and per kind."""
        env = CostModelEnv(CFG, seed=0)
        ref = brute_force_labels(env, SITES)
        fused = FusedTuner(CFG).actions(SITES)
        np.testing.assert_array_equal(fused, np.asarray(ref))

    def test_tune_matches_inline_vectorizer_assembly(self):
        env = CostModelEnv(CFG, seed=0)
        space = ActionSpace(CFG)
        ref = brute_force_labels(env, SITES)
        prog = FusedTuner(CFG).tune(SITES)
        assert set(prog.tiles) == {s.key() for s in SITES}
        for s, a in zip(SITES, ref):
            assert prog.tiles[s.key()] == space.tiles(s.kind, a)

    def test_one_dispatch_and_bucketed_trace_reuse(self):
        """tune() is ONE device dispatch; batch sizes inside one
        power-of-two bucket reuse the jit specialization (no retrace)."""
        t = FusedTuner(CFG)
        t.tune(SITES[:3])
        assert t.dispatch_count == 1 and t.trace_count == 1
        t.tune(SITES)                         # 4 sites: same bucket of 8
        assert t.dispatch_count == 2 and t.trace_count == 1
        t.actions(SITES[:2])
        assert t.dispatch_count == 3 and t.trace_count == 1
        assert t.last_padded_batch == bucket_size(2)
        st = t.stats()
        assert st["serving_fused_dispatches_total"] == 3
        assert st["serving_fused_traces_total"] == 1
        assert st["serving_fused_sites_total"] == 9

    def test_tune_many_slices_bitwise_equal_to_solo_tunes(self):
        t = FusedTuner(CFG)
        a, b = SITES[:2], SITES[2:]
        many = t.tune_many([a, b, []])
        assert many[0].tiles == FusedTuner(CFG).tune(a).tiles
        assert many[1].tiles == FusedTuner(CFG).tune(b).tiles
        assert many[2].tiles == {}
        assert t.dispatch_count == 1          # the pair was one dispatch

    def test_fused_surrogate_matches_surrogate_oracle_argmin(self, tmp_path):
        from repro.measure.db import MeasureDB, make_key
        from repro.surrogate import SurrogateOracle, train_from_db

        db = MeasureDB(str(tmp_path / "m.jsonl"))
        for s in SITES:
            if s.kind != "matmul":
                continue
            for t0 in (16, 32):
                db.put(make_key(s.key(), (t0, 128, 128), "fix"),
                       1e-3 * (1 + t0) * (1 + s.m / 64))
        db.put(make_key(SITES[2].key(), (64, 128, 1), "fix"), 2e-3)
        db.put(make_key(SITES[3].key(), (32, 1, 1), "fix"), 3e-3)
        db.close()
        model = train_from_db(str(tmp_path / "m.jsonl"), min_pairs=4,
                              hidden=(16,), ensemble=2, steps=40)
        assert model is not None
        oracle = SurrogateOracle(CFG, model, seed=0)
        ref = brute_force_labels(oracle, SITES)
        fused = FusedTuner(CFG, surrogate=model).actions(SITES)
        np.testing.assert_array_equal(fused, np.asarray(ref))


# ---------------------------------------------------------------------------
# AgentBatch: spy-asserted bitwise parity for every registry agent
# ---------------------------------------------------------------------------

def _fitted(name: str):
    agent = make_agent(name, CFG, seed=0)
    env = CostModelEnv(CFG, seed=0)
    kw = {"total_steps": 48} if name == "ppo" else {}
    agent.fit(SITES, env, **kw)
    return agent


@pytest.mark.parametrize("name", AGENT_NAMES)
def test_batched_act_bitwise_equals_sequential_act(name):
    """Concatenate two requests through one AgentBatch forward: each
    request's actions are bitwise what a solo act() returns, and a spy
    proves the batched path ran ONE forward (batch-unsafe agents run one
    per request by design)."""
    agent = _fitted(name)
    a, b = SITES[:2], SITES[2:]
    expect = [np.asarray(agent.act(a, sample=False)),
              np.asarray(agent.act(b, sample=False))]

    calls = []
    orig_act = agent.act
    agent.act = lambda *args, **kw: (calls.append("act"),
                                     orig_act(*args, **kw))[1]
    if hasattr(agent, "act_bucketed"):
        orig_bucketed = agent.act_bucketed
        agent.act_bucketed = lambda *args, **kw: (
            calls.append("bucketed"), orig_bucketed(*args, **kw))[1]
    batch = AgentBatch(agent)
    got = batch.act_many([a, b])

    np.testing.assert_array_equal(got[0], expect[0])
    np.testing.assert_array_equal(got[1], expect[1])
    if batch.coalesced:
        assert len(calls) == 1               # one forward for the batch
        if name == "ppo":
            assert calls == ["bucketed"]     # padded-bucket jit reuse
    else:
        assert calls == ["act", "act"]       # per-request by design
    assert batch.requests == 2 and batch.sites == len(SITES)


def test_ppo_act_bucketed_padding_is_bitwise_invisible():
    agent = _fitted("ppo")
    plain = np.asarray(agent.act(SITES, sample=False))
    padded = agent.act_bucketed(SITES, bucket=16)
    np.testing.assert_array_equal(plain, padded)


# ---------------------------------------------------------------------------
# Server: admission, batching, typed errors, health
# ---------------------------------------------------------------------------

def test_concurrent_sessions_one_fused_dispatch_and_isolation():
    """Concurrent model-oracle tunes coalesce into one batch = one fused
    device dispatch; each session gets exactly its own program."""
    lists = [_sites(f"c{i}", n=2 + i % 2) for i in range(4)]
    with TuningService(CFG, serving={"max_wait_ms": 50.0},
                       metrics=False) as svc:
        sessions = [svc.open_session(agent="brute", oracle="model")
                    for _ in lists]
        for s, ss in zip(sessions, lists):
            s.fit(ss)
        futs = [s.tune_async(ss) for s, ss in zip(sessions, lists)]
        progs = [f.result(timeout=120) for f in futs]
        st = svc.server.stats()
    env = CostModelEnv(CFG, seed=0)
    space = ActionSpace(CFG)
    for ss, prog in zip(lists, progs):
        assert set(prog.tiles) == {x.key() for x in ss}
        for x, a in zip(ss, brute_force_labels(env, ss)):
            assert prog.tiles[x.key()] == space.tiles(x.kind, a)
    assert st["serving_requests_total"] == 4
    assert st["serving_batches_total"] == 1
    assert st["serving_fused_dispatches_total"] == 1
    assert st["serving_fused_traces_total"] == 1


def test_fifo_resolution_within_an_slo_class():
    """Requests sharing one SLO class resolve strictly in admission
    order within the flushed batch."""
    order = []
    with TuningService(CFG, serving={"max_wait_ms": 30.0},
                       metrics=False) as svc:
        sessions = [svc.open_session(agent="brute", oracle="model")
                    for _ in range(4)]
        lists = [_sites(f"f{i}") for i in range(4)]
        for s, ss in zip(sessions, lists):
            s.fit(ss)
        futs = []
        for i, (s, ss) in enumerate(zip(sessions, lists)):
            f = s.tune_async(ss)
            f.add_done_callback(lambda _f, i=i: order.append(i))
            futs.append(f)
        for f in futs:
            f.result(timeout=120)
    assert order == [0, 1, 2, 3]


def test_queue_full_sheds_with_typed_error_and_degrades_health():
    with TuningService(CFG, serving={"max_queue": 1, "max_wait_ms": 150.0,
                                     "slo_ms": 10_000.0},
                       metrics=False) as svc:
        s = svc.open_session(agent="brute", oracle="model")
        s.fit(SITES[:1])
        assert svc.server.health() == "ok"
        f1 = s.tune_async(SITES[:1])
        with pytest.raises(QueueFull, match="max_queue"):
            s.tune_async(SITES[:1])
        assert svc.server.health() == "degraded"     # breach in window
        assert svc.health() == "degraded"            # service agrees
        assert f1.result(timeout=120) is not None    # queued one survives
        assert svc.server.stats()["serving_shed_total"] == 1
    assert svc.server.health() == "down"             # closed


def test_expired_budget_fails_future_with_deadline_exceeded():
    with TuningService(CFG, serving=True, metrics=False) as svc:
        s = svc.open_session(agent="brute", oracle="model")
        s.fit(SITES[:1])
        fut = s.tune_async(SITES[:1], slo_ms=1e-4)   # expired on arrival
        with pytest.raises(DeadlineExceeded, match="budget"):
            fut.result(timeout=120)
        st = svc.server.stats()
        assert st["serving_deadline_misses_total"] == 1
        assert svc.server.health() == "degraded"
        # the session survives its failed request — and close() drains
        # the dead future without re-raising
        assert s.tune(SITES[:1]).tiles


def test_health_recovers_after_breach_window():
    with TuningService(CFG, serving={"max_queue": 1, "max_wait_ms": 1.0,
                                     "health_window_s": 0.2},
                       metrics=False) as svc:
        s = svc.open_session(agent="brute", oracle="model")
        s.fit(SITES[:1])
        f1 = s.tune_async(SITES[:1])
        try:
            s.tune_async(SITES[:1])
            shed = False
        except QueueFull:
            shed = True
        if shed:                      # breach is fresh: inside the window
            assert svc.server.health() == "degraded"
        f1.result(timeout=120)
        time.sleep(0.25)              # ...and expired once it passes
        assert svc.server.health() == "ok"


def test_submit_after_close_raises_and_slo_needs_serving():
    svc = TuningService(CFG, serving=True, metrics=False)
    s = svc.open_session(agent="brute", oracle="model")
    svc.close()
    with pytest.raises(ServingError, match="closed"):
        svc.server.submit(s, SITES[:1])
    with TuningService(CFG, metrics=False) as plain:
        p = plain.open_session(agent="brute", oracle="model")
        with pytest.raises(ValueError, match="serving"):
            p.tune_async(SITES[:1], slo_ms=5.0)


def test_empty_sites_resolve_immediately():
    with TuningService(CFG, serving=True, metrics=False) as svc:
        s = svc.open_session(agent="brute", oracle="model")
        assert s.tune([]).tiles == {}
        assert svc.server.stats()["serving_batches_total"] == 0


def test_warm_store_tier_answers_at_admission(tmp_path):
    with TuningService(CFG, serving=True, metrics=False,
                       program_store=str(tmp_path / "p.jsonl")) as svc:
        s = svc.open_session(agent="brute", oracle="model")
        s.fit(SITES[:2])
        p1 = s.tune(SITES[:2])               # miss: through the batcher
        p2 = s.tune(SITES[:2])               # hit: resolved at admission
        assert p2.tiles == p1.tiles
        st = svc.server.stats()
        assert st["serving_store_hits_total"] == 1
        assert st["serving_batches_total"] == 1      # hit never queued
        sst = s.stats()
        assert sst["session_store_hits_total"] == 1
        assert sst["session_store_misses_total"] == 1


def test_mixed_agent_routes_interleaved_under_load():
    """Fused (brute/model) and coalesced-forward (ppo) sessions submit
    concurrently from threads: every result is isolated per session and
    bitwise equal to that session's own unbatched decision."""
    with TuningService(CFG, serving={"max_wait_ms": 30.0},
                       metrics=False) as svc:
        brutes = [(svc.open_session(agent="brute", oracle="model"),
                   _sites(f"mb{i}")) for i in range(2)]
        ppos = [(svc.open_session(agent="ppo", oracle="model"),
                 _sites(f"mp{i}")) for i in range(2)]
        for s, ss in brutes + ppos:
            kw = {"total_steps": 48} if s.agent.name == "ppo" else {}
            s.fit(ss, **kw)
        space = ActionSpace(CFG)
        expect = {}
        for s, ss in brutes + ppos:
            acts = np.asarray(s.agent.act(ss, sample=False))
            expect[s.name] = {x.key(): space.tiles(x.kind, a)
                              for x, a in zip(ss, acts)}

        results, errors = {}, []

        def worker(sess, ss):
            try:
                results[sess.name] = sess.tune(ss)
            except Exception as e:           # pragma: no cover - surfaced
                errors.append(e)

        threads = [threading.Thread(target=worker, args=(s, ss))
                   for s, ss in brutes + ppos]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=120)
        st = svc.server.stats()
    assert not errors
    for s, _ in brutes + ppos:
        assert results[s.name].tiles == expect[s.name]
    assert st["serving_requests_total"] == 4
    assert st["serving_fused_dispatches_total"] >= 1
    assert st["serving_batched_requests_total"] >= 1


def test_serving_config_spellings_and_stats_keys():
    with TuningService(CFG, serving=ServingConfig(slo_ms=250.0),
                       metrics=False) as svc:
        assert isinstance(svc.server, Server)
        assert svc.server.cfg.slo_ms == 250.0
        s = svc.open_session(agent="brute", oracle="model")
        s.fit(SITES[:1]).tune(SITES[:1])
        st = svc.server.stats()
        for k in ("serving_requests_total", "serving_queue_depth",
                  "serving_shed_total", "serving_deadline_misses_total",
                  "serving_batches_total", "serving_store_hits_total",
                  "serving_queue_wait_seconds_total",
                  "serving_batch_requests_hist", "serving_tune_p50_ms",
                  "serving_tune_p99_ms", "serving_fused_dispatches_total",
                  "health"):
            assert k in st, k
        assert st["serving_tune_p99_ms"] >= st["serving_tune_p50_ms"] >= 0
        assert "serving" in svc.stats()
    assert svc.stats()["serving"]["health"] == "down"


def test_instrument_serving_lands_series_in_registry():
    from repro.obs import MetricsRegistry
    reg = MetricsRegistry()
    with TuningService(CFG, serving=True, metrics=reg) as svc:
        s = svc.open_session(agent="brute", oracle="model")
        s.fit(SITES[:2]).tune(SITES[:2])
        snap = reg.snapshot()
    assert snap["serving_requests_total"] == 1.0
    assert snap["serving_batches_total"] == 1.0
    assert snap["serving_fused_dispatches_total"] == 1.0
    assert snap["serving_tune_seconds"]["count"] == 1
    assert snap["serving_queue_wait_seconds"]["count"] == 1
    assert snap["serving_batch_requests"]["count"] == 1
