"""End-to-end behaviour tests for the paper's system: the full
extract -> train-RL -> tune -> inject -> run pipeline, plus the training
and serving drivers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.neurovec import NeuroVecConfig
from repro.core import dataset
from repro.core.agents import PPOAgent, brute_force_action
from repro.core.env import CostModelEnv
from repro.core.extractor import extract_arch_sites, extract_sites
from repro.core.vectorizer import TileProgram, inject, program_speedup, tune
from repro.models import compute
from repro.models.lm import build_model

NV = NeuroVecConfig(train_batch=256, sgd_minibatch=64, ppo_epochs=4)


def test_end_to_end_vectorization_pipeline():
    """The paper's Fig. 3 loop: extract loops -> embed -> RL tune ->
    inject pragmas -> the tuned program is faster under the cost model and
    numerically identical when executed."""
    env = CostModelEnv(NV)
    # 1. extract kernel sites from a real model step ("loop extractor")
    sites = extract_arch_sites("stablelm_3b", batch=4, seq=512)
    assert sites, "extractor found no tunable sites"

    # 2. train the agent on the synthetic corpus (paper §3.2)
    corpus = dataset.generate(400, seed=0, base=sites)
    agent = PPOAgent(NV, lr=5e-4, seed=0)
    agent.train(corpus, env, total_steps=2500)

    # 3. tune the extracted sites (greedy inference — paper §4.2)
    prog = tune(sites, agent, env.space)
    sp = program_speedup(prog, sites)
    assert sp > 1.0, f"tuned program slower than baseline: {sp}"

    # 4. inject: model math must be unchanged by the tiles
    cfg = get_config("stablelm_3b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = {"tokens": jnp.ones((2, 16), jnp.int32),
             "targets": jnp.ones((2, 16), jnp.int32)}
    loss_xla, _ = model.train_loss(params, batch)
    small_sites = extract_sites(
        lambda p, b: model.train_loss(p, b)[0],
        jax.eval_shape(model.init, jax.random.PRNGKey(0)), batch)
    small_prog = tune(small_sites, agent, env.space)
    with inject(small_prog, interpret=True):
        loss_tuned, _ = model.train_loss(params, batch)
    np.testing.assert_allclose(float(loss_tuned), float(loss_xla),
                               rtol=5e-3)


def test_rl_close_to_brute_force():
    """Paper §4: RL within a few percent of brute force on held-out sites
    (we assert within 60% extra cost at this tiny training budget; the
    benchmark harness trains longer and reports the headline gap)."""
    env = CostModelEnv(NV)
    train = dataset.generate(600, seed=7)
    test = dataset.generate(40, seed=8)
    agent = PPOAgent(NV, lr=5e-4, seed=0)
    agent.train(train, env, total_steps=4000)
    a_rl = agent.act(test, sample=False)
    t_rl = 0.0
    for s, a in zip(test, a_rl):
        c = env.cost(s, a)
        t_rl += c if c is not None else 10 * brute_force_action(env, s)[1]
    t_bf = sum(brute_force_action(env, s)[1] for s in test)
    assert t_rl <= 1.6 * t_bf, (t_rl, t_bf)


def test_train_driver_runs_and_loss_decreases(tmp_path):
    from repro.launch import train as train_mod
    losses = train_mod.main(["--arch", "stablelm_3b", "--steps", "30",
                             "--batch", "8", "--seq", "64",
                             "--lr", "1e-3"])
    assert losses[-1] < losses[0] - 0.3, (losses[0], losses[-1])


def test_serve_driver_generates():
    from repro.launch import serve as serve_mod
    seq = serve_mod.main(["--arch", "stablelm_3b", "--batch", "2",
                          "--prompt-len", "8", "--gen", "4"])
    assert seq.shape == (2, 4)
    assert bool(jnp.all(seq >= 0))


def test_serve_driver_ssm_and_encdec():
    from repro.launch import serve as serve_mod
    for arch in ("xlstm_1_3b", "seamless_m4t_medium", "jamba_v0_1_52b"):
        seq = serve_mod.main(["--arch", arch, "--batch", "2",
                              "--prompt-len", "8", "--gen", "3"])
        assert seq.shape == (2, 3), arch
