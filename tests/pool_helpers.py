"""Worker-side runner factories for the transport tests.

The pool transport's ``factory="module:attr"`` seam imports these *inside
real worker subprocesses* (the pool propagates ``sys.path``, which
includes this directory under pytest), so the conformance suite can run
deterministic — or deliberately crashing — runners through the genuine
pipe protocol without paying a jax import per worker.

Determinism across processes matters: values derive from ``zlib.crc32``
of the DB key material (``hash()`` is salted per process and would break
the in-process-vs-pool parity assertions).
"""
from __future__ import annotations

import os
import time
import zlib

import numpy as np


def fake_value(site_key: str, tiles) -> float:
    """Deterministic pseudo-seconds for one (site, tiles) pair."""
    text = f"{site_key}|{tuple(int(x) for x in tiles)}"
    return 1e-4 * (1 + zlib.crc32(text.encode()) % 1000)


class FakeRunner:
    """Deterministic batched runner with a stable backend fingerprint."""

    backend_key = "fake-backend"

    def __init__(self, delay: float = 0.0):
        self.delay = delay
        self.calls = 0
        self.pairs = 0

    def __call__(self, sites, tiles):
        self.calls += 1
        self.pairs += len(sites)
        if self.delay:
            time.sleep(self.delay)
        return np.array([fake_value(s.key(), t)
                         for s, t in zip(sites, tiles)], np.float64)


class _BoomRunner(FakeRunner):
    """Kills the whole worker process on the marked site.

    ``transient=True`` leaves a sentinel file (``REPRO_TEST_BOOM_FILE``)
    behind first, so the *respawned* worker measures the pair normally —
    the requeue-recovers path.  ``transient=False`` dies every time — the
    fail-closed-after-K-attempts path.
    """

    def __init__(self, transient: bool):
        super().__init__()
        self.transient = transient

    def __call__(self, sites, tiles):
        sentinel = os.environ.get("REPRO_TEST_BOOM_FILE", "")
        for s in sites:
            if s.site == "boom" and not (self.transient and sentinel
                                         and os.path.exists(sentinel)):
                if self.transient and sentinel:
                    with open(sentinel, "w") as f:
                        f.write("died once\n")
                os._exit(3)         # simulated hard worker death
        return super().__call__(sites, tiles)


class FailRunner(FakeRunner):
    """Fails (``inf``) on any site named ``"fail"``, measures the rest."""

    def __call__(self, sites, tiles):
        out = super().__call__(sites, tiles)
        return np.where([s.site == "fail" for s in sites], np.inf, out)


class RaisingRunner(FakeRunner):
    """Raises (instead of returning inf) on any site named ``"boom"`` —
    the misbehaving-custom-runner case the worker must survive."""

    def __call__(self, sites, tiles):
        if any(s.site == "boom" for s in sites):
            raise RuntimeError("simulated runner bug")
        return super().__call__(sites, tiles)


class WedgingRunner(FakeRunner):
    """Hangs forever on any site named ``"wedge"`` — the stuck-kernel
    case ``job_timeout`` exists for."""

    def __call__(self, sites, tiles):
        if any(s.site == "wedge" for s in sites):
            time.sleep(3600)
        return super().__call__(sites, tiles)


def deterministic():
    return FakeRunner()


def failing():
    return FailRunner()


def raising():
    return RaisingRunner()


def wedging():
    return WedgingRunner()


def slow():
    return FakeRunner(delay=0.3)


def boom_once():
    return _BoomRunner(transient=True)


def boom_always():
    return _BoomRunner(transient=False)


# -- chaos factories (PR 6) -------------------------------------------------

def chaos():
    """A :class:`repro.measure.faults.ChaosRunner` around a configurable
    base factory — the worker-side half of the chaos conformance runs.

    ``REPRO_CHAOS_BASE``  base factory (default the deterministic one),
    ``REPRO_CHAOS_SEED``  fault-schedule seed,
    ``REPRO_CHAOS_STATE`` one-shot sentinel directory (required).
    """
    import importlib

    from repro.measure.faults import ChaosRunner, FaultSchedule

    base_spec = os.environ.get("REPRO_CHAOS_BASE",
                               "pool_helpers:deterministic")
    mod, _, attr = base_spec.partition(":")
    base = getattr(importlib.import_module(mod), attr)()
    return ChaosRunner(base,
                       FaultSchedule(int(os.environ.get("REPRO_CHAOS_SEED",
                                                        "0"))),
                       os.environ["REPRO_CHAOS_STATE"], hang_s=3600.0)


class _TornOnceRunner(FakeRunner):
    """Tears the protocol pipe (and dies) the first time it sees the site
    named ``"torn"`` — sentinel ``REPRO_TEST_TORN_FILE`` — then measures
    it normally on the respawned worker: the torn-result-frame analogue
    of ``boom_once``."""

    def __call__(self, sites, tiles):
        from repro.measure.faults import _tear_frame

        sentinel = os.environ.get("REPRO_TEST_TORN_FILE", "")
        for s in sites:
            if s.site == "torn" and sentinel and not os.path.exists(sentinel):
                with open(sentinel, "w") as f:
                    f.write("tore once\n")
                _tear_frame(int(os.environ["REPRO_WORKER_PROTO_FD"]), 1)
                os._exit(3)
        return super().__call__(sites, tiles)


def torn_once():
    return _TornOnceRunner()


class _DieOnJobRunner(FakeRunner):
    """Dies on the first job it receives — setup for the crash-loop
    backoff test (the respawn then fails via ``spawn_flaky``)."""

    def __call__(self, sites, tiles):
        os._exit(3)


def spawn_flaky():
    """First spawn hands out a runner that dies on any job; every later
    spawn fails *during the handshake* — driving the dispatcher through
    its respawn-backoff loop until ``_MAX_SPAWN_FAILURES``.  Sentinel:
    ``REPRO_TEST_SPAWN_FILE``."""
    sentinel = os.environ["REPRO_TEST_SPAWN_FILE"]
    try:
        fd = os.open(sentinel, os.O_CREAT | os.O_EXCL | os.O_WRONLY)
    except FileExistsError:
        os._exit(2)                 # spawn failure: no ready handshake
    os.close(fd)
    return _DieOnJobRunner()
