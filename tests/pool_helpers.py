"""Worker-side runner factories for the transport tests.

The pool transport's ``factory="module:attr"`` seam imports these *inside
real worker subprocesses* (the pool propagates ``sys.path``, which
includes this directory under pytest), so the conformance suite can run
deterministic — or deliberately crashing — runners through the genuine
pipe protocol without paying a jax import per worker.

Determinism across processes matters: values derive from ``zlib.crc32``
of the DB key material (``hash()`` is salted per process and would break
the in-process-vs-pool parity assertions).
"""
from __future__ import annotations

import os
import time
import zlib

import numpy as np


def fake_value(site_key: str, tiles) -> float:
    """Deterministic pseudo-seconds for one (site, tiles) pair."""
    text = f"{site_key}|{tuple(int(x) for x in tiles)}"
    return 1e-4 * (1 + zlib.crc32(text.encode()) % 1000)


class FakeRunner:
    """Deterministic batched runner with a stable backend fingerprint."""

    backend_key = "fake-backend"

    def __init__(self, delay: float = 0.0):
        self.delay = delay
        self.calls = 0
        self.pairs = 0

    def __call__(self, sites, tiles):
        self.calls += 1
        self.pairs += len(sites)
        if self.delay:
            time.sleep(self.delay)
        return np.array([fake_value(s.key(), t)
                         for s, t in zip(sites, tiles)], np.float64)


class _BoomRunner(FakeRunner):
    """Kills the whole worker process on the marked site.

    ``transient=True`` leaves a sentinel file (``REPRO_TEST_BOOM_FILE``)
    behind first, so the *respawned* worker measures the pair normally —
    the requeue-recovers path.  ``transient=False`` dies every time — the
    fail-closed-after-K-attempts path.
    """

    def __init__(self, transient: bool):
        super().__init__()
        self.transient = transient

    def __call__(self, sites, tiles):
        sentinel = os.environ.get("REPRO_TEST_BOOM_FILE", "")
        for s in sites:
            if s.site == "boom" and not (self.transient and sentinel
                                         and os.path.exists(sentinel)):
                if self.transient and sentinel:
                    with open(sentinel, "w") as f:
                        f.write("died once\n")
                os._exit(3)         # simulated hard worker death
        return super().__call__(sites, tiles)


class FailRunner(FakeRunner):
    """Fails (``inf``) on any site named ``"fail"``, measures the rest."""

    def __call__(self, sites, tiles):
        out = super().__call__(sites, tiles)
        return np.where([s.site == "fail" for s in sites], np.inf, out)


class RaisingRunner(FakeRunner):
    """Raises (instead of returning inf) on any site named ``"boom"`` —
    the misbehaving-custom-runner case the worker must survive."""

    def __call__(self, sites, tiles):
        if any(s.site == "boom" for s in sites):
            raise RuntimeError("simulated runner bug")
        return super().__call__(sites, tiles)


class WedgingRunner(FakeRunner):
    """Hangs forever on any site named ``"wedge"`` — the stuck-kernel
    case ``job_timeout`` exists for."""

    def __call__(self, sites, tiles):
        if any(s.site == "wedge" for s in sites):
            time.sleep(3600)
        return super().__call__(sites, tiles)


def deterministic():
    return FakeRunner()


def failing():
    return FailRunner()


def raising():
    return RaisingRunner()


def wedging():
    return WedgingRunner()


def slow():
    return FakeRunner(delay=0.3)


def boom_once():
    return _BoomRunner(transient=True)


def boom_always():
    return _BoomRunner(transient=False)
