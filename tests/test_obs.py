"""The observability substrate (PR 8): registry semantics under
concurrency, histogram bucket math, span nesting + exception safety,
trace round-trips, the Prometheus surfaces, and — the load-bearing
guarantee — spy-proven "instrumentation changes no return values"
parity on the live tuning stack."""
import json
import threading
import urllib.request

import numpy as np
import pytest

from repro.configs.neurovec import NeuroVecConfig
from repro.obs import (DEFAULT_LATENCY_BUCKETS, NULL_TRACER, MetricsRegistry,
                       MetricsServer, Tracer, get_registry, read_trace,
                       resolve_obs, to_chrome_trace)
from repro.obs.instrument import (instrument_oracle_stack,
                                  instrument_program_store,
                                  instrument_transport)


def small_cfg() -> NeuroVecConfig:
    return NeuroVecConfig(
        bm_choices=(16, 32), bn_choices=(128,), bk_choices=(128,),
        bq_choices=(64,), bkv_choices=(128,), chunk_choices=(32,),
        train_batch=32, sgd_minibatch=16, ppo_epochs=2)


def sites():
    from repro.models.compute import KernelSite
    return [KernelSite(site="t.mm", kind="matmul", m=32, n=128, k=128),
            KernelSite(site="t.attn", kind="attention", m=64, n=32, k=64,
                       batch=2, causal=True)]


# -- registry ----------------------------------------------------------------
class TestRegistry:
    def test_counter_gauge_basics(self):
        r = MetricsRegistry()
        c = r.counter("x_total", "help text")
        c.inc()
        c.inc(2.5)
        assert c.value == 3.5
        with pytest.raises(ValueError):
            c.inc(-1)
        g = r.gauge("depth")
        g.set(5)
        g.dec(2)
        assert g.value == 3.0

    def test_get_or_create_returns_same_family(self):
        r = MetricsRegistry()
        assert r.counter("a_total") is r.counter("a_total")
        with pytest.raises(ValueError):        # kind conflict
            r.gauge("a_total")
        with pytest.raises(ValueError):        # labelnames conflict
            r.counter("a_total", labelnames=("x",))
        with pytest.raises(ValueError):        # invalid name
            r.counter("9bad")

    def test_labels(self):
        r = MetricsRegistry()
        c = r.counter("t_total", labelnames=("session",))
        c.labels(session="s1").inc(2)
        c.labels(session="s2").inc(3)
        snap = r.snapshot()
        assert snap['t_total{session="s1"}'] == 2.0
        assert snap['t_total{session="s2"}'] == 3.0
        with pytest.raises(ValueError):        # wrong label set
            c.labels(nope="x")
        with pytest.raises(ValueError):        # unlabelled use of labelled
            c.inc()

    def test_thread_safety_under_concurrent_sessions(self):
        """N threads hammering one counter/histogram lose no updates."""
        r = MetricsRegistry()
        c = r.counter("hits_total")
        h = r.histogram("lat_seconds", buckets=(0.5, 1.0))
        n_threads, per = 8, 500

        def work():
            for i in range(per):
                c.inc()
                h.observe(0.25 if i % 2 else 0.75)

        ts = [threading.Thread(target=work) for _ in range(n_threads)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        assert c.value == n_threads * per
        v = h.value
        assert v["count"] == n_threads * per
        assert v["buckets"]["0.5"] == n_threads * per // 2
        assert v["buckets"]["+Inf"] == n_threads * per

    def test_collector_runs_before_snapshot(self):
        r = MetricsRegistry()
        g = r.gauge("synced")
        state = {"v": 1.0}
        fn = r.register_collector(lambda: g.set(state["v"]))
        assert r.snapshot()["synced"] == 1.0
        state["v"] = 7.0
        assert r.snapshot()["synced"] == 7.0
        r.unregister_collector(fn)
        state["v"] = 9.0
        assert r.snapshot()["synced"] == 7.0


class TestHistogram:
    def test_bucket_correctness_le_semantics(self):
        """v <= le lands in that bucket (Prometheus), cumulative counts."""
        r = MetricsRegistry()
        h = r.histogram("h_seconds", buckets=(0.1, 1.0, 10.0))
        for v in (0.05, 0.1, 0.5, 1.0, 5.0, 100.0):
            h.observe(v)
        v = h.value
        # boundary values land in their own bucket: 0.1 <= 0.1
        assert v["buckets"]["0.1"] == 2
        assert v["buckets"]["1.0"] == 4
        assert v["buckets"]["10.0"] == 5
        assert v["buckets"]["+Inf"] == 6
        assert v["count"] == 6
        assert v["sum"] == pytest.approx(106.65)

    def test_default_latency_buckets_log_spaced(self):
        b = DEFAULT_LATENCY_BUCKETS
        assert list(b) == sorted(b)
        assert b[0] == pytest.approx(1e-6)
        assert b[-1] == pytest.approx(1e2)
        # two per decade
        for lo, hi in zip(b, b[2:]):
            assert hi / lo == pytest.approx(10.0, rel=1e-6)

    def test_bad_buckets_rejected(self):
        r = MetricsRegistry()
        with pytest.raises(ValueError):
            r.histogram("bad", buckets=(1.0, 0.5))
        with pytest.raises(ValueError):
            r.histogram("bad2", buckets=(1.0, 1.0))

    def test_wrong_verbs_raise(self):
        r = MetricsRegistry()
        with pytest.raises(TypeError):
            r.counter("c_total").observe(1)
        with pytest.raises(TypeError):
            r.gauge("g").observe(1)
        with pytest.raises(TypeError):
            r.histogram("h2").inc()


class TestProm:
    def test_render_prom_shapes(self):
        r = MetricsRegistry()
        r.counter("x_total", "things").inc(3)
        r.gauge("q_depth").set(2)
        h = r.histogram("lat_seconds", buckets=(0.5,))
        h.observe(0.1)
        h.observe(0.9)
        text = r.render_prom()
        assert "# TYPE x_total counter" in text
        assert "x_total 3.0" in text
        assert "# HELP x_total things" in text
        assert 'lat_seconds_bucket{le="0.5"} 1' in text
        assert 'lat_seconds_bucket{le="+Inf"} 2' in text
        assert "lat_seconds_count 2" in text

    def test_http_exporter_serves_registry(self):
        r = MetricsRegistry()
        r.counter("served_total").inc(5)
        with MetricsServer(port=0, registry=r) as srv:
            body = urllib.request.urlopen(
                f"http://127.0.0.1:{srv.port}/metrics", timeout=10
            ).read().decode()
            assert "served_total 5.0" in body
            with pytest.raises(urllib.error.HTTPError):
                urllib.request.urlopen(
                    f"http://127.0.0.1:{srv.port}/nope", timeout=10)


# -- tracing -----------------------------------------------------------------
class TestTrace:
    def test_span_nesting_and_parent_links(self, tmp_path):
        p = str(tmp_path / "t.jsonl")
        tr = Tracer(p)
        with tr.span("outer") as outer:
            with tr.span("inner") as inner:
                assert inner.parent == outer.id
            tr.event("ping", k=1)
        tr.close()
        recs = read_trace(p)
        by = {r["name"]: r for r in recs}
        assert by["inner"]["parent"] == by["outer"]["id"]
        assert by["outer"]["parent"] is None
        assert by["ping"]["parent"] == by["outer"]["id"]
        assert by["ping"]["type"] == "event"
        # inner closed first -> written first; duration nests inside
        assert by["inner"]["dur"] <= by["outer"]["dur"]
        assert by["inner"]["ts"] >= by["outer"]["ts"]

    def test_span_closes_on_raise_and_records_error(self, tmp_path):
        p = str(tmp_path / "t.jsonl")
        tr = Tracer(p)
        with pytest.raises(RuntimeError):
            with tr.span("boom"):
                raise RuntimeError("kaput")
        with tr.span("after") as sp:
            # the raised span must be off the stack: no phantom parent
            assert sp.parent is None
        tr.close()
        by = {r["name"]: r for r in read_trace(p)}
        assert by["boom"]["error"] == "RuntimeError: kaput"
        assert "error" not in by["after"]

    def test_detached_root_and_explicit_parent(self, tmp_path):
        p = str(tmp_path / "t.jsonl")
        tr = Tracer(p)
        root = tr.begin("session", detached=True)
        with tr.span("top") as sp:
            assert sp.parent is None       # detached root not on the stack
        with tr.span("child", parent=root) as sp:
            assert sp.parent == root.id
        root.end()
        tr.close()
        assert len(read_trace(p)) == 3

    def test_cross_thread_spans_do_not_interleave(self, tmp_path):
        p = str(tmp_path / "t.jsonl")
        tr = Tracer(p)
        root = tr.begin("root", detached=True)
        seen = []

        def worker(i):
            with tr.span(f"w{i}", parent=root) as sp:
                seen.append(sp.parent)
        ts = [threading.Thread(target=worker, args=(i,)) for i in range(4)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        root.end()
        tr.close()
        assert seen == [root.id] * 4
        recs = read_trace(p)
        assert len(recs) == 5
        ids = [r["id"] for r in recs]
        assert len(set(ids)) == 5              # unique ids across threads

    def test_chrome_trace_round_trip(self, tmp_path):
        p = str(tmp_path / "t.jsonl")
        tr = Tracer(p)
        with tr.span("tune", n_sites=3):
            tr.event("straggler", z=4.2)
        tr.close()
        out = to_chrome_trace(p)
        evs = out["traceEvents"]
        assert len(evs) == 2
        x = [e for e in evs if e["ph"] == "X"][0]
        i = [e for e in evs if e["ph"] == "i"][0]
        assert x["name"] == "tune" and x["args"]["n_sites"] == 3
        assert x["dur"] >= 0 and x["ts"] > 0          # microseconds
        assert i["name"] == "straggler"
        assert i["args"]["parent_id"] == x["args"]["span_id"]
        json.dumps(out)                               # serializable

    def test_read_trace_skips_corrupt_lines(self, tmp_path):
        p = str(tmp_path / "t.jsonl")
        tr = Tracer(p)
        tr.span("ok").end()
        tr.close()
        with open(p, "a") as f:
            f.write("{torn json\n\n[1,2,3]\n")
        recs = read_trace(p)
        assert [r["name"] for r in recs] == ["ok"]

    def test_null_tracer_is_inert(self):
        with NULL_TRACER.span("x") as sp:
            sp.set(a=1)
        NULL_TRACER.event("y")
        assert NULL_TRACER.n_spans == 0

    def test_resolve_obs(self, tmp_path):
        r1, t1, own1 = resolve_obs(None, None)
        assert r1 is get_registry() and t1 is NULL_TRACER and not own1
        r2, _, _ = resolve_obs(False, None)
        assert r2 is not get_registry()
        p = str(tmp_path / "t.jsonl")
        r3, t3, own3 = resolve_obs(MetricsRegistry(), p)
        assert own3 and t3.path == p
        t3.close()
        with pytest.raises(TypeError):
            resolve_obs(42, None)
        with pytest.raises(TypeError):
            resolve_obs(None, 42)


# -- instrumentation parity ---------------------------------------------------
class _SpyRunner:
    """Deterministic batched runner: value is a pure function of inputs."""

    backend_key = "spy:test"

    def __init__(self):
        self.calls = 0

    def __call__(self, sites_, tiles):
        self.calls += 1
        return np.array([1e-3 * (i + 1) + 1e-5 * int(t[0])
                         for i, t in enumerate(np.asarray(tiles))],
                        np.float64)


class TestInstrumentationParity:
    def test_measured_env_returns_unchanged(self):
        """Byte-identical MeasuredEnv results with and without obs."""
        from repro.core.env import MeasuredEnv
        from repro.measure.transport import (InProcessTransport,
                                             TransportMeasureFn)
        cfg = small_cfg()
        ss = sites()
        tiles = np.array([[16, 128, 128], [64, 128, 32]], np.int64)

        def run(instrumented: bool):
            env = MeasuredEnv(cfg, measure_fn=TransportMeasureFn(
                InProcessTransport(_SpyRunner())), seed=0)
            if instrumented:
                h = instrument_oracle_stack(env, MetricsRegistry(),
                                            NULL_TRACER)
            out = env._measured_costs(ss, tiles)
            rb = env.rewards_batch(ss, np.zeros((2, 3), np.int64))
            if instrumented:
                h.close()
            return out, rb

        (c0, r0), (c1, r1) = run(False), run(True)
        np.testing.assert_array_equal(c0, c1)
        np.testing.assert_array_equal(r0, r1)

    def test_transport_submit_drain_unchanged(self):
        from repro.measure.transport import InProcessTransport
        ss = [sites()[0]] * 2             # same (site, tile) key twice
        tiles = np.array([[16, 128, 128]] * 2, np.int64)

        t_plain = InProcessTransport(_SpyRunner())
        t_obs = InProcessTransport(_SpyRunner())
        reg = MetricsRegistry()
        h = instrument_transport(t_obs, reg, NULL_TRACER)
        v_plain = [f.result() for f in t_plain.submit(ss, tiles)]
        v_obs = [f.result() for f in t_obs.submit(ss, tiles)]
        t_obs.drain()
        assert v_plain == v_obs
        snap = reg.snapshot()
        assert snap["transport_misses_total"] == 1     # second coalesced
        assert snap["transport_coalesced_total"] == 1
        assert snap["transport_submit_seconds"]["count"] == 1
        assert snap["transport_drain_seconds"]["count"] == 1
        # double instrumentation is a no-op (first wins)
        assert instrument_transport(t_obs, MetricsRegistry()) is None
        h.close()

    def test_tuning_service_parity_and_unified_stats(self, tmp_path):
        """Two services — obs into an isolated registry vs metrics
        disabled — produce identical tiles; stats() carries both the
        legacy and the unified key spellings."""
        from repro.service import TuningService
        cfg = small_cfg()
        ss = sites()

        def run(metrics):
            with TuningService(cfg, transport="inproc",
                               metrics=metrics) as svc:
                s = svc.open_session(agent="brute", oracle="model")
                prog = s.fit(ss).tune(ss)
                st = s.stats()
                svc_st = svc.stats()
            return prog, st, svc_st

        reg = MetricsRegistry()
        p_obs, st, svc_st = run(reg)
        p_off, st_off, _ = run(False)
        assert p_obs.tiles == p_off.tiles
        # unified spellings only: the PR 8 "one release" aliases are gone
        for k in ("session_tunes_total", "session_sites_tuned_total",
                  "session_agent_inferences_total", "session_wall_seconds",
                  "session_fit_seconds_total", "session_tune_seconds_total",
                  "session_inflight_tunes", "session_store_hits_total",
                  "session_store_misses_total", "transport"):
            assert k in st
        for legacy in ("tunes", "sites_tuned", "wall_s", "fit_wall_s",
                       "in_flight_tunes", "store_hits"):
            assert legacy not in st
        assert st["session_tunes_total"] == 1
        assert st["session_fit_seconds_total"] > 0
        assert svc_st["service_sessions_total"] == 1
        assert svc_st["service_sessions_open"] == 1
        assert "sessions_total" not in svc_st
        assert "sessions_open" not in svc_st
        # the same series landed in the registry, labelled by session
        snap = reg.snapshot()
        assert snap['session_tunes_total{session="session-1"}'] == 1.0
        assert snap["service_sessions_total"] == 1.0
        assert snap['session_tune_seconds{session="session-1"}'
                    ]["count"] == 1

    def test_transport_stats_unified_only(self):
        from repro.measure.transport import InProcessTransport
        t = InProcessTransport(_SpyRunner())
        ss = sites()
        t.submit(ss, np.array([[16, 128, 128], [64, 128, 32]], np.int64))
        s = t.stats()
        assert s["transport_misses_total"] == 2
        assert s["transport_hits_total"] == 0
        assert s["transport_hit_ratio"] == 0.0
        assert s["transport_inflight_pairs"] == 0
        for legacy in ("hits", "misses", "coalesced", "timed_pairs",
                       "failed_pairs", "retries", "in_flight", "hit_rate"):
            assert legacy not in s

    def test_program_store_instrumentation(self, tmp_path):
        from repro.artifacts import ProgramStore
        from repro.core.vectorizer import TileProgram
        store = ProgramStore(str(tmp_path / "p.jsonl"))
        reg = MetricsRegistry()
        h = instrument_program_store(store, reg)
        assert store.get("k1") is None
        store.put("k1", TileProgram({"s": (32, 32, 32)}))
        assert store.get("k1") is not None
        snap = reg.snapshot()
        assert snap["store_warm_hits_total"] == 1.0
        assert snap["store_misses_total"] == 1.0
        assert snap["store_programs_count"] == 1.0
        h.close()
        store.close()

    def test_straggler_counter_and_trace_event(self, tmp_path, monkeypatch):
        import repro.ft.monitor as m
        reg = MetricsRegistry()
        p = str(tmp_path / "t.jsonl")
        tr = Tracer(p)
        mon = m.StepMonitor(warmup=2, z_thresh=1.0, metrics=reg, tracer=tr)
        # deterministic clock: two warmup steps, a jittered first
        # post-warmup step (seeds var while z is still short-circuited
        # to 0), one steady step, then a 100x outlier that must flag —
        # and only it
        clock = {"t": 0.0}
        monkeypatch.setattr(m.time, "monotonic", lambda: clock["t"])
        for i, dt in enumerate([0.1, 0.1, 0.2, 0.1, 10.0]):
            mon.start()
            clock["t"] += dt
            mon.stop(i)
        tr.close()
        assert len(mon.events) == 1
        assert reg.snapshot()["straggler_flags_total"] == 1.0
        recs = read_trace(p)
        assert [r["name"] for r in recs] == ["straggler"]
        assert recs[0]["attrs"]["step"] == 4


class TestFacadeObs:
    def test_facade_trace_and_close_idempotent(self, tmp_path):
        from repro.api import NeuroVectorizer
        p = str(tmp_path / "t.jsonl")
        nv = NeuroVectorizer(small_cfg(), agent="baseline",
                             metrics=MetricsRegistry(), trace=p)
        nv.fit(sites())
        nv.tune_sites(sites())
        nv.close()
        nv.close()
        recs = read_trace(p)
        by_name = {}
        for r in recs:
            by_name.setdefault(r["name"], []).append(r)
        sess = by_name["session"][0]
        assert by_name["fit"][0]["parent"] == sess["id"]
        assert by_name["tune"][0]["parent"] == sess["id"]
        assert len(by_name["session"]) == 1    # idempotent close: one end

    def test_facade_metrics_default_off_switch(self):
        from repro.api import NeuroVectorizer
        nv = NeuroVectorizer(small_cfg(), agent="baseline", metrics=False)
        prog = nv.fit(sites()).tune_sites(sites())
        nv.close()
        assert len(prog.tiles) == 2
        assert nv.registry is not get_registry()
