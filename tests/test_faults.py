"""Pool hardening + graceful degradation (PR 6): respawn backoff,
poison-job quarantine across processes, the MeasuredEnv circuit breaker,
health reporting end to end, and SIGTERM draining a live service."""
import os
import signal
import time

import numpy as np
import pytest

from repro.api import NeuroVectorizer, NeuroVecConfig, TileProgram
from repro.core.env import MeasuredEnv
from repro.core.protocols import AsyncOracle, resolve_health
from repro.measure import (InProcessTransport, MeasureDB,
                           WorkerPoolTransport, make_key, respawn_backoff)
from repro.service import TuningService
from repro.models.compute import KernelSite

from pool_helpers import FakeRunner, fake_value

SMALL = NeuroVecConfig(
    bm_choices=(16, 32), bn_choices=(128,), bk_choices=(128,),
    bq_choices=(64,), bkv_choices=(128,), chunk_choices=(32,))

MM = KernelSite(site="f.mm", kind="matmul", m=32, n=128, k=128)
ATTN = KernelSite(site="f.attn", kind="attention", m=64, n=32, k=64,
                  batch=2, causal=True)
SITES = [MM, ATTN]


# ---------------------------------------------------------------------------
# respawn backoff
# ---------------------------------------------------------------------------

def test_respawn_backoff_schedule_properties():
    # deterministic: same (failures, seed) -> same delay
    assert respawn_backoff(1) == respawn_backoff(1)
    assert respawn_backoff(3, seed=7) == respawn_backoff(3, seed=7)
    # jitter bounds: [0.5, 1.0] x the exponential envelope
    for n in range(1, 10):
        d = respawn_backoff(n, base=0.1, cap=30.0, seed=5)
        env = min(30.0, 0.1 * 2.0 ** (n - 1))
        assert 0.5 * env <= d <= env
    # grows (envelope doubles, jitter cannot undo a doubling fully
    # across 2 steps)
    assert respawn_backoff(6) > respawn_backoff(1)
    # cap holds
    assert respawn_backoff(60, base=0.1, cap=30.0) <= 30.0
    # distinct seeds desynchronize
    assert len({respawn_backoff(4, seed=s) for s in range(8)}) > 1
    with pytest.raises(ValueError, match="failures"):
        respawn_backoff(0)


def test_dispatcher_backoff_is_deterministic_under_fake_clock(
        tmp_path, monkeypatch):
    """A crash-looping backend drives the dispatcher through exactly the
    respawn_backoff schedule (observed via the _sleep seam — a fake
    clock), and the stranded job fails closed WITHOUT being quarantined
    (spawn failures are pool trouble, not the job's fault)."""
    monkeypatch.setenv("REPRO_TEST_SPAWN_FILE", str(tmp_path / "spawned"))
    p = str(tmp_path / "m.jsonl")
    t = WorkerPoolTransport(workers=1, db=p,
                            factory="pool_helpers:spawn_flaky",
                            backoff_base=0.05, backoff_seed=42)
    recorded = []
    t._sleep = recorded.append          # fake clock: record, don't wait
    futs = t.submit([MM], np.array([[16, 128, 128]]))
    assert futs[0].result(timeout=60) == float("inf")
    t.drain()
    # sleeps happen for consecutive failures 1.._MAX_SPAWN_FAILURES-1
    assert recorded == [
        respawn_backoff(1, base=0.05, cap=30.0, seed=42),
        respawn_backoff(2, base=0.05, cap=30.0, seed=42)]
    assert t.health() == "down"         # every dispatcher gave up
    t.close()
    db = MeasureDB(p)
    key = make_key(MM.key(), (16, 128, 128), "fake-backend")
    assert db.get(key) is None          # hard failure: nothing persisted
    assert db.n_quarantined == 0


# ---------------------------------------------------------------------------
# poison-job quarantine
# ---------------------------------------------------------------------------

def test_quarantine_persists_and_blocks_reattempts_across_processes(
        tmp_path):
    """A pair that kills workers max_attempts times is quarantined in the
    DB; a second pool over the same path serves inf from the quarantine
    record — zero attempts, zero worker deaths."""
    p = str(tmp_path / "m.jsonl")
    boom = KernelSite(site="boom", kind="matmul", m=64, n=128, k=128)
    with WorkerPoolTransport(workers=2, db=p,
                             factory="pool_helpers:boom_always",
                             max_attempts=2) as t1:
        futs = t1.submit([boom, MM], np.array([[16, 128, 128]] * 2))
        t1.drain()
        assert futs[0].result() == float("inf")
        backend = t1.backend_key
        assert t1.stats()["pool_quarantined_total"] == 1
    key = make_key(boom.key(), (16, 128, 128), backend)
    rec = MeasureDB(p).quarantined(key)
    assert rec is not None and rec["attempts"] == 2
    assert "died" in rec["reason"] or "worker" in rec["reason"]

    # "fresh process": a new pool over the same DB path
    with WorkerPoolTransport(workers=2, db=p,
                             factory="pool_helpers:boom_always",
                             max_attempts=2) as t2:
        futs = t2.submit([boom], np.array([[16, 128, 128]]))
        t2.drain()
        assert futs[0].result() == float("inf")
        st = t2.stats()
    assert st["transport_hits_total"] == 1         # never re-submitted
    assert st["transport_misses_total"] == 0
    assert st["pool_worker_restarts_total"] == 0   # no worker died for it


# ---------------------------------------------------------------------------
# circuit breaker -> cost-model fallback
# ---------------------------------------------------------------------------

def test_breaker_trips_on_raising_hook_and_tune_completes():
    """Transport fully down: the facade still tunes (analytic fallback)
    and reports health() == 'degraded' — the acceptance criterion."""
    t = InProcessTransport(FakeRunner())
    nv = NeuroVectorizer(SMALL, agent="brute", oracle="measured",
                         transport=t)
    assert nv.health() == "ok"
    t.close()                           # backend collapses under the facade
    prog = nv.fit(SITES).tune_sites(SITES)
    assert isinstance(prog, TileProgram)
    assert set(prog.tiles) == {s.key() for s in SITES}
    assert all(np.isfinite(v).all() for v in prog.tiles.values())
    assert nv.health() == "degraded"
    assert nv.oracle.breaker_open
    assert "raised" in nv.oracle.degraded_reason
    assert nv.oracle.measure_calls == 0            # nothing was measured
    # degraded oracle still prices finitely (model, not all-inf)
    assert np.isfinite(nv.oracle.costs_batch(
        SITES, np.zeros((2, 3), np.int64))).all()


MM2 = KernelSite(site="f.mm2", kind="matmul", m=64, n=128, k=128)


def test_breaker_trips_after_consecutive_all_failed_batches():
    calls = []

    def all_fail(sites, tiles):
        calls.append(len(sites))
        return np.full(len(sites), np.nan)

    mms = [MM, MM2]
    env = MeasuredEnv(SMALL, measure_fn=all_fail, breaker_threshold=2)
    a0 = np.zeros((2, 3), np.int64)          # tiles (16, 128, 128)
    # batch 1: honest fail-closed data, breaker stays armed
    c1 = env.costs_batch(mms, a0)
    assert not env.breaker_open and np.isinf(c1).all()
    assert env.health() == "ok"
    # batch 2 mixes one cached-failed key with one fresh key; the fresh
    # key also fails -> the streak trips the breaker mid-batch, and BOTH
    # entries come back analytic (the purged verdict re-prices too)
    c2 = env.costs_batch(mms, np.array([[0, 0, 0], [1, 0, 0]]))
    assert env.breaker_open and env.health() == "degraded"
    assert np.isfinite(c2).all()
    assert "consecutive" in env.degraded_reason
    # cached failure verdicts from the collapse were purged: re-pricing
    # batch 1 now uses the model, and the dead hook is never called again
    n_calls = len(calls)
    c1b = env.costs_batch(mms, a0)
    assert np.isfinite(c1b).all()
    assert len(calls) == n_calls
    # recovery is explicit
    env.reset_breaker()
    assert env.health() == "ok" and not env.breaker_open


def test_breaker_not_tripped_by_single_flaky_batch():
    flaky = {"n": 0}

    def sometimes(sites, tiles):
        flaky["n"] += 1
        if flaky["n"] == 1:
            return np.full(len(sites), np.nan)
        return np.array([fake_value(s.key(), t)
                         for s, t in zip(sites, tiles)])

    mms = [MM, MM2]
    env = MeasuredEnv(SMALL, measure_fn=sometimes, breaker_threshold=2)
    c1 = env.costs_batch(mms, np.zeros((2, 3), np.int64))
    assert np.isinf(c1).all()           # honest fail-closed, no fallback
    c2 = env.costs_batch(mms, np.array([[1, 0, 0]] * 2))
    assert np.isfinite(c2).all()        # success resets the streak
    assert not env.breaker_open and env.health() == "ok"
    with pytest.raises(ValueError, match="breaker_threshold"):
        MeasuredEnv(SMALL, breaker_threshold=0)


def test_resolve_health_matrix():
    class H:
        def __init__(self, h):
            self._h = h

        def health(self):
            return self._h

    class DegradableOracle(H):
        can_degrade = True

    assert resolve_health(object()) == "ok"            # no health member
    assert resolve_health(H("ok"), H("ok")) == "ok"
    assert resolve_health(H("degraded"), H("ok")) == "degraded"
    assert resolve_health(H("ok"), H("degraded")) == "degraded"
    # down transport + degradable oracle = degraded, not down
    assert resolve_health(DegradableOracle("ok"), H("down")) == "degraded"
    assert resolve_health(H("ok"), H("down")) == "down"


def test_health_surfaces_through_service_and_async_oracle():
    t = WorkerPoolTransport(workers=2,
                            factory="pool_helpers:deterministic")
    with TuningService(SMALL, transport=t) as svc:
        assert svc.health() == "ok"
        assert svc.stats()["health"] == "ok"
        s = svc.open_session(agent="brute", oracle="measured")
        assert isinstance(s.oracle, AsyncOracle)
        assert s.health() == "ok"
        assert s.stats()["health"] == "ok"
        assert "health" in t.stats()
    # service closed (borrowed transport still open)
    assert t.health() == "ok"
    t.close()
    assert t.health() == "down"


# ---------------------------------------------------------------------------
# SIGTERM drains a live session
# ---------------------------------------------------------------------------

def test_sigterm_drains_inflight_tunes_and_closes_service():
    prev = signal.getsignal(signal.SIGTERM)
    t = WorkerPoolTransport(workers=2, factory="pool_helpers:slow")
    svc = TuningService(SMALL, transport=t, preemption=True)
    try:
        assert signal.getsignal(signal.SIGTERM) != prev  # handler installed
        s = svc.open_session(agent="brute", oracle="measured")
        fut = s.fit(SITES).tune_async(SITES)
        os.kill(os.getpid(), signal.SIGTERM)
        deadline = time.monotonic() + 120
        while not svc._closed and time.monotonic() < deadline:
            time.sleep(0.05)
        assert svc._closed                    # the handler drained + closed
        assert fut.done()                     # in-flight tune finished
        prog = fut.result()
        assert isinstance(prog, TileProgram) and len(prog.tiles) == 2
        assert signal.getsignal(signal.SIGTERM) == prev  # handler restored
        with pytest.raises(RuntimeError, match="closed"):
            s.tune(SITES)
    finally:
        signal.signal(signal.SIGTERM, prev)
        t.close()
