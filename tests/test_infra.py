"""Substrate tests: checkpointing, data pipeline, optimizer, fault
tolerance, compression, sharding rules."""
import os
import signal
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpoint import CheckpointManager
from repro.configs import SHAPES, get_config
from repro.configs.base import ShapeConfig
from repro.data.pipeline import DataConfig, SyntheticPipeline
from repro.distributed import sharding as shd
from repro.distributed.compression import make_compressor
from repro.ft.monitor import (PreemptionHandler, StepMonitor,
                              plan_elastic_mesh)
from repro.models.lm import build_model
from repro.optim import adamw
from repro.train.steps import make_train_state, make_train_step


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

def _tiny_state():
    return {"params": {"w": jnp.arange(6.0).reshape(2, 3),
                       "blocks": ({"a": jnp.ones((2, 2))},)},
            "step": jnp.int32(7)}


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    state = _tiny_state()
    mgr.save(state, 10)
    restored, step = mgr.restore(jax.tree.map(jnp.zeros_like, state))
    assert step == 10
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(state["params"]["w"]))


def test_checkpoint_resume_latest_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_n=2)
    state = _tiny_state()
    for s in (10, 20, 30):
        mgr.save(state, s)
    assert mgr.complete_steps() == [20, 30]   # GC kept 2
    assert mgr.latest_step() == 30


def test_checkpoint_async_and_partial_ignored(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    state = _tiny_state()
    mgr.save_async(state, 5)
    mgr.wait()
    # a partial (manifest-less) step dir must not be restorable
    os.makedirs(tmp_path / "step_000000099", exist_ok=True)
    assert mgr.latest_step() == 5


def test_trainer_restart_reproduces_loss(tmp_path):
    """FT end-to-end: train 6 steps; kill; resume from ckpt at 4 and verify
    the loss trajectory matches an uninterrupted run."""
    from repro.launch import train as train_mod
    args = ["--arch", "stablelm_3b", "--steps", "6", "--batch", "4",
            "--seq", "32", "--ckpt-dir", str(tmp_path), "--ckpt-every", "2"]
    losses_full = train_mod.main(args)
    # wipe later checkpoints so the resume starts at step 4
    mgr = CheckpointManager(str(tmp_path))
    for s in mgr.complete_steps():
        if s > 4:
            import shutil
            shutil.rmtree(mgr._step_dir(s))
    losses_resumed = train_mod.main(args)
    np.testing.assert_allclose(losses_resumed, losses_full[4:], rtol=1e-4)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_data_determinism_and_restart():
    cfg = get_config("qwen3_8b").reduced()
    shape = ShapeConfig("t", 64, 8, "train")
    p1 = SyntheticPipeline(cfg, shape, DataConfig(seed=3))
    p2 = SyntheticPipeline(cfg, shape, DataConfig(seed=3))
    b1, b2 = p1.batch_at(17), p2.batch_at(17)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = p1.batch_at(18)
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))


def test_data_host_sharding_differs():
    cfg = get_config("qwen3_8b").reduced()
    shape = ShapeConfig("t", 64, 8, "train")
    a = SyntheticPipeline(cfg, shape, DataConfig(seed=3, host_index=0,
                                                 host_count=2))
    b = SyntheticPipeline(cfg, shape, DataConfig(seed=3, host_index=1,
                                                 host_count=2))
    assert a.local_batch == 4
    assert not np.array_equal(np.asarray(a.batch_at(0)["tokens"]),
                              np.asarray(b.batch_at(0)["tokens"]))


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_reduces_quadratic_loss():
    cfg = adamw.AdamWConfig(lr=0.1, warmup_steps=0, total_steps=100,
                            weight_decay=0.0)
    params = {"w": jnp.array([3.0, -2.0])}
    state = adamw.init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2)

    l0 = float(loss(params))
    for _ in range(50):
        grads = jax.grad(loss)(params)
        params, state, _ = adamw.update(cfg, grads, state, params)
    assert float(loss(params)) < 0.05 * l0


def test_adamw_clips_gradients():
    cfg = adamw.AdamWConfig(clip_norm=1.0)
    params = {"w": jnp.ones((3,))}
    state = adamw.init(params)
    _, _, metrics = adamw.update(cfg, {"w": jnp.full((3,), 100.0)}, state,
                                 params)
    assert float(metrics["grad_norm"]) > 100


def test_lr_schedule_shape():
    cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(adamw.lr_schedule(cfg, jnp.int32(s)))
           for s in (0, 5, 10, 50, 100)]
    assert lrs[0] < lrs[1] < lrs[2]
    assert lrs[2] == pytest.approx(1e-3, rel=1e-5)
    assert lrs[3] > lrs[4]


def test_grad_accum_matches_single_batch():
    cfg = get_config("stablelm_3b").reduced()
    model = build_model(cfg)
    opt_cfg = adamw.AdamWConfig()
    state = make_train_state(model, jax.random.PRNGKey(0), opt_cfg)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0,
                                          cfg.vocab_size, jnp.int32),
             "targets": jax.random.randint(jax.random.PRNGKey(2), (4, 16),
                                           0, cfg.vocab_size, jnp.int32)}
    s1 = make_train_step(model, opt_cfg, accum=1)
    s2 = make_train_step(model, opt_cfg, accum=2)
    st1, m1 = jax.jit(s1)(state, batch)
    st2, m2 = jax.jit(s2)(state, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-5)
    w1 = jax.tree.leaves(st1["params"])[0]
    w2 = jax.tree.leaves(st2["params"])[0]
    np.testing.assert_allclose(np.asarray(w1), np.asarray(w2), atol=2e-5)


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------

def test_step_monitor_flags_straggler():
    mon = StepMonitor(warmup=3, z_thresh=2.0)
    for i in range(10):
        mon.start()
        mon._t0 -= 0.01           # simulate 10ms steps without sleeping
        ev = mon.stop(i)
        assert ev is None
    mon.start()
    mon._t0 -= 1.0                # a 1s step: 100x the mean
    ev = mon.stop(99)
    assert ev is not None and ev["kind"] == "straggler"


def test_preemption_handler():
    h = PreemptionHandler(signals=(signal.SIGUSR1,))
    assert not h.should_stop
    os.kill(os.getpid(), signal.SIGUSR1)
    time.sleep(0.05)
    assert h.should_stop
    h.restore()


def test_elastic_plan():
    p = plan_elastic_mesh(healthy_chips=256, model_parallel=16,
                          global_batch=256)
    assert p.mesh_shape == (16, 16) and p.dropped_chips == 0
    p = plan_elastic_mesh(healthy_chips=250, model_parallel=16,
                          global_batch=256)      # lost 6 chips
    assert p.mesh_shape == (8, 16)               # largest pow2 DP that fits
    assert p.global_batch % p.mesh_shape[0] == 0
    with pytest.raises(AssertionError):
        plan_elastic_mesh(healthy_chips=8, model_parallel=16,
                          global_batch=256)


# ---------------------------------------------------------------------------
# gradient compression (int8 error feedback)
# ---------------------------------------------------------------------------

def test_compression_error_feedback_converges():
    params = {"w": jnp.zeros((32,))}
    comp = make_compressor(params)
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.normal(size=(32,)), jnp.float32)
    total_q = jnp.zeros((32,))
    for _ in range(50):
        deq, _ = comp({"w": g_true})
        total_q = total_q + deq["w"]
    # over many steps the quantized stream must integrate to the true sum
    np.testing.assert_allclose(np.asarray(total_q / 50),
                               np.asarray(g_true), atol=1e-2)


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------

def test_param_specs_cover_all_archs():
    from jax.sharding import PartitionSpec as P
    for arch in ("qwen3_8b", "deepseek_v2_236b", "xlstm_1_3b",
                 "jamba_v0_1_52b", "seamless_m4t_medium"):
        cfg = get_config(arch).reduced()
        model = build_model(cfg)
        shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        specs = shd.param_specs(shapes)
        flat_shapes = jax.tree.leaves(shapes)
        flat_specs = jax.tree.leaves(
            specs, is_leaf=lambda x: isinstance(x, P))
        assert len(flat_shapes) == len(flat_specs)
        for sh, sp in zip(flat_shapes, flat_specs):
            assert len(sp) <= len(sh.shape), (sh.shape, sp)


def test_fit_spec_drops_indivisible_axes():
    from jax.sharding import PartitionSpec as P
    mesh = jax.make_mesh((1,), ("model",))

    class FakeMesh:
        shape = {"model": 16, "data": 16}
    spec = shd._fit_spec(P(None, "model"), (4, 85), FakeMesh())
    assert spec == P(None, None)
    spec = shd._fit_spec(P("data", "model"), (32, 512), FakeMesh())
    assert spec == P("data", "model")
