"""The ``repro.surrogate`` learned cost model: featurizer determinism,
corpus round-trip from a fixture DB, checkpoint fingerprint discipline,
and the payoff layer — ``MeasuredEnv(prune_topk=k)`` submitting exactly
the top-k candidates per site to the measurement transport."""
import numpy as np
import pytest

from repro.artifacts.agentio import ArtifactError
from repro.configs.neurovec import NeuroVecConfig
from repro.core import costmodel_vec
from repro.core.env import CostModelEnv, MeasuredEnv
from repro.measure import CachedMeasureFn, MeasureDB, make_key
from repro.models.compute import KernelSite
from repro.surrogate import (N_FEATURES, build_corpus, featurize,
                             load_surrogate, parse_key, save_surrogate,
                             train_from_db, train_surrogate)
from test_measure import ATTN, MM, SCAN, SpyRunner

# baseline matmul tile for MM (m=32) is (32, 128, 128) — deliberately NOT
# in bm_choices, so the pruned-grid pair count below is exactly top-k
# with no baseline-tile overlap.
CFG = NeuroVecConfig(
    bm_choices=(4, 8, 16), bn_choices=(128,), bk_choices=(128,),
    bq_choices=(64,), bkv_choices=(128,), chunk_choices=(32,))

FIXTURE_SITES = [
    KernelSite(site=f"f.mm{i}", kind="matmul", m=32 * (1 + i % 2),
               n=128, k=128)
    for i in range(4)
] + [ATTN, SCAN]


def _fixture_db(path, backend="fix"):
    """A warm MeasureDB: deterministic per-(site, tile) timings with real
    variance, plus one failed and one foreign-backend record."""
    db = MeasureDB(str(path))
    for s in FIXTURE_SITES:
        if s.kind != "matmul":
            continue
        for t0 in (4, 8, 16):
            db.put(make_key(s.key(), (t0, 128, 128), backend),
                   1e-3 * (1 + t0) * (1 + s.m / 64))
    db.put(make_key(ATTN.key(), (64, 128, 1), backend), 2e-3)
    db.put(make_key(SCAN.key(), (32, 1, 1), backend), 3e-3)
    db.put(make_key(MM.key(), (8, 128, 128), backend), float("inf"))
    db.put(make_key(MM.key(), (16, 128, 128), "other-backend"), 9e-3)
    db.close()
    return str(path)


# ---------------------------------------------------------------------------
# featurizer
# ---------------------------------------------------------------------------

def test_featurizer_shape_finite_deterministic():
    sites = [MM, ATTN, SCAN]
    tiles = np.array([[16, 128, 128], [64, 128, 1], [32, 1, 1]])
    X1 = featurize(sites, tiles)
    assert X1.shape == (3, N_FEATURES)
    assert np.isfinite(X1).all()
    # bitwise deterministic — the corpus and the oracle must agree
    np.testing.assert_array_equal(X1, featurize(sites, tiles))
    # sites differing only in shape get distinct rows
    assert not np.array_equal(X1[0], featurize(
        [KernelSite(site="t.mm", kind="matmul", m=64, n=128, k=128)],
        tiles[:1])[0])


def test_featurizer_illegal_tile_still_finite():
    # the analytic-prior feature is clamped for illegal tiles; the row
    # must stay finite so training never sees inf/nan
    X = featurize([MM], np.array([[4096, 4096, 4096]]))
    assert X.shape == (1, N_FEATURES) and np.isfinite(X).all()


# ---------------------------------------------------------------------------
# corpus builder
# ---------------------------------------------------------------------------

def test_corpus_roundtrip_from_fixture_db(tmp_path):
    p = _fixture_db(tmp_path / "m.jsonl")
    corpus = build_corpus(p)
    # finite records only: the inf row never enters the corpus
    assert len(corpus.sites) == 3 * 4 + 2 + 1
    assert corpus.tiles.shape == (len(corpus.sites), 3)
    assert np.isfinite(corpus.y).all()
    # THE round-trip: every parsed (site, tiles, backend) regenerates its
    # own DB key exactly
    db = MeasureDB(p)
    vals = {r.key: r.value for r in db.iter_records()}
    for site, tiles, backend, y in zip(corpus.sites, corpus.tiles,
                                       corpus.backends, corpus.y):
        key = make_key(site.key(), tuple(int(t) for t in tiles), backend)
        assert key in vals
        assert y == pytest.approx(np.log(vals[key]))
    # backend filter drops the foreign fingerprint
    ours = build_corpus(p, backend="fix")
    assert len(ours.sites) == len(corpus.sites) - 1
    assert set(ours.backends) == {"fix"}


def test_parse_key_rejects_malformed():
    assert parse_key("malformed-key|1x2x3|b") is None
    assert parse_key("no pipes at all") is None
    ok = parse_key(make_key(ATTN.key(), (64, 128, 1), "be"))
    site, tiles, backend = ok
    assert site.kind == "attention" and site.causal and backend == "be"
    assert tiles == (64, 128, 1)
    assert site.key() == ATTN.key()


# ---------------------------------------------------------------------------
# model: training + checkpoint discipline
# ---------------------------------------------------------------------------

def test_train_predict_checkpoint_roundtrip(tmp_path):
    corpus = build_corpus(_fixture_db(tmp_path / "m.jsonl"), backend="fix")
    model = train_surrogate(corpus, hidden=(16,), ensemble=2, steps=60,
                            seed=0, backend="fix")
    pred = model.predict_seconds(list(corpus.sites), corpus.tiles)
    assert pred.shape == (len(corpus.sites),)
    assert np.isfinite(pred).all() and (pred > 0).all()
    # ranking should beat chance on its own (noiseless) training corpus
    mm = [i for i, s in enumerate(corpus.sites)
          if s.kind == "matmul" and s.m == 32]
    order = np.argsort(pred[mm])
    assert list(order) == list(np.argsort(corpus.y[mm]))

    art = str(tmp_path / "ck")
    save_surrogate(model, art)
    loaded = load_surrogate(art)
    assert loaded.backend == "fix"
    np.testing.assert_allclose(
        loaded.predict_seconds(list(corpus.sites), corpus.tiles), pred)


def test_checkpoint_fingerprint_rejection(tmp_path):
    corpus = build_corpus(_fixture_db(tmp_path / "m.jsonl"), backend="fix")
    model = train_surrogate(corpus, hidden=(16,), ensemble=2, steps=30)
    art = str(tmp_path / "ck")
    save_surrogate(model, art)
    # perturb one stored tensor (keeping the archive well-formed): the
    # recomputed fingerprint must disagree with the manifest — a silently
    # corrupted cost model is worse than none
    npz = tmp_path / "ck" / "state.npz"
    arrays = dict(np.load(str(npz)))
    key = sorted(arrays)[0]
    arrays[key] = arrays[key] + 1.0
    np.savez(str(npz), **arrays)
    with pytest.raises(ArtifactError, match="fingerprint"):
        load_surrogate(art)


def test_train_from_db_cold_returns_none(tmp_path):
    p = str(tmp_path / "cold.jsonl")
    db = MeasureDB(p)
    db.put(make_key(MM.key(), (8, 128, 128), "b"), 1e-3)
    db.close()
    assert train_from_db(p) is None               # < min_pairs
    assert train_from_db(None) is None            # no DB at all
    warm = train_from_db(_fixture_db(tmp_path / "warm.jsonl"),
                         hidden=(16,), ensemble=2, steps=30)
    assert warm is not None and warm.backend == "fix"


# ---------------------------------------------------------------------------
# the payoff: pruned measured grid
# ---------------------------------------------------------------------------

def test_pruned_env_submits_exactly_topk(tmp_path):
    surrogate = train_from_db(_fixture_db(tmp_path / "m.jsonl"),
                              hidden=(16,), ensemble=2, steps=60)
    grid = costmodel_vec.action_tiles_grid(CostModelEnv(CFG).space,
                                           "matmul")
    n_legal = int(np.isfinite(
        costmodel_vec.costs_for_tiles([MM] * len(grid), grid)).sum())
    assert n_legal == 3                   # the fixture grid, sanity

    for topk in (1, 2):
        spy = SpyRunner()
        env = MeasuredEnv(CFG, measure_fn=CachedMeasureFn(spy, db=None),
                          prune_topk=topk, surrogate=surrogate)
        assert env.prune_active
        costs = env.cost_grid([MM])[0]
        # exactly top-k pairs reach the transport (baseline tile is
        # off-grid by construction); the rest are surrogate-priced
        assert spy.pairs == topk
        assert env.pruned_pairs == n_legal - topk
        assert np.isfinite(costs[:n_legal]).all()

    # without a surrogate the same env measures the full legal grid
    spy = SpyRunner()
    env = MeasuredEnv(CFG, measure_fn=CachedMeasureFn(spy, db=None))
    assert not env.prune_active
    env.cost_grid([MM])
    assert spy.pairs == n_legal


def test_pruned_env_baseline_always_measured(tmp_path):
    """Eq. 2 stays measured-vs-measured: the heuristic-baseline tile is
    in every site's allowed set even when the surrogate ranks it last."""
    surrogate = train_from_db(_fixture_db(tmp_path / "m.jsonl"),
                              hidden=(16,), ensemble=2, steps=60)
    spy = SpyRunner()
    env = MeasuredEnv(CFG, measure_fn=CachedMeasureFn(spy, db=None),
                      prune_topk=1, surrogate=surrogate)
    r = env.rewards_batch([ATTN, SCAN], np.array([[0, 0, 0], [0, 0, 0]]))
    assert r.shape == (2,) and np.isfinite(r).all()
    allowed = env._allowed_tiles(ATTN)
    base = tuple(int(x) for x in
                 costmodel_vec.baseline_tiles_batch([ATTN])[0])
    assert base in allowed


def test_pruned_env_rejects_bad_topk():
    with pytest.raises(ValueError, match="prune_topk"):
        MeasuredEnv(CFG, prune_topk=0)
