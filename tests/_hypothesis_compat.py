"""Minimal stand-in for ``hypothesis`` so tier-1 runs on a bare env.

``tests/test_core.py`` property-tests the cost model with
``@given(st.integers(...))``.  When the real ``hypothesis`` package is
installed (see ``requirements-dev.txt``) it is used; when it is missing we
fall back to this shim, which replays each property over a deterministic
seeded sweep instead of skipping the test outright (the graceful
degradation requested for bare environments — strictly better than
``pytest.importorskip``, which would skip the whole module).

Only the tiny API surface the test suite uses is provided:
``given`` (kwargs of strategies), ``settings(max_examples=, deadline=)``,
and ``st.integers(min_value, max_value)``.
"""
from __future__ import annotations

import functools
import random
from types import SimpleNamespace

_FALLBACK_EXAMPLES = 25


class _IntStrategy:
    def __init__(self, lo: int, hi: int):
        self.lo, self.hi = lo, hi

    def sample(self, rng: random.Random) -> int:
        return rng.randint(self.lo, self.hi)


class _ChoiceStrategy:
    def __init__(self, options):
        self.options = list(options)

    def sample(self, rng: random.Random):
        return rng.choice(self.options)


def integers(min_value: int, max_value: int) -> _IntStrategy:
    return _IntStrategy(min_value, max_value)


def sampled_from(options) -> _ChoiceStrategy:
    return _ChoiceStrategy(options)


def booleans() -> _ChoiceStrategy:
    return _ChoiceStrategy([False, True])


st = SimpleNamespace(integers=integers, sampled_from=sampled_from,
                     booleans=booleans)


def settings(max_examples=None, **_kw):
    """Caps the fallback sweep at ``max_examples`` (tests tuned down for
    expensive bodies keep their budget); other hypothesis knobs ignored."""
    def deco(fn):
        if max_examples is not None:
            fn._max_examples = max_examples
        return fn
    return deco


def given(**strategies):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper():
            rng = random.Random(0xC0FFEE)
            n = min(getattr(wrapper, "_max_examples", _FALLBACK_EXAMPLES),
                    _FALLBACK_EXAMPLES)
            for _ in range(n):
                fn(**{k: s.sample(rng) for k, s in strategies.items()})
        # pytest must see a zero-arg test, not the wrapped signature —
        # otherwise the strategy kwargs are mistaken for fixtures
        del wrapper.__wrapped__
        return wrapper
    return deco
