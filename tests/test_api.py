"""The unified Agent/Oracle protocol and the ``repro.api`` facade.

Contains THE shared agent contract test: every registry name must produce
an Agent whose actions are well-shaped, integer, in-range (strict-actions
compliant) and deterministic under ``sample=False``."""
import numpy as np
import pytest

from repro.api import (AGENT_NAMES, Agent, CostModelEnv, MeasuredEnv,
                       NeuroVecConfig, NeuroVectorizer, Oracle, TileProgram,
                       baseline_program, make_agent, program_speedup)
from repro.core import costmodel, dataset
from repro.core.agents import polly
from repro.core.env import set_strict_actions
from repro.models.compute import KernelSite

NV = NeuroVecConfig(train_batch=64, sgd_minibatch=32, ppo_epochs=2)
ENV = CostModelEnv(NV)
CORPUS = dataset.generate(24, seed=7)          # mixed kinds
HELDOUT = dataset.generate(12, seed=8)


def _fitted(name):
    agent = make_agent(name, NV, seed=0)
    fit_kw = {"total_steps": 128} if name == "ppo" else {}
    return agent.fit(CORPUS, ENV, **fit_kw)


# ---------------------------------------------------------------------------
# the shared agent contract
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", AGENT_NAMES)
def test_agent_contract(name, tmp_path):
    agent = _fitted(name)
    assert isinstance(agent, Agent)
    assert agent.name == name

    a1 = np.asarray(agent.act(HELDOUT, sample=False))
    # shape / dtype
    assert a1.shape == (len(HELDOUT), 3)
    assert np.issubdtype(a1.dtype, np.integer)
    # range: strict-actions compliant per site kind (no clamp reliance)
    for s, a in zip(HELDOUT, a1):
        for d, n in enumerate(ENV.space.valid_sizes(s.kind)):
            assert 0 <= a[d] < n, (name, s.kind, d, a)
    # determinism under sample=False (the deployment mode)
    a2 = np.asarray(agent.act(HELDOUT, sample=False))
    np.testing.assert_array_equal(a1, a2)
    # actions survive strict mode end to end
    set_strict_actions(True)
    try:
        sp = ENV.speedups_batch(HELDOUT, a1)
    finally:
        set_strict_actions(False)
    assert sp.shape == (len(HELDOUT),) and (sp > 0).all()
    # sampling path keeps the same output contract
    a3 = np.asarray(agent.act(HELDOUT, sample=True))
    assert a3.shape == (len(HELDOUT), 3)
    # save -> load -> act round-trip (PR 5): the loaded agent's
    # deployment actions are bitwise-equal to the original's
    from repro.artifacts import agent_fingerprint, load_agent, save_agent
    art = str(tmp_path / "agent")
    fp = save_agent(agent, art)
    loaded = load_agent(art, cfg=NV, seed=0)
    if name == "brute":                 # captured-oracle rebind (load docs)
        loaded.oracle = ENV
    a4 = np.asarray(loaded.act(HELDOUT, sample=False))
    np.testing.assert_array_equal(a1, a4)
    # the fingerprint is a stable function of the deployable state
    assert agent_fingerprint(loaded) == fp == agent_fingerprint(agent)


def test_make_agent_registry_smoke():
    for name in AGENT_NAMES:
        agent = make_agent(name, NV, seed=0)
        assert isinstance(agent, Agent) and agent.name == name
    with pytest.raises(ValueError, match="unknown agent"):
        make_agent("definitely-not-an-agent", NV)


def test_random_agent_vectorized_and_seeded():
    r1 = make_agent("random", NV, seed=3).fit([], ENV)
    r2 = make_agent("random", NV, seed=3).fit([], ENV)
    sites = dataset.generate(64, seed=9)
    np.testing.assert_array_equal(r1.act(sites), r2.act(sites))
    # sample=True advances the stream (random *search*), sample=False not
    s1 = r1.act(sites, sample=True)
    s2 = r1.act(sites, sample=True)
    assert (np.asarray(s1) != np.asarray(s2)).any()
    np.testing.assert_array_equal(r1.act(sites), r2.act(sites))
    # draws cover the per-kind space, not a constant
    a = np.asarray(r1.act(sites))
    assert len(np.unique(a[:, 0])) > 1


def test_polly_vectorized_matches_scalar_walk():
    sites = dataset.generate(40, seed=12)
    acts = make_agent("polly", NV).fit([], ENV).act(sites)
    for s, a in zip(sites, acts):
        ref = polly._polly_action_ref(ENV.space, s)
        assert tuple(a) == tuple(ref), (s.kind, tuple(a), tuple(ref))


# ---------------------------------------------------------------------------
# Oracle protocol: CostModelEnv and MeasuredEnv are interchangeable
# ---------------------------------------------------------------------------

def test_oracle_protocol_conformance(tmp_path):
    assert isinstance(ENV, Oracle)
    assert isinstance(MeasuredEnv(NV), Oracle)
    assert not isinstance(object(), Oracle)
    # the learned cost model joins the same contract (PR 7)
    from repro.core.costmodel_vec import tiles_for_actions
    from repro.measure import MeasureDB, make_key
    from repro.surrogate import SurrogateOracle, train_from_db
    db = MeasureDB(str(tmp_path / "m.jsonl"))
    for s in CORPUS[:4]:
        for i, t in enumerate(tiles_for_actions(
                ENV.space, [s] * 2, np.array([[0, 0, 0], [1, 0, 0]]))):
            db.put(make_key(s.key(), tuple(int(x) for x in t), "t"),
                   1e-3 * (i + 1))
    db.close()
    model = train_from_db(str(tmp_path / "m.jsonl"),
                          hidden=(16,), ensemble=2, steps=30)
    orc = SurrogateOracle(NV, model)
    assert isinstance(orc, Oracle)
    sites = CORPUS[:4]
    acts = make_agent("baseline", NV).fit([], ENV).act(sites)
    sp = orc.speedups_batch(sites, acts)
    assert sp.shape == (len(sites),) and np.isfinite(sp).all()


def test_measured_env_cost_model_fallback():
    m = MeasuredEnv(NV)                        # no hook: off-TPU fallback
    sites = CORPUS[:8]
    acts = make_agent("baseline", NV).fit([], ENV).act(sites)
    np.testing.assert_allclose(m.costs_batch(sites, acts),
                               ENV.costs_batch(sites, acts), rtol=1e-12)
    np.testing.assert_allclose(m.baseline_costs(sites),
                               ENV.baseline_costs(sites), rtol=1e-12)
    np.testing.assert_allclose(m.cost_grid(sites), ENV.cost_grid(sites),
                               rtol=1e-12)
    np.testing.assert_allclose(m.rewards_batch(sites, acts),
                               ENV.rewards_batch(sites, acts), rtol=1e-6)


def test_measured_env_batched_hook_and_cache():
    calls = []

    def hook(sites, tiles):
        calls.append(len(sites))
        out = [costmodel.site_cost(s, tuple(int(x) for x in t))
               for s, t in zip(sites, tiles)]
        assert all(c is not None for c in out), "hook saw an illegal tile"
        return np.array([2.0 * c for c in out])   # "hardware" = 2x model

    m = MeasuredEnv(NV, measure_fn=hook)
    sites = CORPUS[:6]
    acts = make_agent("baseline", NV).fit([], ENV).act(sites)
    c1 = m.costs_batch(sites, acts)
    assert calls == [len(sites)], "hook must be called once, batched"
    np.testing.assert_allclose(c1, 2.0 * ENV.costs_batch(sites, acts),
                               rtol=1e-12)
    # per-site result cache: repeats measure nothing
    np.testing.assert_allclose(m.costs_batch(sites, acts), c1, rtol=0)
    assert calls == [len(sites)]
    # rewards/speedups are scale-invariant: measured == modelled here
    np.testing.assert_allclose(m.rewards_batch(sites, acts),
                               ENV.rewards_batch(sites, acts), rtol=1e-5)
    np.testing.assert_allclose(m.speedups_batch(sites, acts),
                               ENV.speedups_batch(sites, acts), rtol=1e-6)


def test_measured_env_illegal_never_measured():
    def hook(sites, tiles):                     # hardware would hang/fail
        for s, t in zip(sites, tiles):
            assert costmodel.site_cost(s, tuple(int(x) for x in t)) \
                is not None
        return np.array([1e-3] * len(sites))

    m = MeasuredEnv(NV, measure_fn=hook)
    big = KernelSite(site="x", kind="matmul", m=65536, n=16384, k=16384)
    a_ill = np.array([[len(NV.bm_choices) - 1, len(NV.bn_choices) - 1,
                       len(NV.bk_choices) - 1]])
    assert m.rewards_batch([big], a_ill)[0] == NV.fail_penalty
    assert m.speedups_batch([big], a_ill)[0] == pytest.approx(
        1.0 / NV.illegal_slowdown)
    assert m.cost(big, a_ill[0]) is None


def test_measured_env_failed_run_is_illegal():
    m = MeasuredEnv(NV, measure_fn=lambda sites, tiles: np.full(
        len(sites), np.nan))                    # every measurement fails
    s = CORPUS[0]
    acts = make_agent("baseline", NV).fit([], ENV).act([s])
    assert m.rewards_batch([s], acts)[0] == NV.fail_penalty


def test_measured_env_failed_baseline_fails_closed():
    # a flaky baseline measurement must not leak nan rewards / inf speedups
    def hook(sites, tiles):
        return np.array([np.nan if (s.key(), tuple(map(int, t))) in bad
                         else costmodel.site_cost(s, tuple(map(int, t)))
                         for s, t in zip(sites, tiles)], np.float64)

    s = CORPUS[0]
    bad = {(s.key(), tuple(costmodel.baseline_tiles(s))
            + (1,) * (3 - len(costmodel.baseline_tiles(s))))}
    m = MeasuredEnv(NV, measure_fn=hook)
    acts = make_agent("brute", NV).fit([s], CostModelEnv(NV)).act([s])
    r = m.rewards_batch([s], acts)
    sp = m.speedups_batch([s], acts)
    assert np.isfinite(r).all() and r[0] == NV.fail_penalty
    assert np.isfinite(sp).all() and sp[0] == pytest.approx(
        1.0 / NV.illegal_slowdown)
    assert m.speedup(s, acts[0]) == pytest.approx(1.0 / NV.illegal_slowdown)
    assert m.reward(s, acts[0]) == NV.fail_penalty


def test_measured_env_dedups_within_batch():
    pairs = []

    def hook(sites, tiles):
        pairs.append(len(sites))
        return np.asarray([costmodel.site_cost(s, tuple(map(int, t)))
                           for s, t in zip(sites, tiles)], np.float64)

    m = MeasuredEnv(NV, measure_fn=hook)
    s = CORPUS[0]
    a = make_agent("baseline", NV).fit([], ENV).act([s])[0]
    # training samples sites with replacement: 5 copies = 1 measurement
    c = m.costs_batch([s] * 5, np.tile(a, (5, 1)))
    assert pairs == [1] and m.measured_pairs == 1
    assert np.allclose(c, c[0])


def test_program_speedup_consistent_under_measured_oracle():
    # baselines AND program tiles must be priced by the same oracle: a
    # uniform 2x-slower "hardware" cancels out exactly
    m = MeasuredEnv(NV, measure_fn=lambda sites, tiles: np.asarray(
        [2.0 * costmodel.site_cost(s, tuple(map(int, t)))
         for s, t in zip(sites, tiles)], np.float64))
    sites = dataset.generate(6, seed=13)
    assert program_speedup(baseline_program(sites), sites,
                           m) == pytest.approx(1.0, rel=1e-9)


def test_program_speedup_excludes_failed_baseline_sites():
    # a site whose baseline measurement failed must not drag the aggregate
    # to inf/nan — it is excluded
    sites = dataset.generate(4, seed=14)
    bad_key = sites[0].key()

    def hook(ss, tt):
        return np.asarray(
            [np.nan if s.key() == bad_key
             else costmodel.site_cost(s, tuple(map(int, t)))
             for s, t in zip(ss, tt)], np.float64)

    m = MeasuredEnv(NV, measure_fn=hook)
    sp = program_speedup(baseline_program(sites), sites, m)
    assert np.isfinite(sp) and sp == pytest.approx(1.0, rel=1e-9)


def test_measured_env_real_runner_conformance(tmp_path):
    """The PR-3 acceptance seam: MeasuredEnv with the REAL MeasureRunner
    (interpret mode) — Oracle-conformant, finite rewards, model-illegal
    tiles never executed."""
    from repro.measure import make_measured_env

    cfg = NeuroVecConfig(bm_choices=(16, 32), bn_choices=(128,),
                         bk_choices=(128,), bq_choices=(64,),
                         bkv_choices=(128,), chunk_choices=(32,))
    env = make_measured_env(cfg, db_path=str(tmp_path / "m.jsonl"),
                            reps=1, warmup=1, interpret=True, max_dim=64)
    assert isinstance(env, Oracle)

    small = [KernelSite(site="r.mm", kind="matmul", m=32, n=128, k=128),
             KernelSite(site="r.at", kind="attention", m=64, n=32, k=64,
                        batch=2, causal=True)]
    acts = np.array([[0, 0, 0], [0, 0, 0]])
    r = env.rewards_batch(small, acts)
    assert r.shape == (2,) and np.isfinite(r).all()
    assert (env.speedups_batch(small, acts) > 0).all()

    # a model-illegal (VMEM-overflow) tile is never built or timed: with
    # this action space every action decodes to the illegal top-corner
    # tile, so only the site's legal baseline pair may reach the runner
    big = KernelSite(site="r.big", kind="matmul", m=65536, n=16384,
                     k=16384)
    bad_cfg = NeuroVecConfig(bm_choices=(512,), bn_choices=(512,),
                             bk_choices=(4096,))
    bad_env = make_measured_env(bad_cfg, reps=1, warmup=1, interpret=True,
                                max_dim=64)
    assert bad_env.rewards_batch([big], np.array([[0, 0, 0]]))[0] \
        == bad_cfg.fail_penalty
    attempted = (bad_env.measure_fn.runner.timed_pairs
                 + bad_env.measure_fn.runner.failed_pairs)
    assert attempted == 1               # the baseline only — never the tile


def test_measured_env_real_runner_failure_fails_closed():
    """A kernel that dies at build/compile/run time (not merely
    model-illegal) must come back as the penalty, not poison the batch."""
    from repro.measure import MeasureRunner
    from repro.measure.db import CachedMeasureFn

    class ExplodingRunner(MeasureRunner):
        def _build(self, site, tiles):
            if site.site == "r.boom":
                raise RuntimeError("simulated compile failure")
            return super()._build(site, tiles)

    cfg = NeuroVecConfig(bm_choices=(16,), bn_choices=(128,),
                         bk_choices=(128,), bq_choices=(64,),
                         bkv_choices=(128,), chunk_choices=(32,))
    runner = ExplodingRunner(reps=1, warmup=1, interpret=True, max_dim=64)
    m = MeasuredEnv(cfg, measure_fn=CachedMeasureFn(runner))
    boom = KernelSite(site="r.boom", kind="matmul", m=32, n=128, k=128)
    ok = KernelSite(site="r.ok", kind="matmul", m=32, n=128, k=128)
    r = m.rewards_batch([boom, ok], np.zeros((2, 3), np.int64))
    assert r[0] == cfg.fail_penalty     # baseline failed -> site fails closed
    assert np.isfinite(r).all()
    assert runner.failed_pairs >= 1 and runner.timed_pairs >= 1
    sp = m.speedups_batch([boom, ok], np.zeros((2, 3), np.int64))
    assert sp[0] == pytest.approx(1.0 / cfg.illegal_slowdown)
    assert np.isfinite(sp).all()


def test_facade_measured_oracle_string(tmp_path):
    """``NeuroVectorizer(cfg, oracle="measured")`` assembles the stack."""
    from repro.measure.db import CachedMeasureFn

    cfg = NeuroVecConfig(bm_choices=(16, 32), bn_choices=(128,),
                         bk_choices=(128,), bq_choices=(64,),
                         bkv_choices=(128,), chunk_choices=(32,))
    nv = NeuroVectorizer(cfg, agent="brute", oracle="measured",
                         db_path=str(tmp_path / "m.jsonl"),
                         oracle_kwargs=dict(reps=1, warmup=1,
                                            interpret=True, max_dim=64))
    assert isinstance(nv.oracle, MeasuredEnv)
    assert isinstance(nv.oracle.measure_fn, CachedMeasureFn)
    sites = [KernelSite(site="f.mm", kind="matmul", m=32, n=128, k=128)]
    prog = nv.fit(sites).tune_sites(sites)
    assert len(prog.tiles) == 1
    assert nv.oracle.measure_fn.runner.timed_pairs > 0
    with pytest.raises(ValueError, match="unknown oracle"):
        NeuroVectorizer(cfg, oracle="wat")
    with pytest.raises(ValueError, match="oracle='measured'"):
        NeuroVectorizer(cfg, oracle="model", db_path="x")


def test_brute_agent_works_against_measured_oracle():
    # same protocol => brute force can exhaustively 'measure' hardware
    m = MeasuredEnv(NV, measure_fn=lambda sites, tiles: np.asarray(
        [costmodel.site_cost(s, tuple(int(x) for x in t))
         for s, t in zip(sites, tiles)], np.float64))
    sites = CORPUS[:4]
    a_meas = make_agent("brute", NV).fit(sites, m).act(sites)
    a_model = make_agent("brute", NV).fit(sites, ENV).act(sites)
    np.testing.assert_array_equal(a_meas, a_model)


# ---------------------------------------------------------------------------
# the facade
# ---------------------------------------------------------------------------

def test_facade_fit_tune_inject_speedup():
    import jax
    import jax.numpy as jnp

    from repro.models import compute

    nv = NeuroVectorizer(NV, agent="brute", seed=0)
    sites = dataset.generate(10, seed=9)
    prog = nv.fit(sites).tune_sites(sites)
    assert set(prog.tiles) == {s.key() for s in sites}
    assert nv.speedup(prog, sites) >= 1.0      # brute >= baseline

    # step-fn path: extract -> tune -> inject, numbers unchanged
    def step(x, w):
        return compute.matmul(x, w, site="facade.mm")

    specs = (jax.ShapeDtypeStruct((64, 96), jnp.float32),
             jax.ShapeDtypeStruct((96, 128), jnp.float32))
    prog2 = nv.tune(step, specs)
    assert prog2.tiles
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 96))
    w = jax.random.normal(jax.random.PRNGKey(1), (96, 128))
    y_ref = step(x, w)
    with nv.inject(prog2, interpret=True):
        y_tuned = step(x, w)
    np.testing.assert_allclose(np.asarray(y_tuned), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)


def test_facade_accepts_prebuilt_agent_and_oracle():
    agent = make_agent("polly", NV)
    oracle = MeasuredEnv(NV)
    nv = NeuroVectorizer(NV, agent=agent, oracle=oracle)
    assert nv.agent is agent and nv.oracle is oracle
    sites = dataset.generate(5, seed=10)
    prog = nv.fit(sites).tune_sites(sites)
    assert len(prog.tiles) == 5


# ---------------------------------------------------------------------------
# TileProgram / program_speedup coverage (satellite)
# ---------------------------------------------------------------------------

def test_tileprogram_roundtrip_restores_tuples(tmp_path):
    prog = TileProgram({"a|1": (128, 256, 512), "b|2": (64,),
                        "c|3": (256, 1024)})
    f = str(tmp_path / "tiles.json")
    prog.save(f)
    loaded = TileProgram.load(f)
    assert loaded.tiles == prog.tiles
    # JSON stores lists; load must restore hashable/equal-comparable tuples
    assert all(isinstance(v, tuple) for v in loaded.tiles.values())


def test_baseline_program_is_heuristic_and_unit_speedup():
    sites = dataset.generate(8, seed=10)
    prog = baseline_program(sites)
    assert set(prog.tiles) == {s.key() for s in sites}
    for s in sites:
        assert prog.tiles[s.key()] == costmodel.baseline_tiles(s)
    assert program_speedup(prog, sites, ENV) == pytest.approx(1.0,
                                                              rel=1e-9)


def test_program_speedup_missing_site_runs_at_baseline():
    sites = dataset.generate(6, seed=11)
    assert program_speedup(TileProgram(), sites) == pytest.approx(1.0,
                                                                  rel=1e-9)
    assert program_speedup(TileProgram(), []) == 1.0


def test_program_speedup_illegal_tiles_charged_uniformly():
    s = KernelSite(site="big", kind="matmul", m=65536, n=16384, k=16384)
    bad = TileProgram({s.key(): (512, 512, 4096)})   # VMEM overflow
    assert costmodel.site_cost(s, (512, 512, 4096)) is None
    assert program_speedup(bad, [s], ENV) == pytest.approx(
        1.0 / NV.illegal_slowdown)


def test_illegal_penalty_constant_unified():
    cfg = NeuroVecConfig(illegal_slowdown=25.0)
    e = CostModelEnv(cfg)
    s = KernelSite(site="big", kind="matmul", m=65536, n=16384, k=16384)
    a = (len(cfg.bm_choices) - 1, len(cfg.bn_choices) - 1,
         len(cfg.bk_choices) - 1)
    # one cfg constant drives all three clamp sites
    assert e.speedup(s, a) == pytest.approx(1 / 25.0)
    assert e.speedups_batch([s], np.array([a]))[0] == pytest.approx(1 / 25.0)
    prog = TileProgram({s.key(): e.space.tiles(s.kind, a)})
    assert program_speedup(prog, [s], e) == pytest.approx(1 / 25.0)
