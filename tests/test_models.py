"""Per-arch smoke tests (reduced configs) + cache-path consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, supported_shapes
from repro.models.lm import build_model


def _smoke_batch(cfg, B=2, S=16, key=0):
    k = jax.random.PRNGKey(key)
    batch = {"tokens": jax.random.randint(k, (B, S), 0, cfg.vocab_size,
                                          jnp.int32),
             "targets": jax.random.randint(jax.random.fold_in(k, 1), (B, S),
                                           0, cfg.vocab_size, jnp.int32)}
    if cfg.frontend == "vision":
        n = cfg.n_frontend_tokens
        batch["tokens"] = batch["tokens"][:, :S - n]
        batch["targets"] = batch["targets"][:, :S - n]
        batch["frontend_embeds"] = jax.random.normal(
            jax.random.fold_in(k, 2), (B, n, cfg.d_model)) * 0.1
    if cfg.enc_dec:
        batch["src_embeds"] = jax.random.normal(
            jax.random.fold_in(k, 3), (B, S, cfg.d_model)) * 0.1
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_train_step(arch):
    """One forward/backward on the reduced config: shapes + finite values."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _smoke_batch(cfg)
    (loss, metrics), grads = jax.jit(
        jax.value_and_grad(model.train_loss, has_aux=True))(params, batch)
    assert jnp.isfinite(loss), (arch, loss)
    assert 1.0 < float(loss) < 20.0, (arch, loss)
    leaves = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in leaves), arch
    gnorm = sum(float(jnp.sum(g.astype(jnp.float32) ** 2)) for g in leaves)
    assert gnorm > 0, arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_decode_matches_prefill(arch, monkeypatch):
    """Cache-path correctness: prefill(t[:n]) + decode(t[n]) must equal
    prefill(t[:n+1]) logits.

    MoE capacity drops legitimately differ between the two paths (GShard
    token-priority depends on the batch composition), so the comparison
    runs dropless."""
    from repro.models import moe
    monkeypatch.setattr(moe, "CAPACITY_FACTOR", 100.0)
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 12
    batch = _smoke_batch(cfg, B, S)
    n_pre = cfg.n_frontend_tokens if cfg.frontend == "vision" else 0
    toks = batch["tokens"]
    S_text = toks.shape[1]
    ctx = n_pre + S_text

    full = dict(batch)
    logits_full, _ = jax.jit(model.prefill)(
        params, full, model.make_cache(B, ctx, jnp.dtype(cfg.dtype)))

    part = dict(batch)
    part["tokens"] = toks[:, :-1]
    logits_part, cache = jax.jit(model.prefill)(
        params, part, model.make_cache(B, ctx, jnp.dtype(cfg.dtype)))
    logits_dec, _ = jax.jit(model.decode_step)(
        params, toks[:, -1:], jnp.int32(ctx - 1), cache)

    np.testing.assert_allclose(np.asarray(logits_dec),
                               np.asarray(logits_full),
                               rtol=2e-2, atol=2e-2)


def test_moe_capacity_drops_are_bounded():
    from repro.models import moe
    cfg = get_config("jamba_v0_1_52b").reduced(n_experts=4, moe_top_k=2,
                                               moe_d_ff=32)
    p = moe.moe_init(cfg, jax.random.PRNGKey(0), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 32, cfg.d_model))
    y, aux = moe.apply_moe(cfg, p, x)
    assert y.shape == x.shape
    assert float(aux["lb_loss"]) > 0.5          # ~1.0 when balanced
    assert jnp.all(jnp.isfinite(y))


def test_moe_grads_match_dense_reference():
    from repro.models import moe
    cfg = get_config("jamba_v0_1_52b").reduced(n_experts=4, moe_top_k=2,
                                               moe_d_ff=32)
    key = jax.random.PRNGKey(0)
    p = moe.moe_init(cfg, key, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1),
                          (2, 16, cfg.d_model)) * 0.5

    def loss(p):
        return (moe.apply_moe(cfg, p, x)[0] ** 2).sum()

    def ref_loss(p):
        B, S, d = x.shape
        xt = x.reshape(-1, d)
        logits = xt @ p["router"]
        gate, eidx = jax.lax.top_k(jax.nn.softmax(logits, -1),
                                   cfg.moe_top_k)
        gate = gate / gate.sum(-1, keepdims=True)
        y = jnp.zeros_like(xt, dtype=jnp.float32)
        for e in range(cfg.n_experts):
            h = xt @ p["ewi"][e]
            g = jax.nn.silu(xt @ p["ewg"][e])
            ye = (h * g) @ p["ewo"][e]
            we = ((eidx == e) * gate).sum(-1)
            y += ye.astype(jnp.float32) * we[:, None]
        return (y.astype(x.dtype).reshape(B, S, d) ** 2).sum()

    g1 = jax.grad(loss)(p)
    g2 = jax.grad(ref_loss)(p)
    for k in ("ewi", "ewg", "ewo", "router"):
        scale = float(jnp.max(jnp.abs(g2[k]))) + 1e-9
        err = float(jnp.max(jnp.abs(g1[k] - g2[k]))) / scale
        assert err < 1e-5, (k, err)


def test_ssd_chunk_matches_sequential_decode():
    from repro.models import ssm
    cfg = get_config("jamba_v0_1_52b").reduced()
    key = jax.random.PRNGKey(0)
    p = ssm.ssm_init(cfg, key, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 32, cfg.d_model))
    y_chunk, _ = ssm.apply_ssm(cfg, p, x)
    cache = ssm.make_ssm_cache(cfg, 2, jnp.float32)
    ys = []
    for t in range(32):
        yt, cache = ssm.apply_ssm(cfg, p, x[:, t:t + 1], cache=cache,
                                  decode_pos=t)
        ys.append(yt)
    np.testing.assert_allclose(np.asarray(y_chunk),
                               np.asarray(jnp.concatenate(ys, 1)),
                               rtol=1e-3, atol=1e-4)


def test_mlstm_chunk_matches_sequential_decode():
    from repro.models import xlstm
    cfg = get_config("xlstm_1_3b").reduced()
    key = jax.random.PRNGKey(0)
    p = xlstm.mlstm_init(cfg, key, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 16, cfg.d_model))
    cache0 = xlstm.make_mlstm_cache(cfg, 2)
    y_chunk, _ = xlstm.apply_mlstm(cfg, p, x, cache=cache0, chunk=8)
    cache = xlstm.make_mlstm_cache(cfg, 2)
    ys = []
    for t in range(16):
        yt, cache = xlstm.apply_mlstm(cfg, p, x[:, t:t + 1], cache=cache,
                                      decode_pos=t)
        ys.append(yt)
    np.testing.assert_allclose(np.asarray(y_chunk),
                               np.asarray(jnp.concatenate(ys, 1)),
                               rtol=1e-3, atol=1e-4)


def test_supported_shapes_policy():
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        sup = supported_shapes(cfg)
        assert sup["train_4k"] == "run"
        if cfg.family in ("ssm", "hybrid"):
            assert sup["long_500k"] == "run"
        else:
            assert sup["long_500k"].startswith("SKIP")


def test_param_counts_match_published():
    expect = {"starcoder2_7b": 7.4e9, "qwen3_8b": 8.2e9,
              "deepseek_v2_236b": 239e9, "llama4_maverick_400b": 401e9,
              "jamba_v0_1_52b": 51e9}
    for arch, n in expect.items():
        got = get_config(arch).param_count()
        assert abs(got - n) / n < 0.05, (arch, got, n)
    active = {"deepseek_v2_236b": 21.4e9, "llama4_maverick_400b": 17.2e9,
              "jamba_v0_1_52b": 12e9}
    for arch, n in active.items():
        got = get_config(arch).active_param_count()
        assert abs(got - n) / n < 0.05, (arch, got, n)
