"""Per-kernel allclose sweeps + hypothesis property tests vs ref.py oracles
(interpret mode executes the kernel bodies in Python on CPU).

Includes the action-space correctness sweeps: every *distinct effective*
tile the DEFAULT NeuroVec action grid can produce on a test shape (after
the kernels' internal clamping) is executed once against the pure-jnp
oracle — the guard for every tile the measurement runner
(``repro.measure``) will ever compile and time."""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:                                   # property-based when available ...
    from hypothesis import given, settings, strategies as st
except ImportError:                    # ... deterministic sweep on bare envs
    from _hypothesis_compat import given, settings, st

from repro.configs.neurovec import DEFAULT as NV
from repro.kernels import ops, ref
from repro.kernels.matmul import _ceil_mult


def _rel_err(a, b):
    return float(jnp.max(jnp.abs(a - b)) / (jnp.max(jnp.abs(b)) + 1e-9))


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------

MM_SHAPES = [(64, 128, 128), (128, 256, 512), (100, 300, 200), (8, 128, 64),
             (513, 129, 257), (16, 384, 48)]
MM_TILES = [(32, 128, 128), (64, 256, 128), (8, 128, 512)]


@pytest.mark.parametrize("shape", MM_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_shapes(shape, dtype):
    M, N, K = shape
    k1, k2 = jax.random.split(jax.random.PRNGKey(M + N + K))
    x = jax.random.normal(k1, (M, K), dtype)
    w = jax.random.normal(k2, (K, N), dtype)
    y = ops.matmul(x, w, tiles=(64, 128, 128), interpret=True)
    yr = ref.matmul_ref(x, w)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    assert y.shape == (M, N)
    assert _rel_err(y.astype(jnp.float32), yr.astype(jnp.float32)) < tol


@pytest.mark.parametrize("tiles", MM_TILES)
def test_matmul_tile_invariance(tiles):
    """Property: the result must not depend on the tile choice."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    x = jax.random.normal(k1, (96, 160), jnp.float32)
    w = jax.random.normal(k2, (160, 192), jnp.float32)
    y0 = ops.matmul(x, w, tiles=(96, 192, 160), interpret=True)
    y1 = ops.matmul(x, w, tiles=tiles, interpret=True)
    assert _rel_err(y1, y0) < 1e-5


@settings(max_examples=15, deadline=None)
@given(m=st.integers(1, 96), n=st.integers(1, 160), k=st.integers(1, 128),
       bm=st.sampled_from([8, 16, 32, 64]),
       bn=st.sampled_from([128, 256]),
       bk=st.sampled_from([128, 256]))
def test_matmul_property(m, n, k, bm, bn, bk):
    k1, k2 = jax.random.split(jax.random.PRNGKey(m * 7 + n * 3 + k))
    x = jax.random.normal(k1, (m, k), jnp.float32)
    w = jax.random.normal(k2, (k, n), jnp.float32)
    y = ops.matmul(x, w, tiles=(bm, bn, bk), interpret=True)
    assert y.shape == (m, n)
    assert _rel_err(y, ref.matmul_ref(x, w)) < 1e-4


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("hq,hkv", [(4, 4), (4, 2), (8, 1)])
@pytest.mark.parametrize("tiles", [(64, 128), (128, 128)])
def test_flash_attention(causal, hq, hkv, tiles):
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (2, hq, 256, 64))
    k = jax.random.normal(jax.random.fold_in(key, 1), (2, hkv, 256, 64))
    v = jax.random.normal(jax.random.fold_in(key, 2), (2, hkv, 256, 64))
    y = ops.flash_attention(q, k, v, causal=causal, scale=0.125,
                            tiles=tiles, interpret=True)
    rep = hq // hkv
    yr = ref.attention_ref(q, jnp.repeat(k, rep, 1), jnp.repeat(v, rep, 1),
                           causal=causal, scale=0.125)
    assert float(jnp.max(jnp.abs(y - yr))) < 2e-5


@settings(max_examples=8, deadline=None)
@given(sq=st.sampled_from([64, 128, 256]), d=st.sampled_from([32, 64]),
       bq=st.sampled_from([32, 64]), bkv=st.sampled_from([64, 128]),
       causal=st.booleans())
def test_flash_property(sq, d, bq, bkv, causal):
    key = jax.random.PRNGKey(sq + d)
    q = jax.random.normal(key, (1, 2, sq, d))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 2, sq, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 2, sq, d))
    y = ops.flash_attention(q, k, v, causal=causal, scale=d ** -0.5,
                            tiles=(bq, bkv), interpret=True)
    yr = ref.attention_ref(q, k, v, causal=causal, scale=d ** -0.5)
    assert float(jnp.max(jnp.abs(y - yr))) < 2e-5


# ---------------------------------------------------------------------------
# chunk scan (SSD)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("chunk", [16, 32, 128])
def test_chunk_scan(chunk):
    key = jax.random.PRNGKey(1)
    G, S, P, N = 3, 128, 32, 16
    x = jax.random.normal(key, (G, S, P))
    Bm = jax.random.normal(jax.random.fold_in(key, 1), (G, S, N)) * 0.3
    Cm = jax.random.normal(jax.random.fold_in(key, 2), (G, S, N)) * 0.3
    la = -jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 3),
                                            (G, S)))
    y = ops.chunk_scan(x, Bm, Cm, la, chunk=chunk, interpret=True)
    yr = ref.chunk_scan_ref(x, Bm, Cm, la)
    assert _rel_err(y, yr) < 1e-4


def test_chunk_scan_chunk_invariance():
    """Chunk size is a pure performance knob — results must agree."""
    key = jax.random.PRNGKey(2)
    G, S, P, N = 2, 64, 16, 8
    x = jax.random.normal(key, (G, S, P))
    Bm = jax.random.normal(jax.random.fold_in(key, 1), (G, S, N)) * 0.3
    Cm = jax.random.normal(jax.random.fold_in(key, 2), (G, S, N)) * 0.3
    la = -jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 3),
                                            (G, S)))
    outs = [ops.chunk_scan(x, Bm, Cm, la, chunk=c, interpret=True)
            for c in (8, 16, 64)]
    for o in outs[1:]:
        assert _rel_err(o, outs[0]) < 1e-4


# ---------------------------------------------------------------------------
# action-space sweeps: the full DEFAULT tile grid, deduplicated by the
# kernels' internal clamping (what the measurement runner executes)
# ---------------------------------------------------------------------------

# non-pow2 test shape: stresses padding under every tile
_MM_SHAPE = (48, 160, 136)


def _mm_sweep():
    M, N, K = _MM_SHAPE
    eff = {(min(bm, _ceil_mult(M, 8)), min(bn, _ceil_mult(N, 128)),
            min(bk, _ceil_mult(K, 128)))
           for bm, bn, bk in itertools.product(
               NV.bm_choices, NV.bn_choices, NV.bk_choices)}
    return sorted(eff)


@pytest.mark.parametrize("tiles", _mm_sweep())
def test_matmul_action_space_sweep(tiles):
    M, N, K = _MM_SHAPE
    k1, k2 = jax.random.split(jax.random.PRNGKey(42))
    x = jax.random.normal(k1, (M, K), jnp.float32)
    w = jax.random.normal(k2, (K, N), jnp.float32)
    y = ops.matmul(x, w, tiles=tiles, interpret=True)
    assert y.shape == (M, N)
    assert _rel_err(y, ref.matmul_ref(x, w)) < 1e-5


# Rectangular Sq != Skv: kernel, XLA path, and ref all share bottom-right
# aligned causal semantics (query row i sees keys 0..i + Skv - Sq), so the
# sweep covers cross-attention shapes too.  Skv >= Sq: under bottom-right
# alignment a query block with Sq > Skv would attend to nothing, which the
# ref softmax maps to NaN — not a shape the model layer ever emits.
_ATTN_SQ, _ATTN_SKV, _ATTN_D = 128, 256, 64


def _attn_sweep():
    eff = {(min(bq, _ATTN_SQ), min(bkv, _ATTN_SKV))
           for bq, bkv in itertools.product(NV.bq_choices, NV.bkv_choices)}
    return sorted(eff)


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("tiles", _attn_sweep())
def test_attention_action_space_sweep(tiles, causal):
    key = jax.random.PRNGKey(7)
    q = jax.random.normal(key, (1, 2, _ATTN_SQ, _ATTN_D))
    k = jax.random.normal(jax.random.fold_in(key, 1),
                          (1, 2, _ATTN_SKV, _ATTN_D))
    v = jax.random.normal(jax.random.fold_in(key, 2),
                          (1, 2, _ATTN_SKV, _ATTN_D))
    y = ops.flash_attention(q, k, v, causal=causal,
                            scale=_ATTN_D ** -0.5, tiles=tiles,
                            interpret=True)
    yr = ref.attention_ref(q, k, v, causal=causal, scale=_ATTN_D ** -0.5)
    assert float(jnp.max(jnp.abs(y - yr))) < 2e-5


_SCAN_S = 128


@pytest.mark.parametrize("chunk",
                         sorted({min(c, _SCAN_S) for c in NV.chunk_choices}))
def test_chunk_scan_action_space_sweep(chunk):
    key = jax.random.PRNGKey(11)
    G, S, P, N = 2, _SCAN_S, 32, 16
    x = jax.random.normal(key, (G, S, P))
    Bm = jax.random.normal(jax.random.fold_in(key, 1), (G, S, N)) * 0.3
    Cm = jax.random.normal(jax.random.fold_in(key, 2), (G, S, N)) * 0.3
    la = -jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 3),
                                            (G, S)))
    y = ops.chunk_scan(x, Bm, Cm, la, chunk=chunk, interpret=True)
    assert _rel_err(y, ref.chunk_scan_ref(x, Bm, Cm, la)) < 1e-4


# ---------------------------------------------------------------------------
# the XLA flash path (custom VJP) vs oracle — gradients included
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("sq,skv", [(128, 128), (64, 128)])
def test_mem_efficient_attention_grads(causal, sq, skv):
    from repro.models import compute
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (2, 4, sq, 32))
    k = jax.random.normal(jax.random.fold_in(key, 1), (2, 2, skv, 32))
    v = jax.random.normal(jax.random.fold_in(key, 2), (2, 2, skv, 32))

    def fn(q, k, v):
        return compute.flash_attention(q, k, v, site="t", causal=causal,
                                       q_chunk=32, kv_chunk=64).sum()

    def naive(q, k, v):
        ke, ve = jnp.repeat(k, 2, 1), jnp.repeat(v, 2, 1)
        return ref.attention_ref(q, ke, ve, causal=causal,
                                 scale=32 ** -0.5).sum()

    g1 = jax.grad(fn, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(naive, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        assert float(jnp.max(jnp.abs(a - b))) < 1e-4
