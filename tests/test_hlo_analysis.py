"""The loop-aware HLO analyzer must stay exact on closed-form programs —
it is the source of every roofline number (EXPERIMENTS.md §Roofline)."""
import json
import os
import subprocess
import sys

import pytest

_PROBE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, json
sys.path.insert(0, "src")
import jax, jax.numpy as jnp
from repro.launch.hlo_analysis import analyze

w = jax.ShapeDtypeStruct((512, 512), jnp.float32)
x = jax.ShapeDtypeStruct((512, 512), jnp.float32)

def f(w, x):
    def body(x, _):
        return jnp.tanh(x @ w), None
    x, _ = jax.lax.scan(body, x, None, length=10)
    return (x @ w).sum()

def g(w, x):   # nested scans: 3 x 5 dots
    def outer(x, _):
        def inner(x, _):
            return x @ w, None
        x, _ = jax.lax.scan(inner, x, None, length=5)
        return x, None
    x, _ = jax.lax.scan(outer, x, None, length=3)
    return x.sum()

def h(w, x):   # grad through remat scan: 10 fwd + 10 recompute + 20 bwd
    body = jax.checkpoint(lambda x, _: (jnp.tanh(x @ w), None),
                          policy=jax.checkpoint_policies.nothing_saveable)
    y, _ = jax.lax.scan(body, x, None, length=10)
    return (y ** 2).sum()

D = 2 * 512 ** 3
out = {}
out["flat"] = analyze(jax.jit(f).lower(w, x).compile().as_text())["flops"] / (11 * D)
out["nested"] = analyze(jax.jit(g).lower(w, x).compile().as_text())["flops"] / (15 * D)
out["remat_grad"] = analyze(
    jax.jit(jax.grad(h)).lower(w, x).compile().as_text())["flops"] / (40 * D)

from jax.sharding import PartitionSpec as P, NamedSharding
mesh = jax.make_mesh((8,), ("d",))
c = jax.jit(f, in_shardings=(NamedSharding(mesh, P(None, "d")),
                             NamedSharding(mesh, P("d", None)))).lower(
    w, x).compile()
res = analyze(c.as_text())
out["sharded"] = res["flops"] / (11 * D / 8)
out["has_collectives"] = res["collectives"]["total"] > 0
print(json.dumps(out))
"""


@pytest.mark.slow
def test_analyzer_exact_on_closed_forms():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _PROBE], capture_output=True,
                       text=True, env=env, cwd=os.path.dirname(
                           os.path.dirname(os.path.abspath(__file__))))
    assert r.returncode == 0, r.stderr[-2000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    for key in ("flat", "nested", "remat_grad", "sharded"):
        assert abs(out[key] - 1.0) < 0.05, (key, out[key])
    assert out["has_collectives"]
