"""The ``repro.artifacts`` persistence layer (PR 5): agent checkpoints
(atomic, fingerprinted, corruption-rejecting), the ``ProgramStore``
warm-start cache, and the facade/service wiring on top.

THE acceptance invariant lives here: ``load(save(nv)).tune_sites(S)`` is
bitwise-identical to ``nv.tune_sites(S)``, and a second tune of the same
site set through a ``ProgramStore`` performs zero agent inferences and
zero oracle evaluations."""
import json
import os

import numpy as np
import pytest

from repro.api import (CostModelEnv, NeuroVecConfig, NeuroVectorizer,
                       TileProgram, make_agent)
from repro.artifacts import (ArtifactError, ProgramStore, agent_fingerprint,
                             load_agent, program_key, read_agent_state,
                             save_agent, tune_through_store)
from repro.core import dataset
from repro.service import TuningService

NV = NeuroVecConfig(train_batch=64, sgd_minibatch=32, ppo_epochs=2)
ENV = CostModelEnv(NV)
SITES = dataset.generate(8, seed=21)
OTHER = dataset.generate(5, seed=22)


class CountingOracle:
    """CostModelEnv wrapper counting every oracle evaluation — proves the
    store's hit path never consults the reward source."""

    def __init__(self, cfg):
        self._env = CostModelEnv(cfg)
        self.calls = 0

    def __getattr__(self, name):
        attr = getattr(self._env, name)
        if name in ("baseline_costs", "costs_batch", "rewards_batch",
                    "speedups_batch", "cost_grid", "tiles_costs"):
            def counted(*a, **k):
                self.calls += 1
                return attr(*a, **k)
            return counted
        return attr


class CountingAgent:
    """Protocol agent whose act() counts inferences."""

    name = "polly"          # reuse a registry name: key stability not at issue

    def __init__(self, cfg):
        self._inner = make_agent("polly", cfg)
        self.act_calls = 0

    def fit(self, sites, oracle, **kw):
        self._inner.fit(sites, oracle, **kw)
        return self

    def act(self, sites, *, sample=False):
        self.act_calls += 1
        return self._inner.act(sites, sample=sample)

    def state_dict(self):
        return self._inner.state_dict()

    def load_state(self, state):
        self._inner.load_state(state)
        return self


# ---------------------------------------------------------------------------
# agent checkpoint format
# ---------------------------------------------------------------------------

def test_agent_artifact_fingerprint_mismatch_rejected(tmp_path):
    agent = make_agent("ppo", NV, seed=0).fit(SITES, ENV, total_steps=64)
    art = str(tmp_path / "a")
    save_agent(agent, art)
    # tamper with the array payload: the manifest fingerprint no longer
    # matches and the load must refuse
    npz = os.path.join(art, "state.npz")
    data = bytearray(open(npz, "rb").read())
    data[len(data) // 2] ^= 0xFF
    with open(npz, "wb") as f:
        f.write(bytes(data))
    # the flipped byte lands either in a compressed block (zip/zlib layer
    # rejects) or in plain array bytes (the fingerprint check rejects) —
    # both are refusals, never a silently-wrong policy
    import zipfile
    import zlib
    with pytest.raises((ArtifactError, zipfile.BadZipFile, zlib.error,
                        OSError, ValueError)):
        load_agent(art, cfg=NV, seed=0)


def test_agent_artifact_tampered_json_rejected(tmp_path):
    agent = make_agent("random", NV, seed=3).fit([], ENV)
    art = str(tmp_path / "a")
    save_agent(agent, art)
    sj = os.path.join(art, "state.json")
    state = json.load(open(sj))
    state["seed"] = 999                      # silent behaviour change
    with open(sj, "w") as f:
        json.dump(state, f)
    with pytest.raises(ArtifactError, match="fingerprint mismatch"):
        load_agent(art, cfg=NV, seed=3)


def test_agent_artifact_missing_manifest_not_restorable(tmp_path):
    agent = make_agent("baseline", NV).fit(SITES, ENV)
    art = str(tmp_path / "a")
    save_agent(agent, art)
    os.remove(os.path.join(art, "manifest.json"))   # "interrupted save"
    with pytest.raises(ArtifactError, match="manifest.json missing"):
        read_agent_state(art)
    with pytest.raises(ArtifactError, match="no restorable"):
        load_agent(str(tmp_path / "never-written"))


def test_agent_state_name_version_validation():
    ppo = make_agent("ppo", NV, seed=0)
    state = make_agent("random", NV, seed=0).state_dict()
    with pytest.raises(ValueError, match="cannot load into"):
        ppo.load_state(state)
    bad = ppo.state_dict()
    bad["version"] = 999
    with pytest.raises(ValueError, match="version"):
        ppo.load_state(bad)


def test_ppo_state_mode_mismatch_rejected():
    a = make_agent("ppo", NV, seed=0)
    b = make_agent("ppo", NV, seed=0, mode="cont1")
    with pytest.raises(ValueError, match="mode"):
        b.load_state(a.state_dict())


def test_fit_changes_agent_fingerprint():
    a = make_agent("ppo", NV, seed=0)
    fp0 = agent_fingerprint(a)
    a.fit(SITES, ENV, total_steps=64)
    assert agent_fingerprint(a) != fp0   # training invalidates store keys


# ---------------------------------------------------------------------------
# the ProgramStore
# ---------------------------------------------------------------------------

def test_program_store_roundtrip_and_last_wins(tmp_path):
    p = str(tmp_path / "progs.jsonl")
    store = ProgramStore(p)
    prog = TileProgram({"a|1": (128, 256, 512), "b|2": (64, 1, 1)})
    store.put("k1", prog)
    store.put("k1", TileProgram({"a|1": (8, 128, 128)}))    # re-tune
    store.close()

    s2 = ProgramStore(p)
    assert len(s2) == 1
    got = s2.get("k1")
    assert got.tiles == {"a|1": (8, 128, 128)}              # last wins
    assert all(isinstance(v, tuple) for v in got.tiles.values())
    assert s2.get("nope") is None
    assert s2.stats()["hits"] == 1 and s2.stats()["misses"] == 1


def test_program_store_corrupted_file_recovery(tmp_path):
    p = str(tmp_path / "progs.jsonl")
    good = {"k": "ok", "v": {"s|1": [16, 128, 128]}}
    with open(p, "w") as f:
        f.write(json.dumps(good) + "\n")
        f.write("not json at all\n")
        f.write('{"k": "torn", "v": {"s|1": [16,\n')        # torn write
        f.write('{"no_key": 1}\n')
        f.write('{"k": "badv", "v": "not-a-mapping"}\n')
        f.write('{"k": "badtile", "v": {"s|1": ["x", 1, 2]}}\n')
    store = ProgramStore(p)
    assert store.skipped_lines == 5
    assert store.get("ok").tiles == {"s|1": (16, 128, 128)}
    store.put("fresh", TileProgram({"t|2": (8, 1, 1)}))     # still writable
    store.close()
    assert ProgramStore(p).get("fresh").tiles == {"t|2": (8, 1, 1)}


def test_program_key_discriminates_all_three_coordinates():
    a1 = make_agent("polly", NV).fit([], ENV)
    k = program_key(SITES, a1, ENV)
    # site set: order-insensitive, content-sensitive
    assert program_key(list(reversed(SITES)), a1, ENV) == k
    assert program_key(OTHER, a1, ENV) != k
    # agent state: a differently-trained agent must not share entries
    p0 = make_agent("ppo", NV, seed=0)
    p1 = make_agent("ppo", NV, seed=0)
    assert program_key(SITES, p0, ENV) == program_key(SITES, p1, ENV)
    p1.fit(SITES, ENV, total_steps=64)
    assert program_key(SITES, p0, ENV) != program_key(SITES, p1, ENV)
    # oracle: a different config fingerprint must miss
    other_env = CostModelEnv(NeuroVecConfig(illegal_slowdown=25.0))
    assert program_key(SITES, a1, other_env) != k


def test_store_hit_performs_zero_inferences_and_zero_oracle_evals(tmp_path):
    store = ProgramStore(str(tmp_path / "p.jsonl"))
    agent = CountingAgent(NV)
    oracle = CountingOracle(NV)
    agent.fit(SITES, oracle)

    prog1, hit1 = tune_through_store(SITES, agent, ENV.space, oracle, store)
    assert not hit1 and agent.act_calls == 1
    oracle.calls = 0
    prog2, hit2 = tune_through_store(SITES, agent, ENV.space, oracle, store)
    assert hit2
    assert agent.act_calls == 1          # zero agent inferences
    assert oracle.calls == 0             # zero oracle evaluations
    assert prog2.tiles == prog1.tiles
    store.close()


# ---------------------------------------------------------------------------
# facade: save/load + program_store + close()
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ("ppo", "dtree", "nns", "brute", "random",
                                  "polly", "baseline"))
def test_facade_save_load_roundtrip_invariant(name, tmp_path):
    nv = NeuroVectorizer(NV, agent=name, seed=0)
    fit_kw = {"total_steps": 96} if name == "ppo" else {}
    nv.fit(SITES, **fit_kw)
    p1 = nv.tune_sites(SITES)

    art = str(tmp_path / "facade")
    nv.save(art)
    nv2 = NeuroVectorizer.load(art)
    assert nv2.cfg == NV
    p2 = nv2.tune_sites(SITES)
    assert p2.tiles == p1.tiles          # THE round-trip invariant


def test_facade_load_shares_program_store_across_facades(tmp_path):
    store_path = str(tmp_path / "progs.jsonl")
    art = str(tmp_path / "facade")
    nv = NeuroVectorizer(NV, agent="ppo", seed=0,
                         program_store=store_path)
    nv.fit(SITES, total_steps=96)
    p1 = nv.tune_sites(SITES)
    assert nv.store_misses == 1 and nv.agent_inferences == len(SITES)
    nv.save(art)
    nv.close()

    # a "fresh process": load the artifact, reuse the store — pure lookup
    nv2 = NeuroVectorizer.load(art, program_store=store_path)
    p2 = nv2.tune_sites(SITES)
    assert p2.tiles == p1.tiles
    assert nv2.store_hits == 1 and nv2.agent_inferences == 0
    # an unseen site set still tunes (and is appended)
    p3 = nv2.tune_sites(OTHER)
    assert nv2.store_misses == 1 and nv2.agent_inferences == len(OTHER)
    assert len(p3.tiles) == len(OTHER)
    nv2.close()


def test_facade_closed_raises_clear_runtime_error(tmp_path):
    nv = NeuroVectorizer(NV, agent="polly",
                         program_store=str(tmp_path / "p.jsonl"))
    nv.fit(SITES)
    nv.close()
    nv.close()                                       # idempotent
    with pytest.raises(RuntimeError, match="closed"):
        nv.tune_sites(SITES)
    with pytest.raises(RuntimeError, match="closed"):
        nv.fit(SITES)


def test_save_agent_resave_keeps_artifact_restorable(tmp_path):
    # re-saving over an existing artifact is a whole-directory swap: the
    # refreshed artifact must load (no torn old/new file mix)
    art = str(tmp_path / "a")
    agent = make_agent("ppo", NV, seed=0)
    save_agent(agent, art)
    agent.fit(SITES, ENV, total_steps=64)
    fp2 = save_agent(agent, art)
    loaded = load_agent(art, cfg=NV, seed=0)
    assert agent_fingerprint(loaded) == fp2
    assert not [d for d in os.listdir(tmp_path)
                if ".tmp-" in d or ".old-" in d]    # staging cleaned up


def test_facade_save_rejects_handbuilt_embedding_agent(tmp_path):
    # a hand-passed embed_fn is a live callable: save must refuse loudly
    # instead of letting load() silently rebuild with the default embedder
    agent = make_agent("nns", NV, seed=0).fit(SITES, ENV)
    nv = NeuroVectorizer(NV, agent=agent)
    with pytest.raises(ArtifactError, match="embed_fn"):
        nv.save(str(tmp_path / "f"))
    # ...but the same fitted agent saved via the registry path round-trips,
    # and load(agent=) restores into a caller-constructed instance
    nv2 = NeuroVectorizer(NV, agent="nns", seed=0)
    nv2.agent.load_state(agent.state_dict())
    art = str(tmp_path / "g")
    nv2.save(art)
    fresh = make_agent("nns", NV, seed=0)
    nv3 = NeuroVectorizer.load(art, agent=fresh)
    assert nv3.agent is fresh
    assert nv3.tune_sites(SITES).tiles == nv2.tune_sites(SITES).tiles


def test_facade_load_model_override_skips_transport_requirement(tmp_path):
    # a custom-transport recipe must not block loading under a model
    # oracle override that never touches a transport
    from repro.measure import InProcessTransport

    class Spy:
        backend_key = "spy-backend"

        def __call__(self, sites, tiles):
            return np.full(len(sites), 1e-3)

    t = InProcessTransport(Spy())
    nv = NeuroVectorizer(NV, agent="polly", oracle="measured", transport=t)
    nv.fit(SITES)
    art = str(tmp_path / "f")
    nv.save(art)
    with pytest.raises(ArtifactError, match="hand-built"):
        NeuroVectorizer.load(art)                    # measured needs it
    nv2 = NeuroVectorizer.load(art, oracle="model")  # model does not
    assert len(nv2.tune_sites(SITES).tiles) == len(SITES)
    t.close()


def test_facade_save_rejects_custom_oracle_on_load(tmp_path):
    nv = NeuroVectorizer(NV, agent="polly", oracle=CostModelEnv(NV))
    nv.fit(SITES)
    art = str(tmp_path / "facade")
    nv.save(art)
    with pytest.raises(ArtifactError, match="hand-built Oracle"):
        NeuroVectorizer.load(art)
    # an explicit override re-assembles fine
    nv2 = NeuroVectorizer.load(art, oracle=CostModelEnv(NV))
    assert nv2.tune_sites(SITES).tiles == nv.tune_sites(SITES).tiles


# ---------------------------------------------------------------------------
# service: warm sessions over one shared store
# ---------------------------------------------------------------------------

def test_service_sessions_share_store_and_warm_start_ckpt(tmp_path):
    art = str(tmp_path / "agent")
    fitted = make_agent("ppo", NV, seed=0).fit(SITES, ENV, total_steps=96)
    save_agent(fitted, art)
    expect = np.asarray(fitted.act(SITES, sample=False))

    store_path = str(tmp_path / "progs.jsonl")
    with TuningService(NV, transport="inproc",
                       program_store=store_path) as svc:
        s1 = svc.open_session(agent="ppo", oracle="model", agent_ckpt=art)
        # the checkpointed policy acts identically without any fit
        np.testing.assert_array_equal(
            np.asarray(s1.agent.act(SITES, sample=False)), expect)
        p1 = s1.tune(SITES)
        assert s1.stats()["session_store_misses_total"] == 1
        # a SECOND warm session from the same ckpt: same fingerprint,
        # same store -> lookup, zero inferences
        s2 = svc.open_session(agent="ppo", oracle="model", agent_ckpt=art)
        p2 = s2.tune(SITES)
        st = s2.stats()
        assert st["session_store_hits_total"] == 1
        assert st["session_agent_inferences_total"] == 0
        assert p2.tiles == p1.tiles


def test_open_session_rejects_bad_ckpt(tmp_path):
    with TuningService(NV) as svc:
        with pytest.raises(ArtifactError, match="no restorable"):
            svc.open_session(agent="ppo", oracle="model",
                             agent_ckpt=str(tmp_path / "nope"))
