"""The ``repro.measure`` subsystem: timing helper, measurement DB
(round-trip, key collisions, corruption recovery, zero re-timing),
runner fail-closed behaviour, and the assembled measured oracle."""
import json

import numpy as np
import pytest

from repro.configs.neurovec import NeuroVecConfig
from repro.measure import (CachedMeasureFn, MeasureDB, MeasureRunner,
                           make_key, make_measured_env, timing)
from repro.models.compute import KernelSite

SMALL = NeuroVecConfig(
    bm_choices=(8, 16), bn_choices=(128,), bk_choices=(128,),
    bq_choices=(64,), bkv_choices=(128,), chunk_choices=(32,))

MM = KernelSite(site="t.mm", kind="matmul", m=32, n=128, k=128)
ATTN = KernelSite(site="t.attn", kind="attention", m=64, n=32, k=64,
                  batch=2, causal=True)
SCAN = KernelSite(site="t.scan", kind="chunk_scan", m=32, n=16, k=8,
                  batch=2)


class SpyRunner:
    """Counting measure_fn with a stable backend fingerprint."""

    backend_key = "spy-backend"

    def __init__(self, value=1e-3):
        self.value = value
        self.calls = 0
        self.pairs = 0

    def __call__(self, sites, tiles):
        self.calls += 1
        self.pairs += len(sites)
        return np.full(len(sites), self.value, np.float64)


# ---------------------------------------------------------------------------
# timing helper
# ---------------------------------------------------------------------------

def test_median_time_basic():
    t = timing.median_time(lambda: sum(range(1000)), reps=3, warmup=1)
    assert t >= 0.0 and np.isfinite(t)
    with pytest.raises(ValueError):
        timing.median_time(lambda: None, reps=0)


def test_interleaved_medians_shapes():
    ta, tb = timing.interleaved_medians(lambda: 1, lambda: 2, reps=3)
    assert ta >= 0.0 and tb >= 0.0


# ---------------------------------------------------------------------------
# the persistent DB
# ---------------------------------------------------------------------------

def test_db_roundtrip(tmp_path):
    p = str(tmp_path / "m.jsonl")
    db = MeasureDB(p)
    k1 = make_key(MM.key(), (16, 128, 128), "b")
    k2 = make_key(ATTN.key(), (64, 128, 1), "b")
    db.put(k1, 1.5e-3)
    db.put(k2, float("inf"))            # failed measurement persists too
    db.close()

    db2 = MeasureDB(p)
    assert len(db2) == 2
    assert db2.get(k1) == pytest.approx(1.5e-3)
    assert db2.get(k2) == float("inf")  # null round-trips to inf
    assert db2.get("missing") is None
    assert db2.skipped_lines == 0


def test_db_key_collision_safety_dtype(tmp_path):
    # two sites differing ONLY in dtype must never share an entry
    a = KernelSite(site="x", kind="matmul", m=64, n=128, k=128,
                   dtype="bfloat16")
    b = KernelSite(site="x", kind="matmul", m=64, n=128, k=128,
                   dtype="float32")
    t = (16, 128, 128)
    ka, kb = make_key(a.key(), t, "be"), make_key(b.key(), t, "be")
    assert ka != kb
    db = MeasureDB(str(tmp_path / "m.jsonl"))
    db.put(ka, 1.0)
    db.put(kb, 2.0)
    assert db.get(ka) == 1.0 and db.get(kb) == 2.0
    # same site, different backend fingerprint: also distinct
    assert make_key(a.key(), t, "other") != ka


def test_db_corrupted_file_recovery(tmp_path):
    p = str(tmp_path / "m.jsonl")
    good1 = {"k": "a", "v": 1.0}
    good2 = {"k": "b", "v": None}
    with open(p, "w") as f:
        f.write(json.dumps(good1) + "\n")
        f.write("this is not json\n")
        f.write('{"k": "truncated", "v": 0.\n')      # torn write
        f.write('{"no_key_field": 1}\n')
        f.write('{"k": "c", "v": "not-a-number"}\n')
        f.write(json.dumps(good2) + "\n")
    db = MeasureDB(p)
    assert db.get("a") == 1.0
    assert db.get("b") == float("inf")
    assert db.skipped_lines == 4
    db.put("d", 3.0)                     # still writable after recovery
    db.close()
    assert MeasureDB(p).get("d") == 3.0


def test_db_torn_trailing_line_recovery(tmp_path):
    """A crash mid-append leaves a partial record with no newline; the
    next open must keep every intact line AND isolate the torn tail so
    the first new append cannot merge into it."""
    p = str(tmp_path / "m.jsonl")
    with open(p, "w") as f:
        f.write(json.dumps({"k": "a", "v": 1.0}) + "\n")
        f.write(json.dumps({"k": "b", "v": 2.0}) + "\n")
        f.write('{"k": "c", "v": 3.')           # torn: no newline
    db = MeasureDB(p)
    assert db.get("a") == 1.0 and db.get("b") == 2.0
    assert db.get("c") is None
    assert db.skipped_lines == 1
    db.put("d", 3.0)                     # must land on a fresh line
    db.close()
    db2 = MeasureDB(p)
    assert db2.get("d") == 3.0
    assert db2.get("a") == 1.0 and db2.get("b") == 2.0
    assert db2.skipped_lines == 1        # torn tail still isolated, not
    assert len(db2) == 3                 # merged into the new record


def test_db_iter_records_skips_quarantine_and_corruption(tmp_path):
    """``iter_records`` is the surrogate training corpus: finite and
    failed measurements come through (last-wins), quarantined keys and
    corrupt lines never do, and the LRU bound does not hide disk rows."""
    p = str(tmp_path / "m.jsonl")
    kmm = make_key(MM.key(), (16, 128, 128), "spy-backend")
    kat = make_key(ATTN.key(), (64, 128, 1), "spy-backend")
    with open(p, "w") as f:
        f.write(json.dumps({"k": kmm, "v": 1.0}) + "\n")
        f.write("not json at all\n")                    # corrupt: skipped
        f.write(json.dumps({"k": "malformed-key", "v": 2.0}) + "\n")
        f.write(json.dumps({"k": kat, "v": None}) + "\n")
        f.write(json.dumps({"k": kmm, "v": 4.0}) + "\n")  # last-wins
    db = MeasureDB(p, max_entries=1)      # LRU must not limit iteration
    db.quarantine(make_key(SCAN.key(), (32, 1, 1), "spy-backend"),
                  attempts=2, reason="wedged")
    db.put(make_key(MM.key(), (8, 128, 128), "spy-backend"), 5.0)

    recs = {r.key: r for r in db.iter_records()}
    assert kmm in recs and recs[kmm].value == 4.0       # last-wins
    assert recs[kmm].kind == "matmul"
    assert recs[kmm].fingerprint == "spy-backend"
    assert recs[kat].value == float("inf")              # null -> inf
    assert recs[kat].kind == "attention"
    assert "malformed-key" not in recs                  # no 3-part shape
    assert not any("chunk_scan:t.scan" in k for k in recs)  # quarantined
    assert len(recs) == 3                # kmm, kat, and the post-open put
    db.close()
    assert {r.key for r in MeasureDB(p).iter_records()} == set(recs)


def test_db_quarantine_roundtrip_and_lru_survival(tmp_path):
    p = str(tmp_path / "m.jsonl")
    db = MeasureDB(p, max_entries=1)
    db.quarantine("poison", attempts=3, reason="killed workers")
    db.put("x", 1.0)                     # evicts "poison" from the LRU
    db.put("y", 2.0)
    assert db.get("poison") == float("inf")   # survives LRU eviction
    assert db.n_quarantined == 1
    db.close()
    db2 = MeasureDB(p)                   # fresh process analogue
    assert db2.get("poison") == float("inf")
    assert db2.quarantined("poison") == {"attempts": 3,
                                         "reason": "killed workers"}
    assert db2.quarantined("x") is None
    # backward compatible: an old reader sees a plain failed measurement
    rec = json.loads(open(p).readline())
    assert rec["v"] is None and rec["kind"] == "quarantine"


def test_db_duplicate_key_last_wins(tmp_path):
    p = str(tmp_path / "m.jsonl")
    db = MeasureDB(p)
    db.put("k", 1.0)
    db.put("k", 2.0)                     # re-measure appends; load last-wins
    db.close()
    assert MeasureDB(p).get("k") == 2.0


def test_db_lru_bounds_memory_not_disk(tmp_path):
    p = str(tmp_path / "m.jsonl")
    db = MeasureDB(p, max_entries=2)
    for i in range(4):
        db.put(f"k{i}", float(i))
    assert len(db) == 2 and db.get("k3") == 3.0 and db.get("k0") is None
    db.close()
    assert len(MeasureDB(p)) == 4        # disk kept everything


def test_second_run_performs_zero_timings(tmp_path):
    """THE persistence guarantee: same DB path => no runner calls."""
    p = str(tmp_path / "m.jsonl")
    sites = [MM, ATTN, SCAN, MM]                   # duplicate in batch
    tiles = np.array([[16, 128, 128], [64, 128, 1], [32, 1, 1],
                      [16, 128, 128]])

    spy1 = SpyRunner()
    fn1 = CachedMeasureFn(spy1, MeasureDB(p))
    out1 = fn1(sites, tiles)
    # cold DB: the 3 unique pairs are timed once each — the in-batch
    # duplicate coalesces onto the in-flight key (transport semantics)
    assert spy1.pairs == 3 and fn1.misses == 3
    assert fn1.transport.stats()["transport_coalesced_total"] == 1
    np.testing.assert_allclose(out1[3], out1[0])
    fn1.db.close()

    spy2 = SpyRunner(value=99.0)                   # would be visible if run
    fn2 = CachedMeasureFn(spy2, MeasureDB(p))
    out2 = fn2(sites, tiles)
    assert spy2.calls == 0 and spy2.pairs == 0     # zero timings
    assert fn2.hit_rate == 1.0
    np.testing.assert_allclose(out2, out1)


def test_cached_measure_fn_without_db_still_counts():
    spy = SpyRunner()
    fn = CachedMeasureFn(spy, db=None)
    fn([MM], np.array([[16, 128, 128]]))
    fn([MM], np.array([[16, 128, 128]]))
    assert spy.pairs == 2 and fn.misses == 2 and fn.hit_rate == 0.0


# ---------------------------------------------------------------------------
# the runner (interpret mode; tiny caps keep this fast)
# ---------------------------------------------------------------------------

def _tiny_runner(**kw):
    kw.setdefault("reps", 1)
    kw.setdefault("warmup", 1)
    kw.setdefault("interpret", True)
    kw.setdefault("max_dim", 64)
    return MeasureRunner(**kw)


def test_runner_times_every_kind():
    r = _tiny_runner()
    out = r([MM, ATTN, SCAN],
            np.array([[16, 128, 128], [64, 128, 1], [32, 1, 1]]))
    assert out.shape == (3,)
    assert np.isfinite(out).all() and (out > 0).all()
    assert r.timed_pairs == 3 and r.failed_pairs == 0


def test_runner_failure_fails_closed():
    r = _tiny_runner()
    bogus = KernelSite(site="b", kind="unknown_kind", m=8, n=8, k=8)
    out = r([bogus, MM], np.array([[16, 128, 128], [16, 128, 128]]))
    assert out[0] == float("inf")                  # isolated failure
    assert np.isfinite(out[1]) and out[1] > 0      # batch survives
    assert r.failed_pairs == 1 and r.timed_pairs == 1


def test_runner_backend_key_reflects_conditions():
    a = _tiny_runner().backend_key
    b = _tiny_runner(max_dim=32).backend_key
    assert a != b                       # different caps must not share cache
    assert "interpret" in a


# ---------------------------------------------------------------------------
# the assembled measured oracle
# ---------------------------------------------------------------------------

def test_make_measured_env_persistent_stack(tmp_path):
    p = str(tmp_path / "m.jsonl")
    env = make_measured_env(SMALL, db_path=p, reps=1, warmup=1,
                            interpret=True, max_dim=64)
    sites = [MM, ATTN]
    acts = np.array([[1, 0, 0], [0, 0, 0]])
    r = env.rewards_batch(sites, acts)
    assert r.shape == (2,) and np.isfinite(r).all()
    first_timed = env.measure_fn.runner.timed_pairs
    assert first_timed > 0

    # fresh env + runner, same DB: rewards identical, zero timings
    env2 = make_measured_env(SMALL, db_path=p, reps=1, warmup=1,
                             interpret=True, max_dim=64)
    np.testing.assert_allclose(env2.rewards_batch(sites, acts), r)
    assert env2.measure_fn.runner.timed_pairs == 0
    assert env2.measure_fn.hit_rate == 1.0


def test_make_measured_env_rejects_conflicting_args():
    with pytest.raises(TypeError):
        make_measured_env(SMALL, runner=_tiny_runner(), reps=2)
