"""The MeasureTransport contract — ONE conformance suite over every
implementation, plus the pool-specific failure modes (worker death,
requeue, fail-closed, persistent-DB exactly-once semantics).

The pool cases run *real* worker subprocesses speaking the real pipe
protocol; the runners inside them come from ``pool_helpers`` factories
(deterministic values derived from the DB key, so in-process and pool
results are bit-identical — the parity the service tests build on).
"""
import json
import os

import numpy as np
import pytest

from repro.core.protocols import AsyncOracle, MeasureTransport, Oracle
from repro.measure import (InProcessTransport, MeasureDB, TransportMeasureFn,
                           WorkerPoolTransport, make_key, make_measured_env,
                           make_transport)
from repro.models.compute import KernelSite

from pool_helpers import FailRunner, FakeRunner, fake_value

MM = KernelSite(site="t.mm", kind="matmul", m=32, n=128, k=128)
ATTN = KernelSite(site="t.attn", kind="attention", m=64, n=32, k=64,
                  batch=2, causal=True)
SCAN = KernelSite(site="t.scan", kind="chunk_scan", m=32, n=16, k=8,
                  batch=2)
SITES = [MM, ATTN, SCAN]
TILES = np.array([[16, 128, 128], [64, 128, 1], [32, 1, 1]])

TRANSPORTS = ("inproc", "pool")


def _make(kind: str, db_path=None, factory="pool_helpers:deterministic",
          **kw):
    if kind == "inproc":
        runner = kw.pop("runner", None) or FakeRunner()
        assert not kw
        return InProcessTransport(
            runner, MeasureDB(db_path) if db_path else None)
    return WorkerPoolTransport(workers=2, db=db_path, factory=factory, **kw)


# ---------------------------------------------------------------------------
# the shared conformance suite
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", TRANSPORTS)
def test_conformance_protocol_and_values(kind):
    with _make(kind) as t:
        assert isinstance(t, MeasureTransport)
        futs = t.submit(SITES, TILES)
        t.drain()
        assert len(futs) == 3
        for s, tile, f in zip(SITES, TILES, futs):
            assert f.done()
            assert f.result() == fake_value(s.key(), tile)
        st = t.stats()
        assert st["transport_misses_total"] == 3
        assert st["transport_timed_pairs_total"] == 3
        assert st["transport_inflight_pairs"] == 0
        for key in ("transport_hits_total", "transport_misses_total",
                    "transport_coalesced_total",
                    "transport_timed_pairs_total",
                    "transport_failed_pairs_total",
                    "transport_retries_total",
                    "transport_inflight_pairs", "transport_hit_ratio"):
            assert key in st
        for legacy in ("hits", "misses", "timed_pairs", "in_flight",
                       "hit_rate"):
            assert legacy not in st


@pytest.mark.parametrize("kind", TRANSPORTS)
def test_conformance_duplicate_keys_coalesce(kind):
    """The same (site, tiles) key submitted many times in one batch is
    measured exactly once; every future resolves to that value."""
    with _make(kind) as t:
        sites = [MM] * 4 + [ATTN]
        tiles = np.array([[16, 128, 128]] * 4 + [[64, 128, 1]])
        futs = t.submit(sites, tiles)
        t.drain()
        vals = [f.result() for f in futs]
        assert vals[:4] == [fake_value(MM.key(), (16, 128, 128))] * 4
        st = t.stats()
        assert st["transport_misses_total"] == 2
        assert st["transport_coalesced_total"] == 3
        assert st["transport_timed_pairs_total"] == 2


@pytest.mark.parametrize("kind", TRANSPORTS)
def test_conformance_db_hits_and_zero_retiming(kind, tmp_path):
    """Second transport against the same DB path re-times nothing."""
    p = str(tmp_path / "m.jsonl")
    with _make(kind, db_path=p) as t1:
        out1 = [f.result() for f in t1.submit(SITES, TILES)]
    with _make(kind, db_path=p) as t2:
        futs = t2.submit(SITES, TILES)
        out2 = [f.result() for f in futs]
        st = t2.stats()
    assert out2 == out1
    assert st["transport_hits_total"] == 3
    assert st["transport_misses_total"] == 0
    assert st["transport_timed_pairs_total"] == 0
    assert st["transport_hit_ratio"] == 1.0


@pytest.mark.parametrize("kind", TRANSPORTS)
def test_conformance_db_written_exactly_once_per_key(kind, tmp_path):
    """Coalesced duplicates must not produce duplicate DB lines."""
    p = str(tmp_path / "m.jsonl")
    sites = [MM, MM, ATTN, MM]
    tiles = np.array([[16, 128, 128]] * 2 + [[64, 128, 1], [16, 128, 128]])
    with _make(kind, db_path=p) as t:
        t.submit(sites, tiles)
        t.drain()
        backend = t.backend_key
    keys = [json.loads(line)["k"] for line in open(p)]
    assert sorted(keys) == sorted({
        make_key(MM.key(), (16, 128, 128), backend),
        make_key(ATTN.key(), (64, 128, 1), backend)})


@pytest.mark.parametrize("kind", TRANSPORTS)
def test_conformance_failure_fails_closed(kind):
    """A pair the runner cannot measure resolves to inf — never raises."""
    fail = KernelSite(site="fail", kind="matmul", m=32, n=128, k=128)
    t = _make(kind, factory="pool_helpers:failing") if kind == "pool" \
        else _make(kind, runner=FailRunner())
    with t:
        futs = t.submit([fail, MM], np.array([[16, 128, 128]] * 2))
        t.drain()
        assert futs[0].result() == float("inf")
        assert futs[1].result() == fake_value(MM.key(), (16, 128, 128))
        st = t.stats()
        assert st["transport_failed_pairs_total"] == 1
        assert st["transport_timed_pairs_total"] == 1


@pytest.mark.parametrize("kind", TRANSPORTS)
def test_conformance_submit_after_close_raises(kind):
    t = _make(kind)
    t.close()
    with pytest.raises(RuntimeError, match="closed"):
        t.submit([MM], np.array([[16, 128, 128]]))
    t.close()                                      # idempotent


# ---------------------------------------------------------------------------
# pool-specific failure modes
# ---------------------------------------------------------------------------

def test_pool_worker_death_requeues_and_recovers(tmp_path, monkeypatch):
    """A worker killed mid-batch loses one attempt; the requeued job
    succeeds on the respawned worker and the batch completes."""
    sentinel = str(tmp_path / "died_once")
    monkeypatch.setenv("REPRO_TEST_BOOM_FILE", sentinel)
    boom = KernelSite(site="boom", kind="matmul", m=64, n=128, k=128)
    with _make("pool", factory="pool_helpers:boom_once") as t:
        futs = t.submit([boom, MM], np.array([[16, 128, 128]] * 2))
        t.drain()
        assert futs[0].result() == fake_value(boom.key(), (16, 128, 128))
        assert futs[1].result() == fake_value(MM.key(), (16, 128, 128))
        st = t.stats()
        assert st["transport_retries_total"] >= 1
        assert st["pool_worker_restarts_total"] >= 1
        assert st["transport_failed_pairs_total"] == 0
    assert os.path.exists(sentinel)                # it really did die


def test_pool_worker_death_fails_closed_after_max_attempts(tmp_path):
    """A job that kills every worker it lands on burns its attempts and
    resolves inf (persisted, so it is never re-attempted) while
    unrelated jobs survive."""
    p = str(tmp_path / "m.jsonl")
    boom = KernelSite(site="boom", kind="matmul", m=64, n=128, k=128)
    with _make("pool", db_path=p, factory="pool_helpers:boom_always",
               max_attempts=2) as t:
        futs = t.submit([boom, MM], np.array([[16, 128, 128]] * 2))
        t.drain()
        assert futs[0].result() == float("inf")
        assert futs[1].result() == fake_value(MM.key(), (16, 128, 128))
        st = t.stats()
        assert st["transport_retries_total"] == 1  # attempt 1 requeued
        assert st["transport_failed_pairs_total"] == 1
        assert st["transport_timed_pairs_total"] == 1
        backend = t.backend_key
    # the fail-closed verdict is persisted as null -> inf: a later run
    # serves it from the DB instead of crashing more workers
    db = MeasureDB(p)
    assert db.get(make_key(boom.key(), (16, 128, 128),
                           backend)) == float("inf")


def test_pool_cross_submit_inflight_coalescing():
    """A second submit of a key already measuring joins the in-flight
    job instead of queueing a duplicate."""
    with _make("pool", factory="pool_helpers:slow") as t:
        f1 = t.submit([MM], np.array([[16, 128, 128]]))
        f2 = t.submit([MM], np.array([[16, 128, 128]]))   # while in flight
        t.drain()
        assert f1[0] is f2[0]
        assert f1[0].result() == fake_value(MM.key(), (16, 128, 128))
        st = t.stats()
        assert st["transport_misses_total"] == 1
        assert st["transport_coalesced_total"] == 1


def test_pool_raising_runner_fails_closed_without_killing_worker():
    """A runner that raises inside the worker answers the failure
    marker (inf) instead of dying — no respawn, no retry burn."""
    boom = KernelSite(site="boom", kind="matmul", m=64, n=128, k=128)
    with _make("pool", factory="pool_helpers:raising") as t:
        futs = t.submit([boom, MM], np.array([[16, 128, 128]] * 2))
        t.drain()
        assert futs[0].result() == float("inf")
        assert futs[1].result() == fake_value(MM.key(), (16, 128, 128))
        st = t.stats()
        assert st["transport_failed_pairs_total"] == 1
        assert st["transport_retries_total"] == 0
        assert st["pool_worker_restarts_total"] == 0


def test_pool_wedged_worker_hits_job_timeout_and_fails_closed():
    """A measurement that hangs costs one worker per attempt (killed at
    job_timeout, job requeued), then fails closed — drain() returns."""
    wedge = KernelSite(site="wedge", kind="matmul", m=64, n=128, k=128)
    with WorkerPoolTransport(workers=2, factory="pool_helpers:wedging",
                             max_attempts=2, job_timeout=1.5) as t:
        futs = t.submit([wedge, MM], np.array([[16, 128, 128]] * 2))
        t.drain()
        assert futs[0].result() == float("inf")
        assert futs[1].result() == fake_value(MM.key(), (16, 128, 128))
        st = t.stats()
        assert st["transport_failed_pairs_total"] == 1
        assert st["transport_retries_total"] == 1
        assert st["pool_worker_restarts_total"] >= 1


def test_inproc_raising_runner_resolves_futures_before_propagating():
    """A raising runner must not strand in-flight futures (a coalesced
    waiter would hang forever); they fail closed, then the error
    surfaces to the submitting caller."""

    class Boom(FakeRunner):
        def __call__(self, sites, tiles):
            raise RuntimeError("runner bug")

    t = InProcessTransport(Boom())
    with pytest.raises(RuntimeError, match="runner bug"):
        t.submit([MM], np.array([[16, 128, 128]]))
    t.drain()                                      # must not hang
    st = t.stats()
    assert st["transport_failed_pairs_total"] == 1
    assert st["transport_inflight_pairs"] == 0
    # the key is re-submittable (not stuck on a dead in-flight future)
    t.runner = FakeRunner()
    futs = t.submit([MM], np.array([[16, 128, 128]]))
    assert futs[0].result() == fake_value(MM.key(), (16, 128, 128))
    t.close()


def test_pool_rejects_bad_arguments():
    with pytest.raises(ValueError, match="workers"):
        WorkerPoolTransport(workers=0)
    with pytest.raises(ValueError, match="max_attempts"):
        WorkerPoolTransport(workers=1, max_attempts=0)
    with pytest.raises(RuntimeError, match="failed to start"):
        WorkerPoolTransport(workers=1,
                            factory="pool_helpers:no_such_factory")


# ---------------------------------------------------------------------------
# the factories and adapters around transports
# ---------------------------------------------------------------------------

def test_make_transport_validation():
    with pytest.raises(ValueError, match="unknown transport"):
        make_transport("carrier-pigeon")
    with pytest.raises(ValueError, match="workers"):
        make_transport("inproc", workers=4)
    with pytest.raises(ValueError, match="workers"):
        make_transport("pool", workers=0)          # not coerced to default
    with pytest.raises(TypeError, match="db"):
        make_transport("inproc", db=MeasureDB("/tmp/x.jsonl"),
                       db_path="/tmp/y.jsonl")
    with pytest.raises(TypeError, match="runner"):
        make_transport("pool", runner=FakeRunner())
    t = make_transport("inproc", runner=FakeRunner())
    assert isinstance(t, InProcessTransport)
    t.close()


def test_make_measured_env_rejects_args_with_prebuilt_transport():
    t = InProcessTransport(FakeRunner())
    with pytest.raises(TypeError, match="pre-built transport"):
        make_measured_env(transport=t, db_path="/tmp/x.jsonl")
    with pytest.raises(TypeError, match="pre-built transport"):
        make_measured_env(transport=t, reps=3)
    t.close()


def test_transport_measure_fn_adapts_any_transport():
    with InProcessTransport(FakeRunner()) as t:
        fn = TransportMeasureFn(t)
        out = fn(SITES, TILES)
        assert out.shape == (3,)
        np.testing.assert_allclose(
            out, [fake_value(s.key(), tl) for s, tl in zip(SITES, TILES)])
        assert fn.misses == 3 and fn.hits == 0


def test_async_oracle_delegates_and_submits():
    from repro.configs.neurovec import NeuroVecConfig
    from repro.core.env import CostModelEnv, MeasuredEnv

    cfg = NeuroVecConfig(bm_choices=(8, 16), bn_choices=(128,),
                         bk_choices=(128,), bq_choices=(64,),
                         bkv_choices=(128,), chunk_choices=(32,))
    t = InProcessTransport(FakeRunner())
    env = MeasuredEnv(cfg, measure_fn=TransportMeasureFn(t))
    ao = AsyncOracle(env, t)
    assert isinstance(ao, Oracle)
    assert ao.cfg is cfg and ao.space is env.space

    tiles = np.array([[16, 128, 128]])
    futs = ao.submit_tiles([MM], tiles)
    ao.drain()
    # the async path and the synchronous Oracle path price identically
    np.testing.assert_allclose([f.result() for f in futs],
                               ao.tiles_costs([MM], tiles))

    # a purely synchronous oracle adapts too — but has no async path
    sync = AsyncOracle(CostModelEnv(cfg))
    assert isinstance(sync, Oracle)
    with pytest.raises(RuntimeError, match="no transport"):
        sync.submit_tiles([MM], tiles)
    sync.drain()                                   # no-op, must not raise
    ao.close()
