"""Parity + regression suite for the vectorized cost-model engine.

The batched oracle (``repro.core.costmodel_vec``) must agree with the
scalar reference model to ~1e-9 relative on every legal tile, mark every
VMEM-illegal tile as ``inf``, and the consumers built on top of it
(baseline cache, batched rewards, brute-force argmin, jit-cached PPO
paths) must match their scalar ancestors exactly.
"""
import itertools

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_compat import given, settings, st

from repro.configs.neurovec import NeuroVecConfig
from repro.core import costmodel, costmodel_vec, dataset
from repro.core import env as env_mod
from repro.core.agents import PPOAgent, brute_force_action, brute_force_labels
from repro.core.agents.brute import brute_force_costs
from repro.core.env import ActionSpace, CostModelEnv
from repro.models.compute import KernelSite

NV = NeuroVecConfig(train_batch=256, sgd_minibatch=64, ppo_epochs=4)
ENV = CostModelEnv(NV)
SPACE = ENV.space


def _scalar_brute(env, site):
    """The original interpreted brute force (reference implementation)."""
    best_a, best_c = (0, 0, 0), float("inf")
    for a in itertools.product(*(range(s)
                                 for s in env.space.valid_sizes(site.kind))):
        c = env.cost(site, a)
        if c is not None and c < best_c:
            best_a, best_c = a, c
    return best_a, best_c


# ---------------------------------------------------------------------------
# grid parity: vectorized vs scalar cost over the full action space
# ---------------------------------------------------------------------------

def test_cost_grid_matches_scalar_on_random_corpus():
    sites = dataset.generate(120, seed=42)
    grid = ENV.cost_grid(sites)
    for i, s in enumerate(sites):
        n_a = SPACE.n_actions(s.kind)
        for j, a in enumerate(itertools.product(
                *(range(n) for n in SPACE.valid_sizes(s.kind)))):
            c = costmodel.site_cost(s, SPACE.tiles(s.kind, a))
            if c is None:
                assert np.isinf(grid[i, j]), (s, a)
            else:
                assert abs(grid[i, j] - c) <= 1e-9 * c, (s, a, c, grid[i, j])
        assert np.isinf(grid[i, n_a:]).all()     # padding never legal


@settings(max_examples=40, deadline=None)
@given(m=st.integers(3, 20), n=st.integers(5, 15), k=st.integers(5, 15),
       dt=st.integers(0, 1), kind=st.integers(0, 2), b=st.integers(0, 8))
def test_cost_vec_property_parity(m, n, k, dt, kind, b):
    dtype = ("bfloat16", "float32")[dt]
    kindname = ("matmul", "attention", "chunk_scan")[kind]
    site = KernelSite(site="p", kind=kindname, m=2 ** m, n=2 ** n, k=2 ** k,
                      batch=2 ** b, dtype=dtype, causal=bool(m % 2))
    grid = costmodel_vec.cost_grid_kind(SPACE, [site], kindname)[0]
    for j, a in enumerate(itertools.product(
            *(range(x) for x in SPACE.valid_sizes(kindname)))):
        c = costmodel.site_cost(site, SPACE.tiles(kindname, a))
        if c is None:
            assert np.isinf(grid[j])
        else:
            assert abs(grid[j] - c) <= 1e-9 * c


def test_cost_vec_no_int64_overflow_at_huge_dims():
    # byte/grid products exceed int64 for dims ~2^22+; the engine must
    # promote to float64 and keep parity with the arbitrary-precision
    # scalar model (regression: values wrapped negative and flipped labels)
    for kind, big in (("matmul", dict(m=2 ** 22, n=2 ** 22, k=2 ** 22)),
                      ("attention", dict(m=2 ** 22, n=128, k=2 ** 22,
                                         batch=2 ** 18)),
                      ("chunk_scan", dict(m=2 ** 20, n=512, k=512,
                                          batch=2 ** 22))):
        site = KernelSite(site="huge", kind=kind, causal=True, **big)
        grid = costmodel_vec.cost_grid_kind(SPACE, [site], kind)[0]
        assert (grid[np.isfinite(grid)] > 0).all()
        for j, a in enumerate(itertools.product(
                *(range(x) for x in SPACE.valid_sizes(kind)))):
            c = costmodel.site_cost(site, SPACE.tiles(kind, a))
            if c is None:
                assert np.isinf(grid[j])
            else:
                assert abs(grid[j] - c) <= 1e-9 * c, (kind, a, c, grid[j])


def test_baseline_costs_vectorized_parity():
    sites = dataset.generate(200, seed=43)
    vec = costmodel_vec.baseline_costs(sites)
    ref = np.array([costmodel.baseline_cost(s) for s in sites])
    np.testing.assert_allclose(vec, ref, rtol=1e-9)


def test_rewards_and_costs_batch_match_scalar_env():
    sites = dataset.generate(150, seed=44)
    rng = np.random.default_rng(0)
    actions = np.stack([[rng.integers(0, n)
                         for n in SPACE.valid_sizes(s.kind)] for s in sites])
    env_v = CostModelEnv(NV, vectorized=True)
    env_s = CostModelEnv(NV, vectorized=False)
    np.testing.assert_allclose(env_v.rewards_batch(sites, actions),
                               env_s.rewards_batch(sites, actions),
                               rtol=1e-6, atol=1e-7)
    cv = env_v.costs_batch(sites, actions)
    cs = env_s.costs_batch(sites, actions)
    np.testing.assert_array_equal(np.isinf(cv), np.isinf(cs))
    legal = np.isfinite(cv)
    np.testing.assert_allclose(cv[legal], cs[legal], rtol=1e-9)


def test_rewards_batch_noise_matches_scalar_rng_stream():
    nv = NeuroVecConfig(reward_noise=0.05)
    sites = dataset.generate(40, seed=60)
    # include an illegal action so the streams would diverge if the
    # vectorized path drew noise for penalty entries (regression)
    actions = [[0, 0, 0] for _ in sites]
    actions[3] = [len(nv.bm_choices) - 1, len(nv.bn_choices) - 1,
                  len(nv.bk_choices) - 1]
    big = KernelSite(site="t", kind="matmul", m=65536, n=16384, k=16384)
    sites[3] = big
    env_v = CostModelEnv(nv, seed=7, vectorized=True)
    env_s = CostModelEnv(nv, seed=7, vectorized=False)
    np.testing.assert_allclose(env_v.rewards_batch(sites, actions),
                               env_s.rewards_batch(sites, actions),
                               rtol=1e-6, atol=1e-7)


def test_short_action_rows_raise_like_scalar():
    s = KernelSite(site="t", kind="matmul", m=512, n=512, k=512)
    with pytest.raises(IndexError):
        ENV.costs_batch([s], np.zeros((1, 2), np.int64))
    with pytest.raises(IndexError):
        ENV.rewards_batch([s], np.zeros((1,), np.int64))


def test_speedups_batch_matches_scalar_speedup_on_both_paths():
    sites = dataset.generate(30, seed=61)
    rng = np.random.default_rng(2)
    actions = np.stack([[rng.integers(0, n)
                         for n in SPACE.valid_sizes(s.kind)] for s in sites])
    for vec in (True, False):
        env = CostModelEnv(NV, vectorized=vec)
        ref = np.array([env.speedup(s, a) for s, a in zip(sites, actions)])
        np.testing.assert_allclose(env.speedups_batch(sites, actions), ref,
                                   rtol=1e-9)


def test_rewards_batch_empty_and_penalty():
    assert ENV.rewards_batch([], np.zeros((0, 3))).shape == (0,)
    s = KernelSite(site="t", kind="matmul", m=65536, n=16384, k=16384)
    a = [[len(NV.bm_choices) - 1, len(NV.bn_choices) - 1,
          len(NV.bk_choices) - 1]]
    assert ENV.rewards_batch([s], a)[0] == NV.fail_penalty


# ---------------------------------------------------------------------------
# baseline cache
# ---------------------------------------------------------------------------

def test_baseline_cache_hit_and_invalidation():
    env = CostModelEnv(NV)
    s = KernelSite(site="c", kind="matmul", m=4096, n=4096, k=4096)
    ref = costmodel.baseline_cost(s)
    assert env.baseline_cost(s) == ref
    assert s.key() in env._baseline_cache
    # poison the cache entry: a hit must return it (proving no recompute)
    env._baseline_cache[s.key()] = 123.0
    assert env.baseline_cost(s) == 123.0
    assert env.baseline_costs([s])[0] == 123.0
    # invalidation restores the true value
    env.clear_baseline_cache()
    assert env.baseline_cost(s) == ref


def test_baseline_batch_fills_cache_vectorized():
    env = CostModelEnv(NV)
    sites = dataset.generate(60, seed=45)
    out = env.baseline_costs(sites)
    ref = np.array([costmodel.baseline_cost(s) for s in sites])
    np.testing.assert_allclose(out, ref, rtol=1e-9)
    assert len(env._baseline_cache) == len({s.key() for s in sites})


# ---------------------------------------------------------------------------
# brute force: argmin over the cost tensor == interpreted search
# ---------------------------------------------------------------------------

def test_brute_force_action_matches_scalar_search():
    for s in dataset.generate(40, seed=46):
        ref_a, ref_c = _scalar_brute(ENV, s)
        a, c = brute_force_action(ENV, s)
        assert tuple(a) == tuple(ref_a), (s, a, ref_a)
        assert c == pytest.approx(ref_c, rel=1e-9)


def test_brute_force_labels_batch_matches_per_site():
    sites = dataset.generate(50, seed=47)
    labels = brute_force_labels(ENV, sites)
    assert labels.shape == (len(sites), 3)
    for i, s in enumerate(sites):
        assert tuple(labels[i]) == tuple(brute_force_action(ENV, s)[0])
    costs = brute_force_costs(ENV, sites)
    for i, s in enumerate(sites):
        assert costs[i] == pytest.approx(brute_force_action(ENV, s)[1],
                                         rel=1e-9)


def test_brute_force_all_illegal_returns_inf():
    # chunk_scan holds the full (P, N) state in VMEM for every Q, so huge
    # state dims make every action illegal — the documented inf contract
    s = KernelSite(site="t", kind="chunk_scan", m=256, n=4096, k=4096,
                   batch=64)
    grid = ENV.cost_grid([s])[0]
    assert np.isinf(grid).all()
    a, c = brute_force_action(ENV, s)
    assert np.isinf(c) and tuple(a) == (0, 0, 0)
    assert tuple(a) == tuple(_scalar_brute(ENV, s)[0])
    # and a normal site still returns the finite grid minimum
    s2 = KernelSite(site="t", kind="matmul", m=64, n=64, k=64)
    a2, c2 = brute_force_action(ENV, s2)
    assert np.isfinite(c2) and c2 == ENV.cost_grid([s2])[0].min()


# ---------------------------------------------------------------------------
# strict action mode (the clamp-hides-masking-bugs fix)
# ---------------------------------------------------------------------------

def test_tiles_clamps_by_default_and_raises_in_strict_mode():
    assert SPACE.tiles("matmul", (99, 0, 0)) == \
        SPACE.tiles("matmul", (len(NV.bm_choices) - 1, 0, 0))
    with pytest.raises(IndexError):
        SPACE.tiles("matmul", (99, 0, 0), strict=True)
    with pytest.raises(IndexError):
        SPACE.tiles("attention", (0, 0, 1), strict=True)   # padded head
    # config-level strict
    strict_space = ActionSpace(NeuroVecConfig(strict_actions=True))
    with pytest.raises(IndexError):
        strict_space.tiles("matmul", (0, 99, 0))
    # process-level strict covers the batched path too
    env_mod.set_strict_actions(True)
    try:
        with pytest.raises(IndexError):
            ENV.costs_batch([KernelSite(site="t", kind="matmul",
                                        m=512, n=512, k=512)], [[99, 0, 0]])
    finally:
        env_mod.set_strict_actions(False)
    # valid actions are unaffected in strict mode
    assert SPACE.tiles("matmul", (0, 0, 0), strict=True) == \
        SPACE.tiles("matmul", (0, 0, 0))


# ---------------------------------------------------------------------------
# PPO: greedy act must not retrace; tail minibatch must not be dropped
# ---------------------------------------------------------------------------

def test_greedy_act_does_not_retrace_across_calls():
    agent = PPOAgent(NV, seed=0)
    sites = dataset.generate(16, seed=48)
    a1 = agent.act(sites, sample=False)
    assert agent.trace_counts["greedy"] == 1
    for _ in range(3):
        a2 = agent.act(sites, sample=False)
    assert agent.trace_counts["greedy"] == 1, "greedy path retraced"
    np.testing.assert_array_equal(a1, a2)      # deterministic
    # a different batch size may trace once more, but stays cached after
    agent.act(dataset.generate(8, seed=49), sample=False)
    agent.act(dataset.generate(8, seed=50), sample=False)
    assert agent.trace_counts["greedy"] == 2


def test_update_includes_tail_minibatch():
    agent = PPOAgent(NV, seed=1)
    env = CostModelEnv(NV)
    sites = dataset.generate(70, seed=51)      # 70 % 64 = 6-sample tail
    feats = agent.feats(sites)
    a, raw, logp, v = agent.sample_actions(sites, feats=feats)
    r = env.rewards_batch(sites, a)
    agent.update(sites, a, raw, logp, r, feats=feats)
    # 1 full minibatch + 1 tail minibatch per epoch
    assert agent.last_minibatch_count == NV.ppo_epochs * 2
    # divisible batch: all-full single-dispatch path
    sites = dataset.generate(128, seed=52)
    feats = agent.feats(sites)
    a, raw, logp, v = agent.sample_actions(sites, feats=feats)
    r = env.rewards_batch(sites, a)
    agent.update(sites, a, raw, logp, r, feats=feats)
    assert agent.last_minibatch_count == NV.ppo_epochs * 2


def test_fused_and_legacy_update_both_learn():
    sites = dataset.generate(120, seed=53)
    env = CostModelEnv(NV)
    for fused in (True, False):
        agent = PPOAgent(NV, lr=5e-4, seed=0, fused=fused)
        hist = agent.train(sites, env, total_steps=1500)
        first = np.mean([h["reward_mean"] for h in hist[:2]])
        last = np.mean([h["reward_mean"] for h in hist[-2:]])
        assert last > first, (fused, first, last)
