"""``repro.fleet`` — the cross-host socket transport + artifact service.

The acceptance seam mirrors PR 6's: the transport conformance suite in
``test_transport.py`` is imported *unmodified* and re-run with its
``_make`` factory swapped for one that puts a real localhost
:class:`~repro.fleet.MeasureServer` (fronting the same inner transport
flavors) behind a :class:`~repro.fleet.SocketTransport` — every contract
invariant must hold across a genuine TCP hop.  The chaos variant then
re-runs the suite with a :class:`ChaosRunner` pool *behind* the socket
and a :class:`FaultInjectionTransport` in front of it.

On top of that: the fleet-specific failure modes (backend-fingerprint
rejection, server killed mid-batch, connection reset without
double-timing, fleet-down vs host-down), the shared artifact service
(push invalidation, pull fallback via ``ProgramStore.refresh``,
versioned keep-N GC), and the hardened wire framing.
"""
import inspect
import io
import json
import os
import socket
import struct
import subprocess
import sys
import time

import numpy as np
import pytest

from repro.artifacts import ProgramStore, open_program_store
from repro.core.vectorizer import TileProgram
from repro.fleet import (ArtifactServer, MeasureServer, RemoteMeasureDB,
                         RemoteProgramStore, SocketTransport,
                         complete_versions, parse_address, write_version)
from repro.measure import (TRANSPORT_NAMES, FaultInjectionTransport,
                           InProcessTransport, WorkerPoolTransport,
                           make_transport, open_measure_db)
from repro.measure.wire import MAX_FRAME_BYTES, read_frame, write_frame
from repro.models.compute import KernelSite

import test_transport as tt
from pool_helpers import FailRunner, FakeRunner, fake_value

# ---------------------------------------------------------------------------
# wire hardening (satellite): framing must reject garbage, not allocate it
# ---------------------------------------------------------------------------


def _frame_bytes(msg) -> bytes:
    buf = io.BytesIO()
    write_frame(buf, msg)
    return buf.getvalue()


def test_wire_rejects_absurd_length_prefix():
    # ASCII garbage read as a big-endian length decodes to gigabytes;
    # the cap turns that into a loud error instead of an allocation
    assert struct.unpack(">I", b"garb")[0] > MAX_FRAME_BYTES
    with pytest.raises(ValueError, match="exceeds cap"):
        read_frame(io.BytesIO(b"garbage that is not a frame"))


def test_wire_cap_is_tunable_and_enforced_on_both_sides():
    msg = {"pad": "x" * 100}
    with pytest.raises(ValueError, match="exceeds cap"):
        read_frame(io.BytesIO(_frame_bytes(msg)), max_bytes=16)
    with pytest.raises(ValueError, match="refusing to write"):
        write_frame(io.BytesIO(), msg, max_bytes=16)
    # at the cap is fine — the bound is on the payload, not the message
    data = _frame_bytes(msg)
    assert read_frame(io.BytesIO(data), max_bytes=len(data) - 4) == msg


def test_wire_truncation_is_eof_and_non_utf8_is_value_error():
    data = _frame_bytes({"type": "job", "id": 7})
    assert read_frame(io.BytesIO(data)) == {"type": "job", "id": 7}
    assert read_frame(io.BytesIO(b"")) is None          # clean EOF
    for cut in range(1, len(data)):                      # torn anywhere
        with pytest.raises(EOFError):
            read_frame(io.BytesIO(data[:cut]))
    bad = struct.pack(">I", 4) + b"\xff\xfe\xfd\xfc"     # length OK, bytes not
    with pytest.raises(ValueError):
        read_frame(io.BytesIO(bad))


def test_wire_fuzz_garbage_never_hangs_or_overallocates():
    """Random byte soup must always resolve to clean-EOF / EOFError /
    ValueError — never a hang, huge allocation, or foreign exception."""
    rng = np.random.RandomState(0)
    for trial in range(300):
        blob = rng.bytes(int(rng.randint(0, 64)))
        try:
            msg = read_frame(io.BytesIO(blob))
        except (EOFError, ValueError):
            continue
        assert msg is None                               # only empty input


# ---------------------------------------------------------------------------
# ProgramStore.refresh (satellite): the pull half of store invalidation
# ---------------------------------------------------------------------------


def test_program_store_refresh_sees_other_writers(tmp_path):
    p = str(tmp_path / "progs.jsonl")
    with ProgramStore(p) as a, ProgramStore(p) as b:
        b.put("k1", TileProgram({"s": (16, 128, 128)}))
        assert a.get("k1") is None                       # not seen yet
        assert a.refresh() == 1
        assert a.get("k1").tiles == {"s": (16, 128, 128)}
        assert a.refresh() == 0                          # nothing new
        # own appends re-applied idempotently (last-wins), not skipped
        a.put("k2", TileProgram({"s": (8, 128, 128)}))
        b.refresh()
        assert b.get("k2").tiles == {"s": (8, 128, 128)}


def test_program_store_refresh_skips_garbage_and_leaves_torn_tail(tmp_path):
    p = str(tmp_path / "progs.jsonl")
    with ProgramStore(p) as a:
        line = json.dumps({"k": "k1", "v": {"s": [16, 128, 128]}}) + "\n"
        with open(p, "a") as f:
            f.write("not json\n" + line[:10])            # torn mid-record
        assert a.refresh() == 0                          # tail unconsumed
        assert a.skipped_lines == 1
        with open(p, "a") as f:
            f.write(line[10:])                           # writer finishes
        assert a.refresh() == 1
        assert a.get("k1").tiles == {"s": (16, 128, 128)}
        assert a.skipped_lines == 1                      # no double count


# ---------------------------------------------------------------------------
# localhost fleet fixtures
# ---------------------------------------------------------------------------

_CLEANUP = []


def _track(obj):
    _CLEANUP.append(obj)
    return obj


def _start_worker(inner, **kw) -> MeasureServer:
    srv = MeasureServer(inner, **kw)
    srv.start()
    _track(srv)
    _track(inner)
    return srv


@pytest.fixture(autouse=True)
def _fleet_cleanup():
    yield
    while _CLEANUP:
        _CLEANUP.pop().close()


def _socket_make(kind, db_path=None, factory="pool_helpers:deterministic",
                 **kw):
    """``tt._make`` stand-in: the same inner transport flavors, behind a
    real localhost ``MeasureServer``; the DB attaches on the *client*
    (exactly-once and zero-retiming semantics are client-side)."""
    if kind == "inproc":
        runner = kw.pop("runner", None) or FakeRunner()
        assert not kw
        inner = InProcessTransport(runner)
    else:
        inner = WorkerPoolTransport(workers=2, factory=factory, **kw)
    srv = _start_worker(inner)
    return SocketTransport([srv.address], db=db_path,
                           backoff_base=0.05, backoff_cap=0.2)


CONFORMANCE = [f for name, f in sorted(vars(tt).items())
               if name.startswith("test_conformance_")]


@pytest.mark.parametrize("kind", tt.TRANSPORTS)
@pytest.mark.parametrize("case", CONFORMANCE, ids=lambda c: c.__name__)
def test_conformance_suite_over_socket(case, kind, tmp_path, monkeypatch):
    """The unmodified transport contract suite, across a real TCP hop."""
    monkeypatch.setattr(tt, "_make", _socket_make)
    kwargs = ({"tmp_path": tmp_path}
              if "tmp_path" in inspect.signature(case).parameters else {})
    case(kind, **kwargs)


def _chaos_socket_make(kind, db_path=None,
                       factory="pool_helpers:deterministic", **kw):
    """Chaos variant: a ChaosRunner worker pool *behind* the socket, a
    FaultInjectionTransport in front of it."""
    seed = int(os.environ["REPRO_CHAOS_SEED"])
    os.environ["REPRO_CHAOS_BASE"] = factory
    inner = WorkerPoolTransport(workers=2, factory="pool_helpers:chaos",
                                job_timeout=2.0, **kw)
    srv = _start_worker(inner)
    return FaultInjectionTransport(
        SocketTransport([srv.address], db=db_path,
                        backoff_base=0.05, backoff_cap=0.2), seed=seed)


@pytest.mark.parametrize("case", CONFORMANCE, ids=lambda c: c.__name__)
def test_chaos_conformance_over_socket(case, tmp_path, monkeypatch):
    """Contract suite again, with workers crashing/wedging/tearing frames
    on the far side of the socket."""
    state = tmp_path / "chaos_state"
    state.mkdir()
    monkeypatch.setenv("REPRO_CHAOS_STATE", str(state))
    monkeypatch.setenv("REPRO_CHAOS_SEED", "0")
    monkeypatch.setattr(tt, "_make", _chaos_socket_make)
    kwargs = ({"tmp_path": tmp_path}
              if "tmp_path" in inspect.signature(case).parameters else {})
    case("pool", **kwargs)


# ---------------------------------------------------------------------------
# fleet-specific failure modes
# ---------------------------------------------------------------------------


class _OtherBackendRunner(FakeRunner):
    backend_key = "other-backend"


def test_backend_mismatch_host_is_rejected():
    """Two hosts with different backend fingerprints: whichever wins the
    handshake sets the fleet's backend; the other is rejected for good
    (mixed-hardware timings must never land in one DB)."""
    a = _start_worker(InProcessTransport(FakeRunner()))
    b = _start_worker(InProcessTransport(_OtherBackendRunner()))
    with SocketTransport([a.address, b.address], backoff_base=0.05,
                         backoff_cap=0.2) as t:
        futs = t.submit(tt.SITES, tt.TILES)
        t.drain()
        assert [f.result() for f in futs] == \
            [fake_value(s.key(), tuple(tl))
             for s, tl in zip(tt.SITES, tt.TILES)]
        assert t.backend_key in ("fake-backend", "other-backend")
        for _ in range(200):                             # loser handshakes
            if "rejected" in t.host_states().values():
                break
            time.sleep(0.02)
        states = list(t.host_states().values())
        assert states.count("rejected") == 1
        assert states.count("connected") == 1
        assert t.health() == "degraded"
        assert t.stats()["transport_failed_pairs_total"] == 0


def test_server_killed_mid_batch_fails_over_to_surviving_host():
    """Host A dies with jobs windowed on it: the jobs requeue and finish
    on host B — no pair fails, values exact."""
    a = _start_worker(InProcessTransport(FakeRunner(delay=0.2)))
    b = _start_worker(InProcessTransport(FakeRunner(delay=0.2)))
    sites = [KernelSite(site=f"s{i}", kind="matmul", m=32, n=128, k=128)
             for i in range(8)]
    tiles = np.array([[16, 128, 128]] * 8)
    with SocketTransport([a.address, b.address], max_connect_failures=2,
                         backoff_base=0.05, backoff_cap=0.2) as t:
        futs = t.submit(sites, tiles)
        time.sleep(0.3)                                  # jobs in flight
        a.drop_connections()
        a.close()                                        # host A is gone
        t.drain()
        for s, tl, f in zip(sites, tiles, futs):
            assert f.result() == fake_value(s.key(), tuple(tl))
        st = t.stats()
        assert st["transport_failed_pairs_total"] == 0
        assert st["transport_retries_total"] >= 1
        assert t.host_states()[a.address] in ("gone", "backing_off",
                                              "connecting")


def test_connection_reset_resends_without_double_timing():
    """A connection RST mid-measure: the client re-sends after reconnect
    and the server answers from its idempotency cache — the inner
    transport times the pair exactly once."""
    inner = InProcessTransport(FakeRunner(delay=0.5))
    srv = _start_worker(inner)
    with SocketTransport([srv.address], backoff_base=0.05,
                         backoff_cap=0.2) as t:
        futs = t.submit([tt.MM], np.array([[16, 128, 128]]))
        time.sleep(0.15)
        srv.drop_connections()                           # RST mid-measure
        t.drain()
        assert futs[0].result() == fake_value(tt.MM.key(), (16, 128, 128))
        st = t.stats()
        assert st["transport_retries_total"] >= 1
        assert st["transport_failed_pairs_total"] == 0
    # never re-timed
    assert inner.stats()["transport_timed_pairs_total"] == 1


def test_idle_reset_then_resubmit_reconnects():
    """A reset between batches: the next submit rides the reconnect."""
    srv = _start_worker(InProcessTransport(FakeRunner()))
    with SocketTransport([srv.address], backoff_base=0.05,
                         backoff_cap=0.2) as t:
        f1 = t.submit([tt.MM], np.array([[16, 128, 128]]))
        t.drain()
        assert f1[0].result() == fake_value(tt.MM.key(), (16, 128, 128))
        srv.drop_connections()
        time.sleep(0.1)
        f2 = t.submit([tt.ATTN], np.array([[64, 128, 1]]))
        t.drain()
        assert f2[0].result() == fake_value(tt.ATTN.key(), (64, 128, 1))
        assert t.stats()["transport_failed_pairs_total"] == 0


def test_fleet_down_at_construction_raises():
    """No serve-worker reachable at all is a configuration error (fleet
    down), not a degraded state — fail loudly before accepting work."""
    s = socket.socket()
    s.bind(("127.0.0.1", 0))
    port = s.getsockname()[1]
    s.close()                                            # nobody listening
    with pytest.raises(RuntimeError, match="failed to start"):
        SocketTransport([f"127.0.0.1:{port}"], max_connect_failures=2,
                        backoff_base=0.01, backoff_cap=0.02)


def test_every_host_dying_fails_pending_closed_and_health_down():
    inner = InProcessTransport(FakeRunner(delay=0.4))
    srv = _start_worker(inner)
    t = SocketTransport([srv.address], max_connect_failures=2,
                        backoff_base=0.02, backoff_cap=0.05)
    futs = t.submit([tt.MM, tt.ATTN],
                    np.array([[16, 128, 128], [64, 128, 1]]))
    srv.drop_connections()
    srv.close()                                          # fleet is gone
    t.drain()                                            # must not hang
    assert [f.result() for f in futs] == [float("inf")] * 2
    assert t.stats()["transport_failed_pairs_total"] == 2
    assert t.health() == "down"
    # a submit AFTER the fleet died must fail closed immediately — with
    # no dispatcher left nothing would ever service the queue, so
    # queueing it would hang drain() forever
    [f3] = t.submit([tt.MM], np.array([[32, 128, 128]]))
    assert f3.result(timeout=1) == float("inf")
    t.drain()                                            # still not hung
    t.close()


# ---------------------------------------------------------------------------
# registration + facade wiring
# ---------------------------------------------------------------------------


def test_make_transport_socket_validation():
    assert TRANSPORT_NAMES == ("inproc", "pool", "socket")
    with pytest.raises(ValueError, match="hosts"):
        make_transport("socket")
    with pytest.raises(ValueError, match="socket"):
        make_transport("pool", hosts=["h:1"])
    with pytest.raises(ValueError, match="workers"):
        make_transport("socket", hosts=["h:1"], workers=4)
    with pytest.raises(TypeError, match="serve-worker"):
        make_transport("socket", hosts=["h:1"], reps=3)


def test_parse_address_shapes():
    assert parse_address("h:7761") == ("h", 7761)
    assert parse_address("fleet://h:7761") == ("h", 7761)
    assert parse_address(("h", 7761)) == ("h", 7761)
    with pytest.raises(ValueError, match="host:port"):
        parse_address("nonsense")


def test_facade_socket_transport_end_to_end(tmp_path):
    """``NeuroVectorizer(transport="socket", hosts=[...])`` tunes through
    the fleet with zero facade-code special-casing; the recorded spec
    reloads against the same hosts."""
    from repro.api import NeuroVectorizer

    srv = _start_worker(InProcessTransport(FakeRunner()))
    p = str(tmp_path / "m.jsonl")
    with NeuroVectorizer(_small_cfg(),
                         agent="brute", oracle="measured",
                         transport="socket", hosts=[srv.address],
                         db_path=p) as nv:
        t = nv.oracle.measure_fn.transport
        assert t.backend_key == "fake-backend"
        prog = nv.fit([tt.MM]).tune_sites([tt.MM])
        assert tt.MM.key() in prog.tiles
        assert t.stats()["transport_timed_pairs_total"] > 0
        assert nv._spec["hosts"] == [srv.address]
    # hosts= outside the measured oracle is rejected like its siblings
    with pytest.raises(ValueError, match="hosts"):
        NeuroVectorizer(_small_cfg(), hosts=[srv.address])


def _small_cfg():
    from repro.configs.neurovec import NeuroVecConfig
    return NeuroVecConfig(bm_choices=(16, 32), bn_choices=(128,),
                          bk_choices=(128,), bq_choices=(64,),
                          bkv_choices=(128,), chunk_choices=(32,))


def test_serve_worker_cli_roundtrip(tmp_path):
    """``python -m repro.fleet serve-worker --port 0`` binds, prints its
    ready line, and serves a real client."""
    tests_dir = os.path.dirname(os.path.abspath(__file__))
    src_dir = os.path.join(os.path.dirname(tests_dir), "src")
    env = dict(os.environ,
               PYTHONPATH=os.pathsep.join([src_dir, tests_dir]))
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.fleet", "serve-worker",
         "--host", "127.0.0.1", "--port", "0", "--transport", "inproc",
         "--factory", "pool_helpers:deterministic"],
        stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True,
        env=env)
    try:
        addr = None
        for _ in range(20):
            line = proc.stdout.readline()
            if "ready on" in line:
                addr = line.rsplit("ready on", 1)[1].strip()
                break
        assert addr, "serve-worker never printed its ready line"
        with SocketTransport([addr]) as t:
            futs = t.submit([tt.MM], np.array([[16, 128, 128]]))
            t.drain()
            assert futs[0].result() == fake_value(tt.MM.key(),
                                                  (16, 128, 128))
    finally:
        proc.terminate()
        proc.wait(timeout=10)


# ---------------------------------------------------------------------------
# the shared artifact service
# ---------------------------------------------------------------------------


def test_program_store_push_invalidation_and_pull_fallback(tmp_path):
    """A put through one subscriber reaches the others *without* a
    refresh (push); a write from an unsubscribed local process is picked
    up by ``refresh()`` (pull fallback)."""
    p = str(tmp_path / "p.jsonl")
    art = _track(ArtifactServer(program_store=p))
    art.start()
    url = f"fleet://{art.address}"
    a = _track(open_program_store(url))
    b = _track(open_program_store(url))
    assert isinstance(a, RemoteProgramStore)
    b.put("k1", TileProgram({"s": (16, 128, 128)}))
    for _ in range(200):
        if a.pushes_received:
            break
        time.sleep(0.02)
    assert a.pushes_received >= 1
    assert a.get("k1").tiles == {"s": (16, 128, 128)}    # no refresh needed
    # pull fallback: a plain local writer on the same file
    with ProgramStore(p) as local:
        local.put("k2", TileProgram({"s2": (8, 64, 32)}))
    a.refresh()                                          # server refreshes
    assert a.get("k2").tiles == {"s2": (8, 64, 32)}


def test_remote_measure_db_round_trip_and_quarantine(tmp_path):
    art = _track(ArtifactServer(measure_db=str(tmp_path / "m.jsonl")))
    art.start()
    url = f"fleet://{art.address}"
    d1 = _track(RemoteMeasureDB(url))
    d2 = _track(RemoteMeasureDB(url))
    d1.put("mm|(16, 128, 128)|fake-backend", 0.125)
    d1.quarantine("bad|(1, 1, 1)|fake-backend", 3, "kills workers")
    for _ in range(200):
        if d2.pushes_received >= 2:
            break
        time.sleep(0.02)
    assert d2.get("mm|(16, 128, 128)|fake-backend") == 0.125
    assert d2.get("bad|(1, 1, 1)|fake-backend") == float("inf")
    assert d2.quarantined("bad|(1, 1, 1)|fake-backend")["attempts"] == 3
    # a fresh client syncs the full state at connect
    d3 = _track(RemoteMeasureDB(url))
    assert d3.get("mm|(16, 128, 128)|fake-backend") == 0.125
    assert d3.n_quarantined == 1
    assert [(r.key, r.value) for r in d3.iter_records()] == \
        [("mm|(16, 128, 128)|fake-backend", 0.125)]


def test_fleet_db_gives_second_run_zero_retimings(tmp_path):
    """The acceptance criterion: two fleet clients sharing a
    ``fleet://`` MeasureDB — the second run re-times nothing."""
    art = _track(ArtifactServer(measure_db=str(tmp_path / "m.jsonl")))
    art.start()
    url = f"fleet://{art.address}"
    srv = _start_worker(InProcessTransport(FakeRunner()))
    with SocketTransport([srv.address], db=url) as t1:
        out1 = [f.result() for f in t1.submit(tt.SITES, tt.TILES)]
        t1.drain()
    with SocketTransport([srv.address], db=url) as t2:
        out2 = [f.result() for f in t2.submit(tt.SITES, tt.TILES)]
        st = t2.stats()
    assert out2 == out1
    assert st["transport_hits_total"] == 3
    assert st["transport_timed_pairs_total"] == 0    # zero re-timings


def test_versioned_snapshots_keep_n_and_gc(tmp_path):
    vdir = str(tmp_path / "versions")
    art = _track(ArtifactServer(measure_db=str(tmp_path / "m.jsonl"),
                                program_store=str(tmp_path / "p.jsonl"),
                                versions_dir=vdir, keep_n=2))
    art.start()
    db = _track(RemoteMeasureDB(f"fleet://{art.address}"))
    db.put("k|(8, 8, 8)|b", 0.5)
    for i in range(4):
        art.snapshot()
    kept = complete_versions(vdir)
    assert kept == [2, 3]                                # keep-2 GC'd 0, 1
    for v in kept:
        vd = os.path.join(vdir, f"version_{v:06d}")
        assert os.path.exists(os.path.join(vd, "manifest.json"))
        assert os.path.exists(os.path.join(vd, "measure.jsonl"))
    # an in-progress (manifest-less) version directory is not "complete"
    os.makedirs(os.path.join(vdir, "version_000009"))
    assert complete_versions(vdir) == [2, 3]


def test_instrument_fleet_exports_per_host_series():
    from repro.obs import MetricsRegistry, instrument_transport

    srv = _start_worker(InProcessTransport(FakeRunner()))
    reg = MetricsRegistry()
    with SocketTransport([srv.address]) as t:
        h = instrument_transport(t, reg)
        t.submit(tt.SITES, tt.TILES)
        t.drain()
        snap = reg.snapshot()
        assert snap["fleet_hosts_live"] == 1
        assert snap["fleet_hosts_count"] == 1
        assert snap[f'fleet_host_up{{host="{srv.address}"}}'] == 1.0
        assert snap[f'fleet_host_jobs_total{{host="{srv.address}"}}'] == 3
        assert snap["transport_timed_pairs_total"] == 3
        h.close()
