"""The transport conformance suite, re-run under injected faults.

The tentpole proof of PR 6: every invariant the contract suite in
``test_transport.py`` pins down — exact values, submission-order futures,
duplicate-key coalescing, exactly-once DB writes, fail-closed inf,
drain-never-hangs — must survive workers that crash mid-job, wedge past
``job_timeout``, and tear result frames mid-write.  The suite itself is
imported *unmodified*; only its module-global ``_make`` factory is
swapped for one that wraps every transport in
:class:`~repro.measure.faults.FaultInjectionTransport` and (for the
pool) runs a :class:`~repro.measure.faults.ChaosRunner` inside the real
worker subprocesses.

Faults are deterministic (pure function of seed + event key) and
destructive ones are one-shot, so a retried job recovers within the
pool's attempt budget and the value/DB assertions remain exact.
"""
import inspect
import os

import numpy as np
import pytest

from repro.measure import (ChaosRunner, FaultInjectionTransport,
                           FaultSchedule, InProcessTransport, MeasureDB,
                           WorkerPoolTransport, make_key)

import test_transport as tt
from pool_helpers import FakeRunner, fake_value

SEEDS = (0, 1)


def _chaos_make(kind, db_path=None, factory="pool_helpers:deterministic",
                **kw):
    seed = int(os.environ["REPRO_CHAOS_SEED"])
    if kind == "inproc":
        runner = kw.pop("runner", None) or FakeRunner()
        assert not kw
        inner = InProcessTransport(
            runner, MeasureDB(db_path) if db_path else None)
        return FaultInjectionTransport(inner, seed=seed)
    os.environ["REPRO_CHAOS_BASE"] = factory
    inner = WorkerPoolTransport(workers=2, db=db_path,
                                factory="pool_helpers:chaos",
                                job_timeout=2.0, **kw)
    return FaultInjectionTransport(inner, seed=seed)


# collected as plain callables (not via pytest collection of the other
# module) so each case runs here with the swapped factory
CONFORMANCE = [f for name, f in sorted(vars(tt).items())
               if name.startswith("test_conformance_")]


@pytest.mark.parametrize("seed", SEEDS)
@pytest.mark.parametrize("kind", tt.TRANSPORTS)
@pytest.mark.parametrize("case", CONFORMANCE, ids=lambda c: c.__name__)
def test_conformance_suite_survives_faults(case, kind, seed, tmp_path,
                                           monkeypatch):
    state = tmp_path / "chaos_state"
    state.mkdir()
    monkeypatch.setenv("REPRO_CHAOS_STATE", str(state))
    monkeypatch.setenv("REPRO_CHAOS_SEED", str(seed))
    monkeypatch.setattr(tt, "_make", _chaos_make)
    kwargs = ({"tmp_path": tmp_path}
              if "tmp_path" in inspect.signature(case).parameters else {})
    case(kind, **kwargs)


# ---------------------------------------------------------------------------
# the fault-injection machinery itself
# ---------------------------------------------------------------------------

def test_fault_schedule_is_deterministic_and_seed_sensitive():
    a = FaultSchedule(seed=0)
    b = FaultSchedule(seed=0)
    c = FaultSchedule(seed=1)
    keys = [f"site-{i}|(8, 8, 8)" for i in range(200)]
    draws_a = [a.draw(k) for k in keys]
    assert draws_a == [b.draw(k) for k in keys]      # pure function
    assert draws_a != [c.draw(k) for k in keys]      # seed matters
    fired = [d for d in draws_a if d is not None]
    # ~50% fault rate spread over every fault kind
    assert 40 < len(fired) < 160
    assert set(fired) == set(FaultSchedule().faults)
    with pytest.raises(ValueError, match="period"):
        FaultSchedule(period=0)


def test_fault_injection_transport_is_correctness_invisible():
    """Values, coalescing (future identity), counters and health pass
    through the wrapper untouched; only latency changes."""
    inner = InProcessTransport(FakeRunner())
    t = FaultInjectionTransport(inner, seed=0, noise_s=0.001)
    assert t.backend_key == inner.backend_key
    f = t.submit([tt.MM, tt.MM], np.array([[16, 128, 128]] * 2))
    t.drain()
    assert f[0] is f[1]                              # coalescing intact
    assert f[0].result() == fake_value(tt.MM.key(), (16, 128, 128))
    st = t.stats()
    assert st["transport_misses_total"] == 1
    assert st["transport_coalesced_total"] == 1
    assert "faults_injected" in st
    assert t.health() == "ok"
    t.close()
    assert t.health() == "down"                      # delegated, not local
    with pytest.raises(RuntimeError, match="closed"):
        t.submit([tt.MM], np.array([[16, 128, 128]]))


def test_chaos_runner_noise_never_alters_values(tmp_path):
    """A schedule of pure timing noise returns bit-identical values."""
    state = tmp_path / "state"
    state.mkdir()
    r = ChaosRunner(FakeRunner(), FaultSchedule(seed=3, faults=("noise",)),
                    str(state), noise_s=0.001)
    out = r(tt.SITES, tt.TILES)
    np.testing.assert_array_equal(
        out, [fake_value(s.key(), t) for s, t in zip(tt.SITES, tt.TILES)])
    assert r.backend_key == "fake-backend"


def test_pool_torn_result_frame_requeues_and_recovers(tmp_path,
                                                      monkeypatch):
    """A worker that tears its result frame mid-write costs one attempt;
    the requeued job succeeds on the respawn with the identical value."""
    sentinel = str(tmp_path / "tore_once")
    monkeypatch.setenv("REPRO_TEST_TORN_FILE", sentinel)
    torn = tt.KernelSite(site="torn", kind="matmul", m=64, n=128, k=128)
    with WorkerPoolTransport(workers=2,
                             factory="pool_helpers:torn_once") as t:
        futs = t.submit([torn, tt.MM], np.array([[16, 128, 128]] * 2))
        t.drain()
        assert futs[0].result() == fake_value(torn.key(), (16, 128, 128))
        assert futs[1].result() == fake_value(tt.MM.key(), (16, 128, 128))
        st = t.stats()
        assert st["transport_retries_total"] >= 1
        assert st["pool_worker_restarts_total"] >= 1
        assert st["transport_failed_pairs_total"] == 0
    assert os.path.exists(sentinel)                  # it really tore
