"""``repro.service`` — the TuningService session API, its protocol
parity with the in-process facade path, and the new facade/serve wiring.

The acceptance seam: tuning through ``TuningService`` +
``WorkerPoolTransport(workers=2)`` must yield a ``TileProgram`` identical
to the in-process ``oracle="measured"`` path, and a second run against
the same ``MeasureDB`` must perform zero re-timings.
"""
import numpy as np
import pytest

from repro.api import (NeuroVectorizer, NeuroVecConfig, Oracle,
                       SessionHandle, TileProgram, TuningService,
                       WorkerPoolTransport)
from repro.models.compute import KernelSite
from repro.service import open_session

from pool_helpers import fake_value

SMALL = NeuroVecConfig(
    bm_choices=(16, 32), bn_choices=(128,), bk_choices=(128,),
    bq_choices=(64,), bkv_choices=(128,), chunk_choices=(32,))

MM = KernelSite(site="s.mm", kind="matmul", m=32, n=128, k=128)
ATTN = KernelSite(site="s.attn", kind="attention", m=64, n=32, k=64,
                  batch=2, causal=True)
SITES = [MM, ATTN]

RUNNER_KW = dict(reps=1, warmup=1, interpret=True, max_dim=64)


def _fake_pool(**kw):
    return WorkerPoolTransport(workers=2,
                               factory="pool_helpers:deterministic", **kw)


# ---------------------------------------------------------------------------
# THE acceptance criterion: pool-service parity with the in-process path
# ---------------------------------------------------------------------------

def test_service_pool_parity_with_inproc_measured(tmp_path):
    """Real runners: the in-process measured facade populates the DB;
    the pool-backed service must reproduce the identical TileProgram
    with ZERO re-timings (and vice versa on a shared DB)."""
    p = str(tmp_path / "m.jsonl")
    with NeuroVectorizer(SMALL, agent="brute", oracle="measured",
                         db_path=p, oracle_kwargs=RUNNER_KW) as nv:
        prog_inproc = nv.fit(SITES).tune_sites(SITES)
        t = nv.oracle.measure_fn.transport
        assert t.stats()["transport_timed_pairs_total"] > 0

    with TuningService(SMALL, transport="pool", workers=2, db_path=p,
                       **RUNNER_KW) as svc:
        session = svc.open_session(agent="brute", oracle="measured")
        prog_pool = session.fit(SITES).tune(SITES)
        st = svc.transport.stats()
    assert prog_pool.tiles == prog_inproc.tiles
    assert st["transport_timed_pairs_total"] == 0 \
        and st["transport_misses_total"] == 0   # zero re-timings
    assert st["transport_hits_total"] > 0


def test_service_pool_parity_cold_fake_runners():
    """Deterministic fake runners: pool service and in-process facade
    agree bit-for-bit even with *separate* cold DBs (values derive from
    the key, so this checks the whole decision path, not the cache)."""
    from repro.measure import InProcessTransport
    from pool_helpers import FakeRunner

    with NeuroVectorizer(SMALL, agent="brute", oracle="measured",
                         transport=InProcessTransport(FakeRunner())) as nv:
        prog_inproc = nv.fit(SITES).tune_sites(SITES)
    with TuningService(SMALL, transport=_fake_pool()) as svc:
        prog_pool = svc.open_session(
            agent="brute", oracle="measured").fit(SITES).tune(SITES)
    assert prog_pool.tiles == prog_inproc.tiles


# ---------------------------------------------------------------------------
# the session API
# ---------------------------------------------------------------------------

def test_tune_async_returns_program_future_and_tracks_stats():
    with TuningService(SMALL, transport=_fake_pool()) as svc:
        s = svc.open_session(agent="brute", oracle="measured")
        assert isinstance(s, SessionHandle)
        assert isinstance(s.oracle, Oracle)
        fut = s.fit(SITES).tune_async(SITES)
        prog = fut.result(timeout=120)
        assert isinstance(prog, TileProgram)
        assert set(prog.tiles) == {x.key() for x in SITES}
        st = s.stats()
        assert st["session_tunes_total"] == 1
        assert st["session_sites_tuned_total"] == 2
        assert st["session_inflight_tunes"] == 0
        assert st["transport"]["transport_timed_pairs_total"] > 0
        assert st["transport"]["transport_inflight_pairs"] == 0
        assert st["session_wall_seconds"] > 0 and st["agent"] == "brute"


def test_sessions_share_one_transport_and_its_cache(tmp_path):
    """Two sessions over one pool: the second session's identical sweep
    is served entirely from the shared transport's DB — its stats window
    shows hits, not timings."""
    with TuningService(SMALL,
                       transport=_fake_pool(
                           db=str(tmp_path / "m.jsonl"))) as svc:
        s1 = svc.open_session(agent="brute", oracle="measured")
        p1 = s1.fit(SITES).tune(SITES)
        s2 = svc.open_session(agent="brute", oracle="measured")
        p2 = s2.fit(SITES).tune(SITES)
        assert p1.tiles == p2.tiles
        st2 = s2.stats()["transport"]            # deltas since s2 opened
        assert st2["transport_timed_pairs_total"] == 0
        assert svc.stats()["service_sessions_total"] == 2
    # MeasuredEnv caches per oracle; session 2 has its own env, so its
    # sweep re-queries the transport and must land on the cache
    assert st2["transport_hits_total"] > 0


def test_session_model_oracle_needs_no_transport_traffic():
    with TuningService(SMALL, transport=_fake_pool()) as svc:
        s = svc.open_session(agent="brute", oracle="model")
        prog = s.fit(SITES).tune(SITES)
        assert len(prog.tiles) == 2
        st = svc.transport.stats()
        assert st["transport_misses_total"] == 0      # untouched
        assert s.stats()["transport"]["transport_timed_pairs_total"] == 0


def test_service_validation_and_lifecycle():
    svc = TuningService(SMALL)                        # default inproc
    with pytest.raises(ValueError, match="unknown oracle"):
        svc.open_session(oracle="wat")
    s = svc.open_session(agent="baseline", oracle="model")
    svc.close()
    svc.close()                                       # idempotent
    with pytest.raises(RuntimeError, match="closed"):
        svc.open_session(agent="baseline")
    with pytest.raises(RuntimeError, match="closed"):
        s.tune(SITES)
    with pytest.raises(TypeError, match="pre-built transport"):
        TuningService(SMALL, transport=_fake_pool(), workers=4)


def test_service_borrows_prebuilt_transport_without_closing_it():
    t = _fake_pool()
    with TuningService(SMALL, transport=t) as svc:
        svc.open_session(agent="baseline", oracle="measured")
    # the service is closed; the borrowed transport must still work
    futs = t.submit([MM], np.array([[16, 128, 128]]))
    t.drain()
    assert futs[0].result() == fake_value(MM.key(), (16, 128, 128))
    t.close()


def test_open_session_convenience_wraps_private_service():
    h = open_session(SMALL, agent="baseline", oracle="model")
    prog = h.fit(SITES).tune(SITES)
    assert len(prog.tiles) == 2
    h.service.close()


# ---------------------------------------------------------------------------
# facade + serve wiring
# ---------------------------------------------------------------------------

def test_facade_transport_args_require_measured_oracle():
    with pytest.raises(ValueError, match="oracle='measured'"):
        NeuroVectorizer(SMALL, transport="pool")
    with pytest.raises(ValueError, match="oracle='measured'"):
        NeuroVectorizer(SMALL, oracle="model", workers=2)


def test_facade_close_is_safe_for_model_oracle():
    nv = NeuroVectorizer(SMALL, agent="baseline")
    nv.close()                                        # no-op, must not raise
    with NeuroVectorizer(SMALL, agent="baseline"):
        pass


def test_serve_rejects_bad_measure_flags():
    from repro.launch import serve

    base = ["--arch", "stablelm_3b", "--autotune", "brute", "--measured"]
    with pytest.raises(SystemExit):
        serve.main(base + ["--measure-reps", "0"])
    with pytest.raises(SystemExit):
        serve.main(base + ["--transport", "pool", "--workers", "0"])
    with pytest.raises(SystemExit):
        serve.main(base + ["--transport", "teleport"])
    # warm-start flags apply to the tuning pipeline, not loaded plans
    with pytest.raises(SystemExit):
        serve.main(["--arch", "stablelm_3b", "--agent-ckpt", "/tmp/x"])
    with pytest.raises(SystemExit):
        serve.main(["--arch", "stablelm_3b", "--tiles", "t.json",
                    "--program-store", "/tmp/x.jsonl"])


def test_serve_warns_on_uncovered_sites(capsys):
    from repro.launch import serve

    prog = TileProgram({MM.key(): (16, 128, 128)})
    missing = serve._warn_missing_tiles(prog, SITES)
    assert missing == [ATTN.site]
    err = capsys.readouterr().err
    assert "WARNING" in err and ATTN.site in err and "1/2" in err
    # full coverage: silent
    full = TileProgram({s.key(): (16, 128, 128) for s in SITES})
    assert serve._warn_missing_tiles(full, SITES) == []
    assert capsys.readouterr().err == ""
