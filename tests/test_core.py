"""The paper's system: embedding, environment, agents, vectorizer API."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:                                   # property-based when available ...
    from hypothesis import given, settings, strategies as st
except ImportError:                    # ... deterministic sweep on bare envs
    from _hypothesis_compat import given, settings, st

from repro.configs.neurovec import NeuroVecConfig
from repro.core import costmodel, dataset
from repro.core.agents import (DecisionTreeAgent, NNSAgent, PPOAgent,
                               PollyAgent, RandomAgent, brute_force_action,
                               brute_force_labels)
from repro.core.env import ActionSpace, CostModelEnv
from repro.core import embedding as emb
from repro.core.vectorizer import (TileProgram, baseline_program, inject,
                                   program_speedup, tune)
from repro.models.compute import KernelSite

NV = NeuroVecConfig(train_batch=256, sgd_minibatch=64, ppo_epochs=4)
ENV = CostModelEnv(NV)
SPACE = ENV.space


def _mm(m, n, k, dtype="bfloat16"):
    return KernelSite(site="t", kind="matmul", m=m, n=n, k=k, dtype=dtype)


# ---------------------------------------------------------------------------
# cost model + environment (reward eq. 2, §3.4 penalty)
# ---------------------------------------------------------------------------

def test_baseline_action_reward_is_zero():
    s = _mm(4096, 4096, 4096)
    base = costmodel.baseline_tiles(s)
    # find the action matching the baseline tiles
    for a0, bm in enumerate(NV.bm_choices):
        for a1, bn in enumerate(NV.bn_choices):
            for a2, bk in enumerate(NV.bk_choices):
                if (bm, bn, bk) == base:
                    r = ENV.reward(s, (a0, a1, a2))
                    assert abs(r) < 1e-9
                    return
    pytest.skip("baseline tiles not in action space")


def test_illegal_action_gets_penalty():
    s = _mm(65536, 16384, 16384)
    # the top-corner tiles overflow VMEM ("compile failure", §3.4)
    a = (len(NV.bm_choices) - 1, len(NV.bn_choices) - 1,
         len(NV.bk_choices) - 1)
    tiles = SPACE.tiles("matmul", a)
    assert costmodel.site_cost(s, tiles) is None, tiles
    assert ENV.reward(s, a) == NV.fail_penalty


def test_reward_speedup_consistency():
    s = _mm(8192, 4608, 4608)
    for a in [(0, 0, 0), (3, 1, 2), (4, 2, 3)]:
        r = ENV.reward(s, a)
        sp = ENV.speedup(s, a)
        if ENV.cost(s, a) is not None:
            assert abs(r - (1 - 1 / sp)) < 1e-6


@settings(max_examples=30, deadline=None)
@given(m=st.integers(3, 20), n=st.integers(7, 14), k=st.integers(7, 14),
       a0=st.integers(0, 6), a1=st.integers(0, 2), a2=st.integers(0, 4))
def test_cost_positive_and_monotone_in_work(m, n, k, a0, a1, a2):
    s = _mm(2 ** m, 2 ** n, 2 ** k)
    c = ENV.cost(s, (a0, a1, a2))
    if c is not None:
        assert c > 0
        s2 = _mm(2 ** (m + 1), 2 ** n, 2 ** k)   # 2x the rows
        c2 = ENV.cost(s2, (a0, a1, a2))
        if c2 is not None:
            # more work never costs less (ties occur when both sizes round
            # up to the same padded tile grid)
            assert c2 >= c


def test_cost_scales_with_work_when_not_overhead_bound():
    s1 = _mm(8192, 4096, 4096)
    s2 = _mm(16384, 4096, 4096)
    c1 = ENV.cost(s1, (4, 1, 2))
    c2 = ENV.cost(s2, (4, 1, 2))
    assert c2 > 1.8 * c1


def test_brute_force_is_lower_bound():
    rng = np.random.default_rng(0)
    for s in dataset.generate(20, seed=3):
        _, best = brute_force_action(ENV, s)
        for _ in range(10):
            a = [rng.integers(0, n) for n in SPACE.valid_sizes(s.kind)]
            c = ENV.cost(s, a)
            if c is not None:
                assert c >= best - 1e-12


# ---------------------------------------------------------------------------
# embedding (code2vec analogue)
# ---------------------------------------------------------------------------

def test_featurize_is_name_free_and_deterministic():
    s1 = KernelSite(site="attn.q", kind="matmul", m=512, n=512, k=512)
    s2 = KernelSite(site="totally.different.name", kind="matmul",
                    m=512, n=512, k=512)
    f1, m1 = emb.featurize(s1)
    f2, m2 = emb.featurize(s2)
    np.testing.assert_array_equal(f1, f2)    # identifiers are not features
    np.testing.assert_array_equal(m1, m2)


def test_embedding_shape_and_similarity():
    params = emb.embedder_init(jax.random.PRNGKey(0))
    sites = [_mm(512, 512, 512), _mm(512, 512, 512), _mm(65536, 128, 16384)]
    ctx, mask = emb.featurize_batch(sites)
    vecs = np.asarray(emb.embed_sites(params, jnp.asarray(ctx),
                                      jnp.asarray(mask)))
    assert vecs.shape == (3, emb.EMBED_DIM)
    assert emb.EMBED_DIM == 340              # the paper's code-vector width
    np.testing.assert_allclose(vecs[0], vecs[1], rtol=1e-6)
    assert np.linalg.norm(vecs[0] - vecs[2]) > 1e-3


# ---------------------------------------------------------------------------
# agents
# ---------------------------------------------------------------------------

def test_ppo_learns_to_beat_baseline():
    # the paper's convergence claim: positive mean reward (= beats the
    # heuristic baseline) within ~5k env samples
    sites = dataset.generate(400, seed=11)
    agent = PPOAgent(NV, lr=5e-4, seed=0)
    hist = agent.train(sites, ENV, total_steps=6000)
    first = np.mean([h["reward_mean"] for h in hist[:2]])
    last = np.mean([h["reward_mean"] for h in hist[-2:]])
    assert last > first + 1.0, (first, last)
    assert last > 0.0, "positive reward = beats the heuristic baseline"


def test_ppo_greedy_beats_random_on_heldout():
    train_sites = dataset.generate(400, seed=21)
    test_sites = dataset.generate(60, seed=22)
    agent = PPOAgent(NV, lr=5e-4, seed=1)
    agent.train(train_sites, ENV, total_steps=3000)
    a_rl = agent.act(test_sites, sample=False)
    a_rand = RandomAgent(SPACE, seed=0).act(test_sites)
    sp_rl = np.mean([ENV.speedup(s, a) for s, a in zip(test_sites, a_rl)])
    sp_rand = np.mean([ENV.speedup(s, a)
                       for s, a in zip(test_sites, a_rand)])
    assert sp_rl > sp_rand, (sp_rl, sp_rand)


def test_nns_and_dtree_predict_labels():
    sites = dataset.generate(120, seed=31)
    agent = PPOAgent(NV, seed=2)         # untrained embedder is fine here
    labels = brute_force_labels(ENV, sites)
    nns = NNSAgent(agent.code_vectors).fit(sites, ENV, labels=labels)
    pred = nns.act(sites)                # 1-NN on the training set = exact
    assert (pred == labels).all()
    dt = DecisionTreeAgent(agent.code_vectors).fit(sites, ENV,
                                                   labels=labels)
    pred_dt = dt.act(sites)
    sp_dt = np.mean([ENV.speedup(s, a) for s, a in zip(sites, pred_dt)])
    sp_base = 1.0
    assert sp_dt > sp_base               # better than always-baseline


def test_polly_beats_baseline_on_bandwidth_bound():
    # Polly optimizes locality only: on a bandwidth-bound site it should
    # at least match the heuristic baseline
    s = _mm(65536, 512, 512)
    a = PollyAgent(SPACE).act([s])[0]
    assert ENV.speedup(s, a) >= 0.95


def test_polly_action_export_removed():
    # the deprecated per-site shim completed its removal cycle (PR 6):
    # the supported spelling is make_agent("polly", cfg).act(sites)
    import repro.core.agents as agents
    assert not hasattr(agents, "polly_action")
    assert not hasattr(agents.polly, "polly_action")
    assert "polly_action" not in agents.__all__


# ---------------------------------------------------------------------------
# vectorizer API ("pragma injection")
# ---------------------------------------------------------------------------

def test_tileprogram_roundtrip(tmp_path):
    sites = dataset.generate(5, seed=41)
    prog = baseline_program(sites)
    f = str(tmp_path / "tiles.json")
    prog.save(f)
    prog2 = TileProgram.load(f)
    assert prog.tiles == prog2.tiles


def test_inject_runs_pallas_and_matches_xla():
    from repro.models import compute
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 96))
    w = jax.random.normal(jax.random.PRNGKey(1), (96, 128))
    site = KernelSite(site="mlp.up", kind="matmul", m=64, n=128, k=96,
                      dtype="float32")
    prog = TileProgram({site.key(): (32, 128, 128)})
    y_xla = compute.matmul(x, w, site="mlp.up")
    with inject(prog, interpret=True):
        y_pallas = compute.matmul(x, w, site="mlp.up")
    np.testing.assert_allclose(np.asarray(y_pallas), np.asarray(y_xla),
                               rtol=1e-5, atol=1e-5)


def test_extract_and_tune_end_to_end():
    from repro.core.extractor import extract_arch_sites
    sites = extract_arch_sites("qwen3_8b", batch=4, seq=512)
    assert len(sites) >= 6
    kinds = {s.kind for s in sites}
    assert "matmul" in kinds and "attention" in kinds
    agent = PPOAgent(NV, seed=3)
    prog = tune(sites, agent, SPACE)
    assert set(prog.tiles) == {s.key() for s in sites}
    sp = program_speedup(prog, sites)
    assert sp > 0.05                      # a valid program, even untrained


def test_program_speedup_of_brute_force():
    sites = dataset.generate(30, seed=51)
    actions = [brute_force_action(ENV, s)[0] for s in sites]
    prog = TileProgram({s.key(): SPACE.tiles(s.kind, a)
                        for s, a in zip(sites, actions)})
    sp = program_speedup(prog, sites)
    assert sp > 1.5                       # headroom exists over the baseline
