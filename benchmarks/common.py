"""Shared benchmark plumbing: one trained agent reused across figures."""
from __future__ import annotations

import os
import time

import numpy as np

from repro.configs.neurovec import NeuroVecConfig
from repro.core import dataset
from repro.core.agents import (DecisionTreeAgent, NNSAgent, PPOAgent,
                               RandomAgent, brute_force_action,
                               brute_force_labels, polly_action)
from repro.core.env import CostModelEnv

# benchmark-wide config: paper defaults except a batch small enough for the
# single-core container; FAST=1 trims budgets for CI-style runs
FAST = os.environ.get("BENCH_FAST", "0") == "1"
NV = NeuroVecConfig(train_batch=500, sgd_minibatch=125, ppo_epochs=6)
TRAIN_STEPS = 4_000 if FAST else 30_000
CORPUS_N = 2_000 if FAST else 6_000
LABEL_N = 300 if FAST else 1_200

_cache = {}


def env() -> CostModelEnv:
    if "env" not in _cache:
        _cache["env"] = CostModelEnv(NV)
    return _cache["env"]


def corpus():
    if "corpus" not in _cache:
        base = dataset.arch_sites()
        _cache["corpus"] = dataset.generate(CORPUS_N, seed=0, base=base)
    return _cache["corpus"]


def trained_agent(mode: str = "discrete", lr: float = 5e-4,
                  steps: int = None, seed: int = 0) -> PPOAgent:
    key = ("agent", mode, lr, steps, seed)
    if key not in _cache:
        agent = PPOAgent(NV, mode=mode, lr=lr, seed=seed)
        agent.train(corpus(), env(), total_steps=steps or TRAIN_STEPS)
        _cache[key] = agent
    return _cache[key]


def labeled_subset():
    """Brute-force labels on a training subset (paper §3.5 / §4)."""
    if "labels" not in _cache:
        sites = corpus()[:LABEL_N]
        _cache["labels"] = (sites, brute_force_labels(env(), sites))
    return _cache["labels"]


def workload_time(wl, act_fn) -> float:
    """Total modelled runtime of a workload under a policy; fixed_frac of
    the baseline total is untunable (whole-program measurement, Fig. 8/9)."""
    e = env()
    from repro.core import costmodel
    t_base_sites = sum(costmodel.baseline_cost(s) for s in wl.sites)
    t_base_total = t_base_sites / max(1e-12, (1 - wl.fixed_frac))
    fixed = t_base_total * wl.fixed_frac
    actions = act_fn(list(wl.sites))
    t = fixed
    for s, a in zip(wl.sites, actions):
        c = e.cost(s, a)
        t += c if c is not None else 10 * costmodel.baseline_cost(s)
    return t, t_base_total


def suite_speedups(workloads, act_fn):
    out = []
    for wl in workloads:
        t, t_base = workload_time(wl, act_fn)
        out.append(t_base / t)
    return np.array(out)


def policies_for_fig7():
    """All policies in the paper's Fig. 7, as act(sites) callables."""
    e = env()
    agent = trained_agent()
    sites_l, labels = labeled_subset()
    nns = NNSAgent(agent.code_vectors, sites_l, labels)
    dtree = DecisionTreeAgent(agent.code_vectors, e.space, sites_l, labels)
    rand = RandomAgent(e.space, seed=0)
    return {
        "baseline": lambda ss: [_baseline_action(e, s) for s in ss],
        "random": rand.act,
        "polly": lambda ss: [polly_action(e.space, s) for s in ss],
        "nns": nns.act,
        "dtree": dtree.act,
        "rl": lambda ss: agent.act(ss, sample=False),
        "brute": lambda ss: [brute_force_action(e, s)[0] for s in ss],
    }


def _baseline_action(e, s):
    from repro.core import costmodel
    base = costmodel.baseline_tiles(s)
    ch = e.space.choices(s.kind)
    a = []
    for d in range(3):
        opts = list(ch[d])
        tgt = base[d] if d < len(base) else opts[0]
        a.append(opts.index(tgt) if tgt in opts
                 else int(np.argmin([abs(o - tgt) for o in opts])))
    return a


def timed(fn, *args, n=3):
    t0 = time.time()
    for _ in range(n):
        out = fn(*args)
    return out, (time.time() - t0) / n * 1e6
