"""Shared benchmark plumbing: one trained agent reused across figures.

Every decision method is constructed through the ``repro.api`` registry
(``make_agent``) and scored through the batched Oracle surface — no
ad-hoc per-site loops or duck-typed policy callables.
"""
from __future__ import annotations

import os
import time

import numpy as np

from repro.api import (CostModelEnv, NeuroVecConfig, brute_force_labels,
                       make_agent)
from repro.core import dataset

# benchmark-wide config: paper defaults except a batch small enough for the
# single-core container; FAST=1 trims budgets for CI-style runs
FAST = os.environ.get("BENCH_FAST", "0") == "1"
NV = NeuroVecConfig(train_batch=500, sgd_minibatch=125, ppo_epochs=6)
TRAIN_STEPS = 4_000 if FAST else 30_000
CORPUS_N = 2_000 if FAST else 6_000
LABEL_N = 300 if FAST else 1_200

_cache = {}


def env() -> CostModelEnv:
    if "env" not in _cache:
        _cache["env"] = CostModelEnv(NV)
    return _cache["env"]


def corpus():
    if "corpus" not in _cache:
        base = dataset.arch_sites()
        _cache["corpus"] = dataset.generate(CORPUS_N, seed=0, base=base)
    return _cache["corpus"]


def trained_agent(mode: str = "discrete", lr: float = 5e-4,
                  steps: int = None, seed: int = 0):
    key = ("agent", mode, lr, steps, seed)
    if key not in _cache:
        agent = make_agent("ppo", NV, seed=seed, mode=mode, lr=lr)
        agent.fit(corpus(), env(), total_steps=steps or TRAIN_STEPS)
        _cache[key] = agent
    return _cache[key]


def labeled_subset():
    """Brute-force labels on a training subset (paper §3.5 / §4)."""
    if "labels" not in _cache:
        sites = corpus()[:LABEL_N]
        _cache["labels"] = (sites, brute_force_labels(env(), sites))
    return _cache["labels"]


def workload_time(wl, agent):
    """Total modelled runtime of a workload under an agent; fixed_frac of
    the baseline total is untunable (whole-program measurement, Fig. 8/9).
    One batched oracle evaluation per workload."""
    e = env()
    sites = list(wl.sites)
    t_base = e.baseline_costs(sites)
    t_base_total = float(t_base.sum()) / max(1e-12, (1 - wl.fixed_frac))
    fixed = t_base_total * wl.fixed_frac
    actions = np.asarray(agent.act(sites, sample=False))
    c = e.costs_batch(sites, actions)
    c = np.where(np.isfinite(c), c, float(NV.illegal_slowdown) * t_base)
    return fixed + float(c.sum()), t_base_total


def suite_speedups(workloads, agent):
    out = []
    for wl in workloads:
        t, t_base = workload_time(wl, agent)
        out.append(t_base / t)
    return np.array(out)


def policies_for_fig7():
    """All policies in the paper's Fig. 7, as fitted protocol Agents."""
    e = env()
    ppo = trained_agent()
    sites_l, labels = labeled_subset()
    nns = make_agent("nns", NV, seed=0,
                     embed_fn=ppo.code_vectors).fit(sites_l, e,
                                                    labels=labels)
    dtree = make_agent("dtree", NV, seed=0,
                       embed_fn=ppo.code_vectors).fit(sites_l, e,
                                                      labels=labels)
    return {
        "baseline": make_agent("baseline", NV).fit([], e),
        "random": make_agent("random", NV, seed=0).fit([], e),
        "polly": make_agent("polly", NV).fit([], e),
        "nns": nns,
        "dtree": dtree,
        "rl": ppo,
        "brute": make_agent("brute", NV).fit([], e),
    }


def timed(fn, *args, n=3):
    t0 = time.time()
    for _ in range(n):
        out = fn(*args)
    return out, (time.time() - t0) / n * 1e6
