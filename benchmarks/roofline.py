"""Roofline analysis from the dry-run artifacts (§ROOFLINE in the brief).

    compute term    = HLO_FLOPs / (chips x peak_FLOP/s)
    memory term     = HLO_bytes / (chips x HBM_bw)
    collective term = collective_bytes / (chips x link_bw)

cost_analysis() on the partitioned module reports per-device FLOPs/bytes, and
the collective parser sums per-device operand bytes, so each term is simply
per_device_quantity / per_chip_rate.  MODEL_FLOPS uses 6*N*D (dense) or
6*N_active*D (MoE) with D = tokens per step; the ratio MODEL_FLOPS /
(HLO_FLOPs x chips) exposes remat/overcompute waste.
"""
from __future__ import annotations

import glob
import json
import os

from repro.configs import SHAPES, get_config
from repro.core.costmodel import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

CHIPS = {"16x16": 256, "2x16x16": 512}


def roofline_row(d: dict) -> dict:
    if d.get("status") != "ok":
        return {**{k: d.get(k) for k in ("mesh", "arch", "shape", "status")},
                "reason": d.get("reason", d.get("error", ""))[:90]}
    hlo = d.get("hlo", {})
    flops_dev = hlo.get("flops", d.get("flops", 0.0))
    bytes_dev = hlo.get("bytes", d.get("bytes_accessed", 0.0))
    coll_dev = d.get("collectives", {}).get("total", 0)
    t_comp = flops_dev / PEAK_FLOPS_BF16
    t_mem = bytes_dev / HBM_BW
    t_coll = coll_dev / ICI_BW
    dom = max((t_comp, "compute"), (t_mem, "memory"),
              (t_coll, "collective"))[1]
    t_bound = max(t_comp, t_mem, t_coll)

    cfg = get_config(d["arch"])
    shape = SHAPES[d["shape"]]
    chips = CHIPS[d["mesh"]]
    if d["kind"] == "train":
        tokens = shape.seq_len * shape.global_batch
        model_flops = 6 * cfg.active_param_count() * tokens
    elif d["kind"] == "prefill":
        tokens = shape.seq_len * shape.global_batch
        model_flops = 2 * cfg.active_param_count() * tokens
    else:
        tokens = shape.global_batch          # one new token per request
        model_flops = 2 * cfg.active_param_count() * tokens
    hlo_flops_total = flops_dev * chips
    useful = model_flops / hlo_flops_total if hlo_flops_total else 0.0
    # roofline fraction: useful model FLOP/s at the bound, vs peak
    mfu_bound = (model_flops / chips / PEAK_FLOPS_BF16) / t_bound \
        if t_bound else 0.0
    return {
        "mesh": d["mesh"], "arch": d["arch"], "shape": d["shape"],
        "status": "ok", "kind": d["kind"],
        "t_compute_s": t_comp, "t_memory_s": t_mem,
        "t_collective_s": t_coll, "dominant": dom,
        "model_flops": model_flops, "hlo_flops_per_dev": flops_dev,
        "useful_flop_ratio": useful, "roofline_fraction": mfu_bound,
        "peak_gib": d.get("memory", {}).get("peak_bytes", 0) / 2 ** 30,
        "fits_16g": d.get("memory", {}).get("peak_bytes", 0) < 16 * 2 ** 30,
        "collective_bytes_dev": coll_dev,
    }


def load_rows(dirpath="results/dryrun"):
    rows = []
    for f in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        rows.append(roofline_row(json.load(open(f))))
    return rows


def table(dirpath="results/dryrun", mesh="16x16"):
    rows = [r for r in load_rows(dirpath) if r["mesh"] == mesh]
    out = [("arch", "shape", "t_comp_ms", "t_mem_ms", "t_coll_ms",
            "dominant", "useful", "roofline_frac", "peakGiB", "fits")]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        if r["status"] != "ok":
            out.append((r["arch"], r["shape"], "-", "-", "-", "SKIP",
                        "-", "-", "-", "-"))
            continue
        out.append((r["arch"], r["shape"],
                    f"{r['t_compute_s']*1e3:.2f}",
                    f"{r['t_memory_s']*1e3:.2f}",
                    f"{r['t_collective_s']*1e3:.2f}",
                    r["dominant"],
                    f"{r['useful_flop_ratio']:.3f}",
                    f"{r['roofline_fraction']:.3f}",
                    f"{r['peak_gib']:.2f}",
                    "Y" if r["fits_16g"] else "N"))
    return out


def main():
    # per the brief, the roofline table is single-pod; the multi-pod pass
    # proves the "pod" axis shards (see §Dry-run status fields)
    print("\n== roofline, mesh 16x16 (single-pod) ==")
    for row in table(mesh="16x16"):
        print(",".join(str(x) for x in row))


if __name__ == "__main__":
    main()
