"""Benchmark of the persistence layer (``repro.artifacts``, PR 5).

Writes ``BENCH_artifacts.json`` with the numbers the warm-start story is
sold on:

* ``cold`` — full cost of producing a tuning artifact from nothing:
  construct + ``fit`` (PPO vs the analytic oracle) + first ``tune``.
* ``restore`` — ``nv.save`` wall, ``NeuroVectorizer.load`` wall (the
  deploy-time cost that replaces the fit), and the artifact size.
* ``store`` — warm ``tune_sites`` latency through a hot
  :class:`~repro.artifacts.ProgramStore` vs. a cold inference pass, and
  the hit rate over a mixed seen/unseen workload.

Usage: ``PYTHONPATH=src python -m benchmarks.bench_artifacts``
(``BENCH_FAST=1`` trims the RL budget; ``BENCH_ARTIFACTS_OUT`` overrides
the output path).
"""
from __future__ import annotations

import json
import os
import tempfile
import time

from repro.api import NeuroVectorizer
from repro.artifacts import ProgramStore
from repro.configs.neurovec import NeuroVecConfig
from repro.core import dataset

FAST = os.environ.get("BENCH_FAST", "0") == "1"
OUT = os.environ.get("BENCH_ARTIFACTS_OUT", "BENCH_artifacts.json")

CFG = NeuroVecConfig(train_batch=64, sgd_minibatch=32, ppo_epochs=2,
                     lr=5e-4)
FIT_STEPS = 256 if FAST else 2048
N_SITES = 24 if FAST else 64
WARM_REPS = 20 if FAST else 100


def _dir_bytes(path: str) -> int:
    total = 0
    for root, _, files in os.walk(path):
        total += sum(os.path.getsize(os.path.join(root, f)) for f in files)
    return total


def run() -> dict:
    work = tempfile.mkdtemp(prefix="bench_artifacts_")
    art = os.path.join(work, "facade")
    store_path = os.path.join(work, "programs.jsonl")
    sites = dataset.generate(N_SITES, seed=5)
    unseen = dataset.generate(N_SITES // 2, seed=6)

    # -- cold: construct + fit + first tune ---------------------------------
    t0 = time.perf_counter()
    nv = NeuroVectorizer(CFG, agent="ppo", seed=0, program_store=store_path)
    nv.fit(sites, total_steps=FIT_STEPS)
    fit_wall = time.perf_counter() - t0
    t0 = time.perf_counter()
    prog_cold = nv.tune_sites(sites)
    cold_tune_wall = time.perf_counter() - t0

    t0 = time.perf_counter()
    nv.save(art)
    save_wall = time.perf_counter() - t0
    nv.close()

    # -- restore: load replaces the whole fit -------------------------------
    t0 = time.perf_counter()
    nv2 = NeuroVectorizer.load(art, program_store=store_path)
    load_wall = time.perf_counter() - t0

    # -- warm tune: a store hit vs. a fresh inference pass ------------------
    t0 = time.perf_counter()
    for _ in range(WARM_REPS):
        prog_warm = nv2.tune_sites(sites)
    warm_tune_wall = (time.perf_counter() - t0) / WARM_REPS
    assert prog_warm.tiles == prog_cold.tiles, "round-trip broke"
    assert nv2.agent_inferences == 0, "warm tunes must be pure lookups"

    # mixed workload: half the site sets were never tuned before
    nv2.tune_sites(unseen)
    hit_rate = nv2.store_hits / (nv2.store_hits + nv2.store_misses)

    nv2.close()
    results = {
        "config": {"fast": FAST, "fit_steps": FIT_STEPS,
                   "n_sites": N_SITES, "warm_reps": WARM_REPS},
        "cold": {"fit_wall_s": fit_wall,
                 "first_tune_wall_s": cold_tune_wall,
                 "total_wall_s": fit_wall + cold_tune_wall},
        "restore": {"save_wall_s": save_wall, "load_wall_s": load_wall,
                    "artifact_bytes": _dir_bytes(art),
                    "fit_to_load_speedup": fit_wall / max(load_wall, 1e-9)},
        "store": {"warm_tune_wall_s": warm_tune_wall,
                  "cold_tune_wall_s": cold_tune_wall,
                  "lookup_speedup": cold_tune_wall / max(warm_tune_wall,
                                                         1e-9),
                  "hit_rate": hit_rate,
                  "store_bytes": os.path.getsize(store_path)},
    }
    with open(OUT, "w") as f:
        json.dump(results, f, indent=1)
    print(f"bench_artifacts,fit_wall_s,{fit_wall:.3f}")
    print(f"bench_artifacts,load_wall_s,{load_wall:.3f}")
    print(f"bench_artifacts,fit_to_load_speedup,"
          f"{results['restore']['fit_to_load_speedup']:.1f}")
    print(f"bench_artifacts,store_lookup_speedup,"
          f"{results['store']['lookup_speedup']:.1f}")
    print(f"bench_artifacts,store_hit_rate,{hit_rate:.2f}")
    print(f"bench_artifacts,out,{OUT}")
    return results


if __name__ == "__main__":
    import sys
    sys.path.insert(0, "src")
    run()
