"""Kernel-level wall-clock microbench (interpret-mode Pallas on CPU is not
timing-representative, so this times the jitted XLA reference path and
reports the COST-MODEL projection for the TPU target alongside — the
before/after evidence for the tile choices themselves)."""
from __future__ import annotations

from benchmarks import common
from repro.api import brute_force_action
from repro.models.compute import KernelSite


def run():
    e = common.env()
    agent = common.trained_agent()
    rows = [("kernelbench", "site|policy", "tpu_model_us")]
    sites = [
        KernelSite(site="kb.qkv", kind="matmul", m=16384, n=6144, k=4096),
        KernelSite(site="kb.ffn", kind="matmul", m=16384, n=18432, k=4608),
        KernelSite(site="kb.skinny", kind="matmul", m=64, n=8192, k=1024),
        KernelSite(site="kb.attn", kind="attention", m=8192, n=128, k=8192,
                   batch=64, causal=True),
    ]
    for s in sites:
        t_base = e.baseline_cost(s)
        a_rl = agent.act([s], sample=False)[0]
        t_rl = e.cost(s, a_rl) or common.NV.illegal_slowdown * t_base
        _, t_bf = brute_force_action(e, s)
        rows.append(("kernelbench", f"{s.site}|baseline",
                     round(t_base * 1e6, 2)))
        rows.append(("kernelbench", f"{s.site}|rl", round(t_rl * 1e6, 2)))
        rows.append(("kernelbench", f"{s.site}|brute", round(t_bf * 1e6, 2)))
    for r in rows:
        print(",".join(str(x) for x in r))
    return rows
