"""Benchmark of the measurement-transport layer (``repro.measure`` pool).

Writes ``BENCH_service.json`` with the numbers the ROADMAP's
serving-scale story cares about:

* ``throughput`` — timings/s through ``WorkerPoolTransport`` at
  workers=1,2,4, each against a cold DB over the same pair set (compile
  + warmup included; worker spawn cost reported separately so the
  steady-state rate is visible).
* ``coalesce`` — duplicate-submission absorption: every pair submitted
  twice in flight, ``coalesce_rate = coalesced / submitted`` (0.5 is
  perfect absorption).
* ``cache`` — the cross-transport persistence proof: a second, in-process
  pass over a pool-populated DB performs zero timings.
* ``fault_recovery`` — chaos throughput: the same pair set against a cold
  DB with one worker SIGKILLed mid-run vs. the healthy 2-worker rate.
  The requeue path must deliver every timing (``failed_pairs == 0``);
  ``recovery_ratio`` is the throughput retained under the fault.

Interpret-mode timings on CPU are a throughput *proxy* (grid-size
scaling, not MXU behaviour) — exactly enough to track the transport
overhead trajectory per PR.

Usage: ``PYTHONPATH=src python -m benchmarks.bench_service`` (env
``BENCH_FAST=1`` trims the pair set; ``BENCH_SERVICE_OUT`` overrides the
output path).
"""
from __future__ import annotations

import json
import os
import signal
import sys
import tempfile
import threading
import time

import numpy as np

from repro.measure import InProcessTransport, MeasureRunner, MeasureDB, \
    WorkerPoolTransport
from repro.models.compute import KernelSite

FAST = os.environ.get("BENCH_FAST", "0") == "1"
OUT = os.environ.get("BENCH_SERVICE_OUT", "BENCH_service.json")
WORKER_COUNTS = (1, 2, 4)
RUNNER_KW = dict(reps=1, warmup=1, interpret=True, max_dim=32, max_batch=2)


def _pairs():
    """A flat list of distinct (site, tiles) measurement pairs."""
    mm = [KernelSite(site=f"bs.mm{i}", kind="matmul", m=32 * (i + 1),
                     n=128, k=128) for i in range(2 if FAST else 4)]
    at = [KernelSite(site="bs.attn", kind="attention", m=64, n=32, k=64,
                     batch=2, causal=True)]
    sc = [KernelSite(site="bs.scan", kind="chunk_scan", m=32, n=16, k=8,
                     batch=2)]
    pairs = []
    for s in mm:
        for bm in ((8, 16) if FAST else (8, 16, 32)):
            pairs.append((s, (bm, 128, 128)))
    for s in at:
        for bq in (32, 64):
            pairs.append((s, (bq, 64, 1)))
    for s in sc:
        for q in (16, 32):
            pairs.append((s, (q, 1, 1)))
    return pairs


def _submit_all(transport, pairs, dup: int = 1):
    sites = [s for s, _ in pairs] * dup
    tiles = np.array([t for _, t in pairs] * dup, np.int64)
    futs = transport.submit(sites, tiles)
    transport.drain()
    return [f.result() for f in futs]


def _worker_pids() -> list:
    """PIDs of this process's live ``repro.measure.worker`` children."""
    me = os.getpid()
    pids = []
    for d in os.listdir("/proc"):
        if not d.isdigit():
            continue
        try:
            with open(f"/proc/{d}/cmdline", "rb") as f:
                cmd = f.read().replace(b"\0", b" ")
            with open(f"/proc/{d}/stat") as f:
                ppid = int(f.read().split()[3])
        except OSError:
            continue
        if ppid == me and b"repro.measure.worker" in cmd:
            pids.append(int(d))
    return pids


def _kill_one_worker_mid_run(pool, after_pairs: int = 2) -> threading.Thread:
    """SIGKILL one pool worker once ``after_pairs`` results have landed —
    the run is then provably mid-flight, not before or after the batch."""
    def _run():
        while True:
            st = pool.stats()
            done = (st["transport_timed_pairs_total"]
                    + st["transport_failed_pairs_total"])
            if done >= after_pairs:
                break
            if st["transport_inflight_pairs"] == 0 \
                    and st["transport_timed_pairs_total"]:
                return                  # batch already finished: no fault
            time.sleep(0.02)
        pids = _worker_pids()
        if pids:
            os.kill(pids[0], signal.SIGKILL)
    th = threading.Thread(target=_run, daemon=True)
    th.start()
    return th


def run() -> dict:
    pairs = _pairs()
    tmp = tempfile.mkdtemp(prefix="bench_service_")
    throughput = {}
    db_for_cache = None
    for w in WORKER_COUNTS:
        db = os.path.join(tmp, f"measure_w{w}.jsonl")
        t0 = time.perf_counter()
        pool = WorkerPoolTransport(workers=w, db=db, runner_kwargs=RUNNER_KW)
        spawn_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        _submit_all(pool, pairs)
        wall = time.perf_counter() - t0
        st = pool.stats()
        pool.close()
        assert st["transport_timed_pairs_total"] == len(pairs), st
        timed = st["transport_timed_pairs_total"]
        cpus = os.cpu_count() or 1
        if w > cpus:
            print(f"bench_service: WARNING: workers={w} oversubscribes "
                  f"the host ({cpus} CPUs) — scaling numbers for this "
                  f"entry measure contention, not the pool",
                  file=sys.stderr)
        throughput[f"workers_{w}"] = {
            "timed_pairs": timed, "wall_s": wall,
            "spawn_s": spawn_s, "timings_per_s": timed / wall,
            "cpu_count": cpus, "oversubscribed": w > cpus}
        db_for_cache = db
    base = throughput[f"workers_{WORKER_COUNTS[0]}"]["timings_per_s"]

    # -- coalesce rate: every pair submitted twice in one batch -------------
    pool = WorkerPoolTransport(workers=2, runner_kwargs=RUNNER_KW)
    _submit_all(pool, pairs, dup=2)
    st = pool.stats()
    pool.close()
    submitted = (st["transport_misses_total"]
                 + st["transport_coalesced_total"]
                 + st["transport_hits_total"])
    coalesce = {"submitted": submitted,
                "coalesced": st["transport_coalesced_total"],
                "timed_pairs": st["transport_timed_pairs_total"],
                "coalesce_rate":
                    st["transport_coalesced_total"] / submitted}
    assert st["transport_timed_pairs_total"] == len(pairs), st

    # -- cross-transport persistence: pool-written DB, in-process reader ----
    inproc = InProcessTransport(MeasureRunner(**RUNNER_KW),
                                MeasureDB(db_for_cache))
    _submit_all(inproc, pairs)
    st2 = inproc.stats()
    inproc.close()
    assert st2["transport_timed_pairs_total"] == 0, st2

    # -- fault recovery: one worker SIGKILLed mid-run, cold DB --------------
    healthy = throughput["workers_2"]["timings_per_s"]
    pool = WorkerPoolTransport(workers=2,
                               db=os.path.join(tmp, "measure_chaos.jsonl"),
                               runner_kwargs=RUNNER_KW)
    killer = _kill_one_worker_mid_run(pool)
    t0 = time.perf_counter()
    _submit_all(pool, pairs)
    wall = time.perf_counter() - t0
    killer.join(timeout=10)
    st3 = pool.stats()
    pool.close()
    # the requeue path must deliver every timing despite the kill
    assert st3["transport_failed_pairs_total"] == 0, st3
    assert st3["transport_timed_pairs_total"] == len(pairs), st3
    faulted = st3["transport_timed_pairs_total"] / wall
    fault_recovery = {
        "healthy_timings_per_s": healthy,
        "faulted_timings_per_s": faulted,
        "recovery_ratio": faulted / healthy,
        "worker_restarts": st3["pool_worker_restarts_total"],
        "retries": st3["transport_retries_total"],
        "failed_pairs": st3["transport_failed_pairs_total"],
        "health_after": st3["health"]}

    results = {
        "config": {"fast": FAST, "n_pairs": len(pairs),
                   "runner": RUNNER_KW, "worker_counts": WORKER_COUNTS,
                   # pool scaling is bounded by host cores: interpret-mode
                   # measurement is CPU-bound, so expect flat/negative
                   # scaling once workers exceed free cores
                   "cpu_count": os.cpu_count()},
        "throughput": throughput,
        "scaling": {f"speedup_w{w}_vs_w{WORKER_COUNTS[0]}":
                    throughput[f"workers_{w}"]["timings_per_s"] / base
                    for w in WORKER_COUNTS[1:]},
        "coalesce": coalesce,
        "cache": {"second_pass_timed_pairs":
                      st2["transport_timed_pairs_total"],
                  "second_pass_hit_rate": st2["transport_hit_ratio"]},
        "fault_recovery": fault_recovery,
    }
    with open(OUT, "w") as f:
        json.dump(results, f, indent=1)
    for w in WORKER_COUNTS:
        print(f"bench_service,timings_per_s_w{w},"
              f"{throughput[f'workers_{w}']['timings_per_s']:.2f}")
    print(f"bench_service,coalesce_rate,{coalesce['coalesce_rate']:.2f}")
    print(f"bench_service,second_pass_hit_rate,"
          f"{st2['transport_hit_ratio']:.2f}")
    print(f"bench_service,fault_recovery_ratio,"
          f"{fault_recovery['recovery_ratio']:.2f}")
    print(f"bench_service,out,{OUT}")
    return results


if __name__ == "__main__":
    sys.path.insert(0, "src")
    run()
