"""Benchmark of the cross-host fleet transport (``repro.fleet``).

Writes ``BENCH_fleet.json`` with the numbers the fleet story cares
about:

* ``throughput`` — timings/s through a localhost
  ``SocketTransport -> MeasureServer -> WorkerPoolTransport`` stack vs.
  the identical local pool driven directly; ``socket_overhead_ratio`` is
  the fraction of local throughput retained across the TCP hop.
* ``wire`` — per-pair round-trip overhead isolated from measurement
  cost: N distinct pairs through an instant echo runner behind an
  in-process server, ``wire_overhead_per_pair_ms`` = wall / N.
* ``reconnect_recovery`` — two echo hosts, one killed mid-run: every
  pair must still deliver (``failed_pairs == 0``); ``recovery_ratio``
  is the throughput retained under the host loss.

Interpret-mode timings on CPU are a throughput *proxy* — enough to
track the wire-overhead trajectory per PR, not MXU behaviour.

Usage: ``PYTHONPATH=src python -m benchmarks.bench_fleet`` (env
``BENCH_FAST=1`` trims the pair set; ``BENCH_FLEET_OUT`` overrides the
output path).
"""
from __future__ import annotations

import json
import os
import tempfile
import threading
import time
import zlib

import numpy as np

from repro.fleet import MeasureServer, SocketTransport
from repro.measure import InProcessTransport, WorkerPoolTransport

from benchmarks.bench_service import RUNNER_KW, _pairs, _submit_all

FAST = os.environ.get("BENCH_FAST", "0") == "1"
OUT = os.environ.get("BENCH_FLEET_OUT", "BENCH_fleet.json")
N_WIRE_PAIRS = 64 if FAST else 256


class _EchoRunner:
    """Instant deterministic runner: isolates wire cost from measurement
    cost (values derive from the key, like the test fakes, but local to
    the benchmark — no test-directory import)."""

    backend_key = "echo-backend"

    def __init__(self, delay: float = 0.0):
        self.delay = delay

    def __call__(self, sites, tiles):
        if self.delay:
            time.sleep(self.delay)
        return np.array(
            [1e-4 * (1 + zlib.crc32(
                f"{s.key()}|{tuple(int(x) for x in t)}".encode()) % 1000)
             for s, t in zip(sites, tiles)], np.float64)


def _wire_sites(n: int):
    from repro.models.compute import KernelSite
    return [KernelSite(site=f"bf.w{i}", kind="matmul", m=32, n=128, k=128)
            for i in range(n)]


def _kill_host_mid_run(transport, server, after_pairs: int
                       ) -> threading.Thread:
    """Close one serve-worker host once ``after_pairs`` results landed —
    provably mid-flight."""
    def _run():
        while True:
            st = transport.stats()
            done = (st["transport_timed_pairs_total"]
                    + st["transport_failed_pairs_total"])
            if done >= after_pairs:
                break
            if st["transport_inflight_pairs"] == 0 \
                    and st["transport_timed_pairs_total"]:
                return                  # batch already finished: no fault
            time.sleep(0.005)
        server.drop_connections()
        server.close()
    th = threading.Thread(target=_run, daemon=True)
    th.start()
    return th


def run() -> dict:
    pairs = _pairs()
    tmp = tempfile.mkdtemp(prefix="bench_fleet_")

    # -- throughput: local pool vs the same pool behind a socket ------------
    pool = WorkerPoolTransport(workers=2,
                               db=os.path.join(tmp, "local.jsonl"),
                               runner_kwargs=RUNNER_KW)
    t0 = time.perf_counter()
    _submit_all(pool, pairs)
    local_wall = time.perf_counter() - t0
    st_local = pool.stats()
    pool.close()
    assert st_local["transport_timed_pairs_total"] == len(pairs), st_local

    inner = WorkerPoolTransport(workers=2, runner_kwargs=RUNNER_KW)
    srv = MeasureServer(inner)
    srv.start()
    fleet = SocketTransport([srv.address],
                            db=os.path.join(tmp, "fleet.jsonl"))
    t0 = time.perf_counter()
    _submit_all(fleet, pairs)
    fleet_wall = time.perf_counter() - t0
    st_fleet = fleet.stats()
    fleet.close()
    srv.close()
    inner.close()
    assert st_fleet["transport_timed_pairs_total"] == len(pairs), st_fleet
    local_rate = len(pairs) / local_wall
    fleet_rate = len(pairs) / fleet_wall
    throughput = {
        "local_pool_timings_per_s": local_rate,
        "socket_fleet_timings_per_s": fleet_rate,
        "socket_overhead_ratio": fleet_rate / local_rate,
        "local_wall_s": local_wall, "fleet_wall_s": fleet_wall}

    # -- wire overhead per pair: echo runner, measurement cost ~0 -----------
    inner = InProcessTransport(_EchoRunner())
    srv = MeasureServer(inner)
    srv.start()
    fleet = SocketTransport([srv.address])
    sites = _wire_sites(N_WIRE_PAIRS)
    tiles = np.array([[16, 128, 128]] * N_WIRE_PAIRS, np.int64)
    t0 = time.perf_counter()
    futs = fleet.submit(sites, tiles)
    fleet.drain()
    wall = time.perf_counter() - t0
    assert all(f.result() > 0 for f in futs)
    fleet.close()
    srv.close()
    inner.close()
    wire = {"n_pairs": N_WIRE_PAIRS, "wall_s": wall,
            "wire_overhead_per_pair_ms": 1e3 * wall / N_WIRE_PAIRS,
            "round_trips_per_s": N_WIRE_PAIRS / wall}

    # -- reconnect recovery: one of two echo hosts dies mid-run -------------
    delay = 0.002
    inners = [InProcessTransport(_EchoRunner(delay=delay)) for _ in range(2)]
    servers = [MeasureServer(i) for i in inners]
    for s in servers:
        s.start()
    sites = _wire_sites(N_WIRE_PAIRS)

    # healthy baseline over both hosts
    fleet = SocketTransport([s.address for s in servers])
    t0 = time.perf_counter()
    _submit_all(fleet, [(s, (16, 128, 128)) for s in sites])
    healthy_wall = time.perf_counter() - t0
    fleet.close()

    # faulted run (fresh client, no DB: every pair re-measures) with one
    # host killed mid-run
    fleet = SocketTransport([s.address for s in servers],
                            max_connect_failures=2, backoff_base=0.05,
                            backoff_cap=0.2)
    killer = _kill_host_mid_run(fleet, servers[0],
                                after_pairs=N_WIRE_PAIRS // 8)
    t0 = time.perf_counter()
    _submit_all(fleet, [(s, (16, 128, 128)) for s in sites])
    faulted_wall = time.perf_counter() - t0
    killer.join(timeout=10)
    st = fleet.stats()
    fleet.close()
    for s in servers:
        s.close()
    for i in inners:
        i.close()
    # every pair still delivered
    assert st["transport_failed_pairs_total"] == 0, st
    healthy_rate = N_WIRE_PAIRS / healthy_wall
    faulted_rate = N_WIRE_PAIRS / faulted_wall
    reconnect = {
        "healthy_pairs_per_s": healthy_rate,
        "faulted_pairs_per_s": faulted_rate,
        "recovery_ratio": faulted_rate / healthy_rate,
        "retries": st["transport_retries_total"],
        "failed_pairs": st["transport_failed_pairs_total"],
        "reconnects": st["fleet_reconnects_total"],
        "health_after": st["health"]}

    results = {
        "config": {"fast": FAST, "n_pairs": len(pairs),
                   "n_wire_pairs": N_WIRE_PAIRS, "runner": RUNNER_KW,
                   "cpu_count": os.cpu_count()},
        "throughput": throughput,
        "wire": wire,
        "reconnect_recovery": reconnect,
    }
    with open(OUT, "w") as f:
        json.dump(results, f, indent=1)
    print(f"bench_fleet,local_pool_timings_per_s,{local_rate:.2f}")
    print(f"bench_fleet,socket_fleet_timings_per_s,{fleet_rate:.2f}")
    print(f"bench_fleet,socket_overhead_ratio,"
          f"{throughput['socket_overhead_ratio']:.2f}")
    print(f"bench_fleet,wire_overhead_per_pair_ms,"
          f"{wire['wire_overhead_per_pair_ms']:.3f}")
    print(f"bench_fleet,reconnect_recovery_ratio,"
          f"{reconnect['recovery_ratio']:.2f}")
    print(f"bench_fleet,out,{OUT}")
    return results


if __name__ == "__main__":
    import sys
    sys.path.insert(0, "src")
    run()
