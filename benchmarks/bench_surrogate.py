"""Benchmark of the learned cost model (``repro.surrogate``).

``BENCH_measure.json`` established the problem: the analytic model's tile
ranking barely correlates with measured time (mean Spearman ~0.19).  This
benchmark measures the two things the surrogate exists for, and writes
``BENCH_surrogate.json``:

* ``rank_correlation`` — per-site Spearman of *surrogate-predicted* vs
  measured cost over the full action grid, side by side with the analytic
  model's correlation on the identical grid.  The surrogate trains only on
  the MeasureDB the full sweep just produced — exactly the corpus a real
  autotuning installation accumulates for free.
* ``pruning`` — the payoff, measured two ways.  *Timed-pair reduction*:
  a fresh-DB tuning pass with ``prune_topk=K`` must submit a fraction of
  the full grid's pairs to the runner.  *Best-tile agreement*: a pruned
  pass against the warm DB (identical measured values; only the pruning
  decision differs) must select the same per-site best tile as the
  exhaustive sweep.  Agreement is deliberately evaluated with
  measurements held fixed — interpret-mode timings are noisy enough
  that two *unpruned* sweeps disagree on near-tied winners, which would
  measure noise, not pruning.

Usage: ``PYTHONPATH=src python -m benchmarks.bench_surrogate`` (env
``BENCH_FAST=1`` trims the grid via ``bench_measure``'s config;
``BENCH_SURROGATE_OUT`` overrides the output path;
``BENCH_SURROGATE_TOPK`` overrides the pruning width, default 4).
"""
from __future__ import annotations

import json
import os
import tempfile
import time

import numpy as np

from benchmarks.bench_measure import CFG, FAST, REPS, _sites, _spearman
from repro.core.env import CostModelEnv
from repro.measure import make_measured_env
from repro.surrogate import SurrogateOracle, train_from_db

OUT = os.environ.get("BENCH_SURROGATE_OUT", "BENCH_surrogate.json")
TOPK = int(os.environ.get("BENCH_SURROGATE_TOPK", "4"))


def _best(row: np.ndarray) -> int:
    return int(np.argmin(np.where(np.isfinite(row), row, np.inf)))


def run() -> dict:
    tmp = tempfile.mkdtemp(prefix="bench_surrogate_")
    db_full = os.path.join(tmp, "full.jsonl")
    db_pruned = os.path.join(tmp, "pruned.jsonl")
    sites = _sites()

    # -- full exhaustive sweep: the training corpus + the ground truth ------
    env_full = make_measured_env(CFG, db_path=db_full, reps=REPS, warmup=1)
    t0 = time.perf_counter()
    grid_meas = env_full.cost_grid(sites)
    wall_full = time.perf_counter() - t0
    full_pairs = env_full.measure_fn.runner.timed_pairs

    # -- train the surrogate on exactly that DB -----------------------------
    t0 = time.perf_counter()
    model = train_from_db(db_full)
    wall_train = time.perf_counter() - t0
    assert model is not None, "full sweep left the DB too cold to train"

    # -- rank agreement with measured, surrogate vs analytic ----------------
    grid_sur = SurrogateOracle(CFG, model).cost_grid(sites)
    grid_ana = CostModelEnv(CFG).cost_grid(sites)
    rho_sur = [_spearman(grid_meas[i], grid_sur[i])
               for i in range(len(sites))]
    rho_ana = [_spearman(grid_meas[i], grid_ana[i])
               for i in range(len(sites))]

    # -- pruned pass on a fresh DB: the timed-pair reduction ----------------
    env_p = make_measured_env(CFG, db_path=db_pruned, reps=REPS, warmup=1,
                              prune_topk=TOPK, surrogate=model)
    t0 = time.perf_counter()
    env_p.cost_grid(sites)
    wall_pruned = time.perf_counter() - t0
    pruned_timed = env_p.measure_fn.runner.timed_pairs

    # -- pruned pass on the warm DB: best-tile agreement, noise held fixed --
    env_w = make_measured_env(CFG, db_path=db_full, reps=REPS, warmup=1,
                              prune_topk=TOPK, surrogate=model)
    grid_pruned = env_w.cost_grid(sites)
    assert env_w.measure_fn.runner.timed_pairs == 0, \
        "warm-DB pruned pass must re-time nothing"
    matches = [_best(grid_pruned[i]) == _best(grid_meas[i])
               for i in range(len(sites))]

    def _mean(rhos):
        d = [r for r in rhos if not np.isnan(r)]
        return float(np.mean(d)) if d else None

    results = {
        "config": {"fast": FAST, "reps": REPS, "prune_topk": TOPK,
                   "n_sites": len(sites),
                   "backend": env_full.measure_fn.runner.backend_key,
                   "ensemble": model.ensemble,
                   "corpus_pairs": full_pairs},
        "rank_correlation": {
            "per_site_surrogate": {
                s.site: (None if np.isnan(r) else r)
                for s, r in zip(sites, rho_sur)},
            "per_site_analytic": {
                s.site: (None if np.isnan(r) else r)
                for s, r in zip(sites, rho_ana)},
            "mean_spearman_surrogate": _mean(rho_sur),
            "mean_spearman_analytic": _mean(rho_ana)},
        "pruning": {
            "full_timed_pairs": full_pairs,
            "pruned_timed_pairs": pruned_timed,
            "surrogate_priced_pairs": env_p.pruned_pairs,
            "timed_fraction": pruned_timed / max(full_pairs, 1),
            "best_tile_matches": int(sum(matches)),
            "best_tile_match_per_site": {
                s.site: bool(m) for s, m in zip(sites, matches)},
            "wall_full_s": wall_full,
            "wall_pruned_s": wall_pruned,
            "wall_train_s": wall_train},
    }
    with open(OUT, "w") as f:
        json.dump(results, f, indent=1)
    rc = results["rank_correlation"]
    print(f"bench_surrogate,mean_spearman_surrogate,"
          f"{rc['mean_spearman_surrogate']:.3f}")
    print(f"bench_surrogate,mean_spearman_analytic,"
          f"{rc['mean_spearman_analytic']:.3f}")
    pr = results["pruning"]
    print(f"bench_surrogate,timed_fraction,{pr['timed_fraction']:.2f} "
          f"({pr['pruned_timed_pairs']}/{pr['full_timed_pairs']} pairs)")
    print(f"bench_surrogate,best_tile_matches,"
          f"{pr['best_tile_matches']}/{len(sites)}")
    print(f"bench_surrogate,out,{OUT}")
    return results


if __name__ == "__main__":
    import sys
    sys.path.insert(0, "src")
    run()
