"""Benchmark of the observability substrate (``repro.obs``).

Instrumentation only earns its keep if it is effectively free on the
tuning hot path.  This benchmark measures both ends and writes
``BENCH_obs.json``:

* ``micro`` — nanoseconds per primitive operation: unlabelled/labelled
  counter increments, histogram observes, real spans written to a JSONL
  trace, and the :data:`~repro.obs.NULL_TRACER` no-op span (what every
  un-traced call pays).
* ``overhead`` — the headline number: median ``SessionHandle.tune()``
  wall-clock through a :class:`~repro.service.TuningService`, fully
  instrumented (metrics registry *and* file tracing on) vs observability
  disabled, interleaved A/B to cancel background-load drift.  The
  acceptance bar for the PR is ``tune_overhead_frac < 0.03``.

Usage: ``PYTHONPATH=src python -m benchmarks.bench_obs`` (env
``BENCH_FAST=1`` trims reps and micro-op counts;
``BENCH_OBS_OUT`` overrides the output path).
"""
from __future__ import annotations

import json
import os
import tempfile
import time

from repro.api import NeuroVecConfig, TuningService
from repro.measure.timing import interleaved_medians
from repro.models.compute import KernelSite
from repro.obs import NULL_TRACER, MetricsRegistry, Tracer, read_trace

FAST = os.environ.get("BENCH_FAST") == "1"
OUT = os.environ.get("BENCH_OBS_OUT", "BENCH_obs.json")
REPS = 10 if FAST else 40
MICRO_N = 20_000 if FAST else 200_000

# a mid-sized action grid: big enough that brute tune() does real work
# per call (the overhead denominator), small enough to stay sub-second
CFG = NeuroVecConfig(
    bm_choices=(8, 16, 32, 64), bn_choices=(128, 256),
    bk_choices=(128, 256), bq_choices=(64, 128, 256),
    bkv_choices=(128, 256), chunk_choices=(64, 128),
    train_batch=32, sgd_minibatch=16, ppo_epochs=2)


def _sites():
    mm = [KernelSite(site=f"b.mm{i}", kind="matmul",
                     m=32 * (i + 1), n=128, k=128) for i in range(512)]
    at = [KernelSite(site=f"b.attn{i}", kind="attention",
                     m=64 * (i + 1), n=32, k=64, batch=2, causal=True)
          for i in range(128)]
    return mm + at


def _per_op_ns(fn, n: int) -> float:
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e9


def micro(tmp: str) -> dict:
    reg = MetricsRegistry()
    c = reg.counter("bench_ops_total")
    h = reg.histogram("bench_op_seconds")
    lbl = reg.counter("bench_lops_total", labelnames=("s",)).labels(s="x")
    out = {
        "counter_inc_ns": _per_op_ns(c.inc, MICRO_N),
        "histogram_observe_ns": _per_op_ns(lambda: h.observe(0.01),
                                           MICRO_N),
        "labelled_inc_ns": _per_op_ns(lbl.inc, MICRO_N),
        "null_span_ns": _per_op_ns(
            lambda: NULL_TRACER.span("x").end(), MICRO_N),
    }
    trace_path = os.path.join(tmp, "micro.jsonl")
    tr = Tracer(trace_path)
    n_spans = max(MICRO_N // 20, 1000)
    out["traced_span_us"] = _per_op_ns(
        lambda: tr.span("bench").end(), n_spans) / 1e3
    tr.close()
    assert len(read_trace(trace_path)) == n_spans
    return out


def overhead(tmp: str) -> dict:
    sites = _sites()
    trace_path = os.path.join(tmp, "tune.jsonl")

    svc_plain = TuningService(CFG, transport="inproc", metrics=False)
    s_plain = svc_plain.open_session(agent="brute", oracle="model")
    svc_obs = TuningService(CFG, transport="inproc",
                            metrics=MetricsRegistry(), trace=trace_path)
    s_obs = svc_obs.open_session(agent="brute", oracle="model")
    try:
        s_plain.fit(sites)
        s_obs.fit(sites)
        prog_p = s_plain.tune(sites)                    # warm both paths
        prog_o = s_obs.tune(sites)
        assert prog_p.tiles == prog_o.tiles, \
            "instrumentation changed the tuned program"
        t_plain, t_obs = interleaved_medians(
            lambda: s_plain.tune(sites),
            lambda: s_obs.tune(sites), reps=REPS)
        n_series = len(svc_obs.registry.snapshot())
    finally:
        svc_plain.close()
        svc_obs.close()
    return {
        "tune_plain_s": t_plain,
        "tune_obs_s": t_obs,
        "tune_overhead_frac": t_obs / t_plain - 1.0,
        "reps": REPS,
        "n_sites": len(sites),
        "metric_series": n_series,
        "trace_spans": len(read_trace(trace_path)),
    }


def run() -> dict:
    tmp = tempfile.mkdtemp(prefix="bench_obs_")
    results = {
        "config": {"fast": FAST, "reps": REPS, "micro_n": MICRO_N},
        "micro": micro(tmp),
        "overhead": overhead(tmp),
    }
    with open(OUT, "w") as f:
        json.dump(results, f, indent=1)
    m, o = results["micro"], results["overhead"]
    print(f"bench_obs,counter_inc_ns,{m['counter_inc_ns']:.0f}")
    print(f"bench_obs,histogram_observe_ns,{m['histogram_observe_ns']:.0f}")
    print(f"bench_obs,labelled_inc_ns,{m['labelled_inc_ns']:.0f}")
    print(f"bench_obs,null_span_ns,{m['null_span_ns']:.0f}")
    print(f"bench_obs,traced_span_us,{m['traced_span_us']:.1f}")
    print(f"bench_obs,tune_overhead_pct,{100 * o['tune_overhead_frac']:.2f} "
          f"({o['tune_obs_s'] * 1e3:.2f}ms vs {o['tune_plain_s'] * 1e3:.2f}ms"
          f" plain)")
    print(f"bench_obs,out,{OUT}")
    return results


if __name__ == "__main__":
    import sys
    sys.path.insert(0, "src")
    run()
