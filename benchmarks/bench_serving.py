"""Benchmark of the latency-SLO serving path (``repro.serving``).

Writes ``BENCH_serving.json`` with the numbers the serving story is
judged on:

* ``one_dispatch`` — the counter-asserted proof that a model-oracle
  tune through the server is ONE jitted device dispatch per batch:
  over the measured rounds ``fused_dispatches == batches`` with a
  single trace (bucketed jit reuse, no retraces).
* ``throughput`` — tunes/s at 8 concurrent sessions, batched
  (all sessions submit ``tune_async`` and the flusher coalesces them
  into one batch) vs. sequential (one blocking ``tune`` at a time
  through the same server).  The fused route must hold ``speedup >= 2``
  (asserted); the shared-PPO agent route is reported alongside.
* ``latency_ms`` — client-observed p50/p99 per serving tier: ``cold``
  (fresh service, first tune: jit trace + compile included),
  ``warm_agent`` (same server, compiled route, through the batcher),
  ``warm_store`` (repeat site set answered by the ProgramStore at
  admission — never queued).

Interpret-mode numbers on CPU track the *serving overhead* trajectory
(queueing, batching, dispatch count), not device kernel speed.

Usage: ``PYTHONPATH=src python -m benchmarks.bench_serving`` (env
``BENCH_FAST=1`` trims rounds; ``BENCH_SERVING_OUT`` overrides the
output path).
"""
from __future__ import annotations

import json
import os
import sys
import tempfile
import time

import numpy as np

from repro.configs.neurovec import NeuroVecConfig
from repro.core.agents import make_agent
from repro.core.env import CostModelEnv
from repro.models.compute import KernelSite
from repro.service import TuningService

FAST = os.environ.get("BENCH_FAST", "0") == "1"
OUT = os.environ.get("BENCH_SERVING_OUT", "BENCH_serving.json")
N_SESSIONS = 8
ROUNDS = 3 if FAST else 10
COLD_RUNS = 2 if FAST else 3
WARM_TUNES = 10 if FAST else 30
PPO_STEPS = 16 if FAST else 48
# max_wait above the submission jitter of 8 threadless tune_asyncs so
# every round provably coalesces into ONE batch (the dispatch-count
# assert depends on it); both phases pay it, so the speedup is fair.
# The huge slo keeps deadline urgency (whose EMA the warm-up compile
# inflates) from flushing batches early and racing the submissions.
SERVING = {"max_wait_ms": 10.0, "slo_ms": 60_000.0}


def small_cfg() -> NeuroVecConfig:
    return NeuroVecConfig(
        bm_choices=(16, 32), bn_choices=(128,), bk_choices=(128,),
        bq_choices=(32, 64), bkv_choices=(128,), chunk_choices=(16, 32),
        train_batch=32, sgd_minibatch=16, ppo_epochs=2)


CFG = small_cfg()


def _sites(tag: str, n: int = 3):
    """Distinct per-session site lists (cross-request mixing in the
    batcher would be visible as a wrong result)."""
    return [KernelSite(site=f"{tag}.mm{i}", kind="matmul",
                       m=32 * (i + 1), n=128, k=128) for i in range(n)]


def _percentiles(samples_s) -> dict:
    a = np.asarray(samples_s, np.float64) * 1e3
    return {"p50": float(np.percentile(a, 50)),
            "p99": float(np.percentile(a, 99)),
            "n": int(a.size)}


def _phase_throughput(svc, pairs, batched: bool):
    """Tunes/s over ROUNDS; batched submits every session's tune_async
    per round, sequential blocks on one tune at a time."""
    t0 = time.perf_counter()
    for _ in range(ROUNDS):
        if batched:
            futs = [s.tune_async(ss) for s, ss in pairs]
            for f in futs:
                f.result(timeout=300)
        else:
            for s, ss in pairs:
                s.tune(ss)
    wall = time.perf_counter() - t0
    return len(pairs) * ROUNDS / wall


def bench_fused_route() -> tuple:
    """8 brute/model sessions through one server: the one-dispatch proof
    plus batched-vs-sequential tunes/s on the fused route."""
    with TuningService(CFG, serving=SERVING, metrics=False) as svc:
        pairs = [(svc.open_session(agent="brute", oracle="model"),
                  _sites(f"bf{i}")) for i in range(N_SESSIONS)]
        for s, ss in pairs:
            s.fit(ss)
        # warm round: pays the jit trace + compile once, uncounted
        for f in [s.tune_async(ss) for s, ss in pairs]:
            f.result(timeout=300)

        st0 = svc.server.stats()
        batched = _phase_throughput(svc, pairs, batched=True)
        st1 = svc.server.stats()
        sequential = _phase_throughput(svc, pairs, batched=False)
        st2 = svc.server.stats()

    d_batches = st1["serving_batches_total"] - st0["serving_batches_total"]
    d_disp = (st1["serving_fused_dispatches_total"]
              - st0["serving_fused_dispatches_total"])
    d_req = st1["serving_requests_total"] - st0["serving_requests_total"]
    one_dispatch = {
        "requests": d_req,
        "batches": d_batches,
        "fused_dispatches": d_disp,
        "fused_traces_total": st2["serving_fused_traces_total"],
        "dispatches_equal_batches": d_disp == d_batches,
    }
    # the acceptance proof: every coalesced round was ONE device dispatch
    assert d_batches == ROUNDS, (d_batches, ROUNDS)
    assert d_disp == d_batches, one_dispatch
    assert d_req == N_SESSIONS * ROUNDS, one_dispatch
    # bucketed jit reuse: one trace per distinct pad bucket (batched
    # rounds share one bucket, sequential tunes another)
    assert st2["serving_fused_traces_total"] <= 2, st2

    speedup = batched / sequential
    assert speedup >= 2.0, (batched, sequential, speedup)
    return one_dispatch, {"batched_tunes_per_s": batched,
                          "sequential_tunes_per_s": sequential,
                          "speedup": speedup}


def bench_agent_route() -> dict:
    """8 sessions SHARING one fitted PPO agent: concurrent requests
    coalesce into one padded-bucket jitted forward per batch."""
    agent = make_agent("ppo", CFG, seed=0)
    fit_sites = _sites("pf", n=4)
    agent.fit(fit_sites, CostModelEnv(CFG, seed=0),
              total_steps=PPO_STEPS)
    with TuningService(CFG, serving=SERVING, metrics=False) as svc:
        pairs = [(svc.open_session(agent=agent, oracle="model"),
                  _sites(f"ap{i}")) for i in range(N_SESSIONS)]
        for f in [s.tune_async(ss) for s, ss in pairs]:   # warm
            f.result(timeout=300)
        st0 = svc.server.stats()
        batched = _phase_throughput(svc, pairs, batched=True)
        st1 = svc.server.stats()
        sequential = _phase_throughput(svc, pairs, batched=False)
    d_fwd = (st1["serving_agent_batches_total"]
             - st0["serving_agent_batches_total"])
    d_req = (st1["serving_batched_requests_total"]
             - st0["serving_batched_requests_total"])
    return {"batched_tunes_per_s": batched,
            "sequential_tunes_per_s": sequential,
            "speedup": batched / sequential,
            "forwards_batched_phase": d_fwd,
            "requests_batched_phase": d_req,
            "coalesce_ratio": d_req / d_fwd if d_fwd else 0.0}


def bench_latency_tiers() -> dict:
    sites = _sites("lt")
    # cold: fresh service each run — first tune pays trace + compile
    cold = []
    for _ in range(COLD_RUNS):
        with TuningService(CFG, serving=True, metrics=False) as svc:
            s = svc.open_session(agent="brute", oracle="model")
            s.fit(sites)
            t0 = time.perf_counter()
            s.tune(sites)
            cold.append(time.perf_counter() - t0)
            # warm-agent: same server, compiled route, no store
            warm_agent = []
            for _ in range(WARM_TUNES):
                t0 = time.perf_counter()
                s.tune(sites)
                warm_agent.append(time.perf_counter() - t0)
    # warm-store: repeat site set resolved at admission, never queued
    tmp = tempfile.mkdtemp(prefix="bench_serving_")
    with TuningService(CFG, serving=True, metrics=False,
                       program_store=os.path.join(tmp, "p.jsonl")) as svc:
        s = svc.open_session(agent="brute", oracle="model")
        s.fit(sites)
        s.tune(sites)                        # populate the store
        warm_store = []
        for _ in range(WARM_TUNES):
            t0 = time.perf_counter()
            s.tune(sites)
            warm_store.append(time.perf_counter() - t0)
        st = svc.server.stats()
    assert st["serving_store_hits_total"] == WARM_TUNES, st
    assert st["serving_batches_total"] == 1, st      # hits never queued
    return {"cold": _percentiles(cold),
            "warm_agent": _percentiles(warm_agent),
            "warm_store": _percentiles(warm_store)}


def run() -> dict:
    one_dispatch, fused = bench_fused_route()
    agent = bench_agent_route()
    tiers = bench_latency_tiers()
    results = {
        "config": {"fast": FAST, "n_sessions": N_SESSIONS,
                   "rounds": ROUNDS, "cold_runs": COLD_RUNS,
                   "warm_tunes": WARM_TUNES, "serving": SERVING,
                   "sites_per_session": 3, "cpu_count": os.cpu_count()},
        "one_dispatch": one_dispatch,
        "throughput": {"n_sessions": N_SESSIONS,
                       "fused": fused, "agent_ppo": agent},
        "latency_ms": tiers,
    }
    with open(OUT, "w") as f:
        json.dump(results, f, indent=1)
    print(f"bench_serving,fused_batched_tunes_per_s,"
          f"{fused['batched_tunes_per_s']:.1f}")
    print(f"bench_serving,fused_speedup_8_sessions,{fused['speedup']:.2f}")
    print(f"bench_serving,agent_speedup_8_sessions,{agent['speedup']:.2f}")
    print(f"bench_serving,fused_dispatches_per_batch,"
          f"{one_dispatch['fused_dispatches'] / one_dispatch['batches']:.2f}")
    for tier in ("cold", "warm_agent", "warm_store"):
        print(f"bench_serving,{tier}_p50_ms,{tiers[tier]['p50']:.2f}")
        print(f"bench_serving,{tier}_p99_ms,{tiers[tier]['p99']:.2f}")
    print(f"bench_serving,out,{OUT}")
    return results


if __name__ == "__main__":
    sys.path.insert(0, "src")
    run()
