"""Benchmark entry point — one function per paper table/figure.

Prints ``name,key,value`` CSV.  ``BENCH_FAST=1`` trims training budgets.
Usage: PYTHONPATH=src python -m benchmarks.run [fig1 fig2 ... facade]
"""
import sys
import time


def facade_smoke():
    """End-to-end ``repro.api.NeuroVectorizer`` drive: every registered
    agent fits against the shared oracle and tunes the same site set —
    the smoke row for the unified Agent/Oracle protocol."""
    from benchmarks import common
    from repro.api import AGENT_NAMES, NeuroVectorizer
    from repro.core import dataset

    sites = dataset.generate(50, seed=0)
    rows = [("facade", "agent", "program_speedup")]
    for name in AGENT_NAMES:
        nv = NeuroVectorizer(common.NV, agent=name, oracle=common.env(),
                             seed=0)
        nv.fit(sites, **({"total_steps": 1000} if name == "ppo" else {}))
        prog = nv.tune_sites(sites)
        rows.append(("facade", name, round(nv.speedup(prog, sites), 4)))
    for r in rows:
        print(",".join(str(x) for x in r))
    return rows


def main() -> None:
    sys.path.insert(0, "src")
    from benchmarks import bench_env, figures, kernelbench, roofline

    jobs = {
        "bench_env": bench_env.run,
        "facade": facade_smoke,
        "fig1": figures.fig1_dotprod_sweep,
        "fig2": figures.fig2_suite_bruteforce,
        "fig5": figures.fig5_hyperparam_sweep,
        "fig6": figures.fig6_action_spaces,
        "fig7": figures.fig7_benchmarks,
        "fig8": figures.fig8_polybench,
        "fig9": figures.fig9_mibench,
        "kernelbench": kernelbench.run,
        "roofline": roofline.main,
    }
    args = [a for a in sys.argv[1:] if a in jobs] or list(jobs)
    for name in args:
        t0 = time.time()
        print(f"\n### {name} ###")
        jobs[name]()
        print(f"### {name} done in {time.time()-t0:.1f}s ###")


if __name__ == '__main__':
    main()
