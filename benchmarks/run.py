"""Benchmark entry point — one function per paper table/figure.

Prints ``name,key,value`` CSV.  ``BENCH_FAST=1`` trims training budgets.
Usage: PYTHONPATH=src python -m benchmarks.run [fig1 fig2 ... roofline]
"""
import os
import sys
import time


def main() -> None:
    sys.path.insert(0, "src")
    from benchmarks import bench_env, figures, kernelbench, roofline

    jobs = {
        "bench_env": bench_env.run,
        "fig1": figures.fig1_dotprod_sweep,
        "fig2": figures.fig2_suite_bruteforce,
        "fig5": figures.fig5_hyperparam_sweep,
        "fig6": figures.fig6_action_spaces,
        "fig7": figures.fig7_benchmarks,
        "fig8": figures.fig8_polybench,
        "fig9": figures.fig9_mibench,
        "kernelbench": kernelbench.run,
        "roofline": roofline.main,
    }
    args = [a for a in sys.argv[1:] if a in jobs] or list(jobs)
    for name in args:
        t0 = time.time()
        print(f"\n### {name} ###")
        jobs[name]()
        print(f"### {name} done in {time.time()-t0:.1f}s ###")


if __name__ == '__main__':
    main()
