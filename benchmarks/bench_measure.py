"""Benchmark of the hardware measurement subsystem (``repro.measure``).

Writes ``BENCH_measure.json`` with three things the ROADMAP cares about:

* ``timings_per_s`` — how fast the runner turns (site, tile) pairs into
  seconds (compile+warmup included; the autotune-throughput ceiling).
* ``cache`` — persistence proof: a second oracle against the same DB path
  must perform **zero** kernel timings (``second_run_hit_rate == 1.0``,
  ``second_run_timed_pairs == 0``).
* ``rank_correlation`` — mean per-site Spearman correlation between
  measured and analytic-model costs over the full action grid.  On CPU the
  measured side is interpret-mode Pallas, so this tracks *agreement of
  orderings* (what an argmin/agent consumes), not absolute times.

Usage: ``PYTHONPATH=src python -m benchmarks.bench_measure`` (env
``BENCH_FAST=1`` trims the grid; ``BENCH_MEASURE_OUT`` overrides the
output path; ``BENCH_MEASURE_DB`` pins the DB file — default is a fresh
temp file so the persistence proof starts cold).
"""
from __future__ import annotations

import json
import os
import tempfile
import time

import numpy as np

from repro.configs.neurovec import NeuroVecConfig
from repro.core.env import CostModelEnv
from repro.measure import MeasureRunner, make_measured_env
from repro.models.compute import KernelSite

FAST = os.environ.get("BENCH_FAST", "0") == "1"
OUT = os.environ.get("BENCH_MEASURE_OUT", "BENCH_measure.json")
REPS = 1 if FAST else 2

# a deliberately small action space: the benchmark sweeps FULL grids, and
# interpret-mode timing is seconds per pair — the integration/statistics
# are identical at any scale
CFG = NeuroVecConfig(
    bm_choices=(8, 16, 32) if FAST else (8, 16, 32, 64),
    bn_choices=(128,) if FAST else (128, 256),
    bk_choices=(128,) if FAST else (128, 256),
    bq_choices=(64, 128), bkv_choices=(64, 128),
    chunk_choices=(32, 64) if FAST else (32, 64, 128),
)


def _sites():
    s = [KernelSite(site="bm.mm0", kind="matmul", m=64, n=128, k=256),
         KernelSite(site="bm.attn", kind="attention", m=128, n=64, k=128,
                    batch=2, causal=True),
         KernelSite(site="bm.scan", kind="chunk_scan", m=64, n=32, k=16,
                    batch=2)]
    if not FAST:
        s.insert(1, KernelSite(site="bm.mm1", kind="matmul", m=128, n=256,
                               k=128, dtype="float32"))
    return s


def _spearman(a: np.ndarray, b: np.ndarray) -> float:
    """Spearman rho with average-tie ranks; nan if < 3 common entries."""
    ok = np.isfinite(a) & np.isfinite(b)
    if ok.sum() < 3:
        return float("nan")
    ra, rb = _avg_ranks(a[ok]), _avg_ranks(b[ok])
    ra, rb = ra - ra.mean(), rb - rb.mean()
    d = np.sqrt((ra ** 2).sum() * (rb ** 2).sum())
    return float((ra * rb).sum() / d) if d else float("nan")


def _avg_ranks(x: np.ndarray) -> np.ndarray:
    order = np.argsort(x, kind="stable")
    ranks = np.empty(len(x), np.float64)
    ranks[order] = np.arange(len(x), dtype=np.float64)
    # average ranks within tied groups
    xs = x[order]
    i = 0
    while i < len(xs):
        j = i
        while j + 1 < len(xs) and xs[j + 1] == xs[i]:
            j += 1
        if j > i:
            ranks[order[i:j + 1]] = (i + j) / 2.0
        i = j + 1
    return ranks


def run(db_path: str | None = None) -> dict:
    db_path = db_path or os.environ.get("BENCH_MEASURE_DB") or \
        os.path.join(tempfile.mkdtemp(prefix="bench_measure_"),
                     "measure.jsonl")
    sites = _sites()

    # -- run 1: cold DB, every pair timed -----------------------------------
    env1 = make_measured_env(CFG, db_path=db_path, reps=REPS, warmup=1)
    t0 = time.perf_counter()
    grid_meas = env1.cost_grid(sites)
    wall1 = time.perf_counter() - t0
    r1 = env1.measure_fn.runner

    # -- run 2: fresh oracle + runner, same DB -> zero timings --------------
    env2 = make_measured_env(CFG, db_path=db_path, reps=REPS, warmup=1)
    t0 = time.perf_counter()
    grid2 = env2.cost_grid(sites)
    wall2 = time.perf_counter() - t0
    r2, mf2 = env2.measure_fn.runner, env2.measure_fn
    assert r2.timed_pairs == 0, "persistent DB failed: re-timed pairs"
    np.testing.assert_allclose(grid2, grid_meas, rtol=0, atol=0)

    # -- measured vs model rank agreement ------------------------------------
    grid_model = CostModelEnv(CFG).cost_grid(sites)
    rhos = [_spearman(grid_meas[i], grid_model[i])
            for i in range(len(sites))]

    results = {
        "config": {"fast": FAST, "reps": REPS, "n_sites": len(sites),
                   "grid_pairs": int(np.isfinite(grid_model).sum()),
                   "backend": r1.backend_key, "db_path": db_path},
        "timings": {"timed_pairs": r1.timed_pairs,
                    "failed_pairs": r1.failed_pairs,
                    "wall_s": wall1,
                    "timings_per_s": r1.timed_pairs / wall1},
        "cache": {"first_run_hit_rate": env1.measure_fn.hit_rate,
                  "second_run_hit_rate": mf2.hit_rate,
                  "second_run_timed_pairs": r2.timed_pairs,
                  "second_run_wall_s": wall2,
                  "cached_lookup_speedup": wall1 / max(wall2, 1e-9)},
        "rank_correlation": {
            # nan (undefined: <3 common grid entries) -> null, so the
            # report stays strict JSON
            "per_site": {s.site: (None if np.isnan(r) else r)
                         for s, r in zip(sites, rhos)},
            "mean_spearman": (float(np.mean(defined)) if
                              (defined := [r for r in rhos
                                           if not np.isnan(r)])
                              else None)},
    }
    with open(OUT, "w") as f:
        json.dump(results, f, indent=1)
    print(f"bench_measure,timings_per_s,"
          f"{results['timings']['timings_per_s']:.2f}")
    print(f"bench_measure,second_run_hit_rate,"
          f"{results['cache']['second_run_hit_rate']:.2f}")
    rho = results["rank_correlation"]["mean_spearman"]
    print(f"bench_measure,mean_spearman,"
          f"{'undefined' if rho is None else format(rho, '.3f')}")
    print(f"bench_measure,out,{OUT}")
    return results


if __name__ == "__main__":
    import sys
    sys.path.insert(0, "src")
    run()
