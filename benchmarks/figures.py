"""One function per paper table/figure (DESIGN.md §9 index).

Each returns a list of CSV rows ``name,value,derived`` and prints them.
All decision methods come from the ``repro.api`` registry and the factor
sweeps (fig1/fig2) are single ``cost_grid`` tensor evaluations.
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.api import make_agent, n_evaluations
from repro.core import dataset
from repro.models.compute import KernelSite


def _emit(rows):
    for r in rows:
        print(",".join(str(x) for x in r))
    return rows


# ---------------------------------------------------------------------------
# Fig. 1 — dot-product kernel factor sweep, normalized to the baseline
# ---------------------------------------------------------------------------

def fig1_dotprod_sweep():
    """Paper: brute-force VF x IF grid on the dot-product kernel; 26/35
    factor choices beat the baseline cost model, best ~1.2x.  Ours: the
    (bm, bk) grid of the reduction-shaped site — one ``cost_grid`` slice,
    no per-action env calls."""
    e = common.env()
    site = KernelSite(site="fig1.dot", kind="matmul", m=8, n=128, k=4096)
    t_base = float(e.baseline_costs([site])[0])
    sizes = e.space.valid_sizes("matmul")
    cube = e.cost_grid([site])[0][:e.space.n_actions("matmul")]
    cube = cube.reshape(sizes)                    # (bm, bn, bk) axes
    rows = [("fig1", "factor", "speedup_vs_baseline")]
    better = total = 0
    best = 0.0
    for a0 in range(sizes[0]):
        for a2 in range(sizes[2]):
            c = cube[a0, 0, a2]
            sp = 0.0 if not np.isfinite(c) else t_base / float(c)
            tiles = e.space.tiles("matmul", (a0, 0, a2))
            rows.append(("fig1", f"bm{tiles[0]}_bk{tiles[2]}", round(sp, 4)))
            total += 1
            better += sp > 1.0
            best = max(best, sp)
    rows.append(("fig1.summary", f"{better}/{total}_beat_baseline",
                 round(best, 4)))
    return _emit(rows)


# ---------------------------------------------------------------------------
# Fig. 2 — brute force over the extracted "vectorizer test suite"
# ---------------------------------------------------------------------------

def fig2_suite_bruteforce():
    e = common.env()
    sites = dataset.arch_sites()
    # the whole sweep is one cost-grid tensor + a row-wise min
    best = e.cost_grid(sites).min(1)
    sps = e.baseline_costs(sites) / best
    rows = [("fig2", "site", "bruteforce_speedup")]
    for s, sp in zip(sites, sps):
        rows.append(("fig2", f"{s.site}:{s.m}x{s.n}x{s.k}",
                     round(float(sp), 4)))
    rows.append(("fig2.summary", "geomean",
                 round(float(np.exp(np.mean(np.log(sps)))), 4)))
    rows.append(("fig2.summary", "all_geq_1",
                 int(all(sp >= 0.999 for sp in sps))))
    return _emit(rows)


# ---------------------------------------------------------------------------
# Fig. 5 — hyperparameter sweep (lr x network x batch)
# ---------------------------------------------------------------------------

def fig5_hyperparam_sweep(steps=None):
    steps = steps or (2000 if common.FAST else 10000)
    rows = [("fig5", "config@steps", "reward_mean|loss")]
    corpus = common.corpus()
    e = common.env()
    sweeps = {
        "lr5e-3": dict(lr=5e-3), "lr5e-4": dict(lr=5e-4),
        "lr5e-5": dict(lr=5e-5),
        "net256x256": dict(lr=5e-4, hidden=(256, 256)),
        "batch1000": dict(lr=5e-4, batch=1000),
        "batch4000": dict(lr=5e-4, batch=4000),
    }
    for name, kw in sweeps.items():
        nv = common.NV
        if "hidden" in kw:
            import dataclasses
            nv = dataclasses.replace(nv, hidden=kw["hidden"])
        agent = make_agent("ppo", nv, seed=0, lr=kw.get("lr", nv.lr))
        agent.fit(corpus, e, total_steps=steps,
                  batch=kw.get("batch", nv.train_batch))
        for h in agent.history[:: max(1, len(agent.history) // 6)]:
            rows.append(("fig5", f"{name}@{h['steps']}",
                         f"{h['reward_mean']:.4f}|{h['loss']:.4f}"))
    return _emit(rows)


# ---------------------------------------------------------------------------
# Fig. 6 — action-space ablation (discrete vs continuous encodings)
# ---------------------------------------------------------------------------

def fig6_action_spaces(steps=None):
    steps = steps or (2000 if common.FAST else 8000)
    rows = [("fig6", "action_space@steps", "reward_mean")]
    finals = {}
    for mode in ("discrete", "cont1", "cont2"):
        agent = make_agent("ppo", common.NV, seed=0, mode=mode, lr=5e-4)
        agent.fit(common.corpus(), common.env(), total_steps=steps)
        for h in agent.history[:: max(1, len(agent.history) // 5)]:
            rows.append(("fig6", f"{mode}@{h['steps']}",
                         round(h["reward_mean"], 4)))
        finals[mode] = np.mean([h["reward_mean"]
                                for h in agent.history[-3:]])
    rows.append(("fig6.summary", "discrete_best",
                 int(finals["discrete"] >= max(finals.values()) - 1e-6)))
    return _emit(rows)


# ---------------------------------------------------------------------------
# Fig. 7 — the main comparison on 12 held-out benchmarks
# ---------------------------------------------------------------------------

def fig7_benchmarks():
    pol = common.policies_for_fig7()
    wls = dataset.twelve_benchmarks()
    rows = [("fig7", "benchmark|policy", "speedup_vs_baseline")]
    summary = {}
    for name, agent in pol.items():
        sps = common.suite_speedups(wls, agent)
        for wl, sp in zip(wls, sps):
            rows.append(("fig7", f"{wl.name}|{name}", round(float(sp), 4)))
        summary[name] = float(np.exp(np.mean(np.log(np.maximum(sps,
                                                               1e-3)))))
    for name, g in summary.items():
        rows.append(("fig7.summary", f"geomean_{name}", round(g, 4)))
    # the paper's sample-efficiency claim: brute force needs ~35x more
    # compile+run evaluations than the RL training budget
    n_bf = n_evaluations(common.env(), common.corpus())
    rows.append(("fig7.summary", "bruteforce_vs_rl_samples",
                 round(n_bf / common.TRAIN_STEPS, 2)))
    rows.append(("fig7.summary", "rl_within_of_brute",
                 round(summary["brute"] / max(summary["rl"], 1e-6), 4)))
    return _emit(rows)


# ---------------------------------------------------------------------------
# Fig. 8 / Fig. 9 — transfer to PolyBench / MiBench analogues
# ---------------------------------------------------------------------------

def _transfer(figname, workloads):
    pol = common.policies_for_fig7()
    rows = [(figname, "benchmark|policy", "speedup_vs_baseline")]
    for name in ("baseline", "polly", "rl"):
        sps = common.suite_speedups(workloads, pol[name])
        for wl, sp in zip(workloads, sps):
            rows.append((figname, f"{wl.name}|{name}", round(float(sp), 4)))
        rows.append((f"{figname}.summary", f"geomean_{name}",
                     round(float(np.exp(np.mean(np.log(sps)))), 4)))
    return _emit(rows)


def fig8_polybench():
    return _transfer("fig8", dataset.polybench())


def fig9_mibench():
    return _transfer("fig9", dataset.mibench())
