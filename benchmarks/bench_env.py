"""Microbenchmark for the cost-model engine: env rewards/s, brute-force
labels/s, PPO train steps/s — vectorized vs the scalar (seed) reference
path.  Writes ``BENCH_env.json`` so the perf trajectory is tracked from
this PR onward.

Methodology: both paths are compile/cache-warmed first, then timed over
``REPS`` interleaved repetitions (median), which cancels slow drift in
shared-container load.  The scalar reference is the seed implementation:
per-call Python cost model with baseline recomputation
(``CostModelEnv(vectorized=False)``), interpreted factor-product brute
force, and the un-fused PPO update (``PPOAgent(fused=False)``: jitted
grads, Python-side Adam, per-call featurization).

Usage: ``PYTHONPATH=src python -m benchmarks.bench_env`` (env
``BENCH_FAST=1`` trims budgets; ``BENCH_ENV_OUT`` overrides the output
path).
"""
from __future__ import annotations

import itertools
import json
import os

import numpy as np

from repro.configs.neurovec import NeuroVecConfig
from repro.core import dataset
from repro.api import PPOAgent, brute_force_labels
from repro.core.env import CostModelEnv
from repro.measure.timing import interleaved_medians

FAST = os.environ.get("BENCH_FAST", "0") == "1"
OUT = os.environ.get("BENCH_ENV_OUT", "BENCH_env.json")
REPS = 3 if FAST else 5

NV = NeuroVecConfig(train_batch=256, sgd_minibatch=64, ppo_epochs=4)

N_REWARD_SITES = 512 if FAST else 2048
N_BRUTE_SITES = 64 if FAST else 256
PPO_STEPS = 512 if FAST else 1024
PPO_CORPUS = 400


def _median_times(fn_a, fn_b, reps=REPS):
    """The shared interleaved A/B loop from ``repro.measure.timing``."""
    return interleaved_medians(fn_a, fn_b, reps=reps)


def _scalar_brute_labels(env, sites):
    """The seed implementation: interpreted walk of the factor product."""
    out = []
    for s in sites:
        best_a, best_c = (0, 0, 0), float("inf")
        for a in itertools.product(
                *(range(n) for n in env.space.valid_sizes(s.kind))):
            c = env.cost(s, a)
            if c is not None and c < best_c:
                best_a, best_c = a, c
        out.append(best_a)
    return np.array(out, np.int32)


def bench_rewards(env_vec, env_scl):
    sites = dataset.generate(N_REWARD_SITES, seed=0)
    rng = np.random.default_rng(0)
    actions = np.stack([[rng.integers(0, n)
                         for n in env_vec.space.valid_sizes(s.kind)]
                        for s in sites])
    # warm both paths (fills the vectorized env's baseline cache so the
    # steady-state — what training actually sees — is measured)
    r_v = env_vec.rewards_batch(sites, actions)
    r_s = env_scl.rewards_batch(sites, actions)
    assert np.allclose(r_v, r_s, rtol=1e-6, atol=1e-7), "parity violated"
    t_v, t_s = _median_times(lambda: env_vec.rewards_batch(sites, actions),
                             lambda: env_scl.rewards_batch(sites, actions))
    return {"n_rewards": len(sites),
            "scalar_rewards_per_s": len(sites) / t_s,
            "vectorized_rewards_per_s": len(sites) / t_v,
            "speedup": t_s / t_v}


def bench_brute(env_vec, env_scl):
    sites = dataset.generate(N_BRUTE_SITES, seed=1)
    lab_v = brute_force_labels(env_vec, sites)          # warm grids
    lab_s = _scalar_brute_labels(env_scl, sites)
    assert (lab_v == lab_s).all(), "brute-force parity violated"
    t_v, t_s = _median_times(lambda: brute_force_labels(env_vec, sites),
                             lambda: _scalar_brute_labels(env_scl, sites),
                             reps=min(REPS, 3))
    return {"n_sites": len(sites),
            "scalar_labels_per_s": len(sites) / t_s,
            "vectorized_labels_per_s": len(sites) / t_v,
            "speedup": t_s / t_v}


def bench_ppo(env_vec, env_scl):
    sites = dataset.generate(PPO_CORPUS, seed=2)
    agent_v = PPOAgent(NV, lr=5e-4, seed=0)
    agent_s = PPOAgent(NV, lr=5e-4, seed=0, fused=False)
    # compile/cache warmup: one full update on each path
    agent_v.train(sites, env_vec, total_steps=NV.train_batch)
    agent_s.train(sites, env_scl, total_steps=NV.train_batch)
    t_v, t_s = _median_times(
        lambda: agent_v.train(sites, env_vec, total_steps=PPO_STEPS),
        lambda: agent_s.train(sites, env_scl, total_steps=PPO_STEPS))
    return {"train_steps": PPO_STEPS,
            "scalar_steps_per_s": PPO_STEPS / t_s,
            "vectorized_steps_per_s": PPO_STEPS / t_v,
            "scalar_s": t_s, "vectorized_s": t_v,
            "speedup": t_s / t_v}


def run() -> dict:
    env_vec = CostModelEnv(NV, vectorized=True)
    env_scl = CostModelEnv(NV, vectorized=False)
    results = {
        "config": {"train_batch": NV.train_batch,
                   "sgd_minibatch": NV.sgd_minibatch,
                   "ppo_epochs": NV.ppo_epochs,
                   "fast": FAST, "reps": REPS},
        "env_rewards": bench_rewards(env_vec, env_scl),
        "brute_force_labels": bench_brute(env_vec, env_scl),
        "ppo_train": bench_ppo(env_vec, env_scl),
    }
    with open(OUT, "w") as f:
        json.dump(results, f, indent=1)
    for k in ("env_rewards", "brute_force_labels", "ppo_train"):
        print(f"bench_env,{k}_speedup,{results[k]['speedup']:.2f}")
    print(f"bench_env,out,{OUT}")
    return results


if __name__ == "__main__":
    import sys
    sys.path.insert(0, "src")
    run()
