"""Training / serving step functions (the things the launcher pjit-compiles).

``make_train_step`` builds a microbatched (gradient-accumulation) step:
the global batch is split into ``accum`` microbatches scanned sequentially —
the standard memory/throughput lever at scale.  Optional int8 error-feedback
gradient compression hooks in before the optimizer (see
``distributed/compression.py``).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.lm import Model
from repro.optim import adamw


def make_train_state(model: Model, key, opt_cfg: adamw.AdamWConfig):
    params = model.init(key)
    return {"params": params, "opt": adamw.init(params),
            "step": jnp.zeros((), jnp.int32)}


def _split_microbatches(batch, accum: int, mb_specs=None):
    """Reshape (B, ...) -> (accum, B/accum, ...).

    GSPMD is free to re-shard a reshaped tensor and (observed) may shard the
    *accumulation* axis, collapsing the data-parallel batch sharding inside
    the scan and replicating every activation.  When ``mb_specs`` (the batch
    PartitionSpecs) is given, each microbatched leaf is pinned to
    P(None, <original batch spec>)."""
    from jax.sharding import PartitionSpec as P

    def sp(x):
        b = x.shape[0]
        assert b % accum == 0, (b, accum)
        return x.reshape(accum, b // accum, *x.shape[1:])

    out = jax.tree.map(sp, batch)
    if mb_specs is not None:
        def pin(x, spec):
            return jax.lax.with_sharding_constraint(x, P(None, *spec))
        out = jax.tree.map(pin, out, mb_specs,
                           is_leaf=lambda v: isinstance(v, P))
    return out


def make_train_step(model: Model, opt_cfg: adamw.AdamWConfig,
                    accum: int = 1, compression=None, mb_specs=None,
                    accum_dtype=jnp.float32):
    """Returns train_step(state, batch) -> (state, metrics).

    ``accum_dtype``: dtype of the gradient-accumulation buffers.  f32 is the
    safe default; bf16 halves a full parameter-sized buffer set, which is
    the difference between fitting and not fitting the 200B+ MoE configs on
    a single 256-chip pod (see EXPERIMENTS.md §Perf).
    """

    def loss_fn(params, mb):
        loss, metrics = model.train_loss(params, mb)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state, batch):
        params = state["params"]
        if accum == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            mbs = _split_microbatches(batch, accum, mb_specs)

            def body(carry, mb):
                gsum, lsum = carry
                (l, m), g = grad_fn(params, mb)
                gsum = jax.tree.map(
                    lambda a, b: a + b.astype(accum_dtype), gsum, g)
                return (gsum, lsum + l), m

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, accum_dtype), params)
            (gsum, lsum), ms = jax.lax.scan(body, (zero, 0.0), mbs)
            grads = jax.tree.map(lambda g: g / accum, gsum)
            loss = lsum / accum
            metrics = jax.tree.map(lambda a: a[-1], ms)

        if compression is not None:
            grads, comp_metrics = compression(grads)
            metrics = {**metrics, **comp_metrics}

        new_params, new_opt, opt_metrics = adamw.update(
            opt_cfg, grads, state["opt"], params)
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        return new_state, {"loss": loss, **metrics, **opt_metrics}

    return train_step


def make_prefill_step(model: Model):
    def prefill_step(params, batch, cache):
        return model.prefill(params, batch, cache)
    return prefill_step


def make_serve_step(model: Model):
    """One decode step: sample greedy next token for a batch of requests."""

    def serve_step(params, token, pos, cache):
        logits, cache = model.decode_step(params, token, pos, cache)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return next_tok, logits, cache

    return serve_step
