"""Deterministic synthetic token pipeline (shard-aware, restartable).

Production shape: every host materializes only its shard of the global
batch; ``batch_at(step)`` is a pure function of (seed, step) so a restore
at step N reproduces exactly the stream a non-failed run would have seen —
the property the fault-tolerance path relies on (no data-loader state in
checkpoints).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    host_index: int = 0
    host_count: int = 1


class SyntheticPipeline:
    """Zipf-ish token stream + targets = next token (causal LM)."""

    def __init__(self, cfg: ModelConfig, shape: ShapeConfig,
                 data_cfg: DataConfig = DataConfig()):
        self.cfg = cfg
        self.shape = shape
        self.dc = data_cfg
        assert shape.global_batch % data_cfg.host_count == 0
        self.local_batch = shape.global_batch // data_cfg.host_count

    def _tokens(self, key, batch, seq):
        """Learnable synthetic stream: with p=0.9 the next token follows a
        fixed affine rule (so the LM has signal to fit), else it resets to
        a Zipf-ish random token."""
        V = self.cfg.vocab_size
        k1, k2, k3 = jax.random.split(key, 3)
        u = jax.random.uniform(k1, (batch, seq + 1))
        noise = (u * u * (V - 1)).astype(jnp.int32)
        follow = jax.random.uniform(k2, (batch, seq + 1)) < 0.9

        def step(prev, inp):
            nz, fl = inp
            nxt = jnp.where(fl, (prev * 5 + 7) % V, nz)
            return nxt, nxt

        first = noise[:, 0]
        _, rest = jax.lax.scan(
            step, first, (noise[:, 1:].T, follow[:, 1:].T))
        return jnp.concatenate([first[:, None], rest.T], axis=1)

    def batch_at(self, step: int):
        cfg, shape = self.cfg, self.shape
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(self.dc.seed), step),
            self.dc.host_index)
        seq = shape.seq_len
        n_pre = cfg.n_frontend_tokens if cfg.frontend == "vision" else 0
        toks = self._tokens(key, self.local_batch, seq - n_pre)
        batch = {"tokens": toks[:, :-1], "targets": toks[:, 1:]}
        if cfg.frontend == "vision":
            batch["frontend_embeds"] = jax.random.normal(
                jax.random.fold_in(key, 1),
                (self.local_batch, n_pre, cfg.d_model)) * 0.02
        if cfg.enc_dec:
            batch["src_embeds"] = jax.random.normal(
                jax.random.fold_in(key, 2),
                (self.local_batch, seq, cfg.d_model)) * 0.02
        return batch
