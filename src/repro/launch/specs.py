"""ShapeDtypeStruct stand-ins for every model input — weak-type-correct,
shardable, no device allocation (the dry-run lowers against these)."""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import ModelConfig, SHAPES, ShapeConfig
from repro.distributed import sharding as shd
from repro.models.lm import Model
from repro.optim.adamw import AdamWConfig
from repro.train.steps import make_train_state

sds = jax.ShapeDtypeStruct


def batch_specs_abstract(cfg: ModelConfig, shape: ShapeConfig):
    """Training/prefill batch ShapeDtypeStructs."""
    B, S = shape.global_batch, shape.seq_len
    n_pre = cfg.n_frontend_tokens if cfg.frontend == "vision" else 0
    b = {"tokens": sds((B, S - n_pre), jnp.int32),
         "targets": sds((B, S - n_pre), jnp.int32)}
    if cfg.frontend == "vision":
        b["frontend_embeds"] = sds((B, n_pre, cfg.d_model), jnp.float32)
    if cfg.enc_dec:
        b["src_embeds"] = sds((B, S, cfg.d_model), jnp.float32)
    return b


def input_specs(model: Model, shape_name: str,
                opt_cfg: AdamWConfig = AdamWConfig()):
    """-> (kind, abstract args tuple) for the step that this shape lowers:
    train -> train_step(state, batch); prefill -> (params, batch, cache);
    decode -> serve_step(params, token, pos, cache)."""
    cfg = model.cfg
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        state = jax.eval_shape(
            lambda k: make_train_state(model, k, opt_cfg),
            jax.random.PRNGKey(0))
        return "train", (state, batch_specs_abstract(cfg, shape))
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    cache = jax.eval_shape(
        lambda: model.make_cache(shape.global_batch, shape.seq_len,
                                 jnp.dtype(cfg.dtype)))
    if shape.kind == "prefill":
        return "prefill", (params, batch_specs_abstract(cfg, shape), cache)
    token = sds((shape.global_batch, 1), jnp.int32)
    pos = sds((), jnp.int32)
    return "decode", (params, token, pos, cache)


def input_shardings(model: Model, shape_name: str, mesh: Mesh, abstract,
                    fsdp: bool = True):
    """NamedShardings matching ``input_specs`` output."""
    cfg = model.cfg
    shape = SHAPES[shape_name]
    dp = shd.dp_axes(mesh)
    bspec = shd.batch_specs(cfg, shape, mesh)
    if shape.kind == "train":
        state, batch = abstract
        state_specs = {"params": shd.param_specs(state["params"], mesh,
                                                 fsdp=fsdp),
                       "opt": shd.param_specs(state["opt"], mesh, fsdp=fsdp),
                       "step": P()}
        return (shd.named(mesh, state_specs), shd.named(mesh, bspec))
    if shape.kind == "prefill":
        params, batch, cache = abstract
        return (shd.named(mesh, shd.param_specs(params, mesh, fsdp=fsdp)),
                shd.named(mesh, bspec),
                shd.named(mesh, shd.cache_specs(cfg, shape, mesh, cache)))
    params, token, pos, cache = abstract
    n_dp = 1
    for a in dp:
        n_dp *= mesh.shape[a]
    tok_spec = P(dp, None) if shape.global_batch % n_dp == 0 \
        and shape.global_batch >= n_dp else P(None, None)
    return (shd.named(mesh, shd.param_specs(params, mesh, fsdp=fsdp)),
            shd.named(mesh, tok_spec),
            shd.named(mesh, P()),
            shd.named(mesh, shd.cache_specs(cfg, shape, mesh, cache)))
