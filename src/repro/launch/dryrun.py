import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, record memory/cost/collective analysis.

The two lines above MUST stay first: jax locks the device count on first
init, and the dry-run needs 512 placeholder host devices to build the
(2,16,16) production mesh.  Smoke tests and benchmarks must NOT import this
module (they want the real single device).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
      --mesh both --out results/dryrun
"""
import argparse   # noqa: E402
import json       # noqa: E402
import re         # noqa: E402
import time       # noqa: E402
import traceback  # noqa: E402

import jax        # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import ARCH_IDS, SHAPES, get_config, supported_shapes  # noqa: E402
from repro.launch.mesh import make_production_mesh                         # noqa: E402
from repro.launch.specs import input_shardings, input_specs                # noqa: E402
from repro.models.lm import build_model                                    # noqa: E402
from repro.optim.adamw import AdamWConfig                                  # noqa: E402
from repro.train.steps import (make_prefill_step, make_serve_step,         # noqa: E402
                               make_train_step)

# ---------------------------------------------------------------------------
# HLO collective accounting
# ---------------------------------------------------------------------------

_DT_BYTES = {"f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
             "bf16": 2, "f16": 2, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
             "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"=\s*(?P<res>.*?)\s*"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?P<suffix>-start|-done)?\(")


def _shape_bytes(txt: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(txt):
        if dt not in _DT_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DT_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-device bytes moved by each collective kind (sum of result-operand
    sizes of every collective op in the optimized, partitioned HLO)."""
    out = {}
    for m in _COLL_RE.finditer(hlo_text):
        if m.group("suffix") == "-done":
            continue        # async pair: count the -start only
        b = _shape_bytes(m.group("res"))
        out[m.group("op")] = out.get(m.group("op"), 0) + b
        out["total"] = out.get("total", 0) + b
    return out


# ---------------------------------------------------------------------------
# per-cell dry-run
# ---------------------------------------------------------------------------

def run_cell(arch: str, shape_name: str, multi_pod: bool,
             accum: int = 4, accum_dtype: str = "float32",
             fsdp: bool = True, carry_tp: bool = True) -> dict:
    cfg = get_config(arch)
    sup = supported_shapes(cfg)[shape_name]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    meta = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
            "family": cfg.family,
            "params": cfg.param_count(),
            "active_params": cfg.active_param_count()}
    if sup != "run":
        return {**meta, "status": "skip", "reason": sup}

    mesh = make_production_mesh(multi_pod=multi_pod)
    model = build_model(cfg)
    opt_cfg = AdamWConfig()
    kind, abstract = input_specs(model, shape_name, opt_cfg)
    shardings = input_shardings(model, shape_name, mesh, abstract,
                                fsdp=fsdp)

    if kind == "train":
        from repro.distributed import sharding as shd
        mb_specs = shd.batch_specs(cfg, SHAPES[shape_name], mesh)
        import jax.numpy as _jnp
        fn = make_train_step(model, opt_cfg, accum=accum, mb_specs=mb_specs,
                             accum_dtype=_jnp.dtype(accum_dtype))
        donate = (0,)
        out_sh = (shardings[0], None)
    elif kind == "prefill":
        fn = make_prefill_step(model)
        donate = (2,)
        out_sh = (None, shardings[2])
    else:
        fn = make_serve_step(model)
        donate = (3,)
        out_sh = (None, None, shardings[3])

    from repro.distributed.sharding import dp_axes
    from repro.models import compute as _compute

    t0 = time.time()
    with mesh, _compute.sharding_hints(dp=dp_axes(mesh), tp="model",
                                        carry_tp=carry_tp):
        jitted = jax.jit(fn, in_shardings=shardings, out_shardings=out_sh,
                         donate_argnums=donate)
        lowered = jitted.lower(*abstract)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    res = {**meta, "status": "ok", "kind": kind,
           "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
           "accum": accum if kind == "train" else None,
           "knobs": {"accum_dtype": accum_dtype, "fsdp": fsdp,
                     "carry_tp": carry_tp}}

    try:
        ma = compiled.memory_analysis()
        res["memory"] = {
            "argument_bytes": int(getattr(ma, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(ma, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(ma, "temp_size_in_bytes", 0)),
            "alias_bytes": int(getattr(ma, "alias_size_in_bytes", 0)),
            "code_bytes": int(getattr(ma, "generated_code_size_in_bytes", 0)),
        }
        res["memory"]["peak_bytes"] = (
            res["memory"]["argument_bytes"] + res["memory"]["output_bytes"]
            + res["memory"]["temp_bytes"] - res["memory"]["alias_bytes"])
    except Exception as e:  # pragma: no cover
        res["memory"] = {"error": str(e)}

    try:
        ca = compiled.cost_analysis()
        res["cost"] = {k: float(v) for k, v in ca.items()
                       if isinstance(v, (int, float)) and (
                           "flops" in k or "bytes" in k or "utilization" in k
                       )} if isinstance(ca, dict) else {}
        res["flops"] = float(ca.get("flops", 0.0)) if isinstance(ca, dict) \
            else 0.0
        res["bytes_accessed"] = float(ca.get("bytes accessed", 0.0)) \
            if isinstance(ca, dict) else 0.0
    except Exception as e:  # pragma: no cover
        res["cost"] = {"error": str(e)}

    txt = compiled.as_text()
    # loop-aware accounting (cost_analysis counts while bodies ONCE and
    # undercounts scanned programs ~40-150x — see launch/hlo_analysis.py)
    from repro.launch import hlo_analysis
    ana = hlo_analysis.analyze(txt)
    res["hlo"] = {"flops": ana["flops"], "bytes": ana["bytes"]}
    res["collectives"] = ana["collectives"]
    res["top_collectives"] = ana.get("top_collectives", [])
    res["collectives_unrolled_once"] = collective_bytes(txt)
    res["hlo_ops"] = {op: txt.count(f" {op}(")
                      for op in ("fusion", "while", "dot", "convolution",
                                 "custom-call")}
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--accum", type=int, default=4)
    ap.add_argument("--accum-dtype", default="float32")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--no-carry-tp", action="store_true")
    args = ap.parse_args()

    archs = list(ARCH_IDS) if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for multi_pod in meshes:
        mesh_name = "2x16x16" if multi_pod else "16x16"
        for arch in archs:
            for shape_name in shapes:
                path = os.path.join(
                    args.out, f"{mesh_name}__{arch}__{shape_name}.json")
                if os.path.exists(path) and not args.force:
                    print(f"[cached] {mesh_name} {arch} {shape_name}")
                    continue
                print(f"[run]    {mesh_name} {arch} {shape_name} ...",
                      flush=True)
                try:
                    res = run_cell(arch, shape_name, multi_pod,
                                   accum=args.accum,
                                   accum_dtype=args.accum_dtype,
                                   fsdp=not args.no_fsdp,
                                   carry_tp=not args.no_carry_tp)
                except Exception:
                    res = {"arch": arch, "shape": shape_name,
                           "mesh": mesh_name, "status": "FAIL",
                           "error": traceback.format_exc()[-2000:]}
                    failures += 1
                with open(path, "w") as f:
                    json.dump(res, f, indent=1)
                st = res["status"]
                extra = ""
                if st == "ok":
                    mem = res.get("memory", {}).get("peak_bytes", 0)
                    extra = (f" compile={res['compile_s']:.0f}s "
                             f"peak={mem/2**30:.2f}GiB "
                             f"coll={res['collectives'].get('total',0)/2**20:.0f}MiB")
                print(f"         -> {st}{extra}", flush=True)
    print(f"done; {failures} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
