"""Training driver: data pipeline -> pjit train step -> checkpoints, with
fault-tolerance wiring (auto-resume, preemption checkpointing, straggler
monitor).

Runs end-to-end on this CPU container at reduced scale::

  PYTHONPATH=src python -m repro.launch.train --arch qwen3_8b --steps 50 \
      --ckpt-dir /tmp/ckpt

On a TPU slice the same driver runs the full config over the production
mesh (--full --model-parallel 16); jax.distributed initialization and the
per-host data sharding come from the environment.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpoint import CheckpointManager
from repro.configs import SHAPES, get_config
from repro.configs.base import ShapeConfig
from repro.data.pipeline import DataConfig, SyntheticPipeline
from repro.distributed import sharding as shd
from repro.ft.monitor import PreemptionHandler, StepMonitor
from repro.launch.mesh import make_local_mesh
from repro.models import compute
from repro.models.lm import build_model
from repro.optim.adamw import AdamWConfig
from repro.train.steps import make_train_state, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_8b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--full", action="store_true",
                    help="full-size config (TPU slice), not the smoke config")
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--tune", default="",
                    help="TileProgram json from repro.core.vectorizer; "
                         "routes hot ops through tuned Pallas kernels")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    model = build_model(cfg)
    opt_cfg = AdamWConfig(lr=args.lr, total_steps=args.steps,
                          warmup_steps=max(1, args.steps // 10))
    mesh = make_local_mesh(args.model_parallel)

    pipe = SyntheticPipeline(cfg, shape, DataConfig(seed=0))
    step_fn = make_train_step(model, opt_cfg, accum=args.accum)

    state = make_train_state(model, jax.random.PRNGKey(0), opt_cfg)
    start_step = 0
    mgr = None
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir)
        state, restored = mgr.restore(state)
        if restored is not None:
            start_step = restored
            print(f"[train] resumed from step {restored}")

    state_sh = shd.named(mesh, shd.param_specs(state, mesh))
    jitted = jax.jit(step_fn, in_shardings=(state_sh, None),
                     out_shardings=(state_sh, None), donate_argnums=0)

    tune_ctx = None
    if args.tune:
        from repro.core.vectorizer import TileProgram, inject
        prog = TileProgram.load(args.tune)
        # interpret=True on CPU; on a TPU slice the kernels compile natively
        tune_ctx = inject(prog, interpret=jax.devices()[0].platform == "cpu")
        tune_ctx.__enter__()        # active during tracing below
        print(f"[tune] injected {len(prog.tiles)} kernel-site tile choices")

    monitor = StepMonitor()
    preempt = PreemptionHandler()
    losses = []
    with mesh:
        for step in range(start_step, args.steps):
            batch = pipe.batch_at(step)
            monitor.start()
            state, metrics = jitted(state, batch)
            loss = float(metrics["loss"])
            ev = monitor.stop(step)
            losses.append(loss)
            if ev:
                print(f"[ft] straggler flagged: {ev}")
            if step % 10 == 0 or step == args.steps - 1:
                print(f"step {step:5d} loss {loss:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"lr {float(metrics['lr']):.2e}")
            if mgr and ((step + 1) % args.ckpt_every == 0):
                mgr.save_async(state, step + 1)
            if preempt.should_stop:
                print("[ft] preemption signal — checkpointing and exiting")
                if mgr:
                    mgr.save(state, step + 1)
                break
    if mgr:
        mgr.wait()
    print(f"[train] done: first loss {losses[0]:.4f} -> last "
          f"{losses[-1]:.4f}")
    return losses


if __name__ == "__main__":
    main()
