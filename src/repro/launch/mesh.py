"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run must set
``--xla_force_host_platform_device_count`` *before* first jax init.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model_parallel: int = 1):
    """Smoke-scale mesh on whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    assert n % model_parallel == 0
    return jax.make_mesh((n // model_parallel, model_parallel),
                         ("data", "model"))
