"""Serving driver: batched prefill + greedy decode with a KV/state cache,
optionally running under a NeuroVectorizer tile plan (``repro.api``).

Smoke scale on CPU::

  PYTHONPATH=src python -m repro.launch.serve --arch xlstm_1_3b \
      --batch 4 --prompt-len 32 --gen 16

Tile tuning: ``--autotune brute`` plans tiles for the serving kernels with
any registered agent (modelled speedup is printed); ``--tiles f.json``
loads a saved :class:`~repro.api.TileProgram` instead; ``--inject`` routes
the decode through the tuned Pallas kernels (interpret mode off-TPU).
``--measured`` swaps the analytic reward oracle for compile-and-time
measurement of the kernels themselves (``repro.measure``; native on
TPU/GPU, interpret-mode with capped shapes on CPU) and ``--measure-db
PATH`` persists the timings so repeat invocations re-time nothing.
``--transport pool --workers N`` fans the measurements out to N
subprocess workers (the ``WorkerPoolTransport``) instead of timing in
this process; ``--transport socket --hosts a:7761,b:7761`` ships them to
remote ``python -m repro.fleet serve-worker`` daemons instead
(``repro.fleet``; a ``fleet://host:port`` ``--measure-db`` attaches the
shared artifact service).

Warm starts (``repro.artifacts``): ``--agent-ckpt DIR`` restores a
fitted agent saved by ``nv.save()``/``save_agent`` and skips the fit
entirely (tune-only serving — the paper's train-once deployment);
``--program-store PATH`` memoizes finished tile programs, so a serving
process that has seen this site set before performs zero agent
inferences.
"""
from __future__ import annotations

import argparse
import contextlib
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models.lm import build_model
from repro.train.steps import make_prefill_step, make_serve_step


def _warn_missing_tiles(prog, sites) -> list:
    """Sites a loaded ``TileProgram`` does not cover run at baseline
    tiles; say so on stderr (with the site names) instead of silently
    degrading.  Returns the missing site names."""
    missing = [s for s in sites if s.key() not in prog.tiles]
    # names dedup'd for readability (prefill/decode share site names)
    names = sorted({s.site for s in missing})
    if missing:
        print(f"[serve] WARNING: tile plan covers {len(sites) - len(missing)}"
              f"/{len(sites)} extracted sites; these run at baseline "
              f"tiles: {', '.join(names)}", file=sys.stderr)
    return names


def _serving_plan(args, sites):
    """Tune through ``TuningService(serving=...)``: the request is
    admitted to the deadline-aware batch server and (model/surrogate
    oracles + brute search) executes as one fused device dispatch."""
    from repro.configs.neurovec import DEFAULT
    from repro.service import TuningService

    svc_kw = {}
    if args.program_store:
        svc_kw["program_store"] = args.program_store
    oracle = "model"
    if args.measured:
        oracle = "measured"
        svc_kw.update(
            db_path=args.measure_db, transport=args.transport,
            workers=(args.workers if args.transport == "pool" else None))
        if args.transport == "socket":
            svc_kw["hosts"] = args.hosts.split(",")
        else:
            svc_kw["reps"] = args.measure_reps
    with TuningService(DEFAULT, serving={"slo_ms": args.slo_ms},
                       **svc_kw) as svc:
        sess = svc.open_session(agent=args.autotune, oracle=oracle,
                                agent_ckpt=args.agent_ckpt or None)
        if not args.agent_ckpt:
            fit_kw = ({"total_steps": args.autotune_steps}
                      if args.autotune == "ppo" else {})
            sess.fit(sites, **fit_kw)
        prog = sess.tune(sites)            # admitted under the SLO budget
        st = svc.server.stats()
        print(f"[serve] serving: p50 {st['serving_tune_p50_ms']:.2f} ms, "
              f"p99 {st['serving_tune_p99_ms']:.2f} ms "
              f"(slo {args.slo_ms:.0f} ms), shed: "
              f"{st['serving_shed_total']}, fused dispatches: "
              f"{st['serving_fused_dispatches_total']}, "
              f"health: {svc.server.health()}")
    return prog


def _tile_plan(args, model, params, batch, cache):
    """Extract the serving-step kernel sites and produce a TileProgram
    through the ``repro.api`` facade (or load one from disk)."""
    from repro import api

    B = batch["tokens"].shape[0]
    tok = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    sites = {s.key(): s for s in api.extract_sites(
        make_prefill_step(model), params, batch, cache)}
    sites.update((s.key(), s) for s in api.extract_sites(
        make_serve_step(model), params, tok, jnp.int32(0), cache))
    sites = list(sites.values())

    if args.tiles:
        prog = api.TileProgram.load(args.tiles)
        _warn_missing_tiles(prog, sites)
        nv = None
    elif args.serving:
        prog = _serving_plan(args, sites)
        if args.save_tiles:
            prog.save(args.save_tiles)
        nv = None
    else:
        oracle_kw = {}
        if args.measured:
            oracle_kw = dict(oracle="measured", db_path=args.measure_db,
                             transport=args.transport,
                             workers=(args.workers
                                      if args.transport == "pool" else None),
                             hosts=(args.hosts.split(",")
                                    if args.transport == "socket" else None),
                             prune_topk=args.prune_topk,
                             surrogate=args.surrogate)
            if args.transport != "socket":
                # serve-worker hosts own their runner config; reps= on the
                # client would be rejected by make_transport
                oracle_kw["oracle_kwargs"] = dict(reps=args.measure_reps)
        nv = api.NeuroVectorizer(agent=args.autotune,
                                 program_store=args.program_store,
                                 trace=args.trace_out,
                                 **oracle_kw)
        if args.agent_ckpt:
            # warm start: the checkpointed policy replaces the fit
            api.load_agent(args.agent_ckpt, agent=nv.agent)
            if isinstance(nv.agent, api.BruteForceAgent):
                nv.agent.oracle = nv.oracle
            print(f"[serve] agent warm-start: {args.agent_ckpt} "
                  f"(fit skipped)")
        else:
            fit_kw = ({"total_steps": args.autotune_steps}
                      if args.autotune == "ppo" else {})
            nv.fit(sites, **fit_kw)
        prog = nv.tune_sites(sites)
        if args.save_tiles:
            prog.save(args.save_tiles)
    env = nv.oracle if nv is not None else None
    sp = api.program_speedup(prog, sites, env)
    how = "measured" if args.measured and nv is not None else "modelled"
    print(f"[serve] tile plan: {len(prog.tiles)} tiles over {len(sites)} "
          f"sites, {how} speedup {sp:.2f}x")
    if nv is not None and args.program_store:
        st = nv.program_store.stats()
        print(f"[serve] program store: {st['hits']} hits, "
              f"{st['misses']} misses, {nv.agent_inferences} agent "
              f"inferences ({st['entries']} stored programs)")
    if args.measured and nv is not None:
        t = env.measure_fn.transport
        st = t.stats()
        print(f"[serve] measurements: {st['transport_timed_pairs_total']} "
              f"timed, {st['transport_hits_total']} DB hits, "
              f"{st['transport_coalesced_total']} coalesced "
              f"({t.backend_key})")
        if args.prune_topk is not None:
            state = "active" if env.prune_active else \
                "inactive (DB too cold to train the surrogate)"
            print(f"[serve] pruning top-{args.prune_topk}: {state}, "
                  f"{env.pruned_pairs} pairs surrogate-priced")
        print(f"[serve] health: {nv.health()}")
    if nv is not None:
        nv.close()                      # release pool workers / DB handles
        if args.trace_out:
            print(f"[serve] trace: {nv.tracer.n_spans} spans + "
                  f"{nv.tracer.n_events} events -> {args.trace_out} "
                  f"(chrome://tracing via repro.obs.to_chrome_trace)")
    return prog


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--autotune", default=None,
                    help="tune serving kernels with this repro.api agent "
                         "(ppo, dtree, nns, brute, random, polly, baseline)")
    ap.add_argument("--autotune-steps", type=int, default=2000,
                    help="RL budget when --autotune ppo")
    ap.add_argument("--serving", action="store_true",
                    help="tune through the latency-SLO serving path "
                         "(repro.serving): requests are admitted to a "
                         "deadline-aware batch server and executed as "
                         "fused device dispatches")
    ap.add_argument("--slo-ms", type=float, default=100.0,
                    help="per-request tune SLO budget for --serving")
    ap.add_argument("--tiles", default=None,
                    help="load a saved TileProgram instead of tuning")
    ap.add_argument("--save-tiles", default=None)
    ap.add_argument("--measured", action="store_true",
                    help="tune against wall-clock kernel timings "
                         "(repro.measure) instead of the analytic model")
    ap.add_argument("--measure-db", default=None,
                    help="persistent measurement-DB path (repeat runs "
                         "against the same path re-time nothing)")
    ap.add_argument("--measure-reps", type=int, default=3,
                    help="timing repetitions per (site, tile) pair")
    ap.add_argument("--prune-topk", type=int, default=None,
                    help="with --measured: only each site's top-K "
                         "surrogate-ranked tile candidates are timed; the "
                         "rest are priced by the learned cost model "
                         "(repro.surrogate, trained from --measure-db)")
    ap.add_argument("--surrogate", default=None,
                    help="surrogate checkpoint directory for --prune-topk "
                         "(default: train from the measurement DB)")
    ap.add_argument("--transport", choices=("inproc", "pool", "socket"),
                    default="inproc",
                    help="how measurements execute: this process, a "
                         "subprocess worker pool (repro.measure), or a "
                         "remote serve-worker fleet (repro.fleet)")
    ap.add_argument("--workers", type=int, default=2,
                    help="pool size for --transport pool")
    ap.add_argument("--hosts", default=None,
                    help="comma-separated serve-worker host:port list for "
                         "--transport socket (start them with "
                         "`python -m repro.fleet serve-worker`)")
    ap.add_argument("--agent-ckpt", default=None,
                    help="warm-start --autotune from a saved agent "
                         "artifact directory (repro.artifacts; skips fit)")
    ap.add_argument("--program-store", default=None,
                    help="persistent ProgramStore path: previously-tuned "
                         "site sets are answered by lookup (zero agent "
                         "inferences)")
    ap.add_argument("--inject", action="store_true",
                    help="run decode through the tuned Pallas kernels")
    ap.add_argument("--trace-out", default=None,
                    help="append the tuning span tree (session -> fit -> "
                         "tune -> submit/drain) to this JSONL trace file "
                         "(repro.obs; convert with to_chrome_trace)")
    ap.add_argument("--metrics-out", default=None,
                    help="write the final repro.obs metrics snapshot to "
                         "this JSON file")
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="serve the live metrics registry in Prometheus "
                         "text format on this HTTP port (0 = ephemeral)")
    args = ap.parse_args(argv)
    if args.inject and not (args.autotune or args.tiles):
        ap.error("--inject requires a tile plan: pass --autotune or --tiles")
    if args.serving and (args.tiles or not args.autotune):
        ap.error("--serving tunes through the batch server: pass "
                 "--autotune and no --tiles (which loads a finished plan)")
    if args.serving and args.prune_topk is not None:
        ap.error("--prune-topk is not supported on the --serving path")
    if args.serving and args.trace_out:
        ap.error("--trace-out records the facade span tree; it does not "
                 "apply to --serving (use --metrics-out for serving_* "
                 "series)")
    if args.measured and (args.tiles or not args.autotune):
        ap.error("--measured requires --autotune and no --tiles (it "
                 "changes the tuning oracle; --tiles loads a finished "
                 "plan)")
    if (args.agent_ckpt or args.program_store) and not args.autotune:
        ap.error("--agent-ckpt/--program-store warm-start the tuning "
                 "pipeline: pass --autotune (they do not apply to --tiles, "
                 "which loads a finished plan)")
    if args.measure_reps < 1:
        ap.error(f"--measure-reps must be >= 1, got {args.measure_reps}")
    if args.prune_topk is not None and not args.measured:
        ap.error("--prune-topk applies only to --measured tuning")
    if args.prune_topk is not None and args.prune_topk < 1:
        ap.error(f"--prune-topk must be >= 1, got {args.prune_topk}")
    if args.surrogate and args.prune_topk is None:
        ap.error("--surrogate applies only with --prune-topk")
    if args.workers < 1:
        ap.error(f"--workers must be >= 1, got {args.workers}")
    if args.transport == "socket" and not args.hosts:
        ap.error("--transport socket needs --hosts host:port[,host:port...] "
                 "naming the serve-worker daemons")
    if args.hosts and args.transport != "socket":
        ap.error("--hosts applies only to --transport socket")
    if args.trace_out and not args.autotune:
        ap.error("--trace-out records the tuning span tree: pass "
                 "--autotune (loading --tiles produces no spans)")
    if args.metrics_port is not None and not 0 <= args.metrics_port < 65536:
        ap.error(f"--metrics-port must be in [0, 65536), got "
                 f"{args.metrics_port}")
    if args.measured:
        workers = args.workers if args.transport == "pool" else "-"
        reps = args.measure_reps if args.transport != "socket" else "-"
        where = (f"hosts={args.hosts}" if args.transport == "socket"
                 else f"workers={workers}")
        print(f"[serve] measured oracle: transport={args.transport} "
              f"{where} reps={reps} "
              f"db={args.measure_db or '-'}")

    metrics_srv = None
    if args.metrics_port is not None:
        from repro.obs import MetricsServer
        metrics_srv = MetricsServer(port=args.metrics_port).start()
        print(f"[serve] metrics: http://127.0.0.1:{metrics_srv.port}"
              f"/metrics (Prometheus text format)")

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    B = args.batch
    ctx = args.prompt_len + args.gen
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, args.prompt_len),
                                 0, cfg.vocab_size, jnp.int32)
    batch = {"tokens": prompts}
    if cfg.frontend == "vision":
        batch["frontend_embeds"] = jnp.zeros(
            (B, cfg.n_frontend_tokens, cfg.d_model))
    if cfg.enc_dec:
        batch["src_embeds"] = jax.random.normal(
            jax.random.PRNGKey(2), (B, args.prompt_len, cfg.d_model)) * 0.02

    cache = model.make_cache(B, ctx, jnp.dtype(cfg.dtype))
    prefill = jax.jit(make_prefill_step(model))
    serve = jax.jit(make_serve_step(model), donate_argnums=(3,))

    prog = None
    if args.autotune or args.tiles:
        prog = _tile_plan(args, model, params, batch, cache)

    run_ctx = contextlib.nullcontext()
    if prog is not None and args.inject:
        from repro import api
        # interpret keyed on the real backend: Pallas compiles natively on
        # TPU, interprets elsewhere — independent of the model-size flag
        run_ctx = api.inject(prog,
                             interpret=jax.default_backend() != "tpu")

    with run_ctx:
        t0 = time.time()
        logits, cache = prefill(params, batch, cache)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        out = [tok]
        n_pre = cfg.n_frontend_tokens if cfg.frontend == "vision" else 0
        for i in range(args.gen - 1):
            pos = jnp.int32(n_pre + args.prompt_len + i)
            tok, logits, cache = serve(params, tok, pos, cache)
            out.append(tok)
        seq = jnp.concatenate(out, axis=1)
        dt = time.time() - t0
    print(f"[serve] {B} requests, {args.gen} tokens each in {dt:.2f}s "
          f"({B * args.gen / dt:.1f} tok/s)")
    print("[serve] sample:", seq[0].tolist())
    if args.metrics_out:
        import json as _json

        from repro.obs import get_registry
        with open(args.metrics_out, "w") as f:
            _json.dump(get_registry().snapshot(), f, indent=1, default=str)
        print(f"[serve] metrics snapshot -> {args.metrics_out}")
    if metrics_srv is not None:
        metrics_srv.close()
    return seq


if __name__ == "__main__":
    main()
