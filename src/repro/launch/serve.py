"""Serving driver: batched prefill + greedy decode with a KV/state cache.

Smoke scale on CPU::

  PYTHONPATH=src python -m repro.launch.serve --arch xlstm_1_3b \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models.lm import build_model
from repro.train.steps import make_prefill_step, make_serve_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    B = args.batch
    ctx = args.prompt_len + args.gen
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, args.prompt_len),
                                 0, cfg.vocab_size, jnp.int32)
    batch = {"tokens": prompts}
    if cfg.frontend == "vision":
        batch["frontend_embeds"] = jnp.zeros(
            (B, cfg.n_frontend_tokens, cfg.d_model))
    if cfg.enc_dec:
        batch["src_embeds"] = jax.random.normal(
            jax.random.PRNGKey(2), (B, args.prompt_len, cfg.d_model)) * 0.02

    cache = model.make_cache(B, ctx, jnp.dtype(cfg.dtype))
    prefill = jax.jit(make_prefill_step(model))
    serve = jax.jit(make_serve_step(model), donate_argnums=(3,))

    t0 = time.time()
    logits, cache = prefill(params, batch, cache)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    out = [tok]
    n_pre = cfg.n_frontend_tokens if cfg.frontend == "vision" else 0
    for i in range(args.gen - 1):
        pos = jnp.int32(n_pre + args.prompt_len + i)
        tok, logits, cache = serve(params, tok, pos, cache)
        out.append(tok)
    seq = jnp.concatenate(out, axis=1)
    dt = time.time() - t0
    print(f"[serve] {B} requests, {args.gen} tokens each in {dt:.2f}s "
          f"({B * args.gen / dt:.1f} tok/s)")
    print("[serve] sample:", seq[0].tolist())
    return seq


if __name__ == "__main__":
    main()
