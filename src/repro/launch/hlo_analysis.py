"""Loop-aware analysis of optimized (post-SPMD) HLO text.

``compiled.cost_analysis()`` visits every computation ONCE — a step function
built from ``lax.scan`` (layers, microbatches, attention chunks) therefore
undercounts FLOPs, bytes and collective traffic by the loop trip counts
(measured ~40-150x on our stacks).  This walker parses the HLO module text,
recovers while-loop trip counts from their condition computations, and
accumulates per-device totals with loop multipliers:

* flops            — dot ops: 2 * prod(result dims) * contraction size
                     (contraction inferred from operand/result elements)
* bytes            — every op: operand reads + result writes (post-fusion
                     HLO materializes each op result, so this matches the
                     "bytes accessed" definition)
* collective bytes — per collective kind, result-operand sizes

All quantities are per-device (the module is the partitioned program).
"""
from __future__ import annotations

import math
import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DT_BYTES = {"f64": 8, "s64": 8, "u64": 8, "c64": 8, "f32": 4, "s32": 4,
             "u32": 4, "bf16": 2, "f16": 2, "s16": 2, "u16": 2, "s8": 1,
             "u8": 1, "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c128": 16}

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
# result type may be a tuple containing /*index=N*/ comments — match to the
# first ')' (tuples never nest parens in HLO text)
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(.*?\)|\S+)\s+([\w\-]+)\(")
_CALLED_RE = re.compile(r"(?:calls|to_apply|body|condition|branch_computations)="
                        r"[{]?%?([\w.\-]+(?:,\s*%?[\w.\-]+)*)[}]?")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\([^)]*\))?\s*->",
                          re.M)

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_elems(txt: str) -> List[Tuple[str, int]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(txt):
        if dt not in _DT_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        out.append((dt, n))
    return out


def _shape_bytes(txt: str) -> int:
    return sum(n * _DT_BYTES[dt] for dt, n in _shape_elems(txt))


@dataclass
class _Comp:
    name: str
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = field(default_factory=dict)
    coll_sites: List[Tuple[str, int, str]] = field(default_factory=list)
    # (multiplier_kind, called_comp): "while" bodies get trip count,
    # fusions/calls get 1
    calls: List[Tuple[str, str, Optional[int]]] = field(default_factory=list)


def _split_computations(txt: str) -> Dict[str, List[str]]:
    """Computation header = top-level line ending in '{' with a '->' return
    annotation; signatures contain nested parens, so take the name token."""
    comps: Dict[str, List[str]] = {}
    cur = None
    for line in txt.splitlines():
        if cur is None:
            ls = line.strip()
            if ls.endswith("{") and "->" in ls and not line.startswith(" "):
                tok = ls.split()[1] if ls.startswith("ENTRY") else \
                    ls.split()[0]
                name = tok.lstrip("%").split("(")[0]
                cur = name
                comps[cur] = []
        else:
            if line.strip() == "}":
                cur = None
            else:
                comps[cur].append(line)
    return comps


def _trip_count(cond_lines: List[str]) -> int:
    """Recover the trip count from a while condition computation: the
    largest s32 constant used in (or feeding) a compare."""
    consts = {}
    best = 1
    for ln in cond_lines:
        m = re.search(r"%?([\w.\-]+)\s*=\s*s32\[\]\s*constant\((\d+)\)", ln)
        if m:
            consts[m.group(1)] = int(m.group(2))
    for ln in cond_lines:
        if " compare(" in ln or "_compare_" in ln or " call(" in ln \
                or " fusion(" in ln:
            for name, v in consts.items():
                if "%" + name in ln or "(" + name in ln or " " + name in ln:
                    best = max(best, v)
    if best == 1 and consts:
        best = max(consts.values())
    return max(best, 1)


def analyze(txt: str) -> dict:
    comps_lines = _split_computations(txt)
    comps: Dict[str, _Comp] = {}

    for name, lines in comps_lines.items():
        c = _Comp(name)
        # pass 1: symbol table name -> result type text
        sym: Dict[str, str] = {}
        parsed = []
        for ln in lines:
            m = _OP_RE.match(ln)
            if not m:
                continue
            res_name, result_txt, op = m.group(1), m.group(2), m.group(3)
            sym[res_name] = result_txt
            parsed.append((res_name, result_txt, op, ln[m.end():]))
        # pass 2: accounting
        for res_name, result_txt, op, rest in parsed:
            res_b = _shape_bytes(result_txt)
            arg_names = re.findall(r"%([\w.\-]+)", rest.split("),")[0]
                                   if ")," in rest else rest)
            arg_b = sum(_shape_bytes(sym.get(a, "")) for a in arg_names)
            # bytes: only ops that actually move data.  Tuple plumbing on
            # the while carry (gte/tuple/bitcast of the full stacked-weight
            # tuple) would otherwise be charged as DRAM traffic every
            # iteration (measured ~100x inflation).
            if op not in ("get-tuple-element", "tuple", "bitcast",
                          "parameter", "constant", "after-all",
                          "partition-id", "reshape", "optimization-barrier",
                          "while", "call", "conditional"):
                c.bytes += res_b + arg_b

            if op in ("dot", "convolution"):
                m_c = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", rest)
                lhs_txt = sym.get(arg_names[0], "") if arg_names else ""
                m_lhs = _SHAPE_RE.search(lhs_txt)
                res_elems = sum(n for _, n in _shape_elems(result_txt))
                if m_c and m_lhs:
                    dims = ([int(d) for d in m_lhs.group(2).split(",")]
                            if m_lhs.group(2) else [])
                    k = 1
                    for ci in m_c.group(1).split(","):
                        if ci and int(ci) < len(dims):
                            k *= dims[int(ci)]
                    c.flops += 2.0 * res_elems * k
                else:
                    c.flops += 2.0 * res_elems
            elif op.startswith("fusion") or op.startswith("wrapped"):
                c.flops += sum(n for _, n in _shape_elems(result_txt))

            for coll in COLLECTIVES:
                if op == coll or op == coll + "-start":
                    c.coll[coll] = c.coll.get(coll, 0) + res_b
                    m_meta = re.search(r'op_name="([^"]*)"', rest)
                    tag = m_meta.group(1)[:120] if m_meta else "?"
                    c.coll_sites.append((coll, res_b, tag))

            if op == "while":
                mb = re.search(r"body=%?([\w.\-]+)", rest)
                mc = re.search(r"condition=%?([\w.\-]+)", rest)
                if mb:
                    trips = _trip_count(
                        comps_lines.get(mc.group(1), []) if mc else [])
                    c.calls.append(("while", mb.group(1), trips))
            elif op in ("call", "async-start"):
                mt = re.search(r"to_apply=%?([\w.\-]+)", rest)
                if mt:
                    c.calls.append(("call", mt.group(1), 1))
            elif op == "conditional":
                for mt in re.findall(r"branch_computations=\{([^}]*)\}",
                                     rest):
                    for b in mt.split(","):
                        c.calls.append(("branch", b.strip().lstrip("%"), 1))
        comps[name] = c

    entry = None
    for ln in txt.splitlines():
        if ln.startswith("ENTRY"):
            m = re.match(r"ENTRY\s+%?([\w.\-]+)", ln)
            if m:
                entry = m.group(1)
                break
    if entry is None:
        entry = next(iter(comps))

    totals = {"flops": 0.0, "bytes": 0.0,
              "collectives": defaultdict(float)}
    site_totals = defaultdict(float)
    seen_stack = []

    def walk(name: str, mult: float):
        if name not in comps or name in seen_stack or mult <= 0:
            return
        seen_stack.append(name)
        c = comps[name]
        totals["flops"] += c.flops * mult
        totals["bytes"] += c.bytes * mult
        for k, v in c.coll.items():
            totals["collectives"][k] += v * mult
        for kind, b, tag in c.coll_sites:
            site_totals[(kind, tag)] += b * mult
        for kind, callee, trips in c.calls:
            if kind == "while":
                walk(callee, mult * trips)
            elif kind in ("call", "branch"):
                walk(callee, mult)
        seen_stack.pop()

    walk(entry, 1.0)
    coll = dict(totals["collectives"])
    coll["total"] = sum(coll.values())
    top = sorted(site_totals.items(), key=lambda kv: -kv[1])[:12]
    return {"flops": totals["flops"], "bytes": totals["bytes"],
            "collectives": coll,
            "top_collectives": [
                {"kind": k, "bytes": v, "op": t} for (k, t), v in top]}
