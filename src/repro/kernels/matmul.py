"""Tiled matmul Pallas kernel — the primary tunable site (VF/IF analogue).

Grid is (M/bm, N/bn, K/bk); the K dimension is innermost (sequential on
TPU), accumulating into a VMEM f32 scratch tile.  ``(bm, bn, bk)`` are the
factors the NeuroVectorizer agent picks; they directly set the VMEM working
set (bm*bk + bk*bn + bm*bn tiles, double-buffered by the pipeline) and the
MXU utilization (alignment to 128x128).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _matmul_kernel(x_ref, w_ref, o_ref, acc_ref, *, n_k: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def matmul_pallas(x: jax.Array, w: jax.Array, *, block_m: int, block_n: int,
                  block_k: int, interpret: bool = False) -> jax.Array:
    """x: (M, K), w: (K, N) -> (M, N).  Pads to tile multiples internally."""
    M, K = x.shape
    K2, N = w.shape
    assert K == K2

    bm = min(block_m, _ceil_mult(M, 8))
    bn = min(block_n, _ceil_mult(N, 128))
    bk = min(block_k, _ceil_mult(K, 128))

    Mp, Np, Kp = _ceil_mult(M, bm), _ceil_mult(N, bn), _ceil_mult(K, bk)
    if (Mp, Kp) != (M, K):
        x = jnp.pad(x, ((0, Mp - M), (0, Kp - K)))
    if (Kp, Np) != (K, N):
        w = jnp.pad(w, ((0, Kp - K), (0, Np - N)))

    grid = (Mp // bm, Np // bn, Kp // bk)
    out = pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w)
    return out[:M, :N]


def _ceil_mult(x: int, m: int) -> int:
    return -(-x // m) * m
