"""jit'd wrappers around the Pallas kernels.

``tiles`` is the injected factor tuple from the NeuroVectorizer agent
(``repro.core.vectorizer``); ``None`` falls back to the heuristic baseline
(``repro.core.costmodel.baseline_tiles``) — exactly as un-pragma'd loops
fall back to LLVM's default cost model.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.chunk_scan import chunk_scan_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.matmul import matmul_pallas


def _default_matmul_tiles(M: int, N: int, K: int) -> Tuple[int, int, int]:
    from repro.core.costmodel import baseline_matmul_tiles
    return baseline_matmul_tiles(M, N, K)


def _default_attn_tiles(Sq: int, Skv: int) -> Tuple[int, int]:
    from repro.core.costmodel import baseline_attn_tiles
    return baseline_attn_tiles(Sq, Skv)


@functools.partial(jax.jit, static_argnames=("tiles", "interpret"))
def matmul(x: jax.Array, w: jax.Array,
           tiles: Optional[Tuple[int, int, int]] = None,
           interpret: bool = False) -> jax.Array:
    M, K = x.shape
    _, N = w.shape
    bm, bn, bk = tiles if tiles is not None else _default_matmul_tiles(M, N, K)
    return matmul_pallas(x, w, block_m=bm, block_n=bn, block_k=bk,
                         interpret=interpret)


@functools.partial(jax.jit,
                   static_argnames=("causal", "scale", "tiles", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool, scale: float,
                    tiles: Optional[Tuple[int, int]] = None,
                    interpret: bool = False) -> jax.Array:
    B, Hq, Sq, D = q.shape
    _, Hkv, Skv, _ = k.shape
    # TileProgram entries carry the unified 3-head action; attention uses
    # the first two factors
    bq, bkv = tiles[:2] if tiles is not None \
        else _default_attn_tiles(Sq, Skv)
    if Hq != Hkv:   # expand GQA groups for the kernel
        rep = Hq // Hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    return flash_attention_pallas(q, k, v, causal=causal, scale=scale,
                                  block_q=bq, block_kv=bkv,
                                  interpret=interpret)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def chunk_scan(x: jax.Array, Bm: jax.Array, Cm: jax.Array, la: jax.Array,
               chunk: int = 256, interpret: bool = False) -> jax.Array:
    return chunk_scan_pallas(x, Bm, Cm, la, chunk=chunk, interpret=interpret)
