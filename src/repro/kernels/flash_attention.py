"""Flash-attention Pallas kernel with tunable (block_q, block_kv).

Grid is (B*H, Sq/bq, Skv/bkv); the kv dimension is innermost/sequential and
carries the online-softmax state (m, l, acc) in VMEM scratch.  ``(bq, bkv)``
are the NeuroVectorizer-tunable factors for attention sites.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, n_kv: int, bq: int, bkv: int,
                  q_off: int):
    kv_i = pl.program_id(2)
    q_i = pl.program_id(1)

    @pl.when(kv_i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                                 # (bq, d)
    k = k_ref[0]                                 # (bkv, d)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale   # (bq, bkv)

    if causal:
        # bottom-right aligned (matches ``ref.attention_ref``): query row i
        # attends to keys 0..i + (Skv - Sq), so for Sq != Skv the final query
        # still sees the full key sequence.
        q_pos = (q_i * bq + q_off
                 + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0))
        k_pos = kv_i * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
        s = jnp.where(q_pos >= k_pos, s, NEG_INF)

    m_prev, l_prev = m_ref[...], l_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = (acc_ref[...] * corr
                    + jax.lax.dot_general(
                        p.astype(v_ref.dtype), v_ref[0],
                        (((1,), (0,)), ((), ())),
                        preferred_element_type=jnp.float32))
    m_ref[...], l_ref[...] = m_new, l_new

    @pl.when(kv_i == n_kv - 1)
    def _flush():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool, scale: float, block_q: int,
                           block_kv: int,
                           interpret: bool = False) -> jax.Array:
    """q: (B, H, Sq, D); k, v: (B, Hkv, Skv, D).  GQA groups are expanded by
    the wrapper in ``ops.py``; here H == Hkv."""
    B, H, Sq, D = q.shape
    _, _, Skv, _ = k.shape
    bq = min(block_q, Sq)
    bkv = min(block_kv, Skv)
    assert Sq % bq == 0 and Skv % bkv == 0, (Sq, bq, Skv, bkv)

    qf = q.reshape(B * H, Sq, D)
    kf = k.reshape(B * H, Skv, D)
    vf = v.reshape(B * H, Skv, D)
    grid = (B * H, Sq // bq, Skv // bkv)

    out = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, causal=causal,
                          n_kv=grid[2], bq=bq, bkv=bkv, q_off=Skv - Sq),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bkv, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bkv, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, Sq, D)
