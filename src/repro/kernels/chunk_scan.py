"""SSD chunk-scan Pallas kernel (Mamba-2 / mLSTM style linear-attention).

Computes, per group g (= batch x head) with a scalar-per-position log-decay:

    y[t] = sum_{s<=t} exp(cum[t]-cum[s]) * (C[t].B[s]) * x[s]  (+ carried state)

Grid is (G, S/Q) with the chunk dimension innermost/sequential carrying the
(P, N) state in VMEM scratch.  The chunk size Q is the tunable factor for
recurrent blocks (the IF analogue — DESIGN.md §2).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _chunk_kernel(x_ref, b_ref, c_ref, la_ref, o_ref, state_ref, *, Q: int):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0].astype(jnp.float32)             # (Q, P)
    Bm = b_ref[0].astype(jnp.float32)            # (Q, N)
    Cm = c_ref[0].astype(jnp.float32)            # (Q, N)
    la = la_ref[0].astype(jnp.float32)           # (Q,) via (1, Q) block
    cum = jnp.cumsum(la)                         # inclusive (Q,)

    # intra-chunk
    li = cum[:, None] - cum[None, :]             # decay j..i
    causal = (jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
              >= jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1))
    L = jnp.where(causal, jnp.exp(li), 0.0)
    cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    y = jax.lax.dot_general(cb * L, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # inter-chunk from carried state (P, N)
    y += jnp.exp(cum)[:, None] * jax.lax.dot_general(
        Cm, state_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    # state update
    seg = jnp.exp(cum[-1] - cum)                 # (Q,)
    state_ref[...] = (state_ref[...] * jnp.exp(cum[-1])
                      + jax.lax.dot_general(
                          x, Bm * seg[:, None], (((0,), (0,)), ((), ())),
                          preferred_element_type=jnp.float32))
    o_ref[0] = y.astype(o_ref.dtype)


def chunk_scan_pallas(x: jax.Array, Bm: jax.Array, Cm: jax.Array,
                      la: jax.Array, *, chunk: int,
                      interpret: bool = False) -> jax.Array:
    """x: (G, S, P); Bm/Cm: (G, S, N); la: (G, S) log-decay.  -> y (G, S, P)."""
    G, S, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0
    grid = (G, S // Q)
    return pl.pallas_call(
        functools.partial(_chunk_kernel, Q=Q),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Q, P), lambda g, c: (g, c, 0)),
            pl.BlockSpec((1, Q, N), lambda g, c: (g, c, 0)),
            pl.BlockSpec((1, Q, N), lambda g, c: (g, c, 0)),
            pl.BlockSpec((1, Q), lambda g, c: (g, c)),
        ],
        out_specs=pl.BlockSpec((1, Q, P), lambda g, c: (g, c, 0)),
        out_shape=jax.ShapeDtypeStruct((G, S, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(x, Bm, Cm, la)
