"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    return jnp.dot(x.astype(jnp.float32),
                   w.astype(jnp.float32)).astype(x.dtype)


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool,
                  scale: float) -> jax.Array:
    """q: (B,H,Sq,D); k,v: (B,H,Skv,D) (heads already expanded)."""
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32) * scale
    if causal:
        Sq, Skv = q.shape[2], k.shape[2]
        mask = (jnp.arange(Skv)[None, :] <= jnp.arange(Sq)[:, None]
                + (Skv - Sq))
        s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(v.dtype), v)


def chunk_scan_ref(x: jax.Array, Bm: jax.Array, Cm: jax.Array,
                   la: jax.Array) -> jax.Array:
    """Sequential oracle for the SSD scan.  x (G,S,P); Bm/Cm (G,S,N);
    la (G,S)."""
    G, S, P = x.shape
    N = Bm.shape[-1]

    def step(state, inp):
        xt, bt, ct, lat = inp                    # (G,P),(G,N),(G,N),(G,)
        state = (state * jnp.exp(lat)[:, None, None]
                 + xt[:, :, None] * bt[:, None, :])
        y = jnp.einsum("gpn,gn->gp", state, ct)
        return state, y

    init = jnp.zeros((G, P, N), jnp.float32)
    _, ys = jax.lax.scan(
        step, init,
        (jnp.moveaxis(x, 1, 0).astype(jnp.float32),
         jnp.moveaxis(Bm, 1, 0).astype(jnp.float32),
         jnp.moveaxis(Cm, 1, 0).astype(jnp.float32),
         jnp.moveaxis(la, 1, 0).astype(jnp.float32)))
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype)
