"""``TuningService`` — session-oriented autotuning over one shared
measurement transport.

The facade (:class:`~repro.api.NeuroVectorizer`) is one pipeline, one
oracle, one caller.  The service is the next altitude: a long-lived
object owning one :class:`~repro.core.protocols.MeasureTransport`
(typically a :class:`~repro.measure.pool.WorkerPoolTransport`) that many
concurrent *sessions* share — each session pairing its own agent with its
own oracle view, all feeding the same worker pool and the same persistent
:class:`~repro.measure.db.MeasureDB`.  Duplicate (site, tiles) keys
across sessions coalesce inside the transport, so two sessions tuning
overlapping corpora never measure the same pair twice.

Sessions warm-start from persistent artifacts (PR 5):
``open_session(agent_ckpt=...)`` restores a fitted agent from a
``repro.artifacts`` checkpoint instead of re-paying ``fit``, and a
service-wide ``program_store=`` lets every session answer
previously-tuned site sets by lookup — zero agent inferences, shared
across sessions and across processes (the decision-level analogue of
the shared timing DB).

::

    with TuningService(cfg, transport="pool", workers=4,
                       db_path="measure.jsonl", reps=3) as svc:
        s1 = svc.open_session(agent="ppo", oracle="measured")
        s2 = svc.open_session(agent="brute", oracle="measured")
        s1.fit(corpus, total_steps=5000)
        f1 = s1.tune_async(sites_a)          # overlapping tunes...
        f2 = s2.fit(sites_b).tune_async(sites_b)
        prog_a, prog_b = f1.result(), f2.result()
        print(s1.stats())                    # timings, hit rate, in-flight

Sessions run their async work on the service's thread pool; the actual
measurement parallelism lives below, in the transport's workers.
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Optional, Sequence, Union

from repro.artifacts import (ProgramStore, load_agent, open_program_store,
                             tune_through_store)
from repro.configs.neurovec import DEFAULT, NeuroVecConfig
from repro.core.agents import BruteForceAgent, make_agent
from repro.core.env import CostModelEnv, MeasuredEnv
from repro.core.protocols import Agent, AsyncOracle, Oracle
from repro.core.vectorizer import TileProgram
from repro.ft.monitor import PreemptionHandler
from repro.measure import (TransportMeasureFn, make_transport,
                           resolve_surrogate)
from repro.obs import NULL_TRACER, ObsHandle, resolve_obs
from repro.obs.instrument import (instrument_oracle_stack,
                                  instrument_program_store,
                                  instrument_serving,
                                  instrument_transport)
from repro.serving.server import Server, ServingConfig
from repro.surrogate import SurrogateOracle

_COUNTERS = ("transport_hits_total", "transport_misses_total",
             "transport_coalesced_total", "transport_timed_pairs_total",
             "transport_failed_pairs_total", "transport_retries_total")


class SessionHandle:
    """One tuning session: an agent + an oracle view over the service's
    shared transport.

    ``fit``/``tune`` are the synchronous verbs of the facade;
    :meth:`tune_async` submits the tune to the service's thread pool and
    returns a :class:`~concurrent.futures.Future` of the
    :class:`TileProgram`, so callers overlap tuning across sessions (the
    measurements themselves already overlap inside the transport).
    :meth:`stats` reports per-session wall/throughput counters plus the
    transport's counter *deltas since the session opened*."""

    def __init__(self, service: "TuningService", name: str, agent: Agent,
                 oracle: AsyncOracle,
                 program_store: Optional[ProgramStore] = None):
        self.service = service
        self.name = name
        self.agent = agent
        self.oracle = oracle
        self.program_store = program_store
        self._lock = threading.Lock()
        self._opened = time.perf_counter()
        self._fit_wall = 0.0
        self._tune_wall = 0.0
        self._tunes = 0
        self._sites_tuned = 0
        self._agent_inferences = 0
        self._store_hits = 0
        self._store_misses = 0
        self._outstanding: "set[Future]" = set()
        self._closed = False
        t = oracle.transport
        self._base = dict.fromkeys(_COUNTERS, 0) if t is None else t.stats()
        # -- obs wiring: the session's registry series + root span -----------
        reg = service.registry
        self._tracer = service.tracer
        lbl = {"session": name}
        self._m_fit_s = reg.histogram(
            "session_fit_seconds", "fit() latency per session",
            labelnames=("session",)).labels(**lbl)
        self._m_tune_s = reg.histogram(
            "session_tune_seconds", "tune() latency per session",
            labelnames=("session",)).labels(**lbl)
        self._m_tunes = reg.counter(
            "session_tunes_total", "tunes completed",
            labelnames=("session",)).labels(**lbl)
        self._m_sites = reg.counter(
            "session_sites_tuned_total", "sites tuned",
            labelnames=("session",)).labels(**lbl)
        self._m_infer = reg.counter(
            "session_agent_inferences_total", "sites through agent.act",
            labelnames=("session",)).labels(**lbl)
        self._m_store_hits = reg.counter(
            "session_store_hits_total", "tunes answered by program lookup",
            labelnames=("session",)).labels(**lbl)
        self._m_store_miss = reg.counter(
            "session_store_misses_total", "tunes that ran inference",
            labelnames=("session",)).labels(**lbl)
        self._m_inflight = reg.gauge(
            "session_inflight_tunes", "async tunes outstanding",
            labelnames=("session",)).labels(**lbl)
        self._span = self._tracer.begin("session", detached=True,
                                        session=name, agent=agent.name)

    # -- the facade verbs ----------------------------------------------------
    def fit(self, sites: Sequence, **fit_kwargs) -> "SessionHandle":
        """Train/label the session's agent against its oracle."""
        self._check_open()
        t0 = time.perf_counter()
        with self._tracer.span("fit", parent=self._span,
                               session=self.name, n_sites=len(sites)):
            self.agent.fit(sites, self.oracle, **fit_kwargs)
        dt = time.perf_counter() - t0
        self._m_fit_s.observe(dt)
        with self._lock:
            self._fit_wall += dt
        return self

    def tune(self, sites: Sequence, *,
             slo_ms: Optional[float] = None) -> TileProgram:
        """Greedy inference-mode tiles for ``sites`` (synchronous).
        Under ``TuningService(serving=...)`` the call is admitted to the
        shared :class:`~repro.serving.Server` (``slo_ms`` overrides the
        server's default budget) and may raise its typed errors."""
        self._check_open()
        if self.service.server is not None:
            return self.service.server.submit(self, list(sites),
                                              slo_ms=slo_ms).result()
        return self._tune(list(sites))

    def tune_async(self, sites: Sequence, *,
                   slo_ms: Optional[float] = None) -> "Future[TileProgram]":
        """Submit :meth:`tune` and return a
        :class:`~concurrent.futures.Future` of the :class:`TileProgram`.
        Without serving the tune runs on the service's session pool;
        under ``serving=`` it is admitted to the shared batch server
        (raising :class:`~repro.serving.QueueFull` when shedding)."""
        self._check_open()
        if self.service.server is not None:
            fut = self.service.server.submit(self, list(sites),
                                             slo_ms=slo_ms)
        else:
            if slo_ms is not None:
                raise ValueError("slo_ms needs TuningService(serving=...)")
            fut = self.service._submit(self._tune, list(sites))
        with self._lock:
            self._outstanding.add(fut)
            self._m_inflight.set(len(self._outstanding))
        fut.add_done_callback(self._forget)
        return fut

    def _tune(self, sites: list) -> TileProgram:
        t0 = time.perf_counter()
        with self._tracer.span("tune", parent=self._span,
                               session=self.name, n_sites=len(sites)) as sp:
            prog, hit = tune_through_store(sites, self.agent,
                                           self.oracle.space,
                                           self.oracle, self.program_store)
            sp.set(store_hit=bool(hit))
        self._account_tune(time.perf_counter() - t0, len(sites), hit)
        return prog

    def _account_tune(self, dt: float, n_sites: int, hit: bool) -> None:
        """Book one completed tune (wall time, inference/store counters)
        — shared by the inline path and the serving path, so a request
        fulfilled by the batch server reports identically."""
        self._m_tune_s.observe(dt)
        self._m_tunes.inc()
        self._m_sites.inc(n_sites)
        with self._lock:
            self._tune_wall += dt
            self._tunes += 1
            self._sites_tuned += n_sites
            if self.program_store is not None and n_sites:
                if hit:
                    self._store_hits += 1
                else:
                    self._store_misses += 1
            if not hit:
                self._agent_inferences += n_sites
        if self.program_store is not None and n_sites:
            (self._m_store_hits if hit else self._m_store_miss).inc()
        if not hit:
            self._m_infer.inc(n_sites)

    def _forget(self, fut: Future) -> None:
        with self._lock:
            self._outstanding.discard(fut)
            self._m_inflight.set(len(self._outstanding))

    # -- observability / lifecycle -------------------------------------------
    def health(self) -> str:
        """``ok | degraded | down`` for this session's oracle+transport
        pair (:func:`~repro.core.protocols.resolve_health` semantics)."""
        return self.oracle.health()

    def stats(self) -> dict:
        """Per-session counters + transport deltas since ``open_session``.

        Keys are the unified ``<subsystem>_<noun>_<unit>`` spellings only
        (the PR 8 "one release" legacy aliases — ``wall_s``, ``tunes``,
        transport ``hits``/``misses``/... — are gone as scheduled): the
        same series the service's :class:`~repro.obs.MetricsRegistry`
        exposes, labelled by session name, in
        ``snapshot()``/``render_prom()``.
        """
        t = self.oracle.transport
        now = self._base if t is None else t.stats()
        delta = {k: now.get(k, 0) - self._base.get(k, 0) for k in _COUNTERS}
        n = (delta["transport_hits_total"] + delta["transport_misses_total"]
             + delta["transport_coalesced_total"])
        delta["transport_hit_ratio"] = \
            (delta["transport_hits_total"] / n) if n else 0.0
        delta["transport_inflight_pairs"] = now.get(
            "transport_inflight_pairs", 0)
        with self._lock:
            out = {"session": self.name, "agent": self.agent.name,
                   "health": self.oracle.health(),
                   "session_wall_seconds":
                       time.perf_counter() - self._opened,
                   "session_fit_seconds_total": self._fit_wall,
                   "session_tune_seconds_total": self._tune_wall,
                   "session_tunes_total": self._tunes,
                   "session_sites_tuned_total": self._sites_tuned,
                   "session_agent_inferences_total": self._agent_inferences,
                   "session_store_hits_total": self._store_hits,
                   "session_store_misses_total": self._store_misses,
                   "session_inflight_tunes": len(self._outstanding),
                   "transport": delta}
        return out

    def drain(self) -> None:
        """Block until this session's async tunes (and everything the
        shared transport has in flight) are finished.  Waits without
        re-raising: a serving-path future that failed its SLO carries
        :class:`~repro.serving.DeadlineExceeded` for *its* caller, not
        for whoever closes the session."""
        for f in list(self._outstanding):
            f.exception()
        self.oracle.drain()

    def close(self) -> None:
        """Finish outstanding work and detach.  The shared transport
        stays up — it belongs to the service."""
        if not self._closed:
            self.drain()
            self._closed = True
            self._span.end()

    def _check_open(self) -> None:
        if self._closed:
            raise RuntimeError(f"session {self.name!r} is closed")
        if self.service._closed:
            raise RuntimeError("the TuningService is closed")

    def __enter__(self) -> "SessionHandle":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class TuningService:
    """The service root: one shared transport, many sessions.

    Parameters
    ----------
    cfg:        default :class:`NeuroVecConfig` for sessions that do not
                bring their own.
    transport:  ``"inproc"`` (default) / ``"pool"`` / a pre-built
                :class:`~repro.core.protocols.MeasureTransport` (the
                service then *borrows* it and will not close it).
    workers:    pool size when ``transport="pool"``.
    db_path:    persistent :class:`MeasureDB` path shared by every
                session (repeat runs re-time nothing).
    program_store: a :class:`~repro.artifacts.ProgramStore` (borrowed) or
                a path (opened and owned by the service) shared by every
                session that does not bring its own: finished tile
                programs are served by lookup across sessions *and*
                processes — the warm-start analogue of the shared
                timing DB, one level up.
    max_parallel_tunes: thread-pool width for :meth:`SessionHandle.
                tune_async` (measurement parallelism is the transport's).
    serving:    ``True`` / a :class:`~repro.serving.ServingConfig` / a
                kwargs dict — start a shared :class:`~repro.serving
                .Server`: every session's ``tune``/``tune_async`` is
                admitted to its deadline-aware queue and batched through
                fused device dispatches (``slo_ms=`` per call; typed
                shedding via :class:`~repro.serving.QueueFull`).
    preemption: install a :class:`~repro.ft.monitor.PreemptionHandler`
                whose SIGTERM callback is :meth:`close` — in-flight
                tunes drain, workers stop, and every owned store/DB
                closes cleanly before the process dies (the handler is
                restored on close).
    runner_kwargs: :class:`~repro.measure.runner.MeasureRunner` options
                (``reps=``, ``interpret=``, ``max_dim=``, ...) — per
                worker under the pool transport.  With
                ``transport="socket"``, pass ``hosts=["host:port", ...]``
                here instead (it flows to
                :func:`~repro.measure.make_transport`; runner options
                then live on the ``serve-worker`` hosts).
    """

    def __init__(self, cfg: NeuroVecConfig = DEFAULT,
                 transport: Union[str, object] = "inproc",
                 workers: Optional[int] = None,
                 db_path: Optional[str] = None, seed: int = 0,
                 program_store: Union[str, ProgramStore, None] = None,
                 max_parallel_tunes: int = 4, preemption: bool = False,
                 metrics=None, trace=None,
                 serving: Union[bool, dict, ServingConfig, None] = None,
                 **runner_kwargs):
        self.cfg = cfg
        self.seed = seed
        # obs substrate (PR 8): metrics default to the process-wide
        # registry (False disables), tracing is off unless trace= names a
        # path (owned) or passes a Tracer (borrowed)
        self.registry, self.tracer, self._owns_tracer = \
            resolve_obs(metrics, trace)
        if isinstance(transport, str):
            self.transport = make_transport(transport, db_path=db_path,
                                            workers=workers, **runner_kwargs)
            self._owns_transport = True
        else:
            if db_path is not None or workers is not None or runner_kwargs:
                raise TypeError("a pre-built transport carries its own "
                                "runner/db/workers — drop the extra "
                                "arguments")
            self.transport = transport
            self._owns_transport = False
        self._owned_stores: "list[ProgramStore]" = []
        self.program_store = self._resolve_store(program_store)
        self._executor = ThreadPoolExecutor(max_workers=max_parallel_tunes,
                                            thread_name_prefix="tune")
        self._sessions: "list[SessionHandle]" = []
        self._n_opened = 0
        self._closed = False
        self._preemption = (PreemptionHandler(on_stop=self.close)
                            if preemption else None)
        self._obs = ObsHandle(self.registry)
        self._obs.adopt(instrument_transport(self.transport, self.registry,
                                             self.tracer))
        self._obs.adopt(instrument_program_store(self.program_store,
                                                 self.registry))
        self._m_sessions = self.registry.gauge(
            "service_sessions_open", "sessions currently open")
        self._m_sessions_total = self.registry.counter(
            "service_sessions_total", "sessions opened over the lifetime")
        # serving path (PR 10): sessions' tune/tune_async route through
        # one shared batch server when serving= is set
        if serving is None or serving is False:
            self.server = None
        else:
            sc = (ServingConfig() if serving is True
                  else ServingConfig(**serving) if isinstance(serving, dict)
                  else serving)
            self.server = Server(self, sc)
            self._obs.adopt(instrument_serving(self.server, self.registry))

    def _resolve_store(self, store: Union[str, ProgramStore, None]
                       ) -> Optional[ProgramStore]:
        """A path opens a service-owned store (closed with the service);
        an instance is borrowed.  ``fleet://host:port`` paths open a
        :class:`~repro.fleet.RemoteProgramStore` against the shared
        ``serve-artifacts`` daemon."""
        if isinstance(store, str):
            store = open_program_store(store)
            self._owned_stores.append(store)
        return store

    # -- sessions ------------------------------------------------------------
    def open_session(self, cfg: Optional[NeuroVecConfig] = None,
                     agent: Union[str, Agent] = "ppo",
                     oracle: Union[str, Oracle] = "measured",
                     seed: Optional[int] = None,
                     agent_ckpt: Optional[str] = None,
                     program_store: Union[str, ProgramStore, None] = None,
                     prune_topk: Optional[int] = None,
                     surrogate=None,
                     **agent_kwargs) -> SessionHandle:
        """A new session: ``agent`` (registry name or :class:`Agent`)
        paired with ``oracle`` — ``"measured"`` (reward = the shared
        transport's timings), ``"model"`` (the analytic
        :class:`CostModelEnv`), ``"surrogate"`` (the learned cost model,
        trained from the shared transport's DB unless ``surrogate=``
        supplies a model/checkpoint dir), or a pre-built :class:`Oracle`.

        ``oracle="measured"`` accepts ``prune_topk=N``: the surrogate
        ranks each site's legal grid and only the top-N candidates are
        submitted to the shared transport (trained from the transport's
        DB when ``surrogate`` is ``None``; a DB too cold to train leaves
        pruning inactive for the session).

        ``agent_ckpt`` warm-starts the session: the constructed agent's
        state is restored from a ``repro.artifacts`` checkpoint
        directory (fingerprint-verified), so the session can tune
        without paying ``fit`` again.  ``program_store`` overrides the
        service-wide store for this session (``None`` inherits it)."""
        if self._closed:
            raise RuntimeError("open_session on a closed TuningService")
        cfg = self.cfg if cfg is None else cfg
        seed = self.seed if seed is None else seed
        if oracle == "measured":
            if prune_topk is not None:
                surrogate = resolve_surrogate(
                    surrogate, db=getattr(self.transport, "db", None))
            env: Oracle = MeasuredEnv(
                cfg, measure_fn=TransportMeasureFn(self.transport),
                seed=seed, prune_topk=prune_topk, surrogate=surrogate)
            async_oracle = AsyncOracle(env, self.transport)
        elif oracle == "surrogate":
            if prune_topk is not None:
                raise ValueError("prune_topk applies only to "
                                 "oracle='measured' (a surrogate oracle "
                                 "performs no measurements to prune)")
            model = resolve_surrogate(
                surrogate, db=getattr(self.transport, "db", None))
            if model is None:
                raise ValueError(
                    "oracle='surrogate' needs a trained model: pass "
                    "surrogate= (a SurrogateModel or checkpoint dir) or "
                    "give the service a DB with enough finite records")
            async_oracle = AsyncOracle(SurrogateOracle(cfg, model,
                                                       seed=seed))
        elif oracle == "model":
            async_oracle = AsyncOracle(CostModelEnv(cfg, seed=seed))
        elif isinstance(oracle, str):
            raise ValueError(f"unknown oracle {oracle!r}: expected "
                             f"'model', 'measured', or 'surrogate'")
        else:
            async_oracle = AsyncOracle(oracle)
        a = (make_agent(agent, cfg, seed=seed, **agent_kwargs)
             if isinstance(agent, str) else agent)
        if agent_ckpt is not None:
            load_agent(agent_ckpt, agent=a)
            if isinstance(a, BruteForceAgent):    # brute: re-bind live oracle
                a.oracle = async_oracle.oracle
        store = (self.program_store if program_store is None
                 else self._resolve_store(program_store))
        self._n_opened += 1
        handle = SessionHandle(self, f"session-{self._n_opened}", a,
                               async_oracle, program_store=store)
        self._sessions.append(handle)
        # the session's oracle view (env counters, breaker gauge, a
        # per-session surrogate) feeds the service registry too; the
        # shared transport is already instrumented — first wins
        self._obs.adopt(instrument_oracle_stack(async_oracle.oracle,
                                                self.registry, self.tracer))
        if store is not None and store is not self.program_store:
            self._obs.adopt(instrument_program_store(store, self.registry))
        self._m_sessions_total.inc()
        self._m_sessions.set(sum(not s._closed for s in self._sessions))
        return handle

    def _submit(self, fn, *args) -> Future:
        return self._executor.submit(fn, *args)

    # -- observability / lifecycle -------------------------------------------
    def health(self) -> str:
        """``ok | degraded | down``: the worst of the shared transport's
        health and (under ``serving=``) the batch server's."""
        h = getattr(self.transport, "health", None)
        states = [h() if callable(h) else "ok"]
        if self.server is not None:
            states.append(self.server.health())
        for level in ("down", "degraded"):
            if level in states:
                return level
        return "ok"

    def stats(self) -> dict:
        """Service-level counters + the shared transport's snapshot (and
        the batch server's ``serving_*`` block when serving is on).
        Unified key spellings only — the PR 8 legacy aliases
        (``sessions_open``/``sessions_total``) are gone as scheduled."""
        open_n = sum(not s._closed for s in self._sessions)
        self._m_sessions.set(open_n)
        out = {"service_sessions_open": open_n,
               "service_sessions_total": self._n_opened,
               "owns_transport": self._owns_transport,
               "health": self.health(),
               "transport": self.transport.stats()}
        if self.server is not None:
            out["serving"] = self.server.stats()
        return out

    def close(self) -> None:
        """Drain every session, stop the tune pool, and — when the
        service built them — close the transport and any program stores
        it opened from paths.  Idempotent; also the SIGTERM drain path
        under ``preemption=True``."""
        if self._closed:
            return
        self._closed = True
        if self._preemption is not None:
            self._preemption.restore()
            self._preemption = None
        # the server first: sessions' drain waits on futures it fulfills
        if self.server is not None:
            self.server.close()
        for s in self._sessions:
            s.close()
        self._executor.shutdown(wait=True)
        if self._owns_transport:
            self.transport.close()
        for store in self._owned_stores:
            store.close()
        self._m_sessions.set(0)
        self._obs.close()
        if self._owns_tracer:
            self.tracer.close()

    def __enter__(self) -> "TuningService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def open_session(cfg: NeuroVecConfig = DEFAULT, agent="ppo",
                 oracle="measured", **service_kwargs) -> SessionHandle:
    """One-shot convenience: a private :class:`TuningService` wrapped
    around a single session.  Closing the returned session's *service*
    (``handle.service.close()`` or using it as a context manager) tears
    the private transport down."""
    svc = TuningService(cfg, **service_kwargs)
    return svc.open_session(agent=agent, oracle=oracle)
