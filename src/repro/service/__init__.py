"""``repro.service`` — service-oriented autotuning.

:class:`TuningService` owns one shared
:class:`~repro.core.protocols.MeasureTransport` (in-process or a
subprocess worker pool) and hands out :class:`SessionHandle` sessions —
each an agent + oracle pair with async tuning (``tune_async`` →
``Future[TileProgram]``) and per-session statistics.  See
:mod:`repro.service.service` for the full picture.
"""
from __future__ import annotations

from repro.service.service import SessionHandle, TuningService, open_session

__all__ = ["TuningService", "SessionHandle", "open_session"]
