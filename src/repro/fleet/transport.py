"""``SocketTransport`` — ship (site, tiles) measurements to a fleet of
remote ``serve-worker`` hosts over TCP.

The cross-host half of the :class:`~repro.core.protocols.MeasureTransport`
contract: scheduling semantics are identical to
:class:`~repro.measure.pool.WorkerPoolTransport` (non-blocking
``submit``, DB hits resolve instantly, duplicate keys coalesce, failures
fail closed to ``inf`` with attempts-exhausted quarantine), but the
"worker" under each dispatcher thread is a whole remote host speaking
the :mod:`repro.fleet.worker_server` protocol instead of a subprocess
pipe.

Per-host mechanics:

* **handshake** — each connection opens with hello/welcome; the first
  host's ``backend`` fingerprint becomes the fleet's, and any host whose
  fingerprint disagrees is *rejected* permanently (mixed measurement
  conditions would poison the shared DB).  ``welcome.slots`` advertises
  the host's local parallelism; the dispatcher keeps at most that many
  jobs in flight on the connection (pipelined — the host's inner pool
  measures them concurrently).
* **reconnect with backoff** — a lost connection requeues every
  windowed job (each loss costs the jobs one attempt) and reconnects on
  the jittered :func:`~repro.measure.pool.respawn_backoff` schedule; a
  host that refuses ``max_connect_failures`` consecutive connects is
  given up on.  Re-sent jobs never double-time: the server answers
  repeats from its completed-results cache (and the shared DB).
* **health** — ``ok`` with every host connected, ``degraded`` while any
  host is down/backing off/rejected (work continues on the rest),
  ``down`` when closed or no dispatcher survives — at which point
  pending jobs fail closed so ``drain()`` never hangs, and the
  oracle-level circuit breaker (via ``resolve_health``) degrades tuning
  to the analytic model exactly as for a dead local pool.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import asdict
from typing import Optional, Sequence

import numpy as np

from repro.fleet import rpc
from repro.fleet.rpc import PROTO_VERSION, format_address, parse_address
from repro.measure.db import make_key
from repro.measure.pool import _Job, respawn_backoff
from repro.measure.transport import _TransportStats, _resolved


class _BackendMismatch(RuntimeError):
    """A host's measurement fingerprint disagrees with the fleet's."""


class _HostLink:
    """Mutable per-host record (guarded by the transport's lock)."""

    __slots__ = ("index", "address", "name", "state", "failures",
                 "reconnects", "jobs_done", "error")

    def __init__(self, index: int, address):
        self.index = index
        host, port = parse_address(address)
        self.address = (host, port)
        self.name = format_address(host, port)
        self.state = "connecting"   # connecting|backing_off|connected|
        #                             rejected|gone|closed
        self.failures = 0           # consecutive failed connects
        self.reconnects = 0         # connections lost mid-serve
        self.jobs_done = 0
        self.error: Optional[str] = None


class SocketTransport:
    """Remote measurement fleet behind the MeasureTransport contract.

    Parameters
    ----------
    hosts:          ``serve-worker`` addresses (``"host:port"`` strings
                    or ``(host, port)`` pairs) — one dispatcher thread
                    each.
    db:             a :class:`~repro.measure.db.MeasureDB` (or
                    compatible remote store), a path for one —
                    ``fleet://host:port`` names a ``serve-artifacts``
                    service — or ``None``.  The *client* owns the
                    exactly-once DB write discipline, same as the pool.
    max_attempts:   total tries per job before failing closed to ``inf``
                    (a try is consumed each time a connection dies
                    holding the job).
    connect_timeout: seconds per connect+handshake attempt; also how
                    long the constructor waits for the first live host.
    job_timeout:    seconds a host may hold the *oldest* windowed job
                    before the connection is treated as wedged (torn
                    down + jobs requeued; ``None`` = unlimited).
    max_connect_failures: consecutive failed connects before a host is
                    given up on for the transport's lifetime.
    backoff_base / backoff_cap / backoff_seed:
                    the reconnect backoff schedule; each dispatcher
                    jitters from ``backoff_seed + its index``.
    """

    def __init__(self, hosts: Sequence, db=None, max_attempts: int = 3,
                 connect_timeout: float = 60.0,
                 job_timeout: Optional[float] = 900.0,
                 max_connect_failures: int = 5,
                 backoff_base: float = 0.1, backoff_cap: float = 30.0,
                 backoff_seed: int = 0):
        hosts = list(hosts)
        if not hosts:
            raise ValueError("hosts must name at least one serve-worker "
                             "address, e.g. ['127.0.0.1:7761']")
        if max_attempts < 1:
            raise ValueError(f"max_attempts must be >= 1, got {max_attempts}")
        if max_connect_failures < 1:
            raise ValueError(f"max_connect_failures must be >= 1, got "
                             f"{max_connect_failures}")
        if isinstance(db, str):
            from repro.measure.db import open_measure_db
            db = open_measure_db(db)
        self.db = db
        self.max_attempts = max_attempts
        self.connect_timeout = connect_timeout
        self.job_timeout = job_timeout
        self.max_connect_failures = max_connect_failures
        self.backoff_base = backoff_base
        self.backoff_cap = backoff_cap
        self.backoff_seed = backoff_seed
        self._sleep = time.sleep        # seam: fake clock in backoff tests

        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self._pending: "deque[_Job]" = deque()
        self._inflight: dict = {}       # key -> _Job (queued or in a window)
        self._stats = _TransportStats()
        self._closing = False
        self._backend: Optional[str] = None
        self._links = [_HostLink(i, h) for i, h in enumerate(hosts)]
        self._live = len(self._links)   # dispatcher threads still running
        self._ready_hosts = 0           # links currently connected
        self._backing_off = 0
        self._first_error: Optional[BaseException] = None
        self.reconnects = 0             # connections lost mid-serve, total
        self.queue_wait_seconds = 0.0
        self.run_seconds = 0.0
        self.jobs_finished = 0
        self.job_observer = None

        self._threads = [
            threading.Thread(target=self._dispatch, args=(i,),
                             name=f"fleet-h{i}", daemon=True)
            for i in range(len(self._links))]
        for t in self._threads:
            t.start()
        # Unlike the pool (which requires its full worker complement),
        # a fleet starts as soon as ONE host answers: a missing host is
        # the degraded-but-working case, an empty fleet is an error.
        with self._cv:
            ok = self._cv.wait_for(
                lambda: self._ready_hosts > 0 or self._live == 0,
                timeout=connect_timeout)
            dead = self._live == 0 and self._ready_hosts == 0
            err = self._first_error
            if dead or not ok:
                self._closing = True
                self._cv.notify_all()
        if dead:
            raise RuntimeError(
                "fleet failed to start: no serve-worker host reachable"
            ) from err
        if not ok:
            raise TimeoutError(
                f"fleet: no host completed the handshake within "
                f"{connect_timeout}s")

    # -- per-host dispatcher thread ---------------------------------------

    def _dispatch(self, index: int) -> None:
        link = self._links[index]
        try:
            while True:
                with self._cv:
                    if self._closing:
                        return
                try:
                    stream, slots = self._connect(link)
                except _BackendMismatch as e:
                    with self._cv:
                        link.state = "rejected"
                        link.error = str(e)
                        if self._first_error is None:
                            self._first_error = e
                        self._cv.notify_all()
                    return
                except (OSError, EOFError, ValueError, RuntimeError) as e:
                    with self._cv:
                        link.failures += 1
                        link.error = f"{type(e).__name__}: {e}"
                        if self._first_error is None:
                            self._first_error = e
                        give_up = link.failures >= self.max_connect_failures
                        link.state = "gone" if give_up else "backing_off"
                        if not give_up:
                            self._backing_off += 1
                        self._cv.notify_all()
                    if give_up:
                        return
                    try:
                        self._backoff_sleep(respawn_backoff(
                            link.failures, base=self.backoff_base,
                            cap=self.backoff_cap,
                            seed=self.backoff_seed + index))
                    finally:
                        with self._cv:
                            self._backing_off -= 1
                    continue
                with self._cv:
                    link.failures = 0
                    link.error = None
                    link.state = "connected"
                    self._ready_hosts += 1
                    self._cv.notify_all()
                try:
                    clean = self._serve(link, stream, slots)
                finally:
                    with self._cv:
                        self._ready_hosts -= 1
                        if link.state == "connected":
                            link.state = "connecting"
                if clean:
                    try:
                        stream.write({"type": "bye"})
                    except (OSError, ValueError):
                        pass
                    stream.close()
                    return
                stream.close()
        finally:
            with self._cv:
                if link.state not in ("rejected", "gone"):
                    link.state = "closed" if self._closing else "gone"
                self._live -= 1
                if self._live == 0:
                    # no dispatcher survives: fail queued jobs closed so
                    # drain() never hangs (fleet-down, not a bad pair —
                    # nothing is quarantined)
                    while self._pending:
                        self._requeue_or_fail(self._pending.popleft(),
                                              hard=True)
                self._cv.notify_all()

    def _connect(self, link: _HostLink):
        stream = rpc.connect(link.address, timeout=self.connect_timeout)
        try:
            stream.settimeout(self.connect_timeout)
            stream.write({"type": "hello", "role": "measure",
                          "proto": PROTO_VERSION})
            welcome = stream.read()
            if not isinstance(welcome, dict) \
                    or welcome.get("type") != "welcome":
                raise RuntimeError(f"fleet handshake failed: {welcome!r}")
            backend = welcome.get("backend") or "unknown"
            with self._cv:
                if self._backend is None:
                    self._backend = backend     # first host wins
                elif self._backend != backend:
                    raise _BackendMismatch(
                        f"host {link.name} backend {backend!r} != fleet "
                        f"backend {self._backend!r} — mixed measurement "
                        f"conditions would poison the DB")
            slots = max(1, int(welcome.get("slots", 1)))
            return stream, slots
        except BaseException:
            stream.close()
            raise

    def _serve(self, link: _HostLink, stream, slots: int) -> bool:
        """Feed the connection a window of up to ``slots`` jobs, reading
        results as they complete.  ``True`` = clean shutdown; ``False``
        = connection lost (windowed jobs already requeued)."""
        window: "dict[int, _Job]" = {}
        next_id = 0
        while True:
            to_send = []
            with self._cv:
                if self._closing and not self._pending and not window:
                    return True
                while len(window) < slots and self._pending:
                    job = self._pending.popleft()
                    job.queue_wait_s += time.monotonic() - job.t_queued
                    job.t_start = time.monotonic()
                    next_id += 1
                    window[next_id] = job
                    to_send.append((next_id, job))
                if not to_send and not window:
                    self._cv.wait_for(lambda: self._pending or self._closing)
                    continue
            try:
                for jid, job in to_send:
                    stream.write({"type": "job", "id": jid, "key": job.key,
                                  "site": asdict(job.site),
                                  "tiles": job.tiles})
                if not window:
                    continue
                msg = self._read_result(stream, window)
                if msg is None:
                    raise EOFError("host closed the connection")
            except (OSError, EOFError, ValueError) as e:
                reason = "host wedged (job timeout)" \
                    if isinstance(e, TimeoutError) \
                    else f"connection lost ({type(e).__name__})"
                with self._cv:
                    link.reconnects += 1
                    self.reconnects += 1
                    for job in window.values():
                        self._requeue_or_fail(job, reason=reason)
                    self._cv.notify_all()
                return False
            if msg.get("type") != "result":
                continue                # pong / forward-compat frames
            job = window.pop(msg.get("id"), None)
            if job is None:
                continue                # stale id — already requeued
            v = float("inf") if msg.get("v") is None else float(msg["v"])
            with self._cv:
                link.jobs_done += 1
            self._resolve(job, v)

    def _read_result(self, stream, window: dict):
        """One frame, bounded by the oldest windowed job's deadline."""
        if self.job_timeout is not None:
            oldest = min(j.t_start for j in window.values())
            remaining = (oldest + self.job_timeout) - time.monotonic()
            if remaining <= 0:
                raise TimeoutError("host did not answer before the "
                                   "deadline (wedged measurement?)")
            stream.settimeout(remaining)
        else:
            stream.settimeout(None)
        return stream.read()

    def _backoff_sleep(self, delay: float) -> None:
        """Sleep out a reconnect backoff in small slices so ``close()``
        is never stuck behind a long schedule."""
        deadline = time.monotonic() + delay
        while True:
            with self._cv:
                if self._closing:
                    return
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                return
            self._sleep(min(0.1, remaining))

    # -- shared job accounting (mirrors WorkerPoolTransport) ---------------

    # call with self._lock held
    def _account(self, job: _Job) -> None:
        run_s = 0.0 if job.t_start is None \
            else time.monotonic() - job.t_start
        self.queue_wait_seconds += job.queue_wait_s
        self.run_seconds += run_s
        self.jobs_finished += 1
        obs = self.job_observer
        if obs is not None:
            try:
                obs(job.queue_wait_s, run_s)
            except Exception:
                pass                    # telemetry must never fail a job

    # call with self._lock held
    def _requeue_or_fail(self, job: Optional[_Job], hard: bool = False,
                         reason: str = "connection lost") -> None:
        if job is None:
            return
        job.attempts += 1
        if hard or job.attempts >= self.max_attempts:
            if not hard and self.db is not None:
                self.db.quarantine(job.key, job.attempts, reason)
            self._stats.failed_pairs += 1
            self._inflight.pop(job.key, None)
            self._account(job)
            job.future.set_result(float("inf"))
        else:
            self._stats.retries += 1
            job.t_queued = time.monotonic()
            job.t_start = None
            self._pending.append(job)

    def _resolve(self, job: _Job, v: float) -> None:
        with self._cv:
            if self.db is not None:
                self.db.put(job.key, v)
            if np.isfinite(v):
                self._stats.timed_pairs += 1
            else:
                self._stats.failed_pairs += 1
            self._inflight.pop(job.key, None)
            self._account(job)
            job.future.set_result(v)
            self._cv.notify_all()

    # -- MeasureTransport surface ------------------------------------------

    @property
    def backend_key(self) -> str:
        return self._backend or "unknown"

    def submit(self, sites: Sequence, tiles) -> list:
        tiles = np.asarray(tiles, np.int64)
        futs: list = [None] * len(sites)
        with self._cv:
            if self._closing:
                raise RuntimeError("submit on a closed transport")
            backend = self.backend_key
            for i, (s, t) in enumerate(zip(sites, tiles)):
                key = make_key(s.key(), t, backend)
                v = self.db.get(key) if self.db is not None else None
                if v is not None:
                    self._stats.hits += 1
                    futs[i] = _resolved(v)
                elif key in self._inflight:
                    self._stats.coalesced += 1
                    futs[i] = self._inflight[key].future
                elif self._live == 0:
                    # every dispatcher is gone (fleet down, not closed):
                    # nothing will ever service the queue, so fail the
                    # pair closed now instead of hanging drain()
                    self._stats.misses += 1
                    self._stats.failed_pairs += 1
                    futs[i] = _resolved(float("inf"))
                else:
                    job = _Job(key, s, t)
                    self._stats.misses += 1
                    self._inflight[key] = job
                    self._pending.append(job)
                    futs[i] = job.future
            self._cv.notify_all()
        return futs

    def drain(self) -> None:
        with self._cv:
            self._cv.wait_for(lambda: not self._inflight)

    def close(self) -> None:
        if self._closing:
            return
        self.drain()
        with self._cv:
            self._closing = True
            self._cv.notify_all()
        for t in self._threads:
            t.join(timeout=30)
        if self.db is not None:
            self.db.close()

    def health(self) -> str:
        """``ok`` — every host connected; ``degraded`` — at least one
        host down/backing off/rejected (the rest keep measuring);
        ``down`` — closed, or no dispatcher survives."""
        with self._cv:
            return self._health_locked()

    def _health_locked(self) -> str:
        if self._closing or self._live == 0:
            return "down"
        if self._backing_off or self._ready_hosts < len(self._links):
            return "degraded"
        return "ok"

    def host_states(self) -> dict:
        """``{address: state}`` — the per-host view obs labels on."""
        with self._cv:
            return {l.name: l.state for l in self._links}

    def stats(self) -> dict:
        """Transport counters + fleet-specific keys (unified
        ``fleet_<noun>_<unit>`` naming; ``hosts`` is the per-host
        breakdown obs attaches labels from)."""
        with self._cv:
            s = self._stats.snapshot(in_flight=len(self._inflight))
            s["health"] = self._health_locked()
            s["fleet_hosts_count"] = len(self._links)
            s["fleet_hosts_live"] = self._ready_hosts
            s["fleet_reconnects_total"] = self.reconnects
            s["fleet_queue_depth"] = len(self._pending)
            s["fleet_queue_wait_seconds_total"] = self.queue_wait_seconds
            s["fleet_run_seconds_total"] = self.run_seconds
            s["fleet_jobs_finished_total"] = self.jobs_finished
            s["hosts"] = {
                l.name: {"state": l.state, "jobs_done": l.jobs_done,
                         "reconnects": l.reconnects,
                         "connect_failures": l.failures}
                for l in self._links}
        s["fleet_quarantined_total"] = \
            getattr(self.db, "n_quarantined", 0) if self.db is not None else 0
        return s

    def __enter__(self) -> "SocketTransport":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
