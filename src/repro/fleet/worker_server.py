"""``fleet serve-worker`` — a measurement host daemon.

Wraps a *local* transport (``InProcessTransport`` or
``WorkerPoolTransport``) and serves its measurements over TCP with the
:mod:`repro.measure.wire` framing.  The per-connection protocol mirrors
the worker pipe protocol one level up:

    client →  ``{"type": "hello", "role": "measure", "proto": 1}``
    server →  ``{"type": "welcome", "backend": ..., "slots": N, ...}``
    client →  ``{"type": "job", "id": n, "key": k,
                 "site": {...}, "tiles": [a, b, c]}``
    server →  ``{"type": "result", "id": n, "v": seconds | null}``
    client →  ``{"type": "bye"}`` or EOF → connection closes

``welcome.backend`` is the host's measurement-conditions fingerprint —
the client rejects hosts whose fingerprint disagrees with the fleet's.
``welcome.slots`` advertises local parallelism (pool size, or 1 for
in-process); the client keeps at most that many jobs in flight per host,
and results stream back in completion order via future callbacks — no
extra server threads, natural pipelining.

Jobs are idempotent by ``key``: every finished measurement lands in a
bounded completed-results cache, so a job re-sent after a connection
loss (client never saw the result) answers from the cache instead of
re-timing the kernel.  With a :class:`~repro.measure.db.MeasureDB`
attached to the inner transport the DB provides the same guarantee
durably; the cache covers DB-less hosts and the
measured-but-undelivered window.
"""
from __future__ import annotations

import math
import threading
from collections import OrderedDict

import numpy as np

from repro.fleet.rpc import PROTO_VERSION, FrameServer, SocketStream

#: Completed-results cache bound — plenty for any tuning run's key set
#: while keeping a long-lived daemon's footprint flat.
DONE_CACHE_MAX = 65536


def _site(d: dict):
    from repro.models.compute import KernelSite
    return KernelSite(**d)


def _wire_value(v) -> "float | None":
    v = float(v)
    return None if not math.isfinite(v) else v


class MeasureServer(FrameServer):
    """Serve a local :class:`MeasureTransport` to remote fleet clients.

    Borrows ``transport`` (the caller/CLI owns its lifecycle).  One
    server handles any number of client connections; duplicate keys
    across clients coalesce inside the inner transport exactly as they
    would for local callers.
    """

    def __init__(self, transport, host: str = "127.0.0.1", port: int = 0,
                 slots: "int | None" = None):
        super().__init__(host=host, port=port)
        self.transport = transport
        self.slots = int(slots if slots is not None
                         else max(1, getattr(transport, "workers", 1)))
        self._done_lock = threading.Lock()
        self._done: "OrderedDict[str, float]" = OrderedDict()

    # -- idempotency cache ------------------------------------------------

    def _done_get(self, key):
        if not key:
            return None
        with self._done_lock:
            return self._done.get(key)

    def _done_put(self, key, v: float) -> None:
        if not key:
            return
        with self._done_lock:
            self._done[key] = float(v)
            self._done.move_to_end(key)
            while len(self._done) > DONE_CACHE_MAX:
                self._done.popitem(last=False)

    # -- per-connection protocol ------------------------------------------

    def handle(self, stream: SocketStream) -> None:
        hello = stream.read()
        if not isinstance(hello, dict) or hello.get("type") != "hello":
            return
        if hello.get("proto", PROTO_VERSION) != PROTO_VERSION:
            stream.write({"type": "error",
                          "error": f"unsupported proto {hello.get('proto')}"})
            return
        wlock = threading.Lock()
        with wlock:
            stream.write({"type": "welcome", "role": "measure",
                          "proto": PROTO_VERSION,
                          "backend": self.transport.backend_key,
                          "slots": self.slots})
        while True:
            msg = stream.read()
            if msg is None or msg.get("type") == "bye":
                return
            kind = msg.get("type")
            if kind == "job":
                self._handle_job(stream, wlock, msg)
            elif kind == "ping":
                self._send(stream, wlock,
                           {"type": "pong",
                            "health": self.transport.health()})
            # unknown frame types are ignored — forward compatibility

    def _handle_job(self, stream, wlock, msg) -> None:
        jid, key = msg.get("id"), msg.get("key")
        cached = self._done_get(key)
        if cached is not None:
            self._send(stream, wlock,
                       {"type": "result", "id": jid,
                        "v": _wire_value(cached), "cached": True})
            return
        try:
            site = _site(msg["site"])
            tiles = np.asarray([msg["tiles"]])
            [fut] = self.transport.submit([site], tiles)
        except Exception:
            # malformed site / transport closed under us — fail the job
            # closed; the client resolves inf or retries elsewhere
            self._send(stream, wlock,
                       {"type": "result", "id": jid, "v": None})
            return
        fut.add_done_callback(
            lambda f, jid=jid, key=key: self._reply(stream, wlock, jid,
                                                    key, f))

    def _reply(self, stream, wlock, jid, key, fut) -> None:
        v = fut.result()  # transports never raise out of result()
        self._done_put(key, v)
        self._send(stream, wlock,
                   {"type": "result", "id": jid, "v": _wire_value(v)})

    @staticmethod
    def _send(stream, wlock, msg) -> None:
        try:
            with wlock:
                stream.write(msg)
        except (OSError, ValueError):
            pass  # client gone mid-reply; it will reconnect and retry
