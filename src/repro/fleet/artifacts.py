"""``fleet serve-artifacts`` — the shared artifact service, and its
client mirrors.

One daemon fronts a :class:`~repro.measure.db.MeasureDB` and/or a
:class:`~repro.artifacts.store.ProgramStore` over the
:mod:`repro.measure.wire` framing:

* **append** — ``put``/``quarantine`` requests write through to the
  backing store (acked, so a client knows its record is durable);
* **push invalidation** — every connection may ``subscribe`` to a
  store; appends from any client are pushed to all *other* subscribers
  as they land, so a serving fleet sees new tuned programs without
  re-opening anything.  The pull fallback behind the push path is
  :meth:`ProgramStore.refresh` — the server folds in records appended
  by co-located processes before answering every ``sync``;
* **versioned GC** — ``snapshot`` copies the stores into a
  ``version_%06d`` directory with a manifest written last (the
  ``checkpoint.py`` completeness discipline) and keeps the newest
  ``keep_n``, so long-lived stores can be rolled back or shipped.

The client halves — :class:`RemoteMeasureDB` and
:class:`RemoteProgramStore` — present the *local* store interfaces over
a full in-memory mirror (synced at connect, push-updated afterwards):
``get`` never touches the network, ``put`` writes through.  Because
they duck-type the local classes, a ``fleet://host:port`` string
anywhere a ``db_path=``/``program_store=`` path is accepted turns that
caller into a fleet client with zero code changes (see
``open_measure_db`` / ``open_program_store``).

Degradation: a lost artifact connection never fails a measurement or a
tune — reads keep serving the mirror, writes land locally and count
``put_failures`` — matching the transports' telemetry-never-fails-a-job
stance.
"""
from __future__ import annotations

import json
import math
import os
import shutil
import threading
import time
from concurrent.futures import Future
from concurrent.futures import TimeoutError as _FutureTimeout
from typing import Iterator, Optional

from repro.fleet import rpc
from repro.fleet.rpc import (FLEET_SCHEME, PROTO_VERSION, FrameServer,
                             SocketStream, format_address, parse_address)
from repro.measure.db import MeasureDB, MeasureRecord
from repro.artifacts.store import ProgramStore
from repro.core.vectorizer import TileProgram


def _wire_value(v) -> "float | None":
    v = float(v)
    return None if not math.isfinite(v) else v


def _from_wire(v) -> float:
    return float("inf") if v is None else float(v)


# -- versioned GC (the checkpoint.py keep-N discipline) ---------------------

def complete_versions(versions_dir: str) -> "list[int]":
    """Sorted version numbers whose directory holds a manifest (written
    last — a version without one is torn and invisible)."""
    try:
        entries = os.listdir(versions_dir)
    except OSError:
        return []
    out = []
    for e in entries:
        if not e.startswith("version_"):
            continue
        try:
            v = int(e.split("_", 1)[1])
        except ValueError:
            continue                    # tmp dirs and strangers
        if os.path.exists(os.path.join(versions_dir, e, "manifest.json")):
            out.append(v)
    return sorted(out)


def write_version(versions_dir: str, sources: dict,
                  keep_n: int = 3) -> int:
    """Copy ``sources`` (``{name_in_version: src_path}``) into the next
    ``version_%06d`` directory — files first, ``manifest.json`` last,
    then an atomic rename from a tmp dir — and GC all but the newest
    ``keep_n`` complete versions.  Returns the new version number."""
    if keep_n < 1:
        raise ValueError(f"keep_n must be >= 1, got {keep_n}")
    os.makedirs(versions_dir, exist_ok=True)
    existing = complete_versions(versions_dir)
    v = (existing[-1] + 1) if existing else 0
    final = os.path.join(versions_dir, f"version_{v:06d}")
    tmp = final + f".tmp-{os.getpid()}"
    shutil.rmtree(tmp, ignore_errors=True)
    os.makedirs(tmp)
    copied = []
    for name, src in sorted(sources.items()):
        if src is not None and os.path.exists(src):
            shutil.copyfile(src, os.path.join(tmp, name))
            copied.append(name)
    manifest = {"version": v, "files": copied, "created": time.time()}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    os.rename(tmp, final)
    for old in complete_versions(versions_dir)[:-keep_n]:
        shutil.rmtree(os.path.join(versions_dir, f"version_{old:06d}"),
                      ignore_errors=True)
    return v


# -- server -----------------------------------------------------------------

def _measure_records(db: MeasureDB) -> "tuple[dict, dict]":
    """Full last-wins state of a MeasureDB's on-disk log, *including*
    failed pairs (``null``) — the sync payload.  Reads the file rather
    than ``db._mem`` so LRU-evicted entries are still served."""
    if db._fh is not None:
        db._fh.flush()
    records: dict = {}
    quarantined: dict = {}
    if not os.path.exists(db.path):
        return records, quarantined
    with open(db.path, "rb") as f:
        for raw in f.read().split(b"\n"):
            line = raw.strip()
            if not line:
                continue
            try:
                rec = json.loads(line.decode("utf-8"))
                key = rec["k"]
                val = None if rec["v"] is None else float(rec["v"])
            except (ValueError, KeyError, TypeError):
                continue
            records[key] = val
            if rec.get("kind") == "quarantine":
                quarantined[key] = {"attempts": int(rec.get("attempts", 0)),
                                    "reason": str(rec.get("reason", ""))}
    return records, quarantined


class _Conn:
    """One subscribed client connection (server side)."""

    def __init__(self, stream: SocketStream):
        self.stream = stream
        self.wlock = threading.Lock()

    def send(self, msg: dict) -> bool:
        try:
            with self.wlock:
                self.stream.write(msg)
            return True
        except (OSError, ValueError):
            return False                # subscriber gone; reaped on close


class ArtifactServer(FrameServer):
    """Serve a MeasureDB and/or ProgramStore to fleet clients.

    ``measure_db`` / ``program_store`` accept instances (borrowed) or
    paths (opened and owned).  ``versions_dir`` enables :meth:`snapshot`
    versioning with keep-``keep_n`` GC; ``snapshot_every`` (appends)
    makes snapshots automatic.
    """

    def __init__(self, measure_db=None, program_store=None,
                 host: str = "127.0.0.1", port: int = 0,
                 versions_dir: Optional[str] = None, keep_n: int = 3,
                 snapshot_every: Optional[int] = None):
        super().__init__(host=host, port=port)
        self._owns_db = isinstance(measure_db, str)
        self._owns_store = isinstance(program_store, str)
        self.measure_db = MeasureDB(measure_db) \
            if self._owns_db else measure_db
        self.program_store = ProgramStore(program_store) \
            if self._owns_store else program_store
        if self.measure_db is None and self.program_store is None:
            raise ValueError("serve-artifacts needs a measure DB and/or a "
                             "program store to front")
        self.versions_dir = versions_dir
        self.keep_n = keep_n
        self.snapshot_every = snapshot_every
        self._state_lock = threading.Lock()
        self._subscribers: "dict[str, set[_Conn]]" = {
            "measure": set(), "program": set()}
        self._conn_by_stream: "dict[SocketStream, _Conn]" = {}
        self._appends_since_snapshot = 0
        self.pushes_sent = 0

    @property
    def stores(self) -> "tuple[str, ...]":
        return tuple(name for name, s in
                     (("measure", self.measure_db),
                      ("program", self.program_store)) if s is not None)

    # -- per-connection protocol ------------------------------------------

    def handle(self, stream: SocketStream) -> None:
        hello = stream.read()
        if not isinstance(hello, dict) or hello.get("type") != "hello":
            return
        conn = _Conn(stream)
        if hello.get("proto", PROTO_VERSION) != PROTO_VERSION:
            conn.send({"type": "error",
                       "error": f"unsupported proto {hello.get('proto')}"})
            return
        with self._state_lock:
            self._conn_by_stream[stream] = conn
        conn.send({"type": "welcome", "role": "artifacts",
                   "proto": PROTO_VERSION, "stores": list(self.stores)})
        while True:
            msg = stream.read()
            if msg is None or msg.get("type") == "bye":
                return
            rid = msg.get("id")
            try:
                reply = self._handle_msg(conn, msg)
            except Exception as e:      # a bad request must not kill the conn
                reply = {"type": "error", "error": f"{type(e).__name__}: {e}"}
            if reply is not None and rid is not None:
                conn.send(dict(reply, re=rid))

    def connection_closed(self, stream: SocketStream) -> None:
        with self._state_lock:
            conn = self._conn_by_stream.pop(stream, None)
            if conn is not None:
                for subs in self._subscribers.values():
                    subs.discard(conn)

    def _store_for(self, msg):
        name = msg.get("store")
        store = {"measure": self.measure_db,
                 "program": self.program_store}.get(name)
        if store is None:
            raise ValueError(f"no such store: {name!r} (serving "
                             f"{list(self.stores)})")
        return name, store

    def _handle_msg(self, conn: _Conn, msg: dict) -> Optional[dict]:
        kind = msg.get("type")
        if kind == "sync":
            name, store = self._store_for(msg)
            if name == "measure":
                records, quarantined = _measure_records(store)
                return {"type": "state", "store": name,
                        "records": records, "quarantined": quarantined}
            store.refresh()             # pull in co-located writers' appends
            return {"type": "state", "store": name,
                    "records": store.records()}
        if kind == "subscribe":
            name, _ = self._store_for(msg)
            with self._state_lock:
                self._subscribers[name].add(conn)
            return {"type": "ok"}
        if kind == "put":
            name, store = self._store_for(msg)
            key = str(msg["key"])
            if name == "measure":
                store.put(key, _from_wire(msg.get("v")))
                push = {"type": "push", "store": name, "key": key,
                        "v": msg.get("v")}
            else:
                tiles = {str(sk): tuple(int(x) for x in tv)
                         for sk, tv in dict(msg["v"]).items()}
                store.put(key, TileProgram(tiles))
                push = {"type": "push", "store": name, "key": key,
                        "v": {sk: list(tv) for sk, tv in tiles.items()}}
            self._push(name, push, origin=conn)
            self._maybe_snapshot()
            return {"type": "ok"}
        if kind == "quarantine":
            if self.measure_db is None:
                raise ValueError("no measure store to quarantine in")
            key = str(msg["key"])
            attempts = int(msg.get("attempts", 0))
            reason = str(msg.get("reason", ""))
            self.measure_db.quarantine(key, attempts, reason)
            self._push("measure",
                       {"type": "push", "store": "measure", "key": key,
                        "v": None, "kind": "quarantine",
                        "attempts": attempts, "reason": reason},
                       origin=conn)
            self._maybe_snapshot()
            return {"type": "ok"}
        if kind == "snapshot":
            v = self.snapshot()
            if v is None:
                raise ValueError("versioning is off (no versions_dir)")
            return {"type": "ok", "version": v,
                    "kept": complete_versions(self.versions_dir)}
        if kind == "ping":
            return {"type": "pong", "stores": list(self.stores)}
        raise ValueError(f"unknown request type {kind!r}")

    def _push(self, store_name: str, push: dict, origin: _Conn) -> None:
        with self._state_lock:
            targets = [c for c in self._subscribers[store_name]
                       if c is not origin]
        for c in targets:
            if c.send(push):
                self.pushes_sent += 1

    # -- versioning --------------------------------------------------------

    def _maybe_snapshot(self) -> None:
        if self.snapshot_every is None or self.versions_dir is None:
            return
        with self._state_lock:
            self._appends_since_snapshot += 1
            due = self._appends_since_snapshot >= self.snapshot_every
            if due:
                self._appends_since_snapshot = 0
        if due:
            self.snapshot()

    def snapshot(self) -> Optional[int]:
        """Version the current store files (keep-``keep_n`` GC); ``None``
        when versioning is off."""
        if self.versions_dir is None:
            return None
        sources = {}
        if self.measure_db is not None:
            if self.measure_db._fh is not None:
                self.measure_db._fh.flush()
            sources["measure.jsonl"] = self.measure_db.path
        if self.program_store is not None:
            with self.program_store._lock:
                if self.program_store._fh is not None:
                    self.program_store._fh.flush()
            sources["programs.jsonl"] = self.program_store.path
        return write_version(self.versions_dir, sources, keep_n=self.keep_n)

    def close(self) -> None:
        super().close()
        if self._owns_db and self.measure_db is not None:
            self.measure_db.close()
        if self._owns_store and self.program_store is not None:
            self.program_store.close()


# -- client plumbing --------------------------------------------------------

class _ArtifactClient:
    """One request/response-correlated connection to serve-artifacts,
    shared by a remote store: a reader thread routes replies (by the
    ``re`` echo of each request ``id``) and fans push frames out to
    handlers."""

    def __init__(self, address, timeout: float = 30.0):
        self.host, self.port = parse_address(address)
        self.timeout = timeout
        self._stream = rpc.connect((self.host, self.port), timeout=timeout)
        self._wlock = threading.Lock()
        self._lock = threading.Lock()
        self._waiting: "dict[int, Future]" = {}
        self._next_id = 0
        self._push_handlers: list = []
        self._closed = False
        self.connected = False
        try:
            self._stream.write({"type": "hello", "role": "artifacts",
                                "proto": PROTO_VERSION})
            welcome = self._stream.read()
        except (OSError, EOFError, ValueError) as e:
            self._stream.close()
            raise ConnectionError(
                f"artifact service handshake failed: {e}") from e
        if not isinstance(welcome, dict) or welcome.get("type") != "welcome":
            self._stream.close()
            raise ConnectionError(
                f"artifact service handshake failed: {welcome!r}")
        self.stores = tuple(welcome.get("stores", ()))
        self.connected = True
        self._stream.settimeout(None)   # the reader thread blocks
        self._reader = threading.Thread(
            target=self._read_loop, daemon=True,
            name=f"fleet-artifacts-{self.port}")
        self._reader.start()

    def add_push_handler(self, handler) -> None:
        self._push_handlers.append(handler)

    def _read_loop(self) -> None:
        try:
            while True:
                msg = self._stream.read()
                if msg is None:
                    raise EOFError("artifact service closed the connection")
                if "re" in msg:
                    with self._lock:
                        fut = self._waiting.pop(msg["re"], None)
                    if fut is not None:
                        fut.set_result(msg)
                elif msg.get("type") == "push":
                    for h in list(self._push_handlers):
                        try:
                            h(msg)
                        except Exception:
                            pass        # a bad handler must not kill reads
        except (OSError, EOFError, ValueError) as e:
            with self._lock:
                self.connected = False
                waiting, self._waiting = self._waiting, {}
            err = ConnectionError(
                f"artifact service connection lost ({type(e).__name__})")
            for fut in waiting.values():
                fut.set_exception(err)

    def request(self, msg: dict) -> dict:
        with self._lock:
            if self._closed or not self.connected:
                raise ConnectionError("artifact service connection is down")
            self._next_id += 1
            rid = self._next_id
            fut: Future = Future()
            self._waiting[rid] = fut
        try:
            with self._wlock:
                self._stream.write(dict(msg, id=rid))
            reply = fut.result(timeout=self.timeout)
        except (OSError, ValueError, _FutureTimeout, TimeoutError) as e:
            with self._lock:
                self._waiting.pop(rid, None)
            raise ConnectionError(
                f"artifact request failed ({type(e).__name__})") from e
        if reply.get("type") == "error":
            raise RuntimeError(f"artifact service error: "
                               f"{reply.get('error')}")
        return reply

    def close(self) -> None:
        with self._lock:
            if self._closed:
                return
            self._closed = True
            self.connected = False
        try:
            with self._wlock:
                self._stream.write({"type": "bye"})
        except (OSError, ValueError):
            pass
        self._stream.close()
        self._reader.join(timeout=5.0)


# -- remote stores ----------------------------------------------------------

class RemoteMeasureDB:
    """A :class:`~repro.measure.db.MeasureDB` view of the fleet's shared
    timing store: full mirror synced at connect, push-updated afterwards.
    ``get`` is local; ``put``/``quarantine`` write through (acked).  A
    lost connection degrades to the mirror (``put_failures`` counts
    writes that only landed locally) — never an exception out of the
    measurement path."""

    def __init__(self, address, timeout: float = 30.0):
        self._c = _ArtifactClient(address, timeout=timeout)
        if "measure" not in self._c.stores:
            self._c.close()
            raise ConnectionError(
                f"artifact service at {address} serves no measure store "
                f"(has: {list(self._c.stores)})")
        self.path = FLEET_SCHEME + format_address(self._c.host, self._c.port)
        self._lock = threading.Lock()
        self.skipped_lines = 0
        self.pushes_received = 0
        self.put_failures = 0
        self._mem: dict = {}
        self._quarantined: dict = {}
        self._c.add_push_handler(self._on_push)
        self._c.request({"type": "subscribe", "store": "measure"})
        self._sync()

    def _sync(self) -> int:
        st = self._c.request({"type": "sync", "store": "measure"})
        with self._lock:
            before = len(self._mem)
            for k, v in st.get("records", {}).items():
                self._mem[k] = _from_wire(v)
            for k, info in st.get("quarantined", {}).items():
                self._quarantined[k] = dict(info)
            return len(self._mem) - before

    def _on_push(self, msg: dict) -> None:
        if msg.get("store") != "measure":
            return
        with self._lock:
            key = str(msg.get("key"))
            self._mem[key] = _from_wire(msg.get("v"))
            if msg.get("kind") == "quarantine":
                self._quarantined[key] = {
                    "attempts": int(msg.get("attempts", 0)),
                    "reason": str(msg.get("reason", ""))}
            self.pushes_received += 1

    def refresh(self) -> int:
        """Pull fallback: full re-sync from the service."""
        return self._sync()

    # -- MeasureDB surface -------------------------------------------------

    def get(self, key: str) -> Optional[float]:
        with self._lock:
            v = self._mem.get(key)
            if v is None and key in self._quarantined:
                return float("inf")
            return v

    def put(self, key: str, val: float) -> None:
        val = float(val)
        with self._lock:
            self._mem[key] = val
        try:
            self._c.request({"type": "put", "store": "measure",
                             "key": key, "v": _wire_value(val)})
        except (ConnectionError, RuntimeError):
            with self._lock:
                self.put_failures += 1

    def quarantine(self, key: str, attempts: int, reason: str) -> None:
        info = {"attempts": int(attempts), "reason": str(reason)}
        with self._lock:
            self._quarantined[key] = info
            self._mem[key] = float("inf")
        try:
            self._c.request({"type": "quarantine", "key": key, **info})
        except (ConnectionError, RuntimeError):
            with self._lock:
                self.put_failures += 1

    def quarantined(self, key: str) -> Optional[dict]:
        with self._lock:
            return self._quarantined.get(key)

    @property
    def n_quarantined(self) -> int:
        with self._lock:
            return len(self._quarantined)

    def iter_records(self) -> Iterator[MeasureRecord]:
        """Resolved measurements from the mirror, shaped exactly like
        :meth:`MeasureDB.iter_records` (quarantined and malformed keys
        skipped) — the surrogate trains off a fleet DB unchanged."""
        with self._lock:
            snapshot = dict(self._mem)
            poisoned = set(self._quarantined)
        for key, val in snapshot.items():
            if key in poisoned:
                continue
            parts = key.split("|")
            if len(parts) != 3:
                continue
            site_key, _, backend = parts
            yield MeasureRecord(key=key, kind=site_key.split(":", 1)[0],
                                value=val, fingerprint=backend)

    def close(self) -> None:
        self._c.close()

    def __len__(self) -> int:
        with self._lock:
            return len(self._mem)

    def __contains__(self, key: str) -> bool:
        with self._lock:
            return key in self._mem


class RemoteProgramStore:
    """A :class:`~repro.artifacts.store.ProgramStore` view of the
    fleet's shared program store — same mirror + write-through + push
    discipline as :class:`RemoteMeasureDB`.  A serving client holding
    one of these sees every newly tuned program arrive *without*
    re-opening anything (``pushes_received`` counts them); ``refresh``
    is the pull fallback, triggering a server-side
    :meth:`ProgramStore.refresh` on the way."""

    def __init__(self, address, timeout: float = 30.0):
        self._c = _ArtifactClient(address, timeout=timeout)
        if "program" not in self._c.stores:
            self._c.close()
            raise ConnectionError(
                f"artifact service at {address} serves no program store "
                f"(has: {list(self._c.stores)})")
        self.path = FLEET_SCHEME + format_address(self._c.host, self._c.port)
        self._lock = threading.Lock()
        self._mem: dict = {}            # key -> {site_key: (tiles...)}
        self.hits = 0
        self.misses = 0
        self.skipped_lines = 0
        self.pushes_received = 0
        self.put_failures = 0
        self._c.add_push_handler(self._on_push)
        self._c.request({"type": "subscribe", "store": "program"})
        self._sync()

    def _sync(self) -> int:
        st = self._c.request({"type": "sync", "store": "program"})
        applied = 0
        with self._lock:
            for k, tiles in st.get("records", {}).items():
                try:
                    self._mem[str(k)] = {
                        str(sk): tuple(int(x) for x in tv)
                        for sk, tv in tiles.items()}
                    applied += 1
                except (TypeError, ValueError, AttributeError):
                    self.skipped_lines += 1
        return applied

    def _on_push(self, msg: dict) -> None:
        if msg.get("store") != "program":
            return
        with self._lock:
            try:
                self._mem[str(msg["key"])] = {
                    str(sk): tuple(int(x) for x in tv)
                    for sk, tv in msg["v"].items()}
            except (KeyError, TypeError, ValueError, AttributeError):
                self.skipped_lines += 1
                return
            self.pushes_received += 1

    def refresh(self) -> int:
        """Pull fallback: re-sync (the server refreshes its store from
        disk first, so co-located writers' appends arrive too)."""
        return self._sync()

    # -- ProgramStore surface ----------------------------------------------

    def get(self, key: str) -> Optional[TileProgram]:
        with self._lock:
            tiles = self._mem.get(key)
            if tiles is None:
                self.misses += 1
                return None
            self.hits += 1
            return TileProgram(dict(tiles))

    def put(self, key: str, program: TileProgram) -> None:
        tiles = {str(sk): tuple(int(x) for x in tv)
                 for sk, tv in program.tiles.items()}
        with self._lock:
            self._mem[key] = tiles
        try:
            self._c.request({"type": "put", "store": "program", "key": key,
                             "v": {sk: list(tv)
                                   for sk, tv in tiles.items()}})
        except (ConnectionError, RuntimeError):
            with self._lock:
                self.put_failures += 1

    def records(self) -> dict:
        with self._lock:
            return {k: {sk: list(tv) for sk, tv in tiles.items()}
                    for k, tiles in self._mem.items()}

    def stats(self) -> dict:
        with self._lock:
            n = self.hits + self.misses
            return {"entries": len(self._mem), "hits": self.hits,
                    "misses": self.misses,
                    "hit_rate": (self.hits / n) if n else 0.0,
                    "skipped_lines": self.skipped_lines,
                    "pushes_received": self.pushes_received}

    def close(self) -> None:
        self._c.close()

    def __len__(self) -> int:
        with self._lock:
            return len(self._mem)

    def __contains__(self, key: str) -> bool:
        with self._lock:
            return key in self._mem

    def __enter__(self) -> "RemoteProgramStore":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
