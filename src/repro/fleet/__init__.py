"""``repro.fleet`` — cross-host measurement + shared artifacts.

The "one tuning service, many machines" subsystem: the
:class:`SocketTransport` ships (site, tiles) batches to remote
``serve-worker`` hosts over TCP (full
:class:`~repro.core.protocols.MeasureTransport` contract — conformance-
and chaos-tested over real localhost sockets in ``tests/test_fleet.py``),
and the ``serve-artifacts`` daemon (:class:`ArtifactServer`) promotes
:class:`~repro.measure.db.MeasureDB` + :class:`~repro.artifacts.store.
ProgramStore` into a shared, push-invalidated, keep-N-versioned artifact
service with :class:`RemoteMeasureDB` / :class:`RemoteProgramStore`
client mirrors.

Nothing upstream imports this package unless asked to: callers opt in
with ``make_transport("socket", hosts=[...])``, facade/service
``transport="socket", hosts=[...]``, ``serve.py --transport socket
--hosts ...``, or a ``fleet://host:port`` store path.  Daemons start
from the CLI::

    python -m repro.fleet serve-worker --port 7761 --transport pool --workers 2
    python -m repro.fleet serve-artifacts --port 7762 \\
        --measure-db measure.jsonl --program-store programs.jsonl
"""
from repro.fleet.artifacts import (ArtifactServer, RemoteMeasureDB,
                                   RemoteProgramStore, complete_versions,
                                   write_version)
from repro.fleet.rpc import FLEET_SCHEME, PROTO_VERSION, parse_address
from repro.fleet.transport import SocketTransport
from repro.fleet.worker_server import MeasureServer

__all__ = [
    "ArtifactServer", "FLEET_SCHEME", "MeasureServer", "PROTO_VERSION",
    "RemoteMeasureDB", "RemoteProgramStore", "SocketTransport",
    "complete_versions", "parse_address", "write_version",
]
