"""Fleet daemons: ``python -m repro.fleet serve-worker|serve-artifacts``.

Each subcommand binds, prints one flushed ``ready`` line with the bound
address (``--port 0`` picks an ephemeral port — parse the line to learn
it), then serves until SIGINT/SIGTERM, draining cleanly.

    # a measurement host: local pool of 2 subprocess workers
    python -m repro.fleet serve-worker --port 7761 \\
        --transport pool --workers 2 --reps 3

    # the shared artifact service, with keep-3 versioned snapshots
    python -m repro.fleet serve-artifacts --port 7762 \\
        --measure-db /data/measure.jsonl \\
        --program-store /data/programs.jsonl \\
        --versions-dir /data/versions --keep 3
"""
import argparse
import signal
import sys
import threading


def _serve(server, what: str) -> int:
    stop = threading.Event()
    for sig in (signal.SIGINT, signal.SIGTERM):
        signal.signal(sig, lambda *_: stop.set())
    server.start()
    print(f"[fleet] {what} ready on {server.address}", flush=True)
    stop.wait()
    print(f"[fleet] {what} on {server.address}: draining", flush=True)
    server.close()
    return 0


def _serve_worker(args) -> int:
    from repro.fleet.worker_server import MeasureServer
    from repro.measure import (InProcessTransport, WorkerPoolTransport,
                               make_transport)

    runner_kwargs = dict(reps=args.reps, warmup=args.warmup)
    if args.max_dim is not None:
        runner_kwargs["max_dim"] = args.max_dim
    if args.max_batch is not None:
        runner_kwargs["max_batch"] = args.max_batch
    if args.factory:
        # test seam, mirroring the pool's: a "module:attr" runner factory
        if args.transport == "pool":
            transport = WorkerPoolTransport(workers=args.workers,
                                            factory=args.factory)
        else:
            mod, _, attr = args.factory.partition(":")
            import importlib
            transport = InProcessTransport(
                getattr(importlib.import_module(mod), attr)())
    else:
        transport = make_transport(
            args.transport,
            workers=args.workers if args.transport == "pool" else None,
            **runner_kwargs)
    server = MeasureServer(transport, host=args.host, port=args.port)
    print(f"[fleet] serve-worker: transport={args.transport} "
          f"slots={server.slots} backend={transport.backend_key}",
          flush=True)
    try:
        return _serve(server, "serve-worker")
    finally:
        transport.close()


def _serve_artifacts(args) -> int:
    from repro.fleet.artifacts import ArtifactServer

    server = ArtifactServer(
        measure_db=args.measure_db, program_store=args.program_store,
        host=args.host, port=args.port, versions_dir=args.versions_dir,
        keep_n=args.keep, snapshot_every=args.snapshot_every)
    print(f"[fleet] serve-artifacts: stores={','.join(server.stores)}"
          + (f" versions={args.versions_dir} keep={args.keep}"
             if args.versions_dir else ""), flush=True)
    return _serve(server, "serve-artifacts")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.fleet",
                                 description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)

    w = sub.add_parser("serve-worker",
                       help="serve local measurements to fleet clients")
    w.add_argument("--host", default="0.0.0.0")
    w.add_argument("--port", type=int, default=7761,
                   help="0 = ephemeral (printed in the ready line)")
    w.add_argument("--transport", choices=("inproc", "pool"),
                   default="pool", help="the local transport to front")
    w.add_argument("--workers", type=int, default=2,
                   help="pool size when --transport pool")
    w.add_argument("--reps", type=int, default=1,
                   help="timing repetitions per (site, tile) pair")
    w.add_argument("--warmup", type=int, default=1)
    w.add_argument("--max-dim", type=int, default=None)
    w.add_argument("--max-batch", type=int, default=None)
    w.add_argument("--factory", default=None,
                   help="module:attr runner factory override (test seam)")

    a = sub.add_parser("serve-artifacts",
                       help="serve a shared MeasureDB/ProgramStore")
    a.add_argument("--host", default="0.0.0.0")
    a.add_argument("--port", type=int, default=7762,
                   help="0 = ephemeral (printed in the ready line)")
    a.add_argument("--measure-db", default=None,
                   help="JSONL timing-store path to front")
    a.add_argument("--program-store", default=None,
                   help="JSONL program-store path to front")
    a.add_argument("--versions-dir", default=None,
                   help="enable keep-N versioned snapshots in this dir")
    a.add_argument("--keep", type=int, default=3,
                   help="complete versions to keep (GC the rest)")
    a.add_argument("--snapshot-every", type=int, default=None,
                   help="auto-snapshot every N appends")

    args = ap.parse_args(argv)
    if args.cmd == "serve-worker":
        if args.workers < 1:
            ap.error(f"--workers must be >= 1, got {args.workers}")
        if args.reps < 1:
            ap.error(f"--reps must be >= 1, got {args.reps}")
        return _serve_worker(args)
    if args.measure_db is None and args.program_store is None:
        ap.error("serve-artifacts needs --measure-db and/or "
                 "--program-store")
    if args.keep < 1:
        ap.error(f"--keep must be >= 1, got {args.keep}")
    return _serve_artifacts(args)


if __name__ == "__main__":
    sys.exit(main())
