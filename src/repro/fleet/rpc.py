"""Shared plumbing for the fleet wire protocol.

Both fleet daemons (``serve-worker``, ``serve-artifacts``) and both
client halves (:class:`~repro.fleet.transport.SocketTransport`, the
remote stores in :mod:`repro.fleet.artifacts`) speak the same
length-prefixed JSON framing as the worker-pool pipe protocol
(:mod:`repro.measure.wire`), over TCP.  This module holds the pieces
they share: address parsing (including the ``fleet://host:port`` URL
scheme that lets store *paths* name remote services), buffered socket
streams, and the threaded accept loop every daemon runs.
"""
from __future__ import annotations

import socket
import struct
import threading

from repro.measure.wire import read_frame, write_frame  # noqa: F401 (re-export)

#: Protocol version carried in every hello/welcome frame.  A server
#: refuses a hello whose ``proto`` it does not speak, so a mixed-version
#: fleet fails loudly at handshake instead of mid-batch.
PROTO_VERSION = 1

#: URL scheme marking a store path as remote ("fleet://host:port").
FLEET_SCHEME = "fleet://"


def parse_address(address) -> "tuple[str, int]":
    """``"host:port"`` / ``"fleet://host:port"`` / ``(host, port)`` →
    ``(host, port)``."""
    if isinstance(address, (tuple, list)):
        host, port = address
        return str(host), int(port)
    addr = str(address)
    if addr.startswith(FLEET_SCHEME):
        addr = addr[len(FLEET_SCHEME):]
    host, sep, port = addr.rpartition(":")
    if not sep or not host:
        raise ValueError(
            f"fleet address {address!r} is not host:port — e.g. "
            f"'127.0.0.1:7761' or 'fleet://tpu-host:7761'")
    return host, int(port)


def format_address(host: str, port: int) -> str:
    return f"{host}:{port}"


class SocketStream:
    """A connected TCP socket with buffered read/write file views.

    Owns the socket: ``close()`` tears down both file objects and the
    socket itself (idempotent, swallows errors — a ruined connection is
    closed the same way as a healthy one).
    """

    def __init__(self, sock: socket.socket):
        self.sock = sock
        # TCP_NODELAY: frames are small request/response units; Nagle
        # buffering only adds latency here.
        try:
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        except OSError:
            pass
        self.rfile = sock.makefile("rb")
        self.wfile = sock.makefile("wb")

    def read(self) -> "dict | None":
        return read_frame(self.rfile)

    def write(self, msg: dict) -> None:
        write_frame(self.wfile, msg)

    def settimeout(self, timeout) -> None:
        self.sock.settimeout(timeout)

    def close(self) -> None:
        # Wake any thread blocked in read() BEFORE touching the file
        # objects: closing a buffered file acquires its internal lock,
        # which a reader parked in recv() holds — a cross-thread close
        # would deadlock on it.  shutdown() returns that recv EOF
        # immediately; only then is closing the files safe.
        try:
            self.sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        self._close_parts()

    def kill(self) -> None:
        """Abort the connection without the FIN handshake (RST to the
        peer where the OS allows) — the hard-failure seam chaos tests
        use to simulate a killed host."""
        try:
            # SHUT_RD wakes a local blocked reader (same deadlock hazard
            # as close()) without sending FIN — the peer must see the
            # RST from the lingering close below, not a clean EOF.
            self.sock.shutdown(socket.SHUT_RD)
        except OSError:
            pass
        try:
            self.sock.setsockopt(socket.SOL_SOCKET, socket.SO_LINGER,
                                 struct.pack("ii", 1, 0))
        except OSError:
            pass
        self._close_parts()

    def _close_parts(self) -> None:
        for part in (self.rfile, self.wfile, self.sock):
            try:
                part.close()
            except OSError:
                pass


def connect(address, timeout=None) -> SocketStream:
    host, port = parse_address(address)
    return SocketStream(socket.create_connection((host, port),
                                                 timeout=timeout))


class FrameServer:
    """Threaded TCP accept loop: one daemon thread per connection.

    Subclasses implement ``handle(stream)`` — called on its own thread
    with a :class:`SocketStream`; the server tracks live streams so
    ``close()`` (and the chaos seam ``drop_connections()``) can tear
    them down.  ``port=0`` binds an ephemeral port; the bound address is
    exposed as ``.address`` either way.
    """

    def __init__(self, host: str = "127.0.0.1", port: int = 0):
        self._listener = socket.create_server((host, port))
        bound = self._listener.getsockname()
        self.host, self.port = bound[0], bound[1]
        self.address = format_address(self.host, self.port)
        self._lock = threading.Lock()
        self._streams: "set[SocketStream]" = set()
        self._threads: "list[threading.Thread]" = []
        self._accept_thread = None
        self._closing = False

    # -- lifecycle --------------------------------------------------------

    def start(self) -> "FrameServer":
        self._accept_thread = threading.Thread(
            target=self._accept_loop, name=f"fleet-accept-{self.port}",
            daemon=True)
        self._accept_thread.start()
        return self

    def serve_forever(self) -> None:
        self._accept_loop()

    def _accept_loop(self) -> None:
        while True:
            try:
                sock, _ = self._listener.accept()
            except OSError:
                return  # listener closed
            stream = SocketStream(sock)
            with self._lock:
                if self._closing:
                    stream.close()
                    return
                self._streams.add(stream)
                t = threading.Thread(target=self._run_handler,
                                     args=(stream,),
                                     name=f"fleet-conn-{self.port}",
                                     daemon=True)
                self._threads.append(t)
            t.start()

    def _run_handler(self, stream: SocketStream) -> None:
        try:
            self.handle(stream)
        except (OSError, EOFError, ValueError):
            pass  # peer vanished or ruined the stream — drop it
        finally:
            stream.close()
            with self._lock:
                self._streams.discard(stream)
            self.connection_closed(stream)

    def handle(self, stream: SocketStream) -> None:  # pragma: no cover
        raise NotImplementedError

    def connection_closed(self, stream: SocketStream) -> None:
        """Hook: a connection's handler has finished (any reason)."""

    def drop_connections(self) -> None:
        """Abort every live client connection (listener stays up) — the
        connection-reset chaos seam: clients must reconnect + retry."""
        with self._lock:
            streams = list(self._streams)
        for s in streams:
            s.kill()

    def close(self) -> None:
        """Stop accepting and tear down every live connection.
        Idempotent."""
        with self._lock:
            if self._closing:
                return
            self._closing = True
            streams = list(self._streams)
        try:
            # closing an fd does not wake a thread already blocked in
            # accept() on it (Linux) — shutdown() does, so the accept
            # thread exits instead of leaking
            self._listener.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        try:
            self._listener.close()
        except OSError:
            pass
        for s in streams:
            s.close()
        for t in list(self._threads):
            t.join(timeout=5.0)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
