"""The two formal contracts behind ``repro.api`` (paper §3.5).

The paper's framework is *end-to-end with interchangeable decision
methods*: one extraction → embedding → decision → injection pipeline, into
which RL, nearest-neighbor search, decision trees, brute force, random
search, or the Polly-style heuristic can be slotted.  These protocols make
that pluggability formal:

* :class:`Agent` — a decision method.  ``fit(sites, oracle)`` trains (or
  labels, or no-ops for search-free methods) against a reward oracle;
  ``act(sites, sample=False)`` maps a batch of kernel sites to ``(n, 3)``
  per-head action indices.  ``sample=False`` must be deterministic (the
  deployment mode, paper §4.2); every returned index must be in range for
  its site's kind (strict-actions compliant — no reliance on clamping).
  A fitted agent is a *deployable artifact* (PR 5): ``state_dict()``
  snapshots everything ``act`` depends on into plain numpy/python data
  and ``load_state(state)`` restores it into a freshly constructed agent
  of the same registry name, such that the loaded agent's
  ``act(sites, sample=False)`` is bitwise-equal to the original's.
  Search-free methods return a versioned empty state.  The on-disk
  format (atomic, fingerprinted) lives in :mod:`repro.artifacts`.

* :class:`Oracle` — a reward source.  The batched surface grown in PR 1
  (``costs_batch`` / ``rewards_batch`` / ``speedups_batch`` / ``cost_grid``
  / ``baseline_costs``) is the canonical interface; the analytic
  :class:`~repro.core.env.CostModelEnv` and the hardware-measuring
  :class:`~repro.core.env.MeasuredEnv` both satisfy it, so agents and the
  :class:`~repro.api.NeuroVectorizer` facade never care which one they are
  talking to.

* :class:`MeasureTransport` — how measurements *execute*.  The Oracle
  protocol is synchronous by design (agents consume arrays); underneath
  it, turning ``(site, tiles)`` pairs into seconds may happen in-process
  (:class:`~repro.measure.transport.InProcessTransport`), across a local
  subprocess pool (:class:`~repro.measure.pool.WorkerPoolTransport`), or —
  the seam this protocol exists for — on remote accelerator hosts.
  ``submit(sites, tiles)`` returns one future per pair, ``drain()`` blocks
  until everything in flight resolved, ``close()`` releases workers; the
  whole object is context-managed.  Implementations own deduplication
  (serve DB hits instantly, coalesce duplicate in-flight keys) and
  fail-closed semantics (a pair that cannot be measured resolves to
  ``inf``, never an exception out of ``result()``).

:class:`AsyncOracle` is the bridge between the last two: it wraps a
synchronous :class:`Oracle` together with the transport feeding it, so
callers that want arrays call the Oracle surface and callers that want
overlap (:class:`~repro.service.TuningService`) submit futures and drain.

``Agent``/``Oracle``/``MeasureTransport`` are
:func:`typing.runtime_checkable`, so ``isinstance(x, Oracle)`` verifies
structural conformance (presence of the members, not signatures — the
shared contract tests in ``tests/test_api.py`` / ``tests/test_transport.py``
check behaviour).
"""
from __future__ import annotations

from typing import Protocol, Sequence, runtime_checkable

import numpy as np

# Schema version of every agent's ``state_dict``.  Bumped when a state
# layout changes incompatibly; ``check_agent_state`` rejects mismatches
# so an old process never mis-reads a newer artifact (or vice versa).
AGENT_STATE_VERSION = 1


def check_agent_state(state: dict, expect_name: str) -> None:
    """Shared validation for ``Agent.load_state`` implementations:
    the state must carry the matching registry name and a supported
    schema version.  Raises ``ValueError`` with a precise message."""
    if not isinstance(state, dict):
        raise ValueError(f"agent state must be a dict, got {type(state)}")
    name = state.get("name")
    if name != expect_name:
        raise ValueError(f"agent state is for {name!r}, cannot load into "
                         f"a {expect_name!r} agent")
    version = state.get("version")
    if version != AGENT_STATE_VERSION:
        raise ValueError(f"agent state version {version!r} is not the "
                         f"supported {AGENT_STATE_VERSION}")


@runtime_checkable
class Agent(Protocol):
    """A vectorization decision method (RL, NNS, dtree, brute, ...)."""

    name: str

    def fit(self, sites: Sequence, oracle: "Oracle", **kwargs) -> "Agent":
        """Train/label against ``oracle``; returns ``self`` for chaining.

        Search-free methods (random, polly, baseline) treat this as a
        no-op that may capture the oracle for later use."""
        ...

    def act(self, sites: Sequence, *, sample: bool = False) -> np.ndarray:
        """``(n, 3)`` integer per-head action indices for ``sites``.

        ``sample=False`` (default, the deployment mode) must be
        deterministic; ``sample=True`` may draw from the method's
        exploration distribution."""
        ...

    def state_dict(self) -> dict:
        """Everything ``act`` depends on, as a nested dict of plain
        python values and numpy arrays, carrying ``name`` and
        ``version`` (:data:`AGENT_STATE_VERSION`).  Must be stable:
        saving twice without intervening training yields identical
        state (the ``repro.artifacts`` fingerprint relies on it)."""
        ...

    def load_state(self, state: dict) -> "Agent":
        """Restore a ``state_dict`` snapshot into this (compatibly
        constructed) agent; returns ``self`` for chaining.  Must
        validate name/version (``check_agent_state``) and leave the
        agent bitwise-equivalent to the one that produced ``state``
        under ``act(sites, sample=False)``."""
        ...


@runtime_checkable
class Oracle(Protocol):
    """A batched reward oracle over (site, action) pairs.

    ``space`` is the shared :class:`~repro.core.env.ActionSpace` and
    ``cfg`` the :class:`~repro.configs.neurovec.NeuroVecConfig` whose
    penalty semantics (``fail_penalty``, ``illegal_slowdown``) the
    methods below honour."""

    cfg: object
    space: object

    def baseline_costs(self, sites: Sequence) -> np.ndarray:
        """(n,) heuristic-baseline runtime per site."""
        ...

    def costs_batch(self, sites: Sequence, actions) -> np.ndarray:
        """(n,) runtime of each site under its chosen action; ``inf`` =
        illegal (the compile-failure analogue)."""
        ...

    def rewards_batch(self, sites: Sequence, actions) -> np.ndarray:
        """(n,) paper eq. 2 rewards with the fail penalty for illegal."""
        ...

    def speedups_batch(self, sites: Sequence, actions) -> np.ndarray:
        """(n,) t_baseline / t_action, clamped for illegal actions."""
        ...

    def cost_grid(self, sites: Sequence) -> np.ndarray:
        """(n, max_n_actions) full action-grid cost tensor (``inf`` pads
        illegal tiles and columns past a kind's action count)."""
        ...

    def tiles_costs(self, sites: Sequence, tiles) -> np.ndarray:
        """(n,) runtime of each site under explicit tile values (which
        need not lie on the action grid; ``inf`` = illegal) — what
        ``program_speedup`` prices saved ``TileProgram`` entries with."""
        ...


@runtime_checkable
class MeasureTransport(Protocol):
    """An asynchronous executor of ``(site, tiles)`` measurements.

    The contract every implementation (in-process, subprocess pool,
    future remote hosts) must honour — exercised for all of them by the
    shared conformance suite in ``tests/test_transport.py``:

    * ``submit`` never blocks on measurement (in-process transports may
      execute eagerly, but the *futures* interface is the contract);
      the returned futures are index-aligned with the submitted pairs.
    * duplicate keys — whether already in flight or repeated within one
      batch — coalesce to a single measurement feeding every future.
    * results stream into the transport's :class:`~repro.measure.db.
      MeasureDB` (when one is attached) exactly once per key; pairs
      already in the DB resolve instantly without re-measuring.
    * a pair that cannot be measured (kernel build/compile/run failure,
      worker death past the retry budget) resolves to ``inf`` —
      fail-closed, never an exception out of ``future.result()``.
    """

    @property
    def backend_key(self) -> str:
        """Measurement-conditions fingerprint (DB cache key component)."""
        ...

    def submit(self, sites: Sequence, tiles) -> Sequence:
        """Enqueue ``(site, tiles)`` pairs; one future per pair, in
        submission order.  Each future's ``result()`` is seconds
        (``inf`` = failed/fail-closed)."""
        ...

    def drain(self) -> None:
        """Block until every in-flight measurement has resolved."""
        ...

    def close(self) -> None:
        """Drain, then release workers/files.  Idempotent."""
        ...

    def stats(self) -> dict:
        """Counters: ``hits`` / ``misses`` / ``coalesced`` /
        ``timed_pairs`` / ``failed_pairs`` / ``retries`` /
        ``in_flight``."""
        ...

    def health(self) -> str:
        """``"ok"`` — full capacity; ``"degraded"`` — still measuring
        but impaired (workers lost, respawn backoff in progress);
        ``"down"`` — closed or unable to make progress.  The signal the
        oracle-level circuit breaker consumes."""
        ...

    def __enter__(self) -> "MeasureTransport":
        ...

    def __exit__(self, *exc) -> None:
        ...


def resolve_health(oracle, transport=None) -> str:
    """Combine oracle-level and transport-level health into one
    ``ok | degraded | down`` verdict.

    The oracle's own state wins (a tripped circuit breaker on
    :class:`~repro.core.env.MeasuredEnv` reports ``degraded`` no matter
    what the transport says — it already switched to the analytic
    model).  A ``down`` transport under an oracle that *can* degrade
    (``can_degrade``) is reported ``degraded``, not ``down``: tuning
    still completes via the cost model.  Objects without a ``health``
    member are treated as ``ok`` (the analytic oracle never fails)."""
    h = getattr(oracle, "health", None)
    env_h = h() if callable(h) else "ok"
    if env_h != "ok":
        return env_h
    if transport is None:
        return "ok"
    h = getattr(transport, "health", None)
    t_h = h() if callable(h) else "ok"
    if t_h == "down" and getattr(oracle, "can_degrade", False):
        return "degraded"
    return t_h


class AsyncOracle:
    """A synchronous :class:`Oracle` and its :class:`MeasureTransport`
    behind one handle — the adapter :class:`~repro.service.TuningService`
    sessions talk to.

    The full Oracle surface delegates to ``oracle`` (so ``isinstance(x,
    Oracle)`` holds and agents train against it unchanged); the async
    surface exposes the transport underneath: :meth:`submit_tiles` returns
    raw futures for callers that overlap measurement with other work, and
    :meth:`drain`/:meth:`close` manage the transport lifecycle.  Closing
    is context-managed and never closes a transport the adapter did not
    receive (``transport=None`` adapts a purely synchronous oracle, e.g.
    the analytic :class:`~repro.core.env.CostModelEnv`)."""

    def __init__(self, oracle: Oracle, transport=None):
        self.oracle = oracle
        self.transport = transport

    # -- Oracle delegation ---------------------------------------------------
    @property
    def cfg(self):
        return self.oracle.cfg

    @property
    def space(self):
        return self.oracle.space

    def baseline_costs(self, sites: Sequence) -> np.ndarray:
        return self.oracle.baseline_costs(sites)

    def costs_batch(self, sites: Sequence, actions) -> np.ndarray:
        return self.oracle.costs_batch(sites, actions)

    def rewards_batch(self, sites: Sequence, actions) -> np.ndarray:
        return self.oracle.rewards_batch(sites, actions)

    def speedups_batch(self, sites: Sequence, actions) -> np.ndarray:
        return self.oracle.speedups_batch(sites, actions)

    def cost_grid(self, sites: Sequence) -> np.ndarray:
        return self.oracle.cost_grid(sites)

    def tiles_costs(self, sites: Sequence, tiles) -> np.ndarray:
        return self.oracle.tiles_costs(sites, tiles)

    # -- async surface -------------------------------------------------------
    def submit_tiles(self, sites: Sequence, tiles) -> Sequence:
        """Futures of raw seconds per explicit ``(site, tiles)`` pair —
        the overlap path (submit, do other work, ``drain()``, collect)."""
        if self.transport is None:
            raise RuntimeError("AsyncOracle has no transport "
                               "(synchronous oracle) — use tiles_costs")
        return self.transport.submit(sites, tiles)

    def drain(self) -> None:
        if self.transport is not None:
            self.transport.drain()

    def close(self) -> None:
        if self.transport is not None:
            self.transport.close()

    def health(self) -> str:
        """``ok | degraded | down`` for this oracle+transport pair
        (see :func:`resolve_health`)."""
        return resolve_health(self.oracle, self.transport)

    def __enter__(self) -> "AsyncOracle":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
