"""The two formal contracts behind ``repro.api`` (paper §3.5).

The paper's framework is *end-to-end with interchangeable decision
methods*: one extraction → embedding → decision → injection pipeline, into
which RL, nearest-neighbor search, decision trees, brute force, random
search, or the Polly-style heuristic can be slotted.  These protocols make
that pluggability formal:

* :class:`Agent` — a decision method.  ``fit(sites, oracle)`` trains (or
  labels, or no-ops for search-free methods) against a reward oracle;
  ``act(sites, sample=False)`` maps a batch of kernel sites to ``(n, 3)``
  per-head action indices.  ``sample=False`` must be deterministic (the
  deployment mode, paper §4.2); every returned index must be in range for
  its site's kind (strict-actions compliant — no reliance on clamping).

* :class:`Oracle` — a reward source.  The batched surface grown in PR 1
  (``costs_batch`` / ``rewards_batch`` / ``speedups_batch`` / ``cost_grid``
  / ``baseline_costs``) is the canonical interface; the analytic
  :class:`~repro.core.env.CostModelEnv` and the hardware-measuring
  :class:`~repro.core.env.MeasuredEnv` both satisfy it, so agents and the
  :class:`~repro.api.NeuroVectorizer` facade never care which one they are
  talking to.

Both are :func:`typing.runtime_checkable`, so ``isinstance(x, Oracle)``
verifies structural conformance (presence of the members, not signatures —
the shared contract test in ``tests/test_api.py`` checks behaviour).
"""
from __future__ import annotations

from typing import Protocol, Sequence, runtime_checkable

import numpy as np


@runtime_checkable
class Agent(Protocol):
    """A vectorization decision method (RL, NNS, dtree, brute, ...)."""

    name: str

    def fit(self, sites: Sequence, oracle: "Oracle", **kwargs) -> "Agent":
        """Train/label against ``oracle``; returns ``self`` for chaining.

        Search-free methods (random, polly, baseline) treat this as a
        no-op that may capture the oracle for later use."""
        ...

    def act(self, sites: Sequence, *, sample: bool = False) -> np.ndarray:
        """``(n, 3)`` integer per-head action indices for ``sites``.

        ``sample=False`` (default, the deployment mode) must be
        deterministic; ``sample=True`` may draw from the method's
        exploration distribution."""
        ...


@runtime_checkable
class Oracle(Protocol):
    """A batched reward oracle over (site, action) pairs.

    ``space`` is the shared :class:`~repro.core.env.ActionSpace` and
    ``cfg`` the :class:`~repro.configs.neurovec.NeuroVecConfig` whose
    penalty semantics (``fail_penalty``, ``illegal_slowdown``) the
    methods below honour."""

    cfg: object
    space: object

    def baseline_costs(self, sites: Sequence) -> np.ndarray:
        """(n,) heuristic-baseline runtime per site."""
        ...

    def costs_batch(self, sites: Sequence, actions) -> np.ndarray:
        """(n,) runtime of each site under its chosen action; ``inf`` =
        illegal (the compile-failure analogue)."""
        ...

    def rewards_batch(self, sites: Sequence, actions) -> np.ndarray:
        """(n,) paper eq. 2 rewards with the fail penalty for illegal."""
        ...

    def speedups_batch(self, sites: Sequence, actions) -> np.ndarray:
        """(n,) t_baseline / t_action, clamped for illegal actions."""
        ...

    def cost_grid(self, sites: Sequence) -> np.ndarray:
        """(n, max_n_actions) full action-grid cost tensor (``inf`` pads
        illegal tiles and columns past a kind's action count)."""
        ...

    def tiles_costs(self, sites: Sequence, tiles) -> np.ndarray:
        """(n,) runtime of each site under explicit tile values (which
        need not lie on the action grid; ``inf`` = illegal) — what
        ``program_speedup`` prices saved ``TileProgram`` entries with."""
        ...
