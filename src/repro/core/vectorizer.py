"""Public NeuroVectorizer API — extract, tune, inject (paper Fig. 3+4).

The trained agent is deployed *inference-only* (paper §4.2): ``tune()``
maps each extracted kernel site to its factor tuple; ``inject()`` installs
the resulting :class:`TileProgram` so every ``pl.pallas_call`` in the model
picks up its tuned BlockSpecs — the analogue of writing
``#pragma clang loop vectorize_width(VF) interleave_count(IF)``.
"""
from __future__ import annotations

import contextlib
import json
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.configs.neurovec import DEFAULT, NeuroVecConfig
from repro.core import costmodel, costmodel_vec
from repro.core.env import ActionSpace, CostModelEnv
from repro.core.extractor import extract_sites
from repro.models import compute
from repro.models.compute import KernelSite


@dataclass
class TileProgram:
    """site key -> tile tuple; the 'pragma file' for a model."""
    tiles: Dict[str, Tuple[int, ...]] = field(default_factory=dict)

    def save(self, path: str):
        with open(path, "w") as f:
            json.dump(self.tiles, f, indent=1)

    @classmethod
    def load(cls, path: str) -> "TileProgram":
        with open(path) as f:
            return cls({k: tuple(v) for k, v in json.load(f).items()})


def tune(sites: List[KernelSite], agent, space: ActionSpace) -> TileProgram:
    """Greedy (inference-mode) factor assignment for every site.

    ``agent`` must satisfy the :class:`repro.core.protocols.Agent`
    protocol (``name`` / ``fit(sites, oracle)`` /
    ``act(sites, sample=False)``) — the PR-2 protocol is mandatory and
    the old ``hasattr`` duck-typing fallback is gone.  Get one from
    ``repro.api.make_agent`` rather than hand-rolling."""
    if not sites:
        return TileProgram()
    actions = np.asarray(agent.act(sites, sample=False))
    prog = TileProgram()
    for s, a in zip(sites, actions):
        prog.tiles[s.key()] = space.tiles(s.kind, a)
    return prog


def baseline_program(sites: List[KernelSite]) -> TileProgram:
    return TileProgram({s.key(): costmodel.baseline_tiles(s) for s in sites})


@contextlib.contextmanager
def inject(program: TileProgram, interpret: bool = False):
    """Run model code with the tuned tiles routed through Pallas kernels."""
    with compute.compute_mode("pallas", tiles=program.tiles,
                              interpret=interpret):
        yield


def tune_step_fn(step_fn, abstract_args, agent,
                 nv: NeuroVecConfig = DEFAULT) -> TileProgram:
    """End-to-end: extract sites from a step function and tune them."""
    sites = extract_sites(step_fn, *abstract_args)
    return tune(sites, agent, ActionSpace(nv))


def program_speedup(program: TileProgram, sites: List[KernelSite],
                    env: Optional[CostModelEnv] = None) -> float:
    """Aggregate modelled speedup of a program over the heuristic baseline.

    Sites missing from the program run at baseline; sites whose tiles are
    illegal are charged ``cfg.illegal_slowdown * t_baseline`` — the same
    constant the environment's ``speedup``/``speedups_batch`` clamp to.
    Pass ``env`` (any Oracle) to reuse its baseline cache / config."""
    if not sites:
        return 1.0
    cfg = env.cfg if env is not None else DEFAULT
    t_base = (np.asarray(env.baseline_costs(sites)) if env is not None
              else costmodel_vec.baseline_costs(sites))
    rows = np.ones((len(sites), 3), np.int64)
    for i, s in enumerate(sites):
        tiles = program.tiles.get(s.key())
        if tiles is None:
            tiles = costmodel.baseline_tiles(s)
        k = min(len(tiles), 3)
        rows[i, :k] = tiles[:k]
    price = getattr(env, "tiles_costs", None) if env is not None else None
    t_new = (np.asarray(price(sites, rows)) if price is not None
             else costmodel_vec.costs_for_tiles(sites, rows))
    # a site whose *baseline* failed to measure (inf under MeasuredEnv) is
    # unscorable — excluded from the aggregate rather than failing open to
    # inf/nan
    ok = np.isfinite(t_base)
    if not ok.any():
        return 1.0
    t_base, t_new = t_base[ok], t_new[ok]
    t_new = np.where(np.isfinite(t_new), t_new,
                     float(cfg.illegal_slowdown) * t_base)
    return float(t_base.sum() / t_new.sum())
