"""Public NeuroVectorizer API — extract, tune, inject (paper Fig. 3+4).

The trained agent is deployed *inference-only* (paper §4.2): ``tune()``
maps each extracted kernel site to its factor tuple; ``inject()`` installs
the resulting :class:`TileProgram` so every ``pl.pallas_call`` in the model
picks up its tuned BlockSpecs — the analogue of writing
``#pragma clang loop vectorize_width(VF) interleave_count(IF)``.
"""
from __future__ import annotations

import contextlib
import json
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.configs.neurovec import DEFAULT, NeuroVecConfig
from repro.core import costmodel
from repro.core.env import ActionSpace, CostModelEnv
from repro.core.extractor import extract_sites
from repro.models import compute
from repro.models.compute import KernelSite


@dataclass
class TileProgram:
    """site key -> tile tuple; the 'pragma file' for a model."""
    tiles: Dict[str, Tuple[int, ...]] = field(default_factory=dict)

    def save(self, path: str):
        with open(path, "w") as f:
            json.dump(self.tiles, f, indent=1)

    @classmethod
    def load(cls, path: str) -> "TileProgram":
        with open(path) as f:
            return cls({k: tuple(v) for k, v in json.load(f).items()})


def tune(sites: List[KernelSite], agent, space: ActionSpace) -> TileProgram:
    """Greedy (inference-mode) factor assignment for every site."""
    if not sites:
        return TileProgram()
    actions = agent.act(sites, sample=False) if hasattr(
        agent, "act") else agent(sites)
    prog = TileProgram()
    for s, a in zip(sites, actions):
        prog.tiles[s.key()] = space.tiles(s.kind, a)
    return prog


def baseline_program(sites: List[KernelSite]) -> TileProgram:
    return TileProgram({s.key(): costmodel.baseline_tiles(s) for s in sites})


@contextlib.contextmanager
def inject(program: TileProgram, interpret: bool = False):
    """Run model code with the tuned tiles routed through Pallas kernels."""
    with compute.compute_mode("pallas", tiles=program.tiles,
                              interpret=interpret):
        yield


def tune_step_fn(step_fn, abstract_args, agent,
                 nv: NeuroVecConfig = DEFAULT) -> TileProgram:
    """End-to-end: extract sites from a step function and tune them."""
    sites = extract_sites(step_fn, *abstract_args)
    return tune(sites, agent, ActionSpace(nv))


def program_speedup(program: TileProgram, sites: List[KernelSite],
                    env: Optional[CostModelEnv] = None) -> float:
    """Aggregate modelled speedup of a program over the heuristic baseline."""
    t_base = sum(costmodel.baseline_cost(s) for s in sites)
    t_new = 0.0
    for s in sites:
        tiles = program.tiles.get(s.key())
        c = (costmodel.site_cost(s, tiles) if tiles is not None
             else costmodel.baseline_cost(s))
        t_new += c if c is not None else 10 * costmodel.baseline_cost(s)
    return t_base / t_new
