"""Vectorized (NumPy) cost-model engine — the batched reward oracle.

The scalar functions in :mod:`repro.core.costmodel` price one (site, tile)
pair per interpreted Python call; that made the reward oracle the slowest
thing in the repo (every RL step, every brute-force label, every benchmark
figure walks it point-by-point).  This module evaluates whole
``(n_sites, n_actions)`` grids at once with float64 NumPy, keeping every
expression in the *same evaluation order* as the scalar model so the two
agree to ~1e-9 relative on all legal tiles (property-tested in
``tests/test_costmodel_vec.py``).

Illegal tiles (VMEM overflow — the paper's compile-timeout analogue) are
``np.inf`` entries instead of ``None``, so downstream consumers can mask,
argmin, and broadcast without branching:

* :func:`cost_grid` — the full per-site action-grid cost tensor (brute
  force becomes a single argmin; see ``agents/brute.py``).
* :func:`costs_for_actions` — one chosen action per site (the
  ``CostModelEnv.rewards_batch`` fast path).
* :func:`baseline_costs` — vectorized heuristic-baseline pricing (feeds
  the environment's per-site baseline cache).

All site-metadata packing is O(n_sites) Python; the pricing itself is pure
array math.
"""
from __future__ import annotations

import itertools
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.core import costmodel as cm
from repro.models.compute import KernelSite

ILLEGAL = np.inf


# ---------------------------------------------------------------------------
# vectorized primitives (exact array translations of the scalar model)
# ---------------------------------------------------------------------------


def _ceil(a, b):
    return -(-a // b)


def _mxu_util_vec(bm, bn, bk):
    """Array version of ``costmodel._mxu_util`` (same op order)."""
    u = np.minimum(bm, cm.MXU) / cm.MXU * (np.minimum(bn, cm.LANE) / cm.LANE)
    u = np.where(bm % cm.SUBLANE != 0, u * 0.6, u)
    u = np.where(bn % cm.LANE != 0, u * 0.5, u)
    u = u * (bk / (bk + cm.MXU))
    return np.maximum(u, 1e-3)


def matmul_cost_vec(M, N, K, s, peak, bm, bn, bk) -> np.ndarray:
    """Broadcasted ``matmul_cost``: site params (n, 1) x tiles (1, a)."""
    tm, tn, tk = _ceil(M, bm), _ceil(N, bn), _ceil(K, bk)
    vmem = 2 * (bm * bk + bk * bn) * s + bm * bn * 4 + bm * bn * s
    legal = vmem <= cm.VMEM_BYTES
    # padded extents promoted to float64 up front: the byte/flop/grid
    # products overflow int64 for dims ~2^22+, while float64 stays exact
    # below 2^53 and within ~1e-16 relative beyond (scalar parity holds)
    pm = (tm * bm).astype(np.float64)
    pn = (tn * bn).astype(np.float64)
    pk = (tk * bk).astype(np.float64)
    grid = tm.astype(np.float64) * tn * tk
    flops = 2.0 * pm * pn * pk
    t_compute = flops / (peak * _mxu_util_vec(bm, bn, bk))
    bytes_ = pm * pk * tn * s + pk * pn * tm * s + pm * pn * s
    t_mem = bytes_ / cm.HBM_BW
    cost = (np.maximum(t_compute, t_mem) + grid * cm.GRID_STEP_OVERHEAD
            + cm.FIXED_OVERHEAD)
    return np.where(legal, cost, ILLEGAL)


def attention_cost_vec(Sq, Skv, D, BH, causal, s, peak, bq, bkv) -> np.ndarray:
    tq, tkv = _ceil(Sq, bq), _ceil(Skv, bkv)
    vmem = 2 * (bq * D + 2 * bkv * D) * s + bq * D * 4 + 2 * bq * 4 \
        + bq * bkv * 4
    legal = vmem <= cm.VMEM_BYTES
    pq = (tq * bq).astype(np.float64)       # float64 early: see matmul note
    pkv = (tkv * bkv).astype(np.float64)
    grid = BH.astype(np.float64) * tq * tkv
    frac = np.where(causal, 0.5 * (1 + 1 / np.maximum(tq, 1)), 1.0)
    flops = 4.0 * BH * pq * pkv * D * frac
    vpu_ops = 6.0 * BH * pq * pkv * frac
    t_compute = (flops / (peak * _mxu_util_vec(bq, bkv, D))
                 + vpu_ops / (cm.PEAK_FLOPS_BF16 / 16))
    bytes_ = BH * s * (pq * D + 2 * pkv * D * tq * frac + pq * D)
    t_mem = bytes_ / cm.HBM_BW
    cost = (np.maximum(t_compute, t_mem) + grid * frac * cm.GRID_STEP_OVERHEAD
            + cm.FIXED_OVERHEAD)
    return np.where(legal, cost, ILLEGAL)


def chunk_scan_cost_vec(m, P, N, batch, s, peak, Q) -> np.ndarray:
    tokens = batch * m
    vmem = 2 * Q * (P + 2 * N) * s + P * N * 4 + Q * Q * 4
    legal = vmem <= cm.VMEM_BYTES
    chunks_total = _ceil(tokens, Q)
    per_chunk = 2.0 * Q * Q * N + 2.0 * Q * Q * P + 4.0 * Q * P * N
    flops = per_chunk * chunks_total
    t_compute = flops / (peak * _mxu_util_vec(Q, np.maximum(P, N), Q))
    bytes_ = tokens.astype(np.float64) * (P + 2 * N) * s * 2
    t_mem = bytes_ / cm.HBM_BW
    cost = (np.maximum(t_compute, t_mem)
            + chunks_total * cm.GRID_STEP_OVERHEAD + cm.FIXED_OVERHEAD)
    return np.where(legal, cost, ILLEGAL)


# ---------------------------------------------------------------------------
# site packing
# ---------------------------------------------------------------------------


_DTYPE_META: Dict[str, Tuple[int, float]] = {}


def _dtype_meta(dtype: str) -> Tuple[int, float]:
    m = _DTYPE_META.get(dtype)
    if m is None:
        m = (cm._dtype_bytes(dtype), cm._peak(dtype))
        _DTYPE_META[dtype] = m
    return m


def _site_cols(sites: Sequence[KernelSite], grid: bool = True):
    """Pack site fields into int64/float64 arrays — column vectors (n, 1)
    when broadcasting against an action grid, flat (n,) when evaluating one
    aligned tile per site.  Single Python pass over the sites."""
    rows = [(s.m, s.n, s.k, s.batch, s.causal, *_dtype_meta(s.dtype))
            for s in sites]
    m, n, k, b, causal, sb, peak = zip(*rows) if rows else ((),) * 7
    def col(vals, dt):
        a = np.array(vals, dt)
        return a[:, None] if grid else a
    return {
        "m": col(m, np.int64), "n": col(n, np.int64), "k": col(k, np.int64),
        "batch": col(b, np.int64), "causal": col(causal, bool),
        "s": col(sb, np.int64), "peak": col(peak, np.float64),
    }


def _cost_kind(kind: str, c: Dict[str, np.ndarray],
               tiles: np.ndarray, grid: bool = True) -> np.ndarray:
    """Cost of sites (packed in ``c``) under tile rows of ``tiles``.

    ``tiles``: (a, 3) int64 — columns beyond the kind's arity are ignored.
    With ``grid=True`` every site is priced under every tile row (``c``
    holds (n, 1) columns; result (n_sites, a)).  With ``grid=False`` tile
    row i belongs to site i (``c`` holds flat (n,) columns; result (n,)).
    ``inf`` marks VMEM-illegal entries.
    """
    t = np.asarray(tiles, np.int64)
    if grid:
        t0, t1, t2 = t[None, :, 0], t[None, :, 1], t[None, :, 2]
    else:
        t0, t1, t2 = t[:, 0], t[:, 1], t[:, 2]
    if kind == "matmul":
        return matmul_cost_vec(c["m"], c["n"], c["k"], c["s"], c["peak"],
                               t0, t1, t2)
    if kind == "attention":
        # site semantics: m=Sq, k=Skv, n=D, batch=B*H
        return attention_cost_vec(c["m"], c["k"], c["n"], c["batch"],
                                  c["causal"], c["s"], c["peak"], t0, t1)
    if kind == "chunk_scan":
        return chunk_scan_cost_vec(c["m"], c["n"], c["k"], c["batch"],
                                   c["s"], c["peak"], t0)
    raise ValueError(kind)


def group_by_kind(sites: Sequence[KernelSite]) -> Dict[str, np.ndarray]:
    """kind -> int index array into ``sites`` (order-preserving)."""
    out: Dict[str, List[int]] = {}
    for i, s in enumerate(sites):
        out.setdefault(s.kind, []).append(i)
    return {k: np.asarray(v, np.int64) for k, v in out.items()}


# ---------------------------------------------------------------------------
# action grids (full factor product, itertools.product / row-major order —
# matching the scalar brute-force enumeration so argmin ties break the same)
# ---------------------------------------------------------------------------

_GRID_CACHE: Dict[Tuple, np.ndarray] = {}


def action_tiles_grid(space, kind: str) -> np.ndarray:
    """(n_actions, 3) tile values in flat-action order for ``kind``."""
    choices = space.choices(kind)
    key = (choices, kind)
    g = _GRID_CACHE.get(key)
    if g is None:
        g = np.array(list(itertools.product(*choices)), np.int64)
        _GRID_CACHE[key] = g
    return g


def cost_grid_kind(space, sites: Sequence[KernelSite],
                   kind: str) -> np.ndarray:
    """(n_sites, n_actions(kind)) cost tensor for same-kind ``sites``."""
    return _cost_kind(kind, _site_cols(sites), action_tiles_grid(space, kind))


def cost_grid(space, sites: Sequence[KernelSite]) -> np.ndarray:
    """(n_sites, max_n_actions) cost tensor over the full action grid.

    Rows are per-site; columns follow the flat-action order of that site's
    kind.  Columns past ``space.n_actions(kind)`` are padded with ``inf``
    (never win an argmin), so a row-wise argmin directly yields the
    brute-force flat action.
    """
    groups = group_by_kind(sites)
    if len(groups) == 1:                   # single kind: no padding needed
        (kind, _), = groups.items()
        return cost_grid_kind(space, sites, kind)
    a_max = max((space.n_actions(k) for k in groups), default=0)
    # empty + per-row padding writes (not np.full): every cell is written
    # exactly once, which matters on the memory-bound assembly path
    out = np.empty((len(sites), a_max), np.float64)
    for kind, idx in groups.items():
        na = space.n_actions(kind)
        out[idx, :na] = cost_grid_kind(space, [sites[i] for i in idx], kind)
        if na < a_max:
            out[idx, na:] = ILLEGAL
    return out


# ---------------------------------------------------------------------------
# chosen-action costs (the rewards_batch fast path)
# ---------------------------------------------------------------------------


def _tiles_for_actions_kind(space, kind: str, acts: np.ndarray,
                            idx: np.ndarray) -> np.ndarray:
    """(g, 3) tile values for same-kind action rows (clamped like
    ``ActionSpace.tiles``; validated when strict mode is active)."""
    ch = space.choices(kind)
    if acts.shape[1] < 3:
        # the scalar ActionSpace.tiles indexes action[0..2] for every kind
        # and raises on short actions; mirror that instead of silently
        # pricing missing heads at the tile=1 placeholder
        raise IndexError(
            f"actions need 3 head indices, got shape {acts.shape}")
    out = np.ones((len(acts), 3), np.int64)
    strict = space.strict_enabled(None)
    for d in range(3):
        arr = np.asarray(ch[d], np.int64)
        if strict:
            bad = (acts[:, d] < 0) | (acts[:, d] >= len(arr))
            if bad.any():
                j = int(np.flatnonzero(bad)[0])
                raise IndexError(
                    f"action index {int(acts[j, d])} out of range "
                    f"[0, {len(arr)}) for head {d} of kind {kind!r} "
                    f"(site index {int(idx[j])})")
        out[:, d] = arr[np.minimum(acts[:, d], len(arr) - 1)]
    return out


def costs_for_actions(space, sites: Sequence[KernelSite],
                      actions) -> np.ndarray:
    """(n,) cost of each site under its chosen action (``inf`` = illegal).

    One grouping pass: per kind, action indices are decoded to tile values
    and priced in the same vectorized evaluation."""
    acts = np.asarray(actions, np.int64).reshape(len(sites), -1)
    out = np.empty((len(sites),), np.float64)
    for kind, idx in group_by_kind(sites).items():
        tiles = _tiles_for_actions_kind(space, kind, acts[idx], idx)
        c = _site_cols([sites[i] for i in idx], grid=False)
        out[idx] = _cost_kind(kind, c, tiles, grid=False)
    return out


def tiles_for_actions(space, sites: Sequence[KernelSite],
                      actions) -> np.ndarray:
    """(n, 3) tile values for per-site action indices (unused dims = 1).

    The batched ``ActionSpace.tiles``: clamps by default, raises in strict
    mode.  Used by oracles that price tiles rather than action indices
    (``MeasuredEnv``)."""
    acts = np.asarray(actions, np.int64).reshape(len(sites), -1)
    out = np.ones((len(sites), 3), np.int64)
    for kind, idx in group_by_kind(sites).items():
        out[idx] = _tiles_for_actions_kind(space, kind, acts[idx], idx)
    return out


def costs_for_tiles(sites: Sequence[KernelSite], tiles) -> np.ndarray:
    """(n,) model cost of each site under explicit tile values (``inf`` =
    illegal).  Unlike :func:`costs_for_actions` the tiles need not lie on
    the action grid — this prices arbitrary ``TileProgram`` entries and is
    the legality pre-filter for hardware measurement."""
    t = np.asarray(tiles, np.int64)
    if t.ndim != 2 or t.shape[0] != len(sites):
        raise ValueError(f"tiles must be (n_sites, k), got {t.shape}")
    if t.shape[1] < 3:
        t = np.concatenate(
            [t, np.ones((len(t), 3 - t.shape[1]), np.int64)], 1)
    out = np.empty((len(sites),), np.float64)
    for kind, idx in group_by_kind(sites).items():
        c = _site_cols([sites[i] for i in idx], grid=False)
        out[idx] = _cost_kind(kind, c, t[idx], grid=False)
    return out


# ---------------------------------------------------------------------------
# baselines (the heuristic "LLVM cost model" tiles, vectorized)
# ---------------------------------------------------------------------------


def baseline_tiles_batch(sites: Sequence[KernelSite]) -> np.ndarray:
    """(n, 3) heuristic-baseline tile values (unused dims = 1)."""
    out = np.ones((len(sites), 3), np.int64)
    for kind, idx in group_by_kind(sites).items():
        M = np.array([sites[i].m for i in idx], np.int64)
        N = np.array([sites[i].n for i in idx], np.int64)
        K = np.array([sites[i].k for i in idx], np.int64)
        if kind == "matmul":
            out[idx, 0] = np.minimum(128, _ceil(M, cm.SUBLANE) * cm.SUBLANE)
            out[idx, 1] = np.minimum(128, _ceil(N, cm.LANE) * cm.LANE)
            out[idx, 2] = np.minimum(512, _ceil(K, cm.LANE) * cm.LANE)
        elif kind == "attention":
            out[idx, 0] = np.minimum(128, _ceil(M, cm.SUBLANE) * cm.SUBLANE)
            out[idx, 1] = np.minimum(512, _ceil(K, cm.LANE) * cm.LANE)
        elif kind == "chunk_scan":
            out[idx, 0] = np.minimum(256, M)
    return out


def baseline_costs(sites: Sequence[KernelSite]) -> np.ndarray:
    """(n,) baseline cost per site — vectorized ``costmodel.baseline_cost``."""
    tiles = baseline_tiles_batch(sites)
    out = np.empty((len(sites),), np.float64)
    for kind, idx in group_by_kind(sites).items():
        c = _site_cols([sites[i] for i in idx], grid=False)
        out[idx] = _cost_kind(kind, c, tiles[idx], grid=False)
    assert np.isfinite(out).all(), "baseline illegal for some site"
    return out
