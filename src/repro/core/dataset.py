"""Synthetic kernel-site corpus — the analogue of the paper's >10k generated
loops (§3.2).

Two sources:
 1. *Real* sites extracted from the 10 assigned architectures' step
    functions (the analogue of the LLVM vectorizer test suite the paper
    seeded from).
 2. Generated variants: dim/dtype/flag perturbations of those sites plus
    random shape families — the paper's renamed/re-strided/re-nested loop
    generators (which it found crucial against embedding bias).

Held-out evaluation suites (paper §4):
 * ``twelve_benchmarks()``  — 12 diverse held-out sites        (Fig. 7)
 * ``polybench()``          — matrix-op-dominated workloads    (Fig. 8)
 * ``mibench()``            — workloads where tunable kernels are a minor
   fraction of total time (``fixed_frac``)                     (Fig. 9)
"""
from __future__ import annotations

import math
import random
from dataclasses import dataclass
from typing import List, Optional, Tuple

from repro.models.compute import KernelSite

_DTYPES = ("bfloat16", "float32")
# include SMALL dims: embedded-style workloads (the MiBench transfer set)
# live at the bottom of this range, and the paper's generators stressed
# diverse trip counts for exactly this reason (§3.2)
_MODEL_DIMS = (8, 16, 32, 64, 128, 256, 512, 1024, 1536, 2048, 2560, 3072,
               4096, 4608, 5120, 6912, 8192, 12288, 13696, 14336, 16384,
               18432)
_TOKEN_COUNTS = (8, 32, 128, 256, 512, 1024, 2048, 4096, 8192, 16384,
                 32768, 65536)
_SEQS = (128, 256, 512, 1024, 2048, 4096, 8192, 16384, 32768)
_HEAD_DIMS = (64, 80, 96, 128, 192)


def _mm(site, m, n, k, dtype="bfloat16", fused=0):
    return KernelSite(site=site, kind="matmul", m=m, n=n, k=k,
                      dtype=dtype, fused_ops=fused)


def _attn(site, sq, skv, d, bh, causal=True, dtype="bfloat16"):
    return KernelSite(site=site, kind="attention", m=sq, n=d, k=skv,
                      batch=bh, causal=causal, dtype=dtype)


def _scan(site, q, p, n, batch, dtype="bfloat16"):
    return KernelSite(site=site, kind="chunk_scan", m=q, n=p, k=n,
                      batch=batch, dtype=dtype)


def arch_sites() -> List[KernelSite]:
    """Extract real sites from every assigned architecture (reduced batch
    dims to keep extraction instant; shapes of the weights are exact)."""
    from repro.core.extractor import extract_arch_sites
    out = []
    for arch in ("starcoder2_7b", "qwen3_8b", "stablelm_3b", "chatglm3_6b",
                 "deepseek_v2_236b", "llama4_maverick_400b", "xlstm_1_3b",
                 "phi3_vision_4_2b", "seamless_m4t_medium", "jamba_v0_1_52b"):
        try:
            out.extend(extract_arch_sites(arch))
        except Exception:
            pass
    return out


def generate(n: int, seed: int = 0,
             base: Optional[List[KernelSite]] = None) -> List[KernelSite]:
    """Generate ``n`` synthetic sites (mix of perturbed-real and random)."""
    rng = random.Random(seed)
    base = list(base or [])
    out: List[KernelSite] = []
    while len(out) < n:
        r = rng.random()
        if base and r < 0.4:
            s = rng.choice(base)
            out.append(_perturb(s, rng))
        elif r < 0.75:
            m = rng.choice(_TOKEN_COUNTS)
            nn = rng.choice(_MODEL_DIMS)
            k = rng.choice(_MODEL_DIMS)
            out.append(_mm("gen.mm", m, nn, k, rng.choice(_DTYPES),
                           rng.randint(0, 2)))
        elif r < 0.92:
            sq = rng.choice(_SEQS)
            out.append(_attn("gen.attn", sq, sq, rng.choice(_HEAD_DIMS),
                             rng.choice((8, 16, 32, 64, 128, 256)),
                             causal=rng.random() < 0.7,
                             dtype=rng.choice(_DTYPES)))
        else:
            out.append(_scan("gen.scan", rng.choice((64, 128, 256, 512)),
                             rng.choice((32, 64, 128)),
                             rng.choice((16, 64, 128)),
                             rng.choice((64, 256, 1024, 4096))))
    return out[:n]


def _perturb(s: KernelSite, rng: random.Random) -> KernelSite:
    def jig(v):
        f = rng.choice((1, 1, 2, 2, 4)) / rng.choice((1, 2))
        return max(8, int(v * f))
    kw = dict(site=s.site + ".v", kind=s.kind, m=jig(s.m), n=jig(s.n),
              k=jig(s.k), batch=max(1, jig(s.batch) // 8),
              dtype=rng.choice(_DTYPES), transpose=s.transpose,
              causal=s.causal, fused_ops=rng.randint(0, 3))
    return KernelSite(**kw)


def split(sites: List[KernelSite], test_frac: float, seed: int = 0):
    rng = random.Random(seed)
    s = list(sites)
    rng.shuffle(s)
    n_test = int(len(s) * test_frac)
    return s[n_test:], s[:n_test]


# ---------------------------------------------------------------------------
# held-out evaluation suites (the paper's benchmark sets)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Workload:
    """A benchmark = a bag of tunable sites + a fixed (non-tunable) fraction
    of total baseline runtime, mirroring whole-program measurement."""
    name: str
    sites: Tuple[KernelSite, ...]
    fixed_frac: float = 0.0


def twelve_benchmarks() -> List[Workload]:
    """12 held-out benchmarks with diverse functionality (paper Fig. 7):
    predicates/strides/reductions/type conversions map to causality,
    layouts, fusions and dtypes in our site space."""
    bs = [
        Workload("dot_product", (_mm("b.dot", 8, 128, 4096),)),
        Workload("skinny_gemm", (_mm("b.skinny", 64, 8192, 1024),)),
        Workload("wide_gemm", (_mm("b.wide", 16384, 512, 512),)),
        Workload("square_gemm", (_mm("b.square", 4096, 4096, 4096),)),
        Workload("ffn_fused", (_mm("b.ffn", 8192, 13696, 4096, fused=2),
                               _mm("b.ffn2", 8192, 4096, 13696),)),
        Workload("qkv_proj", (_mm("b.qkv", 16384, 6144, 4096),)),
        Workload("f32_gemm", (_mm("b.f32", 2048, 2048, 2048, "float32"),)),
        Workload("prefill_attn", (_attn("b.pre", 8192, 8192, 128, 64),)),
        Workload("bidir_attn", (_attn("b.bi", 4096, 4096, 64, 32,
                                      causal=False),)),
        Workload("long_attn", (_attn("b.long", 32768, 32768, 128, 16),)),
        Workload("ssd_scan", (_scan("b.ssd", 256, 64, 16, 2048),)),
        Workload("mlstm_scan", (_scan("b.mlstm", 256, 512, 512, 64),)),
    ]
    return bs


def polybench() -> List[Workload]:
    """Matrix-op suite (Fig. 8): gemm chains / decompositions — large loop
    trip counts, kernels dominate runtime."""
    return [
        Workload("2mm", (_mm("p.2mm_a", 4096, 4096, 4096),
                         _mm("p.2mm_b", 4096, 4096, 4096))),
        Workload("3mm", tuple(_mm(f"p.3mm_{i}", 2048, 2048, 2048)
                              for i in range(3))),
        Workload("gemver", (_mm("p.gemver", 8192, 8192, 128),
                            _mm("p.gemver2", 8192, 128, 8192))),
        Workload("syrk", (_mm("p.syrk", 4096, 4096, 1024),)),
        Workload("atax", (_mm("p.atax", 16384, 128, 4096),
                          _mm("p.atax2", 128, 4096, 16384))),
        Workload("correlation", (_mm("p.corr", 2048, 2048, 8192),),
                 fixed_frac=0.1),
    ]


def mibench() -> List[Workload]:
    """Embedded-style suite (Fig. 9): kernels are a minor part of the
    program (high fixed_frac), and some workloads barely vectorize."""
    return [
        Workload("susan", (_mm("m.susan", 1024, 128, 128),),
                 fixed_frac=0.85),
        Workload("jpeg", (_mm("m.jpeg", 512, 512, 64),), fixed_frac=0.80),
        Workload("typeset", (_mm("m.typeset", 256, 128, 256),),
                 fixed_frac=0.92),
        Workload("qsort_partition", (_mm("m.qsort", 2048, 128, 8),),
                 fixed_frac=0.90),
        Workload("fft", (_mm("m.fft", 4096, 128, 128, "float32"),),
                 fixed_frac=0.70),
        Workload("gsm", (_mm("m.gsm", 1024, 256, 64),), fixed_frac=0.88),
    ]
