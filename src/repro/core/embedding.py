"""Code-embedding generator — the code2vec analogue (paper §3.1).

code2vec decomposes a snippet into AST *path contexts* (leaf, path, leaf),
learns token/path embeddings, and attention-pools them into one fixed-length
code vector (340 features).  Our "AST" is the canonicalized kernel site
(DESIGN.md §2): leaves are name-free operand descriptors (dim buckets,
dtype, layout, causality, fusion), the root is the primitive kind, and a
path context is (leaf_i, role-pair path, leaf_j).  The embedder is trained
end-to-end with the RL agent, exactly as in the paper.
"""
from __future__ import annotations

import itertools
import math
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.compute import KernelSite

# ---------------------------------------------------------------------------
# token vocabulary (name-free by construction — paper §3.2 found identifier
# names bias the embedding; our descriptors never contain them)
# ---------------------------------------------------------------------------

_KINDS = ("matmul", "attention", "chunk_scan")
_ROLES = ("m", "n", "k", "batch")
_N_BUCKETS = 26              # log2 buckets for dims up to 2^25
_DTYPES = ("bfloat16", "float32", "float16", "int8")
_LAYOUTS = ("nn", "nt", "tn", "tt")


def _build_vocab():
    toks: List[str] = ["<pad>"]
    for r in _ROLES:
        toks += [f"{r}:b{i}" for i in range(_N_BUCKETS)]
        toks += [f"{r}:align{a}" for a in (0, 1)]   # 128-aligned or not
    toks += [f"dtype:{d}" for d in _DTYPES]
    toks += [f"layout:{l}" for l in _LAYOUTS]
    toks += ["causal:0", "causal:1"]
    toks += [f"fused:{i}" for i in range(4)]
    return {t: i for i, t in enumerate(toks)}


_VOCAB = _build_vocab()
N_TOKENS = len(_VOCAB)

_PATHS = ["<pad>"] + [f"{k}|{a}-{b}" for k in _KINDS
                      for a, b in itertools.combinations_with_replacement(
                          ("dim", "dtype", "layout", "flag"), 2)]
_PATH_IDX = {p: i for i, p in enumerate(_PATHS)}
N_PATHS = len(_PATHS)

MAX_PATHS = 32
EMBED_DIM = 340              # the paper's code-vector width
TOK_DIM = 64


def _bucket(v: int) -> int:
    return min(_N_BUCKETS - 1, int(math.log2(max(1, v))))


def _leaf_tokens(site: KernelSite) -> List[Tuple[str, str]]:
    """(token, category) leaves of the site's mini-AST."""
    leaves = []
    for r, v in (("m", site.m), ("n", site.n), ("k", site.k),
                 ("batch", site.batch)):
        leaves.append((f"{r}:b{_bucket(v)}", "dim"))
        leaves.append((f"{r}:align{int(v % 128 == 0)}", "dim"))
    leaves.append((f"dtype:{site.dtype}", "dtype"))
    leaves.append((f"layout:{site.transpose}", "layout"))
    leaves.append((f"causal:{int(site.causal)}", "flag"))
    leaves.append((f"fused:{min(site.fused_ops, 3)}", "flag"))
    return leaves


# featurization is a pure function of the site, and training resamples the
# same corpus sites every batch — memoize (read-only arrays; bounded)
_FEAT_CACHE: dict = {}
_FEAT_CACHE_MAX = 65536


def featurize(site: KernelSite,
              cache: bool = True) -> Tuple[np.ndarray, np.ndarray]:
    """-> (contexts (MAX_PATHS, 3) int32, mask (MAX_PATHS,) f32).

    ``cache=False`` bypasses the memo (the legacy/benchmark-reference path
    recomputes features every call, like the original implementation)."""
    key = site.key()
    if cache:
        hit = _FEAT_CACHE.get(key)
        if hit is not None:
            return hit
    leaves = _leaf_tokens(site)
    ctxs = []
    for (ta, ca), (tb, cb) in itertools.combinations(leaves, 2):
        pa, pb = sorted((ca, cb))
        path = f"{site.kind}|{pa}-{pb}"
        ctxs.append((_VOCAB[ta], _PATH_IDX.get(path, 0), _VOCAB[tb]))
    # deterministic subsample to MAX_PATHS (keep coverage of all leaves)
    if len(ctxs) > MAX_PATHS:
        step = len(ctxs) / MAX_PATHS
        ctxs = [ctxs[int(i * step)] for i in range(MAX_PATHS)]
    arr = np.zeros((MAX_PATHS, 3), np.int32)
    mask = np.zeros((MAX_PATHS,), np.float32)
    for i, c in enumerate(ctxs):
        arr[i] = c
        mask[i] = 1.0
    if cache:
        arr.flags.writeable = False
        mask.flags.writeable = False
        if len(_FEAT_CACHE) >= _FEAT_CACHE_MAX:
            _FEAT_CACHE.clear()
        _FEAT_CACHE[key] = (arr, mask)
    return arr, mask


def featurize_batch(sites, cache: bool = True
                    ) -> Tuple[np.ndarray, np.ndarray]:
    fs = [featurize(s, cache=cache) for s in sites]
    return (np.stack([f[0] for f in fs]), np.stack([f[1] for f in fs]))


# ---------------------------------------------------------------------------
# the embedding network (learned; trained jointly with the agent)
# ---------------------------------------------------------------------------

def embedder_init(key):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "tok": jax.random.normal(k1, (N_TOKENS, TOK_DIM)) * 0.1,
        "path": jax.random.normal(k2, (N_PATHS, TOK_DIM)) * 0.1,
        "W": jax.random.normal(k3, (3 * TOK_DIM, EMBED_DIM))
        * (1.0 / math.sqrt(3 * TOK_DIM)),
        "att": jax.random.normal(k4, (EMBED_DIM,)) * 0.1,
    }


def embed_sites(params, contexts, mask):
    """contexts: (B, MAX_PATHS, 3) int32; mask (B, MAX_PATHS).
    -> (B, EMBED_DIM) code vectors (code2vec attention pooling).

    The projection is factored through the (tiny) vocab tables:
    ``gather(tok) @ W_slot == gather(tok @ W_slot)``, so each token/path
    row is projected once per call instead of once per path-context —
    identical math to the reference below at a fraction of the FLOPs
    (the projection matmul dominated the whole PPO step)."""
    W = params["W"]
    tok_a = params["tok"] @ W[:TOK_DIM]              # (N_TOKENS, EMBED_DIM)
    pth_w = params["path"] @ W[TOK_DIM:2 * TOK_DIM]  # (N_PATHS, EMBED_DIM)
    tok_b = params["tok"] @ W[2 * TOK_DIM:]
    c = jnp.tanh(tok_a[contexts[..., 0]] + pth_w[contexts[..., 1]]
                 + tok_b[contexts[..., 2]])
    score = c @ params["att"]                        # (B, MAX_PATHS)
    score = jnp.where(mask > 0, score, -1e30)
    alpha = jax.nn.softmax(score, axis=-1)
    return jnp.einsum("bp,bpe->be", alpha, c)


def embed_sites_ref(params, contexts, mask):
    """The original (seed) formulation: per-context concat then project.
    Kept as the benchmark reference path (``PPOAgent(fused=False)``)."""
    t1 = params["tok"][contexts[..., 0]]
    pth = params["path"][contexts[..., 1]]
    t2 = params["tok"][contexts[..., 2]]
    c = jnp.tanh(jnp.concatenate([t1, pth, t2], -1) @ params["W"])
    score = c @ params["att"]                        # (B, MAX_PATHS)
    score = jnp.where(mask > 0, score, -1e30)
    alpha = jax.nn.softmax(score, axis=-1)
    return jnp.einsum("bp,bpe->be", alpha, c)
