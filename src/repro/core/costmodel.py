"""TPU-v5e analytic kernel cost model — the reward source (DESIGN.md §5).

Plays the role of the paper's wall-clock measurement on the i7-8559U: for a
kernel site and a tile choice it returns estimated seconds, or ``None`` when
the tile is illegal (VMEM overflow — the TPU analogue of the paper's
compile-timeout, penalized with −9 by the environment).

Also provides the *heuristic baseline* tile pickers — the stand-in for
LLVM's fixed cost model that the agent is rewarded against.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

from repro.models.compute import KernelSite

# ---- TPU v5e hardware constants (also used by the roofline analysis) ----
PEAK_FLOPS_BF16 = 197e12          # per chip
PEAK_FLOPS_F32 = 49.25e12         # MXU f32 ~ 1/4 of bf16
HBM_BW = 819e9                    # bytes/s
ICI_BW = 50e9                     # bytes/s per link
VMEM_BYTES = 16 * 2 ** 20         # usable vector memory per core
MXU = 128                         # systolic array dim
SUBLANE = 8
LANE = 128
GRID_STEP_OVERHEAD = 3e-7         # s per grid step (pipeline bubble, DMA setup)
FIXED_OVERHEAD = 2e-6             # s per kernel launch


def _dtype_bytes(dtype: str) -> int:
    return {"bfloat16": 2, "float32": 4, "float16": 2, "int8": 1}.get(
        str(dtype), 2)


def _peak(dtype: str) -> float:
    return PEAK_FLOPS_F32 if "32" in str(dtype) else PEAK_FLOPS_BF16


def _ceil(a: int, b: int) -> int:
    return -(-a // b)


def _mxu_util(bm: int, bn: int, bk: int) -> float:
    """Fraction of MXU throughput achieved by a (bm,bn,bk) tile.

    Tiles smaller than the 128x128 systolic array waste rows/columns;
    sublane-misaligned bm wastes loads; small bk pays pipeline fill.
    """
    u = min(bm, MXU) / MXU * (min(bn, LANE) / LANE)
    if bm % SUBLANE:
        u *= 0.6
    if bn % LANE:
        u *= 0.5
    # systolic fill: K-dim pipeline latency ~128 cycles amortized over bk
    u *= bk / (bk + MXU)
    return max(u, 1e-3)


# ===========================================================================
# matmul
# ===========================================================================

def matmul_cost(site: KernelSite,
                tiles: Tuple[int, int, int]) -> Optional[float]:
    M, N, K = site.m, site.n, site.k
    bm, bn, bk = tiles
    s = _dtype_bytes(site.dtype)
    if bm <= 0 or bn <= 0 or bk <= 0:
        return None
    tm, tn, tk = _ceil(M, bm), _ceil(N, bn), _ceil(K, bk)
    # VMEM: in/out tiles double-buffered + f32 accumulator
    vmem = 2 * (bm * bk + bk * bn) * s + bm * bn * 4 + bm * bn * s
    if vmem > VMEM_BYTES:
        return None                                   # "compile failure"
    grid = tm * tn * tk
    # compute (over padded extents — padding waste is real work)
    flops = 2.0 * (tm * bm) * (tn * bn) * (tk * bk)
    t_compute = flops / (_peak(site.dtype) * _mxu_util(bm, bn, bk))
    # memory: A re-streamed tn times, B re-streamed tm times, C written once
    bytes_ = (tm * bm) * (tk * bk) * tn * s \
        + (tk * bk) * (tn * bn) * tm * s \
        + (tm * bm) * (tn * bn) * s
    t_mem = bytes_ / HBM_BW
    return (max(t_compute, t_mem) + grid * GRID_STEP_OVERHEAD
            + FIXED_OVERHEAD)


def baseline_matmul_tiles(M: int, N: int, K: int) -> Tuple[int, int, int]:
    """The heuristic "LLVM cost model": fixed square-ish MXU-aligned tiles.

    Decent defaults, but shape-oblivious — it never adapts bm to skinny
    matmuls, never grows bn for bandwidth-bound wide outputs, and caps bk at
    512 regardless of reuse, which is exactly the gap the agent learns to
    exploit (paper Fig. 1 phenomenology).
    """
    bm = min(128, _ceil(M, SUBLANE) * SUBLANE)
    bn = min(128, _ceil(N, LANE) * LANE)
    bk = min(512, _ceil(K, LANE) * LANE)
    return bm, bn, bk


# ===========================================================================
# attention (flash)
# ===========================================================================

def attention_cost(site: KernelSite,
                   tiles: Tuple[int, int]) -> Optional[float]:
    Sq, Skv, D, BH = site.m, site.k, site.n, site.batch
    bq, bkv = tiles
    s = _dtype_bytes(site.dtype)
    if bq <= 0 or bkv <= 0:
        return None
    tq, tkv = _ceil(Sq, bq), _ceil(Skv, bkv)
    vmem = 2 * (bq * D + 2 * bkv * D) * s + bq * D * 4 + 2 * bq * 4 \
        + bq * bkv * 4
    if vmem > VMEM_BYTES:
        return None
    grid = BH * tq * tkv
    frac = 0.5 * (1 + 1 / max(tq, 1)) if site.causal else 1.0
    flops = 4.0 * BH * (tq * bq) * (tkv * bkv) * D * frac
    # softmax runs on the VPU at ~1/16 MXU rate: exp + max + sum ~ 6 ops/elt
    vpu_ops = 6.0 * BH * (tq * bq) * (tkv * bkv) * frac
    t_compute = (flops / (_peak(site.dtype) * _mxu_util(bq, bkv, D))
                 + vpu_ops / (PEAK_FLOPS_BF16 / 16))
    bytes_ = BH * s * ((tq * bq) * D            # q once
                       + 2 * (tkv * bkv) * D * tq * frac   # k,v per q block
                       + (tq * bq) * D)         # out
    t_mem = bytes_ / HBM_BW
    return (max(t_compute, t_mem) + grid * frac * GRID_STEP_OVERHEAD
            + FIXED_OVERHEAD)


def baseline_attn_tiles(Sq: int, Skv: int) -> Tuple[int, int]:
    """Heuristic: fixed 128/512 blocks (shape-oblivious)."""
    bq = min(128, _ceil(Sq, SUBLANE) * SUBLANE)
    bkv = min(512, _ceil(Skv, LANE) * LANE)
    return bq, bkv


# ===========================================================================
# chunk scan (SSD / mLSTM)
# ===========================================================================

def chunk_scan_cost(site: KernelSite, tiles: Tuple[int]) -> Optional[float]:
    """Site semantics: m = model-configured chunk, n = P (head dim),
    k = N (state dim), batch = #(group x configured-chunk) instances, so
    total scanned tokens = batch * m.  The action re-tiles the scan with
    chunk Q — bigger Q amortizes state I/O but grows the O(Q^2) intra term.
    """
    Q = tiles[0]
    P, N = site.n, site.k
    tokens = site.batch * site.m
    s = _dtype_bytes(site.dtype)
    if Q <= 0:
        return None
    vmem = 2 * Q * (P + 2 * N) * s + P * N * 4 + Q * Q * 4
    if vmem > VMEM_BYTES:
        return None
    chunks_total = _ceil(tokens, Q)
    # FLOPs/chunk: CB^T (2QQN) + (cb*L)X (2QQP) + inter (2QPN) + state (2QPN)
    per_chunk = 2.0 * Q * Q * N + 2.0 * Q * Q * P + 4.0 * Q * P * N
    flops = per_chunk * chunks_total
    t_compute = flops / (_peak(site.dtype) * _mxu_util(Q, max(P, N), Q))
    bytes_ = tokens * (P + 2 * N) * s * 2
    t_mem = bytes_ / HBM_BW
    return (max(t_compute, t_mem) + chunks_total * GRID_STEP_OVERHEAD
            + FIXED_OVERHEAD)


def baseline_chunk(S: int) -> Tuple[int]:
    return (min(256, S),)


# ===========================================================================
# dispatch
# ===========================================================================

def site_cost(site: KernelSite, tiles: Tuple[int, ...]) -> Optional[float]:
    if site.kind == "matmul":
        return matmul_cost(site, tiles[:3])
    if site.kind == "attention":
        return attention_cost(site, tiles[:2])
    if site.kind == "chunk_scan":
        return chunk_scan_cost(site, tiles[:1])
    raise ValueError(site.kind)


def baseline_tiles(site: KernelSite) -> Tuple[int, ...]:
    if site.kind == "matmul":
        return baseline_matmul_tiles(site.m, site.n, site.k)
    if site.kind == "attention":
        return baseline_attn_tiles(site.m, site.k)
    if site.kind == "chunk_scan":
        return baseline_chunk(site.m)
    raise ValueError(site.kind)


def baseline_cost(site: KernelSite) -> float:
    c = site_cost(site, baseline_tiles(site))
    assert c is not None, f"baseline illegal for {site}"
    return c
