"""The contextual-bandit environment (paper §3.3–3.4).

State  = kernel site (embedded by the agent's code-embedding generator).
Action = joint discrete factor indices — (i_bm, i_bn, i_bk) for matmul,
         (i_bq, i_bkv, ·) for attention, (i_chunk, ·, ·) for chunk scans —
         the VF/IF analogue, powers of two only (eq. 3).
Reward = (t_baseline − t_action) / t_baseline                       (eq. 2)
         with the −9 penalty for VMEM-overflow tiles (§3.4's compile
         timeout).  On TPU hardware the cost model is swapped for wall-clock
         measurement of the compiled kernel (``MeasuredEnv`` hook).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional, Sequence, Tuple

import numpy as np

from repro.configs.neurovec import NeuroVecConfig
from repro.core import costmodel
from repro.models.compute import KernelSite


@dataclass(frozen=True)
class ActionSpace:
    """Per-kind factor arrays + unified 3-head indexing with masking."""

    cfg: NeuroVecConfig

    def choices(self, kind: str) -> Tuple[Tuple[int, ...], ...]:
        c = self.cfg
        if kind == "matmul":
            return (c.bm_choices, c.bn_choices, c.bk_choices)
        if kind == "attention":
            return (c.bq_choices, c.bkv_choices, (1,))
        if kind == "chunk_scan":
            return (c.chunk_choices, (1,), (1,))
        raise ValueError(kind)

    @property
    def head_sizes(self) -> Tuple[int, int, int]:
        c = self.cfg
        return (max(len(c.bm_choices), len(c.bq_choices), len(c.chunk_choices)),
                max(len(c.bn_choices), len(c.bkv_choices)),
                len(c.bk_choices))

    def valid_sizes(self, kind: str) -> Tuple[int, int, int]:
        return tuple(len(x) for x in self.choices(kind))

    def tiles(self, kind: str, action: Sequence[int]) -> Tuple[int, ...]:
        ch = self.choices(kind)
        return tuple(ch[d][min(int(action[d]), len(ch[d]) - 1)]
                     for d in range(3))

    def n_actions(self, kind: str) -> int:
        return int(np.prod(self.valid_sizes(kind)))

    def unflatten(self, kind: str, flat: int) -> Tuple[int, int, int]:
        s = self.valid_sizes(kind)
        return (flat // (s[1] * s[2]), (flat // s[2]) % s[1], flat % s[2])


class CostModelEnv:
    """Reward oracle backed by the analytic TPU cost model."""

    def __init__(self, nv_cfg: NeuroVecConfig, seed: int = 0):
        self.cfg = nv_cfg
        self.space = ActionSpace(nv_cfg)
        self._rng = np.random.default_rng(seed)

    # -- the paper's eq. 2 --
    def reward(self, site: KernelSite, action: Sequence[int]) -> float:
        tiles = self.space.tiles(site.kind, action)
        t = costmodel.site_cost(site, tiles)
        if t is None:
            return float(self.cfg.fail_penalty)
        t_base = costmodel.baseline_cost(site)
        if self.cfg.reward_noise > 0:
            t *= float(np.exp(self._rng.normal(0, self.cfg.reward_noise)))
        return float((t_base - t) / t_base)

    def cost(self, site: KernelSite, action: Sequence[int]) -> Optional[float]:
        return costmodel.site_cost(site, self.space.tiles(site.kind, action))

    def speedup(self, site: KernelSite, action: Sequence[int]) -> float:
        """t_baseline / t_action (clamped to the penalty semantics)."""
        t = self.cost(site, action)
        t_base = costmodel.baseline_cost(site)
        if t is None:
            return 0.1                  # illegal: 10x slower, as the penalty
        return float(t_base / t)

    def rewards_batch(self, sites, actions) -> np.ndarray:
        return np.array([self.reward(s, a) for s, a in zip(sites, actions)],
                        np.float32)
