"""The contextual-bandit environment (paper §3.3–3.4).

State  = kernel site (embedded by the agent's code-embedding generator).
Action = joint discrete factor indices — (i_bm, i_bn, i_bk) for matmul,
         (i_bq, i_bkv, ·) for attention, (i_chunk, ·, ·) for chunk scans —
         the VF/IF analogue, powers of two only (eq. 3).
Reward = (t_baseline − t_action) / t_baseline                       (eq. 2)
         with the −9 penalty for VMEM-overflow tiles (§3.4's compile
         timeout).  On TPU hardware the cost model is swapped for wall-clock
         measurement of the compiled kernel (``MeasuredEnv`` hook).

Perf architecture: baselines are pure functions of the site, so the
environment keeps a per-site baseline-cost cache (keyed by ``site.key()``)
and every batched entry point — :meth:`CostModelEnv.rewards_batch`,
:meth:`costs_batch`, :meth:`cost_grid` — routes through the vectorized
engine in :mod:`repro.core.costmodel_vec` instead of the scalar per-call
model.  Construct with ``vectorized=False`` to get the original scalar
loops (kept as the reference path for parity tests and benchmarks).
"""
from __future__ import annotations

import math
import os
from dataclasses import dataclass
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.configs.neurovec import NeuroVecConfig
from repro.core import costmodel
from repro.core import costmodel_vec
from repro.models.compute import KernelSite

# Global strict-action toggle: when on, out-of-range action indices raise
# instead of being clamped, so head-masking bugs can't hide behind the
# clamp.  Enable per-call (``tiles(..., strict=True)``), per-config
# (``NeuroVecConfig.strict_actions``), process-wide via this switch, or
# with ``REPRO_STRICT_ACTIONS=1`` in the environment.
_STRICT_ACTIONS = os.environ.get("REPRO_STRICT_ACTIONS", "0") == "1"


def set_strict_actions(on: bool) -> None:
    global _STRICT_ACTIONS
    _STRICT_ACTIONS = bool(on)


@dataclass(frozen=True)
class ActionSpace:
    """Per-kind factor arrays + unified 3-head indexing with masking."""

    cfg: NeuroVecConfig

    def choices(self, kind: str) -> Tuple[Tuple[int, ...], ...]:
        c = self.cfg
        if kind == "matmul":
            return (c.bm_choices, c.bn_choices, c.bk_choices)
        if kind == "attention":
            return (c.bq_choices, c.bkv_choices, (1,))
        if kind == "chunk_scan":
            return (c.chunk_choices, (1,), (1,))
        raise ValueError(kind)

    @property
    def head_sizes(self) -> Tuple[int, int, int]:
        c = self.cfg
        return (max(len(c.bm_choices), len(c.bq_choices), len(c.chunk_choices)),
                max(len(c.bn_choices), len(c.bkv_choices)),
                len(c.bk_choices))

    def valid_sizes(self, kind: str) -> Tuple[int, int, int]:
        return tuple(len(x) for x in self.choices(kind))

    def strict_enabled(self, strict: Optional[bool]) -> bool:
        if strict is not None:
            return strict
        return _STRICT_ACTIONS or getattr(self.cfg, "strict_actions", False)

    def tiles(self, kind: str, action: Sequence[int],
              strict: Optional[bool] = None) -> Tuple[int, ...]:
        ch = self.choices(kind)
        if self.strict_enabled(strict):
            for d in range(3):
                if not 0 <= int(action[d]) < len(ch[d]):
                    raise IndexError(
                        f"action index {int(action[d])} out of range "
                        f"[0, {len(ch[d])}) for head {d} of kind {kind!r}")
        return tuple(ch[d][min(int(action[d]), len(ch[d]) - 1)]
                     for d in range(3))

    def n_actions(self, kind: str) -> int:
        return int(np.prod(self.valid_sizes(kind)))

    def unflatten(self, kind: str, flat: int) -> Tuple[int, int, int]:
        s = self.valid_sizes(kind)
        return (flat // (s[1] * s[2]), (flat // s[2]) % s[1], flat % s[2])

    def unflatten_batch(self, kind: str, flat: np.ndarray) -> np.ndarray:
        """(n,) flat actions -> (n, 3) head indices (vectorized)."""
        s = self.valid_sizes(kind)
        flat = np.asarray(flat, np.int64)
        return np.stack([flat // (s[1] * s[2]),
                         (flat // s[2]) % s[1],
                         flat % s[2]], -1)


class CostModelEnv:
    """Reward oracle backed by the analytic TPU cost model.

    ``vectorized=True`` (default) uses the batched engine with a per-site
    baseline cache; ``vectorized=False`` reproduces the original scalar
    per-call loops (the reference path for parity tests and benchmarks).
    """

    def __init__(self, nv_cfg: NeuroVecConfig, seed: int = 0,
                 vectorized: bool = True):
        self.cfg = nv_cfg
        self.space = ActionSpace(nv_cfg)
        self.vectorized = vectorized
        self._rng = np.random.default_rng(seed)
        self._baseline_cache: Dict[str, float] = {}

    # -- baseline cache ----------------------------------------------------
    def baseline_cost(self, site: KernelSite) -> float:
        """Cached ``costmodel.baseline_cost`` (pure function of the site)."""
        key = site.key()
        c = self._baseline_cache.get(key)
        if c is None:
            c = costmodel.baseline_cost(site)
            self._baseline_cache[key] = c
        return c

    def baseline_costs(self, sites: Sequence[KernelSite]) -> np.ndarray:
        """(n,) baseline costs; fills the cache for unseen sites in one
        vectorized evaluation."""
        keys = [s.key() for s in sites]
        missing = [i for i, k in enumerate(keys)
                   if k not in self._baseline_cache]
        if missing:
            fresh = costmodel_vec.baseline_costs([sites[i] for i in missing])
            for i, c in zip(missing, fresh):
                self._baseline_cache[keys[i]] = float(c)
        return np.array([self._baseline_cache[k] for k in keys], np.float64)

    def clear_baseline_cache(self) -> None:
        self._baseline_cache.clear()

    # -- the paper's eq. 2 --
    def reward(self, site: KernelSite, action: Sequence[int]) -> float:
        t = self.cost(site, action)
        if t is None:
            return float(self.cfg.fail_penalty)
        # the scalar reference path recomputes the baseline per call,
        # faithful to the original implementation (what bench_env measures)
        t_base = (self.baseline_cost(site) if self.vectorized
                  else costmodel.baseline_cost(site))
        if not math.isfinite(t_base):       # failed baseline measurement
            return float(self.cfg.fail_penalty)
        if self.cfg.reward_noise > 0:
            t *= float(np.exp(self._rng.normal(0, self.cfg.reward_noise)))
        return float((t_base - t) / t_base)

    def cost(self, site: KernelSite, action: Sequence[int]) -> Optional[float]:
        return costmodel.site_cost(site, self.space.tiles(site.kind, action))

    def speedup(self, site: KernelSite, action: Sequence[int]) -> float:
        """t_baseline / t_action (clamped to the penalty semantics)."""
        t = self.cost(site, action)
        t_base = (self.baseline_cost(site) if self.vectorized
                  else costmodel.baseline_cost(site))
        if t is None or not math.isfinite(t_base):
            # illegal tile (or failed baseline measurement):
            # cfg.illegal_slowdown-times slower than baseline — the same
            # constant vectorizer.program_speedup charges
            return 1.0 / float(self.cfg.illegal_slowdown)
        return float(t_base / t)

    # -- batched fast paths -------------------------------------------------
    def costs_batch(self, sites, actions) -> np.ndarray:
        """(n,) per-site cost of the chosen actions; ``inf`` = illegal."""
        if not len(sites):
            return np.zeros((0,), np.float64)
        if not self.vectorized:
            return np.array([c if (c := self.cost(s, a)) is not None
                             else np.inf for s, a in zip(sites, actions)],
                            np.float64)
        return costmodel_vec.costs_for_actions(self.space, sites, actions)

    def rewards_batch(self, sites, actions) -> np.ndarray:
        if not self.vectorized:
            return np.array([self.reward(s, a)
                             for s, a in zip(sites, actions)], np.float32)
        if not len(sites):
            return np.zeros((0,), np.float32)
        # routed through the overridable batched surface so subclasses
        # (MeasuredEnv) swap the cost source without reimplementing eq. 2
        t = self.costs_batch(sites, actions)
        t_base = self.baseline_costs(sites)
        if self.cfg.reward_noise > 0:
            # draw only for legal entries, in site order — the same RNG
            # stream as the scalar path (which returns the penalty before
            # drawing), so seeded runs agree across both paths
            legal = np.isfinite(t)
            t = t.copy()
            t[legal] *= np.exp(self._rng.normal(
                0, self.cfg.reward_noise, size=int(legal.sum())))
        # a failed baseline measurement (inf t_base under MeasuredEnv)
        # fails closed to the penalty — never a silent nan into training.
        # errstate: the np.where arms evaluate eagerly and the discarded
        # arm divides by inf
        with np.errstate(invalid="ignore", divide="ignore"):
            r = np.where(np.isfinite(t) & np.isfinite(t_base),
                         (t_base - t) / t_base,
                         float(self.cfg.fail_penalty))
        return r.astype(np.float32)

    def speedups_batch(self, sites, actions) -> np.ndarray:
        """(n,) t_baseline / t_action with the illegal-tile clamp
        (``1 / cfg.illegal_slowdown`` — the env/vectorizer-shared
        constant)."""
        t = self.costs_batch(sites, actions)
        if self.vectorized:
            t_base = self.baseline_costs(sites)
        else:                     # faithful scalar reference: recompute
            t_base = np.array([costmodel.baseline_cost(s) for s in sites])
        return np.where(np.isfinite(t) & np.isfinite(t_base),
                        t_base / np.maximum(t, 1e-300),
                        1.0 / float(self.cfg.illegal_slowdown))

    def cost_grid(self, sites) -> np.ndarray:
        """(n_sites, max_n_actions) full action-grid cost tensor (``inf``
        for illegal tiles and for padding past a kind's action count)."""
        return costmodel_vec.cost_grid(self.space, sites)

    def tiles_costs(self, sites, tiles) -> np.ndarray:
        """(n,) cost of explicit tile values — need not lie on the action
        grid (``inf`` = illegal).  Prices arbitrary ``TileProgram``
        entries with the same source as the rest of this oracle, so
        ``program_speedup`` never mixes cost sources."""
        if not len(sites):
            return np.zeros((0,), np.float64)
        return costmodel_vec.costs_for_tiles(sites, tiles)


class MeasuredEnv(CostModelEnv):
    """Hardware-measurement oracle — eq. 2 priced by wall-clock timings.

    On TPU the analytic cost model is swapped for measurement of the
    compiled kernel; this class is that swap, behind the *same* batched
    Oracle surface as :class:`CostModelEnv` (``costs_batch`` /
    ``rewards_batch`` / ``speedups_batch`` / ``cost_grid`` /
    ``baseline_costs``), so agents and the facade never branch on it.

    ``measure_fn(sites, tiles) -> (n,) seconds`` is the batched measure
    hook: called at most once per oracle entry point with every
    cache-missing, model-legal ``(site, tile)`` pair of that batch
    (``tiles`` is an ``(n, 3)`` int array; unused dims are 1).  Non-finite
    or non-positive returns mark failed runs and are treated as illegal
    (a failed *baseline* measurement fails the whole site closed to the
    penalty — never a nan reward).  Results, including failures, are
    cached per ``(site.key(), tiles)`` and deduplicated within a batch, so
    repeated tuning sweeps re-measure nothing; ``clear_result_cache()``
    forces a re-measure after flaky runs.

    Tiles the cost model rejects (VMEM overflow — the compile-failure
    analogue) are never sent to the hook: a kernel that cannot compile
    cannot be timed.  With ``measure_fn=None`` (off-TPU) every query falls
    back to the analytic model, making this a drop-in
    :class:`CostModelEnv`.

    **Grid pruning** (``prune_topk`` + ``surrogate``): with a trained
    surrogate cost model attached, each site's legal tile grid is ranked
    by predicted runtime once and only the top-k candidates (plus the
    heuristic baseline tile, so eq. 2 stays measured-vs-measured) are
    ever submitted to the measurement hook — everything else is priced
    by the surrogate directly.  ``surrogate`` is duck-typed
    (``predict_seconds(sites, tiles) -> (n,) seconds``; see
    ``repro.surrogate``), keeping this module free of any model
    dependency.  ``pruned_pairs`` counts pairs priced by the surrogate
    instead of hardware.

    **Circuit breaker** (graceful degradation): when the measurement path
    collapses — the hook raises (dead transport), or
    ``breaker_threshold`` consecutive batches come back with *every* pair
    failed — the breaker opens and the oracle degrades to the analytic
    cost model instead of feeding all-``inf`` costs (= all-penalty
    rewards, a corrupted training signal) into tuning.  ``health()``
    reports ``"degraded"`` while open; cached failure verdicts from the
    collapse are purged so degraded queries re-price with the model.
    The breaker is one-way by design: call :meth:`reset_breaker` once
    the backend recovers.
    """

    #: a down transport degrades this oracle (resolve_health) rather
    #: than taking tuning down with it
    can_degrade = True

    def __init__(self, nv_cfg: NeuroVecConfig, measure_fn=None,
                 seed: int = 0, breaker_threshold: int = 2,
                 prune_topk: Optional[int] = None, surrogate=None):
        super().__init__(nv_cfg, seed=seed, vectorized=True)
        if breaker_threshold < 1:
            raise ValueError(
                f"breaker_threshold must be >= 1, got {breaker_threshold}")
        if prune_topk is not None and prune_topk < 1:
            raise ValueError(
                f"prune_topk must be >= 1, got {prune_topk}")
        self.measure_fn = measure_fn
        self.prune_topk = prune_topk
        self.surrogate = surrogate
        self._allowed_cache: Dict[str, frozenset] = {}
        self.breaker_threshold = breaker_threshold
        self.breaker_open = False
        self.degraded_reason: Optional[str] = None
        self._consec_failed_batches = 0
        self._result_cache: Dict[Tuple[str, Tuple[int, int, int]],
                                 float] = {}
        self.measure_calls = 0          # hook invocations (for tests/ops)
        self.measured_pairs = 0         # (site, tile) pairs sent to hw
        self.pruned_pairs = 0           # pairs priced by the surrogate

    def clear_result_cache(self) -> None:
        self._result_cache.clear()

    def health(self) -> str:
        """``"degraded"`` once the breaker opened (analytic fallback in
        effect), ``"ok"`` otherwise."""
        return "degraded" if self.breaker_open else "ok"

    def _trip_breaker(self, reason: str) -> None:
        self.breaker_open = True
        self.degraded_reason = reason
        # failure verdicts cached during the collapse are artifacts of
        # the dead measurement path, not of the kernels: purge them so
        # degraded-mode queries re-price with the analytic model
        for k in [k for k, v in self._result_cache.items()
                  if not math.isfinite(v)]:
            del self._result_cache[k]

    def reset_breaker(self) -> None:
        """Re-arm measurement after the backend recovers."""
        self.breaker_open = False
        self.degraded_reason = None
        self._consec_failed_batches = 0

    # -- surrogate grid pruning ---------------------------------------------
    @property
    def prune_active(self) -> bool:
        """Pruning needs all three legs: a budget, a trained surrogate,
        and an actual measurement path to save work on."""
        return (self.prune_topk is not None and self.surrogate is not None
                and self.measure_fn is not None)

    def _allowed_tiles(self, site) -> frozenset:
        """The measurable tile set for ``site``: the surrogate's top-k of
        the legal action grid plus the heuristic baseline tile (eq. 2
        must stay measured-vs-measured).  Ranked once per site."""
        key = site.key()
        allowed = self._allowed_cache.get(key)
        if allowed is None:
            grid = costmodel_vec.action_tiles_grid(self.space, site.kind)
            legal = np.flatnonzero(np.isfinite(
                costmodel_vec.costs_for_tiles([site] * len(grid), grid)))
            pred = np.asarray(self.surrogate.predict_seconds(
                [site] * len(legal), grid[legal]), np.float64)
            top = legal[np.argsort(pred, kind="stable")[:self.prune_topk]]
            base = costmodel_vec.baseline_tiles_batch([site])[0]
            allowed = frozenset(
                [tuple(int(x) for x in grid[i]) for i in top]
                + [tuple(int(x) for x in base)])
            self._allowed_cache[key] = allowed
        return allowed

    # -- the measured cost of explicit tiles --------------------------------
    def _measured_costs(self, sites, tiles) -> np.ndarray:
        """(n,) seconds per (site, tile) pair; ``inf`` = illegal/failed.
        One batched hook call covering all cache misses."""
        tiles = np.asarray(tiles, np.int64)
        keys = [(s.key(), (int(t[0]), int(t[1]), int(t[2])))
                for s, t in zip(sites, tiles)]
        # first occurrence of each uncached key: duplicates inside one
        # batch (training samples sites with replacement) are measured once
        first = {}
        for i, k in enumerate(keys):
            if k not in self._result_cache and k not in first:
                first[k] = i
        miss = list(first.values())
        if miss:
            m_sites = [sites[i] for i in miss]
            m_tiles = tiles[miss]
            vals = costmodel_vec.costs_for_tiles(m_sites, m_tiles)
            if self.measure_fn is not None and not self.breaker_open:
                legal = np.flatnonzero(np.isfinite(vals))
                if len(legal) and self.prune_active:
                    # surrogate grid pruning: only each site's top-k
                    # candidates (plus its baseline tile) reach the
                    # hardware; the rest are priced by the surrogate
                    keep = np.array(
                        [tuple(int(x) for x in m_tiles[j])
                         in self._allowed_tiles(m_sites[j])
                         for j in legal], bool)
                    pruned = legal[~keep]
                    if len(pruned):
                        vals[pruned] = self.surrogate.predict_seconds(
                            [m_sites[j] for j in pruned], m_tiles[pruned])
                        self.pruned_pairs += len(pruned)
                    legal = legal[keep]
                if len(legal):
                    try:
                        raw = self.measure_fn(
                            [m_sites[j] for j in legal], m_tiles[legal])
                    except Exception as e:
                        # a raising hook is a collapsed measurement path
                        # (closed/dead transport): open the breaker and
                        # keep the analytic prices for this batch
                        self._trip_breaker(
                            f"measure_fn raised {type(e).__name__}: {e}")
                        raw = None
                    if raw is not None:
                        t = np.asarray(raw, np.float64).reshape(-1)
                        if t.shape != (len(legal),):
                            raise ValueError(
                                f"measure_fn returned shape {t.shape}, "
                                f"expected ({len(legal)},)")
                        measured = np.where(np.isfinite(t) & (t > 0),
                                            t, np.inf)
                        self.measure_calls += 1
                        self.measured_pairs += len(legal)
                        if np.isfinite(measured).any():
                            self._consec_failed_batches = 0
                            vals[legal] = measured
                        else:
                            # every pair failed: one flaky batch is
                            # honest data (fail-closed inf), a streak is
                            # a dead backend — degrade instead of
                            # poisoning rewards with all-penalty
                            self._consec_failed_batches += 1
                            if self._consec_failed_batches \
                                    >= self.breaker_threshold:
                                self._trip_breaker(
                                    f"{self._consec_failed_batches} "
                                    f"consecutive all-failed "
                                    f"measurement batches")
                            else:
                                vals[legal] = measured
            for i, v in zip(miss, vals):
                self._result_cache[keys[i]] = float(v)
        gone = [i for i, k in enumerate(keys)
                if k not in self._result_cache]
        if gone:
            # a mid-batch breaker trip purged these keys' cached failure
            # verdicts (they were cached before this batch, so they are
            # not in ``miss``): re-price them with the analytic model
            fresh = costmodel_vec.costs_for_tiles(
                [sites[i] for i in gone], tiles[gone])
            for i, v in zip(gone, fresh):
                self._result_cache[keys[i]] = float(v)
        return np.array([self._result_cache[k] for k in keys], np.float64)

    # -- Oracle surface (measured) ------------------------------------------
    def costs_batch(self, sites, actions) -> np.ndarray:
        if not len(sites):
            return np.zeros((0,), np.float64)
        tiles = costmodel_vec.tiles_for_actions(self.space, sites, actions)
        return self._measured_costs(sites, tiles)

    def baseline_costs(self, sites) -> np.ndarray:
        if not len(sites):
            return np.zeros((0,), np.float64)
        return self._measured_costs(
            sites, costmodel_vec.baseline_tiles_batch(sites))

    def baseline_cost(self, site: KernelSite) -> float:
        return float(self.baseline_costs([site])[0])

    def cost(self, site: KernelSite, action: Sequence[int]) -> Optional[float]:
        c = float(self.costs_batch([site], np.asarray([action]))[0])
        return None if math.isinf(c) else c

    def tiles_costs(self, sites, tiles) -> np.ndarray:
        if not len(sites):
            return np.zeros((0,), np.float64)
        t = np.asarray(tiles, np.int64)
        if t.ndim != 2 or t.shape[0] != len(sites):  # same error as model
            raise ValueError(f"tiles must be (n_sites, k), got {t.shape}")
        if t.shape[1] < 3:                   # pad unused dims like the model
            t = np.concatenate(
                [t, np.ones((len(t), 3 - t.shape[1]), np.int64)], 1)
        return self._measured_costs(sites, t)

    def cost_grid(self, sites) -> np.ndarray:
        groups = costmodel_vec.group_by_kind(sites)
        a_max = max((self.space.n_actions(k) for k in groups), default=0)
        out = np.full((len(sites), a_max), np.inf, np.float64)
        for kind, idx in groups.items():
            tg = costmodel_vec.action_tiles_grid(self.space, kind)
            rep_sites = [sites[i] for i in idx for _ in range(len(tg))]
            rep_tiles = np.tile(tg, (len(idx), 1))
            out[idx, :len(tg)] = self._measured_costs(
                rep_sites, rep_tiles).reshape(len(idx), len(tg))
        return out
