# The paper's primary contribution: end-to-end RL kernel-factor tuning.
from repro.core.env import ActionSpace, CostModelEnv
from repro.core.extractor import extract_arch_sites, extract_sites
from repro.core.vectorizer import (TileProgram, baseline_program, inject,
                                   program_speedup, tune, tune_step_fn)

__all__ = [
    "ActionSpace", "CostModelEnv", "extract_arch_sites", "extract_sites",
    "TileProgram", "baseline_program", "inject", "program_speedup", "tune",
    "tune_step_fn",
]
