from repro.core.agents.ppo import PPOAgent
from repro.core.agents.brute import brute_force_action, brute_force_labels
from repro.core.agents.random_search import RandomAgent
from repro.core.agents.nns import NNSAgent
from repro.core.agents.dtree import DecisionTreeAgent
from repro.core.agents.polly import polly_action

__all__ = ["PPOAgent", "brute_force_action", "brute_force_labels",
           "RandomAgent", "NNSAgent", "DecisionTreeAgent", "polly_action"]
