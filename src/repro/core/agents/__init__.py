"""The decision methods of paper §3.5, all behind one Agent protocol and a
string-keyed registry.

``make_agent(name, cfg, seed=...)`` constructs any of the seven methods —
``ppo`` (deep RL), ``dtree``/``nns`` (supervised on brute-force labels),
``brute`` (exhaustive oracle), ``random``, ``polly`` (mem-only heuristic)
and ``baseline`` (the fixed LLVM-cost-model stand-in).  Every agent
satisfies :class:`repro.core.protocols.Agent` —
``fit(sites, oracle) -> self`` and ``act(sites, sample=False) -> (n, 3)``
— and is exercised by the shared contract test in ``tests/test_api.py``.
"""
from __future__ import annotations

import numpy as np

from repro.configs.neurovec import DEFAULT, NeuroVecConfig
from repro.core.agents.baseline import BaselineHeuristicAgent
from repro.core.agents.brute import (BruteForceAgent, brute_force_action,
                                     brute_force_costs, brute_force_labels,
                                     n_evaluations)
from repro.core.agents.dtree import DecisionTreeAgent
from repro.core.agents.nns import NNSAgent
from repro.core.agents.polly import PollyAgent
from repro.core.agents.ppo import PPOAgent
from repro.core.agents.random_search import RandomAgent
from repro.core.env import ActionSpace

AGENT_NAMES = ("ppo", "dtree", "nns", "brute", "random", "polly",
               "baseline")


def default_embed_fn(seed: int = 0):
    """A frozen randomly-initialized code2vec embedder — the stand-in used
    by ``nns``/``dtree`` when no trained embedding generator is supplied
    (random projections preserve the shape-feature geometry well enough
    for the supervised methods; pass ``embed_fn=ppo.code_vectors`` for the
    paper's frozen-after-RL setup).  Sized by the module-level embedding
    constants, not the tile config."""
    import jax
    import jax.numpy as jnp

    from repro.core import embedding as emb

    params = emb.embedder_init(jax.random.PRNGKey(seed))

    def embed(sites):
        ctx, mask = emb.featurize_batch(sites)
        return np.asarray(emb.embed_sites(params, jnp.asarray(ctx),
                                          jnp.asarray(mask)))

    return embed


def make_agent(name: str, cfg: NeuroVecConfig = DEFAULT, *, seed: int = 0,
               **kwargs):
    """Construct a registered agent by name.

    Extra ``kwargs`` flow to the constructor (e.g. ``mode=``/``lr=`` for
    ppo, ``embed_fn=`` for nns/dtree, ``oracle=`` for brute,
    ``max_depth=`` for dtree)."""
    if name == "ppo":
        return PPOAgent(cfg, seed=seed, **kwargs)
    if name == "dtree":
        embed_fn = kwargs.pop("embed_fn", None) or default_embed_fn(seed)
        return DecisionTreeAgent(embed_fn, seed=seed, **kwargs)
    if name == "nns":
        embed_fn = kwargs.pop("embed_fn", None) or default_embed_fn(seed)
        return NNSAgent(embed_fn, **kwargs)
    if name == "brute":
        return BruteForceAgent(cfg=cfg, **kwargs)
    if name == "random":
        return RandomAgent(ActionSpace(cfg), seed=seed, **kwargs)
    if name == "polly":
        return PollyAgent(ActionSpace(cfg), **kwargs)
    if name == "baseline":
        return BaselineHeuristicAgent(ActionSpace(cfg), **kwargs)
    raise ValueError(
        f"unknown agent {name!r}; registered: {', '.join(AGENT_NAMES)}")


__all__ = ["AGENT_NAMES", "make_agent", "default_embed_fn",
           "PPOAgent", "BruteForceAgent", "DecisionTreeAgent", "NNSAgent",
           "PollyAgent", "RandomAgent", "BaselineHeuristicAgent",
           "brute_force_action", "brute_force_labels", "brute_force_costs",
           "n_evaluations"]
