"""The Polly analogue (DESIGN.md §2): a strong *non-learned* domain
baseline.  Polly optimizes polyhedral data locality (tiling/fusion) but not
ISA-level vectorization heuristics; our analogue picks the tile that
minimizes *data movement only* subject to VMEM — ignoring MXU alignment,
pipeline overheads and dispatch cost, which is exactly the blind spot the
RL agent exploits (paper §4: Polly beats baseline by 17%, loses to RL by
56%)."""
from __future__ import annotations

import itertools

import numpy as np

from repro.core import costmodel
from repro.models.compute import KernelSite


def _mem_only_cost(site: KernelSite, tiles) -> float:
    s = costmodel._dtype_bytes(site.dtype)
    if site.kind == "matmul":
        M, N, K = site.m, site.n, site.k
        bm, bn, bk = tiles
        vmem = 2 * (bm * bk + bk * bn) * s + bm * bn * 4 + bm * bn * s
        if vmem > costmodel.VMEM_BYTES:
            return float("inf")
        tm, tn = -(-M // bm), -(-N // bn)
        return (M * K * tn + K * N * tm + M * N) * s
    if site.kind == "attention":
        Sq, Skv, D, BH = site.m, site.k, site.n, site.batch
        bq, bkv = tiles[:2]
        vmem = 2 * (bq * D + 2 * bkv * D) * s + bq * D * 4 + bq * bkv * 4
        if vmem > costmodel.VMEM_BYTES:
            return float("inf")
        tq = -(-Sq // bq)
        return BH * (Sq * D + 2 * Skv * D * tq + Sq * D) * s
    if site.kind == "chunk_scan":
        Q = tiles[0]
        tokens = site.batch * site.m
        vmem = 2 * Q * (site.n + 2 * site.k) * s + site.n * site.k * 4 \
            + Q * Q * 4
        if vmem > costmodel.VMEM_BYTES:
            return float("inf")
        # state re-load per chunk boundary
        return tokens * (site.n + 2 * site.k) * s * 2 \
            + (-(-tokens // Q)) * site.n * site.k * 4
    raise ValueError(site.kind)


def polly_action(space, site: KernelSite):
    sizes = space.valid_sizes(site.kind)
    best_a, best_c = (0, 0, 0), float("inf")
    for a in itertools.product(*(range(n) for n in sizes)):
        c = _mem_only_cost(site, space.tiles(site.kind, a))
        if c < best_c:
            best_a, best_c = a, c
    return np.array(best_a, np.int64)
