"""The Polly analogue (DESIGN.md §2): a strong *non-learned* domain
baseline.  Polly optimizes polyhedral data locality (tiling/fusion) but not
ISA-level vectorization heuristics; our analogue picks the tile that
minimizes *data movement only* subject to VMEM — ignoring MXU alignment,
pipeline overheads and dispatch cost, which is exactly the blind spot the
RL agent exploits (paper §4: Polly beats baseline by 17%, loses to RL by
56%).

The search is one vectorized mem-only cost grid per site kind (exact int64
byte counts, so ties break identically to the scalar ``itertools.product``
walk, which is kept below as the parity reference).
"""
from __future__ import annotations

import itertools

import numpy as np

from repro.core import costmodel, costmodel_vec
from repro.models.compute import KernelSite

_ILLEGAL = np.iinfo(np.int64).max      # sentinel: never wins an argmin


def _mem_only_cost(site: KernelSite, tiles) -> float:
    """Scalar reference (the original per-tile walk) — parity-tested
    against the vectorized grid."""
    s = costmodel._dtype_bytes(site.dtype)
    if site.kind == "matmul":
        M, N, K = site.m, site.n, site.k
        bm, bn, bk = tiles
        vmem = 2 * (bm * bk + bk * bn) * s + bm * bn * 4 + bm * bn * s
        if vmem > costmodel.VMEM_BYTES:
            return float("inf")
        tm, tn = -(-M // bm), -(-N // bn)
        return (M * K * tn + K * N * tm + M * N) * s
    if site.kind == "attention":
        Sq, Skv, D, BH = site.m, site.k, site.n, site.batch
        bq, bkv = tiles[:2]
        vmem = 2 * (bq * D + 2 * bkv * D) * s + bq * D * 4 + bq * bkv * 4
        if vmem > costmodel.VMEM_BYTES:
            return float("inf")
        tq = -(-Sq // bq)
        return BH * (Sq * D + 2 * Skv * D * tq + Sq * D) * s
    if site.kind == "chunk_scan":
        Q = tiles[0]
        tokens = site.batch * site.m
        vmem = 2 * Q * (site.n + 2 * site.k) * s + site.n * site.k * 4 \
            + Q * Q * 4
        if vmem > costmodel.VMEM_BYTES:
            return float("inf")
        # state re-load per chunk boundary
        return tokens * (site.n + 2 * site.k) * s * 2 \
            + (-(-tokens // Q)) * site.n * site.k * 4
    raise ValueError(site.kind)


def _ceil(a, b):
    return -(-a // b)


def mem_only_grid_kind(space, sites, kind: str) -> np.ndarray:
    """(n_sites, n_actions(kind)) data-movement bytes in flat-action
    order; VMEM-illegal entries carry the int64-max sentinel.  Exact
    integer arithmetic — identical ordering (and argmin tie-breaks) to
    the scalar walk."""
    tiles = costmodel_vec.action_tiles_grid(space, kind)
    t0, t1, t2 = tiles[None, :, 0], tiles[None, :, 1], tiles[None, :, 2]
    c = costmodel_vec._site_cols(sites)             # (n, 1) int columns
    s = c["s"]
    if kind == "matmul":
        M, N, K = c["m"], c["n"], c["k"]
        vmem = 2 * (t0 * t2 + t2 * t1) * s + t0 * t1 * 4 + t0 * t1 * s
        tm, tn = _ceil(M, t0), _ceil(N, t1)
        cost = (M * K * tn + K * N * tm + M * N) * s
    elif kind == "attention":
        Sq, Skv, D, BH = c["m"], c["k"], c["n"], c["batch"]
        vmem = 2 * (t0 * D + 2 * t1 * D) * s + t0 * D * 4 + t0 * t1 * 4
        tq = _ceil(Sq, t0)
        cost = BH * (Sq * D + 2 * Skv * D * tq + Sq * D) * s
    elif kind == "chunk_scan":
        P, N, tokens = c["n"], c["k"], c["batch"] * c["m"]
        vmem = 2 * t0 * (P + 2 * N) * s + P * N * 4 + t0 * t0 * 4
        cost = tokens * (P + 2 * N) * s * 2 + _ceil(tokens, t0) * P * N * 4
    else:
        raise ValueError(kind)
    cost = np.broadcast_to(cost, vmem.shape)
    return np.where(vmem <= costmodel.VMEM_BYTES, cost, _ILLEGAL)


class PollyAgent:
    """Mem-only argmin behind the Agent protocol (search-free: ``fit`` is
    a no-op that may pick up the oracle's action space)."""

    name = "polly"

    def __init__(self, space=None):
        self.space = space

    def fit(self, sites, oracle, **_) -> "PollyAgent":
        if self.space is None:
            self.space = oracle.space
        return self

    def state_dict(self) -> dict:
        """Versioned empty state (search-free; the action space comes
        from construction via the registry)."""
        from repro.core.protocols import AGENT_STATE_VERSION
        return {"version": AGENT_STATE_VERSION, "name": self.name}

    def load_state(self, state: dict) -> "PollyAgent":
        from repro.core.protocols import check_agent_state
        check_agent_state(state, self.name)
        return self

    def act(self, sites, *, sample: bool = False) -> np.ndarray:
        if self.space is None:
            raise RuntimeError("PollyAgent.act before fit (no ActionSpace)")
        out = np.zeros((len(sites), 3), np.int64)
        for kind, idx in costmodel_vec.group_by_kind(sites).items():
            grid = mem_only_grid_kind(self.space,
                                      [sites[i] for i in idx], kind)
            out[idx] = self.space.unflatten_batch(kind, grid.argmin(1))
        return out


def _polly_action_ref(space, site: KernelSite):
    """The original interpreted factor-product walk (parity reference)."""
    sizes = space.valid_sizes(site.kind)
    best_a, best_c = (0, 0, 0), float("inf")
    for a in itertools.product(*(range(n) for n in sizes)):
        c = _mem_only_cost(site, space.tiles(site.kind, a))
        if c < best_c:
            best_a, best_c = a, c
    return np.array(best_a, np.int64)
