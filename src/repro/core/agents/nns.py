"""Nearest-neighbor search on the learned code embeddings (paper §3.5):
after end-to-end RL training, the embedding generator is frozen and NNS
predicts the brute-force-labelled action of the closest training site."""
from __future__ import annotations

import numpy as np

from repro.core.agents.brute import brute_force_labels
from repro.core.protocols import AGENT_STATE_VERSION, check_agent_state


class NNSAgent:
    """``fit(sites, oracle)`` brute-force-labels the training sites via the
    oracle's cost grid (pass ``labels=`` to reuse precomputed ones) and
    freezes their embeddings; ``act`` is one vectorized cosine argmax."""

    name = "nns"

    def __init__(self, embed_fn=None):
        self.embed_fn = embed_fn
        self.keys = None
        self.labels = None
        self.train_kinds = None

    def fit(self, sites, oracle, labels=None, **_) -> "NNSAgent":
        if self.embed_fn is None:
            raise ValueError("NNSAgent needs an embed_fn "
                             "(e.g. PPOAgent.code_vectors)")
        if labels is None:
            labels = brute_force_labels(oracle, sites)
        self.keys = self._norm(np.asarray(self.embed_fn(sites)))
        self.labels = np.asarray(labels, np.int64)
        self.train_kinds = np.array([s.kind for s in sites])
        return self

    @staticmethod
    def _norm(x):
        return x / (np.linalg.norm(x, axis=-1, keepdims=True) + 1e-9)

    def state_dict(self) -> dict:
        """The frozen training-set embeddings + brute-force labels (the
        whole fitted model; the embed_fn itself is reconstructed from the
        construction seed, not serialized)."""
        st = {"version": AGENT_STATE_VERSION, "name": self.name,
              "fitted": self.keys is not None}
        if self.keys is not None:
            st["keys"] = np.asarray(self.keys)
            st["labels"] = np.asarray(self.labels, np.int64)
            st["train_kinds"] = [str(k) for k in self.train_kinds]
        return st

    def load_state(self, state: dict) -> "NNSAgent":
        check_agent_state(state, self.name)
        if state["fitted"]:
            # keys keep their saved dtype: act() mixes them into float
            # matmuls and a silent up/downcast could perturb argmax ties
            self.keys = np.asarray(state["keys"])
            self.labels = np.asarray(state["labels"], np.int64)
            self.train_kinds = np.array([str(k)
                                         for k in state["train_kinds"]])
        else:
            self.keys = self.labels = self.train_kinds = None
        return self

    def act(self, sites, *, sample: bool = False) -> np.ndarray:
        if self.keys is None:
            raise RuntimeError("NNSAgent.act before fit")
        q = self._norm(np.asarray(self.embed_fn(sites)))
        sims = q @ self.keys.T                        # (B, n_train) cosine
        # restrict to same-kind neighbors (different kinds have different
        # action semantics) — one vectorized mask+argmax, no Python loop
        kinds = np.array([s.kind for s in sites])
        match = kinds[:, None] == self.train_kinds[None, :]
        nn = np.where(match, sims, -np.inf).argmax(1)
        return self.labels[nn]
