"""Nearest-neighbor search on the learned code embeddings (paper §3.5):
after end-to-end RL training, the embedding generator is frozen and NNS
predicts the brute-force-labelled action of the closest training site."""
from __future__ import annotations

import numpy as np

from repro.core.agents.brute import brute_force_labels


class NNSAgent:
    """``fit(sites, oracle)`` brute-force-labels the training sites via the
    oracle's cost grid (pass ``labels=`` to reuse precomputed ones) and
    freezes their embeddings; ``act`` is one vectorized cosine argmax."""

    name = "nns"

    def __init__(self, embed_fn=None):
        self.embed_fn = embed_fn
        self.keys = None
        self.labels = None
        self.train_kinds = None

    def fit(self, sites, oracle, labels=None, **_) -> "NNSAgent":
        if self.embed_fn is None:
            raise ValueError("NNSAgent needs an embed_fn "
                             "(e.g. PPOAgent.code_vectors)")
        if labels is None:
            labels = brute_force_labels(oracle, sites)
        self.keys = self._norm(np.asarray(self.embed_fn(sites)))
        self.labels = np.asarray(labels, np.int64)
        self.train_kinds = np.array([s.kind for s in sites])
        return self

    @staticmethod
    def _norm(x):
        return x / (np.linalg.norm(x, axis=-1, keepdims=True) + 1e-9)

    def act(self, sites, *, sample: bool = False) -> np.ndarray:
        if self.keys is None:
            raise RuntimeError("NNSAgent.act before fit")
        q = self._norm(np.asarray(self.embed_fn(sites)))
        sims = q @ self.keys.T                        # (B, n_train) cosine
        # restrict to same-kind neighbors (different kinds have different
        # action semantics) — one vectorized mask+argmax, no Python loop
        kinds = np.array([s.kind for s in sites])
        match = kinds[:, None] == self.train_kinds[None, :]
        nn = np.where(match, sims, -np.inf).argmax(1)
        return self.labels[nn]
