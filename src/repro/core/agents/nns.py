"""Nearest-neighbor search on the learned code embeddings (paper §3.5):
after end-to-end RL training, the embedding generator is frozen and NNS
predicts the brute-force-labelled action of the closest training site."""
from __future__ import annotations

import numpy as np


class NNSAgent:
    def __init__(self, embed_fn, train_sites, labels: np.ndarray):
        self.embed_fn = embed_fn
        self.keys = self._norm(embed_fn(train_sites))
        self.labels = labels
        self.train_kinds = np.array([s.kind for s in train_sites])

    @staticmethod
    def _norm(x):
        return x / (np.linalg.norm(x, axis=-1, keepdims=True) + 1e-9)

    def act(self, sites):
        q = self._norm(self.embed_fn(sites))
        sims = q @ self.keys.T                        # (B, n_train) cosine
        # restrict to same-kind neighbors (different kinds have different
        # action semantics) — one vectorized mask+argmax, no Python loop
        kinds = np.array([s.kind for s in sites])
        match = kinds[:, None] == self.train_kinds[None, :]
        nn = np.where(match, sims, -np.inf).argmax(1)
        return np.asarray(self.labels, np.int64)[nn]
