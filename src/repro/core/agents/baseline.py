"""The heuristic baseline as an Agent: the stand-in for LLVM's fixed cost
model (paper Fig. 7's 1.0x reference bar).  ``act`` maps each site's
heuristic baseline tiles back onto the nearest action-grid indices — one
vectorized pass per site kind."""
from __future__ import annotations

import numpy as np

from repro.core import costmodel_vec


class BaselineHeuristicAgent:
    name = "baseline"

    def __init__(self, space=None):
        self.space = space

    def fit(self, sites, oracle, **_) -> "BaselineHeuristicAgent":
        if self.space is None:
            self.space = oracle.space
        return self

    def state_dict(self) -> dict:
        """Versioned empty state (the fixed heuristic learns nothing)."""
        from repro.core.protocols import AGENT_STATE_VERSION
        return {"version": AGENT_STATE_VERSION, "name": self.name}

    def load_state(self, state: dict) -> "BaselineHeuristicAgent":
        from repro.core.protocols import check_agent_state
        check_agent_state(state, self.name)
        return self

    def act(self, sites, *, sample: bool = False) -> np.ndarray:
        if self.space is None:
            raise RuntimeError("BaselineHeuristicAgent.act before fit "
                               "(no ActionSpace)")
        tiles = costmodel_vec.baseline_tiles_batch(sites)
        out = np.zeros((len(sites), 3), np.int64)
        for kind, idx in costmodel_vec.group_by_kind(sites).items():
            for d, opts in enumerate(self.space.choices(kind)):
                opts_a = np.asarray(opts, np.int64)
                # exact match when the tile is a choice, else nearest
                out[idx, d] = np.abs(opts_a[None, :]
                                     - tiles[idx, d][:, None]).argmin(1)
        return out
