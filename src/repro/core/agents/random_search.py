"""Random search: one uniform action per site (paper Fig. 7 — performs
*worse* than the baseline, evidencing that the RL policy learned structure).

Vectorized: one ``rng.integers`` draw per site-kind group (the per-head
upper bounds broadcast), instead of a Python loop over sites.
"""
from __future__ import annotations

import numpy as np

from repro.core import costmodel_vec


class RandomAgent:
    name = "random"

    def __init__(self, space=None, seed: int = 0):
        self.space = space
        self.seed = seed
        self.rng = np.random.default_rng(seed)

    def fit(self, sites, oracle, **_) -> "RandomAgent":
        if self.space is None:
            self.space = oracle.space
        return self

    def state_dict(self) -> dict:
        """The seed is the whole deployable state: ``act(sample=False)``
        redraws from it, so restoring it reproduces deployment actions
        exactly.  The exploration stream (``sample=True``) restarts."""
        from repro.core.protocols import AGENT_STATE_VERSION
        return {"version": AGENT_STATE_VERSION, "name": self.name,
                "seed": int(self.seed)}

    def load_state(self, state: dict) -> "RandomAgent":
        from repro.core.protocols import check_agent_state
        check_agent_state(state, self.name)
        self.seed = int(state["seed"])
        self.rng = np.random.default_rng(self.seed)
        return self

    def act(self, sites, *, sample: bool = False) -> np.ndarray:
        if self.space is None:
            raise RuntimeError("RandomAgent.act before fit (no ActionSpace)")
        # sample=False (deployment) must be deterministic: redraw from the
        # construction seed instead of advancing the stateful stream
        rng = self.rng if sample else np.random.default_rng(self.seed)
        out = np.zeros((len(sites), 3), np.int64)
        for kind, idx in costmodel_vec.group_by_kind(sites).items():
            sizes = np.asarray(self.space.valid_sizes(kind), np.int64)
            out[idx] = rng.integers(0, sizes, size=(len(idx), 3))
        return out
