"""Random search: one uniform action per site (paper Fig. 7 — performs
*worse* than the baseline, evidencing that the RL policy learned structure)."""
from __future__ import annotations

import numpy as np


class RandomAgent:
    def __init__(self, space, seed: int = 0):
        self.space = space
        self.rng = np.random.default_rng(seed)

    def act(self, sites):
        out = []
        for s in sites:
            sizes = self.space.valid_sizes(s.kind)
            out.append([self.rng.integers(0, n) for n in sizes])
        return np.array(out, np.int64)
