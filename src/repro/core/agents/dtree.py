"""CART decision tree on the learned code embeddings (paper §3.5, Fig. 7).

Pure-numpy classification tree over the flattened action index, trained on
brute-force labels.  Per-kind trees (action semantics differ by site kind).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.core.protocols import AGENT_STATE_VERSION, check_agent_state


@dataclass
class _Node:
    feature: int = -1
    thresh: float = 0.0
    left: Optional["_Node"] = None
    right: Optional["_Node"] = None
    label: int = 0


def _gini(y, n_classes):
    if len(y) == 0:
        return 0.0
    counts = np.bincount(y, minlength=n_classes)
    p = counts / len(y)
    return 1.0 - (p * p).sum()


def _build(X, y, n_classes, depth, max_depth, min_samples, rng):
    node = _Node(label=int(np.bincount(y, minlength=n_classes).argmax()))
    if depth >= max_depth or len(y) < min_samples or len(np.unique(y)) == 1:
        return node
    best_gain, best = 0.0, None
    parent = _gini(y, n_classes)
    # random feature subsample keeps this O(n log n)-ish at 340 dims
    feats = rng.choice(X.shape[1], size=min(48, X.shape[1]), replace=False)
    for f in feats:
        vals = X[:, f]
        qs = np.quantile(vals, (0.25, 0.5, 0.75))
        for t in qs:
            m = vals <= t
            if m.sum() < 2 or (~m).sum() < 2:
                continue
            g = parent - (m.mean() * _gini(y[m], n_classes)
                          + (~m).mean() * _gini(y[~m], n_classes))
            if g > best_gain:
                best_gain, best = g, (f, t, m)
    if best is None:
        return node
    f, t, m = best
    node.feature, node.thresh = int(f), float(t)
    node.left = _build(X[m], y[m], n_classes, depth + 1, max_depth,
                       min_samples, rng)
    node.right = _build(X[~m], y[~m], n_classes, depth + 1, max_depth,
                        min_samples, rng)
    return node


def _predict_one(node, x):
    while node.feature >= 0:
        node = node.left if x[node.feature] <= node.thresh else node.right
    return node.label


def _node_to_dict(node: _Node) -> dict:
    d = {"f": node.feature, "t": node.thresh, "label": node.label}
    if node.feature >= 0:
        d["left"] = _node_to_dict(node.left)
        d["right"] = _node_to_dict(node.right)
    return d


def _node_from_dict(d: dict) -> _Node:
    node = _Node(feature=int(d["f"]), thresh=float(d["t"]),
                 label=int(d["label"]))
    if node.feature >= 0:
        node.left = _node_from_dict(d["left"])
        node.right = _node_from_dict(d["right"])
    return node


class DecisionTreeAgent:
    """``fit(sites, oracle)`` brute-force-labels the training sites via
    the oracle's cost grid (pass ``labels=`` to reuse precomputed ones)
    and grows one tree per site kind."""

    name = "dtree"

    def __init__(self, embed_fn=None, max_depth: int = 12,
                 min_samples: int = 4, seed: int = 0):
        self.embed_fn = embed_fn
        self.max_depth = max_depth
        self.min_samples = min_samples
        self.seed = seed
        self.space = None
        self.trees = {}

    def fit(self, train_sites, oracle, labels=None, **_) -> "DecisionTreeAgent":
        if self.embed_fn is None:
            raise ValueError("DecisionTreeAgent needs an embed_fn "
                             "(e.g. PPOAgent.code_vectors)")
        if labels is None:
            from repro.core.agents.brute import brute_force_labels
            labels = brute_force_labels(oracle, train_sites)
        labels = np.asarray(labels)
        self.space = oracle.space
        self.trees = {}
        X = np.asarray(self.embed_fn(train_sites))
        rng = np.random.default_rng(self.seed)
        kinds = sorted({s.kind for s in train_sites})
        for kind in kinds:
            idx = [i for i, s in enumerate(train_sites) if s.kind == kind]
            sizes = self.space.valid_sizes(kind)
            flat = (labels[idx, 0] * sizes[1] * sizes[2]
                    + labels[idx, 1] * sizes[2] + labels[idx, 2])
            n_classes = sizes[0] * sizes[1] * sizes[2]
            self.trees[kind] = _build(X[idx], flat.astype(np.int64),
                                      n_classes, 0, self.max_depth,
                                      self.min_samples, rng)
        return self

    def state_dict(self) -> dict:
        """The grown per-kind trees plus the action-space config they
        unflatten through (the constructor never sees a cfg, so ``act``
        after ``load_state`` must not depend on a later ``fit``)."""
        from repro.configs.neurovec import cfg_to_dict
        st = {"version": AGENT_STATE_VERSION, "name": self.name,
              "trees": {k: _node_to_dict(t) for k, t in self.trees.items()},
              "space_cfg": (cfg_to_dict(self.space.cfg)
                            if self.space is not None else None)}
        return st

    def load_state(self, state: dict) -> "DecisionTreeAgent":
        check_agent_state(state, self.name)
        from repro.configs.neurovec import cfg_from_dict
        from repro.core.env import ActionSpace
        self.trees = {k: _node_from_dict(d)
                      for k, d in state["trees"].items()}
        self.space = (ActionSpace(cfg_from_dict(state["space_cfg"]))
                      if state["space_cfg"] is not None else None)
        return self

    def act(self, sites, *, sample: bool = False) -> np.ndarray:
        if not self.trees:
            raise RuntimeError("DecisionTreeAgent.act before fit")
        X = np.asarray(self.embed_fn(sites))
        out = []
        for i, s in enumerate(sites):
            flat = _predict_one(self.trees[s.kind], X[i])
            out.append(self.space.unflatten(s.kind, int(flat)))
        return np.array(out, np.int64)
