"""PPO contextual bandit in pure JAX (paper §2.3, §3.3, §4).

One episode = one loop/site (contextual bandit).  A single network embeds
the site (code2vec analogue, trained end-to-end) and emits a *joint* action
over the factor heads — the configuration the paper found best (§3.3).
Action-space ablations for Fig. 6:

* ``discrete``  (default): 3 masked categorical heads (VF/IF-style indices).
* ``cont1``: one continuous output decoding to a flattened action index.
* ``cont2``: one continuous output per head, rounded to the nearest index.
* ``two_agents``: independent policies per head (the paper's inferior
  baseline from §3.3).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.neurovec import NeuroVecConfig
from repro.core import embedding as emb
from repro.core.env import ActionSpace, CostModelEnv
from repro.core.protocols import AGENT_STATE_VERSION, check_agent_state
from repro.models.compute import KernelSite

_KIND_IDX = {"matmul": 0, "attention": 1, "chunk_scan": 2}


# ---------------------------------------------------------------------------
# network
# ---------------------------------------------------------------------------

def _mlp_init(key, sizes):
    params = []
    for i, (a, b) in enumerate(zip(sizes[:-1], sizes[1:])):
        k1, key = jax.random.split(key)
        params.append({"w": jax.random.normal(k1, (a, b))
                       * math.sqrt(2.0 / a), "b": jnp.zeros((b,))})
    return params


def _mlp(params, x, final_tanh=False):
    for i, p in enumerate(params):
        x = x @ p["w"] + p["b"]
        if i < len(params) - 1:
            x = jnp.tanh(x)
    return x


def agent_init(key, nv: NeuroVecConfig, head_sizes, mode: str):
    ks = jax.random.split(key, 6)
    hid = list(nv.hidden)
    n_out = (sum(head_sizes) if mode in ("discrete", "two_agents")
             else (2 if mode == "cont1" else 2 * len(head_sizes)))
    return {
        "embedder": emb.embedder_init(ks[0]),
        "trunk": _mlp_init(ks[1], [emb.EMBED_DIM] + hid),
        "pi": _mlp_init(ks[2], [hid[-1], n_out]),
        "vf": _mlp_init(ks[3], [hid[-1], 1]),
    }


# ---------------------------------------------------------------------------
# distributions
# ---------------------------------------------------------------------------

def _head_logits(nv: NeuroVecConfig, head_sizes, out, valid_sizes):
    """Split flat logits into masked per-head logits.
    valid_sizes: (B, 3) int — per-sample valid head lengths."""
    logits = []
    off = 0
    for h, size in enumerate(head_sizes):
        lg = out[:, off:off + size]
        idx = jnp.arange(size)[None, :]
        lg = jnp.where(idx < valid_sizes[:, h:h + 1], lg, -1e30)
        logits.append(lg)
        off += size
    return logits


def policy_forward(params, nv, head_sizes, contexts, mask, valid_sizes,
                   mode: str, fast_embed: bool = True):
    """-> (per-head logits or (mu, logstd), value).  ``fast_embed=False``
    uses the seed's un-factored embedder (benchmark reference path)."""
    embed = emb.embed_sites if fast_embed else emb.embed_sites_ref
    code = embed(params["embedder"], contexts, mask)
    h = jnp.tanh(_mlp(params["trunk"], code))
    out = _mlp(params["pi"], h)
    v = _mlp(params["vf"], h)[:, 0]
    if mode in ("discrete", "two_agents"):
        return _head_logits(nv, head_sizes, out, valid_sizes), v
    return out, v     # continuous params


def sample_discrete(key, logits_list):
    acts, logps, ent = [], 0.0, 0.0
    for i, lg in enumerate(logits_list):
        k = jax.random.fold_in(key, i)
        a = jax.random.categorical(k, lg)
        lp = jax.nn.log_softmax(lg)
        logps += jnp.take_along_axis(lp, a[:, None], 1)[:, 0]
        p = jnp.exp(lp)
        ent += -(p * jnp.where(p > 0, lp, 0.0)).sum(-1)
        acts.append(a)
    return jnp.stack(acts, -1), logps, ent


def logp_discrete(logits_list, actions):
    logps, ent = 0.0, 0.0
    for i, lg in enumerate(logits_list):
        lp = jax.nn.log_softmax(lg)
        logps += jnp.take_along_axis(lp, actions[:, i:i + 1], 1)[:, 0]
        p = jnp.exp(lp)
        ent += -(p * jnp.where(p > 0, lp, 0.0)).sum(-1)
    return logps, ent


# continuous helpers (Fig. 6 ablations) -------------------------------------

def _cont_decode(nv, head_sizes, raw, valid_sizes, mode):
    """Map continuous samples in R -> action indices (rounded)."""
    if mode == "cont1":
        u = jax.nn.sigmoid(raw[:, 0])
        n_flat = (valid_sizes[:, 0] * valid_sizes[:, 1]
                  * valid_sizes[:, 2]).astype(jnp.float32)
        flat = jnp.minimum((u * n_flat).astype(jnp.int32),
                           (n_flat - 1).astype(jnp.int32))
        s1 = valid_sizes[:, 1] * valid_sizes[:, 2]
        a0 = flat // s1
        a1 = (flat // valid_sizes[:, 2]) % valid_sizes[:, 1]
        a2 = flat % valid_sizes[:, 2]
        return jnp.stack([a0, a1, a2], -1)
    u = jax.nn.sigmoid(raw)                                   # (B,3)
    a = jnp.minimum((u * valid_sizes).astype(jnp.int32), valid_sizes - 1)
    return a


def sample_continuous(key, out, valid_sizes, mode):
    n = 1 if mode == "cont1" else valid_sizes.shape[1]
    mu, logstd = out[:, :n], jnp.clip(out[:, n:], -3.0, 1.0)
    eps = jax.random.normal(key, mu.shape)
    raw = mu + jnp.exp(logstd) * eps
    logp = (-0.5 * (eps ** 2) - logstd
            - 0.5 * math.log(2 * math.pi)).sum(-1)
    ent = (logstd + 0.5 * math.log(2 * math.pi * math.e)).sum(-1)
    return raw, logp, ent


def logp_continuous(out, raw, mode, n_heads):
    n = 1 if mode == "cont1" else n_heads
    mu, logstd = out[:, :n], jnp.clip(out[:, n:], -3.0, 1.0)
    z = (raw - mu) / jnp.exp(logstd)
    logp = (-0.5 * (z ** 2) - logstd - 0.5 * math.log(2 * math.pi)).sum(-1)
    ent = (logstd + 0.5 * math.log(2 * math.pi * math.e)).sum(-1)
    return logp, ent


# ---------------------------------------------------------------------------
# Adam (local, tiny)
# ---------------------------------------------------------------------------

def adam_init(params):
    z = jax.tree.map(jnp.zeros_like, params)
    return {"m": z, "v": jax.tree.map(jnp.zeros_like, params),
            "t": jnp.zeros((), jnp.int32)}


def adam_update(params, grads, state, lr, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"],
                     grads)
    mhat = jax.tree.map(lambda m: m / (1 - b1 ** t), m)
    vhat = jax.tree.map(lambda v: v / (1 - b2 ** t), v)
    params = jax.tree.map(lambda p, m, v: p - lr * m / (jnp.sqrt(v) + eps),
                          params, mhat, vhat)
    return params, {"m": m, "v": v, "t": t}


# ---------------------------------------------------------------------------
# the agent
# ---------------------------------------------------------------------------

@dataclass
class PPOAgent:
    nv: NeuroVecConfig
    mode: str = "discrete"       # discrete | cont1 | cont2 | two_agents
    seed: int = 0
    lr: Optional[float] = None
    fused: bool = True           # fully-jitted update (Adam + minibatch scan
                                 # inside jit); False = legacy per-minibatch
                                 # path, kept as the benchmark reference

    name = "ppo"                 # registry key (Agent protocol)

    def __post_init__(self):
        self.space = ActionSpace(self.nv)
        self.head_sizes = self.space.head_sizes
        key = jax.random.PRNGKey(self.seed)
        self.params = agent_init(key, self.nv, self.head_sizes, self.mode)
        self.opt = adam_init(self.params)
        self._lr = self.lr if self.lr is not None else self.nv.lr
        self.history: List[dict] = []
        self._key = jax.random.fold_in(key, 777)
        self._jit_sample = jax.jit(self._sample_impl)
        self._jit_greedy = jax.jit(self._greedy_impl)
        self._jit_epoch = jax.jit(self._epoch_impl)
        self._jit_step = jax.jit(self._step_impl)
        self._jit_grads = jax.jit(self._grads_impl)
        # incremented inside the impls, i.e. only when jax (re)traces them —
        # regression-tested so the greedy path can't silently start
        # re-tracing per call again
        self.trace_counts = {"sample": 0, "greedy": 0, "epoch": 0, "step": 0}
        self.last_minibatch_count = 0

    # -- featurization ----------------------------------------------------
    def feats(self, sites):
        # the legacy (fused=False) reference path recomputes features every
        # call, matching the original implementation
        ctx, mask = emb.featurize_batch(sites, cache=self.fused)
        vs = np.array([self.space.valid_sizes(s.kind) for s in sites],
                      np.int32)
        return jnp.asarray(ctx), jnp.asarray(mask), jnp.asarray(vs)

    # -- acting -----------------------------------------------------------
    def _sample_impl(self, params, key, ctx, mask, vs):
        self.trace_counts["sample"] += 1
        out, v = policy_forward(params, self.nv, self.head_sizes, ctx, mask,
                                vs, self.mode, fast_embed=self.fused)
        if self.mode in ("discrete", "two_agents"):
            a, logp, _ = sample_discrete(key, out)
            return a, a.astype(jnp.float32), logp, v
        raw, logp, _ = sample_continuous(key, out, vs, self.mode)
        a = _cont_decode(self.nv, self.head_sizes, raw, vs, self.mode)
        return a, raw, logp, v

    def _greedy_impl(self, params, ctx, mask, vs):
        self.trace_counts["greedy"] += 1
        out, _ = policy_forward(params, self.nv, self.head_sizes, ctx, mask,
                                vs, self.mode, fast_embed=self.fused)
        if self.mode in ("discrete", "two_agents"):
            return jnp.stack([lg.argmax(-1) for lg in out], -1)
        n = 1 if self.mode == "cont1" else 3
        return _cont_decode(self.nv, self.head_sizes, out[:, :n], vs,
                            self.mode)

    def sample_actions(self, sites, feats=None):
        """Stochastic draw for the PPO update: (actions, raw, logp, value)
        as numpy arrays.  ``act(sample=True)`` is this minus the
        training-only extras."""
        ctx, mask, vs = feats if feats is not None else self.feats(sites)
        self._key, k = jax.random.split(self._key)
        a, raw, logp, v = self._jit_sample(self.params, k, ctx, mask, vs)
        return (np.asarray(a), np.asarray(raw), np.asarray(logp),
                np.asarray(v))

    def act(self, sites, *, sample: bool = False, feats=None) -> np.ndarray:
        """(n, 3) action indices (Agent protocol).  ``sample=False`` is the
        deterministic greedy deployment mode (paper §4.2, jit cached
        across calls); ``sample=True`` draws from the policy."""
        if sample:
            return self.sample_actions(sites, feats=feats)[0]
        ctx, mask, vs = feats if feats is not None else self.feats(sites)
        return np.asarray(self._jit_greedy(self.params, ctx, mask, vs))

    def act_bucketed(self, sites, *, bucket: Optional[int] = None,
                     feats=None) -> np.ndarray:
        """Greedy ``act`` with the batch dim padded up to ``bucket`` rows
        (repeating row 0) so serving-path batches of varying size share one
        jit specialization per bucket instead of retracing per batch shape.
        Per-row results are bitwise equal to :meth:`act` — the forward is
        row-independent (regression-tested in ``tests/test_serving.py``)."""
        n = len(sites)
        ctx, mask, vs = feats if feats is not None else self.feats(sites)
        if bucket is not None and bucket > n:
            pad = [(0, bucket - n)] + [(0, 0)] * (ctx.ndim - 1)
            ctx = jnp.pad(ctx, pad, mode="edge")
            mask = jnp.pad(mask, [(0, bucket - n)] + [(0, 0)]
                           * (mask.ndim - 1), mode="edge")
            vs = jnp.pad(vs, [(0, bucket - n), (0, 0)], mode="edge")
        return np.asarray(self._jit_greedy(self.params, ctx, mask, vs))[:n]

    # -- PPO update ---------------------------------------------------------
    def _loss_fn(self, p, ctx, mask, vs, actions, raw, old_logp, rewards):
        out, v = policy_forward(p, self.nv, self.head_sizes, ctx, mask,
                                vs, self.mode, fast_embed=self.fused)
        if self.mode in ("discrete", "two_agents"):
            logp, ent = logp_discrete(out, actions)
        else:
            logp, ent = logp_continuous(out, raw, self.mode,
                                        len(self.head_sizes))
        adv = rewards - jax.lax.stop_gradient(v)
        adv = (adv - adv.mean()) / (adv.std() + 1e-6)
        ratio = jnp.exp(logp - old_logp)
        clipped = jnp.clip(ratio, 1 - self.nv.clip, 1 + self.nv.clip)
        pg = -jnp.minimum(ratio * adv, clipped * adv).mean()
        vloss = ((v - rewards) ** 2).mean()
        loss = (pg + self.nv.value_coef * vloss
                - self.nv.entropy_coef * ent.mean())
        return loss, (pg, vloss)

    def _grads_impl(self, params, ctx, mask, vs, actions, raw, old_logp,
                    rewards):
        """Legacy: loss+grads only; Adam runs un-jitted outside."""
        (loss, _), grads = jax.value_and_grad(
            self._loss_fn, has_aux=True)(params, ctx, mask, vs, actions,
                                         raw, old_logp, rewards)
        return loss, grads

    def _step_impl(self, params, opt, ctx, mask, vs, actions, raw, old_logp,
                   rewards):
        """One fused minibatch step: grads + Adam move inside the jit."""
        self.trace_counts["step"] += 1
        (loss, _), grads = jax.value_and_grad(
            self._loss_fn, has_aux=True)(params, ctx, mask, vs, actions,
                                         raw, old_logp, rewards)
        params, opt = adam_update(params, grads, opt, self._lr)
        return params, opt, loss

    def _epoch_impl(self, params, opt, ctx, mask, vs, actions, raw,
                    old_logp, rewards, idx_mat):
        """A stack of minibatches via lax.scan — a single device dispatch.
        ``idx_mat``: (n_minibatches, mb) int indices.  Minibatch rows are
        gathered once up front (one fused gather instead of a dynamic
        gather per scan step) and the scan is moderately unrolled — both
        are significant wins on the XLA CPU backend."""
        self.trace_counts["epoch"] += 1
        data = (ctx[idx_mat], mask[idx_mat], vs[idx_mat], actions[idx_mat],
                raw[idx_mat], old_logp[idx_mat], rewards[idx_mat])

        def body(carry, xs):
            params, opt = carry
            (loss, _), grads = jax.value_and_grad(
                self._loss_fn, has_aux=True)(params, *xs)
            params, opt = adam_update(params, grads, opt, self._lr)
            return (params, opt), loss

        (params, opt), losses = jax.lax.scan(
            body, (params, opt), data,
            unroll=min(4, int(idx_mat.shape[0])))
        return params, opt, losses

    def update(self, sites, actions, raw, old_logp, rewards, feats=None):
        ctx, mask, vs = feats if feats is not None else self.feats(sites)
        actions = jnp.asarray(actions)
        raw = jnp.asarray(raw)
        old_logp = jnp.asarray(old_logp)
        rewards = jnp.asarray(rewards, jnp.float32)
        n = len(sites)
        mb = min(self.nv.sgd_minibatch, n)
        if not self.fused:
            return self._update_legacy(ctx, mask, vs, actions, raw,
                                       old_logp, rewards, n, mb)
        n_full, rem = divmod(n, mb)
        losses = []
        self.last_minibatch_count = 0
        params, opt = self.params, self.opt
        if rem == 0:
            # no tail: every epoch is full minibatches, so the whole update
            # (all epochs x minibatches, epoch-major order) is ONE device
            # dispatch — a single lax.scan over the stacked permutations
            keys = jax.random.split(self._key, self.nv.ppo_epochs + 1)
            self._key = keys[0]
            idx_mat = jnp.concatenate(
                [jax.random.permutation(k, n).reshape(n_full, mb)
                 for k in keys[1:]])
            params, opt, ls = self._jit_epoch(
                params, opt, ctx, mask, vs, actions, raw, old_logp, rewards,
                idx_mat)
            losses.append(ls)
            self.last_minibatch_count = self.nv.ppo_epochs * n_full
        else:
            for _ in range(self.nv.ppo_epochs):
                self._key, k = jax.random.split(self._key)
                perm = jax.random.permutation(k, n)
                idx_mat = perm[:n_full * mb].reshape(n_full, mb)
                params, opt, ls = self._jit_epoch(
                    params, opt, ctx, mask, vs, actions, raw, old_logp,
                    rewards, idx_mat)
                losses.append(ls)
                self.last_minibatch_count += n_full
                # the tail minibatch: the remainder samples are trained on
                # too (the legacy path silently dropped them)
                sl = perm[n_full * mb:]
                params, opt, loss = self._jit_step(
                    params, opt, ctx[sl], mask[sl], vs[sl], actions[sl],
                    raw[sl], old_logp[sl], rewards[sl])
                losses.append(loss[None])
                self.last_minibatch_count += 1
        self.params, self.opt = params, opt
        # returned lazily (0-d jax array): jax's async dispatch then overlaps
        # this update's device work with the next batch's host-side
        # featurization/rewards; callers needing a float can float() it
        return jnp.mean(jnp.concatenate(losses))

    def _update_legacy(self, ctx, mask, vs, actions, raw, old_logp, rewards,
                       n, mb):
        """The original (seed) update loop: jitted grads, Python-side Adam,
        tail minibatch dropped.  Reference path for ``benchmarks/bench_env``."""
        losses = []
        self.last_minibatch_count = 0
        for _ in range(self.nv.ppo_epochs):
            self._key, k = jax.random.split(self._key)
            perm = np.asarray(jax.random.permutation(k, n))
            for i in range(0, n - mb + 1, mb):
                sl = perm[i:i + mb]
                loss, grads = self._jit_grads(
                    self.params, ctx[sl], mask[sl], vs[sl], actions[sl],
                    raw[sl], old_logp[sl], rewards[sl])
                self.params, self.opt = adam_update(
                    self.params, grads, self.opt, self._lr)
                losses.append(float(loss))
                self.last_minibatch_count += 1
        return float(np.mean(losses))

    # -- Agent protocol: fit == the RL training loop ------------------------
    def fit(self, sites, oracle, *, total_steps: Optional[int] = None,
            batch: Optional[int] = None, log_every: int = 1,
            rng_seed: int = 0) -> "PPOAgent":
        """Train the bandit against ``oracle`` (any Oracle — cost-model or
        measured).  Default budget: 10 training batches."""
        self.train(sites, oracle,
                   total_steps=total_steps or 10 * self.nv.train_batch,
                   batch=batch, log_every=log_every, rng_seed=rng_seed)
        return self

    # -- training loop (contextual bandit) ---------------------------------
    def train(self, sites, env: CostModelEnv, total_steps: int,
              batch: Optional[int] = None, log_every: int = 1,
              rng_seed: int = 0):
        batch = batch or self.nv.train_batch
        rng = np.random.default_rng(rng_seed)
        steps = 0
        first = len(self.history)
        while steps < total_steps:
            idx = rng.integers(0, len(sites), size=min(batch,
                                                       total_steps - steps))
            batch_sites = [sites[i] for i in idx]
            feats = self.feats(batch_sites)       # featurize once per batch
            if self.fused:
                # keep raw/logp on device (only the actions need numpy for
                # the env); with the lazy update loss this lets the host
                # featurize/reward the next batch while XLA still runs the
                # previous update
                self._key, k = jax.random.split(self._key)
                a, raw, logp, v = self._jit_sample(self.params, k, *feats)
                a = np.asarray(a)
            else:
                a, raw, logp, v = self.sample_actions(batch_sites,
                                                      feats=feats)
            rewards = env.rewards_batch(batch_sites, a)
            loss = self.update(batch_sites, a, raw, logp, rewards,
                               feats=feats)
            steps += len(batch_sites)
            self.history.append({"steps": steps,
                                 "reward_mean": float(rewards.mean()),
                                 "loss": loss})
        for h in self.history[first:]:            # one sync at the end
            h["loss"] = float(h["loss"])
        return self.history

    # -- persistence (Agent protocol) ---------------------------------------
    def state_dict(self) -> dict:
        """Policy + value params, the Adam state, and the sampling key —
        the full trained artifact (paper §4: train once, deploy greedy)."""
        return {"version": AGENT_STATE_VERSION, "name": self.name,
                "mode": self.mode, "lr": float(self._lr),
                "params": jax.tree.map(np.asarray, self.params),
                "opt": jax.tree.map(np.asarray, self.opt),
                "rng_key": np.asarray(self._key)}

    def load_state(self, state: dict) -> "PPOAgent":
        check_agent_state(state, self.name)
        if state["mode"] != self.mode:
            raise ValueError(f"state was trained in mode {state['mode']!r}; "
                             f"this agent is {self.mode!r} — construct with "
                             f"make_agent('ppo', cfg, mode=...) to match")
        # restore into the existing pytree structure: shapes must agree
        # (same cfg/head_sizes), values are taken verbatim from the state
        for attr in ("params", "opt"):
            have = jax.tree_util.tree_leaves(getattr(self, attr))
            new = jax.tree_util.tree_leaves(state[attr])
            if len(have) != len(new) or any(
                    tuple(np.shape(a)) != tuple(np.shape(b))
                    for a, b in zip(have, new)):
                raise ValueError(f"{attr} structure mismatch: the state was "
                                 f"saved under a different config/network")
        self.params = jax.tree.map(jnp.asarray, state["params"])
        self.opt = jax.tree.map(jnp.asarray, state["opt"])
        self._key = jnp.asarray(state["rng_key"], jnp.uint32)
        self._lr = float(state["lr"])
        return self

    # -- embedding for downstream supervised methods (paper §3.5) ----------
    def code_vectors(self, sites) -> np.ndarray:
        ctx, mask, _ = self.feats(sites)
        return np.asarray(emb.embed_sites(self.params["embedder"], ctx,
                                          mask))
