"""Brute-force search over the full factor space (the paper's oracle and
the label source for the supervised methods, §3.5).

The search is a single argmin over the vectorized cost tensor from
:mod:`repro.core.costmodel_vec` — no interpreted factor-product walk.  Flat
action order matches the old ``itertools.product`` enumeration, so argmin
tie-breaking is identical to the scalar implementation.
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np

from repro.core import costmodel_vec
from repro.core.env import CostModelEnv
from repro.models.compute import KernelSite


def brute_force_action(env: CostModelEnv, site: KernelSite
                       ) -> Tuple[Tuple[int, int, int], float]:
    """Exhaustive argmin of cost.  Returns (action_indices, best_cost);
    best_cost is ``inf`` when every tile is VMEM-illegal."""
    grid = costmodel_vec.cost_grid_kind(env.space, [site], site.kind)[0]
    flat = int(np.argmin(grid))
    return env.space.unflatten(site.kind, flat), float(grid[flat])


def brute_force_labels(env: CostModelEnv, sites: List[KernelSite]
                       ) -> np.ndarray:
    """(n_sites, 3) optimal action indices — brute-force labels.

    One vectorized cost-grid evaluation + argmin per site kind."""
    out = np.zeros((len(sites), 3), np.int32)
    for kind, idx in costmodel_vec.group_by_kind(sites).items():
        grid = costmodel_vec.cost_grid_kind(
            env.space, [sites[i] for i in idx], kind)
        out[idx] = env.space.unflatten_batch(kind, grid.argmin(1))
    return out


def brute_force_costs(env: CostModelEnv, sites: List[KernelSite]
                      ) -> np.ndarray:
    """(n_sites,) best achievable cost per site (the oracle's runtime)."""
    out = np.empty((len(sites),), np.float64)
    for kind, idx in costmodel_vec.group_by_kind(sites).items():
        grid = costmodel_vec.cost_grid_kind(
            env.space, [sites[i] for i in idx], kind)
        out[idx] = grid.min(1)
    return out


def n_evaluations(env: CostModelEnv, sites) -> int:
    """How many compile+run evaluations brute force costs (the paper's
    35x-more-samples claim)."""
    return sum(env.space.n_actions(s.kind) for s in sites)
