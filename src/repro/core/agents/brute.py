"""Brute-force search over the full factor space (the paper's oracle and
the label source for the supervised methods, §3.5).

The search is a single argmin over the oracle's ``cost_grid`` tensor — no
interpreted factor-product walk, and no assumption about *which* oracle:
the analytic :class:`~repro.core.env.CostModelEnv` and the hardware-backed
:class:`~repro.core.env.MeasuredEnv` expose the same grid, so brute force
exhaustively measures real kernels on TPU with the identical code path.
Flat action order matches the old ``itertools.product`` enumeration, so
argmin tie-breaking is identical to the scalar implementation.
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np

from repro.core import costmodel_vec
from repro.models.compute import KernelSite


def brute_force_action(oracle, site: KernelSite
                       ) -> Tuple[Tuple[int, int, int], float]:
    """Exhaustive argmin of cost.  Returns (action_indices, best_cost);
    best_cost is ``inf`` when every tile is VMEM-illegal."""
    grid = oracle.cost_grid([site])[0]
    flat = int(np.argmin(grid))
    return oracle.space.unflatten(site.kind, flat), float(grid[flat])


def brute_force_labels(oracle, sites: List[KernelSite]) -> np.ndarray:
    """(n_sites, 3) optimal action indices — brute-force labels.

    One ``cost_grid`` evaluation + argmin per site kind."""
    out = np.zeros((len(sites), 3), np.int32)
    if not len(sites):
        return out
    # row argmin over the padded grid IS the flat action (padding columns
    # are inf and never win) — no per-kind sub-grid copies
    flat = oracle.cost_grid(sites).argmin(1)
    for kind, idx in costmodel_vec.group_by_kind(sites).items():
        out[idx] = oracle.space.unflatten_batch(kind, flat[idx])
    return out


def brute_force_costs(oracle, sites: List[KernelSite]) -> np.ndarray:
    """(n_sites,) best achievable cost per site (the oracle's runtime)."""
    if not len(sites):
        return np.zeros((0,), np.float64)
    return oracle.cost_grid(sites).min(1)


def n_evaluations(oracle, sites) -> int:
    """How many compile+run evaluations brute force costs (the paper's
    35x-more-samples claim)."""
    return sum(oracle.space.n_actions(s.kind) for s in sites)


class BruteForceAgent:
    """The exhaustive-search method behind the Agent protocol.

    ``fit`` just captures the oracle (brute force has nothing to learn);
    ``act`` is the cost-grid argmin.  Constructed lazily against a
    cost-model oracle when none is supplied, so
    ``make_agent("brute", cfg)`` works standalone."""

    name = "brute"

    def __init__(self, cfg=None, oracle=None):
        self._cfg = cfg
        self.oracle = oracle

    def _ensure_oracle(self):
        if self.oracle is None:
            from repro.configs.neurovec import DEFAULT
            from repro.core.env import CostModelEnv
            self.oracle = CostModelEnv(self._cfg or DEFAULT)
        return self.oracle

    def fit(self, sites, oracle, **_) -> "BruteForceAgent":
        self.oracle = oracle
        return self

    def state_dict(self) -> dict:
        """Versioned empty state: the search has nothing learned to
        persist.  The captured oracle is a live object — a loading
        facade re-binds its own oracle (``NeuroVectorizer.load``)."""
        from repro.core.protocols import AGENT_STATE_VERSION
        return {"version": AGENT_STATE_VERSION, "name": self.name}

    def load_state(self, state: dict) -> "BruteForceAgent":
        from repro.core.protocols import check_agent_state
        check_agent_state(state, self.name)
        return self

    def act(self, sites, *, sample: bool = False) -> np.ndarray:
        return brute_force_labels(self._ensure_oracle(),
                                  sites).astype(np.int64)
