"""Brute-force search over the full factor space (the paper's oracle and
the label source for the supervised methods, §3.5)."""
from __future__ import annotations

import itertools
from typing import Dict, List, Tuple

import numpy as np

from repro.core.env import CostModelEnv
from repro.models.compute import KernelSite


def brute_force_action(env: CostModelEnv, site: KernelSite
                       ) -> Tuple[Tuple[int, int, int], float]:
    """Exhaustive argmin of cost.  Returns (action_indices, best_cost)."""
    sizes = env.space.valid_sizes(site.kind)
    best_a, best_c = (0, 0, 0), float("inf")
    for a in itertools.product(*(range(s) for s in sizes)):
        c = env.cost(site, a)
        if c is not None and c < best_c:
            best_a, best_c = a, c
    return best_a, best_c


def brute_force_labels(env: CostModelEnv, sites: List[KernelSite]
                       ) -> np.ndarray:
    """(n_sites, 3) optimal action indices — brute-force labels."""
    return np.array([brute_force_action(env, s)[0] for s in sites],
                    np.int32)


def n_evaluations(env: CostModelEnv, sites) -> int:
    """How many compile+run evaluations brute force costs (the paper's
    35x-more-samples claim)."""
    return sum(env.space.n_actions(s.kind) for s in sites)
