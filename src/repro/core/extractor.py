"""Kernel-site extraction — the paper's "automatic loop extractor" (§3).

Traces a model's step functions abstractly (``jax.eval_shape`` — no compute,
no allocation) with the :class:`SiteRecorder` installed; every tunable op the
model executes registers its concrete shapes/dtypes.  The output feeds the
code-embedding generator exactly as extracted loop bodies feed code2vec.
"""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, get_config
from repro.models import compute
from repro.models.lm import build_model


def _abstract_batch(cfg: ModelConfig, batch: int, seq: int):
    sds = jax.ShapeDtypeStruct
    b = {"tokens": sds((batch, seq), jnp.int32),
         "targets": sds((batch, seq), jnp.int32)}
    if cfg.frontend == "vision":
        n = cfg.n_frontend_tokens
        b["tokens"] = sds((batch, seq - n), jnp.int32)
        b["targets"] = sds((batch, seq - n), jnp.int32)
        b["frontend_embeds"] = sds((batch, n, cfg.d_model), jnp.float32)
    if cfg.enc_dec:
        b["src_embeds"] = sds((batch, seq, cfg.d_model), jnp.float32)
    return b


def extract_sites(fn, *abstract_args) -> List[compute.KernelSite]:
    """Trace ``fn`` over ShapeDtypeStructs, collecting kernel sites."""
    rec = compute.SiteRecorder()
    with compute.compute_mode("xla", recorder=rec):
        jax.eval_shape(fn, *abstract_args)
    return rec.unique_sites()


def extract_arch_sites(arch: str, batch: int = 8,
                       seq: int = 2048) -> List[compute.KernelSite]:
    """All tunable sites in one training step of an assigned architecture."""
    cfg = get_config(arch)
    model = build_model(cfg)
    params_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    batch_specs = _abstract_batch(cfg, batch, seq)

    def loss_fn(params, b):
        return model.train_loss(params, b)[0]

    return extract_sites(loss_fn, params_shapes, batch_specs)
