"""Length-prefixed JSON framing — the wire format of the worker-pool
pipe protocol and the :mod:`repro.fleet` socket protocol.

Every frame is ``len(payload)`` as a 4-byte big-endian prefix followed by
the UTF-8 JSON payload.  Shared by :mod:`repro.measure.pool` (parent
side), :mod:`repro.measure.worker` (child side), and the fleet
client/servers — kept free of heavy imports so the worker entrypoint
stays cheap to load.

A frame payload is capped at :data:`MAX_FRAME_BYTES`: a torn or garbage
header decodes to an arbitrary 32-bit length (``b"garb"`` ≈ 1.7 GB), and
without the cap a reader would attempt that allocation before noticing
the stream is ruined.  Oversize prefixes raise ``ValueError`` — the same
exception class readers already treat as a poisoned-connection signal.
"""
from __future__ import annotations

import json
import struct

_LEN = struct.Struct(">I")

#: Hard ceiling on a single frame's JSON payload.  Far above any real
#: message (jobs/results are < 1 KB; a full artifact-store sync of ~1e5
#: records is a few MB) yet small enough that a garbage length prefix is
#: rejected instead of driving a multi-GB read.
MAX_FRAME_BYTES = 64 * 1024 * 1024


def read_frame(stream, max_bytes: int = MAX_FRAME_BYTES) -> "dict | None":
    """One length-prefixed JSON frame; ``None`` on clean EOF.

    Raises ``EOFError`` on a truncated header/payload and ``ValueError``
    on a length prefix beyond ``max_bytes`` (garbage/torn header) or a
    payload that is not valid UTF-8 JSON.
    """
    head = stream.read(_LEN.size)
    if not head:
        return None
    if len(head) < _LEN.size:
        raise EOFError("truncated frame header")
    (n,) = _LEN.unpack(head)
    if n > max_bytes:
        raise ValueError(
            f"frame length {n} exceeds cap {max_bytes} — garbage or torn "
            f"header")
    payload = stream.read(n)
    if len(payload) < n:
        raise EOFError("truncated frame payload")
    try:
        return json.loads(payload.decode("utf-8"))
    except UnicodeDecodeError as e:  # surface as the poisoned-stream class
        raise ValueError(f"frame payload is not UTF-8: {e}") from e


def write_frame(stream, msg: dict, max_bytes: int = MAX_FRAME_BYTES) -> None:
    payload = json.dumps(msg).encode("utf-8")
    if len(payload) > max_bytes:
        raise ValueError(
            f"refusing to write a {len(payload)}-byte frame (cap "
            f"{max_bytes})")
    stream.write(_LEN.pack(len(payload)) + payload)
    stream.flush()
