"""Length-prefixed JSON framing — the wire format of the worker-pool
pipe protocol (and the seam a future socket transport reuses).

Every frame is ``len(payload)`` as a 4-byte big-endian prefix followed by
the UTF-8 JSON payload.  Shared by :mod:`repro.measure.pool` (parent
side) and :mod:`repro.measure.worker` (child side) — kept free of heavy
imports so the worker entrypoint stays cheap to load.
"""
from __future__ import annotations

import json
import struct

_LEN = struct.Struct(">I")


def read_frame(stream) -> "dict | None":
    """One length-prefixed JSON frame; ``None`` on clean EOF."""
    head = stream.read(_LEN.size)
    if not head:
        return None
    if len(head) < _LEN.size:
        raise EOFError("truncated frame header")
    (n,) = _LEN.unpack(head)
    payload = stream.read(n)
    if len(payload) < n:
        raise EOFError("truncated frame payload")
    return json.loads(payload.decode("utf-8"))


def write_frame(stream, msg: dict) -> None:
    payload = json.dumps(msg).encode("utf-8")
    stream.write(_LEN.pack(len(payload)) + payload)
    stream.flush()
