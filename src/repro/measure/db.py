"""Persistent measurement database — timings survive the process.

Autotuning repeatedly prices the same ``(site, tile)`` pairs: PPO resamples
them across epochs, brute force sweeps the full grid, and every re-run of a
tuning job starts from zero.  :class:`~repro.core.env.MeasuredEnv` already
deduplicates *within* a process; this module is the layer below it —
an append-only JSON-lines store keyed by
``(site.key(), tiles, backend_key)`` where ``backend_key`` fingerprints
the measurement conditions (backend, device kind, interpret caps, jax
version), so a cache entry is only ever served back under the conditions
that produced it.  A second autotune run against the same DB path performs
zero kernel timings (proven by ``benchmarks/bench_measure.py``).

Robustness: lines that fail to parse (truncated writes, manual edits) are
skipped and counted, never fatal — the DB degrades to re-measuring.
A torn *trailing* line (crash mid-append leaves no newline) is isolated
on the next open: the first append starts on a fresh line, so one torn
record never corrupts the record written after it.  Failed measurements
are stored as ``null`` (strict JSON) and round-trip back to ``inf``, so
known-bad tiles are not re-timed either.

Quarantine records (PR 6) are the poison-job ledger: a ``(site, tiles)``
pair that repeatedly kills or wedges measurement workers is recorded via
:meth:`MeasureDB.quarantine` with its attempt count and a reason.  A
quarantined key reads back as ``inf`` (fail-closed, exactly like a
kernel that cannot build) in *every* process that opens the DB, so no
future run ever re-attempts it; :meth:`MeasureDB.quarantined` exposes
the forensic record.

Execution moved behind the transport layer in PR 4:
:class:`~repro.measure.transport.CachedMeasureFn` (still importable from
here) composes a runner with a DB into the batched ``measure_fn`` hook via
:class:`~repro.measure.transport.InProcessTransport`; the
:class:`~repro.measure.pool.WorkerPoolTransport` streams subprocess-pool
results into the same store.
"""
from __future__ import annotations

import json
import os
from collections import OrderedDict
from typing import Iterator, NamedTuple, Optional

import numpy as np


def make_key(site_key: str, tiles, backend: str) -> str:
    t = tuple(int(x) for x in tiles)
    return f"{site_key}|{t[0]}x{t[1]}x{t[2]}|{backend}"


class MeasureRecord(NamedTuple):
    """One resolved measurement from :meth:`MeasureDB.iter_records`."""
    key: str            # full DB key: "site_key|t0xt1xt2|backend"
    kind: str           # site kind parsed from the key ("matmul", ...)
    value: float        # measured seconds; inf for failed measurements
    fingerprint: str    # backend fingerprint component of the key


class MeasureDB:
    """Append-only JSONL timing store with an in-process LRU on top.

    ``max_entries`` bounds the in-memory map only (LRU eviction); the
    on-disk log keeps everything and duplicate keys resolve last-wins on
    load, so an evicted-then-remeasured pair stays consistent.
    """

    def __init__(self, path: str, max_entries: Optional[int] = None):
        self.path = path
        self.max_entries = max_entries
        self._mem: "OrderedDict[str, float]" = OrderedDict()
        self._quarantined: dict = {}    # key -> {"attempts", "reason"}
        self.skipped_lines = 0          # corrupt/garbage lines ignored
        self._torn_tail = False         # file ends mid-record (no newline)
        self._fh = None
        self._load()

    # -- persistence ---------------------------------------------------------
    def _load(self) -> None:
        if not os.path.exists(self.path):
            return
        with open(self.path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    rec = json.loads(line)
                    key = rec["k"]
                    val = float("inf") if rec["v"] is None else float(rec["v"])
                except (ValueError, KeyError, TypeError):
                    self.skipped_lines += 1
                    continue
                if rec.get("kind") == "quarantine":
                    self._quarantined[key] = {
                        "attempts": int(rec.get("attempts", 0)),
                        "reason": str(rec.get("reason", ""))}
                self._remember(key, val)
        # a crash mid-append leaves the final line unterminated; the line
        # itself was skipped above — remember to start the next append on
        # a fresh line so the torn bytes cannot corrupt a later record
        try:
            with open(self.path, "rb") as fb:
                fb.seek(-1, os.SEEK_END)
                self._torn_tail = fb.read(1) != b"\n"
        except OSError:                 # empty file: nothing to isolate
            self._torn_tail = False

    def _remember(self, key: str, val: float) -> None:
        self._mem[key] = val
        self._mem.move_to_end(key)
        if self.max_entries is not None:
            while len(self._mem) > self.max_entries:
                self._mem.popitem(last=False)

    def _append(self, rec: dict) -> None:
        if self._fh is None:
            os.makedirs(os.path.dirname(os.path.abspath(self.path)),
                        exist_ok=True)
            self._fh = open(self.path, "a")
            if self._torn_tail:
                self._fh.write("\n")    # isolate the torn trailing record
                self._torn_tail = False
        self._fh.write(json.dumps(rec) + "\n")
        self._fh.flush()

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    # -- mapping -------------------------------------------------------------
    def get(self, key: str) -> Optional[float]:
        v = self._mem.get(key)
        if v is not None:
            self._mem.move_to_end(key)
        elif key in self._quarantined:
            return float("inf")         # quarantine survives LRU eviction
        return v

    def put(self, key: str, val: float) -> None:
        self._append({"k": key, "v": None if not np.isfinite(val) else val})
        self._remember(key, val)

    # -- poison-job quarantine ----------------------------------------------
    def quarantine(self, key: str, attempts: int, reason: str) -> None:
        """Persist ``key`` as poisoned: it reads back ``inf`` (fail-closed)
        in every process that opens this DB, with the attempt count and
        reason kept for forensics.  Older readers see a plain failed
        measurement (``v: null`` → ``inf``) — the record stays
        backward-compatible."""
        info = {"attempts": int(attempts), "reason": str(reason)}
        self._append({"k": key, "v": None, "kind": "quarantine", **info})
        self._quarantined[key] = info
        self._remember(key, float("inf"))

    def quarantined(self, key: str) -> Optional[dict]:
        """The quarantine record for ``key`` — ``{"attempts", "reason"}``
        — or ``None`` if the key is not poisoned."""
        return self._quarantined.get(key)

    @property
    def n_quarantined(self) -> int:
        return len(self._quarantined)

    # -- iteration -----------------------------------------------------------
    def iter_records(self) -> Iterator[MeasureRecord]:
        """Iterate every resolved measurement in the on-disk log.

        Streams the file (so entries evicted from the in-memory LRU are
        still seen), resolving duplicate keys last-wins exactly like
        :meth:`_load`.  Quarantined and corrupt/unparseable entries are
        skipped — this is the training-corpus surface for
        ``repro.surrogate``, and poisoned or torn records are not data.
        Keys that do not have the ``site|t0xt1xt2|backend`` shape are
        skipped too (future record kinds stay non-fatal).
        """
        if self._fh is not None:
            self._fh.flush()            # records put() after open
        if not os.path.exists(self.path):
            return
        resolved: "OrderedDict[str, Optional[float]]" = OrderedDict()
        with open(self.path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    rec = json.loads(line)
                    key = rec["k"]
                    val = float("inf") if rec["v"] is None else float(rec["v"])
                except (ValueError, KeyError, TypeError):
                    continue            # counted at load time; not data
                if rec.get("kind") == "quarantine":
                    resolved[key] = None        # poisoned: excluded
                else:
                    resolved[key] = val
        for key, val in resolved.items():
            if val is None:
                continue
            parts = key.split("|")
            if len(parts) != 3:
                continue
            site_key, _, backend = parts
            kind = site_key.split(":", 1)[0]
            yield MeasureRecord(key=key, kind=kind, value=val,
                                fingerprint=backend)

    def __len__(self) -> int:
        return len(self._mem)

    def __contains__(self, key: str) -> bool:
        return key in self._mem


def open_measure_db(path: str, **kwargs):
    """:class:`MeasureDB` factory that understands fleet addresses.

    A ``fleet://host:port`` path opens a
    :class:`~repro.fleet.artifacts.RemoteMeasureDB` — a live,
    push-invalidated mirror of the shared ``serve-artifacts`` timing
    store — so every ``db_path=`` string in facade/service/serve can
    name a fleet service with zero caller changes.  Anything else is a
    local JSONL path."""
    if isinstance(path, str) and path.startswith("fleet://"):
        from repro.fleet import RemoteMeasureDB
        return RemoteMeasureDB(path)
    return MeasureDB(path, **kwargs)


def __getattr__(name):
    # CachedMeasureFn moved to repro.measure.transport (it is a shim over
    # InProcessTransport now); keep the historical import path working
    # without a module-level circular import
    if name == "CachedMeasureFn":
        from repro.measure.transport import CachedMeasureFn
        return CachedMeasureFn
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
