"""Persistent measurement database — timings survive the process.

Autotuning repeatedly prices the same ``(site, tile)`` pairs: PPO resamples
them across epochs, brute force sweeps the full grid, and every re-run of a
tuning job starts from zero.  :class:`~repro.core.env.MeasuredEnv` already
deduplicates *within* a process; this module is the layer below it —
an append-only JSON-lines store keyed by
``(site.key(), tiles, backend_key)`` where ``backend_key`` fingerprints
the measurement conditions (backend, device kind, interpret caps, jax
version), so a cache entry is only ever served back under the conditions
that produced it.  A second autotune run against the same DB path performs
zero kernel timings (proven by ``benchmarks/bench_measure.py``).

Robustness: lines that fail to parse (truncated writes, manual edits) are
skipped and counted, never fatal — the DB degrades to re-measuring.
Failed measurements are stored as ``null`` (strict JSON) and round-trip
back to ``inf``, so known-bad tiles are not re-timed either.

:class:`CachedMeasureFn` composes a :class:`~repro.measure.runner.
MeasureRunner` with a DB into the batched ``measure_fn`` hook the oracle
consumes, tracking hit/miss statistics for the benchmark report.
"""
from __future__ import annotations

import json
import os
from collections import OrderedDict
from typing import Optional, Sequence

import numpy as np


def make_key(site_key: str, tiles, backend: str) -> str:
    t = tuple(int(x) for x in tiles)
    return f"{site_key}|{t[0]}x{t[1]}x{t[2]}|{backend}"


class MeasureDB:
    """Append-only JSONL timing store with an in-process LRU on top.

    ``max_entries`` bounds the in-memory map only (LRU eviction); the
    on-disk log keeps everything and duplicate keys resolve last-wins on
    load, so an evicted-then-remeasured pair stays consistent.
    """

    def __init__(self, path: str, max_entries: Optional[int] = None):
        self.path = path
        self.max_entries = max_entries
        self._mem: "OrderedDict[str, float]" = OrderedDict()
        self.skipped_lines = 0          # corrupt/garbage lines ignored
        self._fh = None
        self._load()

    # -- persistence ---------------------------------------------------------
    def _load(self) -> None:
        if not os.path.exists(self.path):
            return
        with open(self.path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    rec = json.loads(line)
                    key = rec["k"]
                    val = float("inf") if rec["v"] is None else float(rec["v"])
                except (ValueError, KeyError, TypeError):
                    self.skipped_lines += 1
                    continue
                self._remember(key, val)

    def _remember(self, key: str, val: float) -> None:
        self._mem[key] = val
        self._mem.move_to_end(key)
        if self.max_entries is not None:
            while len(self._mem) > self.max_entries:
                self._mem.popitem(last=False)

    def _append(self, key: str, val: float) -> None:
        if self._fh is None:
            os.makedirs(os.path.dirname(os.path.abspath(self.path)),
                        exist_ok=True)
            self._fh = open(self.path, "a")
        rec = {"k": key, "v": None if not np.isfinite(val) else val}
        self._fh.write(json.dumps(rec) + "\n")
        self._fh.flush()

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    # -- mapping -------------------------------------------------------------
    def get(self, key: str) -> Optional[float]:
        v = self._mem.get(key)
        if v is not None:
            self._mem.move_to_end(key)
        return v

    def put(self, key: str, val: float) -> None:
        self._append(key, val)
        self._remember(key, val)

    def __len__(self) -> int:
        return len(self._mem)

    def __contains__(self, key: str) -> bool:
        return key in self._mem


class CachedMeasureFn:
    """DB-backed batched ``measure_fn``: time only what the DB lacks.

    ``runner`` is any batched ``(sites, tiles) -> (n,) seconds`` callable
    exposing ``backend_key`` (a :class:`MeasureRunner` in production, a
    counting spy in tests); ``db=None`` disables persistence but keeps the
    statistics, so callers can always report a hit rate.
    """

    def __init__(self, runner, db: Optional[MeasureDB] = None):
        self.runner = runner
        self.db = db
        self.hits = 0                   # pairs served from the DB
        self.misses = 0                 # pairs timed by the runner

    @property
    def hit_rate(self) -> float:
        n = self.hits + self.misses
        return self.hits / n if n else 0.0

    def __call__(self, sites: Sequence, tiles) -> np.ndarray:
        tiles = np.asarray(tiles, np.int64)
        backend = getattr(self.runner, "backend_key", "unknown")
        out = np.empty(len(sites), np.float64)
        miss = []
        for i, (s, t) in enumerate(zip(sites, tiles)):
            v = self.db.get(make_key(s.key(), t, backend)) \
                if self.db is not None else None
            if v is None:
                miss.append(i)
            else:
                out[i] = v
                self.hits += 1
        if miss:
            vals = np.asarray(self.runner([sites[i] for i in miss],
                                          tiles[miss]), np.float64)
            for i, v in zip(miss, vals):
                if self.db is not None:
                    self.db.put(make_key(sites[i].key(), tiles[i], backend),
                                float(v))
                out[i] = v
            self.misses += len(miss)
        return out
