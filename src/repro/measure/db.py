"""Persistent measurement database — timings survive the process.

Autotuning repeatedly prices the same ``(site, tile)`` pairs: PPO resamples
them across epochs, brute force sweeps the full grid, and every re-run of a
tuning job starts from zero.  :class:`~repro.core.env.MeasuredEnv` already
deduplicates *within* a process; this module is the layer below it —
an append-only JSON-lines store keyed by
``(site.key(), tiles, backend_key)`` where ``backend_key`` fingerprints
the measurement conditions (backend, device kind, interpret caps, jax
version), so a cache entry is only ever served back under the conditions
that produced it.  A second autotune run against the same DB path performs
zero kernel timings (proven by ``benchmarks/bench_measure.py``).

Robustness: lines that fail to parse (truncated writes, manual edits) are
skipped and counted, never fatal — the DB degrades to re-measuring.
Failed measurements are stored as ``null`` (strict JSON) and round-trip
back to ``inf``, so known-bad tiles are not re-timed either.

Execution moved behind the transport layer in PR 4:
:class:`~repro.measure.transport.CachedMeasureFn` (still importable from
here) composes a runner with a DB into the batched ``measure_fn`` hook via
:class:`~repro.measure.transport.InProcessTransport`; the
:class:`~repro.measure.pool.WorkerPoolTransport` streams subprocess-pool
results into the same store.
"""
from __future__ import annotations

import json
import os
from collections import OrderedDict
from typing import Optional

import numpy as np


def make_key(site_key: str, tiles, backend: str) -> str:
    t = tuple(int(x) for x in tiles)
    return f"{site_key}|{t[0]}x{t[1]}x{t[2]}|{backend}"


class MeasureDB:
    """Append-only JSONL timing store with an in-process LRU on top.

    ``max_entries`` bounds the in-memory map only (LRU eviction); the
    on-disk log keeps everything and duplicate keys resolve last-wins on
    load, so an evicted-then-remeasured pair stays consistent.
    """

    def __init__(self, path: str, max_entries: Optional[int] = None):
        self.path = path
        self.max_entries = max_entries
        self._mem: "OrderedDict[str, float]" = OrderedDict()
        self.skipped_lines = 0          # corrupt/garbage lines ignored
        self._fh = None
        self._load()

    # -- persistence ---------------------------------------------------------
    def _load(self) -> None:
        if not os.path.exists(self.path):
            return
        with open(self.path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    rec = json.loads(line)
                    key = rec["k"]
                    val = float("inf") if rec["v"] is None else float(rec["v"])
                except (ValueError, KeyError, TypeError):
                    self.skipped_lines += 1
                    continue
                self._remember(key, val)

    def _remember(self, key: str, val: float) -> None:
        self._mem[key] = val
        self._mem.move_to_end(key)
        if self.max_entries is not None:
            while len(self._mem) > self.max_entries:
                self._mem.popitem(last=False)

    def _append(self, key: str, val: float) -> None:
        if self._fh is None:
            os.makedirs(os.path.dirname(os.path.abspath(self.path)),
                        exist_ok=True)
            self._fh = open(self.path, "a")
        rec = {"k": key, "v": None if not np.isfinite(val) else val}
        self._fh.write(json.dumps(rec) + "\n")
        self._fh.flush()

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    # -- mapping -------------------------------------------------------------
    def get(self, key: str) -> Optional[float]:
        v = self._mem.get(key)
        if v is not None:
            self._mem.move_to_end(key)
        return v

    def put(self, key: str, val: float) -> None:
        self._append(key, val)
        self._remember(key, val)

    def __len__(self) -> int:
        return len(self._mem)

    def __contains__(self, key: str) -> bool:
        return key in self._mem


def __getattr__(name):
    # CachedMeasureFn moved to repro.measure.transport (it is a shim over
    # InProcessTransport now); keep the historical import path working
    # without a module-level circular import
    if name == "CachedMeasureFn":
        from repro.measure.transport import CachedMeasureFn
        return CachedMeasureFn
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
