"""``repro.measure`` — the hardware measurement subsystem.

Closes the paper's loop: the reward signal becomes *measured execution
time* of the compiled Pallas kernels (eq. 2) instead of the analytic
stand-in.  Three layers:

* :mod:`repro.measure.timing` — the one median-of-reps timing loop every
  consumer shares (runner + benchmarks).
* :mod:`repro.measure.runner` — :class:`MeasureRunner`, the batched
  compile-and-time ``measure_fn`` (real kernels on TPU/GPU, interpret-mode
  Pallas on CPU so CI runs the full loop; per-tile failures fail closed).
* :mod:`repro.measure.db` — :class:`MeasureDB`, the persistent JSONL
  timing store + :class:`CachedMeasureFn` gluing runner and DB into the
  oracle hook (repeat autotune runs re-time nothing).

:func:`make_measured_env` assembles the stack into a ready
:class:`~repro.core.env.MeasuredEnv` — what
``NeuroVectorizer(cfg, oracle="measured")`` constructs.
"""
from __future__ import annotations

from typing import Optional

from repro.measure.db import CachedMeasureFn, MeasureDB, make_key
from repro.measure.runner import (MeasureRunner, default_interpret,
                                  device_kind)
from repro.measure import timing

__all__ = ["MeasureRunner", "MeasureDB", "CachedMeasureFn", "make_key",
           "make_measured_env", "default_interpret", "device_kind",
           "timing"]


def make_measured_env(cfg=None, db_path: Optional[str] = None,
                      runner: Optional[MeasureRunner] = None,
                      seed: int = 0, **runner_kwargs):
    """A :class:`~repro.core.env.MeasuredEnv` wired to a real runner.

    ``db_path`` enables the persistent timing DB (a second run against the
    same path performs zero timings); extra kwargs construct the default
    :class:`MeasureRunner` (``reps=``, ``warmup=``, ``interpret=``,
    ``max_dim=``...).  The assembled hook is reachable as
    ``env.measure_fn`` (`.runner` / `.db` for stats and counters).
    """
    from repro.configs.neurovec import DEFAULT
    from repro.core.env import MeasuredEnv

    if runner is None:
        runner = MeasureRunner(**runner_kwargs)
    elif runner_kwargs:
        raise TypeError("pass either runner= or runner kwargs, not both")
    db = MeasureDB(db_path) if db_path else None
    return MeasuredEnv(cfg if cfg is not None else DEFAULT,
                       measure_fn=CachedMeasureFn(runner, db), seed=seed)
