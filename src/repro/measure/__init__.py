"""``repro.measure`` — the hardware measurement subsystem.

Closes the paper's loop: the reward signal becomes *measured execution
time* of the compiled Pallas kernels (eq. 2) instead of the analytic
stand-in.  Layered bottom-up:

* :mod:`repro.measure.timing` — the one median-of-reps timing loop every
  consumer shares (runner + benchmarks).
* :mod:`repro.measure.runner` — :class:`MeasureRunner`, the batched
  compile-and-time primitive (real kernels on TPU/GPU, interpret-mode
  Pallas on CPU so CI runs the full loop; per-tile failures fail closed).
* :mod:`repro.measure.db` — :class:`MeasureDB`, the persistent JSONL
  timing store (repeat autotune runs re-time nothing).
* :mod:`repro.measure.transport` / :mod:`repro.measure.pool` — *how*
  measurements execute, behind the asynchronous
  :class:`~repro.core.protocols.MeasureTransport` contract:
  :class:`InProcessTransport` (the single-process path) and
  :class:`WorkerPoolTransport` (fan out to N subprocess workers over a
  length-prefixed JSON pipe protocol, coalescing duplicates, requeuing on
  worker death).  :class:`TransportMeasureFn` adapts any transport into
  the synchronous batched ``measure_fn`` hook the oracle consumes;
  :class:`CachedMeasureFn` keeps the historical runner+DB spelling.

:func:`make_transport` builds a transport by name;
:func:`make_measured_env` assembles a stack into a ready
:class:`~repro.core.env.MeasuredEnv` — what
``NeuroVectorizer(cfg, oracle="measured", transport=...)`` constructs.

Reliability (PR 6): :mod:`repro.measure.faults` supplies deterministic
chaos machinery (:class:`FaultInjectionTransport`, :class:`ChaosRunner`,
:class:`FaultSchedule`) used to prove the transport contract under
crashes/hangs/torn frames; :func:`respawn_backoff` is the pool's
crash-loop backoff schedule.
"""
from __future__ import annotations

from typing import Optional, Union

from repro.measure.db import MeasureDB, make_key, open_measure_db
from repro.measure.faults import (ChaosRunner, FaultInjectionTransport,
                                  FaultSchedule)
from repro.measure.pool import WorkerPoolTransport, respawn_backoff
from repro.measure.runner import (MeasureRunner, default_interpret,
                                  device_kind)
from repro.measure.transport import (CachedMeasureFn, InProcessTransport,
                                     TransportMeasureFn)
from repro.measure import timing

TRANSPORT_NAMES = ("inproc", "pool", "socket")

__all__ = ["MeasureRunner", "MeasureDB", "CachedMeasureFn", "make_key",
           "open_measure_db",
           "InProcessTransport", "WorkerPoolTransport", "TransportMeasureFn",
           "TRANSPORT_NAMES", "make_transport", "make_measured_env",
           "resolve_surrogate",
           "default_interpret", "device_kind", "timing",
           "FaultInjectionTransport", "ChaosRunner", "FaultSchedule",
           "respawn_backoff"]


def make_transport(name: str = "inproc", *, db_path: Optional[str] = None,
                   db: Optional[MeasureDB] = None,
                   runner: Optional[MeasureRunner] = None,
                   workers: Optional[int] = None,
                   hosts=None, **runner_kwargs):
    """Build a :class:`~repro.core.protocols.MeasureTransport` by name.

    ``"inproc"`` — the calling process measures (``workers`` must be
    unset); ``"pool"`` — ``workers`` subprocess workers (default 2), each
    building its own :class:`MeasureRunner` from ``runner_kwargs``;
    ``"socket"`` — a :class:`~repro.fleet.transport.SocketTransport`
    fanning out to the remote ``serve-worker`` daemons named by
    ``hosts=["host:port", ...]`` (runner configuration lives on those
    hosts, not here).  ``db_path``/``db`` attach the persistent timing
    store either way — ``db_path="fleet://host:port"`` attaches the
    shared artifact service.
    """
    if db is not None and db_path is not None:
        raise TypeError("pass either db= or db_path=, not both")
    if db is None and db_path:
        db = open_measure_db(db_path)
    if hosts is not None and name != "socket":
        raise ValueError("hosts= applies only to transport='socket'")
    if name == "inproc":
        if workers is not None:
            raise ValueError("workers= applies only to transport='pool'")
        if runner is None:
            runner = MeasureRunner(**runner_kwargs)
        elif runner_kwargs:
            raise TypeError("pass either runner= or runner kwargs, not both")
        return InProcessTransport(runner, db)
    if name == "pool":
        if runner is not None:
            raise TypeError("transport='pool' builds one runner per worker "
                            "from runner kwargs; runner= cannot be shared "
                            "across processes")
        return WorkerPoolTransport(
            workers=workers if workers is not None else 2,
            db=db, runner_kwargs=runner_kwargs)
    if name == "socket":
        if not hosts:
            raise ValueError("transport='socket' needs hosts=['host:port', "
                             "...] naming the serve-worker daemons")
        if workers is not None:
            raise ValueError("workers= applies only to transport='pool' "
                             "(each serve-worker host sets its own pool "
                             "size)")
        if runner is not None or runner_kwargs:
            raise TypeError("transport='socket' measures on the "
                            "serve-worker hosts — runner configuration "
                            "(runner=, reps=, interpret=, ...) belongs "
                            "there, not on the client")
        from repro.fleet import SocketTransport
        return SocketTransport(hosts, db=db)
    raise ValueError(f"unknown transport {name!r}; "
                     f"registered: {', '.join(TRANSPORT_NAMES)}")


def make_measured_env(cfg=None, db_path: Optional[str] = None,
                      runner: Optional[MeasureRunner] = None,
                      seed: int = 0, transport: Union[str, object, None] = None,
                      workers: Optional[int] = None, hosts=None,
                      prune_topk: Optional[int] = None,
                      surrogate=None, **runner_kwargs):
    """A :class:`~repro.core.env.MeasuredEnv` wired to a real measurement
    stack.

    ``db_path`` enables the persistent timing DB (a second run against the
    same path performs zero timings; a ``fleet://host:port`` path
    attaches the shared artifact service); ``transport`` selects how
    timings execute — ``None``/``"inproc"`` (this process), ``"pool"``
    with ``workers=N`` (subprocess pool), ``"socket"`` with
    ``hosts=["host:port", ...]`` (remote serve-worker fleet), or a
    pre-built :class:`~repro.core.protocols.MeasureTransport`.  Extra
    kwargs
    construct the :class:`MeasureRunner` (``reps=``, ``warmup=``,
    ``interpret=``, ``max_dim=``...) — per worker under the pool.  The
    assembled hook is reachable as ``env.measure_fn``
    (``.transport`` / ``.db`` for stats and lifecycle; ``.runner`` on the
    in-process path).

    ``prune_topk=N`` enables surrogate grid pruning: only each site's
    top-N predicted candidates (plus the baseline tile) are submitted to
    the transport.  ``surrogate`` may be a trained
    :class:`~repro.surrogate.model.SurrogateModel`, a checkpoint
    directory path, or ``None`` — in which case one is trained from the
    attached DB's existing records; a DB too cold to train (fewer than
    ``repro.surrogate.model.train_from_db``'s ``min_pairs``) leaves
    pruning inactive for this run.
    """
    from repro.configs.neurovec import DEFAULT
    from repro.core.env import MeasuredEnv

    if transport is None or isinstance(transport, str):
        t = make_transport(transport or "inproc", db_path=db_path,
                           runner=runner, workers=workers, hosts=hosts,
                           **runner_kwargs)
    else:
        if db_path is not None or runner is not None or workers is not None \
                or hosts is not None or runner_kwargs:
            raise TypeError("a pre-built transport carries its own "
                            "runner/db/workers/hosts — drop the extra "
                            "arguments")
        t = transport
    fn = (CachedMeasureFn(t) if isinstance(t, InProcessTransport)
          else TransportMeasureFn(t))
    if prune_topk is not None:
        surrogate = resolve_surrogate(surrogate,
                                      db=getattr(t, "db", None))
    return MeasuredEnv(cfg if cfg is not None else DEFAULT,
                       measure_fn=fn, seed=seed,
                       prune_topk=prune_topk, surrogate=surrogate)


def resolve_surrogate(surrogate, db=None):
    """Normalize the facade/service ``surrogate=`` argument: a trained
    model passes through, a string loads a checkpoint directory, and
    ``None`` trains from ``db`` (``None`` again when the DB is too cold
    — pruning simply stays inactive)."""
    if surrogate is None:
        from repro.surrogate.model import train_from_db
        return train_from_db(db)
    if isinstance(surrogate, str):
        from repro.surrogate.model import load_surrogate
        return load_surrogate(surrogate)
    return surrogate
