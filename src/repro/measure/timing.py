"""Shared wall-clock timing primitives — ONE median-of-reps loop.

Every timing consumer in the repo (the hardware :class:`MeasureRunner`,
``benchmarks/bench_env.py``, ``benchmarks/bench_measure.py``) routes
through these two helpers instead of hand-rolling its own loop, so the
methodology — warmup to exclude compile/cache effects, ``block_until_ready``
on device values, median over repetitions — is defined exactly once.

* :func:`median_time` — seconds per call of one function (the measurement
  primitive: warmup + median of ``reps``).
* :func:`interleaved_medians` — A/B comparison timing that alternates the
  two functions each repetition, cancelling slow drift in shared-container
  load (the ``bench_env`` methodology).
"""
from __future__ import annotations

import time
from typing import Callable, Tuple

import numpy as np


def _block(x) -> None:
    """Synchronize on a (possibly nested) jax result; no-op for host values."""
    try:
        import jax
        jax.block_until_ready(x)
    except ImportError:                          # host-only timing consumer
        pass


def median_time(fn: Callable[[], object], *, reps: int = 5,
                warmup: int = 1) -> float:
    """Median wall-clock seconds per call of ``fn()``.

    ``warmup`` calls run first (compile + cache fill) and are discarded;
    each timed call blocks on its result so async dispatch cannot hide
    device time.  ``reps`` must be >= 1.
    """
    if reps < 1:
        raise ValueError(f"reps must be >= 1, got {reps}")
    for _ in range(warmup):
        _block(fn())
    ts = np.empty(reps, np.float64)
    for i in range(reps):
        t0 = time.perf_counter()
        _block(fn())
        ts[i] = time.perf_counter() - t0
    return float(np.median(ts))


def interleaved_medians(fn_a: Callable[[], object],
                        fn_b: Callable[[], object], *,
                        reps: int = 5) -> Tuple[float, float]:
    """Median seconds per call of two functions, interleaved A/B/A/B...

    Interleaving cancels slow drift in background load (each rep of A has
    a neighbouring rep of B under the same conditions), which is why the
    benchmark speedup ratios use this rather than two back-to-back
    :func:`median_time` calls.  Callers warm both paths themselves (the
    first call often carries compile/caching work worth asserting on).
    """
    ta, tb = np.empty(reps, np.float64), np.empty(reps, np.float64)
    for i in range(reps):
        t0 = time.perf_counter()
        _block(fn_a())
        ta[i] = time.perf_counter() - t0
        t0 = time.perf_counter()
        _block(fn_b())
        tb[i] = time.perf_counter() - t0
    return float(np.median(ta)), float(np.median(tb))
