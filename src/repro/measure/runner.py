"""Hardware measurement runner — turn ``(KernelSite, tiles)`` into seconds.

This is the real ``measure_fn`` for :class:`~repro.core.env.MeasuredEnv`
(paper eq. 2: the reward is *measured* execution time, not a model).  For
every pair it materializes inputs from the site's shapes/dtype, builds the
corresponding Pallas kernel from :mod:`repro.kernels` with the candidate
tile factors — the exact jitted wrappers deployment injects through — and
times it with warmup + ``block_until_ready`` + median-of-reps
(:mod:`repro.measure.timing`).

Backend selection is automatic: on TPU/GPU the kernels compile natively
and shapes are measured at full size; elsewhere Pallas runs in
``interpret=True`` mode so the complete measure→reward→train loop runs in
CI, with site dimensions capped (``max_dim``/``max_batch``) to keep the
interpreted grids tractable.  Interpret-mode timings are a *proxy* — they
scale with grid size and arithmetic volume, not MXU behaviour — which is
exactly enough to exercise every integration seam (measured-vs-model rank
agreement is tracked by ``benchmarks/bench_measure.py``).

Failure isolation is per pair: a tile whose kernel fails to build, compile
or run (VMEM overflow on hardware, shape-constraint violations, OOM)
yields ``inf`` — the same fail-closed marker the oracle maps to the
paper's compile-timeout penalty.  A failure never aborts the batch.
"""
from __future__ import annotations

import math
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.measure import timing

_JNP_DTYPES = {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
               "float16": jnp.float16}


def _ceil_mult(x: int, m: int) -> int:
    return -(-x // m) * m


def default_interpret() -> bool:
    """Compiled kernels on TPU/GPU, interpret-mode Pallas elsewhere."""
    return jax.default_backend() not in ("tpu", "gpu")


def device_kind() -> str:
    try:
        return jax.devices()[0].device_kind
    except Exception:
        return "unknown"


class MeasureRunner:
    """Batched compile-and-time hook: ``runner(sites, tiles) -> (n,) s``.

    Parameters
    ----------
    reps, warmup: the timing loop (median of ``reps`` after ``warmup``
        discarded calls — the warmup also pays jit compilation).
    interpret:  force Pallas interpret mode; ``None`` auto-selects
        (compiled on TPU/GPU, interpreted on CPU).
    max_dim, max_batch: per-dimension caps applied when interpreting
        (``None`` = auto: 128/2 interpreted, uncapped compiled).  Capped
        shapes are snapped to tile multiples, so every model-legal tile
        still builds and runs.
    seed:   input materialization seed.
    """

    def __init__(self, *, reps: int = 3, warmup: int = 1,
                 interpret: Optional[bool] = None,
                 max_dim: Optional[int] = None,
                 max_batch: Optional[int] = None, seed: int = 0):
        self.interpret = default_interpret() if interpret is None \
            else interpret
        self.max_dim = (128 if self.interpret else 0) if max_dim is None \
            else max_dim
        self.max_batch = (2 if self.interpret else 0) if max_batch is None \
            else max_batch
        self.reps = reps
        self.warmup = warmup
        self.seed = seed
        self.timed_pairs = 0            # successful timings performed
        self.failed_pairs = 0           # build/compile/run failures (-> inf)

    # -- identity ------------------------------------------------------------
    @property
    def backend_key(self) -> str:
        """Measurement-conditions fingerprint for the persistent DB key.

        Two timings are comparable only under the same backend, device,
        jax version and shape caps — anything else must miss the cache."""
        mode = (f"interpret(dim<={self.max_dim},b<={self.max_batch})"
                if self.interpret else "compiled")
        return f"{jax.default_backend()}:{device_kind()}:{mode}" \
               f":jax{jax.__version__}"

    # -- shape capping -------------------------------------------------------
    def _cap(self, v: int) -> int:
        return min(v, self.max_dim) if self.max_dim else v

    def _cap_b(self, v: int) -> int:
        return min(v, self.max_batch) if self.max_batch else v

    # -- per-kind kernel closures --------------------------------------------
    def _build(self, site, tiles):
        """Return a zero-arg callable running the site's Pallas kernel
        under the candidate tiles (inputs pre-materialized on device)."""
        from repro.kernels import ops
        key = jax.random.PRNGKey(self.seed)
        dt = _JNP_DTYPES.get(str(site.dtype), jnp.bfloat16)
        t = tuple(int(x) for x in tiles)
        interp = self.interpret

        if site.kind == "matmul":
            M, N, K = self._cap(site.m), self._cap(site.n), self._cap(site.k)
            x = jax.random.normal(key, (M, K), dt)
            w = jax.random.normal(jax.random.fold_in(key, 1), (K, N), dt)
            return lambda: ops.matmul(x, w, tiles=t[:3], interpret=interp)

        if site.kind == "attention":
            # site semantics: m=Sq, k=Skv, n=D, batch=B*H
            H = self._cap_b(site.batch)
            D = self._cap(site.n)
            bq, bkv = max(t[0], 1), max(t[1], 1)
            # the kernel requires Sq % min(bq, Sq) == 0: snap capped
            # lengths up to the tile multiple so every model-legal tile
            # runs (a no-op for the pow2 shapes real models extract)
            Sq = _ceil_mult(self._cap(site.m), min(bq, self._cap(site.m)))
            Skv = _ceil_mult(self._cap(site.k), min(bkv, self._cap(site.k)))
            q = jax.random.normal(key, (1, H, Sq, D), dt)
            k = jax.random.normal(jax.random.fold_in(key, 1),
                                  (1, H, Skv, D), dt)
            v = jax.random.normal(jax.random.fold_in(key, 2),
                                  (1, H, Skv, D), dt)
            scale = 1.0 / math.sqrt(D)
            causal = site.causal
            return lambda: ops.flash_attention(
                q, k, v, causal=causal, scale=scale, tiles=t[:2],
                interpret=interp)

        if site.kind == "chunk_scan":
            # site semantics: m=configured chunk, n=P, k=N,
            # batch=#instances; total scanned tokens = batch * m
            P, N = self._cap(site.n), self._cap(site.k)
            S = self._cap(site.batch * site.m)
            Q = max(t[0], 1)
            S = _ceil_mult(S, min(Q, S))
            x = jax.random.normal(key, (1, S, P), dt)
            Bm = jax.random.normal(jax.random.fold_in(key, 1),
                                   (1, S, N), dt) * 0.3
            Cm = jax.random.normal(jax.random.fold_in(key, 2),
                                   (1, S, N), dt) * 0.3
            la = -jax.nn.softplus(jax.random.normal(
                jax.random.fold_in(key, 3), (1, S))).astype(dt)
            return lambda: ops.chunk_scan(x, Bm, Cm, la, chunk=Q,
                                          interpret=interp)

        raise ValueError(site.kind)

    # -- measurement ---------------------------------------------------------
    def measure_one(self, site, tiles) -> float:
        """Seconds for one (site, tile) pair; ``inf`` on any failure."""
        try:
            fn = self._build(site, tiles)
            s = timing.median_time(fn, reps=self.reps, warmup=self.warmup)
        except Exception:
            # fail closed: a kernel that cannot build/compile/run is the
            # compile-timeout analogue — inf maps to the oracle's penalty
            self.failed_pairs += 1
            return float("inf")
        self.timed_pairs += 1
        return s

    def __call__(self, sites: Sequence, tiles) -> np.ndarray:
        """The batched ``MeasuredEnv.measure_fn`` hook: ``(n,) seconds``."""
        tiles = np.asarray(tiles, np.int64)
        return np.array([self.measure_one(s, t)
                         for s, t in zip(sites, tiles)], np.float64)
