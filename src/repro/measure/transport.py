"""The in-process :class:`~repro.core.protocols.MeasureTransport`.

This is the PR-3 ``MeasureRunner`` + ``MeasureDB`` stack re-expressed
behind the asynchronous transport contract: ``submit`` serves DB hits as
already-resolved futures, coalesces duplicate keys to one measurement,
executes the misses eagerly on the calling thread (there is no worker to
hand them to — ``drain()`` is therefore a no-op by the time it can be
called) and streams every fresh timing into the attached
:class:`~repro.measure.db.MeasureDB` exactly once per key.

:class:`TransportMeasureFn` is the inverse adapter: any transport behind
the *synchronous* batched ``measure_fn(sites, tiles) -> (n,) seconds``
hook that :class:`~repro.core.env.MeasuredEnv` consumes — submit, drain,
gather.  The legacy ``CachedMeasureFn(runner, db)`` surface in
:mod:`repro.measure.db` is now a thin shim over these two classes.
"""
from __future__ import annotations

import threading
from concurrent.futures import Future
from typing import Optional, Sequence

import numpy as np

from repro.measure.db import MeasureDB, make_key


def _resolved(value: float) -> Future:
    f = Future()
    f.set_result(float(value))
    return f


class _TransportStats:
    """The shared counter block every transport reports via ``stats()``."""

    def __init__(self):
        self.hits = 0            # pairs served from the DB
        self.misses = 0          # pairs that required a measurement
        self.coalesced = 0       # pairs folded onto an in-flight duplicate
        self.timed_pairs = 0     # successful measurements performed
        self.failed_pairs = 0    # measurements resolved to inf (fail-closed)
        self.retries = 0         # jobs requeued after a worker death

    def snapshot(self, in_flight: int = 0) -> dict:
        """Counter snapshot in the unified ``<subsystem>_<noun>_<unit>``
        spellings — the same series ``repro.obs`` registries expose.
        (The PR 8 "one release" bare aliases — ``hits``, ``misses``,
        ``coalesced``, ``timed_pairs``, ``failed_pairs``, ``retries``,
        ``in_flight``, ``hit_rate`` — are removed as scheduled.)"""
        n = self.hits + self.misses + self.coalesced
        return {"transport_hits_total": self.hits,
                "transport_misses_total": self.misses,
                "transport_coalesced_total": self.coalesced,
                "transport_timed_pairs_total": self.timed_pairs,
                "transport_failed_pairs_total": self.failed_pairs,
                "transport_retries_total": self.retries,
                "transport_inflight_pairs": in_flight,
                "transport_hit_ratio": (self.hits / n) if n else 0.0}


class InProcessTransport:
    """Eager single-process transport: the calling thread measures.

    ``runner`` is any batched ``(sites, tiles) -> (n,) seconds`` callable
    exposing ``backend_key`` (a :class:`~repro.measure.runner.
    MeasureRunner` in production, a counting spy in tests); ``db=None``
    disables persistence but keeps the statistics.
    """

    def __init__(self, runner, db: Optional[MeasureDB] = None):
        self.runner = runner
        self.db = db
        self._stats = _TransportStats()
        self._lock = threading.Lock()
        self._idle = threading.Condition(self._lock)
        self._inflight: dict = {}       # key -> Future (across submit calls)
        self._closed = False

    @property
    def backend_key(self) -> str:
        return getattr(self.runner, "backend_key", "unknown")

    def submit(self, sites: Sequence, tiles) -> list:
        if self._closed:
            raise RuntimeError("submit on a closed transport")
        tiles = np.asarray(tiles, np.int64)
        backend = self.backend_key
        futs: list = [None] * len(sites)
        run_idx: list = []              # (index, key) pairs to measure here
        with self._lock:
            for i, (s, t) in enumerate(zip(sites, tiles)):
                key = make_key(s.key(), t, backend)
                v = self.db.get(key) if self.db is not None else None
                if v is not None:
                    self._stats.hits += 1
                    futs[i] = _resolved(v)
                elif key in self._inflight:
                    # duplicate of a key this submit call — or a concurrent
                    # one from another thread — is already measuring
                    self._stats.coalesced += 1
                    futs[i] = self._inflight[key]
                else:
                    f: Future = Future()
                    self._inflight[key] = f
                    futs[i] = f
                    run_idx.append((i, key))
        if run_idx:
            idx = [i for i, _ in run_idx]
            try:
                vals = np.asarray(self.runner([sites[i] for i in idx],
                                              tiles[idx]), np.float64)
            except BaseException:
                # a runner that raises (instead of returning inf) must not
                # strand its in-flight futures: anyone already coalesced
                # onto them would block forever.  Fail them closed, then
                # surface the error to this caller.
                with self._lock:
                    for _, key in run_idx:
                        f = self._inflight.pop(key, None)
                        if f is not None:
                            self._stats.misses += 1
                            self._stats.failed_pairs += 1
                            f.set_result(float("inf"))
                    self._idle.notify_all()
                raise
            with self._lock:
                for (i, key), v in zip(run_idx, vals):
                    v = float(v)
                    if self.db is not None:
                        self.db.put(key, v)
                    self._stats.misses += 1
                    if np.isfinite(v):
                        self._stats.timed_pairs += 1
                    else:
                        self._stats.failed_pairs += 1
                    self._inflight.pop(key).set_result(v)
                self._idle.notify_all()
        return futs

    def drain(self) -> None:
        """Block until no measurement (from any thread) is in flight."""
        with self._lock:
            self._idle.wait_for(lambda: not self._inflight)

    def close(self) -> None:
        self._closed = True
        if self.db is not None:
            self.db.close()

    def health(self) -> str:
        """In-process: either the calling thread can measure (``ok``)
        or the transport is closed (``down``) — nothing in between."""
        return "down" if self._closed else "ok"

    def stats(self) -> dict:
        with self._lock:
            return self._stats.snapshot(in_flight=len(self._inflight))

    def __enter__(self) -> "InProcessTransport":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class TransportMeasureFn:
    """Any :class:`~repro.core.protocols.MeasureTransport` behind the
    synchronous batched ``measure_fn`` hook of
    :class:`~repro.core.env.MeasuredEnv`: submit the batch, drain, gather.

    Keeps the historical ``hits`` / ``misses`` / ``hit_rate`` reporting
    surface (delegated to the transport's counters) so callers that print
    cache statistics work across every transport."""

    def __init__(self, transport):
        self.transport = transport

    def __call__(self, sites: Sequence, tiles) -> np.ndarray:
        futs = self.transport.submit(sites, tiles)
        # gather blocks on exactly this batch's futures — NOT drain(),
        # which would also wait out other sessions' unrelated in-flight
        # work on a shared transport
        return np.array([f.result() for f in futs], np.float64)

    @property
    def hits(self) -> int:
        return self.transport.stats()["transport_hits_total"]

    @property
    def misses(self) -> int:
        return self.transport.stats()["transport_misses_total"]

    @property
    def hit_rate(self) -> float:
        return self.transport.stats()["transport_hit_ratio"]

    @property
    def db(self):
        return getattr(self.transport, "db", None)


class CachedMeasureFn(TransportMeasureFn):
    """The PR-3 runner+DB glue, now a shim over
    :class:`InProcessTransport`: ``CachedMeasureFn(runner, db)`` is
    exactly ``TransportMeasureFn(InProcessTransport(runner, db))``.

    Kept because it is the natural spelling for the single-process case
    (and the constructor signature a lot of call sites/tests use); new
    transport-aware code should build the transport explicitly and wrap
    it in :class:`TransportMeasureFn`.  ``runner`` may also be an
    already-built :class:`InProcessTransport` (``db`` stays ``None`` —
    the transport carries its own)."""

    def __init__(self, runner, db: Optional[MeasureDB] = None):
        if isinstance(runner, InProcessTransport):
            if db is not None:
                raise TypeError("the transport carries its own db")
            super().__init__(runner)
        else:
            super().__init__(InProcessTransport(runner, db))

    @property
    def runner(self):
        return self.transport.runner
