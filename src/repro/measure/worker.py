"""Measurement worker — the subprocess end of the worker-pool transport.

One worker = one process = one :class:`~repro.measure.runner.MeasureRunner`
(its own jax runtime, so a kernel that wedges or crashes the interpreter
takes down a *worker*, never the tuning process).  The parent
(:class:`~repro.measure.pool.WorkerPoolTransport`) speaks a length-prefixed
JSON frame protocol over the worker's stdin/stdout pipes:

==========  ============================================================
direction   frame
==========  ============================================================
parent →    ``{"type": "init", "runner": {...}, "factory": mod:attr|null}``
worker →    ``{"type": "ready", "backend": <runner.backend_key>}``
parent →    ``{"type": "job", "id": n, "site": {...}, "tiles": [a, b, c]}``
worker →    ``{"type": "result", "id": n, "v": seconds | null}``
parent →    ``{"type": "exit"}`` (or EOF)  — worker exits 0
==========  ============================================================

Every frame is ``len(payload)`` as a 4-byte big-endian prefix followed by
the UTF-8 JSON payload.  ``"v": null`` is a failed measurement (the
parent resolves it to ``inf`` — the shared fail-closed marker); a worker
that *dies* instead of answering is the parent's problem (requeue).

``factory`` names a ``module:attribute`` callable returning a runner
(``(sites, tiles) -> (n,) seconds`` with ``backend_key``) — the test
seam that lets the conformance suite run deterministic or deliberately
crashing runners inside real worker processes.  Production workers leave
it null and build a :class:`MeasureRunner` from the ``runner`` kwargs.
"""
from __future__ import annotations

import importlib
import os
import sys

from repro.measure.wire import read_frame, write_frame


def _build_runner(init: dict):
    factory = init.get("factory")
    if factory:
        mod, _, attr = factory.partition(":")
        return getattr(importlib.import_module(mod), attr)()
    from repro.measure.runner import MeasureRunner
    return MeasureRunner(**(init.get("runner") or {}))


def _site(d: dict):
    from repro.models.compute import KernelSite
    return KernelSite(**d)


def main() -> int:
    # the protocol owns fd 1: re-route any stray print (jax warnings,
    # user runner chatter) to stderr so it can never corrupt a frame
    proto_out = os.fdopen(os.dup(1), "wb")
    # advertise the protocol fd so fault-injecting runners
    # (repro.measure.faults.ChaosRunner) can tear a result frame
    os.environ["REPRO_WORKER_PROTO_FD"] = str(proto_out.fileno())
    os.dup2(2, 1)
    sys.stdout = sys.stderr
    inp = sys.stdin.buffer

    init = read_frame(inp)
    if init is None or init.get("type") != "init":
        return 2
    runner = _build_runner(init)
    write_frame(proto_out, {"type": "ready",
                            "backend": getattr(runner, "backend_key",
                                               "unknown")})

    while True:
        msg = read_frame(inp)
        if msg is None or msg.get("type") == "exit":
            return 0
        if msg.get("type") != "job":
            continue
        import numpy as np
        try:
            v = float(np.asarray(runner([_site(msg["site"])],
                                        np.asarray([msg["tiles"]],
                                                   np.int64))).reshape(-1)[0])
        except Exception:
            # a runner that raises instead of returning inf must not kill
            # the worker (a death costs the parent a respawn + a retry
            # attempt); answer the documented failure marker instead
            v = float("inf")
        write_frame(proto_out, {"type": "result", "id": msg["id"],
                                "v": None if not np.isfinite(v) else v})


if __name__ == "__main__":
    sys.exit(main())
