"""``WorkerPoolTransport`` — fan (site, tiles) measurements out to N
subprocess workers.

The scaling seam the ROADMAP's remote-measurement open item asked for:
measured-reward throughput is no longer capped at one local runner.  Each
worker is its own process (own jax runtime — a kernel that wedges or
kills the interpreter costs one worker, never the tuning loop) driven
over a length-prefixed JSON pipe protocol (:mod:`repro.measure.worker`).

Scheduling semantics (the :class:`~repro.core.protocols.MeasureTransport`
contract, conformance-tested next to the in-process transport):

* ``submit`` is non-blocking: DB hits resolve instantly, duplicate keys —
  in one batch or across concurrent submitters — coalesce onto the single
  in-flight job, fresh keys queue for the next idle worker.
* results stream into the attached :class:`~repro.measure.db.MeasureDB`
  as they arrive (exactly once per key), so a second run against the same
  DB path performs zero timings no matter which transport produced it.
* a job whose worker dies mid-measurement is requeued (the worker is
  respawned); after ``max_attempts`` total tries it fails closed to
  ``inf`` — the same marker as a kernel that fails to build — and is
  *quarantined* in the DB (:meth:`~repro.measure.db.MeasureDB.
  quarantine`: attempt count + reason), so no future run in any process
  re-attempts a pair that kills workers.
* worker respawns back off exponentially with deterministic jitter
  (:func:`respawn_backoff`) — a crash-looping backend stops eating the
  spawn cost instead of hammering it; ``health()`` reports ``ok`` /
  ``degraded`` (workers lost or backing off) / ``down`` (no dispatcher
  can make progress), the signal the oracle-level circuit breaker
  (:class:`~repro.core.env.MeasuredEnv`) degrades on.

One dispatcher thread per worker keeps the design free of async
machinery: the thread feeds its worker one job at a time (a job is a
whole kernel compile+measure — there is nothing to pipeline under it)
and doubles as the result reader, so worker death is detected exactly
where the job context is known.
"""
from __future__ import annotations

import os
import select
import subprocess
import sys
import threading
import time
import zlib
from collections import deque
from concurrent.futures import Future
from dataclasses import asdict
from typing import Optional, Sequence

import numpy as np

from repro.measure.db import make_key
from repro.measure.transport import _TransportStats, _resolved
from repro.measure.wire import read_frame, write_frame

_MAX_SPAWN_FAILURES = 3                 # consecutive, per dispatcher thread


def respawn_backoff(failures: int, *, base: float = 0.1, cap: float = 30.0,
                    seed: int = 0) -> float:
    """Seconds to wait before respawn attempt ``failures`` (1-based):
    exponential in the consecutive-failure count, capped, with a
    *deterministic* multiplicative jitter in ``[0.5, 1.0]`` derived from
    ``(seed, failures)`` — reproducible under a fake clock, yet distinct
    seeds (one per dispatcher) desynchronize a thundering herd."""
    if failures < 1:
        raise ValueError(f"failures must be >= 1, got {failures}")
    d = min(cap, base * (2.0 ** (failures - 1)))
    u = (zlib.crc32(f"{seed}|{failures}".encode()) % 1000) / 999.0
    return d * (0.5 + 0.5 * u)


def _read_frame_deadline(stream, deadline: Optional[float]):
    """:func:`read_frame` bounded by a monotonic ``deadline`` —
    ``TimeoutError`` on expiry.  Safe here because the protocol is one
    frame per job (the pipe buffer is empty between frames, so select on
    the fd sees everything)."""
    while True:
        if deadline is not None:
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                raise TimeoutError("worker did not answer before the "
                                   "deadline (wedged measurement?)")
            r, _, _ = select.select([stream], [], [], remaining)
            if not r:
                continue
        return read_frame(stream)


class _Job:
    __slots__ = ("key", "site", "tiles", "future", "attempts",
                 "t_queued", "t_start", "queue_wait_s")

    def __init__(self, key: str, site, tiles):
        self.key = key
        self.site = site
        self.tiles = [int(x) for x in tiles]
        self.future: Future = Future()
        self.attempts = 0
        # queue-wait vs in-flight attribution: t_queued stamps every
        # (re)entry into the pending deque, queue_wait_s accumulates the
        # waits across requeues, t_start marks the hand-off to a worker
        self.t_queued = time.monotonic()
        self.t_start: Optional[float] = None
        self.queue_wait_s = 0.0


class WorkerPoolTransport:
    """Subprocess measurement pool behind the MeasureTransport contract.

    Parameters
    ----------
    workers:        pool size (one subprocess + dispatcher thread each).
    db:             a :class:`MeasureDB`, a path for one, or ``None``.
    runner_kwargs:  :class:`~repro.measure.runner.MeasureRunner` options
                    each worker builds its runner from (``reps=``,
                    ``interpret=``, ``max_dim=``, ...).
    max_attempts:   total tries per job before failing closed to ``inf``
                    (a try is consumed each time a worker dies holding
                    the job).
    factory:        ``"module:attr"`` runner factory override for the
                    workers — the test seam (deterministic / crashing
                    runners inside real processes).  Production leaves it
                    ``None``.
    spawn_timeout:  seconds to wait for each worker's ready handshake.
    job_timeout:    seconds a worker may hold one job before it is
                    treated as wedged (killed + job requeued, same as a
                    death; ``None`` = unlimited).  Generous by default:
                    a job is a whole kernel build+measure.
    backoff_base / backoff_cap / backoff_seed:
                    the :func:`respawn_backoff` schedule applied between
                    consecutive failed respawns (crash-loop breaker);
                    each dispatcher jitters from ``backoff_seed + its
                    index``.
    """

    def __init__(self, workers: int = 2, db=None,
                 runner_kwargs: Optional[dict] = None,
                 max_attempts: int = 3, factory: Optional[str] = None,
                 spawn_timeout: float = 180.0,
                 job_timeout: Optional[float] = 900.0,
                 backoff_base: float = 0.1, backoff_cap: float = 30.0,
                 backoff_seed: int = 0):
        if workers < 1:
            raise ValueError(f"workers must be >= 1, got {workers}")
        if max_attempts < 1:
            raise ValueError(f"max_attempts must be >= 1, got {max_attempts}")
        self.workers = workers
        if isinstance(db, str):
            from repro.measure.db import open_measure_db
            db = open_measure_db(db)    # fleet:// paths open remote mirrors
        self.db = db
        self.runner_kwargs = dict(runner_kwargs or {})
        self.max_attempts = max_attempts
        self.factory = factory
        self.spawn_timeout = spawn_timeout
        self.job_timeout = job_timeout
        self.backoff_base = backoff_base
        self.backoff_cap = backoff_cap
        self.backoff_seed = backoff_seed
        self._sleep = time.sleep        # seam: fake clock in backoff tests

        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self._pending: "deque[_Job]" = deque()
        self._inflight: dict = {}       # key -> _Job (queued or measuring)
        self._stats = _TransportStats()
        self._closing = False
        self._backend: Optional[str] = None
        self._ready = 0
        self._live = workers            # dispatcher threads still running
        self._backing_off = 0           # dispatchers sleeping out a backoff
        self._spawn_error: Optional[BaseException] = None
        self.worker_restarts = 0        # respawns after a worker death
        # per-job wait/run attribution (PR 8): totals for stats(), plus an
        # optional observer(queue_wait_s, run_s) called as each job leaves
        # the pool — repro.obs wires histograms here
        self.queue_wait_seconds = 0.0   # summed time jobs spent queued
        self.run_seconds = 0.0          # summed time jobs spent on workers
        self.jobs_finished = 0          # jobs resolved (timed or failed)
        self.job_observer = None

        self._threads = [
            threading.Thread(target=self._dispatch, args=(i,),
                             name=f"measure-w{i}", daemon=True)
            for i in range(workers)]
        for t in self._threads:
            t.start()
        with self._cv:
            ok = self._cv.wait_for(
                lambda: self._ready == workers or self._spawn_error,
                timeout=spawn_timeout)
            err = self._spawn_error
            if err is not None or not ok:
                self._closing = True    # wind the live threads down
                self._cv.notify_all()
        if err is not None:
            raise RuntimeError("worker pool failed to start") from err
        if not ok:
            raise TimeoutError(
                f"worker pool: {self._ready}/{workers} workers ready "
                f"after {spawn_timeout}s")

    # -- worker process lifecycle -------------------------------------------
    def _spawn(self) -> subprocess.Popen:
        env = dict(os.environ)
        # the child must import repro (and, under tests, the helper
        # factories) exactly as this process does
        env["PYTHONPATH"] = os.pathsep.join(p for p in sys.path if p)
        proc = subprocess.Popen(
            [sys.executable, "-m", "repro.measure.worker"],
            stdin=subprocess.PIPE, stdout=subprocess.PIPE, env=env)
        try:
            write_frame(proc.stdin, {"type": "init",
                                     "runner": self.runner_kwargs,
                                     "factory": self.factory})
            ready = _read_frame_deadline(
                proc.stdout, time.monotonic() + self.spawn_timeout)
        except Exception:
            self._kill(proc)
            raise
        if not ready or ready.get("type") != "ready":
            proc.kill()
            raise RuntimeError(f"worker handshake failed: {ready!r}")
        with self._cv:
            if self._backend is None:
                self._backend = ready["backend"]
            elif self._backend != ready["backend"]:
                proc.kill()
                raise RuntimeError(
                    f"worker backend {ready['backend']!r} != pool "
                    f"backend {self._backend!r} — mixed measurement "
                    f"conditions would poison the DB")
        return proc

    def _kill(self, proc: Optional[subprocess.Popen]) -> None:
        if proc is None:
            return
        try:
            proc.kill()
            proc.wait(timeout=5)
        except Exception:
            pass

    def _stop_worker(self, proc: Optional[subprocess.Popen]) -> None:
        """Polite shutdown: exit frame, short grace, then kill."""
        if proc is None:
            return
        try:
            write_frame(proc.stdin, {"type": "exit"})
            proc.stdin.close()
            proc.wait(timeout=10)
        except Exception:
            self._kill(proc)

    # -- the per-worker dispatcher thread ------------------------------------
    def _dispatch(self, index: int) -> None:
        proc: Optional[subprocess.Popen] = None
        counted_ready = False
        spawn_failures = 0
        job: Optional[_Job] = None
        job_id = 0
        try:
            while True:
                # keep a live worker BEFORE waiting for work: the
                # constructor blocks on every worker's ready handshake
                if proc is None or proc.poll() is not None:
                    try:
                        proc = self._spawn()
                        spawn_failures = 0
                    except Exception as e:
                        spawn_failures += 1
                        with self._cv:
                            if not counted_ready:
                                # this worker never came up: abort the
                                # constructor rather than limp along
                                self._spawn_error = e
                                self._requeue_or_fail(job, hard=True)
                                job = None
                                self._cv.notify_all()
                                return
                            self._requeue_or_fail(
                                job, reason=f"respawn failed "
                                f"({type(e).__name__})")
                            job = None
                            self._cv.notify_all()
                            if spawn_failures >= _MAX_SPAWN_FAILURES:
                                return
                            self._backing_off += 1
                        try:
                            self._sleep(respawn_backoff(
                                spawn_failures, base=self.backoff_base,
                                cap=self.backoff_cap,
                                seed=self.backoff_seed + index))
                        finally:
                            with self._cv:
                                self._backing_off -= 1
                        continue
                    if not counted_ready:
                        counted_ready = True
                        with self._cv:
                            self._ready += 1
                            self._cv.notify_all()
                if job is None:
                    with self._cv:
                        self._cv.wait_for(
                            lambda: self._pending or self._closing)
                        if self._closing and not self._pending:
                            return
                        job = self._pending.popleft()
                        job.queue_wait_s += time.monotonic() - job.t_queued
                    continue        # re-check the worker before sending
                job_id += 1
                job.t_start = time.monotonic()
                try:
                    write_frame(proc.stdin, {"type": "job", "id": job_id,
                                             "site": asdict(job.site),
                                             "tiles": job.tiles})
                    deadline = None if self.job_timeout is None else \
                        time.monotonic() + self.job_timeout
                    while True:
                        msg = _read_frame_deadline(proc.stdout, deadline)
                        if msg is None:
                            raise EOFError("worker closed its pipe")
                        if msg.get("type") == "result" \
                                and msg.get("id") == job_id:
                            break
                except (OSError, EOFError, ValueError) as e:
                    # the worker died — or wedged past job_timeout
                    # (TimeoutError is an OSError) — holding this job:
                    # requeue (or fail closed) and respawn on the next
                    # loop iteration
                    self._kill(proc)
                    proc = None
                    reason = "wedged (job timeout)" \
                        if isinstance(e, TimeoutError) \
                        else f"worker died ({type(e).__name__})"
                    with self._cv:
                        self.worker_restarts += 1
                        self._requeue_or_fail(job, reason=reason)
                        job = None
                        self._cv.notify_all()
                    continue
                v = float("inf") if msg["v"] is None else float(msg["v"])
                self._resolve(job, v)
                job = None
        finally:
            self._stop_worker(proc)
            with self._cv:
                self._live -= 1
                if self._live == 0:
                    # last dispatcher gone: nothing can make progress —
                    # fail every queued job closed so drain() never hangs
                    while self._pending:
                        self._requeue_or_fail(self._pending.popleft(),
                                              hard=True)
                self._cv.notify_all()

    # call with self._lock held
    def _account(self, job: _Job) -> None:
        """Book a finished job's queue-wait/run split (lock held)."""
        run_s = 0.0 if job.t_start is None \
            else time.monotonic() - job.t_start
        self.queue_wait_seconds += job.queue_wait_s
        self.run_seconds += run_s
        self.jobs_finished += 1
        obs = self.job_observer
        if obs is not None:
            try:
                obs(job.queue_wait_s, run_s)
            except Exception:
                pass                    # telemetry must never fail a job

    def _requeue_or_fail(self, job: Optional[_Job], hard: bool = False,
                         reason: str = "worker death") -> None:
        if job is None:
            return
        job.attempts += 1
        if hard or job.attempts >= self.max_attempts:
            # fail closed: same marker as a kernel that cannot build.
            # Only the attempts-exhausted verdict is *persisted* — the
            # job itself killed max_attempts workers, so the DB should
            # quarantine it (no future run in any process re-attempts
            # it).  hard failures are pool infrastructure problems
            # (spawn failures, shutdown): the pair was never tried, and
            # a persisted inf would poison every future run.
            if not hard and self.db is not None:
                self.db.quarantine(job.key, job.attempts, reason)
            self._stats.failed_pairs += 1
            self._inflight.pop(job.key, None)
            self._account(job)
            job.future.set_result(float("inf"))
        else:
            self._stats.retries += 1
            job.t_queued = time.monotonic()     # wait clock restarts
            job.t_start = None
            self._pending.append(job)

    def _resolve(self, job: _Job, v: float) -> None:
        with self._cv:
            if self.db is not None:
                self.db.put(job.key, v)
            if np.isfinite(v):
                self._stats.timed_pairs += 1
            else:
                self._stats.failed_pairs += 1
            self._inflight.pop(job.key, None)
            self._account(job)
            job.future.set_result(v)
            self._cv.notify_all()

    # -- MeasureTransport surface --------------------------------------------
    @property
    def backend_key(self) -> str:
        return self._backend or "unknown"

    def submit(self, sites: Sequence, tiles) -> list:
        tiles = np.asarray(tiles, np.int64)
        futs: list = [None] * len(sites)
        with self._cv:
            if self._closing:
                raise RuntimeError("submit on a closed transport")
            backend = self.backend_key
            for i, (s, t) in enumerate(zip(sites, tiles)):
                key = make_key(s.key(), t, backend)
                v = self.db.get(key) if self.db is not None else None
                if v is not None:
                    self._stats.hits += 1
                    futs[i] = _resolved(v)
                elif key in self._inflight:
                    self._stats.coalesced += 1
                    futs[i] = self._inflight[key].future
                elif self._live == 0:
                    # every dispatcher is gone (pool down, not closed):
                    # nothing will ever service the queue, so fail the
                    # pair closed now instead of hanging drain()
                    self._stats.misses += 1
                    self._stats.failed_pairs += 1
                    futs[i] = _resolved(float("inf"))
                else:
                    job = _Job(key, s, t)
                    self._stats.misses += 1
                    self._inflight[key] = job
                    self._pending.append(job)
                    futs[i] = job.future
            self._cv.notify_all()
        return futs

    def drain(self) -> None:
        with self._cv:
            self._cv.wait_for(lambda: not self._inflight)

    def close(self) -> None:
        if self._closing:
            return
        self.drain()
        with self._cv:
            self._closing = True
            self._cv.notify_all()
        for t in self._threads:
            t.join(timeout=30)
        if self.db is not None:
            self.db.close()

    def health(self) -> str:
        """``ok`` — full complement of dispatchers, none backing off;
        ``degraded`` — workers lost or sleeping out a respawn backoff;
        ``down`` — closed, or no dispatcher can make progress."""
        with self._cv:
            return self._health_locked()

    def _health_locked(self) -> str:
        if self._closing or self._live == 0:
            return "down"
        if self._backing_off or self._live < self.workers:
            return "degraded"
        return "ok"

    def stats(self) -> dict:
        """Transport counters + pool-specific keys in the unified
        ``<subsystem>_<noun>_<unit>`` naming (see
        :class:`repro.obs.MetricsRegistry` for the naming authority; the
        PR 8 "one release" ``workers`` / ``worker_restarts`` /
        ``quarantined`` aliases are removed as scheduled)."""
        with self._cv:
            s = self._stats.snapshot(in_flight=len(self._inflight))
            s["health"] = self._health_locked()
            s["pool_queue_depth"] = len(self._pending)
            s["pool_queue_wait_seconds_total"] = self.queue_wait_seconds
            s["pool_run_seconds_total"] = self.run_seconds
            s["pool_jobs_finished_total"] = self.jobs_finished
        s["pool_workers_count"] = self.workers
        s["pool_worker_restarts_total"] = self.worker_restarts
        s["pool_quarantined_total"] = \
            self.db.n_quarantined if self.db is not None else 0
        return s

    def __enter__(self) -> "WorkerPoolTransport":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
