"""Deterministic fault injection for the measurement stack.

Chaos testing for :mod:`repro.measure`: the transport conformance suite
(``tests/test_transport.py``) pins down *what* every transport must do —
this module supplies the machinery to prove those invariants hold while
workers crash, wedge, tear result frames mid-write, and timings jitter.
Everything here is **seedable and deterministic**: a fault schedule is a
pure function of ``(seed, event key)``, so a failing chaos run replays
exactly.

Three layers, composable:

:class:`FaultSchedule`
    The deterministic oracle — maps an event key (e.g. ``"site|tiles"``)
    to a fault name or ``None`` via a crc32 hash.  No state, no RNG
    objects to thread around.

:class:`ChaosRunner`
    A worker-*side* wrapper around any batched runner.  Injected faults
    are the real thing: ``crash`` is ``os._exit`` mid-job, ``hang``
    sleeps past the pool's ``job_timeout``, ``torn`` writes a partial /
    garbage result frame onto the protocol pipe and dies, ``noise``
    adds deterministic latency (never touching the value — measured
    *values* must be bit-identical under chaos).  Destructive faults are
    **one-shot** per event key (sentinel files in ``state_dir``) so the
    retried job succeeds within the pool's ``max_attempts`` and the
    conformance assertions on values and exactly-once DB writes stay
    valid.

:class:`FaultInjectionTransport`
    A parent-side decorator over any
    :class:`~repro.core.protocols.MeasureTransport`: delegates the whole
    surface 1:1 (values, ordering, coalescing and counters pass through
    untouched) while injecting deterministic latency noise around
    ``submit``/``drain`` — the schedule shaking the *caller's* timing
    assumptions rather than the worker's.
"""
from __future__ import annotations

import os
import struct
import time
import zlib
from typing import Optional, Sequence, Tuple

import numpy as np

FAULTS = ("crash", "hang", "torn", "noise")


class FaultSchedule:
    """Deterministic fault oracle: ``draw(event_key)`` → fault name or
    ``None``, a pure function of ``(seed, event_key)``.

    With the default ``period=2`` roughly half of all event keys draw a
    fault, uniformly spread over ``faults``; raising ``period`` thins
    the schedule.
    """

    def __init__(self, seed: int = 0,
                 faults: Tuple[str, ...] = FAULTS, period: int = 2):
        if period < 1:
            raise ValueError(f"period must be >= 1, got {period}")
        self.seed = seed
        self.faults = tuple(faults)
        self.period = period

    def draw(self, event_key: str) -> Optional[str]:
        h = zlib.crc32(f"{self.seed}|{event_key}".encode())
        slot = h % (len(self.faults) * self.period)
        return self.faults[slot] if slot < len(self.faults) else None


def _tear_frame(fd: int, variant: int) -> None:
    """Write one of three torn result frames straight onto the protocol
    pipe: a truncated length header, a length prefix promising more
    payload than follows, or a full frame of invalid JSON — each hits a
    distinct branch of the parent's framing error handling
    (``EOFError`` ×2, ``ValueError``)."""
    torn = (b"\x00\x00",                            # truncated header
            struct.pack(">I", 64) + b"garbage",     # truncated payload
            struct.pack(">I", 5) + b"notjs")        # invalid JSON
    os.write(fd, torn[variant % len(torn)])


class ChaosRunner:
    """Worker-side chaos: wraps a batched runner and injects real faults
    on the :class:`FaultSchedule`'s say-so.

    ``state_dir`` holds the one-shot sentinel files (shared by every
    worker process in the pool via the filesystem); ``hang_s`` should
    comfortably exceed the pool's ``job_timeout`` so a hang is observed
    as a wedge, not a slow success.
    """

    def __init__(self, base, schedule: FaultSchedule, state_dir: str,
                 hang_s: float = 3600.0, noise_s: float = 0.05):
        self.base = base
        self.schedule = schedule
        self.state_dir = state_dir
        self.hang_s = hang_s
        self.noise_s = noise_s

    @property
    def backend_key(self) -> str:
        return getattr(self.base, "backend_key", "unknown")

    def _fire_once(self, fault: str, event_key: str) -> bool:
        """True exactly once per (fault, event_key) across every worker
        process sharing ``state_dir``."""
        name = f"{fault}-{zlib.crc32(event_key.encode()):08x}"
        path = os.path.join(self.state_dir, name)
        try:
            fd = os.open(path, os.O_CREAT | os.O_EXCL | os.O_WRONLY)
        except FileExistsError:
            return False
        os.close(fd)
        return True

    def _inject(self, event_key: str) -> None:
        fault = self.schedule.draw(event_key)
        if fault is None:
            return
        if fault == "noise":
            # latency only — the measured value must survive bit-identical
            u = zlib.crc32(f"noise|{event_key}".encode()) % 1000 / 999.0
            time.sleep(self.noise_s * u)
            return
        if not self._fire_once(fault, event_key):
            return
        if fault == "crash":
            os._exit(3)
        if fault == "hang":
            time.sleep(self.hang_s)
            os._exit(3)             # parent killed us long ago; belt+braces
        if fault == "torn":
            fd = os.environ.get("REPRO_WORKER_PROTO_FD")
            if fd is not None:      # outside a worker: degrade to a crash
                _tear_frame(int(fd), zlib.crc32(event_key.encode()))
            os._exit(3)

    def __call__(self, sites: Sequence, tiles) -> np.ndarray:
        tiles = np.asarray(tiles, np.int64)
        for s, t in zip(sites, tiles):
            self._inject(f"{s.key()}|{tuple(int(x) for x in t)}")
        return self.base(sites, tiles)


class FaultInjectionTransport:
    """Parent-side chaos decorator over any MeasureTransport.

    Correctness-invisible by construction: every call delegates to the
    wrapped transport, so values, future identity (coalescing), counter
    arithmetic and DB writes are untouched — only *timing* changes, via
    deterministic latency noise before ``submit`` and ``drain``.  Pair
    it with a :class:`ChaosRunner` factory in the workers to shake both
    ends of the pipe at once.
    """

    def __init__(self, inner, seed: int = 0, noise_s: float = 0.02):
        self.inner = inner
        self.schedule = FaultSchedule(seed, faults=("noise",), period=2)
        self.noise_s = noise_s
        self.faults_injected = 0
        self._calls = 0

    @property
    def backend_key(self) -> str:
        return self.inner.backend_key

    @property
    def db(self):
        return getattr(self.inner, "db", None)

    def _maybe_noise(self, what: str) -> None:
        self._calls += 1
        if self.schedule.draw(f"{what}|{self._calls}") is None:
            return
        u = zlib.crc32(f"{what}|{self._calls}|u".encode()) % 1000 / 999.0
        self.faults_injected += 1
        time.sleep(self.noise_s * u)

    def submit(self, sites: Sequence, tiles) -> list:
        self._maybe_noise("submit")
        return self.inner.submit(sites, tiles)

    def drain(self) -> None:
        self._maybe_noise("drain")
        self.inner.drain()

    def close(self) -> None:
        self.inner.close()

    def health(self) -> str:
        h = getattr(self.inner, "health", None)
        return h() if callable(h) else "ok"

    def stats(self) -> dict:
        s = self.inner.stats()
        s["faults_injected"] = self.faults_injected
        return s

    def __enter__(self) -> "FaultInjectionTransport":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __getattr__(self, name):
        # transparent decorator: surface anything transport-specific the
        # tests poke at (worker_restarts, runner, ...)
        return getattr(self.inner, name)
