"""Process-wide metrics: ``Counter`` / ``Gauge`` / ``Histogram`` behind a
thread-safe :class:`MetricsRegistry`.

Zero dependencies by design — the tuning stack must stay importable on a
bare worker host — and cheap enough to leave on everywhere: a counter
increment is one dict update under an ``RLock``.  The registry is the
single naming authority for the ``<subsystem>_<noun>_<unit>`` convention
every ``stats()`` dict in the repo now shares (``transport_hits_total``,
``pool_queue_wait_seconds``, ``session_tunes_total``, ...).

Two read surfaces:

* :meth:`MetricsRegistry.snapshot` — a flat ``dict`` (histograms expand to
  ``{"count", "sum", "buckets"}`` with *cumulative* bucket counts), the
  programmatic view ``serve.py --metrics-out`` persists.
* :meth:`MetricsRegistry.render_prom` — Prometheus text exposition
  (``# TYPE`` / ``# HELP`` + samples, histogram ``_bucket{le=...}`` /
  ``_sum`` / ``_count``), what :mod:`repro.obs.exporter` serves over HTTP.

Instrumented objects whose counters live elsewhere (a transport's
``stats()`` block, :class:`~repro.core.env.MeasuredEnv`'s attribute
counters) register a *collector* — a zero-arg callable invoked before
every snapshot/render that syncs the latest values in
(:mod:`repro.obs.instrument` builds these).

The process-wide default registry is :func:`get_registry`; pass an
explicit :class:`MetricsRegistry` for isolation (tests, benchmarks).
"""
from __future__ import annotations

import math
import threading
from typing import Callable, Dict, Iterable, Optional, Sequence, Tuple

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "get_registry", "DEFAULT_LATENCY_BUCKETS"]

#: Fixed log-spaced latency buckets: two per decade from 1 microsecond to
#: 100 seconds (a kernel measurement, a tune, or a full fit all land
#: somewhere useful).  ``+Inf`` is implicit.
DEFAULT_LATENCY_BUCKETS: Tuple[float, ...] = tuple(
    round(10.0 ** (e / 2.0), 12) for e in range(-12, 5))

_VALID_FIRST = set("abcdefghijklmnopqrstuvwxyz"
                   "ABCDEFGHIJKLMNOPQRSTUVWXYZ_:")
_VALID_REST = _VALID_FIRST | set("0123456789")


def _check_name(name: str) -> str:
    if not name or name[0] not in _VALID_FIRST \
            or any(c not in _VALID_REST for c in name):
        raise ValueError(f"invalid metric name {name!r} (want "
                         f"[a-zA-Z_:][a-zA-Z0-9_:]*)")
    return name


def _label_key(labelnames: Sequence[str], labels: dict) -> Tuple[str, ...]:
    if set(labels) != set(labelnames):
        raise ValueError(f"expected labels {tuple(labelnames)}, "
                         f"got {tuple(labels)}")
    return tuple(str(labels[n]) for n in labelnames)


def _fmt_labels(labelnames: Sequence[str], values: Sequence[str]) -> str:
    if not labelnames:
        return ""
    esc = [str(v).replace("\\", r"\\").replace('"', r'\"')
           .replace("\n", r"\n") for v in values]
    inner = ",".join(f'{n}="{v}"' for n, v in zip(labelnames, esc))
    return "{" + inner + "}"


def _fmt_value(v: float) -> str:
    if math.isinf(v):
        return "+Inf" if v > 0 else "-Inf"
    return repr(v) if isinstance(v, float) else str(v)


class _Metric:
    """Shared machinery: one metric *family* = name + labelnames; each
    distinct label-value tuple is a child series.  An unlabelled family is
    its own single child, so ``counter("x").inc()`` just works."""

    kind = "untyped"

    def __init__(self, name: str, help: str, labelnames: Sequence[str],
                 lock: threading.RLock):
        self.name = _check_name(name)
        self.help = help
        self.labelnames = tuple(labelnames)
        for ln in self.labelnames:
            _check_name(ln)
        self._lock = lock
        self._series: Dict[Tuple[str, ...], object] = {}
        if not self.labelnames:
            self._series[()] = self._zero()

    def _zero(self):
        return 0.0

    def labels(self, **labels) -> "_Bound":
        key = _label_key(self.labelnames, labels)
        with self._lock:
            if key not in self._series:
                self._series[key] = self._zero()
        return _Bound(self, key)

    def _default_key(self) -> Tuple[str, ...]:
        if self.labelnames:
            raise ValueError(f"metric {self.name!r} has labels "
                             f"{self.labelnames}; call .labels(...) first")
        return ()

    # Every verb exists on every kind; the _-hooks raise TypeError for
    # kinds that don't support it (counter.observe, histogram.inc, ...)
    # so a wrong verb is a loud type error, never an AttributeError.
    def inc(self, amount: float = 1.0) -> None:
        self._inc(self._default_key(), amount)

    def dec(self, amount: float = 1.0) -> None:
        self._inc(self._default_key(), -amount)

    def set(self, value: float) -> None:
        self._set(self._default_key(), value)

    def observe(self, value: float) -> None:
        self._observe(self._default_key(), value)


class _Bound:
    """One labelled series of a family; proxies the family's verbs."""

    __slots__ = ("_metric", "_key")

    def __init__(self, metric: _Metric, key: Tuple[str, ...]):
        self._metric = metric
        self._key = key

    def inc(self, amount: float = 1.0) -> None:
        self._metric._inc(self._key, amount)

    def dec(self, amount: float = 1.0) -> None:
        self._metric._inc(self._key, -amount)

    def set(self, value: float) -> None:
        self._metric._set(self._key, value)

    def observe(self, value: float) -> None:
        self._metric._observe(self._key, value)

    @property
    def value(self):
        return self._metric._get(self._key)


class Counter(_Metric):
    """Monotonically increasing count (``*_total`` by convention)."""

    kind = "counter"

    def _inc(self, key, amount) -> None:
        if amount < 0:
            raise ValueError(f"counter {self.name!r} cannot decrease "
                             f"(got {amount})")
        with self._lock:
            self._series[key] = self._series.get(key, 0.0) + amount

    def _set(self, key, value) -> None:
        raise TypeError(f"counter {self.name!r} does not support set()")

    def _observe(self, key, value) -> None:
        raise TypeError(f"counter {self.name!r} does not support observe()")

    def _get(self, key):
        with self._lock:
            return self._series.get(key, 0.0)

    @property
    def value(self) -> float:
        return self._get(self._default_key())


class Gauge(_Metric):
    """A value that can go up and down (queue depth, breaker state)."""

    kind = "gauge"

    def _set(self, key, value) -> None:
        with self._lock:
            self._series[key] = float(value)

    def _inc(self, key, amount) -> None:
        with self._lock:
            self._series[key] = self._series.get(key, 0.0) + amount

    def _observe(self, key, value) -> None:
        raise TypeError(f"gauge {self.name!r} does not support observe()")

    def _get(self, key):
        with self._lock:
            return self._series.get(key, 0.0)

    @property
    def value(self) -> float:
        return self._get(self._default_key())


class _HistState:
    __slots__ = ("counts", "sum", "count")

    def __init__(self, n_buckets: int):
        self.counts = [0] * n_buckets       # per-bucket (non-cumulative)
        self.sum = 0.0
        self.count = 0


class Histogram(_Metric):
    """Fixed-bucket histogram (default: log-spaced latency buckets).

    ``observe(v)`` lands in the first bucket whose upper bound satisfies
    ``v <= le`` (Prometheus semantics); values above the last bound land
    in the implicit ``+Inf`` bucket.  ``snapshot`` exposes *cumulative*
    bucket counts keyed by the stringified bound.
    """

    kind = "histogram"

    def __init__(self, name, help, labelnames, lock,
                 buckets: Sequence[float] = DEFAULT_LATENCY_BUCKETS):
        b = tuple(float(x) for x in buckets)
        if not b or list(b) != sorted(b) or len(set(b)) != len(b):
            raise ValueError(f"buckets must be sorted and distinct: {b}")
        if math.isinf(b[-1]):
            b = b[:-1]                      # +Inf is implicit
        self.buckets = b
        super().__init__(name, help, labelnames, lock)

    def _zero(self):
        return _HistState(len(self.buckets) + 1)

    def _observe(self, key, value) -> None:
        value = float(value)
        i = len(self.buckets)
        for j, le in enumerate(self.buckets):       # ~17 bounds: linear scan
            if value <= le:
                i = j
                break
        with self._lock:
            st = self._series.get(key)
            if st is None:
                st = self._series[key] = self._zero()
            st.counts[i] += 1
            st.sum += value
            st.count += 1

    def _inc(self, key, amount) -> None:
        raise TypeError(f"histogram {self.name!r} does not support inc()")

    def _set(self, key, value) -> None:
        raise TypeError(f"histogram {self.name!r} does not support set()")

    def _get(self, key):
        with self._lock:
            st = self._series.get(key)
            if st is None:
                st = self._zero()
            cum, acc = {}, 0
            for le, c in zip(self.buckets, st.counts):
                acc += c
                cum[_fmt_value(le)] = acc
            cum["+Inf"] = acc + st.counts[-1]
            return {"count": st.count, "sum": st.sum, "buckets": cum}

    @property
    def value(self) -> dict:
        return self._get(self._default_key())


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class MetricsRegistry:
    """Thread-safe metric registry: get-or-create families by name.

    Re-requesting a name returns the existing family — with a
    ``ValueError`` if the kind or labelnames disagree (two subsystems
    silently sharing one name under different schemas is a bug).
    """

    def __init__(self):
        self._lock = threading.RLock()
        self._metrics: "Dict[str, _Metric]" = {}
        self._collectors: "list[Callable[[], None]]" = []

    # -- get-or-create -------------------------------------------------------
    def _get_or_create(self, kind: str, name: str, help: str,
                       labelnames: Sequence[str], **kw) -> _Metric:
        with self._lock:
            m = self._metrics.get(name)
            if m is not None:
                if m.kind != kind or m.labelnames != tuple(labelnames):
                    raise ValueError(
                        f"metric {name!r} already registered as {m.kind} "
                        f"with labels {m.labelnames}; cannot re-register "
                        f"as {kind} with labels {tuple(labelnames)}")
                return m
            m = _KINDS[kind](name, help, labelnames, self._lock, **kw)
            self._metrics[name] = m
            return m

    def counter(self, name: str, help: str = "",
                labelnames: Sequence[str] = ()) -> Counter:
        return self._get_or_create("counter", name, help, labelnames)

    def gauge(self, name: str, help: str = "",
              labelnames: Sequence[str] = ()) -> Gauge:
        return self._get_or_create("gauge", name, help, labelnames)

    def histogram(self, name: str, help: str = "",
                  labelnames: Sequence[str] = (),
                  buckets: Sequence[float] = DEFAULT_LATENCY_BUCKETS
                  ) -> Histogram:
        return self._get_or_create("histogram", name, help, labelnames,
                                   buckets=buckets)

    # -- collectors ----------------------------------------------------------
    def register_collector(self, fn: Callable[[], None]) -> Callable:
        """``fn()`` runs before every :meth:`snapshot`/:meth:`render_prom`
        — the sync point for counters that live on other objects.
        Returns ``fn`` (the unregister handle)."""
        with self._lock:
            self._collectors.append(fn)
        return fn

    def unregister_collector(self, fn: Callable[[], None]) -> None:
        with self._lock:
            try:
                self._collectors.remove(fn)
            except ValueError:
                pass

    def _collect(self) -> None:
        with self._lock:
            collectors = list(self._collectors)
        for fn in collectors:
            fn()

    # -- read surfaces -------------------------------------------------------
    def snapshot(self) -> dict:
        """Flat ``{series_name: value}`` dict; labelled series render as
        ``name{label="v",...}``, histograms as
        ``{"count", "sum", "buckets"}`` dicts."""
        self._collect()
        out = {}
        with self._lock:
            for name, m in sorted(self._metrics.items()):
                for key in sorted(m._series):
                    out[name + _fmt_labels(m.labelnames, key)] = m._get(key)
        return out

    def render_prom(self) -> str:
        """Prometheus text exposition format (version 0.0.4)."""
        self._collect()
        lines: "list[str]" = []
        with self._lock:
            for name, m in sorted(self._metrics.items()):
                if m.help:
                    lines.append(f"# HELP {name} {m.help}")
                lines.append(f"# TYPE {name} {m.kind}")
                for key in sorted(m._series):
                    if m.kind == "histogram":
                        v = m._get(key)
                        for le, c in v["buckets"].items():
                            ln = m.labelnames + ("le",)
                            lines.append(f"{name}_bucket"
                                         f"{_fmt_labels(ln, key + (le,))}"
                                         f" {c}")
                        lab = _fmt_labels(m.labelnames, key)
                        lines.append(f"{name}_sum{lab} "
                                     f"{_fmt_value(v['sum'])}")
                        lines.append(f"{name}_count{lab} {v['count']}")
                    else:
                        lines.append(
                            f"{name}{_fmt_labels(m.labelnames, key)} "
                            f"{_fmt_value(m._get(key))}")
        return "\n".join(lines) + "\n"


_GLOBAL = MetricsRegistry()


def get_registry() -> MetricsRegistry:
    """The process-wide default registry — what every facade/service
    instruments into unless handed an explicit one."""
    return _GLOBAL
