"""Retrofit instrumentation for the existing tuning seams — no behavior
change, by construction.

Every ``instrument_*`` function takes a *live instance* and wraps its
methods on the instance (never the class: two transports can feed two
registries in one process), guarded by an ``_obs_instrumented`` marker so
double-instrumentation is a no-op.  Wrappers call the original and return
its value untouched — the spy-based parity tests in ``tests/test_obs.py``
hold them to that.

Counters that already live on the instrumented object (a transport's
``stats()`` block, :class:`~repro.core.env.MeasuredEnv`'s attribute
counters, a store's ``hits``) are not double-booked: a *collector* —
registered on the registry, run before every snapshot/render — mirrors
them in as clamped deltas, so several instrumented instances sum
correctly into one registry and an instance that resets never drives a
counter backwards.

Lock ordering: wrapped methods and collectors may hold an instance lock
while touching the registry (registry ``RLock`` is the innermost lock);
nothing in this module calls back into an instrumented object while
holding the registry lock.

Each function returns an :class:`ObsHandle`; ``handle.close()``
unregisters the collectors (facades/services call it from their own
``close`` so a long-lived global registry does not accumulate dead
collectors).
"""
from __future__ import annotations

import time
from typing import Callable, List, Optional

from .metrics import MetricsRegistry
from .trace import NULL_TRACER

__all__ = ["ObsHandle", "instrument_transport", "instrument_pool",
           "instrument_fleet", "instrument_db", "instrument_env",
           "instrument_surrogate", "instrument_program_store",
           "instrument_serving"]

_MARK = "_obs_instrumented"


class ObsHandle:
    """Undo ticket for one ``instrument_*`` call: unregisters the
    collectors it added (instance-level method wraps stay — they are
    inert once nobody snapshots the registry)."""

    def __init__(self, registry: MetricsRegistry):
        self.registry = registry
        self._collectors: List[Callable[[], None]] = []
        self._children: List["ObsHandle"] = []

    def add_collector(self, fn: Callable[[], None]) -> None:
        self.registry.register_collector(fn)
        self._collectors.append(fn)

    def adopt(self, child: Optional["ObsHandle"]) -> None:
        if child is not None:
            self._children.append(child)

    def close(self) -> None:
        # final sync before detaching: counters accrued since the last
        # snapshot must land in the registry, not die with the collector
        for fn in self._collectors:
            try:
                fn()
            except Exception:
                pass
            self.registry.unregister_collector(fn)
        self._collectors.clear()
        for c in self._children:
            c.close()
        self._children.clear()


def _marked(obj, registry: MetricsRegistry) -> bool:
    """True (and leave the object alone) if ``obj`` is already feeding a
    registry — first instrumentation wins."""
    if getattr(obj, _MARK, None) is not None:
        return True
    try:
        setattr(obj, _MARK, id(registry))
    except (AttributeError, TypeError):    # __slots__ or frozen: skip
        return True
    return False


def _delta_sync(registry: MetricsRegistry, counter_map: dict,
                read: Callable[[], dict], help_map: Optional[dict] = None
                ) -> Callable[[], None]:
    """Build a collector mirroring absolute counters from ``read()`` into
    registry counters as clamped deltas.  ``counter_map`` is
    ``{source_key: metric_name}``."""
    counters = {src: registry.counter(name, (help_map or {}).get(name, ""))
                for src, name in counter_map.items()}
    last = dict.fromkeys(counter_map, 0.0)

    def collect() -> None:
        try:
            cur = read()
        except Exception:
            return                          # a dying source is not fatal
        for src, ctr in counters.items():
            v = float(cur.get(src, 0) or 0)
            d = v - last[src]
            if d > 0:
                ctr.inc(d)
            last[src] = v
    return collect


_HEALTH_CODE = {"ok": 0.0, "degraded": 1.0, "down": 2.0}


# -- transports ---------------------------------------------------------------
def instrument_transport(transport, registry: MetricsRegistry,
                         tracer=NULL_TRACER) -> Optional[ObsHandle]:
    """Any :class:`~repro.core.protocols.MeasureTransport`: submit/drain
    latency histograms + spans, counter mirror, in-flight gauge; the
    worker pool additionally gets its queue/worker instrumentation via
    :func:`instrument_pool`."""
    if _marked(transport, registry):
        return None
    h = ObsHandle(registry)
    submit_hist = registry.histogram(
        "transport_submit_seconds", "submit() call latency")
    drain_hist = registry.histogram(
        "transport_drain_seconds", "drain() wait latency")
    inflight = registry.gauge("transport_inflight_pairs",
                              "measurements currently in flight")
    health = registry.gauge("transport_health",
                            "0=ok 1=degraded 2=down")

    orig_submit, orig_drain = transport.submit, transport.drain

    def submit(sites, tiles):
        t0 = time.monotonic()
        with tracer.span("submit", n_pairs=len(sites)):
            out = orig_submit(sites, tiles)
        submit_hist.observe(time.monotonic() - t0)
        return out

    def drain():
        t0 = time.monotonic()
        with tracer.span("drain"):
            out = orig_drain()
        drain_hist.observe(time.monotonic() - t0)
        return out

    transport.submit, transport.drain = submit, drain

    sync = _delta_sync(registry, {
        "transport_hits_total": "transport_hits_total",
        "transport_misses_total": "transport_misses_total",
        "transport_coalesced_total": "transport_coalesced_total",
        "transport_timed_pairs_total": "transport_timed_pairs_total",
        "transport_failed_pairs_total": "transport_failed_pairs_total",
        "transport_retries_total": "transport_retries_total",
    }, transport.stats, help_map={
        "transport_hits_total": "pairs served from the DB",
        "transport_misses_total": "pairs that required a measurement",
        "transport_coalesced_total": "pairs folded onto in-flight work",
        "transport_timed_pairs_total": "successful measurements",
        "transport_failed_pairs_total": "measurements failed closed to inf",
        "transport_retries_total": "jobs requeued after a worker death",
    })

    def collect() -> None:
        sync()
        try:
            s = transport.stats()
        except Exception:
            return
        inflight.set(s.get("transport_inflight_pairs", 0))
        health.set(_HEALTH_CODE.get(s.get("health", "ok"), 0.0))

    h.add_collector(collect)
    h.adopt(instrument_pool(transport, registry))
    h.adopt(instrument_fleet(transport, registry))
    if getattr(transport, "db", None) is not None:
        h.adopt(instrument_db(transport.db, registry))
    return h


def instrument_pool(pool, registry: MetricsRegistry) -> Optional[ObsHandle]:
    """WorkerPool-specific metrics: queue depth, restarts, quarantine,
    and the per-job queue-wait vs in-flight split (the pool's
    ``job_observer`` seam feeds the two histograms)."""
    if not hasattr(pool, "worker_restarts"):       # not a worker pool
        return None
    h = ObsHandle(registry)
    qwait = registry.histogram("pool_queue_wait_seconds",
                               "per-job time spent queued (incl. requeues)")
    run = registry.histogram("pool_run_seconds",
                             "per-job time in flight on a worker")
    depth = registry.gauge("pool_queue_depth", "jobs waiting for a worker")
    workers = registry.gauge("pool_workers_count", "configured pool size")
    live = registry.gauge("pool_workers_live", "dispatchers still running")

    def observer(queue_wait_s: float, run_s: float) -> None:
        qwait.observe(queue_wait_s)
        run.observe(run_s)
    pool.job_observer = observer

    sync = _delta_sync(registry, {
        "pool_worker_restarts_total": "pool_worker_restarts_total",
        "pool_quarantined_total": "pool_quarantined_total",
    }, pool.stats, help_map={
        "pool_worker_restarts_total": "worker respawns after a death",
        "pool_quarantined_total": "poison pairs quarantined in the DB",
    })

    def collect() -> None:
        sync()
        with pool._cv:
            depth.set(len(pool._pending))
            live.set(pool._live)
        workers.set(pool.workers)

    h.add_collector(collect)
    return h


def instrument_fleet(transport, registry: MetricsRegistry
                     ) -> Optional[ObsHandle]:
    """:class:`~repro.fleet.SocketTransport`-specific metrics (gated on
    its ``host_states`` seam): fleet-wide queue depth and live-host
    gauges, plus per-host labelled up/jobs/reconnects series so a
    dashboard can tell *which* serve-worker host is flapping."""
    if not hasattr(transport, "host_states"):      # not a fleet transport
        return None
    h = ObsHandle(registry)
    depth = registry.gauge("fleet_queue_depth",
                           "jobs waiting for a serve-worker slot")
    hosts_n = registry.gauge("fleet_hosts_count", "configured fleet size")
    hosts_live = registry.gauge("fleet_hosts_live",
                                "hosts currently connected")
    host_up = registry.gauge("fleet_host_up",
                             "1 while this serve-worker host is connected",
                             labelnames=("host",))
    host_jobs = registry.counter("fleet_host_jobs_total",
                                 "results returned by this host",
                                 labelnames=("host",))
    host_reconn = registry.counter("fleet_host_reconnects_total",
                                   "connections re-established to this host",
                                   labelnames=("host",))
    sync = _delta_sync(registry, {
        "fleet_reconnects_total": "fleet_reconnects_total",
        "fleet_quarantined_total": "fleet_quarantined_total",
    }, transport.stats, help_map={
        "fleet_reconnects_total": "connections re-established fleet-wide",
        "fleet_quarantined_total": "poison pairs quarantined in the DB",
    })
    last = {}                                      # per-host counter floors

    def collect() -> None:
        sync()
        try:
            s = transport.stats()
        except Exception:
            return
        depth.set(s.get("fleet_queue_depth", 0))
        hosts_n.set(s.get("fleet_hosts_count", 0))
        hosts_live.set(s.get("fleet_hosts_live", 0))
        for name, hs in (s.get("hosts") or {}).items():
            host_up.labels(host=name).set(
                1.0 if hs.get("state") == "connected" else 0.0)
            for src, ctr in (("jobs_done", host_jobs),
                             ("reconnects", host_reconn)):
                v = float(hs.get(src, 0) or 0)
                prev = last.get((name, src), 0.0)
                if v > prev:                       # clamped delta
                    ctr.labels(host=name).inc(v - prev)
                last[(name, src)] = v

    h.add_collector(collect)
    return h


# -- stores -------------------------------------------------------------------
def instrument_db(db, registry: MetricsRegistry) -> Optional[ObsHandle]:
    """:class:`~repro.measure.db.MeasureDB`: lookup hit/miss counters
    (wrapped at ``get`` — the transport-level hit counter only sees
    submit-time lookups; this one sees every consumer) plus corrupt-line
    and quarantine mirrors."""
    if _marked(db, registry):
        return None
    h = ObsHandle(registry)
    hits = registry.counter("measuredb_hits_total", "get() served a value")
    misses = registry.counter("measuredb_misses_total", "get() found nothing")
    puts = registry.counter("measuredb_puts_total", "records appended")

    orig_get, orig_put = db.get, db.put

    def get(key):
        v = orig_get(key)
        (misses if v is None else hits).inc()
        return v

    def put(key, value):
        out = orig_put(key, value)
        puts.inc()
        return out

    db.get, db.put = get, put

    def read() -> dict:
        return {"skipped_lines": db.skipped_lines,
                "quarantined": db.n_quarantined}
    h.add_collector(_delta_sync(registry, {
        "skipped_lines": "measuredb_corrupt_lines_total",
        "quarantined": "measuredb_quarantined_total",
    }, read, help_map={
        "measuredb_corrupt_lines_total": "unparseable JSONL lines skipped",
        "measuredb_quarantined_total": "poison keys reading back as inf",
    }))
    return h


def instrument_program_store(store, registry: MetricsRegistry
                             ) -> Optional[ObsHandle]:
    """:class:`~repro.artifacts.ProgramStore`: warm-hit/miss mirror +
    entry count gauge."""
    if store is None or _marked(store, registry):
        return None
    h = ObsHandle(registry)
    entries = registry.gauge("store_programs_count", "programs held")
    sync = _delta_sync(registry, {
        "hits": "store_warm_hits_total",
        "misses": "store_misses_total",
        "skipped_lines": "store_corrupt_lines_total",
    }, store.stats, help_map={
        "store_warm_hits_total": "tunes answered by program lookup",
        "store_misses_total": "tunes that ran agent inference",
        "store_corrupt_lines_total": "unparseable JSONL lines skipped",
    })

    def collect() -> None:
        sync()
        try:
            entries.set(len(store))
        except Exception:
            pass
    h.add_collector(collect)
    return h


# -- serving ------------------------------------------------------------------
def instrument_serving(server, registry: MetricsRegistry
                       ) -> Optional[ObsHandle]:
    """:class:`~repro.serving.Server`: queue-wait and end-to-end tune
    latency histograms plus a batch-size histogram via the server's
    ``request_observer`` seam (the serving analogue of the pool's
    ``job_observer``), a queue-depth/health gauge collector, and clamped
    counter mirrors for requests/sheds/deadline-misses/batches and the
    fused one-dispatch counters."""
    if server is None or _marked(server, registry):
        return None
    h = ObsHandle(registry)
    qwait = registry.histogram("serving_queue_wait_seconds",
                               "per-request time in the admission queue")
    lat = registry.histogram("serving_tune_seconds",
                             "end-to-end request latency (admit -> result)")
    bsize = registry.histogram("serving_batch_requests",
                               "requests coalesced per flushed batch")
    depth = registry.gauge("serving_queue_depth",
                           "requests awaiting a batch")
    health = registry.gauge("serving_health", "0=ok 1=degraded 2=down")

    def observer(event: str, queue_wait_s: float = 0.0,
                 latency_s: float = 0.0, batch_requests: int = 0,
                 **_fields) -> None:
        if event == "complete":
            qwait.observe(queue_wait_s)
            lat.observe(latency_s)
        elif event == "store_hit":
            lat.observe(latency_s)
        elif event == "batch":
            bsize.observe(batch_requests)
    server.request_observer = observer

    sync = _delta_sync(registry, {
        "serving_requests_total": "serving_requests_total",
        "serving_shed_total": "serving_shed_total",
        "serving_deadline_misses_total": "serving_deadline_misses_total",
        "serving_batches_total": "serving_batches_total",
        "serving_store_hits_total": "serving_store_hits_total",
        "serving_fused_dispatches_total": "serving_fused_dispatches_total",
        "serving_fused_traces_total": "serving_fused_traces_total",
    }, server.stats, help_map={
        "serving_requests_total": "tune requests admitted (incl. warm)",
        "serving_shed_total": "requests rejected at max_queue depth",
        "serving_deadline_misses_total":
            "requests whose SLO budget expired before execution",
        "serving_batches_total": "batches flushed",
        "serving_store_hits_total":
            "requests answered by program lookup at admission",
        "serving_fused_dispatches_total":
            "fused cost-grid device dispatches",
        "serving_fused_traces_total": "fused cost-grid jit (re)traces",
    })

    def collect() -> None:
        sync()
        try:
            s = server.stats()
        except Exception:
            return
        depth.set(s.get("serving_queue_depth", 0))
        health.set(_HEALTH_CODE.get(s.get("health", "ok"), 0.0))

    h.add_collector(collect)
    return h


# -- oracles ------------------------------------------------------------------
def instrument_env(env, registry: MetricsRegistry,
                   tracer=NULL_TRACER) -> Optional[ObsHandle]:
    """:class:`~repro.core.env.MeasuredEnv`: measured-vs-surrogate-priced
    pair mirror, breaker state gauge, measure-batch latency histogram."""
    if not hasattr(env, "breaker_open") or _marked(env, registry):
        return None
    h = ObsHandle(registry)
    batch_hist = registry.histogram("env_measure_batch_seconds",
                                    "_measured_costs() batch latency")
    breaker = registry.gauge("env_breaker_open",
                             "1 while the measurement circuit breaker "
                             "is open (analytic fallback)")

    orig = env._measured_costs

    def _measured_costs(sites, tiles):
        t0 = time.monotonic()
        out = orig(sites, tiles)
        batch_hist.observe(time.monotonic() - t0)
        return out
    env._measured_costs = _measured_costs

    def read() -> dict:
        return {"measure_calls": env.measure_calls,
                "measured_pairs": env.measured_pairs,
                "pruned_pairs": env.pruned_pairs}
    sync = _delta_sync(registry, {
        "measure_calls": "env_measure_calls_total",
        "measured_pairs": "env_measured_pairs_total",
        "pruned_pairs": "env_surrogate_priced_pairs_total",
    }, read, help_map={
        "env_measure_calls_total": "measure-hook invocations",
        "env_measured_pairs_total": "(site, tile) pairs sent to hardware",
        "env_surrogate_priced_pairs_total":
            "pairs priced by the surrogate instead of measured",
    })

    def collect() -> None:
        sync()
        breaker.set(1.0 if env.breaker_open else 0.0)
    h.add_collector(collect)
    return h


def instrument_surrogate(oracle, registry: MetricsRegistry
                         ) -> Optional[ObsHandle]:
    """:class:`~repro.surrogate.SurrogateOracle` (or a
    :class:`MeasuredEnv`'s attached surrogate path): predict latency +
    result-cache hit counters, derived from the cache-size delta around
    each ``_surrogate_costs`` call."""
    if not hasattr(oracle, "_surrogate_costs") or _marked(oracle, registry):
        return None
    h = ObsHandle(registry)
    predict_hist = registry.histogram("surrogate_predict_seconds",
                                      "surrogate pricing-call latency")
    predicted = registry.counter("surrogate_predicted_pairs_total",
                                 "pairs priced by a fresh model prediction")
    cache_hits = registry.counter("surrogate_cache_hits_total",
                                  "pairs served from the result cache")

    orig = oracle._surrogate_costs

    def _surrogate_costs(sites, tiles):
        before = len(oracle._result_cache)
        t0 = time.monotonic()
        out = orig(sites, tiles)
        predict_hist.observe(time.monotonic() - t0)
        fresh = len(oracle._result_cache) - before
        if fresh > 0:
            predicted.inc(fresh)
        served = len(sites) - max(fresh, 0)
        if served > 0:
            cache_hits.inc(served)
        return out
    oracle._surrogate_costs = _surrogate_costs
    return h


def instrument_oracle_stack(oracle, registry: MetricsRegistry,
                            tracer=NULL_TRACER) -> ObsHandle:
    """Walk one oracle's dependency stack — env, its surrogate, its
    measure transport and DB — and instrument whatever is present.  Safe
    on any oracle (a plain :class:`CostModelEnv` yields an empty
    handle)."""
    h = ObsHandle(registry)
    h.adopt(instrument_env(oracle, registry, tracer))
    h.adopt(instrument_surrogate(oracle, registry))
    sur = getattr(oracle, "surrogate", None)
    if sur is not None and hasattr(sur, "_surrogate_costs"):
        h.adopt(instrument_surrogate(sur, registry))
    fn = getattr(oracle, "measure_fn", None)
    transport = getattr(fn, "transport", None)
    if transport is not None:
        h.adopt(instrument_transport(transport, registry, tracer))
    return h
