"""``repro.obs`` — the shared telemetry substrate: metrics, traces, and
retrofit instrumentation for the tuning stack.

* :mod:`repro.obs.metrics` — zero-dep Counter/Gauge/Histogram registry
  with ``snapshot()`` and Prometheus ``render_prom()``.
* :mod:`repro.obs.trace` — JSONL span tracing (monotonic clock, implicit
  parent links) + ``to_chrome_trace()`` for chrome://tracing.
* :mod:`repro.obs.instrument` — wrap live transports / envs / stores /
  oracles into a registry without behavior change.
* :mod:`repro.obs.exporter` — stdlib HTTP endpoint serving
  ``render_prom()`` (``serve.py --metrics-port``).

The facade and service wire all of this by default into the process-wide
registry (:func:`get_registry`); tracing is opt-in
(``NeuroVectorizer(trace="t.jsonl")``, ``serve.py --trace-out``).
"""
from repro.obs.exporter import MetricsServer
from repro.obs.instrument import (ObsHandle, instrument_db, instrument_env,
                                  instrument_fleet, instrument_oracle_stack,
                                  instrument_pool, instrument_program_store,
                                  instrument_serving, instrument_surrogate,
                                  instrument_transport)
from repro.obs.metrics import (DEFAULT_LATENCY_BUCKETS, Counter, Gauge,
                               Histogram, MetricsRegistry, get_registry)
from repro.obs.trace import (NULL_TRACER, NullTracer, Span, Tracer,
                             read_trace, to_chrome_trace)

__all__ = [
    "MetricsRegistry", "Counter", "Gauge", "Histogram", "get_registry",
    "DEFAULT_LATENCY_BUCKETS",
    "Tracer", "NullTracer", "NULL_TRACER", "Span", "read_trace",
    "to_chrome_trace",
    "MetricsServer",
    "ObsHandle", "instrument_transport", "instrument_pool",
    "instrument_fleet", "instrument_db",
    "instrument_env", "instrument_surrogate", "instrument_program_store",
    "instrument_oracle_stack", "instrument_serving",
    "resolve_obs",
]


def resolve_obs(metrics=None, trace=None):
    """Resolve the facade/service ``metrics=`` / ``trace=`` arguments.

    ``metrics``: ``None`` → the process-wide registry (metrics on by
    default), ``False`` → disabled (an isolated throwaway registry no
    one snapshots), or an explicit :class:`MetricsRegistry`.

    ``trace``: ``None``/``False`` → off (:data:`NULL_TRACER`), a path →
    a new *owned* :class:`Tracer` (the caller closes it), or a ``Tracer``
    instance → borrowed.

    Returns ``(registry, tracer, owns_tracer)``.
    """
    if metrics is None:
        registry = get_registry()
    elif metrics is False:
        registry = MetricsRegistry()
    elif isinstance(metrics, MetricsRegistry):
        registry = metrics
    else:
        raise TypeError(f"metrics= expects None, False, or a "
                        f"MetricsRegistry, got {type(metrics).__name__}")
    if trace is None or trace is False:
        return registry, NULL_TRACER, False
    if isinstance(trace, str):
        return registry, Tracer(trace), True
    if isinstance(trace, (Tracer, NullTracer)):
        return registry, trace, False
    raise TypeError(f"trace= expects None, a path, or a Tracer, "
                    f"got {type(trace).__name__}")
