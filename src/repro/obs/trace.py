"""Structured span tracing — the *when* the metrics registry cannot hold.

A :class:`Tracer` appends one JSON line per finished span (or instant
event) to a trace file: name, monotonic-clock start/duration anchored to
wall time, span id, parent id, thread, attributes, and the exception type
if the span body raised.  Parentage is implicit — a span opened while
another is open on the same thread becomes its child — with an explicit
``parent=`` override for work that hops threads (a session's
``tune_async`` runs on the service's pool, yet its span must hang off the
session's root).

The file is plain JSONL so it can be grepped, tailed, and diffed;
:func:`to_chrome_trace` converts it to the Chrome/Perfetto trace-event
JSON (open ``chrome://tracing`` or https://ui.perfetto.dev and load the
converted file) for a visual timeline of a whole tuning run:
``session`` → ``fit`` → ``tune`` → ``submit``/``drain`` batches.

Tracing off is the default everywhere: :data:`NULL_TRACER` swallows every
call at the cost of one attribute lookup, so instrumented code paths need
no ``if tracing:`` branches.
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Optional, Union

# one shared encoder: json.dumps(..., default=str) builds a fresh
# JSONEncoder per call, which dominates the span write path
_ENCODER = json.JSONEncoder(separators=(",", ":"), default=str)

__all__ = ["Span", "Tracer", "NullTracer", "NULL_TRACER", "read_trace",
           "to_chrome_trace"]


class Span:
    """One open span; close with :meth:`end` (or use as context manager —
    the body raising still closes the span, recording the error)."""

    __slots__ = ("tracer", "name", "id", "parent", "attrs", "t0", "tid")

    def __init__(self, tracer: "Tracer", name: str, span_id: int,
                 parent: Optional[int], attrs: dict):
        self.tracer = tracer
        self.name = name
        self.id = span_id
        self.parent = parent
        self.attrs = attrs
        self.t0 = time.monotonic()
        self.tid = threading.get_ident()

    def set(self, **attrs) -> "Span":
        """Attach/overwrite attributes before the span ends."""
        self.attrs.update(attrs)
        return self

    def end(self, error: Optional[str] = None) -> None:
        self.tracer._end(self, error)

    def __enter__(self) -> "Span":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.end(error=None if exc_type is None
                 else f"{exc_type.__name__}: {exc}")


class _NullSpan:
    """The do-nothing span: every verb is a no-op, so disabled tracing
    costs one method call and nothing else."""

    __slots__ = ()
    id = None
    parent = None

    def set(self, **attrs) -> "_NullSpan":
        return self

    def end(self, error=None) -> None:
        pass

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> None:
        pass


_NULL_SPAN = _NullSpan()


class Tracer:
    """Append-only JSONL span writer with implicit per-thread nesting.

    ``path`` is opened lazily on the first record (mode ``"w"`` truncates
    by default — one trace file per run; pass ``mode="a"`` to accumulate).
    Thread-safe: span ids and file writes are serialized under one lock;
    the open-span stack is thread-local, so concurrent sessions nest
    correctly without seeing each other.
    """

    enabled = True

    def __init__(self, path: str, mode: str = "w"):
        self.path = path
        self._mode = mode
        self._fh = None
        self._lock = threading.Lock()
        self._local = threading.local()
        self._next_id = 1
        self.n_spans = 0
        self.n_events = 0
        # wall-clock anchor for the monotonic timestamps: one pair taken
        # at construction, so all spans share a consistent absolute axis
        self._anchor_wall = time.time()
        self._anchor_mono = time.monotonic()
        self._unflushed = 0
        self._last_flush = self._anchor_mono

    # -- the write path ------------------------------------------------------
    def _stack(self) -> list:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    def _wall(self, mono: float) -> float:
        return self._anchor_wall + (mono - self._anchor_mono)

    def _write(self, rec: dict) -> None:
        line = _ENCODER.encode(rec)
        with self._lock:
            if self._fh is None:
                d = os.path.dirname(os.path.abspath(self.path))
                os.makedirs(d, exist_ok=True)
                self._fh = open(self.path, self._mode)
            self._fh.write(line + "\n")
            # flush periodically, not per record: a crash loses at most
            # ~1s / 64 spans of trail, and the hot path skips the syscall
            self._unflushed += 1
            now = time.monotonic()
            if self._unflushed >= 64 or now - self._last_flush >= 1.0:
                self._fh.flush()
                self._unflushed = 0
                self._last_flush = now

    # -- spans ---------------------------------------------------------------
    def begin(self, name: str, parent: Union[int, Span, None] = None,
              detached: bool = False, **attrs) -> Span:
        """Open a span.  ``parent`` defaults to the innermost open span on
        *this thread*; pass a :class:`Span` (or its id) explicitly when
        the logical parent lives on another thread.  ``detached=True``
        keeps the span off this thread's implicit-parent stack — for
        long-lived roots (a session) whose children arrive from many
        threads with explicit ``parent=`` links."""
        with self._lock:
            span_id = self._next_id
            self._next_id += 1
        if parent is None:
            st = self._stack()
            parent_id = st[-1].id if st else None
        else:
            parent_id = parent.id if isinstance(parent, Span) else int(parent)
        span = Span(self, name, span_id, parent_id, attrs)
        if not detached:
            self._stack().append(span)
        return span

    def span(self, name: str, parent: Union[int, Span, None] = None,
             **attrs) -> Span:
        """Context-manager spelling of :meth:`begin`::

            with tracer.span("tune", n_sites=len(sites)):
                ...
        """
        return self.begin(name, parent=parent, **attrs)

    def _end(self, span: Span, error: Optional[str]) -> None:
        t1 = time.monotonic()
        st = self._stack()
        # exception-safe pop: the span may be closed out of order (or from
        # a different thread than it was opened on) — remove, don't assert
        for i in range(len(st) - 1, -1, -1):
            if st[i] is span:
                del st[i]
                break
        rec = {"type": "span", "name": span.name, "id": span.id,
               "parent": span.parent, "ts": self._wall(span.t0),
               "dur": t1 - span.t0, "pid": os.getpid(), "tid": span.tid}
        if span.attrs:
            rec["attrs"] = span.attrs
        if error is not None:
            rec["error"] = error
        self._write(rec)
        with self._lock:
            self.n_spans += 1

    # -- instants ------------------------------------------------------------
    def event(self, name: str, **attrs) -> None:
        """A zero-duration instant (e.g. a straggler flag), parented to
        the innermost open span on this thread."""
        st = self._stack()
        rec = {"type": "event", "name": name,
               "parent": st[-1].id if st else None,
               "ts": self._wall(time.monotonic()),
               "pid": os.getpid(), "tid": threading.get_ident()}
        if attrs:
            rec["attrs"] = attrs
        self._write(rec)
        with self._lock:
            self.n_events += 1

    # -- lifecycle -----------------------------------------------------------
    def flush(self) -> None:
        with self._lock:
            if self._fh is not None:
                self._fh.flush()
                self._unflushed = 0
                self._last_flush = time.monotonic()

    def close(self) -> None:
        with self._lock:
            if self._fh is not None:
                self._fh.close()
                self._fh = None

    def __enter__(self) -> "Tracer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class NullTracer:
    """Tracing disabled: every span/event is a shared no-op object."""

    enabled = False
    path = None
    n_spans = 0
    n_events = 0

    def begin(self, name, parent=None, detached=False, **attrs) -> _NullSpan:
        return _NULL_SPAN

    def span(self, name, parent=None, **attrs) -> _NullSpan:
        return _NULL_SPAN

    def event(self, name, **attrs) -> None:
        pass

    def flush(self) -> None:
        pass

    def close(self) -> None:
        pass

    def __enter__(self) -> "NullTracer":
        return self

    def __exit__(self, *exc) -> None:
        pass


NULL_TRACER = NullTracer()


def read_trace(path: str) -> list:
    """Parse a trace file back into a list of record dicts (corrupt or
    torn lines are skipped, matching the MeasureDB discipline)."""
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except ValueError:
                continue
            if isinstance(rec, dict) and "name" in rec:
                out.append(rec)
    return out


def to_chrome_trace(trace: Union[str, list]) -> dict:
    """Convert a JSONL trace (path or pre-read record list) to the
    Chrome/Perfetto trace-event format: ``{"traceEvents": [...]}`` with
    complete (``"X"``) events for spans and instant (``"i"``) events.

    Span/parent ids survive in ``args`` (chrome's flow UI does not model
    a parent pointer; the nesting is reconstructed from timing per tid,
    which matches because children are contained in their parents).
    Timestamps are microseconds as the format requires.
    """
    records = read_trace(trace) if isinstance(trace, str) else trace
    events = []
    for r in records:
        args = dict(r.get("attrs") or {})
        if r.get("id") is not None:
            args["span_id"] = r["id"]
        if r.get("parent") is not None:
            args["parent_id"] = r["parent"]
        if r.get("error") is not None:
            args["error"] = r["error"]
        base = {"name": r["name"], "cat": "repro",
                "pid": r.get("pid", 0), "tid": r.get("tid", 0),
                "ts": float(r.get("ts", 0.0)) * 1e6, "args": args}
        if r.get("type") == "event":
            events.append({**base, "ph": "i", "s": "t"})
        else:
            events.append({**base, "ph": "X",
                           "dur": float(r.get("dur", 0.0)) * 1e6})
    return {"traceEvents": events, "displayTimeUnit": "ms"}
