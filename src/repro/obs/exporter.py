"""One-file stdlib Prometheus endpoint: serve ``render_prom()`` on
``GET /metrics`` so a scraper (or ``curl``) can watch a tuning run live.

No dependencies — :class:`http.server.ThreadingHTTPServer` on a daemon
thread.  ``serve.py --metrics-port N`` owns one of these for the life of
the run; tests bind port 0 and read :attr:`MetricsServer.port` back.
"""
from __future__ import annotations

import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Optional

from .metrics import MetricsRegistry, get_registry

__all__ = ["MetricsServer"]

_CONTENT_TYPE = "text/plain; version=0.0.4; charset=utf-8"


class _Handler(BaseHTTPRequestHandler):
    registry: MetricsRegistry = None  # set by MetricsServer per-class

    def do_GET(self):  # noqa: N802 (http.server API)
        if self.path.split("?", 1)[0] not in ("/metrics", "/"):
            self.send_error(404, "try /metrics")
            return
        try:
            body = self.registry.render_prom().encode("utf-8")
        except Exception as e:  # never take the endpoint down with the scrape
            self.send_error(500, f"render failed: {type(e).__name__}")
            return
        self.send_response(200)
        self.send_header("Content-Type", _CONTENT_TYPE)
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def log_message(self, fmt, *args):  # scrapes are not stdout news
        pass


class MetricsServer:
    """Background HTTP server exposing a registry in Prometheus text format.

    >>> srv = MetricsServer(port=0)          # 0 = ephemeral, read .port
    >>> srv.start()
    >>> # curl http://localhost:{srv.port}/metrics
    >>> srv.close()
    """

    def __init__(self, port: int = 0, host: str = "127.0.0.1",
                 registry: Optional[MetricsRegistry] = None):
        self.registry = registry if registry is not None else get_registry()
        # a per-instance handler subclass so two servers can expose two
        # different registries in one process (tests do exactly this)
        handler = type("_BoundHandler", (_Handler,),
                       {"registry": self.registry})
        self._httpd = ThreadingHTTPServer((host, port), handler)
        self._httpd.daemon_threads = True
        self._thread: Optional[threading.Thread] = None

    @property
    def port(self) -> int:
        return self._httpd.server_address[1]

    def start(self) -> "MetricsServer":
        if self._thread is None:
            self._thread = threading.Thread(
                target=self._httpd.serve_forever, kwargs={"poll_interval": 0.1},
                name="obs-metrics-http", daemon=True)
            self._thread.start()
        return self

    def close(self) -> None:
        if self._thread is not None:
            self._httpd.shutdown()
            self._thread.join(timeout=5.0)
            self._thread = None
        self._httpd.server_close()

    def __enter__(self) -> "MetricsServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()
