"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, parallelizable)
and sLSTM (scalar memory, true recurrence), both with stabilized
exponential gating.

mLSTM training uses a chunkwise-parallel form (lax.scan over chunks carrying
the matrix state C (hd x hd), normalizer n and stabilizer m) — the same
compute shape as the SSD chunk scan, so the Pallas chunk kernel applies.
sLSTM is inherently serial (recurrent nonlinearity) and runs as a
lax.scan over time with per-head block-diagonal recurrent weights.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import compute
from repro.models.common import dense_init, split_keys


def _logsig(x):
    return -jax.nn.softplus(-x)


# ===========================================================================
# mLSTM
# ===========================================================================

def mlstm_init(cfg: ModelConfig, key, dtype):
    d = cfg.d_model
    di = int(cfg.xlstm_proj_factor * d)
    h = cfg.n_heads
    hd = di // h
    ks = split_keys(key, 8)
    return {
        "up": dense_init(ks[0], (d, 2 * di), dtype),
        "wq": dense_init(ks[1], (h, hd, hd), dtype),
        "wk": dense_init(ks[2], (h, hd, hd), dtype),
        "wv": dense_init(ks[3], (h, hd, hd), dtype),
        "w_i": dense_init(ks[4], (di, h), jnp.float32, scale=0.01),
        "w_f": dense_init(ks[5], (di, h), jnp.float32, scale=0.01),
        "b_f": jnp.full((h,), 3.0, jnp.float32),   # forget-biased init
        "gn": jnp.ones((di,), dtype),
        "down": dense_init(ks[6], (di, d), dtype),
    }


def _mlstm_qkv(cfg, p, xi):
    """xi: (B,S,di) -> q,k,v (B,S,h,hd) via per-head block-diagonal proj."""
    B, S, di = xi.shape
    h = cfg.n_heads
    hd = di // h
    xh = xi.reshape(B, S, h, hd)
    q = compute.einsum("bshd,hde->bshe", xh, p["wq"], site="mlstm.q")
    k = compute.einsum("bshd,hde->bshe", xh, p["wk"], site="mlstm.k")
    v = compute.einsum("bshd,hde->bshe", xh, p["wv"], site="mlstm.v")
    return q, k * (1.0 / (hd ** 0.5)), v


def apply_mlstm(cfg: ModelConfig, p, x, *, cache: Optional[dict] = None,
                decode_pos=None, chunk: int = 256):
    """x: (B,S,d). Cache: {"C": (B,h,hd,hd) f32, "n": (B,h,hd) f32,
    "m": (B,h) f32}. Returns (y, new_cache_or_None)."""
    B, S, d = x.shape
    di = int(cfg.xlstm_proj_factor * d)
    h = cfg.n_heads
    hd = di // h
    up = compute.matmul(x, p["up"], site="mlstm.up")
    xi, z = up[..., :di], up[..., di:]
    q, k, v = _mlstm_qkv(cfg, p, xi)
    li = jnp.einsum("bsd,dh->bsh", xi.astype(jnp.float32), p["w_i"])
    lf = _logsig(jnp.einsum("bsd,dh->bsh", xi.astype(jnp.float32), p["w_f"])
                 + p["b_f"])

    if cache is not None and decode_pos is not None and S == 1:
        # ---------- O(1) decode ----------
        C0, n0, m0 = cache["C"], cache["n"], cache["m"]
        lf0, li0 = lf[:, 0], li[:, 0]                    # (B,h)
        m1 = jnp.maximum(lf0 + m0, li0)
        fg = jnp.exp(lf0 + m0 - m1)[..., None, None]
        ig = jnp.exp(li0 - m1)[..., None, None]
        kf = k[:, 0].astype(jnp.float32)                      # (B,h,hd)
        vf = v[:, 0].astype(jnp.float32)
        qf = q[:, 0].astype(jnp.float32)
        C1 = fg * C0 + ig * kf[..., :, None] * vf[..., None, :]
        n1 = fg[..., 0] * n0 + ig[..., 0] * kf
        num = jnp.einsum("bhd,bhde->bhe", qf, C1)
        den = jnp.abs(jnp.einsum("bhd,bhd->bh", qf, n1))
        y = num / jnp.maximum(den, jnp.exp(-m1))[..., None]
        y = y.reshape(B, 1, di)
        y = _mlstm_out(cfg, p, y.astype(x.dtype), z)
        return y, {"C": C1, "n": n1, "m": m1}

    # ---------- chunkwise-parallel ----------
    if compute._STATE.recorder is not None:
        compute._STATE.recorder.record(compute.KernelSite(
            site="mlstm.chunk_scan", kind="chunk_scan", m=min(chunk, S),
            n=hd, k=hd, batch=B * h * (S // max(1, min(chunk, S))),
            dtype=str(x.dtype)))
    # NOTE: hd-sharding q/k/v here was tried and refuted — GSPMD padding/
    # resharding nearly doubled executed FLOPs (EXPERIMENTS.md Cell C it2)
    Q = min(chunk, S)
    Sp = -(-S // Q) * Q
    if Sp != S:
        # identity padding: i-gate -inf (no write), f-gate log-decay 0
        pad = ((0, 0), (0, Sp - S), (0, 0), (0, 0))
        q, k, v = (jnp.pad(t, pad) for t in (q, k, v))
        li = jnp.pad(li, ((0, 0), (0, Sp - S), (0, 0)),
                     constant_values=-1e30)
        lf = jnp.pad(lf, ((0, 0), (0, Sp - S), (0, 0)))
    nc = Sp // Q
    qc = q.reshape(B, nc, Q, h, hd).astype(jnp.float32)
    kc = k.reshape(B, nc, Q, h, hd).astype(jnp.float32)
    vc = v.reshape(B, nc, Q, h, hd).astype(jnp.float32)
    lic = li.reshape(B, nc, Q, h)
    lfc = lf.reshape(B, nc, Q, h)

    if cache is not None:
        init = (cache["C"], cache["n"], cache["m"])
    else:
        init = (jnp.zeros((B, h, hd, hd), jnp.float32),
                jnp.zeros((B, h, hd), jnp.float32),
                jnp.full((B, h), -1e30, jnp.float32))

    causal = jnp.tril(jnp.ones((Q, Q), bool))

    def body(carry, inp):
        C0, n0, m0 = carry
        qi, ki, vi, lii, lfi = inp              # (B,Q,h,hd)/(B,Q,h)
        b = jnp.cumsum(lfi, axis=1)             # inclusive (B,Q,h)
        # D_ij = b_i - b_j + li_j (j<=i)
        D = b[:, :, None] - b[:, None, :, :] + lii[:, None]
        D = jnp.where(causal[None, :, :, None], D, -jnp.inf)
        m_intra = D.max(axis=2)                                 # (B,Q,h)
        m_row = jnp.maximum(m_intra, b + m0[:, None])
        W = jnp.exp(D - m_row[:, :, None])                      # (B,Q,Q,h)
        qk = jnp.einsum("bqhd,bkhd->bqkh", qi, ki)
        sc = qk * W
        num = (jnp.einsum("bqkh,bkhd->bqhd", sc, vi)
               + jnp.exp(b + m0[:, None] - m_row)[..., None]
               * jnp.einsum("bqhd,bhde->bqhe", qi, C0))
        den = (sc.sum(axis=2)
               + jnp.exp(b + m0[:, None] - m_row)
               * jnp.einsum("bqhd,bhd->bqh", qi, n0))
        yq = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_row))[..., None]
        # state update to chunk end
        g = b[:, -1]                                            # (B,h)
        dec_j = g[:, None] - b + lii                            # (B,Q,h)
        m1 = jnp.maximum(g + m0, dec_j.max(axis=1))
        wj = jnp.exp(dec_j - m1[:, None])                       # (B,Q,h)
        C1 = (jnp.exp(g + m0 - m1)[..., None, None] * C0
              + jnp.einsum("bqh,bqhd,bqhe->bhde", wj, ki, vi))
        n1 = (jnp.exp(g + m0 - m1)[..., None] * n0
              + jnp.einsum("bqh,bqhd->bhd", wj, ki))
        return (C1, n1, m1), yq

    (C1, n1, m1), ys = jax.lax.scan(
        body, init,
        tuple(jnp.moveaxis(t, 1, 0) for t in (qc, kc, vc, lic, lfc)))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, Sp, di)[:, :S]
    y = _mlstm_out(cfg, p, y.astype(x.dtype), z)
    new_cache = {"C": C1, "n": n1, "m": m1} if cache is not None else None
    return y, new_cache


def _mlstm_out(cfg, p, y, z):
    B, S, di = y.shape
    h = cfg.n_heads
    hd = di // h
    yf = y.astype(jnp.float32).reshape(B, S, h, hd)
    yf = yf * jax.lax.rsqrt((yf ** 2).mean(-1, keepdims=True) + 1e-6)
    y = (yf.reshape(B, S, di)
         * p["gn"].astype(jnp.float32)).astype(y.dtype)
    y = y * jax.nn.silu(z)
    return compute.matmul(y, p["down"], site="mlstm.down")


def make_mlstm_cache(cfg: ModelConfig, batch: int):
    di = int(cfg.xlstm_proj_factor * cfg.d_model)
    h = cfg.n_heads
    hd = di // h
    return {"C": jnp.zeros((batch, h, hd, hd), jnp.float32),
            "n": jnp.zeros((batch, h, hd), jnp.float32),
            "m": jnp.full((batch, h), -1e30, jnp.float32)}


# ===========================================================================
# sLSTM
# ===========================================================================

def slstm_init(cfg: ModelConfig, key, dtype):
    d = cfg.d_model
    h = cfg.n_heads
    hd = d // h
    f = -(-(4 * d // 3) // 128) * 128    # GLU hidden, padded to lane width
    ks = split_keys(key, 4)
    return {
        "wx": dense_init(ks[0], (d, 4 * d), dtype),      # i,f,z,o input
        "r": dense_init(ks[1], (4, h, hd, hd), jnp.float32, scale=0.02),
        "b": jnp.concatenate([jnp.zeros((d,)), jnp.full((d,), 3.0),
                              jnp.zeros((2 * d,))]).astype(jnp.float32),
        "mlp_up": dense_init(ks[2], (d, 2 * f), dtype),
        "mlp_down": dense_init(ks[3], (f, d), dtype),
        "gn": jnp.ones((d,), dtype),
    }


def _slstm_cell(cfg, p, wx_t, state):
    """One recurrence step. wx_t: (B,4,d) f32; state: (h,c,n,m) each (B,*)."""
    B = wx_t.shape[0]
    d = cfg.d_model
    nh = cfg.n_heads
    hd = d // nh
    hprev, c0, n0, m0 = state                       # h: (B,d); c,n: (B,d); m: (B,d)
    hh = hprev.reshape(B, nh, hd)
    rec = jnp.einsum("ghde,bhd->gbhe", p["r"], hh).reshape(4, B, d)
    pre = wx_t.transpose(1, 0, 2) + rec + p["b"].reshape(4, 1, d)
    it, ft, zt, ot = pre[0], pre[1], pre[2], pre[3]
    lf = _logsig(ft)
    m1 = jnp.maximum(lf + m0, it)
    ig = jnp.exp(it - m1)
    fg = jnp.exp(lf + m0 - m1)
    c1 = fg * c0 + ig * jnp.tanh(zt)
    n1 = fg * n0 + ig
    h1 = jax.nn.sigmoid(ot) * c1 / jnp.maximum(n1, 1e-6)
    return (h1, c1, n1, m1)


def apply_slstm(cfg: ModelConfig, p, x, *, cache: Optional[dict] = None,
                decode_pos=None):
    """x: (B,S,d). Cache: {"h","c","n","m"} each (B,d) f32."""
    from jax.sharding import PartitionSpec as _P
    B, S, d = x.shape
    wx = compute.matmul(x, p["wx"], site="slstm.wx").astype(jnp.float32)
    wx = wx.reshape(B, S, 4, d)
    # NOTE: replicating the recurrence was tried and refuted — the
    # batch-sharded per-step dL/dr accumulation all-reduces a full weight
    # replica every timestep (EXPERIMENTS.md Cell C it2)

    if cache is not None and decode_pos is not None and S == 1:
        st = (cache["h"], cache["c"], cache["n"], cache["m"])
        st = _slstm_cell(cfg, p, wx[:, 0], st)
        y = st[0][:, None].astype(x.dtype)
        y = _slstm_out(cfg, p, y)
        return y, {"h": st[0], "c": st[1], "n": st[2], "m": st[3]}

    if cache is not None:
        init = (cache["h"], cache["c"], cache["n"], cache["m"])
    else:
        z = jnp.zeros((B, d), jnp.float32)
        init = (z, z, z, jnp.full((B, d), -1e30, jnp.float32))

    def body(st, wx_t):
        st = _slstm_cell(cfg, p, wx_t, st)
        return st, st[0]

    st, hs = jax.lax.scan(body, init, jnp.moveaxis(wx, 1, 0))
    y = jnp.moveaxis(hs, 0, 1).astype(x.dtype)                 # (B,S,d)
    y = _slstm_out(cfg, p, y)
    new_cache = ({"h": st[0], "c": st[1], "n": st[2], "m": st[3]}
                 if cache is not None else None)
    return y, new_cache


def _slstm_out(cfg, p, y):
    yf = y.astype(jnp.float32)
    yf = yf * jax.lax.rsqrt((yf ** 2).mean(-1, keepdims=True) + 1e-6)
    y = (yf * p["gn"].astype(jnp.float32)).astype(y.dtype)
    up = compute.matmul(y, p["mlp_up"], site="slstm.mlp_up")
    f = up.shape[-1] // 2
    hgelu = jax.nn.gelu(up[..., :f]) * up[..., f:]
    return compute.matmul(hgelu, p["mlp_down"], site="slstm.mlp_down")


def make_slstm_cache(cfg: ModelConfig, batch: int):
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return {"h": z, "c": z, "n": z, "m": jnp.full((batch, d), -1e30,
                                                  jnp.float32)}
