"""Site-aware compute wrappers — the injection point for the paper's technique.

NeuroVectorizer injects ``#pragma clang loop vectorize_width(VF)
interleave_count(IF)`` above each loop.  Here, every tunable hot op in the
model zoo goes through :func:`matmul` / :func:`flash_attention` with a *site*
label.  Three modes:

* ``xla``     — plain jnp ops (the default; what the dry-run lowers).
* ``pallas``  — route through the Pallas kernels in ``repro.kernels`` using
  tile factors from the active :class:`TileProgram` (the "pragma" — see
  ``repro.core.vectorizer``).  Missing sites fall back to the heuristic
  baseline tiles, exactly as un-pragma'd loops fall back to LLVM's cost model.
* recording   — a :class:`SiteRecorder` is installed; tracing a step function
  (``jax.eval_shape``) registers every site with its concrete shapes/dtypes.
  This is the paper's *loop extractor* (DESIGN.md §2).
"""
from __future__ import annotations

import contextlib
import functools
import math
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Global mode (single-threaded tracing; a context stack is sufficient)
# ---------------------------------------------------------------------------


@dataclass
class _ComputeState:
    mode: str = "xla"                  # "xla" | "pallas"
    tiles: Optional[dict] = None       # site -> tile tuple (the TileProgram)
    recorder: Optional["SiteRecorder"] = None
    interpret: bool = False            # Pallas interpret mode (CPU validation)


_STATE = _ComputeState()


@contextlib.contextmanager
def compute_mode(mode: str = "xla", tiles: Optional[dict] = None,
                 recorder: Optional["SiteRecorder"] = None,
                 interpret: bool = False):
    global _STATE
    prev = _STATE
    _STATE = _ComputeState(mode=mode, tiles=tiles, recorder=recorder,
                           interpret=interpret)
    try:
        yield _STATE
    finally:
        _STATE = prev


# ---------------------------------------------------------------------------
# Activation-sharding hints.  Model code is mesh-agnostic; the launcher
# installs logical axis names (dp tuple, tp name) and hot activations get
# pinned with with_sharding_constraint.  Without hints (unit tests, single
# device) every constraint is a no-op.  GSPMD otherwise occasionally drops
# the batch sharding of scan carries / one-hots and replicates multi-GiB
# tensors (observed on the 256-chip dry-run — see DESIGN.md §6).
# ---------------------------------------------------------------------------

_HINTS: dict = {"active": False, "dp": None, "tp": None,
                "carry_tp": True}


@contextlib.contextmanager
def sharding_hints(dp, tp, carry_tp: bool = True):
    prev = dict(_HINTS)
    _HINTS.update(active=True, dp=dp, tp=tp, carry_tp=carry_tp)
    try:
        yield
    finally:
        _HINTS.update(prev)


def constrain(x: jax.Array, builder):
    """builder(dp, tp) -> PartitionSpec; applied only when hints active."""
    if not _HINTS["active"]:
        return x
    spec = builder(_HINTS["dp"], _HINTS["tp"])
    return jax.lax.with_sharding_constraint(x, spec)


# ---------------------------------------------------------------------------
# Site recording (the "loop extractor" output format)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class KernelSite:
    """A tunable kernel instance — the analogue of one extracted loop."""

    site: str            # stable label, e.g. "attn.qkv_proj"
    kind: str            # "matmul" | "attention" | "chunk_scan"
    m: int               # rows (tokens) — matmul M / attention q_len
    n: int               # cols — matmul N / attention head_dim
    k: int               # contraction — matmul K / attention kv_len
    batch: int = 1       # leading batch (attention B*heads; matmul 1)
    dtype: str = "bfloat16"
    transpose: str = "nn"    # operand layouts
    causal: bool = False
    fused_ops: int = 0       # elementwise ops fused at the site (bias/act)

    def key(self) -> str:
        # memoized: key() sits on the batched-oracle hot path (baseline
        # cache, TileProgram lookups) and the dataclass is frozen
        k = self.__dict__.get("_key")
        if k is None:
            k = (f"{self.kind}:{self.site}:m{self.m}n{self.n}k{self.k}"
                 f"b{self.batch}:{self.dtype}:{self.transpose}"
                 f"{':c' if self.causal else ''}:f{self.fused_ops}")
            object.__setattr__(self, "_key", k)
        return k


class SiteRecorder:
    def __init__(self):
        self.sites: dict[str, KernelSite] = {}

    def record(self, s: KernelSite):
        self.sites[s.key()] = s

    def unique_sites(self) -> list[KernelSite]:
        return list(self.sites.values())


# ---------------------------------------------------------------------------
# matmul wrapper
# ---------------------------------------------------------------------------


def matmul(x: jax.Array, w: jax.Array, *, site: str,
           fused_ops: int = 0) -> jax.Array:
    """``x @ w`` where x is (..., K) and w is (K, N)."""
    *lead, K = x.shape
    K2, N = w.shape
    assert K == K2, (site, x.shape, w.shape)
    M = int(math.prod(lead)) if lead else 1
    st = _STATE
    if st.recorder is not None:
        st.recorder.record(KernelSite(
            site=site, kind="matmul", m=M, n=int(N), k=int(K),
            dtype=str(x.dtype), fused_ops=fused_ops))
    if st.mode == "pallas":
        from repro.kernels import ops as kops
        ksite = KernelSite(site=site, kind="matmul", m=M, n=int(N), k=int(K),
                           dtype=str(x.dtype), fused_ops=fused_ops)
        tiles = None if st.tiles is None else st.tiles.get(ksite.key())
        x2 = x.reshape(M, K)
        y = kops.matmul(x2, w, tiles=tiles, interpret=st.interpret)
        return y.reshape(*lead, N)
    return jnp.matmul(x, w)


def einsum(spec: str, *args, site: str) -> jax.Array:
    """Non-canonical contractions (per-head block-diagonal projections etc.).

    Recorded as a matmul site with flattened dims; always executed by XLA —
    the Pallas path only specializes the canonical (M,K)x(K,N) shape.
    """
    st = _STATE
    if st.recorder is not None:
        out = jax.eval_shape(lambda *a: jnp.einsum(spec, *a), *args)
        n = int(out.shape[-1])
        m = int(math.prod(out.shape[:-1])) if out.ndim > 1 else 1
        # contraction length from the (last) weight operand
        k = int(args[-1].shape[-2]) if args[-1].ndim >= 2 else 1
        st.recorder.record(KernelSite(
            site=site, kind="matmul", m=m, n=n, k=k,
            dtype=str(args[0].dtype)))
    return jnp.einsum(spec, *args)


# ---------------------------------------------------------------------------
# attention wrapper (chunked online-softmax "flash" reference in XLA)
# ---------------------------------------------------------------------------


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    site: str, causal: bool,
                    q_chunk: int = 1024, kv_chunk: int = 2048,
                    scale: Optional[float] = None,
                    base_offset=0) -> jax.Array:
    """Memory-chunked attention.

    q: (B, Hq, Sq, D); k/v: (B, Hkv, Skv, D) with Hq % Hkv == 0 (GQA).
    ``base_offset``: absolute position of q[0] (for causal decode masking);
    may be a traced scalar.

    In ``pallas`` mode routes to the flash-attention kernel with tuned
    (block_q, block_kv); in ``xla`` mode runs the same algorithm with
    lax.scan over chunks so 32k-prefill never materializes (Sq, Skv) scores.
    """
    B, Hq, Sq, D = q.shape
    _, Hkv, Skv, _ = k.shape
    Dv = v.shape[-1]        # MLA: v head dim may differ from qk head dim
    assert Hq % Hkv == 0
    st = _STATE
    if st.recorder is not None:
        st.recorder.record(KernelSite(
            site=site, kind="attention", m=Sq, n=D, k=Skv, batch=B * Hq,
            dtype=str(q.dtype), causal=causal))
    if scale is None:
        scale = 1.0 / math.sqrt(D)

    if st.mode == "pallas" and Sq > 1:
        from repro.kernels import ops as kops
        ksite = KernelSite(site=site, kind="attention", m=Sq, n=D, k=Skv,
                           batch=B * Hq, dtype=str(q.dtype), causal=causal)
        tiles = None if st.tiles is None else st.tiles.get(ksite.key())
        return kops.flash_attention(q, k, v, causal=causal, scale=scale,
                                    tiles=tiles, interpret=st.interpret)

    if _HINTS["active"] and Sq > 1:
        # Megatron-style TP attention: expand GQA groups so heads shard
        # over "model" even when Hq % tp != 0 (GSPMD pads intermediates;
        # without the explicit constraint it falls back to full replication
        # of the (bq, bkv) score blocks — observed 2+ GiB/device).
        from jax.sharding import PartitionSpec as _P
        if Hq != Hkv:
            k = jnp.repeat(k, Hq // Hkv, axis=1)
            v = jnp.repeat(v, Hq // Hkv, axis=1)
            Hkv = Hq
        hspec = lambda dp, tp: _P(dp if B > 1 else None, tp, None, None)
        q = constrain(q, hspec)
        k = constrain(k, hspec)
        v = constrain(v, hspec)

    if Sq == 1:
        group = Hq // Hkv
        qf = q.reshape(B, Hkv, group, Sq, D)
        # decode: single position, no chunking needed in q
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qf, k).astype(jnp.float32) * scale
        if causal:
            kpos = jnp.arange(Skv)
            mask = kpos[None, :] <= (base_offset + jnp.arange(Sq))[:, None]
            s = jnp.where(mask[None, None, None], s, -jnp.inf)
        p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
        o = jnp.einsum("bhgqk,bhkd->bhgqd", p, v)
        return o.reshape(B, Hq, Sq, Dv)

    # prefill / train: memory-efficient attention with a flash-style custom
    # VJP.  A plain scan-based implementation saves its per-step (bq, bkv)
    # probability blocks for backward — at 32L x 32k that is tens of GiB per
    # device (measured).  The custom VJP saves only (q, k, v, o, lse) and
    # recomputes blocks in the backward scans.
    if Hq != Hkv:                       # expand GQA groups (grad sums back)
        k = jnp.repeat(k, Hq // Hkv, axis=1)
        v = jnp.repeat(v, Hq // Hkv, axis=1)
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Skv)
    assert Sq % q_chunk == 0 and Skv % kv_chunk == 0, (Sq, Skv)
    return _mem_efficient_attention(
        q, k, v, causal=causal, scale=scale, bq=q_chunk, bkv=kv_chunk)


# ---------------------------------------------------------------------------
# memory-efficient attention (custom VJP, flash algorithm in XLA)
# ---------------------------------------------------------------------------

NEG_INF = -1e30


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _mem_efficient_attention(q, k, v, causal, scale, bq, bkv):
    o, _ = _mea_fwd_impl(q, k, v, causal, scale, bq, bkv)
    return o


def _mea_fwd_impl(q, k, v, causal, scale, bq, bkv):
    B, H, Sq, D = q.shape
    Skv = k.shape[2]
    Dv = v.shape[-1]
    n_q, n_kv = Sq // bq, Skv // bkv
    kc = jnp.moveaxis(k.reshape(B, H, n_kv, bkv, D), 2, 0)
    vc = jnp.moveaxis(v.reshape(B, H, n_kv, bkv, Dv), 2, 0)
    qc = jnp.moveaxis(q.reshape(B, H, n_q, bq, D), 2, 0)

    def q_body(_, qi_idx):
        qi, iq = qi_idx                            # (B,H,bq,D)
        # bottom-right aligned causal offset, matching ref/pallas kernels
        q_pos = iq * bq + jnp.arange(bq) + (Skv - Sq)

        def kv_body(carry, kv_idx):
            m, l, acc = carry
            kj, vj, ik = kv_idx
            s = jnp.einsum("bhqd,bhkd->bhqk", qi, kj).astype(jnp.float32)
            s = s * scale
            if causal:
                k_pos = ik * bkv + jnp.arange(bkv)
                mask = k_pos[None, :] <= q_pos[:, None]
                s = jnp.where(mask[None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhqk,bhkd->bhqd", p.astype(vj.dtype), vj
            ).astype(jnp.float32)
            return (m_new, l_new, acc), None

        init = (jnp.full((B, H, bq), NEG_INF, jnp.float32),
                jnp.zeros((B, H, bq), jnp.float32),
                jnp.zeros((B, H, bq, Dv), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(kv_body, init,
                                      (kc, vc, jnp.arange(n_kv)))
        l = jnp.maximum(l, 1e-30)
        o = (acc / l[..., None]).astype(q.dtype)
        lse = m + jnp.log(l)
        return None, (o, lse)

    _, (o, lse) = jax.lax.scan(q_body, None, (qc, jnp.arange(n_q)))
    o = jnp.moveaxis(o, 0, 2).reshape(B, H, Sq, Dv)
    lse = jnp.moveaxis(lse, 0, 2).reshape(B, H, Sq)
    return o, lse


def _mea_fwd(q, k, v, causal, scale, bq, bkv):
    o, lse = _mea_fwd_impl(q, k, v, causal, scale, bq, bkv)
    return o, (q, k, v, o, lse)


def _mea_bwd(causal, scale, bq, bkv, res, do):
    q, k, v, o, lse = res
    B, H, Sq, D = q.shape
    Skv = k.shape[2]
    Dv = v.shape[-1]
    n_q, n_kv = Sq // bq, Skv // bkv
    delta = (do.astype(jnp.float32) * o.astype(jnp.float32)).sum(-1)  # BHS

    qc = jnp.moveaxis(q.reshape(B, H, n_q, bq, D), 2, 0)
    doc = jnp.moveaxis(do.reshape(B, H, n_q, bq, Dv), 2, 0)
    lsec = jnp.moveaxis(lse.reshape(B, H, n_q, bq), 2, 0)
    dltc = jnp.moveaxis(delta.reshape(B, H, n_q, bq), 2, 0)
    kc = jnp.moveaxis(k.reshape(B, H, n_kv, bkv, D), 2, 0)
    vc = jnp.moveaxis(v.reshape(B, H, n_kv, bkv, Dv), 2, 0)

    def kv_body(dq, kv_idx):
        kj, vj, ik = kv_idx
        k_pos = ik * bkv + jnp.arange(bkv)

        def q_body(carry, q_idx):
            dkj, dvj = carry
            qi, doi, lsei, dlti, iq = q_idx
            q_pos = iq * bq + jnp.arange(bq) + (Skv - Sq)
            s = jnp.einsum("bhqd,bhkd->bhqk", qi, kj).astype(jnp.float32)
            s = s * scale
            if causal:
                mask = k_pos[None, :] <= q_pos[:, None]
                s = jnp.where(mask[None, None], s, NEG_INF)
            p = jnp.exp(s - lsei[..., None])               # (B,H,bq,bkv)
            dvj = dvj + jnp.einsum("bhqk,bhqd->bhkd", p,
                                   doi.astype(jnp.float32))
            dp = jnp.einsum("bhqd,bhkd->bhqk", doi.astype(jnp.float32),
                            vj.astype(jnp.float32))
            ds = p * (dp - dlti[..., None]) * scale
            dkj = dkj + jnp.einsum("bhqk,bhqd->bhkd", ds,
                                   qi.astype(jnp.float32))
            dqi = jnp.einsum("bhqk,bhkd->bhqd", ds, kj.astype(jnp.float32))
            return (dkj, dvj), dqi

        init = (jnp.zeros((B, H, bkv, D), jnp.float32),
                jnp.zeros((B, H, bkv, Dv), jnp.float32))
        (dkj, dvj), dq_blocks = jax.lax.scan(
            q_body, init, (qc, doc, lsec, dltc, jnp.arange(n_q)))
        dq = dq + jnp.moveaxis(dq_blocks, 0, 2).reshape(B, H, Sq, D)
        return dq, (dkj, dvj)

    dq0 = jnp.zeros((B, H, Sq, D), jnp.float32)
    dq, (dk, dv) = jax.lax.scan(kv_body, dq0, (kc, vc, jnp.arange(n_kv)))
    dk = jnp.moveaxis(dk, 0, 2).reshape(B, H, Skv, D)
    dv = jnp.moveaxis(dv, 0, 2).reshape(B, H, Skv, Dv)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


_mem_efficient_attention.defvjp(_mea_fwd, _mea_bwd)
