"""Token-choice top-k MoE with GShard-style capacity dispatch.

Dispatch/combine are INDEX-based gathers wrapped in custom VJPs whose
backward passes are *also* gathers (via the inverse index maps) — three
reasons, all measured on the 256-chip dry-run:

 1. one-hot dispatch einsums would dominate cost_analysis by ~1000x and
    poison the roofline (DESIGN.md §5);
 2. a (K*T, d) gathered-rows intermediate replicates (30 GiB/device);
 3. the *transpose* of a gather is a scatter, and GSPMD's scatter
    partitioning falls back to replicating the (T, d) operand (16+ GiB) —
    expressing each backward as the dual gather keeps every heavy tensor
    sharded in both passes.

Expert tensors are stacked (E, d, f), sharded E over "model" (expert
parallelism) + d over "data" (FSDP); dispatch buffers shard (E, C) over
(TP, DP), so the token->expert movement is GSPMD's all-to-all.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as _P

from repro.configs.base import ModelConfig
from repro.models import compute
from repro.models.common import dense_init, split_keys

CAPACITY_FACTOR = 1.25

_ECD = lambda dp, tp: _P(tp, dp, None)
_TD = lambda dp, tp: _P(dp, None)


def moe_init(cfg: ModelConfig, key, dtype):
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    ks = split_keys(key, 7)
    p = {
        "router": dense_init(ks[0], (d, e), jnp.float32),
        "ewi": dense_init(ks[1], (e, d, f), dtype),
        "ewg": dense_init(ks[2], (e, d, f), dtype),
        "ewo": dense_init(ks[3], (e, f, d), dtype),
    }
    if cfg.n_shared_experts:
        fs = f * cfg.n_shared_experts
        p["shared_wi"] = dense_init(ks[4], (d, fs), dtype)
        p["shared_wg"] = dense_init(ks[5], (d, fs), dtype)
        p["shared_wo"] = dense_init(ks[6], (fs, d), dtype)
    return p


def _capacity(n_tokens: int, n_experts: int, top_k: int) -> int:
    c = int(n_tokens * top_k * CAPACITY_FACTOR / n_experts)
    return max(8, -(-c // 8) * 8)   # multiple of 8, >= 8


# ---------------------------------------------------------------------------
# dispatch: xt (T,d) -> buf (E,C,d); backward is K gathers, not a scatter
# ---------------------------------------------------------------------------

@jax.custom_vjp
def _dispatch(xt, idx, eidx, pos_tk, keep_tk):
    return _dispatch_fwd(xt, idx, eidx, pos_tk, keep_tk)[0]


def _dispatch_fwd(xt, idx, eidx, pos_tk, keep_tk):
    T = xt.shape[0]
    valid = idx >= 0
    buf = jnp.where(valid[..., None], xt[jnp.clip(idx, 0, T - 1)], 0)
    buf = compute.constrain(buf, _ECD)
    return buf, (idx.shape[1], eidx, pos_tk, keep_tk, T)


def _dispatch_bwd(res, dbuf):
    C, eidx, pos_tk, keep_tk, T = res
    K = eidx.shape[1]
    d = dbuf.shape[-1]
    # single-axis (flat) gathers only: GSPMD partitions those; the 2-index
    # form replicates the operand
    dbuf_flat = compute.constrain(dbuf.reshape(-1, d), _TD)
    d_xt = 0.0
    for k in range(K):
        flat = eidx[:, k] * C + jnp.clip(pos_tk[:, k], 0, C - 1)
        rows = compute.constrain(dbuf_flat[flat], _TD)
        d_xt = d_xt + jnp.where(keep_tk[:, k:k + 1], rows, 0)
    return compute.constrain(d_xt, _TD), None, None, None, None


_dispatch.defvjp(_dispatch_fwd, _dispatch_bwd)


# ---------------------------------------------------------------------------
# combine: y_buf (E,C,d), w (T,K) -> y (T,d); backward gathers via idx/kidx
# ---------------------------------------------------------------------------

@jax.custom_vjp
def _combine(y_buf, w, idx, kidx, eidx, pos_tk):
    return _combine_fwd(y_buf, w, idx, kidx, eidx, pos_tk)[0]


def _combine_fwd(y_buf, w, idx, kidx, eidx, pos_tk):
    E, C, d = y_buf.shape
    K = w.shape[1]
    y_flat = compute.constrain(y_buf.reshape(-1, d), _TD)
    y = 0.0
    for k in range(K):
        flat = eidx[:, k] * C + jnp.clip(pos_tk[:, k], 0, C - 1)
        y_k = compute.constrain(y_flat[flat], _TD)
        y = y + y_k.astype(jnp.float32) * w[:, k:k + 1]
    y = compute.constrain(y, _TD)
    return y, (y_buf, w, idx, kidx, eidx, pos_tk)


def _combine_bwd(res, dy):
    y_buf, w, idx, kidx, eidx, pos_tk = res
    E, C, d = y_buf.shape
    T, K = w.shape
    dy = compute.constrain(dy, _TD)
    valid = idx >= 0
    # d_y_buf[e,c] = w[idx[e,c], kidx[e,c]] * dy[idx[e,c]] — flat gathers
    w_flat = w.T.reshape(-1)                                # slot-major (K*T,)
    w_ec = jnp.where(valid, w_flat[jnp.clip(kidx, 0, K - 1) * T
                                   + jnp.clip(idx, 0, T - 1)], 0.0)
    d_y_buf = jnp.where(valid[..., None],
                        dy[jnp.clip(idx, 0, T - 1)], 0) * w_ec[..., None]
    d_y_buf = compute.constrain(d_y_buf.astype(y_buf.dtype), _ECD)
    # d_w[t,k] = dy[t] . y_buf[e_k(t), pos_k(t)]            — flat gathers
    y_flat = compute.constrain(y_buf.reshape(-1, d), _TD)
    dws = []
    for k in range(K):
        flat = eidx[:, k] * C + jnp.clip(pos_tk[:, k], 0, C - 1)
        y_k = compute.constrain(y_flat[flat], _TD)
        dws.append((dy * y_k.astype(jnp.float32)).sum(-1))
    d_w = jnp.stack(dws, axis=1)
    return d_y_buf, d_w, None, None, None, None


_combine.defvjp(_combine_fwd, _combine_bwd)


# ---------------------------------------------------------------------------

def apply_moe(cfg: ModelConfig, p, x):
    """x: (B, S, d) -> (y, aux) where aux = {"lb_loss", "router_z"}."""
    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.moe_top_k
    T = B * S
    xt = x.reshape(T, d)

    logits = compute.matmul(xt.astype(jnp.float32), p["router"],
                            site="moe.router")                  # (T,E) f32
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eidx = jax.lax.top_k(probs, K)                        # (T,K)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # ---- aux losses (Switch-style load balance + router z-loss) ----
    me = probs.mean(0)                                          # (E,)
    ce = jnp.zeros((E,), jnp.float32).at[eidx.reshape(-1)].add(
        1.0 / (T * K))
    lb_loss = E * jnp.sum(me * ce)
    router_z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)

    # ---- capacity assignment (slot-major priority, as GShard) ----
    C = _capacity(T, E, K)
    a_e = eidx.T.reshape(-1)                                    # (K*T,) slot-major
    onehot = jax.nn.one_hot(a_e, E, dtype=jnp.int32)            # (KT,E)
    # expert dim over TP: the cumsum is per-column, so it partitions cleanly
    onehot = compute.constrain(onehot, lambda dp, tp: _P(None, tp))
    pos = (jnp.cumsum(onehot, axis=0) * onehot).sum(-1) - 1     # (KT,)
    keep = pos < C
    tok = jnp.tile(jnp.arange(T), K)                            # (KT,)
    slot = jnp.repeat(jnp.arange(K), T)                         # (KT,)

    # (E, C) inverse maps: token id and slot id per expert slot.  These two
    # scatters are the only scatters in the layer and are int32 (E, C) —
    # a few MiB, safe to let GSPMD replicate.
    pc = jnp.where(keep, pos, C)
    idx = jnp.full((E, C), -1, jnp.int32).at[a_e, pc].set(
        tok.astype(jnp.int32), mode="drop")
    kidx = jnp.full((E, C), 0, jnp.int32).at[a_e, pc].set(
        slot.astype(jnp.int32), mode="drop")
    idx = compute.constrain(idx, lambda dp, tp: _P(tp, dp))
    kidx = compute.constrain(kidx, lambda dp, tp: _P(tp, dp))

    pos_tk = pos.reshape(K, T).T                                # (T,K)
    keep_tk = keep.reshape(K, T).T
    w = gate * keep_tk.astype(jnp.float32)                      # (T,K)

    # ---- dispatch / expert compute / combine ----
    xt_c = compute.constrain(xt, _TD)
    buf = _dispatch(xt_c, idx, eidx, pos_tk, keep_tk)           # (E,C,d)
    h = compute.constrain(jnp.einsum("ecd,edf->ecf", buf, p["ewi"]), _ECD)
    g = jax.nn.silu(
        compute.constrain(jnp.einsum("ecd,edf->ecf", buf, p["ewg"]), _ECD))
    y_buf = compute.constrain(
        jnp.einsum("ecf,efd->ecd", h * g, p["ewo"]), _ECD)       # (E,C,d)
    y = _combine(y_buf, w, idx, kidx, eidx, pos_tk)             # (T,d) f32

    if cfg.n_shared_experts:
        hs = (jax.nn.silu(compute.matmul(xt, p["shared_wg"],
                                         site="moe.shared_gate", fused_ops=1))
              * compute.matmul(xt, p["shared_wi"], site="moe.shared_up"))
        y = y + compute.matmul(hs, p["shared_wo"],
                               site="moe.shared_down").astype(jnp.float32)

    aux = {"lb_loss": lb_loss, "router_z": router_z}
    return y.astype(x.dtype).reshape(B, S, d), aux
