"""Shared layers: norms, RoPE (1d / 2d-partial), MLPs, init helpers."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import compute
from repro.configs.base import ModelConfig


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype, scale: Optional[float] = None):
    fan_in = shape[0] if len(shape) >= 2 else shape[-1]
    if scale is None:
        scale = 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def split_keys(key, n):
    return list(jax.random.split(key, n))


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def norm_init(cfg: ModelConfig, d: int, dtype):
    p = {"scale": jnp.ones((d,), dtype)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def apply_norm(cfg: ModelConfig, p, x):
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + 1e-5)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:  # rmsnorm
        var = (xf ** 2).mean(-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + 1e-6) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


def rms_head_norm(x, scale):
    """qk-norm: rmsnorm over the head dim. x: (..., D_head)."""
    xf = x.astype(jnp.float32)
    var = (xf ** 2).mean(-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + 1e-6)
            * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float,
               mode: str = "1d") -> jax.Array:
    """x: (B, H, S, D); positions: (S,) or (B, S) absolute positions.

    mode "1d": rotate all D dims (pairing [0::2], [1::2]).
    mode "2d": GLM-style — rotate only the first half of D, pass the rest.
    """
    if mode == "none":
        return x
    B, H, S, D = x.shape
    rot_dim = D // 2 if mode == "2d" else D
    freqs = rope_freqs(rot_dim, theta)                       # (rot_dim/2,)
    if positions.ndim == 1:
        ang = positions[:, None].astype(jnp.float32) * freqs[None, :]
        ang = ang[None, None]                                # (1,1,S,rd/2)
    else:
        ang = positions[:, :, None].astype(jnp.float32) * freqs[None, None, :]
        ang = ang[:, None]                                   # (B,1,S,rd/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    xr = x[..., :rot_dim].astype(jnp.float32)
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    rot = jnp.stack([r1, r2], axis=-1).reshape(*x.shape[:-1], rot_dim)
    rot = rot.astype(x.dtype)
    if rot_dim == D:
        return rot
    return jnp.concatenate([rot, x[..., rot_dim:]], axis=-1)


# ---------------------------------------------------------------------------
# MLP (dense)
# ---------------------------------------------------------------------------

def mlp_init(cfg: ModelConfig, key, dtype):
    d, f = cfg.d_model, cfg.d_ff
    ks = split_keys(key, 3)
    if cfg.act == "silu":   # gated
        return {"wi": dense_init(ks[0], (d, f), dtype),
                "wg": dense_init(ks[1], (d, f), dtype),
                "wo": dense_init(ks[2], (f, d), dtype)}
    return {"wi": dense_init(ks[0], (d, f), dtype),
            "wo": dense_init(ks[2], (f, d), dtype)}


def apply_mlp(cfg: ModelConfig, p, x):
    if cfg.act == "silu":
        h = (jax.nn.silu(compute.matmul(x, p["wg"], site="mlp.gate", fused_ops=1))
             * compute.matmul(x, p["wi"], site="mlp.up"))
    else:
        h = jax.nn.gelu(compute.matmul(x, p["wi"], site="mlp.up", fused_ops=1))
    return compute.matmul(h, p["wo"], site="mlp.down")
