"""GQA attention block: RoPE (1d/2d), qk-norm, KV-cache decode, cross-attn."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import compute
from repro.models.common import (apply_norm, apply_rope, dense_init,
                                 norm_init, rms_head_norm, split_keys)


def attn_init(cfg: ModelConfig, key, dtype, cross: bool = False):
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = split_keys(key, 5)
    p = {
        "wq": dense_init(ks[0], (d, hq * hd), dtype),
        "wk": dense_init(ks[1], (d, hkv * hd), dtype),
        "wv": dense_init(ks[2], (d, hkv * hd), dtype),
        "wo": dense_init(ks[3], (hq * hd, d), dtype),
    }
    if cfg.qk_norm and not cross:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def _split_heads(x, n_heads, hd):
    B, S, _ = x.shape
    return x.reshape(B, S, n_heads, hd).transpose(0, 2, 1, 3)  # (B,H,S,hd)


def _merge_heads(x):
    B, H, S, hd = x.shape
    return x.transpose(0, 2, 1, 3).reshape(B, S, H * hd)


def apply_attn(cfg: ModelConfig, p, x, *, positions, causal: bool,
               cache: Optional[dict] = None, decode_pos=None,
               site_prefix: str = "attn"):
    """Self-attention.

    Train/prefill: ``cache is None`` or a zeroed cache to fill (prefill).
    Decode: ``cache`` holds (B, Hkv, S_ctx, hd) k/v; ``decode_pos`` is the
    scalar write position.  Returns (y, new_cache_or_None).
    """
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = _split_heads(compute.matmul(x, p["wq"], site=f"{site_prefix}.q"), hq, hd)
    k = _split_heads(compute.matmul(x, p["wk"], site=f"{site_prefix}.k"), hkv, hd)
    v = _split_heads(compute.matmul(x, p["wv"], site=f"{site_prefix}.v"), hkv, hd)

    if cfg.qk_norm:
        q = rms_head_norm(q, p["q_norm"])
        k = rms_head_norm(k, p["k_norm"])

    q = apply_rope(q, positions, cfg.rope_theta, cfg.rope)
    k = apply_rope(k, positions, cfg.rope_theta, cfg.rope)

    new_cache = None
    base_offset = 0
    if cache is not None and decode_pos is not None:
        # decode: write this step's k/v at decode_pos, attend over full cache
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, decode_pos, axis=2)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, decode_pos, axis=2)
        k, v = ck, cv
        new_cache = {"k": ck, "v": cv}
        base_offset = decode_pos
    elif cache is not None:
        # prefill: fill the cache with the computed k/v
        new_cache = {"k": k, "v": v}

    o = compute.flash_attention(q, k, v, site=f"{site_prefix}.core",
                                causal=causal, base_offset=base_offset)
    y = compute.matmul(_merge_heads(o), p["wo"], site=f"{site_prefix}.o")
    return y, new_cache


def apply_cross_attn(cfg: ModelConfig, p, x, *, memory=None,
                     mem_cache: Optional[dict] = None,
                     site_prefix: str = "xattn"):
    """Cross-attention: q from x, k/v from encoder memory.

    ``memory`` (B, S_src, d) on prefill (k/v computed, returned as cache);
    ``mem_cache`` holds precomputed k/v on decode.
    """
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = _split_heads(compute.matmul(x, p["wq"], site=f"{site_prefix}.q"), hq, hd)
    if mem_cache is None:
        k = _split_heads(compute.matmul(memory, p["wk"], site=f"{site_prefix}.k"), hkv, hd)
        v = _split_heads(compute.matmul(memory, p["wv"], site=f"{site_prefix}.v"), hkv, hd)
        mem_cache = {"k": k, "v": v}
    else:
        k, v = mem_cache["k"], mem_cache["v"]
    o = compute.flash_attention(q, k, v, site=f"{site_prefix}.core", causal=False)
    y = compute.matmul(_merge_heads(o), p["wo"], site=f"{site_prefix}.o")
    return y, mem_cache


def make_attn_cache(cfg: ModelConfig, batch: int, ctx: int, dtype):
    hkv, hd = cfg.n_kv_heads, cfg.head_dim
    return {"k": jnp.zeros((batch, hkv, ctx, hd), dtype),
            "v": jnp.zeros((batch, hkv, ctx, hd), dtype)}
