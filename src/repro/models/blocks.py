"""Per-BlockDesc init/apply dispatch: one period slot = mixer + optional MLP."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import BlockDesc, ModelConfig
from repro.models import attention, mla, moe, ssm, xlstm
from repro.models.common import apply_mlp, apply_norm, mlp_init, norm_init, split_keys


def block_init(cfg: ModelConfig, b: BlockDesc, key, dtype):
    ks = split_keys(key, 4)
    p = {"norm1": norm_init(cfg, cfg.d_model, dtype)}
    if b.kind == "attn":
        p["mixer"] = (mla.mla_init(cfg, ks[0], dtype) if cfg.mla
                      else attention.attn_init(cfg, ks[0], dtype))
    elif b.kind == "mamba":
        p["mixer"] = ssm.ssm_init(cfg, ks[0], dtype)
    elif b.kind == "mlstm":
        p["mixer"] = xlstm.mlstm_init(cfg, ks[0], dtype)
    elif b.kind == "slstm":
        p["mixer"] = xlstm.slstm_init(cfg, ks[0], dtype)
    if b.mlp != "none":
        p["norm2"] = norm_init(cfg, cfg.d_model, dtype)
        p["mlp"] = (moe.moe_init(cfg, ks[1], dtype) if b.mlp == "moe"
                    else mlp_init(cfg, ks[1], dtype))
    return p


def block_cache(cfg: ModelConfig, b: BlockDesc, batch: int, ctx: int, dtype):
    if b.kind == "attn":
        if cfg.mla:
            return mla.make_mla_cache(cfg, batch, ctx, dtype)
        return attention.make_attn_cache(cfg, batch, ctx, dtype)
    if b.kind == "mamba":
        return ssm.make_ssm_cache(cfg, batch, dtype)
    if b.kind == "mlstm":
        return xlstm.make_mlstm_cache(cfg, batch)
    if b.kind == "slstm":
        return xlstm.make_slstm_cache(cfg, batch)
    raise ValueError(b.kind)


def block_apply(cfg: ModelConfig, b: BlockDesc, p, x, *, positions,
                causal: bool = True, cache: Optional[dict] = None,
                decode_pos=None):
    """Returns (x, new_cache, aux)."""
    h = apply_norm(cfg, p["norm1"], x)
    if b.kind == "attn":
        if cfg.mla:
            y, nc = mla.apply_mla(cfg, p["mixer"], h, positions=positions,
                                  causal=causal, cache=cache,
                                  decode_pos=decode_pos)
        else:
            y, nc = attention.apply_attn(cfg, p["mixer"], h,
                                         positions=positions, causal=causal,
                                         cache=cache, decode_pos=decode_pos)
    elif b.kind == "mamba":
        y, nc = ssm.apply_ssm(cfg, p["mixer"], h, cache=cache,
                              decode_pos=decode_pos)
    elif b.kind == "mlstm":
        y, nc = xlstm.apply_mlstm(cfg, p["mixer"], h, cache=cache,
                                  decode_pos=decode_pos, chunk=cfg.ssm_chunk)
    elif b.kind == "slstm":
        y, nc = xlstm.apply_slstm(cfg, p["mixer"], h, cache=cache,
                                  decode_pos=decode_pos)
    else:
        raise ValueError(b.kind)
    x = x + y

    aux = {"lb_loss": jnp.zeros((), jnp.float32),
           "router_z": jnp.zeros((), jnp.float32)}
    if b.mlp != "none":
        h = apply_norm(cfg, p["norm2"], x)
        if b.mlp == "moe":
            y, aux = moe.apply_moe(cfg, p["mlp"], h)
        else:
            y = apply_mlp(cfg, p["mlp"], h)
        x = x + y
    return x, nc, aux
