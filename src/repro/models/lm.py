"""Unified model builder.

``build_model(cfg)`` returns a :class:`Model` of pure functions:

* ``init(key)``                                        -> params
* ``train_loss(params, batch)``                        -> (loss, metrics)
* ``prefill(params, batch)``                           -> (last_logits, cache)
* ``decode_step(params, token, pos, cache)``           -> (logits, new_cache)
* ``make_cache(batch, ctx, dtype)``                    -> zeroed cache pytree

The layer stack is a single ``lax.scan`` over ``cfg.n_periods`` with each
period's parameters stacked on the leading axis (small HLO, fast compiles,
remat via ``jax.checkpoint`` around the period body).  Encoder-decoder
configs scan two stacks and add cross-attention.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import BlockDesc, ModelConfig
from repro.models import attention, blocks, compute
from repro.models.common import apply_norm, dense_init, norm_init, split_keys


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable
    train_loss: Callable
    prefill: Callable
    decode_step: Callable
    make_cache: Callable


# ---------------------------------------------------------------------------
# parameter init
# ---------------------------------------------------------------------------

def _stack_init(cfg: ModelConfig, key, dtype, n_units: int, cross: bool = False):
    """Stacked per-period params: tuple over period slots, leaves
    (n_periods, ...)."""
    n_periods = n_units // len(cfg.period)
    out = []
    for slot, b in enumerate(cfg.period):
        keys = jax.random.split(jax.random.fold_in(key, slot), n_periods)
        per = [blocks.block_init(cfg, b, k, dtype) for k in keys]
        if cross:
            for i, k in enumerate(keys):
                per[i]["cross"] = attention.attn_init(
                    cfg, jax.random.fold_in(k, 99), dtype, cross=True)
                per[i]["norm_x"] = norm_init(cfg, cfg.d_model, dtype)
        out.append(jax.tree.map(lambda *a: jnp.stack(a), *per))
    return tuple(out)


def model_init(cfg: ModelConfig, key):
    dtype = jnp.dtype(cfg.dtype)
    ks = split_keys(key, 8)
    p = {
        "embed": dense_init(ks[0], (cfg.vocab_size, cfg.d_model), dtype,
                            scale=cfg.d_model ** -0.5),
        "final_norm": norm_init(cfg, cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        p["head"] = dense_init(ks[1], (cfg.vocab_size, cfg.d_model), dtype)
    if cfg.frontend != "none" and not cfg.enc_dec:
        p["frontend_proj"] = dense_init(ks[2], (cfg.d_model, cfg.d_model),
                                        dtype)
    if cfg.enc_dec:
        p["enc_blocks"] = _stack_init(cfg, ks[3], dtype, cfg.n_enc_layers)
        p["dec_blocks"] = _stack_init(cfg, ks[4], dtype, cfg.n_dec_layers,
                                      cross=True)
        p["enc_norm"] = norm_init(cfg, cfg.d_model, dtype)
    else:
        p["blocks"] = _stack_init(cfg, ks[3], dtype, cfg.n_layers)
    return p


# ---------------------------------------------------------------------------
# stack application (the scan)
# ---------------------------------------------------------------------------

def _run_stack(cfg: ModelConfig, stack_params, x, *, positions, causal,
               caches=None, decode_pos=None, memory=None, mem_caches=None,
               mem_init=None, remat: bool = True):
    """Scan the period stack.  Returns (x, new_caches, new_mem, aux_sums).

    Caches travel in the scan CARRY and are updated in place with
    dynamic_update_index (XLA aliases while-loop carry buffers), never as
    xs->ys — emitting updated caches as scan outputs allocates a full fresh
    copy of every cache per step (measured +2x cache bytes of pure temp on
    the 32k-decode cells)."""
    has_cache = caches is not None
    has_mem = memory is not None or mem_caches is not None
    # cross-attn k/v is written only on prefill (cache fill); train
    # recomputes it under remat and decode reuses the cache passed in.
    write_mem = has_mem and mem_caches is None and has_cache

    from jax.sharding import PartitionSpec as _P

    def _slice(tree, i):
        return jax.tree.map(
            lambda a: jax.lax.dynamic_index_in_dim(a, i, 0, keepdims=False),
            tree)

    def _update(tree, upd, i):
        return jax.tree.map(
            lambda a, u: jax.lax.dynamic_update_index_in_dim(a, u, i, 0),
            tree, upd)

    def period_body(x, caches_c, mem_c, slot_params, idx):
        # pin the carry: batch over DP, d over TP.  The carry is what scan
        # saves for backward (n_periods, B, S, d) — sharding d cuts the
        # dominant saved-activation term by the TP degree (ZeRO-R style);
        # layer internals all-gather it back (overlappable collectives).
        x = compute.constrain(x, lambda dp, tp: _P(
            dp if x.shape[0] > 1 else None, None,
            tp if compute._HINTS.get("carry_tp", True) else None))
        aux_tot = {"lb_loss": jnp.zeros((), jnp.float32),
                   "router_z": jnp.zeros((), jnp.float32)}
        new_caches = list(caches_c) if has_cache else None
        new_mem = list(mem_c) if mem_c is not None else None
        for slot, b in enumerate(cfg.period):
            pp = slot_params[slot]
            cs = _slice(caches_c[slot], idx) if has_cache else None
            x, nc, aux = blocks.block_apply(
                cfg, b, pp, x, positions=positions, causal=causal,
                cache=cs, decode_pos=decode_pos)
            if has_cache:
                new_caches[slot] = _update(new_caches[slot], nc, idx)
            if has_mem:
                hx = apply_norm(cfg, pp["norm_x"], x)
                mc = _slice(mem_c[slot], idx) if mem_caches is not None \
                    else None
                y, mkv = attention.apply_cross_attn(
                    cfg, pp["cross"], hx, memory=memory, mem_cache=mc)
                x = x + y
                if write_mem:
                    new_mem[slot] = _update(new_mem[slot], mkv, idx)
            aux_tot = jax.tree.map(lambda a, b: a + b, aux_tot, aux)
        return (x, tuple(new_caches) if has_cache else None,
                tuple(new_mem) if new_mem is not None else None, aux_tot)

    if remat:
        period_body = jax.checkpoint(
            period_body, policy=jax.checkpoint_policies.nothing_saveable,
            static_argnums=())

    # stacked mem caches to fill on prefill (donated zeros from make_cache)
    if write_mem:
        assert mem_init is not None, "prefill requires cache['mem']"
        mem0 = mem_init
    else:
        mem0 = mem_caches

    def scan_body(carry, slot_inputs):
        x, caches_c, mem_c = carry
        slot_params, idx = slot_inputs
        x, caches_c, mem_c, aux = period_body(x, caches_c, mem_c,
                                              slot_params, idx)
        return (x, caches_c, mem_c), aux

    n_periods = jax.tree.leaves(stack_params)[0].shape[0]
    (x, new_caches, new_mem), auxes = jax.lax.scan(
        scan_body, (x, caches, mem0),
        (stack_params, jnp.arange(n_periods)))
    aux = jax.tree.map(lambda a: a.sum(), auxes)
    return x, new_caches, new_mem, aux


# ---------------------------------------------------------------------------
# forward paths
# ---------------------------------------------------------------------------

def _embed(cfg, params, tokens):
    x = jnp.take(params["embed"], tokens, axis=0)
    return x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)


def _logits(cfg, params, x):
    head = params["embed"] if cfg.tie_embeddings else params["head"]
    return compute.matmul(x, head.T, site="lm_head").astype(jnp.float32)


def _prep_inputs(cfg, params, batch):
    """tokens (+ frontend prefix embeds) -> (x, positions, loss_mask)."""
    tokens = batch["tokens"]
    x = _embed(cfg, params, tokens)
    B, S_text = tokens.shape
    n_pre = 0
    if cfg.frontend != "none" and not cfg.enc_dec:
        fe = batch["frontend_embeds"]                   # (B, P, d)
        fe = compute.matmul(fe.astype(x.dtype), params["frontend_proj"],
                            site="frontend.proj")
        x = jnp.concatenate([fe, x], axis=1)
        n_pre = fe.shape[1]
    S = x.shape[1]
    positions = jnp.arange(S)
    mask = jnp.concatenate([jnp.zeros((n_pre,)), jnp.ones((S_text,))])
    return x, positions, mask, n_pre


def decoder_forward(cfg, params, batch, caches=None, decode_pos=None):
    if decode_pos is None:
        x, positions, mask, n_pre = _prep_inputs(cfg, params, batch)
    else:
        x = _embed(cfg, params, batch["tokens"])
        positions = decode_pos + jnp.arange(x.shape[1])
        mask, n_pre = None, 0
    x, new_caches, _, aux = _run_stack(
        cfg, params["blocks"], x, positions=positions, causal=True,
        caches=caches, decode_pos=decode_pos,
        remat=(decode_pos is None and caches is None))
    x = apply_norm(cfg, params["final_norm"], x)
    return x, new_caches, aux, mask, n_pre


def encdec_forward(cfg, params, batch, caches=None, decode_pos=None,
                   mem_caches=None, memory=None, mem_init=None):
    """Encoder runs only when memory/mem_caches are absent (train/prefill)."""
    if memory is None and mem_caches is None:
        src = batch["src_embeds"].astype(jnp.dtype(cfg.dtype))   # (B,Ss,d)
        pos_e = jnp.arange(src.shape[1])
        memory, _, _, _ = _run_stack(cfg, params["enc_blocks"], src,
                                     positions=pos_e, causal=False,
                                     remat=(decode_pos is None))
        memory = apply_norm(cfg, params["enc_norm"], memory)
    x = _embed(cfg, params, batch["tokens"])
    if decode_pos is None:
        positions = jnp.arange(x.shape[1])
    else:
        positions = decode_pos + jnp.arange(x.shape[1])
    x, new_caches, new_mem, aux = _run_stack(
        cfg, params["dec_blocks"], x, positions=positions, causal=True,
        caches=caches, decode_pos=decode_pos, memory=memory,
        mem_caches=mem_caches, mem_init=mem_init,
        remat=(decode_pos is None and caches is None))
    x = apply_norm(cfg, params["final_norm"], x)
    return x, new_caches, new_mem, aux


# ---------------------------------------------------------------------------
# public step functions
# ---------------------------------------------------------------------------

def _xent(logits, targets, mask):
    """Cross-entropy in f32.  logits (B,S,V), targets (B,S), mask (S,) or
    (B,S).  The gold logit is picked with a one-hot contraction rather than
    take_along_axis: a gather along the TP-sharded vocab axis would force
    GSPMD to replicate the logits (checked: 700+ GiB/device on 256k vocabs);
    the one-hot einsum partitions cleanly and reduces over the shard."""
    from jax.sharding import PartitionSpec as _P
    spec = lambda dp, tp: _P(dp if logits.shape[0] > 1 else None, None, tp)
    logits = compute.constrain(logits, spec)
    lse = jax.nn.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(targets, logits.shape[-1], dtype=logits.dtype)
    onehot = compute.constrain(onehot, spec)
    gold = jnp.einsum("bsv,bsv->bs", logits, onehot)
    nll = lse - gold
    if mask is not None:
        mask = jnp.broadcast_to(mask, nll.shape)
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()


def train_loss(cfg: ModelConfig, params, batch):
    if cfg.enc_dec:
        x, _, _, aux = encdec_forward(cfg, params, batch)
        mask = None
        n_pre = 0
    else:
        x, _, aux, mask, n_pre = decoder_forward(cfg, params, batch)
    logits = _logits(cfg, params, x)
    tgt = batch["targets"]
    if n_pre:
        logits = logits[:, n_pre:]
        mask = None
    loss = _xent(logits, tgt, mask if not n_pre else None)
    total = loss + 1e-2 * aux["lb_loss"] + 1e-3 * aux["router_z"]
    return total, {"xent": loss, **aux}


def make_cache(cfg: ModelConfig, batch: int, ctx: int, dtype):
    n_periods = ((cfg.n_dec_layers if cfg.enc_dec else cfg.n_layers)
                 // len(cfg.period))

    def stacked(mk):
        one = mk()
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n_periods,) + a.shape).copy(), one)

    caches = tuple(
        stacked(lambda b=b: blocks.block_cache(cfg, b, batch, ctx, dtype))
        for b in cfg.period)
    out = {"caches": caches}
    if cfg.enc_dec:
        out["mem"] = tuple(
            stacked(lambda: attention.make_attn_cache(cfg, batch, ctx, dtype))
            for _ in cfg.period)
    return out


def prefill(cfg: ModelConfig, params, batch, cache):
    """Fill the cache from a full-sequence forward; return last logits."""
    if cfg.enc_dec:
        x, new_caches, new_mem, _ = encdec_forward(
            cfg, params, batch, caches=cache["caches"],
            mem_init=cache["mem"])
        out_cache = {"caches": new_caches, "mem": new_mem}
    else:
        x, new_caches, _, _, _ = decoder_forward(
            cfg, params, batch, caches=cache["caches"])
        out_cache = {"caches": new_caches}
    logits = _logits(cfg, params, x[:, -1:])[:, 0]
    return logits, out_cache


def decode_step(cfg: ModelConfig, params, token, pos, cache):
    """token (B,1) int32; pos scalar int32 — absolute position of the new
    token; cache holds ctx positions.  Returns (logits (B,V), new_cache)."""
    batch = {"tokens": token}
    if cfg.enc_dec:
        x, new_caches, new_mem, _ = encdec_forward(
            cfg, params, batch, caches=cache["caches"],
            mem_caches=cache["mem"], decode_pos=pos)
        out_cache = {"caches": new_caches, "mem": cache["mem"]}
    else:
        x, new_caches, _, _, _ = decoder_forward(
            cfg, params, batch, caches=cache["caches"], decode_pos=pos)
        out_cache = {"caches": new_caches}
    logits = _logits(cfg, params, x)[:, 0]
    return logits, out_cache


def build_model(cfg: ModelConfig) -> Model:
    return Model(
        cfg=cfg,
        init=functools.partial(model_init, cfg),
        train_loss=functools.partial(train_loss, cfg),
        prefill=functools.partial(prefill, cfg),
        decode_step=functools.partial(decode_step, cfg),
        make_cache=functools.partial(make_cache, cfg),
    )
