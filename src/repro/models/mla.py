"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

Prefill/train uses the expanded form; decode uses the *absorbed* form: the
cache stores only the compressed latent c_kv (kv_lora_rank) + the shared
rope key (qk_rope_dim) per position — 576 floats/token for the 236B config —
and scores are computed against the latent directly (W_UK absorbed into q,
W_UV applied after the attention-weighted latent sum).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import compute
from repro.models.common import apply_rope, dense_init, split_keys


def mla_init(cfg: ModelConfig, key, dtype):
    d, h = cfg.d_model, cfg.n_heads
    r_kv, r_q = cfg.kv_lora_rank, cfg.q_lora_rank
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    ks = split_keys(key, 8)
    p = {
        "wkv_a": dense_init(ks[0], (d, r_kv + dr), dtype),
        "kv_norm": jnp.ones((r_kv,), dtype),
        "w_uk": dense_init(ks[1], (r_kv, h, dn), dtype),
        "w_uv": dense_init(ks[2], (r_kv, h, dv), dtype),
        "wo": dense_init(ks[3], (h * dv, d), dtype),
    }
    if r_q:
        p["wq_a"] = dense_init(ks[4], (d, r_q), dtype)
        p["q_norm"] = jnp.ones((r_q,), dtype)
        p["wq_b"] = dense_init(ks[5], (r_q, h * (dn + dr)), dtype)
    else:
        p["wq"] = dense_init(ks[4], (d, h * (dn + dr)), dtype)
    return p


def _rmsn(x, scale):
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt((xf ** 2).mean(-1, keepdims=True) + 1e-6)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def _q_heads(cfg: ModelConfig, p, x, positions):
    B, S, _ = x.shape
    h, dn, dr = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim
    if cfg.q_lora_rank:
        cq = _rmsn(compute.matmul(x, p["wq_a"], site="mla.q_down"), p["q_norm"])
        q = compute.matmul(cq, p["wq_b"], site="mla.q_up")
    else:
        q = compute.matmul(x, p["wq"], site="mla.q")
    q = q.reshape(B, S, h, dn + dr).transpose(0, 2, 1, 3)     # (B,h,S,dn+dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta, "1d")
    return q_nope, q_rope


def _latent(cfg: ModelConfig, p, x, positions):
    r_kv, dr = cfg.kv_lora_rank, cfg.qk_rope_dim
    kv = compute.matmul(x, p["wkv_a"], site="mla.kv_down")     # (B,S,r_kv+dr)
    c_kv = _rmsn(kv[..., :r_kv], p["kv_norm"])
    k_rope = kv[..., None, r_kv:].transpose(0, 2, 1, 3)        # (B,1,S,dr)
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta, "1d")
    return c_kv, k_rope


def apply_mla(cfg: ModelConfig, p, x, *, positions, causal: bool,
              cache: Optional[dict] = None, decode_pos=None):
    """Returns (y, new_cache_or_None).  Cache: {"c_kv": (B,S,r), "k_rope":
    (B,1,S,dr)}."""
    B, S, _ = x.shape
    h = cfg.n_heads
    dn, dr, dv, r_kv = (cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim,
                        cfg.kv_lora_rank)
    q_nope, q_rope = _q_heads(cfg, p, x, positions)

    if cache is not None and decode_pos is not None:
        # ----- absorbed decode -----
        c_new, kr_new = _latent(cfg, p, x, positions)
        c_kv = jax.lax.dynamic_update_slice_in_dim(
            cache["c_kv"], c_new, decode_pos, axis=1)
        k_rope = jax.lax.dynamic_update_slice_in_dim(
            cache["k_rope"], kr_new, decode_pos, axis=2)
        new_cache = {"c_kv": c_kv, "k_rope": k_rope}
        # absorb W_UK into q: (B,h,1,dn) x (r,h,dn) -> (B,h,1,r)
        q_lat = jnp.einsum("bhsd,rhd->bhsr", q_nope, p["w_uk"])
        scale = 1.0 / jnp.sqrt(jnp.float32(dn + dr))
        s = (jnp.einsum("bhsr,bTr->bhsT", q_lat, c_kv)
             + jnp.einsum("bhsd,bxTd->bhsT", q_rope, k_rope))
        s = s.astype(jnp.float32) * scale
        ctx = cache["c_kv"].shape[1]
        mask = jnp.arange(ctx)[None, None, None, :] <= decode_pos
        s = jnp.where(mask, s, -jnp.inf)
        pr = jax.nn.softmax(s, axis=-1).astype(x.dtype)
        ctx_lat = jnp.einsum("bhsT,bTr->bhsr", pr, c_kv)
        o = jnp.einsum("bhsr,rhd->bhsd", ctx_lat, p["w_uv"])   # (B,h,1,dv)
        o = o.transpose(0, 2, 1, 3).reshape(B, S, h * dv)
        y = compute.matmul(o, p["wo"], site="mla.o")
        return y, new_cache

    # ----- expanded train / prefill -----
    c_kv, k_rope = _latent(cfg, p, x, positions)
    k_nope = jnp.einsum("bsr,rhd->bhsd", c_kv, p["w_uk"])      # (B,h,S,dn)
    v = jnp.einsum("bsr,rhd->bhsd", c_kv, p["w_uv"])           # (B,h,S,dv)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(
        k_rope, (B, h, S, dr))], axis=-1)
    o = compute.flash_attention(q, k, v, site="mla.core", causal=causal)
    o = o.transpose(0, 2, 1, 3).reshape(B, S, h * dv)
    y = compute.matmul(o, p["wo"], site="mla.o")
    new_cache = None
    if cache is not None:
        new_cache = {"c_kv": c_kv, "k_rope": k_rope}
    return y, new_cache


def make_mla_cache(cfg: ModelConfig, batch: int, ctx: int, dtype):
    return {"c_kv": jnp.zeros((batch, ctx, cfg.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((batch, 1, ctx, cfg.qk_rope_dim), dtype)}
