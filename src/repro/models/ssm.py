"""Mamba mixer in the SSD (state-space dual / Mamba-2) formulation.

Training/prefill run the chunkwise-parallel algorithm: intra-chunk terms are
4 batched matmuls over (chunk x chunk) decay-masked score matrices (exactly
the structure our Pallas chunk-scan kernel tiles); inter-chunk state is a
`lax.scan` carrying (B, h, P, N).  Decode is the O(1) recurrence.

The chunk size ``cfg.ssm_chunk`` is a tunable kernel-site factor — the IF
analogue for recurrent blocks (DESIGN.md §2).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import compute
from repro.models.common import dense_init, split_keys


def ssm_init(cfg: ModelConfig, key, dtype):
    d = cfg.d_model
    di, n, h = cfg.d_inner_ssm, cfg.ssm_state_dim, cfg.n_ssm_heads
    w = cfg.ssm_conv_width
    ks = split_keys(key, 4)
    conv_ch = di + 2 * n
    return {
        # in_proj -> [z(di) | x(di) | B(n) | C(n) | dt(h)]
        "in_proj": dense_init(ks[0], (d, 2 * di + 2 * n + h), dtype),
        "conv": dense_init(ks[1], (w, conv_ch), dtype, scale=0.5),
        "A_log": jnp.zeros((h,), jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm": jnp.ones((di,), dtype),
        "out_proj": dense_init(ks[2], (di, d), dtype),
    }


def _causal_conv(x, w, conv_state=None):
    """Depthwise causal conv. x: (B,S,C), w: (W,C).  If conv_state (B,W-1,C)
    is given (decode), prepend it; returns (y, new_state)."""
    W = w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    else:
        pad = conv_state
    xp = jnp.concatenate([pad, x], axis=1)                     # (B,S+W-1,C)
    y = sum(xp[:, i:i + x.shape[1]] * w[i][None, None] for i in range(W))
    new_state = xp[:, -(W - 1):] if W > 1 else jnp.zeros_like(pad)
    return y, new_state


def _project(cfg: ModelConfig, p, x):
    di, n, h = cfg.d_inner_ssm, cfg.ssm_state_dim, cfg.n_ssm_heads
    zxbcdt = compute.matmul(x, p["in_proj"], site="ssm.in_proj")
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:di + di + 2 * n]
    dt = zxbcdt[..., -h:]
    return z, xbc, dt


def _split_xbc(cfg, xbc):
    di, n = cfg.d_inner_ssm, cfg.ssm_state_dim
    xs = xbc[..., :di]
    Bm = xbc[..., di:di + n]
    Cm = xbc[..., di + n:]
    return xs, Bm, Cm


def apply_ssm(cfg: ModelConfig, p, x, *, cache: Optional[dict] = None,
              decode_pos=None):
    """x: (B,S,d). Returns (y, new_cache_or_None).

    Cache: {"conv": (B, W-1, di+2n), "ssd": (B, h, P, N)}.
    """
    B, S, _ = x.shape
    di, N, h = cfg.d_inner_ssm, cfg.ssm_state_dim, cfg.n_ssm_heads
    P = cfg.ssm_head_dim
    A = -jnp.exp(p["A_log"])                                   # (h,) negative

    z, xbc, dt_raw = _project(cfg, p, x)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"])                       # (B,S,h)

    if cache is not None and decode_pos is not None and S == 1:
        # ---------- O(1) decode recurrence ----------
        xbc_c, conv_state = _causal_conv(xbc, p["conv"], cache["conv"])
        xs, Bm, Cm = _split_xbc(cfg, jax.nn.silu(xbc_c))
        xh = xs.reshape(B, 1, h, P)[:, 0]                      # (B,h,P)
        a = jnp.exp(dt[:, 0] * A[None])                        # (B,h)
        dBx = jnp.einsum("bh,bhp,bn->bhpn", dt[:, 0],
                         xh.astype(jnp.float32), Bm[:, 0].astype(jnp.float32))
        state = cache["ssd"] * a[..., None, None] + dBx        # (B,h,P,N)
        y = jnp.einsum("bhpn,bn->bhp", state, Cm[:, 0].astype(jnp.float32))
        y = y + p["D"][None, :, None] * xh.astype(jnp.float32)
        y = y.reshape(B, 1, di).astype(x.dtype)
        y = _gated_out(cfg, p, y, z)
        return y, {"conv": conv_state, "ssd": state}

    # ---------- chunkwise-parallel train / prefill ----------
    if compute._STATE.recorder is not None:
        compute._STATE.recorder.record(compute.KernelSite(
            site="ssm.chunk_scan", kind="chunk_scan", m=cfg.ssm_chunk,
            n=P, k=N, batch=B * h * (S // max(1, cfg.ssm_chunk)),
            dtype=str(x.dtype)))
    xbc_c, conv_state = _causal_conv(xbc, p["conv"])
    xs, Bm, Cm = _split_xbc(cfg, jax.nn.silu(xbc_c))

    Q = min(cfg.ssm_chunk, S)
    Sp = -(-S // Q) * Q
    if Sp != S:
        # zero-pad to a chunk multiple: dt=0 => decay exp(0)=1 and zero
        # input, i.e. identity steps that leave the carried state untouched
        pad = ((0, 0), (0, Sp - S), (0, 0))
        xs, Bm, Cm, dt = (jnp.pad(t, pad) for t in (xs, Bm, Cm, dt))
    nc = Sp // Q

    def resh(t, last):
        return t.reshape(B, nc, Q, *last)

    xh = resh(xs, (h, P)).astype(jnp.float32)                  # (B,nc,Q,h,P)
    Bc = resh(Bm, (N,)).astype(jnp.float32)                    # (B,nc,Q,N)
    Cc = resh(Cm, (N,)).astype(jnp.float32)
    dtc = resh(dt, (h,))                                       # (B,nc,Q,h)

    init = (cache["ssd"].astype(jnp.float32) if cache is not None
            else jnp.zeros((B, h, P, N), jnp.float32))
    causal = jnp.tril(jnp.ones((Q, Q), bool))

    # scan over chunks: the (B,Q,Q,h) decay mask exists for ONE chunk at a
    # time — materializing it for all chunks at once was measured at tens
    # of GiB/device on the 32L hybrid config
    def chunk_body(state, inp):
        xc, bc, cc, dc = inp            # (B,Q,h,P),(B,Q,N),(B,Q,N),(B,Q,h)
        la = dc * A[None, None]                                # (B,Q,h)
        cum = jnp.cumsum(la, axis=1)
        Lm = cum[:, :, None, :] - cum[:, None, :, :]           # (B,Q,Q,h)
        Lm = jnp.where(causal[None, :, :, None], jnp.exp(Lm), 0.0)
        cb = jnp.einsum("biN,bjN->bij", cc, bc)                # (B,Q,Q)
        xdt = xc * dc[..., None]                               # (B,Q,h,P)
        y_intra = jnp.einsum("bijh,bjhp->bihp", cb[..., None] * Lm, xdt)
        y_inter = jnp.einsum("bih,biN,bhpN->bihp",
                             jnp.exp(cum), cc, state)
        seg = jnp.exp(cum[:, -1:, :] - cum)                    # (B,Q,h)
        new_state = (state * jnp.exp(cum[:, -1])[..., None, None]
                     + jnp.einsum("bjh,bjN,bjhp->bhpN", seg, bc, xdt))
        return new_state, y_intra + y_inter

    final_state, ys = jax.lax.scan(
        chunk_body, init,
        tuple(jnp.moveaxis(t, 1, 0) for t in (xh, Bc, Cc, dtc)))
    y = jnp.moveaxis(ys, 0, 1)                                 # (B,nc,Q,h,P)
    y = y + p["D"][None, None, None, :, None] * xh
    y = y.reshape(B, Sp, di)[:, :S].astype(x.dtype)
    y = _gated_out(cfg, p, y, z)

    new_cache = None
    if cache is not None:
        new_cache = {"conv": conv_state, "ssd": final_state}
    return y, new_cache


def _gated_out(cfg, p, y, z):
    y = y * jax.nn.silu(z)
    yf = y.astype(jnp.float32)
    yf = yf * jax.lax.rsqrt((yf ** 2).mean(-1, keepdims=True) + 1e-6)
    y = (yf * p["norm"].astype(jnp.float32)).astype(y.dtype)
    return compute.matmul(y, p["out_proj"], site="ssm.out_proj")


def make_ssm_cache(cfg: ModelConfig, batch: int, dtype):
    di, N, h = cfg.d_inner_ssm, cfg.ssm_state_dim, cfg.n_ssm_heads
    P, W = cfg.ssm_head_dim, cfg.ssm_conv_width
    return {"conv": jnp.zeros((batch, W - 1, di + 2 * N), dtype),
            "ssd": jnp.zeros((batch, h, P, N), jnp.float32)}
