"""Fused on-device tune path — the cost grid, inf-masking, and greedy
argmin as ONE jitted dispatch.

``core/costmodel_vec.py`` evaluates ``(n_sites, n_actions)`` grids in
float64 NumPy; brute-force `act` is then a host-side argmin and a Python
decode loop.  For serving, that is several host round-trips per request.
This module re-expresses the same pipeline in JAX so a model-oracle
``tune`` is a single device dispatch:

* the three per-kind cost kernels translated op-for-op from
  ``costmodel_vec`` (float32 on device — argmin agreement with the
  float64 reference is asserted in ``tests/test_serving.py``);
* every kind's action-tile grid padded into one ``(3, a_max, 3)``
  constant baked into the trace, with per-kind action counts masking the
  padding columns to ``inf`` so a row argmin *is* the flat action;
* flat-action → head-index decode and tile lookup on device, so the only
  host transfer is the final result arrays.

The batch dimension is padded up to a power-of-two bucket (rows replicate
row 0) so concurrent serving batches of varying size reuse one jit
specialization; ``trace_count`` is incremented *inside* the jitted impl —
i.e. only when XLA (re)traces — and ``dispatch_count`` once per call, the
counters ``BENCH_serving.json`` and the tests use to assert the
one-dispatch/no-per-site-host-sync property.

``surrogate=`` swaps the analytic formulas for the learned cost model
(PR 7): the 19-dim featurizer, z-normalization, and the MLP-ensemble
forward all run inside the same jit, with analytic legality still
masking VMEM-illegal tiles to ``inf``.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import costmodel as cm
from repro.core import costmodel_vec
from repro.core.env import ActionSpace
from repro.core.vectorizer import TileProgram
from repro.models.compute import KernelSite

KINDS = ("matmul", "attention", "chunk_scan")
_KIND_IDX = {k: i for i, k in enumerate(KINDS)}

_LOG_CLAMP = 64.0           # surrogate prior stand-in for log2(inf)


def bucket_size(n: int, floor: int = 8) -> int:
    """Next power of two >= max(n, floor) — bounds distinct jit shapes."""
    b = floor
    while b < n:
        b *= 2
    return b


# ---------------------------------------------------------------------------
# device cost kernels (op-for-op translations of costmodel_vec, float32)
# ---------------------------------------------------------------------------


def _ceil(a, b):
    return -(-a // b)


def _mxu_util(bm, bn, bk):
    u = (jnp.minimum(bm, cm.MXU) / cm.MXU
         * (jnp.minimum(bn, cm.LANE) / cm.LANE))
    u = jnp.where(bm % cm.SUBLANE != 0, u * 0.6, u)
    u = jnp.where(bn % cm.LANE != 0, u * 0.5, u)
    u = u * (bk / (bk + cm.MXU))
    return jnp.maximum(u, 1e-3)


def _matmul_cost(c, t0, t1, t2):
    M, N, K, s, peak = c["m"], c["n"], c["k"], c["s"], c["peak"]
    tm, tn, tk = _ceil(M, t0), _ceil(N, t1), _ceil(K, t2)
    vmem = 2 * (t0 * t2 + t2 * t1) * s + t0 * t1 * 4 + t0 * t1 * s
    legal = vmem <= cm.VMEM_BYTES
    pm = (tm * t0).astype(jnp.float32)
    pn = (tn * t1).astype(jnp.float32)
    pk = (tk * t2).astype(jnp.float32)
    grid = tm.astype(jnp.float32) * tn * tk
    flops = 2.0 * pm * pn * pk
    t_compute = flops / (peak * _mxu_util(t0, t1, t2))
    bytes_ = pm * pk * tn * s + pk * pn * tm * s + pm * pn * s
    t_mem = bytes_ / cm.HBM_BW
    cost = (jnp.maximum(t_compute, t_mem) + grid * cm.GRID_STEP_OVERHEAD
            + cm.FIXED_OVERHEAD)
    return jnp.where(legal, cost, jnp.inf)


def _attention_cost(c, t0, t1, t2):
    # site semantics: m=Sq, k=Skv, n=D, batch=B*H; tiles (bq, bkv, 1)
    Sq, Skv, D, BH = c["m"], c["k"], c["n"], c["batch"]
    causal, s, peak = c["causal"], c["s"], c["peak"]
    bq, bkv = t0, t1
    tq, tkv = _ceil(Sq, bq), _ceil(Skv, bkv)
    vmem = (2 * (bq * D + 2 * bkv * D) * s + bq * D * 4 + 2 * bq * 4
            + bq * bkv * 4)
    legal = vmem <= cm.VMEM_BYTES
    pq = (tq * bq).astype(jnp.float32)
    pkv = (tkv * bkv).astype(jnp.float32)
    grid = BH.astype(jnp.float32) * tq * tkv
    frac = jnp.where(causal, 0.5 * (1 + 1 / jnp.maximum(tq, 1)), 1.0)
    flops = 4.0 * BH * pq * pkv * D * frac
    vpu_ops = 6.0 * BH * pq * pkv * frac
    t_compute = (flops / (peak * _mxu_util(bq, bkv, D))
                 + vpu_ops / (cm.PEAK_FLOPS_BF16 / 16))
    bytes_ = BH * s * (pq * D + 2 * pkv * D * tq * frac + pq * D)
    t_mem = bytes_ / cm.HBM_BW
    cost = (jnp.maximum(t_compute, t_mem)
            + grid * frac * cm.GRID_STEP_OVERHEAD + cm.FIXED_OVERHEAD)
    return jnp.where(legal, cost, jnp.inf)


def _chunk_scan_cost(c, t0, t1, t2):
    # tiles (chunk, 1, 1); P=site.n, N=site.k
    m, P, N, batch, s, peak = c["m"], c["n"], c["k"], c["batch"], c["s"], \
        c["peak"]
    Q = t0
    tokens = batch * m
    vmem = 2 * Q * (P + 2 * N) * s + P * N * 4 + Q * Q * 4
    legal = vmem <= cm.VMEM_BYTES
    chunks_total = _ceil(tokens, Q)
    per_chunk = 2.0 * Q * Q * N + 2.0 * Q * Q * P + 4.0 * Q * P * N
    flops = per_chunk * chunks_total
    t_compute = flops / (peak * _mxu_util(Q, jnp.maximum(P, N), Q))
    bytes_ = tokens.astype(jnp.float32) * (P + 2 * N) * s * 2
    t_mem = bytes_ / cm.HBM_BW
    cost = (jnp.maximum(t_compute, t_mem)
            + chunks_total * cm.GRID_STEP_OVERHEAD + cm.FIXED_OVERHEAD)
    return jnp.where(legal, cost, jnp.inf)


_KIND_COST = (_matmul_cost, _attention_cost, _chunk_scan_cost)


# ---------------------------------------------------------------------------
# site packing (host, one O(n) pass — mirrors costmodel_vec._site_cols)
# ---------------------------------------------------------------------------


def _pack_sites(sites: Sequence[KernelSite], pad_to: int):
    rows = [(s.m, s.n, s.k, s.batch, s.causal,
             *costmodel_vec._dtype_meta(s.dtype)) for s in sites]
    if pad_to > len(rows):                  # replicate row 0 into padding
        rows = rows + [rows[0]] * (pad_to - len(rows))
    m, n, k, b, causal, sb, peak = zip(*rows)
    cols = {"m": np.array(m, np.int32), "n": np.array(n, np.int32),
            "k": np.array(k, np.int32), "batch": np.array(b, np.int32),
            "causal": np.array(causal, bool), "s": np.array(sb, np.int32),
            "peak": np.array(peak, np.float32)}
    kind_idx = np.array([_KIND_IDX[s.kind] for s in sites]
                        + [0] * (pad_to - len(sites)), np.int32)
    return cols, kind_idx


class FusedTuner:
    """Model/surrogate-oracle tuning as one jitted device dispatch.

    ``actions(sites)`` returns the same ``(n, 3)`` head indices as the
    brute-force argmin over ``oracle.cost_grid`` (flat-action order and
    argmin tie-breaking preserved); ``tune(sites)`` wraps them into a
    :class:`TileProgram`.  Pass ``surrogate=`` (a trained
    :class:`~repro.surrogate.model.SurrogateModel`) to price the grid
    with the learned model instead of the analytic formulas.
    """

    def __init__(self, cfg, surrogate=None):
        self.space = ActionSpace(cfg)
        self.surrogate = surrogate
        grids = {k: costmodel_vec.action_tiles_grid(self.space, k)
                 for k in KINDS}
        self._a_max = max(len(g) for g in grids.values())
        # padded per-kind tile grids + action counts + head sizes: numpy
        # constants closed over by the impl, baked in at trace time
        G = np.ones((3, self._a_max, 3), np.int32)
        NA = np.zeros((3,), np.int32)
        VS = np.ones((3, 3), np.int32)
        for i, k in enumerate(KINDS):
            G[i, :len(grids[k])] = grids[k]
            NA[i] = len(grids[k])
            VS[i] = self.space.valid_sizes(k)
        self._G, self._NA, self._VS = G, NA, VS
        if surrogate is not None:
            self._sur_params = jax.tree.map(jnp.asarray, surrogate.params)
            self._sur_stats = (
                jnp.asarray(surrogate.x_mean, jnp.float32),
                jnp.asarray(np.asarray(surrogate.x_std, np.float64),
                            jnp.float32))
        self._jit = jax.jit(self._impl)
        self.trace_count = 0      # bumped inside the impl: only on (re)trace
        self.dispatch_count = 0   # bumped once per tune/actions call
        self.sites_tuned = 0
        self.last_padded_batch = 0

    # -- the fused pipeline (everything below runs inside one jit) ----------
    def _surrogate_pred(self, c, kidx, t, grid_steps, vmem, analytic):
        """(B, a_max) predicted seconds from the 19-dim featurizer + the
        MLP-ensemble forward, all on device (feature layout matches
        ``surrogate/features.py::featurize`` column-for-column)."""
        B, A = kidx.shape[0], self._a_max

        def col(x):                         # (B,) -> (B, a_max, 1)
            return jnp.broadcast_to(
                x.astype(jnp.float32)[:, None, None], (B, A, 1))

        lt = jnp.log2(jnp.maximum(t.astype(jnp.float32), 1e-30))
        ldims = jnp.log2(jnp.stack(
            [c["m"], c["n"], c["k"], c["batch"]], -1).astype(jnp.float32))
        prior = jnp.where(jnp.isfinite(analytic),
                          jnp.log2(jnp.maximum(analytic, 1e-30)),
                          _LOG_CLAMP)
        feats = ([col(kidx == i) for i in range(3)]            # 0-2 one-hot
                 + [col(ldims[:, i]) for i in range(4)]        # 3-6 dims
                 + [col(c["s"]),                               # 7 bytes
                    col(c["causal"]),                          # 8 causal
                    lt,                                        # 9-11 tiles
                    lt - ldims[:, None, :3],                   # 12-14 ratios
                    jnp.log2(jnp.maximum(vmem, 1e-30))[..., None],   # 15
                    (vmem / cm.VMEM_BYTES)[..., None],               # 16
                    jnp.log2(jnp.maximum(grid_steps, 1.0))[..., None],  # 17
                    prior[..., None]])                               # 18
        X = jnp.concatenate(feats, -1).reshape(-1, 19)    # (B*a_max, 19)
        x_mean, x_std = self._sur_stats
        Xn = (X - x_mean) / x_std
        preds = []
        for member in self._sur_params:
            h = Xn
            for layer in member[:-1]:
                h = jnp.tanh(h @ layer["w"] + layer["b"])
            preds.append((h @ member[-1]["w"] + member[-1]["b"])[:, 0])
        pred = jnp.mean(jnp.stack(preds), 0)
        pred = pred * self.surrogate.y_std + self.surrogate.y_mean
        return jnp.exp(pred).reshape(B, A)   # log-seconds -> seconds

    def _analytic(self, c, kidx, t):
        """(B, a_max) analytic costs with per-kind selection."""
        t0, t1, t2 = t[..., 0], t[..., 1], t[..., 2]
        cc = {k: (v[:, None] if v.ndim == 1 else v) for k, v in c.items()}
        costs = [fn(cc, t0, t1, t2) for fn in _KIND_COST]
        return jnp.select([kidx[:, None] == i for i in range(3)], costs)

    def _vmem_grid(self, c, kidx, t):
        """(B, a_max) VMEM footprint + grid steps per the featurizer's
        formulas (``surrogate/features.py::_vmem_and_grid``)."""
        t0 = t[..., 0].astype(jnp.float32)
        t1 = t[..., 1].astype(jnp.float32)
        t2 = t[..., 2].astype(jnp.float32)
        m = c["m"].astype(jnp.float32)[:, None]
        n = c["n"].astype(jnp.float32)[:, None]
        k = c["k"].astype(jnp.float32)[:, None]
        b = c["batch"].astype(jnp.float32)[:, None]
        s = c["s"].astype(jnp.float32)[:, None]
        vmems = jnp.stack([
            2 * (t0 * t2 + t2 * t1) * s + t0 * t1 * 4 + t0 * t1 * s,
            (2 * (t0 * n + 2 * t1 * n) * s + t0 * n * 4 + 2 * t0 * 4
             + t0 * t1 * 4),
            2 * t0 * (n + 2 * k) * s + n * k * 4 + t0 * t0 * 4])
        grids = jnp.stack([
            jnp.ceil(m / t0) * jnp.ceil(n / t1) * jnp.ceil(k / t2),
            b * jnp.ceil(m / t0) * jnp.ceil(k / t1),
            jnp.ceil(b * m / t0)])
        sel = [kidx[:, None] == i for i in range(3)]
        return jnp.select(sel, list(vmems)), \
            jnp.maximum(jnp.select(sel, list(grids)), 1.0)

    def _impl(self, cols, kind_idx):
        self.trace_count += 1
        t = jnp.asarray(self._G)[kind_idx]          # (B, a_max, 3)
        analytic = self._analytic(cols, kind_idx, t)
        if self.surrogate is not None:
            vmem, grid_steps = self._vmem_grid(cols, kind_idx, t)
            pred = self._surrogate_pred(cols, kind_idx, t, grid_steps, vmem,
                                        analytic)
            # a tile the analytic model rejects has no runtime to predict
            cost = jnp.where(jnp.isfinite(analytic), pred, jnp.inf)
        else:
            cost = analytic
        pad = (jnp.arange(self._a_max)[None, :]
               >= jnp.asarray(self._NA)[kind_idx][:, None])
        cost = jnp.where(pad, jnp.inf, cost)
        flat = jnp.argmin(cost, axis=1)             # first-min, like numpy
        tiles = jnp.take_along_axis(t, flat[:, None, None], 1)[:, 0]
        vs = jnp.asarray(self._VS)[kind_idx]        # (B, 3) head sizes
        heads = jnp.stack([flat // (vs[:, 1] * vs[:, 2]),
                           (flat // vs[:, 2]) % vs[:, 1],
                           flat % vs[:, 2]], -1)
        best = jnp.take_along_axis(cost, flat[:, None], 1)[:, 0]
        return heads, tiles, best

    # -- host entry points ---------------------------------------------------
    def _run(self, sites: Sequence[KernelSite]):
        n = len(sites)
        b = bucket_size(n)
        cols, kind_idx = _pack_sites(sites, b)
        heads, tiles, best = self._jit(cols, kind_idx)
        self.dispatch_count += 1
        self.sites_tuned += n
        self.last_padded_batch = b
        return (np.asarray(heads)[:n], np.asarray(tiles)[:n],
                np.asarray(best)[:n])

    def actions(self, sites: Sequence[KernelSite]) -> np.ndarray:
        """(n, 3) greedy head indices — the device-side brute argmin."""
        if not len(sites):
            return np.zeros((0, 3), np.int64)
        return self._run(sites)[0].astype(np.int64)

    def tune(self, sites: Sequence[KernelSite]) -> TileProgram:
        """Greedy tiles for ``sites`` as one device dispatch."""
        if not len(sites):
            return TileProgram()
        _, tiles, _ = self._run(sites)
        return TileProgram({s.key(): tuple(int(x) for x in t)
                            for s, t in zip(sites, tiles)})

    def tune_many(self, site_lists) -> "list[TileProgram]":
        """One program per request from ONE dispatch over the
        concatenation (the fused route of the serving micro-batcher) —
        the per-site costs are row-independent, so each slice is bitwise
        equal to tuning that request alone."""
        flat = [s for sl in site_lists for s in sl]
        if not flat:
            return [TileProgram() for _ in site_lists]
        _, tiles, _ = self._run(flat)
        out, off = [], 0
        for sl in site_lists:
            out.append(TileProgram(
                {s.key(): tuple(int(x) for x in t)
                 for s, t in zip(sl, tiles[off:off + len(sl)])}))
            off += len(sl)
        return out

    def stats(self) -> Dict[str, float]:
        return {"serving_fused_dispatches_total": self.dispatch_count,
                "serving_fused_traces_total": self.trace_count,
                "serving_fused_sites_total": self.sites_tuned}
