"""``repro.serving`` — batched on-device inference behind a latency SLO.

The serving layer over :class:`~repro.service.TuningService` (the
ROADMAP's "on-device search + latency-SLO serving path"):

* :class:`FusedTuner` — model/surrogate-oracle tuning as ONE jitted
  device dispatch (cost grid + inf-masking + greedy argmin end to end);
* :class:`AgentBatch` — concurrent sessions' ``act`` calls coalesced
  through a single jitted agent forward, bitwise equal to unbatched;
* :class:`Server` — the deadline-aware admission queue: per-request SLO
  budgets, max-wait/max-batch flush, typed shedding
  (:class:`QueueFull` / :class:`DeadlineExceeded`), PR 6-style
  ``health()``, unified ``serving_*`` ``stats()``.

Callers normally never touch this package directly::

    with TuningService(cfg, serving=True) as svc:      # or ServingConfig(...)
        s = svc.open_session(agent="brute", oracle="model")
        prog = s.tune_async(sites).result()            # one device dispatch
"""
from repro.serving.batcher import AgentBatch
from repro.serving.fused import FusedTuner, bucket_size
from repro.serving.server import (DeadlineExceeded, QueueFull, Server,
                                  ServingConfig, ServingError)

__all__ = ["AgentBatch", "FusedTuner", "bucket_size", "Server",
           "ServingConfig", "ServingError", "QueueFull",
           "DeadlineExceeded"]
