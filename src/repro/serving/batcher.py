"""Cross-session micro-batching: many concurrent ``act``/``tune``
requests, one jitted agent forward.

Almost every agent in the registry prices sites independently per row,
so a batch formed by *concatenating* several requests' site lists and
running one forward produces, for each request, results bitwise equal to
running that request alone (spy-asserted in ``tests/test_serving.py``
for all seven agents).  :class:`AgentBatch` is that concat → one forward
→ split step; the admission queue in :mod:`repro.serving.server` decides
*when* a batch is cut.

The one exception is :class:`~repro.core.agents.random_search
.RandomAgent`: its deterministic deployment draw is shaped by the whole
batch (``rng.integers(..., size=(n, 3))`` from the construction seed),
so concatenation would change every request's actions.  Batch-unsafe
agents run one ``act`` per request inside the flush instead — parity by
construction, no coalescing win.

For :class:`~repro.core.agents.ppo.PPOAgent` the forward goes through
:meth:`~repro.core.agents.ppo.PPOAgent.act_bucketed` — the batch
dimension is padded up to a power-of-two bucket so concurrent batches of
varying size reuse one jit specialization instead of retracing per
batch shape (the serving-stack analogue of PR 1's fused PPO step).
"""
from __future__ import annotations

from typing import List, Sequence

import numpy as np

from repro.core.agents.ppo import PPOAgent
from repro.core.agents.random_search import RandomAgent
from repro.serving.fused import bucket_size

#: act(batch) != concat(act(parts)) for these — serve per request
BATCH_UNSAFE = (RandomAgent,)


class AgentBatch:
    """One agent shared by many sessions: concatenated greedy ``act``.

    ``act_many([sites_a, sites_b, ...])`` runs ONE agent forward over the
    concatenation and returns per-request ``(n_i, 3)`` action arrays in
    request order.  Counters feed ``Server.stats()``.
    """

    def __init__(self, agent):
        self.agent = agent
        self.coalesced = not isinstance(agent, BATCH_UNSAFE)
        self.batches = 0          # forwards executed
        self.requests = 0         # requests served through them
        self.sites = 0            # sites across all forwards
        self.last_batch_sites = 0

    def act_many(self, site_lists: Sequence[List]) -> List[np.ndarray]:
        flat = [s for sites in site_lists for s in sites]
        if not self.coalesced:
            out = [np.asarray(self.agent.act(sites, sample=False))
                   for sites in site_lists]
            self.batches += len(site_lists)
        elif isinstance(self.agent, PPOAgent):
            acts = self.agent.act_bucketed(flat,
                                           bucket=bucket_size(len(flat)))
            self.batches += 1
        else:
            acts = np.asarray(self.agent.act(flat, sample=False))
            self.batches += 1
        self.requests += len(site_lists)
        self.sites += len(flat)
        self.last_batch_sites = len(flat)
        if not self.coalesced:
            return out
        out, off = [], 0
        for sites in site_lists:
            out.append(acts[off:off + len(sites)])
            off += len(sites)
        return out
