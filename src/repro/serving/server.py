"""Deadline-aware admission queue + batch scheduler over ``TuningService``.

The serving path turns the service's per-session ``tune``/``tune_async``
verbs into *requests* against one shared :class:`Server`:

* **admission** — each request carries an SLO budget (``slo_ms``,
  defaulting to the server's).  A warm :class:`~repro.artifacts
  .ProgramStore` answer resolves immediately at admission (the
  warm-store tier never queues); past ``max_queue`` depth the request is
  *shed* with a typed :class:`QueueFull` instead of silently blowing
  every queued deadline behind it.
* **flush** — a background flusher cuts a batch when ``max_batch``
  requests are waiting, the oldest has waited ``max_wait_ms``, or the
  oldest request's remaining budget approaches the EMA of batch
  execution time (deadline urgency).  Requests whose budget expired
  before execution fail with :class:`DeadlineExceeded`.
* **execution** — the batch groups by route: sessions whose agent is the
  brute-force search over an analytic or surrogate cost grid run through
  the :class:`~repro.serving.fused.FusedTuner` (the whole group is ONE
  device dispatch); everything else coalesces per agent through
  :class:`~repro.serving.batcher.AgentBatch` (one jitted forward per
  agent).  Results resolve strictly in admission order — FIFO fairness
  within an SLO class.

``health()`` follows PR 6 semantics: ``down`` once closed, ``degraded``
while a shed/deadline breach is younger than ``health_window_s``,
``ok`` otherwise.  ``stats()`` speaks the unified ``serving_*`` key
dialect, and the ``request_observer`` seam (the serving analogue of the
pool's ``job_observer``) feeds ``repro.obs.instrument_serving``.
"""
from __future__ import annotations

import threading
import time
from collections import Counter, deque
from concurrent.futures import Future
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.artifacts import program_key
from repro.core.agents import BruteForceAgent
from repro.core.env import CostModelEnv
from repro.core.vectorizer import TileProgram
from repro.serving.batcher import AgentBatch
from repro.serving.fused import FusedTuner
from repro.surrogate import SurrogateOracle


class ServingError(RuntimeError):
    """Base class of the serving path's typed rejections."""


class QueueFull(ServingError):
    """Shed at admission: the queue is at ``max_queue`` depth."""


class DeadlineExceeded(ServingError):
    """The request's SLO budget expired before a batch could run it."""


@dataclass
class ServingConfig:
    """Knobs of the admission queue + flusher (all times host-side)."""
    slo_ms: float = 100.0        # default per-request budget
    max_batch: int = 32          # requests per flush
    max_wait_ms: float = 2.0     # oldest-request wait that forces a flush
    max_queue: int = 256         # admission depth before shedding
    health_window_s: float = 5.0  # how long a breach keeps health degraded
    fused: bool = True           # allow the FusedTuner route


class _Request:
    __slots__ = ("session", "sites", "future", "slo_ms", "t_submit",
                 "deadline", "store_key", "wait_s")

    def __init__(self, session, sites, slo_ms, store_key):
        self.session = session
        self.sites = sites
        self.future: "Future[TileProgram]" = Future()
        self.slo_ms = slo_ms
        self.t_submit = time.perf_counter()
        self.deadline = (None if slo_ms is None
                         else self.t_submit + slo_ms / 1000.0)
        self.store_key = store_key
        self.wait_s = 0.0


class Server:
    """The serving loop: one admission queue + flusher thread per
    :class:`~repro.service.TuningService` (constructed by the service's
    ``serving=`` argument; sessions route ``tune``/``tune_async`` here
    automatically — zero caller churn)."""

    def __init__(self, service, config: Optional[ServingConfig] = None,
                 request_observer: Optional[Callable] = None):
        self.service = service
        self.cfg = config or ServingConfig()
        #: ``observer(event, **fields)`` with events ``complete`` /
        #: ``batch`` / ``shed`` / ``deadline`` / ``store_hit`` — the
        #: instrumentation seam (``repro.obs.instrument_serving``)
        self.request_observer = request_observer
        self._cv = threading.Condition()
        self._q: "deque[_Request]" = deque()
        self._closed = False
        # routing caches: (session, effective oracle) -> route,
        # shared FusedTuners per (cfg, surrogate), AgentBatch per agent
        self._routes: Dict[Tuple[int, int], tuple] = {}
        self._tuners: Dict[Tuple[int, int], FusedTuner] = {}
        self._batchers: Dict[int, AgentBatch] = {}
        # counters (under _cv); latencies bounded for p50/p99
        self.requests = 0
        self.shed = 0
        self.deadline_misses = 0
        self.batches = 0
        self.store_hits = 0
        self.queue_wait_s = 0.0
        self.batch_requests: "Counter[int]" = Counter()
        self._lat: "deque[float]" = deque(maxlen=4096)
        self._last_breach = 0.0              # monotonic; shed or miss
        self._exec_ema = 0.0                 # EMA of batch execution time
        self._flusher = threading.Thread(target=self._loop, daemon=True,
                                         name="serving-flush")
        self._flusher.start()

    # -- admission -----------------------------------------------------------
    def submit(self, session, sites: Sequence,
               slo_ms: Optional[float] = None) -> "Future[TileProgram]":
        """Admit one tune request for ``session``; resolves to its
        :class:`TileProgram`.  Raises :class:`QueueFull` when shedding;
        the future fails with :class:`DeadlineExceeded` when the budget
        (``slo_ms``, default the server's) expires while queued."""
        if self._closed:
            raise ServingError("the serving path is closed")
        sites = list(sites)
        slo = self.cfg.slo_ms if slo_ms is None else slo_ms
        t0 = time.perf_counter()
        store = session.program_store
        key = None
        if sites and store is not None:
            key = program_key(sites, session.agent, session.oracle)
            prog = store.get(key)
            if prog is not None:             # warm-store tier: no queue
                fut: "Future[TileProgram]" = Future()
                session._account_tune(time.perf_counter() - t0,
                                      len(sites), True)
                with self._cv:
                    self.requests += 1
                    self.store_hits += 1
                    self._lat.append(time.perf_counter() - t0)
                self._observe("store_hit",
                              latency_s=time.perf_counter() - t0)
                fut.set_result(prog)
                return fut
        if not sites:                        # nothing to schedule
            fut = Future()
            session._account_tune(time.perf_counter() - t0, 0, False)
            with self._cv:
                self.requests += 1
            fut.set_result(TileProgram())
            return fut
        req = _Request(session, sites, slo, key)
        with self._cv:
            if self._closed:
                raise ServingError("the serving path is closed")
            if len(self._q) >= self.cfg.max_queue:
                self.shed += 1
                self._last_breach = time.monotonic()
                depth = len(self._q)
                self._cv.notify()
                self._observe("shed", queue_depth=depth)
                raise QueueFull(
                    f"queue depth {depth} at max_queue="
                    f"{self.cfg.max_queue}: request shed (retry later or "
                    f"raise max_queue/workers)")
            self.requests += 1
            self._q.append(req)
            self._cv.notify()
        return req.future

    # -- the flusher ---------------------------------------------------------
    def _loop(self) -> None:
        while True:
            with self._cv:
                while not self._q and not self._closed:
                    self._cv.wait(0.25)
                if not self._q:
                    if self._closed:
                        return
                    continue
                now = time.perf_counter()
                oldest = self._q[0]
                flush_at = oldest.t_submit + self.cfg.max_wait_ms / 1000.0
                if oldest.deadline is not None:
                    # leave enough budget to actually execute the batch
                    # (floored so a cold EMA never schedules the flush
                    # exactly AT the deadline — a guaranteed miss)
                    margin = max(1.5 * self._exec_ema, 1e-3)
                    flush_at = min(flush_at, oldest.deadline - margin)
                if not (self._closed or now >= flush_at
                        or len(self._q) >= self.cfg.max_batch):
                    self._cv.wait(max(flush_at - now, 1e-4))
                    continue
                k = min(len(self._q), self.cfg.max_batch)
                batch = [self._q.popleft() for _ in range(k)]
            self._run_batch(batch)

    def _run_batch(self, batch: List[_Request]) -> None:
        t_start = time.perf_counter()
        live = []
        for r in batch:
            r.wait_s = t_start - r.t_submit
            if r.deadline is not None and t_start > r.deadline:
                with self._cv:
                    self.deadline_misses += 1
                    self._last_breach = time.monotonic()
                self._observe("deadline", queue_wait_s=r.wait_s)
                r.future.set_exception(DeadlineExceeded(
                    f"SLO budget of {r.slo_ms:.1f} ms spent queueing "
                    f"({r.wait_s * 1e3:.1f} ms) before a batch ran"))
                continue
            live.append(r)
        if not live:
            return
        groups: Dict[tuple, List[_Request]] = {}
        for r in live:
            groups.setdefault(self._route(r.session), []).append(r)
        results: Dict[int, object] = {}
        for (kind, engine), reqs in groups.items():
            try:
                if kind == "fused":
                    progs = engine.tune_many([r.sites for r in reqs])
                else:
                    acts = engine.act_many([r.sites for r in reqs])
                    progs = [self._assemble(r, a)
                             for r, a in zip(reqs, acts)]
                for r, p in zip(reqs, progs):
                    results[id(r)] = p
            except Exception as exc:         # fail the group, not the batch
                for r in reqs:
                    results[id(r)] = exc
        dt = time.perf_counter() - t_start
        with self._cv:
            self._exec_ema = (dt if self._exec_ema == 0.0
                              else 0.7 * self._exec_ema + 0.3 * dt)
            self.batches += 1
            self.batch_requests[len(live)] += 1
        self._observe("batch", batch_requests=len(live),
                      batch_sites=sum(len(r.sites) for r in live),
                      exec_s=dt)
        # resolve strictly in admission order: FIFO within the batch
        for r in live:
            out = results[id(r)]
            if isinstance(out, Exception):
                r.future.set_exception(out)
                continue
            if r.store_key is not None:
                r.session.program_store.put(r.store_key, out)
            lat = time.perf_counter() - r.t_submit
            r.session._account_tune(lat, len(r.sites), False)
            with self._cv:
                self._lat.append(lat)
                self.queue_wait_s += r.wait_s
            self._observe("complete", queue_wait_s=r.wait_s, latency_s=lat)
            r.future.set_result(out)

    # -- routing -------------------------------------------------------------
    def _route(self, session) -> tuple:
        agent = session.agent
        key = (id(session), id(getattr(agent, "oracle", None)))
        r = self._routes.get(key)
        if r is None:
            r = self._make_route(session, agent)
            self._routes[key] = r
        return r

    def _make_route(self, session, agent) -> tuple:
        """Fused route for brute-force search over an analytic or
        surrogate cost grid (exactly the grids ``FusedTuner`` reproduces
        bitwise-on-argmin); everything else coalesces per agent."""
        if self.cfg.fused and isinstance(agent, BruteForceAgent):
            o = agent._ensure_oracle()
            o = getattr(o, "oracle", o)      # unwrap AsyncOracle
            sur = None
            eligible = False
            if isinstance(o, SurrogateOracle):
                sur, eligible = o.model, True
            elif type(o) is CostModelEnv:    # MeasuredEnv etc. excluded
                eligible = True
            if eligible:
                tk = (id(o.cfg), id(sur))
                tuner = self._tuners.get(tk)
                if tuner is None:
                    tuner = FusedTuner(o.cfg, surrogate=sur)
                    self._tuners[tk] = tuner
                return ("fused", tuner)
        batcher = self._batchers.get(id(agent))
        if batcher is None:
            batcher = AgentBatch(agent)
            self._batchers[id(agent)] = batcher
        return ("agent", batcher)

    @staticmethod
    def _assemble(r: _Request, actions: np.ndarray) -> TileProgram:
        space = r.session.oracle.space       # same assembly as vectorizer
        prog = TileProgram()
        for s, a in zip(r.sites, actions):
            prog.tiles[s.key()] = space.tiles(s.kind, a)
        return prog

    def _observe(self, event: str, **fields) -> None:
        obs = self.request_observer
        if obs is not None:
            try:
                obs(event, **fields)
            except Exception:
                pass                         # observers never break serving

    # -- observability / lifecycle -------------------------------------------
    def health(self) -> str:
        """``ok | degraded | down`` (PR 6 semantics): degraded while a
        shed or deadline miss is younger than ``health_window_s``."""
        if self._closed:
            return "down"
        if time.monotonic() - self._last_breach < self.cfg.health_window_s:
            return "degraded"
        return "ok"

    def stats(self) -> dict:
        """Unified ``serving_*`` counters + latency quantiles + the fused
        tuners' dispatch/trace counters (summed)."""
        with self._cv:
            lat = np.asarray(self._lat, np.float64)
            out = {
                "serving_requests_total": self.requests,
                "serving_queue_depth": len(self._q),
                "serving_shed_total": self.shed,
                "serving_deadline_misses_total": self.deadline_misses,
                "serving_batches_total": self.batches,
                "serving_store_hits_total": self.store_hits,
                "serving_queue_wait_seconds_total": self.queue_wait_s,
                "serving_batch_requests_hist": dict(self.batch_requests),
                "serving_batch_requests_max":
                    max(self.batch_requests, default=0),
                "serving_tune_p50_ms":
                    float(np.percentile(lat, 50) * 1e3) if len(lat) else 0.0,
                "serving_tune_p99_ms":
                    float(np.percentile(lat, 99) * 1e3) if len(lat) else 0.0,
            }
        for t in self._tuners.values():
            for k, v in t.stats().items():
                out[k] = out.get(k, 0) + v
        out["serving_agent_batches_total"] = sum(
            b.batches for b in self._batchers.values())
        out["serving_batched_requests_total"] = sum(
            b.requests for b in self._batchers.values())
        out["health"] = self.health()
        return out

    def close(self) -> None:
        """Drain the queue (every admitted future resolves or fails) and
        stop the flusher.  Idempotent."""
        with self._cv:
            if self._closed and not self._flusher.is_alive():
                return
            self._closed = True
            self._cv.notify_all()
        self._flusher.join(timeout=60.0)

    def __enter__(self) -> "Server":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
