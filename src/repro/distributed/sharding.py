"""Sharding rules: DP / TP (Megatron-style) / EP / FSDP / SP on a
("pod",)"data","model" mesh.

Parameters get a PartitionSpec from path-keyword rules; every 2-D+ weight is
TP-sharded on its role axis over "model" and FSDP-sharded over "data" on the
other large axis (ZeRO-3 style — weights are all-gathered per layer inside
the scan, gradients reduce-scattered by GSPMD).  Optimizer state inherits
the parameter sharding.  GSPMD (pjit) propagates activation shardings and
inserts the collectives.
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig


def dp_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


DP = "__dp__"      # placeholder replaced with the mesh's dp axes


# ---------------------------------------------------------------------------
# parameter rules (first match on the joined parameter path wins)
# ---------------------------------------------------------------------------
# fmt: off
_PARAM_RULES = [
    # MoE expert tensors: EP over model, FSDP over d_model
    ("ewi",         {3: P("model", "data", None), 4: P(None, "model", "data", None)}),
    ("ewg",         {3: P("model", "data", None), 4: P(None, "model", "data", None)}),
    ("ewo",         {3: P("model", None, "data"), 4: P(None, "model", None, "data")}),
    ("router",      {2: P("data", "model"), 3: P(None, "data", "model")}),
    ("shared_wi",   {2: P("data", "model"), 3: P(None, "data", "model")}),
    ("shared_wg",   {2: P("data", "model"), 3: P(None, "data", "model")}),
    ("shared_wo",   {2: P("model", "data"), 3: P(None, "model", "data")}),
    # embeddings / lm head: vocab over model, d over data
    ("embed",       {2: P("model", "data")}),
    ("head",        {2: P("model", "data")}),
    ("frontend_proj", {2: P("data", "model")}),
    # dense MLP (gated): D x F over (data, model)
    ("wi",          {2: P("data", "model"), 3: P(None, "data", "model")}),
    ("wg",          {2: P("data", "model"), 3: P(None, "data", "model")}),
    # attention / MLA
    ("wq",          {2: P("data", "model"), 3: P(None, "data", "model"), 4: P(None, None, None, "model")}),
    ("wk",          {2: P("data", "model"), 3: P(None, "data", "model"), 4: P(None, None, None, "model")}),
    ("wv",          {2: P("data", "model"), 3: P(None, "data", "model"), 4: P(None, None, None, "model")}),
    ("wo",          {2: P("model", "data"), 3: P(None, "model", "data")}),
    ("wq_a",        {3: P(None, "data", "model")}),
    ("wq_b",        {3: P(None, "data", "model")}),
    ("wkv_a",       {3: P(None, "data", "model")}),
    ("w_uk",        {4: P(None, None, "model", None)}),
    ("w_uv",        {4: P(None, None, "model", None)}),
    # dense / ssm / xlstm projections
    ("in_proj",     {3: P(None, "data", "model")}),
    ("out_proj",    {3: P(None, "model", "data")}),
    ("up",          {3: P(None, "data", "model")}),
    ("down",        {3: P(None, "model", "data")}),
    ("wx",          {3: P(None, "data", "model")}),
    ("conv",        {3: P(None, None, "model")}),
    # sLSTM recurrent weights stay TP-sharded: replicating them was tried
    # and REFUTED — the per-step dL/dr accumulation then all-reduces a
    # full 16 MiB replica every timestep (16x more traffic; EXPERIMENTS.md
    # Cell C it2)
    ("r",           {5: P(None, None, None, None, "model")}),
]
# fmt: on


def _spec_for(path: str, ndim: int) -> P:
    for key, by_rank in _PARAM_RULES:
        if f"/{key}" in path or path.endswith(key) or f"{key}/" in path:
            if ndim in by_rank:
                return by_rank[ndim]
    if ndim >= 2:
        # fallback: FSDP-shard the biggest trailing dim over data
        spec = [None] * ndim
        spec[-1] = "data"
        return P(*spec)
    return P()


def _path_str(path) -> str:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        else:
            out.append(str(p))
    return "/".join(out)


def _fit_spec(spec: P, shape, mesh: Optional[Mesh]) -> P:
    """pjit requires argument dims to divide evenly by their mesh axes;
    drop (replicate) any assignment that doesn't."""
    if mesh is None:
        return spec
    out = []
    for dim, axes in zip(shape, tuple(spec) + (None,) * (len(shape)
                                                         - len(spec))):
        if axes is None:
            out.append(None)
            continue
        ax_tuple = axes if isinstance(axes, tuple) else (axes,)
        n = int(np.prod([mesh.shape[a] for a in ax_tuple]))
        out.append(axes if dim % n == 0 else None)
    return P(*out)


def _drop_axis(spec: P, axis: str) -> P:
    out = []
    for e in spec:
        if e == axis:
            out.append(None)
        elif isinstance(e, tuple):
            keep = tuple(a for a in e if a != axis)
            out.append(keep if keep else None)
        else:
            out.append(e)
    return P(*out)


def param_specs(params_tree, mesh: Optional[Mesh] = None,
                fsdp: bool = True) -> "pytree[P]":
    """PartitionSpec tree for a parameter (or optimizer-state) pytree.

    ``fsdp=False`` drops the "data" axis from every weight spec (pure TP).
    For models whose optimizer state fits without ZeRO-3 this removes the
    per-layer weight all-gathers entirely — a §Perf hillclimb lever.
    """
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_tree)
    specs = []
    for path, leaf in flat:
        sp = _spec_for(_path_str(path), len(leaf.shape))
        if not fsdp:
            sp = _drop_axis(sp, "data")
        specs.append(_fit_spec(sp, leaf.shape, mesh))
    return jax.tree_util.tree_unflatten(treedef, specs)


def named(mesh: Mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree, is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# batch / cache rules
# ---------------------------------------------------------------------------

def batch_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh):
    """PartitionSpecs for the input batch pytree."""
    dp = dp_axes(mesh)
    n_dp = int(np.prod([mesh.shape[a] for a in dp]))
    bdim = dp if shape.global_batch % max(n_dp, 1) == 0 \
        and shape.global_batch >= n_dp else None
    tok = P(bdim, None)
    out = {"tokens": tok, "targets": tok}
    if cfg.frontend == "vision":
        out["frontend_embeds"] = P(bdim, None, "model")
    if cfg.enc_dec:
        out["src_embeds"] = P(bdim, None, "model")
    return out


def cache_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh, cache_tree):
    """Cache shardings.  batch over DP; heads/features over TP.  For the
    batch=1 long-context shape, sequence axes are sharded over "data"
    (sequence parallelism) instead."""
    dp = dp_axes(mesh)
    n_dp = int(np.prod([mesh.shape[a] for a in dp]))
    seq_par = shape.global_batch < n_dp
    b = None if seq_par else dp

    def spec_for(path, leaf):
        p = _path_str(path)
        seg = p.split("/")[-1]       # exact last key ("conv" must not match "v")
        nd = len(leaf.shape)
        # leading axis is the stacked period axis (scan) — unsharded
        if seg in ("k", "v"):                            # (L,B,Hkv,S,hd)
            if cfg.n_kv_heads >= mesh.shape["model"]:
                return P(None, b, "model", "data" if seq_par else None, None)
            return P(None, b, None, "data" if seq_par else "model", None)
        if seg == "c_kv":                                # (L,B,S,r)
            return P(None, b, "data" if seq_par else None, "model")
        if seg == "k_rope":                              # (L,B,1,S,dr)
            return P(None, b, None, "data" if seq_par else None, None)
        if seg == "ssd":                                 # (L,B,h,P,N)
            return P(None, b, "model", None, None)
        if seg == "conv":                                # (L,B,W,C)
            return P(None, b, None, "model")
        if seg == "C":                                   # (L,B,h,hd,hd)
            return P(None, b, None, "model", None)
        if seg == "n" and nd == 4:                       # mlstm n (L,B,h,hd)
            return P(None, b, None, "model")
        if nd == 3 and leaf.shape[-1] == cfg.d_model:    # slstm states (L,B,d)
            return P(None, b, "model")
        if nd >= 3:
            return P(None, b, *([None] * (nd - 2)))
        return P()

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_tree)
    specs = [spec_for(path, leaf) for path, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


def scalar_spec():
    return P()
