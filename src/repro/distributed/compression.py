"""Gradient compression: int8 quantization with error feedback.

``make_compressor`` returns a hook for ``make_train_step``: each gradient
tensor is quantized to int8 against a per-tensor scale with an error-
feedback accumulator (the classical EF-SGD trick, keeps convergence), then
dequantized for the optimizer.  Under pjit the *reduce* of FSDP/DP gradients
happens on the dequantized values; on deployments where collective bytes
dominate (see EXPERIMENTS.md roofline), ``compressed_psum`` shows the
shard_map pattern that moves int8 over the wire instead (4x fewer
collective bytes) and reduces locally in f32.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P


def _quantize(g, err):
    g = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return deq, g - deq, q, scale


def make_compressor(params_like):
    """Stateful-via-closure EF compressor (error state threaded in metrics-
    free form: returned grads are dequantized, residual kept inside)."""
    state = {"err": jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params_like)}

    def compress(grads):
        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_e = treedef.flatten_up_to(state["err"])
        res = [_quantize(g, e) for g, e in zip(flat_g, flat_e)]
        deq = jax.tree_util.tree_unflatten(treedef, [r[0] for r in res])
        state["err"] = jax.tree_util.tree_unflatten(
            treedef, [r[1] for r in res])
        err_norm = sum(jnp.sum(e * e) for e in jax.tree.leaves(state["err"]))
        return deq, {"compress_err_sq": err_norm}

    return compress


def compressed_psum(x: jax.Array, mesh: Mesh, axis: str = "data"):
    """int8-over-the-wire all-reduce: quantize -> all_gather(int8) -> local
    f32 sum.  4x fewer collective bytes than an f32 psum (2x vs bf16)."""

    def inner(xs):
        scale = jnp.maximum(jnp.max(jnp.abs(xs)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(xs / scale), -127, 127).astype(jnp.int8)
        qg = jax.lax.all_gather(q, axis)            # int8 on the wire
        sg = jax.lax.all_gather(scale, axis)
        return jnp.tensordot(sg, qg.astype(jnp.float32), axes=((0,), (0,)))

    from jax.experimental.shard_map import shard_map
    n = len(x.shape)
    spec = P(*([None] * n))
    return shard_map(inner, mesh=mesh, in_specs=spec, out_specs=spec)(x)
