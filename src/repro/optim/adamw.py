"""AdamW with global-norm clipping and schedules (pure JAX, no optax).

Moments are f32 regardless of param dtype and inherit the parameter
sharding (ZeRO-style when params are FSDP-sharded).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def lr_schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init(params):
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree.map(f32, params),
            "v": jax.tree.map(f32, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree):
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(g.astype(jnp.float32)))
        for g in jax.tree.leaves(tree)))


def update(cfg: AdamWConfig, grads, state, params):
    """-> (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    step = state["step"] + 1
    lr = lr_schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        u = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        if p.ndim >= 2:     # decoupled weight decay on matrices only
            u = u + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), m, v

    # explicit flatten/unflatten: params trees may legitimately contain
    # tuples (stacked period slots), so tuple-unzip via tree.map is unsafe
    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    res = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    unf = jax.tree_util.tree_unflatten
    return (unf(treedef, [r[0] for r in res]),
            {"m": unf(treedef, [r[1] for r in res]),
             "v": unf(treedef, [r[2] for r in res]),
             "step": step},
            {"grad_norm": gnorm, "lr": lr})
