"""``SurrogateOracle`` — the learned cost model behind the Oracle protocol.

Structurally a :class:`~repro.core.env.CostModelEnv` whose cost source is
the trained :class:`~repro.surrogate.model.SurrogateModel` instead of the
analytic formulas: the same batched surface (``costs_batch`` /
``baseline_costs`` / ``rewards_batch`` / ``speedups_batch`` /
``cost_grid`` / ``tiles_costs``), the same ``inf`` = illegal masking, the
same eq. 2 reward routing — so every agent, benchmark, and the shared
conformance suite in ``tests/test_api.py`` run against it unchanged.

Mirrors :class:`~repro.core.env.MeasuredEnv`'s shape without the
measurement machinery: tiles the analytic model rejects (VMEM overflow)
are never priced by the network — a kernel that cannot build has no
runtime to predict — and per-key results are cached so repeated sweeps
re-run no inference.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.configs.neurovec import NeuroVecConfig
from repro.core import costmodel_vec
from repro.core.env import CostModelEnv
from repro.models.compute import KernelSite
from repro.surrogate.model import SurrogateModel


class SurrogateOracle(CostModelEnv):
    """Oracle pricing every query with the learned surrogate."""

    def __init__(self, nv_cfg: NeuroVecConfig, model: SurrogateModel,
                 seed: int = 0):
        super().__init__(nv_cfg, seed=seed, vectorized=True)
        self.model = model
        self._result_cache: Dict[Tuple[str, Tuple[int, int, int]],
                                 float] = {}

    def clear_result_cache(self) -> None:
        self._result_cache.clear()

    # -- the surrogate cost of explicit tiles --------------------------------
    def _surrogate_costs(self, sites, tiles) -> np.ndarray:
        """(n,) predicted seconds; ``inf`` = model-illegal tile."""
        tiles = np.asarray(tiles, np.int64)
        keys = [(s.key(), (int(t[0]), int(t[1]), int(t[2])))
                for s, t in zip(sites, tiles)]
        first = {}
        for i, k in enumerate(keys):
            if k not in self._result_cache and k not in first:
                first[k] = i
        miss = list(first.values())
        if miss:
            vals = self.model.predict_seconds(
                [sites[i] for i in miss], tiles[miss])
            for i, v in zip(miss, vals):
                self._result_cache[keys[i]] = float(v)
        return np.array([self._result_cache[k] for k in keys], np.float64)

    # -- Oracle surface (surrogate-priced) -----------------------------------
    def costs_batch(self, sites, actions) -> np.ndarray:
        if not len(sites):
            return np.zeros((0,), np.float64)
        tiles = costmodel_vec.tiles_for_actions(self.space, sites, actions)
        return self._surrogate_costs(sites, tiles)

    def baseline_costs(self, sites) -> np.ndarray:
        if not len(sites):
            return np.zeros((0,), np.float64)
        return self._surrogate_costs(
            sites, costmodel_vec.baseline_tiles_batch(sites))

    def baseline_cost(self, site: KernelSite) -> float:
        return float(self.baseline_costs([site])[0])

    def cost(self, site: KernelSite,
             action: Sequence[int]) -> Optional[float]:
        c = float(self.costs_batch([site], np.asarray([action]))[0])
        return None if math.isinf(c) else c

    def tiles_costs(self, sites, tiles) -> np.ndarray:
        if not len(sites):
            return np.zeros((0,), np.float64)
        t = np.asarray(tiles, np.int64)
        if t.ndim != 2 or t.shape[0] != len(sites):
            raise ValueError(f"tiles must be (n_sites, k), got {t.shape}")
        if t.shape[1] < 3:
            t = np.concatenate(
                [t, np.ones((len(t), 3 - t.shape[1]), np.int64)], 1)
        return self._surrogate_costs(sites, t)

    def cost_grid(self, sites) -> np.ndarray:
        groups = costmodel_vec.group_by_kind(sites)
        a_max = max((self.space.n_actions(k) for k in groups), default=0)
        out = np.full((len(sites), a_max), np.inf, np.float64)
        for kind, idx in groups.items():
            tg = costmodel_vec.action_tiles_grid(self.space, kind)
            rep_sites = [sites[i] for i in idx for _ in range(len(tg))]
            rep_tiles = np.tile(tg, (len(idx), 1))
            out[idx, :len(tg)] = self._surrogate_costs(
                rep_sites, rep_tiles).reshape(len(idx), len(tg))
        return out
