"""Learned cost model trained from the persistent ``MeasureDB``.

The paper's core conjecture is that a learned model "can better predict
the actual performance cost" than a fixed-cost heuristic — and
``BENCH_measure.json`` proves the gap for this repo: the analytic model's
tile ranking barely correlates with measured time (mean Spearman ~0.19).
Every timing ever taken is already persisted in the ``MeasureDB``, so the
training corpus grows for free.  This package closes the loop:

* :mod:`~repro.surrogate.features` — a fixed numeric featurizer over
  ``(site, tiles)`` (shape/dtype/kind one-hots, tile triple, tile/dim
  ratios, VMEM footprint, the analytic cost as a prior).  No code2vec
  dependency, so it works on any measured site.
* :mod:`~repro.surrogate.dataset` — corpus builder iterating finite
  ``MeasureDB`` records (quarantine/corrupt entries skipped) into
  ``(site, tiles) -> log-cost`` training pairs.
* :mod:`~repro.surrogate.model` — a small jitted JAX MLP ensemble
  (``optim/adamw``), checkpointed with the ``artifacts/agentio``
  atomic-save + fingerprint discipline.
* :mod:`~repro.surrogate.oracle` — :class:`SurrogateOracle`, the model
  behind the full ``Oracle`` protocol; drops into every agent,
  benchmark, and the shared conformance suite unchanged.

The payoff layer is **grid pruning**: ``MeasuredEnv(prune_topk=N)`` lets
the surrogate rank each site's full legal grid and submits only the
top-k candidates to the measurement transport — everything else is
priced by the surrogate.  Fewer timings per site beats any amount of
worker-pool parallelism.
"""
from repro.surrogate.dataset import Corpus, build_corpus, parse_key
from repro.surrogate.features import N_FEATURES, featurize
from repro.surrogate.model import (SurrogateModel, load_surrogate,
                                   save_surrogate, train_from_db,
                                   train_surrogate)
from repro.surrogate.oracle import SurrogateOracle

__all__ = [
    "Corpus", "N_FEATURES", "SurrogateModel", "SurrogateOracle",
    "build_corpus", "featurize", "load_surrogate", "parse_key",
    "save_surrogate", "train_from_db", "train_surrogate",
]
