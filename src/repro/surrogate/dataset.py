"""Training corpus from the persistent ``MeasureDB``.

Every measurement ever taken is one append-only JSONL record keyed
``site_key|t0xt1xt2|backend``; ``MeasureDB.iter_records()`` already
resolves duplicates last-wins and drops quarantined/corrupt entries.
This module finishes the job: parse the key back into a
:class:`~repro.models.compute.KernelSite` + tile triple, keep only
finite timings (a ``null``/``inf`` record means the kernel failed — it
carries no cost signal), and hand back aligned arrays ready for the
featurizer.  Targets are ``log(seconds)``: timings span orders of
magnitude and the ranking loss we care about lives on the log scale.
"""
from __future__ import annotations

import re
from typing import NamedTuple, Optional, Sequence, Tuple, Union

import numpy as np

from repro.measure.db import MeasureDB
from repro.models.compute import KernelSite

# KernelSite.key() followed by the DB's tile/backend components.  The
# site label may itself contain separators; the dims block anchors it.
_KEY_RE = re.compile(
    r"^(?P<kind>[^:|]+):(?P<site>.+):m(?P<m>\d+)n(?P<n>\d+)k(?P<k>\d+)"
    r"b(?P<batch>\d+):(?P<dtype>[^:|]+):(?P<transpose>[^:|]+)"
    r"(?P<causal>:c)?:f(?P<fused>\d+)"
    r"\|(?P<t0>\d+)x(?P<t1>\d+)x(?P<t2>\d+)\|(?P<backend>.*)$")


class Corpus(NamedTuple):
    """Aligned training arrays: pair i is ``(sites[i], tiles[i]) ->
    y[i] = log(seconds)``, measured under ``backends[i]``."""
    sites: Tuple[KernelSite, ...]
    tiles: np.ndarray           # (n, 3) int64
    y: np.ndarray               # (n,) float64 log-seconds
    backends: Tuple[str, ...]


def parse_key(key: str) -> Optional[Tuple[KernelSite, Tuple[int, int, int],
                                          str]]:
    """Full DB key -> ``(site, tiles, backend)``; ``None`` if the key
    does not round-trip (foreign record kinds stay non-fatal)."""
    m = _KEY_RE.match(key)
    if m is None:
        return None
    site = KernelSite(
        site=m["site"], kind=m["kind"], m=int(m["m"]), n=int(m["n"]),
        k=int(m["k"]), batch=int(m["batch"]), dtype=m["dtype"],
        transpose=m["transpose"], causal=m["causal"] is not None,
        fused_ops=int(m["fused"]))
    return site, (int(m["t0"]), int(m["t1"]), int(m["t2"])), m["backend"]


def build_corpus(db: Union[MeasureDB, str],
                 backend: Optional[str] = None) -> Corpus:
    """Every finite, parseable measurement in ``db`` as a :class:`Corpus`.

    ``backend`` restricts to records taken under one measurement
    fingerprint — mixing fingerprints trains on incommensurable clocks.
    Accepts an open :class:`MeasureDB` or a path.
    """
    if isinstance(db, str):
        db = MeasureDB(db)
    sites, tiles, ys, backends = [], [], [], []
    for rec in db.iter_records():
        if not np.isfinite(rec.value) or rec.value <= 0:
            continue
        parsed = parse_key(rec.key)
        if parsed is None:
            continue
        site, t, be = parsed
        if backend is not None and be != backend:
            continue
        sites.append(site)
        tiles.append(t)
        ys.append(np.log(rec.value))
        backends.append(be)
    return Corpus(sites=tuple(sites),
                  tiles=np.asarray(tiles, np.int64).reshape(-1, 3),
                  y=np.asarray(ys, np.float64),
                  backends=tuple(backends))
