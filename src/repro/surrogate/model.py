"""The surrogate itself: a small jitted JAX MLP ensemble.

Inputs are the fixed :mod:`~repro.surrogate.features` vectors, targets
are log-seconds; both are z-normalized with statistics learned from the
corpus and stored in the checkpoint.  An ensemble of independently
initialized members (mean prediction) smooths the tiny-corpus variance
that a single MLP fit exhibits — the corpus starts at a few dozen pairs
on a fresh DB.  Training reuses :mod:`repro.optim.adamw` with its cosine
schedule; one ``lax.scan`` per member keeps the whole fit a single
compiled call.

Checkpoints follow the ``artifacts/agentio`` discipline verbatim: the
model exposes ``state_dict()`` (name + version + arrays) so
:func:`save_surrogate` is ``agentio.save_agent`` — atomic staged-rename
writes, manifest last, SHA-256 fingerprint recomputed and enforced on
load.
"""
from __future__ import annotations

from collections import Counter
from typing import Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.artifacts import agentio
from repro.core import costmodel_vec
from repro.core.protocols import AGENT_STATE_VERSION
from repro.measure.db import MeasureDB
from repro.optim import adamw
from repro.surrogate.dataset import Corpus, build_corpus
from repro.surrogate.features import N_FEATURES, featurize

MODEL_NAME = "surrogate"


def _init_member(key, n_in: int, hidden: Sequence[int]):
    sizes = [n_in, *hidden, 1]
    params = []
    for i in range(len(sizes) - 1):
        key, sub = jax.random.split(key)
        scale = float(np.sqrt(2.0 / sizes[i]))
        params.append({
            "w": jax.random.normal(sub, (sizes[i], sizes[i + 1]),
                                   jnp.float32) * scale,
            "b": jnp.zeros((sizes[i + 1],), jnp.float32)})
    return params


def _forward(params, X):
    h = X
    for layer in params[:-1]:
        h = jnp.tanh(h @ layer["w"] + layer["b"])
    return (h @ params[-1]["w"] + params[-1]["b"])[:, 0]


@jax.jit
def _forward_jit(params, X):
    return _forward(params, X)


def _train_member(params, X, y, steps: int, lr: float):
    cfg = adamw.AdamWConfig(lr=lr, weight_decay=1e-4, clip_norm=1.0,
                            warmup_steps=min(20, steps // 5),
                            total_steps=steps, min_lr_frac=0.05)
    opt = adamw.init(params)

    def loss_fn(p):
        return jnp.mean((_forward(p, X) - y) ** 2)

    def step_fn(carry, _):
        p, s = carry
        loss, grads = jax.value_and_grad(loss_fn)(p)
        p, s, _ = adamw.update(cfg, grads, s, p)
        return (p, s), loss

    (params, _), losses = jax.lax.scan(step_fn, (params, opt), None,
                                       length=steps)
    return params, losses


_train_member_jit = jax.jit(_train_member, static_argnames=("steps",))


class SurrogateModel:
    """Ensemble MLP mapping feature vectors to log-seconds."""

    name = MODEL_NAME

    def __init__(self, params, x_mean, x_std, y_mean: float, y_std: float,
                 hidden: Tuple[int, ...], backend: str = "",
                 n_features: int = N_FEATURES):
        self.params = params            # [member][layer] {"w", "b"}
        self.x_mean = np.asarray(x_mean, np.float64)
        self.x_std = np.asarray(x_std, np.float64)
        self.y_mean = float(y_mean)
        self.y_std = float(y_std)
        self.hidden = tuple(int(h) for h in hidden)
        self.backend = str(backend)
        self.n_features = int(n_features)

    @property
    def ensemble(self) -> int:
        return len(self.params)

    # -- inference -----------------------------------------------------------
    def predict_log_seconds(self, X) -> np.ndarray:
        """(n,) predicted log-seconds for raw (unnormalized) features."""
        X = np.asarray(X, np.float64)
        if X.ndim != 2 or X.shape[1] != self.n_features:
            raise ValueError(f"features must be (n, {self.n_features}), "
                             f"got {X.shape}")
        if not len(X):
            return np.zeros((0,), np.float64)
        Xn = jnp.asarray((X - self.x_mean) / self.x_std, jnp.float32)
        pred = np.mean([np.asarray(_forward_jit(p, Xn), np.float64)
                        for p in self.params], axis=0)
        return pred * self.y_std + self.y_mean

    def predict_seconds(self, sites, tiles) -> np.ndarray:
        """(n,) predicted seconds per pair; ``inf`` where the analytic
        model rejects the tile (VMEM overflow — never predict a runtime
        for a kernel that cannot build)."""
        t = np.asarray(tiles, np.int64).reshape(len(sites), -1)
        prior = costmodel_vec.costs_for_tiles(sites, t)
        out = np.full(len(sites), np.inf, np.float64)
        legal = np.flatnonzero(np.isfinite(prior))
        if len(legal):
            X = featurize([sites[i] for i in legal], t[legal])
            out[legal] = np.exp(self.predict_log_seconds(X))
        return out

    # -- checkpoint surface (agentio) ----------------------------------------
    def state_dict(self) -> dict:
        return {
            "name": self.name,
            "version": AGENT_STATE_VERSION,
            "backend": self.backend,
            "hidden": list(self.hidden),
            "n_features": self.n_features,
            "x_mean": self.x_mean, "x_std": self.x_std,
            "y_mean": self.y_mean, "y_std": self.y_std,
            "params": [[{"w": np.asarray(l["w"]), "b": np.asarray(l["b"])}
                        for l in member] for member in self.params],
        }

    @classmethod
    def from_state(cls, state: dict) -> "SurrogateModel":
        if state.get("name") != MODEL_NAME:
            raise agentio.ArtifactError(
                f"not a surrogate checkpoint: name={state.get('name')!r}")
        if state.get("version") != AGENT_STATE_VERSION:
            raise agentio.ArtifactError(
                f"surrogate schema version {state.get('version')!r} "
                f"unsupported (expected {AGENT_STATE_VERSION})")
        params = [[{"w": jnp.asarray(l["w"]), "b": jnp.asarray(l["b"])}
                   for l in member] for member in state["params"]]
        return cls(params, state["x_mean"], state["x_std"],
                   state["y_mean"], state["y_std"],
                   hidden=tuple(state["hidden"]), backend=state["backend"],
                   n_features=int(state["n_features"]))


# ---------------------------------------------------------------------------
# training
# ---------------------------------------------------------------------------


def train_surrogate(corpus: Corpus, *, hidden: Tuple[int, ...] = (64, 64),
                    ensemble: int = 4, steps: int = 500, lr: float = 1e-2,
                    seed: int = 0, backend: str = "") -> SurrogateModel:
    """Fit the ensemble on a :class:`~repro.surrogate.dataset.Corpus`."""
    if not len(corpus.y):
        raise ValueError("cannot train a surrogate on an empty corpus")
    X = featurize(corpus.sites, corpus.tiles)
    x_mean = X.mean(axis=0)
    x_std = np.where(X.std(axis=0) < 1e-8, 1.0, X.std(axis=0))
    y_mean = float(corpus.y.mean())
    y_std = float(corpus.y.std()) or 1.0
    Xn = jnp.asarray((X - x_mean) / x_std, jnp.float32)
    yn = jnp.asarray((corpus.y - y_mean) / y_std, jnp.float32)
    key = jax.random.PRNGKey(seed)
    params = []
    for _ in range(ensemble):
        key, sub = jax.random.split(key)
        member = _init_member(sub, X.shape[1], hidden)
        member, _ = _train_member_jit(member, Xn, yn, steps, lr)
        params.append(jax.tree.map(np.asarray, member))
    return SurrogateModel(params, x_mean, x_std, y_mean, y_std,
                          hidden=hidden, backend=backend)


def train_from_db(db: Union[MeasureDB, str, None], *, min_pairs: int = 8,
                  backend: Optional[str] = None,
                  **train_kwargs) -> Optional[SurrogateModel]:
    """Train from whatever the DB holds; ``None`` when there is not yet
    enough data (``min_pairs`` finite records) — callers treat that as
    "pruning not active yet", the right behaviour for a cold DB.

    With ``backend=None`` the corpus is restricted to the most common
    measurement fingerprint in the DB: mixing fingerprints would train
    on incommensurable clocks.
    """
    if db is None:
        return None
    corpus = build_corpus(db, backend=backend)
    if backend is None and corpus.backends:
        backend = Counter(corpus.backends).most_common(1)[0][0]
        keep = [i for i, b in enumerate(corpus.backends) if b == backend]
        corpus = Corpus(
            sites=tuple(corpus.sites[i] for i in keep),
            tiles=corpus.tiles[keep], y=corpus.y[keep],
            backends=tuple(corpus.backends[i] for i in keep))
    if len(corpus.y) < min_pairs:
        return None
    return train_surrogate(corpus, backend=backend or "", **train_kwargs)


# ---------------------------------------------------------------------------
# checkpoints (agentio atomic-save + fingerprint discipline)
# ---------------------------------------------------------------------------


def save_surrogate(model: SurrogateModel, directory: str) -> str:
    """Atomic artifact write; returns the manifest fingerprint."""
    return agentio.save_agent(model, directory)


def load_surrogate(directory: str) -> SurrogateModel:
    """Load + fingerprint-verify a checkpoint (raises ``ArtifactError``
    on corruption or a non-surrogate artifact)."""
    state, _ = agentio.read_agent_state(directory)
    return SurrogateModel.from_state(state)
