"""Fixed numeric featurizer for ``(site, tiles)`` pairs.

The feature vector is a deterministic function of the site's recorded
shape metadata and the tile triple — no code embedding, no hardware
probe — so it can be computed for any pair the ``MeasureDB`` has ever
seen and for any candidate the tuner wants priced.  Layout (all float64):

====  =====================================================
 0-2  kind one-hot (matmul, attention, chunk_scan)
 3-6  log2 site dims: m, n, k, batch
   7  dtype bytes (2 = bf16, 4 = f32)
   8  causal flag
9-11  log2 tile triple (t0, t1, t2; unused dims are 1)
12-14 log2 tile/dim ratios (t0/m, t1/n, t2/k)
  15  log2 VMEM footprint bytes (the kernels' scratch formulas)
  16  VMEM footprint as a fraction of the budget
  17  log2 grid steps (number of kernel invocations)
  18  log2 analytic model cost — the scalar cost model as a prior
====  =====================================================

Pairs the analytic model rejects (VMEM overflow) have no finite cost to
take a log of; their prior feature is clamped.  Callers are expected to
legality-filter before pricing (both the oracle and the pruner do), so
clamped rows only ever occur in corpora built from hand-edited DBs.
"""
from __future__ import annotations

from typing import Sequence

import numpy as np

from repro.core import costmodel as cm
from repro.core import costmodel_vec
from repro.models.compute import KernelSite

KINDS = ("matmul", "attention", "chunk_scan")
N_FEATURES = 19

_LOG_CLAMP = 64.0       # stand-in for log2(inf) on illegal-pair priors


def _log2(x: np.ndarray) -> np.ndarray:
    return np.log2(np.maximum(np.asarray(x, np.float64), 1e-300))


def _vmem_and_grid(sites: Sequence[KernelSite],
                   tiles: np.ndarray) -> tuple:
    """(n,) VMEM footprint bytes and (n,) grid steps, per the kernels'
    scratch formulas (mirrors the legality math in ``costmodel_vec``)."""
    n = len(sites)
    vmem = np.empty(n, np.float64)
    grid = np.empty(n, np.float64)
    t0 = tiles[:, 0].astype(np.float64)
    t1 = tiles[:, 1].astype(np.float64)
    t2 = tiles[:, 2].astype(np.float64)
    for kind, idx in costmodel_vec.group_by_kind(sites).items():
        s = np.array([cm._dtype_bytes(sites[i].dtype) for i in idx],
                     np.float64)
        m = np.array([sites[i].m for i in idx], np.float64)
        nn = np.array([sites[i].n for i in idx], np.float64)
        kk = np.array([sites[i].k for i in idx], np.float64)
        b = np.array([sites[i].batch for i in idx], np.float64)
        a, c, e = t0[idx], t1[idx], t2[idx]
        if kind == "matmul":
            vmem[idx] = 2 * (a * e + e * c) * s + a * c * 4 + a * c * s
            grid[idx] = (np.ceil(m / a) * np.ceil(nn / c)
                         * np.ceil(kk / e))
        elif kind == "attention":
            # site semantics: m=Sq, k=Skv, n=D; tiles (bq, bkv, 1)
            vmem[idx] = (2 * (a * nn + 2 * c * nn) * s + a * nn * 4
                         + 2 * a * 4 + a * c * 4)
            grid[idx] = b * np.ceil(m / a) * np.ceil(kk / c)
        elif kind == "chunk_scan":
            # tiles (chunk, 1, 1); P=site.n, N=site.k
            vmem[idx] = 2 * a * (nn + 2 * kk) * s + nn * kk * 4 + a * a * 4
            grid[idx] = np.ceil(b * m / a)
        else:                               # unknown kind: neutral values
            vmem[idx] = s
            grid[idx] = 1.0
    return vmem, np.maximum(grid, 1.0)


def featurize(sites: Sequence[KernelSite], tiles) -> np.ndarray:
    """(n, N_FEATURES) float64 feature matrix for the given pairs."""
    t = np.asarray(tiles, np.int64)
    if t.ndim != 2 or t.shape[0] != len(sites):
        raise ValueError(f"tiles must be (n_sites, k), got {t.shape}")
    if t.shape[1] < 3:
        t = np.concatenate([t, np.ones((len(t), 3 - t.shape[1]),
                                       np.int64)], 1)
    n = len(sites)
    X = np.zeros((n, N_FEATURES), np.float64)
    if not n:
        return X
    kind_ix = {k: i for i, k in enumerate(KINDS)}
    dims = np.array([[s.m, s.n, s.k, s.batch] for s in sites], np.float64)
    for i, s in enumerate(sites):
        j = kind_ix.get(s.kind)
        if j is not None:
            X[i, j] = 1.0
        X[i, 7] = cm._dtype_bytes(s.dtype)
        X[i, 8] = float(s.causal)
    X[:, 3:7] = _log2(dims)
    X[:, 9:12] = _log2(t)
    X[:, 12:15] = _log2(t) - _log2(dims[:, :3])
    vmem, grid = _vmem_and_grid(sites, t)
    X[:, 15] = _log2(vmem)
    X[:, 16] = vmem / cm.VMEM_BYTES
    X[:, 17] = _log2(grid)
    prior = costmodel_vec.costs_for_tiles(sites, t)
    X[:, 18] = np.where(np.isfinite(prior), _log2(prior), _LOG_CLAMP)
    return X
