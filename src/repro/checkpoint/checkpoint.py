"""Sharded, atomic, async checkpointing with auto-resume (no orbax).

Layout:  <dir>/step_<N>/host_<i>.npz + manifest.json
* atomic: written to ``.tmp-`` then renamed; a manifest is written last, so
  a partially-written step directory is never considered restorable.
* async: ``save_async`` hands the (host-local, already-device-fetched)
  arrays to a writer thread — training continues immediately.
* GC: ``keep_n`` newest complete checkpoints are retained.
* restore picks the newest *complete* step (manifest present), which makes
  crash/preemption recovery a no-op for the trainer.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Optional, Tuple

import jax
import numpy as np


def _flat(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = []
    for path, _ in flat:
        keys.append(jax.tree_util.keystr(path))
    return keys, [l for _, l in flat], treedef


class CheckpointManager:
    def __init__(self, directory: str, keep_n: int = 3, host_index: int = 0,
                 host_count: int = 1):
        self.dir = directory
        self.keep_n = keep_n
        self.host_index = host_index
        self.host_count = host_count
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:09d}")

    def save(self, state, step: int, block: bool = True):
        keys, leaves, _ = _flat(state)
        # fetch to host memory *now* (donated buffers may be reused)
        host_leaves = [np.asarray(l) for l in leaves]
        if block:
            self._write(keys, host_leaves, step)
        else:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(keys, host_leaves, step),
                daemon=True)
            self._thread.start()

    def save_async(self, state, step: int):
        self.save(state, step, block=False)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, keys, leaves, step: int):
        sdir = self._step_dir(step)
        tmp = sdir + f".tmp-{self.host_index}"
        os.makedirs(tmp, exist_ok=True)
        path = os.path.join(tmp, f"host_{self.host_index}.npz")
        np.savez(path, **{k: v for k, v in zip(keys, leaves)})
        os.makedirs(sdir, exist_ok=True)
        os.replace(path, os.path.join(sdir, f"host_{self.host_index}.npz"))
        shutil.rmtree(tmp, ignore_errors=True)
        if self.host_index == 0:
            manifest = {"step": step, "host_count": self.host_count,
                        "time": time.time(), "keys": keys}
            mtmp = os.path.join(sdir, ".manifest.tmp")
            with open(mtmp, "w") as f:
                json.dump(manifest, f)
            os.replace(mtmp, os.path.join(sdir, "manifest.json"))
        self._gc()

    # ------------------------------------------------------------------
    def complete_steps(self):
        steps = []
        if not os.path.isdir(self.dir):
            return steps
        for name in sorted(os.listdir(self.dir)):
            if not name.startswith("step_") or name.endswith(
                    tuple(f".tmp-{i}" for i in range(64))):
                continue
            if os.path.exists(os.path.join(self.dir, name, "manifest.json")):
                steps.append(int(name.split("_")[1]))
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.complete_steps()
        return steps[-1] if steps else None

    def restore(self, state_like, step: Optional[int] = None
                ) -> Tuple[Any, Optional[int]]:
        """Restore into the structure of ``state_like``.  Returns
        (state, step) — (state_like, None) when nothing is restorable."""
        if step is None:
            step = self.latest_step()
        if step is None:
            return state_like, None
        path = os.path.join(self._step_dir(step),
                            f"host_{self.host_index}.npz")
        data = np.load(path)
        keys, leaves, treedef = _flat(state_like)
        new_leaves = []
        for k, leaf in zip(keys, leaves):
            arr = data[k]
            assert arr.shape == tuple(leaf.shape), (k, arr.shape, leaf.shape)
            new_leaves.append(arr.astype(leaf.dtype))
        return jax.tree_util.tree_unflatten(treedef, new_leaves), step

    def _gc(self):
        steps = self.complete_steps()
        for s in steps[:-self.keep_n]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)
