from repro.configs.base import (
    ARCH_IDS,
    SHAPES,
    BlockDesc,
    ModelConfig,
    ShapeConfig,
    all_configs,
    get_config,
    supported_shapes,
)

__all__ = [
    "ARCH_IDS", "SHAPES", "BlockDesc", "ModelConfig", "ShapeConfig",
    "all_configs", "get_config", "supported_shapes",
]
