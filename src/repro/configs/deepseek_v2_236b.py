"""DeepSeek-V2 236B [arXiv:2405.04434; hf] — MLA (kv_lora=512) + MoE 160e top-6,
2 shared experts, per-expert d_ff=1536.

Deviation from HF checkpoint (recorded): the real model's first layer uses a
dense MLP (d_ff=12288); we make every layer MoE so the stack scans uniformly
(60 identical periods).  Param count impact < 0.1%.
"""
from repro.configs.base import BlockDesc, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,        # nominal; MLA replaces GQA entirely
    d_ff=0,
    vocab_size=102400,
    head_dim=128,
    rope="1d",             # decoupled rope on the qk_rope_dim slice (MLA)
    rope_theta=10_000.0,
    norm="rmsnorm",
    act="silu",
    n_experts=160,
    n_shared_experts=2,
    moe_top_k=6,
    moe_d_ff=1536,
    mla=True,
    kv_lora_rank=512,
    q_lora_rank=1536,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    period=(BlockDesc("attn", "moe"),),
    source="arXiv:2405.04434; hf:deepseek-ai/DeepSeek-V2",
)
