"""Jamba-v0.1 52B [arXiv:2403.19887; hf] — hybrid Mamba+attention 7:1
interleave, MoE 16e top-2 on alternating layers, GQA kv=8.

Period of 8 layers (4 scanned super-blocks): attention sits at index 3 of
each period (matching the paper's placement mid-block), MoE MLP on the odd
indices (every other layer, 16 experts top-2), dense MLP elsewhere.

Hybrid family: Mamba layers have O(1) decode state, the 4 attention layers
keep a KV cache — long_500k runs with the cache sequence-sharded (SP).
The Mamba mixer uses the SSD (Mamba-2 style, scalar-per-head decay)
chunkwise-parallel formulation — TPU-friendly (4 matmuls per chunk) and
profile-equivalent to the paper's Mamba-1 kernel; recorded as a deviation
in DESIGN.md §2.
"""
from repro.configs.base import BlockDesc, ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    head_dim=128,
    rope="none",           # Jamba uses no positional encoding (Mamba provides order)
    norm="rmsnorm",
    act="silu",
    n_experts=16,
    n_shared_experts=0,
    moe_top_k=2,
    moe_d_ff=14336,
    ssm_state_dim=16,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=256,
    period=(
        BlockDesc("mamba", "dense"), BlockDesc("mamba", "moe"),
        BlockDesc("mamba", "dense"), BlockDesc("attn",  "moe"),
        BlockDesc("mamba", "dense"), BlockDesc("mamba", "moe"),
        BlockDesc("mamba", "dense"), BlockDesc("mamba", "moe"),
    ),
    source="arXiv:2403.19887; hf:ai21labs/Jamba-v0.1",
)
