"""The paper's own configuration: NeuroVectorizer RL hyperparameters
(§4 Evaluation) mapped onto the TPU tile-tuning action space (DESIGN.md §2).
"""
from dataclasses import asdict, dataclass, field, fields
from typing import Tuple


@dataclass(frozen=True)
class NeuroVecConfig:
    # --- action space: power-of-two tile factors (the VF/IF analogue) ---
    # matmul sites: (block_m, block_n, block_k); attention: (block_q, block_kv)
    # the top corner (512, 512, 4096) overflows VMEM — over-aggressive
    # factors "fail to compile", giving the -9 penalty a live region of the
    # action space exactly as over-vectorization does in the paper (§3.4)
    bm_choices: Tuple[int, ...] = (8, 16, 32, 64, 128, 256, 512)
    bn_choices: Tuple[int, ...] = (128, 256, 512)
    bk_choices: Tuple[int, ...] = (128, 256, 512, 1024, 2048, 4096)
    bq_choices: Tuple[int, ...] = (64, 128, 256, 512, 1024)
    bkv_choices: Tuple[int, ...] = (128, 256, 512, 1024, 2048)
    chunk_choices: Tuple[int, ...] = (64, 128, 256, 512, 1024)

    # --- embedding (code2vec analogue) ---
    embed_dim: int = 340            # paper: 340-feature code vector
    n_path_tokens: int = 64         # vocabulary of operand/primitive tokens
    max_paths: int = 32             # path-contexts per site

    # --- PPO (paper §4 defaults) ---
    hidden: Tuple[int, ...] = (64, 64)   # 64x64 FCNN
    lr: float = 5e-5
    train_batch: int = 4000
    sgd_minibatch: int = 128
    ppo_epochs: int = 8
    clip: float = 0.2
    entropy_coef: float = 0.01
    value_coef: float = 0.5

    # --- environment (reward eq. 2, §3.4 penalty) ---
    fail_penalty: float = -9.0      # VMEM overflow == compile timeout
    illegal_slowdown: float = 10.0  # an illegal tile "runs" this many times
                                    # slower than baseline: speedup clamps to
                                    # 1/illegal_slowdown and program-level
                                    # scoring charges illegal_slowdown*t_base
                                    # (one constant for env + vectorizer)
    reward_noise: float = 0.0       # measurement-noise injection for tests
    strict_actions: bool = False    # raise on out-of-range action indices
                                    # instead of clamping (debug mode; also
                                    # REPRO_STRICT_ACTIONS=1 /
                                    # env.set_strict_actions)

    # --- dataset (§3.2) ---
    n_synthetic: int = 10_000       # generated corpus size
    train_subset: int = 5_000       # brute-force-labelled training budget
    test_frac: float = 0.2


DEFAULT = NeuroVecConfig()


def cfg_to_dict(cfg: NeuroVecConfig) -> dict:
    """JSON-serializable snapshot of a config (tuples become lists) —
    the on-disk form used by the ``repro.artifacts`` persistence layer."""
    return asdict(cfg)


def cfg_from_dict(d: dict) -> NeuroVecConfig:
    """Inverse of :func:`cfg_to_dict`; restores tuple-typed fields and
    rejects unknown keys (a config written by a newer schema should fail
    loudly, not be silently truncated)."""
    known = {f.name for f in fields(NeuroVecConfig)}
    unknown = sorted(set(d) - known)
    if unknown:
        raise ValueError(f"unknown NeuroVecConfig fields: {unknown}")
    return NeuroVecConfig(**{k: tuple(v) if isinstance(v, list) else v
                             for k, v in d.items()})
