"""Qwen3-8B [hf:Qwen/Qwen3-8B] — dense, GQA kv=8, qk_norm, RoPE, SwiGLU."""
from repro.configs.base import BlockDesc, ModelConfig

CONFIG = ModelConfig(
    name="qwen3-8b",
    family="dense",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=12288,
    vocab_size=151936,
    head_dim=128,
    rope="1d",
    rope_theta=1_000_000.0,
    qk_norm=True,
    norm="rmsnorm",
    act="silu",
    period=(BlockDesc("attn", "dense"),),
    source="hf:Qwen/Qwen3-8B",
)
