"""Llama-4 Maverick 400B-A17B [hf:meta-llama; unverified] — MoE 128e top-1
+ 1 shared expert, interleaved dense/MoE MLP layers (period 2), GQA kv=8.

The 400B total / 17B active split in the public card comes from alternating
dense-MLP and 128-expert layers; we encode that as a period of 2.
"""
from repro.configs.base import BlockDesc, ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=16384,            # dense (non-MoE) layers
    vocab_size=202048,
    head_dim=128,
    rope="1d",
    rope_theta=500_000.0,
    norm="rmsnorm",
    act="silu",
    n_experts=128,
    n_shared_experts=1,
    moe_top_k=1,
    moe_d_ff=8192,
    period=(BlockDesc("attn", "dense"), BlockDesc("attn", "moe")),
    source="hf:meta-llama/Llama-4-Maverick-17B-128E; unverified",
)
