"""Config system: model architecture configs + input-shape registry.

Every assigned architecture is a `ModelConfig` instance in its own module
(``src/repro/configs/<id>.py``).  A config fully determines the model: the
builder in ``repro.models.lm`` consumes nothing else.

Layer stacking is expressed as a repeating *period* of block descriptors
(``BlockDesc``) so that heterogeneous stacks (Jamba's 1:7 attn:mamba
interleave, xLSTM's mLSTM/sLSTM mix) scan cleanly: parameters are stacked
along a leading ``n_periods`` axis and the model body is a single
``lax.scan`` over periods, keeping the HLO small and compile times sane.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Optional, Sequence


# ---------------------------------------------------------------------------
# Block descriptors
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class BlockDesc:
    """One entry of the repeating layer period."""

    kind: str           # "attn" | "mamba" | "mlstm" | "slstm"
    mlp: str = "dense"  # "dense" | "moe" | "none"

    def __post_init__(self):
        assert self.kind in ("attn", "mamba", "mlstm", "slstm"), self.kind
        assert self.mlp in ("dense", "moe", "none"), self.mlp


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | vlm | audio | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int                      # dense-MLP hidden size (0 = no dense MLP)
    vocab_size: int

    # --- attention details ---
    head_dim: int = 0              # 0 -> d_model // n_heads
    rope: str = "1d"               # "1d" | "2d" (chatglm partial) | "none"
    rope_theta: float = 10_000.0
    qk_norm: bool = False
    norm: str = "rmsnorm"          # "rmsnorm" | "layernorm"
    act: str = "silu"              # "silu" (gated) | "gelu" (plain)
    tie_embeddings: bool = False

    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0              # per-expert hidden size

    # --- MLA (DeepSeek-V2) ---
    mla: bool = False
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # --- SSM (Mamba-style, SSD formulation) ---
    ssm_state_dim: int = 128
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    ssm_chunk: int = 256           # chunkwise-parallel scan chunk (tunable site)

    # --- xLSTM ---
    xlstm_proj_factor: float = 2.0

    # --- encoder/decoder ---
    enc_dec: bool = False
    n_enc_layers: int = 0
    n_dec_layers: int = 0

    # --- modality frontend (STUB: input_specs provides embeddings) ---
    frontend: str = "none"         # "none" | "vision" | "audio"
    n_frontend_tokens: int = 0     # patches / frames occupying the prefix

    # --- layer period (heterogeneous stacks) ---
    period: tuple = (BlockDesc("attn", "dense"),)

    # --- numerics ---
    dtype: str = "bfloat16"

    # --- notes recorded into DESIGN/EXPERIMENTS ---
    source: str = ""
    notes: str = ""

    # ------------------------------------------------------------------
    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if not self.enc_dec:
            assert self.n_layers % len(self.period) == 0, (
                f"{self.name}: n_layers={self.n_layers} not divisible by "
                f"period of {len(self.period)}")

    # ------------------------------------------------------------------
    @property
    def n_periods(self) -> int:
        return self.n_layers // len(self.period)

    @property
    def d_head_total(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def d_kv_total(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def d_inner_ssm(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner_ssm // self.ssm_head_dim

    @property
    def attention_free(self) -> bool:
        return all(b.kind != "attn" for b in self.period)

    @property
    def subquadratic(self) -> bool:
        """True when decode state does not grow quadratically costly with
        context — i.e. the arch may run the 500k-context shape."""
        return self.family in ("ssm", "hybrid")

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Total parameter count (for 6·N·D roofline bookkeeping)."""
        return _count_params(self)

    def active_param_count(self) -> int:
        """Parameters active per token (MoE: shared + top-k routed)."""
        return _count_params(self, active_only=True)

    def reduced(self, **overrides) -> "ModelConfig":
        """A tiny same-family config for CPU smoke tests."""
        small = dict(
            n_layers=len(self.period),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 4) if self.n_kv_heads > 1 else 1,
            d_ff=128 if self.d_ff else 0,
            vocab_size=256,
            head_dim=16,
            n_experts=min(self.n_experts, 4),
            moe_top_k=min(self.moe_top_k, 2),
            n_shared_experts=min(self.n_shared_experts, 1),
            moe_d_ff=64 if self.moe_d_ff else 0,
            kv_lora_rank=32 if self.mla else 0,
            q_lora_rank=48 if (self.mla and self.q_lora_rank) else 0,
            qk_nope_dim=16 if self.mla else 0,
            qk_rope_dim=8 if self.mla else 0,
            v_head_dim=16 if self.mla else 0,
            ssm_state_dim=16,
            ssm_head_dim=16,
            ssm_chunk=8,
            n_enc_layers=2 if self.enc_dec else 0,
            n_dec_layers=2 if self.enc_dec else 0,
            n_frontend_tokens=8 if self.frontend != "none" else 0,
            dtype="float32",
            name=self.name + "-smoke",
        )
        if self.enc_dec:
            small["n_layers"] = 4
        small.update(overrides)
        return dataclasses.replace(self, **small)


def _gated(act: str) -> bool:
    return act == "silu"


def _count_params(c: ModelConfig, active_only: bool = False) -> int:
    d = c.d_model
    total = c.vocab_size * d                       # embed
    if not c.tie_embeddings:
        total += c.vocab_size * d                  # lm head

    def attn_params() -> int:
        if c.mla:
            p = 0
            q_dim = c.n_heads * (c.qk_nope_dim + c.qk_rope_dim)
            if c.q_lora_rank:
                p += d * c.q_lora_rank + c.q_lora_rank * q_dim
            else:
                p += d * q_dim
            p += d * (c.kv_lora_rank + c.qk_rope_dim)            # down (kv + rope)
            p += c.kv_lora_rank * c.n_heads * (c.qk_nope_dim + c.v_head_dim)
            p += c.n_heads * c.v_head_dim * d                    # out proj
            return p
        return d * c.d_head_total + 2 * d * c.d_kv_total + c.d_head_total * d

    def dense_mlp_params() -> int:
        mult = 3 if _gated(c.act) else 2
        return mult * d * c.d_ff

    def moe_mlp_params(active: bool) -> int:
        mult = 3 if _gated(c.act) else 2
        n_routed = c.moe_top_k if active else c.n_experts
        p = (n_routed + c.n_shared_experts) * mult * d * c.moe_d_ff
        p += d * c.n_experts                                      # router
        return p

    def ssm_params() -> int:
        di, n = c.d_inner_ssm, c.ssm_state_dim
        h = c.n_ssm_heads
        return (d * 2 * di + di * c.ssm_conv_width + di * 2 * n
                + di + h + di * d)

    def xlstm_params(kind: str) -> int:
        if kind == "mlstm":
            # up(2 branches) + block-diagonal per-head qkv + gates + down
            di = int(c.xlstm_proj_factor * d)
            return d * 2 * di + 3 * di * di // c.n_heads + 2 * di + di * d
        # sLSTM: 4 gates (input + block-diag recurrent per head) + GLU MLP
        hd = d // c.n_heads
        return 4 * d * d + 4 * c.n_heads * hd * hd + 2 * d * (4 * d // 3)

    def block_params(b: BlockDesc, active: bool) -> int:
        p = 0
        if b.kind == "attn":
            p += attn_params()
        elif b.kind == "mamba":
            p += ssm_params()
        elif b.kind in ("mlstm", "slstm"):
            p += xlstm_params(b.kind)
        if b.mlp == "dense":
            p += dense_mlp_params()
        elif b.mlp == "moe":
            p += moe_mlp_params(active)
        return p

    n_units = (c.n_enc_layers + c.n_dec_layers) if c.enc_dec else c.n_layers
    per_period = sum(block_params(b, active_only) for b in c.period)
    total += per_period * (n_units // len(c.period))
    if c.enc_dec:   # cross-attention in decoder layers
        total += c.n_dec_layers * attn_params()
    return int(total)


# ---------------------------------------------------------------------------
# Input shapes
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k":    ShapeConfig("train_4k",    4_096,   256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  ShapeConfig("decode_32k",  32_768,  128, "decode"),
    "long_500k":   ShapeConfig("long_500k",   524_288, 1,   "decode"),
}


def supported_shapes(cfg: ModelConfig) -> dict:
    """Which of the four assigned shapes an arch runs; skips are recorded
    (DESIGN.md §Arch-applicability)."""
    out = {}
    for name, s in SHAPES.items():
        if name == "long_500k" and not cfg.subquadratic:
            out[name] = "SKIP: pure full-attention arch — 500k dense decode "\
                        "is quadratic-state; run only for ssm/hybrid per spec"
            continue
        out[name] = "run"
    return out


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

ARCH_IDS = (
    "starcoder2_7b",
    "qwen3_8b",
    "stablelm_3b",
    "chatglm3_6b",
    "deepseek_v2_236b",
    "llama4_maverick_400b",
    "xlstm_1_3b",
    "phi3_vision_4_2b",
    "seamless_m4t_medium",
    "jamba_v0_1_52b",
)


def get_config(arch: str) -> ModelConfig:
    import importlib
    arch = arch.replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.CONFIG


def all_configs() -> dict:
    return {a: get_config(a) for a in ARCH_IDS}
