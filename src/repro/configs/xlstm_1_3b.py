"""xLSTM-1.3B [arXiv:2405.04517; unverified] — 48 blocks, mLSTM:sLSTM 7:1,
4 heads, no MLP (mLSTM blocks carry their own up/down projection).

Attention-free: decode state is O(1) in context length, so this arch runs
the long_500k shape.
"""
from repro.configs.base import BlockDesc, ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    head_dim=512,
    rope="none",
    norm="layernorm",
    act="gelu",
    xlstm_proj_factor=2.0,
    tie_embeddings=True,
    period=(
        BlockDesc("mlstm", "none"), BlockDesc("mlstm", "none"),
        BlockDesc("mlstm", "none"), BlockDesc("mlstm", "none"),
        BlockDesc("mlstm", "none"), BlockDesc("mlstm", "none"),
        BlockDesc("mlstm", "none"), BlockDesc("slstm", "none"),
    ),
    source="arXiv:2405.04517 (xLSTM[7:1]); unverified",
)
