"""StableLM-3B [hf:stabilityai; unverified] — dense MHA (kv=32), LayerNorm."""
from repro.configs.base import BlockDesc, ModelConfig

CONFIG = ModelConfig(
    name="stablelm-3b",
    family="dense",
    n_layers=32,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=6912,
    vocab_size=50304,
    head_dim=80,
    rope="1d",
    rope_theta=10_000.0,
    norm="layernorm",
    act="silu",
    period=(BlockDesc("attn", "dense"),),
    source="hf:stabilityai/stablelm-2-1_6b family; unverified",
)
