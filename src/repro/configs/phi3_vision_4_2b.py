"""Phi-3-Vision 4.2B [hf:microsoft/Phi-3-vision-128k-instruct] — phi3-mini
backbone (32L d=3072 MHA) + CLIP vision frontend.

Per spec the modality frontend is a STUB: ``input_specs()`` supplies
precomputed patch embeddings (batch, n_patches, d_model) which occupy the
sequence prefix; only the transformer backbone is built/tuned.
"""
from repro.configs.base import BlockDesc, ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=32064,
    head_dim=96,
    rope="1d",
    rope_theta=10_000.0,
    norm="rmsnorm",
    act="silu",
    frontend="vision",
    n_frontend_tokens=256,   # 16x16 patch grid stand-in
    period=(BlockDesc("attn", "dense"),),
    source="hf:microsoft/Phi-3-vision-128k-instruct",
)
