"""SeamlessM4T-medium [arXiv:2308.11596; hf] — encoder-decoder, 12+12 layers,
d=1024, MHA 16 heads, vocab 256206.

Audio frontend is a STUB per spec: ``input_specs()`` supplies precomputed
frame embeddings (batch, n_frames, d_model) as the encoder input; the
text decoder consumes target tokens.  Decode shapes exercise the decoder
with a frozen encoder memory.
"""
from repro.configs.base import BlockDesc, ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="audio",
    n_layers=24,           # 12 encoder + 12 decoder
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=256206,
    head_dim=64,
    rope="none",           # learned/sinusoidal positions in the original
    norm="layernorm",
    act="gelu",
    enc_dec=True,
    n_enc_layers=12,
    n_dec_layers=12,
    frontend="audio",
    n_frontend_tokens=0,   # encoder input IS the frame-embedding sequence
    period=(BlockDesc("attn", "dense"),),
    source="arXiv:2308.11596; hf:facebook/seamless-m4t-medium",
)
