"""ChatGLM3-6B [arXiv:2406.12793; hf] — dense, GQA kv=2, 2d (partial) RoPE."""
from repro.configs.base import BlockDesc, ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b",
    family="dense",
    n_layers=28,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13696,
    vocab_size=65024,
    head_dim=128,
    rope="2d",             # GLM applies rotary to half of each head dim
    rope_theta=10_000.0,
    norm="rmsnorm",
    act="silu",
    period=(BlockDesc("attn", "dense"),),
    source="arXiv:2406.12793; hf:THUDM/chatglm3-6b",
)
