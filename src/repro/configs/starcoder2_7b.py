"""StarCoder2-7B [arXiv:2402.19173; hf] — dense, GQA kv=4, RoPE, LayerNorm."""
from repro.configs.base import BlockDesc, ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b",
    family="dense",
    n_layers=32,
    d_model=4608,
    n_heads=36,
    n_kv_heads=4,
    d_ff=18432,
    vocab_size=49152,
    head_dim=128,
    rope="1d",
    rope_theta=1_000_000.0,
    norm="layernorm",
    act="gelu",            # StarCoder2 uses a plain (non-gated) GELU MLP
    period=(BlockDesc("attn", "dense"),),
    source="arXiv:2402.19173; hf:bigcode/starcoder2-7b",
)
