"""``ProgramStore`` — persistent, append-only store of tuned tile programs.

The measurement DB (PR 3) made *timings* survive the process; this is the
same discipline one level up, for *decisions*: once an agent has tuned a
set of kernel sites, every later process asking the same question gets the
answer by lookup — zero agent inferences, zero oracle evaluations (the
"tune once, look up everywhere" the ROADMAP's serving story needs, and the
cached-verified-result stance of LLM-Vectorizer).

A store entry is only valid for the exact question it answered, so the key
fingerprints all three coordinates (mirroring ``MeasureDB.make_key``):

* the **site set** — sorted ``site.key()``s, hashed (order-insensitive);
* the **agent** — registry name + SHA-256 of its deployable
  ``state_dict`` (:func:`~repro.artifacts.agentio.agent_fingerprint`), so
  further training invalidates exactly the entries it should;
* the **oracle/backend** — oracle type + config hash, plus the
  measurement transport's ``backend_key`` when one is attached (a program
  tuned against interpret-mode timings must not be served for a TPU
  oracle).

On disk it is JSON-lines, append-only: corrupt lines are skipped and
counted (never fatal — the store degrades to re-tuning), duplicate keys
resolve last-wins on load.
"""
from __future__ import annotations

import hashlib
import json
import os
import threading
from typing import Optional, Sequence, Tuple

from repro.artifacts.agentio import agent_fingerprint
from repro.core.vectorizer import TileProgram, tune


def sites_fingerprint(sites: Sequence) -> str:
    """Order-insensitive hash of a site set (sorted ``site.key()``s)."""
    blob = "\n".join(sorted(s.key() for s in sites))
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


def oracle_fingerprint(oracle) -> str:
    """Oracle identity for the store key: type + config hash, plus the
    transport's measurement-conditions fingerprint when one is attached.
    :class:`~repro.core.protocols.AsyncOracle` is unwrapped."""
    from repro.core.protocols import AsyncOracle

    transport = None
    if isinstance(oracle, AsyncOracle):
        transport = oracle.transport
        oracle = oracle.oracle
    if transport is None:
        transport = getattr(getattr(oracle, "measure_fn", None),
                            "transport", None)
    cfg = getattr(oracle, "cfg", None)
    try:
        from repro.configs.neurovec import cfg_to_dict
        cfg_fp = hashlib.sha256(json.dumps(
            cfg_to_dict(cfg), sort_keys=True).encode()).hexdigest()[:12]
    except (TypeError, AttributeError):
        cfg_fp = f"cfg-{type(cfg).__name__}"
    base = f"{type(oracle).__name__}:{cfg_fp}"
    if transport is not None:
        base += f":{transport.backend_key}"
    return base


def program_key(sites: Sequence, agent, oracle) -> str:
    """The full store key: (site set, agent identity, oracle/backend).

    The agent fingerprint is recomputed from ``state_dict()`` on every
    call rather than cached: nothing in the protocol announces state
    mutation (callers may ``fit`` the agent directly), and a stale
    fingerprint would serve a *wrong program* — correctness over the
    hash cost, which is linear in policy size and benchmarked by
    ``benchmarks/bench_artifacts.py``."""
    return (f"{sites_fingerprint(sites)}"
            f"|{agent.name}:{agent_fingerprint(agent)[:16]}"
            f"|{oracle_fingerprint(oracle)}")


class ProgramStore:
    """Append-only JSONL store: ``program_key -> TileProgram`` tiles.

    ``hits``/``misses`` count lookups through :meth:`get` (what the
    facade/service report as their warm-start rate);
    ``skipped_lines`` counts unparseable records ignored at load.

    Thread-safe: one store is shared by every concurrent
    :class:`~repro.service.TuningService` session (their tunes run on a
    thread pool), so lookups, appends and counters are serialized under
    one lock — the same discipline the transports apply to the
    :class:`~repro.measure.db.MeasureDB`.
    """

    def __init__(self, path: str):
        self.path = path
        self._mem: dict = {}            # key -> {site_key: (tiles...)}
        self.hits = 0
        self.misses = 0
        self.skipped_lines = 0
        self._fh = None
        self._lock = threading.Lock()
        self._read_offset = 0           # file bytes folded into _mem so far
        self._load()

    # -- persistence ---------------------------------------------------------
    def _load(self) -> None:
        self._read_offset = 0
        if not os.path.exists(self.path):
            return
        with open(self.path, "rb") as f:
            data = f.read()
        self._read_offset = len(data)
        for raw in data.split(b"\n"):
            self._apply_line(raw)

    def _apply_line(self, raw: bytes) -> bool:
        """Parse one JSONL record into ``_mem`` (last wins); ``False``
        (counting ``skipped_lines``) on anything unparseable."""
        line = raw.strip()
        if not line:
            return False
        try:
            rec = json.loads(line.decode("utf-8"))
            key = rec["k"]
            tiles = {str(sk): tuple(int(x) for x in tv)
                     for sk, tv in rec["v"].items()}
        except (ValueError, KeyError, TypeError, AttributeError):
            self.skipped_lines += 1
            return False
        self._mem[key] = tiles          # duplicate keys: last wins
        return True

    def refresh(self) -> int:
        """Fold in records appended to the file since open (or the last
        refresh) — the *pull* half of fleet store invalidation (the push
        half is the ``serve-artifacts`` subscription).  Returns the
        number of records applied, last-wins like :meth:`_load`.

        Only complete (newline-terminated) lines are consumed: a torn
        tail from a writer caught mid-append stays unread until the next
        refresh sees its newline.  Records this store appended itself
        may be re-applied — idempotent by last-wins."""
        with self._lock:
            if self._fh is not None:
                self._fh.flush()
            try:
                size = os.path.getsize(self.path)
            except OSError:
                return 0
            if size <= self._read_offset:
                return 0
            with open(self.path, "rb") as f:
                f.seek(self._read_offset)
                data = f.read()
            end = data.rfind(b"\n")
            if end < 0:
                return 0
            chunk = data[:end + 1]
            self._read_offset += len(chunk)
            return sum(self._apply_line(raw) for raw in chunk.split(b"\n"))

    def _append(self, key: str, tiles: dict) -> None:
        if self._fh is None:
            parent = os.path.dirname(os.path.abspath(self.path))
            os.makedirs(parent, exist_ok=True)
            self._fh = open(self.path, "a")
        rec = {"k": key, "v": {sk: list(tv) for sk, tv in tiles.items()}}
        self._fh.write(json.dumps(rec) + "\n")
        self._fh.flush()

    def close(self) -> None:
        with self._lock:
            if self._fh is not None:
                self._fh.close()
                self._fh = None

    # -- mapping -------------------------------------------------------------
    def get(self, key: str) -> Optional[TileProgram]:
        with self._lock:
            tiles = self._mem.get(key)
            if tiles is None:
                self.misses += 1
                return None
            self.hits += 1
            return TileProgram(dict(tiles))

    def put(self, key: str, program: TileProgram) -> None:
        tiles = {str(sk): tuple(int(x) for x in tv)
                 for sk, tv in program.tiles.items()}
        with self._lock:
            self._append(key, tiles)
            self._mem[key] = tiles

    def records(self) -> dict:
        """Plain-dict snapshot ``{key: {site_key: [t0, t1, t2]}}`` — the
        sync surface the fleet artifact service serves to subscribers."""
        with self._lock:
            return {k: {sk: list(tv) for sk, tv in tiles.items()}
                    for k, tiles in self._mem.items()}

    def stats(self) -> dict:
        with self._lock:
            n = self.hits + self.misses
            return {"entries": len(self._mem), "hits": self.hits,
                    "misses": self.misses,
                    "hit_rate": (self.hits / n) if n else 0.0,
                    "skipped_lines": self.skipped_lines}

    def __len__(self) -> int:
        with self._lock:
            return len(self._mem)

    def __contains__(self, key: str) -> bool:
        with self._lock:
            return key in self._mem

    def __enter__(self) -> "ProgramStore":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def open_program_store(path: str):
    """:class:`ProgramStore` factory that understands fleet addresses.

    A ``fleet://host:port`` path opens a
    :class:`~repro.fleet.artifacts.RemoteProgramStore` — a live,
    push-invalidated mirror of the shared ``serve-artifacts`` store —
    so facade/service/serve callers point at a fleet simply by passing
    a different *string*.  Anything else is a local JSONL path."""
    if isinstance(path, str) and path.startswith("fleet://"):
        from repro.fleet import RemoteProgramStore
        return RemoteProgramStore(path)
    return ProgramStore(path)


def tune_through_store(sites: Sequence, agent, space, oracle,
                       store: Optional[ProgramStore]
                       ) -> Tuple[TileProgram, bool]:
    """The one warm-start code path the facade and the service share:
    look the site set up in ``store``, tune only on a miss (appending the
    fresh program).  Returns ``(program, hit)`` — on a hit the agent and
    the oracle are never touched."""
    sites = list(sites)
    if store is None or not sites:
        return tune(sites, agent, space), False
    key = program_key(sites, agent, oracle)
    prog = store.get(key)
    if prog is not None:
        return prog, True
    prog = tune(sites, agent, space)
    store.put(key, prog)
    return prog, False
