"""Agent checkpoints — the trained policy as a deployable on-disk artifact.

The paper's end-to-end promise (§4) is *train once, then greedy inference
on new code*; AI-powered-compiler practice ships the fitted model, not the
training job.  This module is the storage half of that: any protocol
:class:`~repro.core.protocols.Agent`'s ``state_dict()`` — a nested dict of
plain python values and numpy arrays — is written as

    <dir>/state.json      non-array structure (arrays as ``__array__`` refs)
    <dir>/state.npz       the array leaves, keyed by their tree path
    <dir>/manifest.json   format, agent name, schema version, fingerprint

with the same atomic discipline as ``checkpoint/checkpoint.py``: everything
is staged in a ``.tmp-<pid>`` sibling and moved into place with the
manifest written **last**, so a partially-written directory is never
considered restorable.  The manifest carries a SHA-256 *fingerprint* of the
canonicalized state; :func:`read_agent_state` recomputes it on load and
refuses a mismatch (torn writes, manual edits) — the same fail-loudly
stance as the measurement DB, except that a corrupted *policy* cannot be
"degraded to re-measuring" and must be rejected outright.

The fingerprint doubles as the agent-identity component of
:func:`repro.artifacts.store.program_key`: two agents with bitwise-equal
deployable state share cached tuning decisions, ones that differ do not.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import time
from typing import Optional, Tuple

import numpy as np

ARTIFACT_FORMAT = "neurovectorizer-agent"
MANIFEST = "manifest.json"
STATE_JSON = "state.json"
STATE_NPZ = "state.npz"

_SCALARS = (str, int, float, bool, type(None))


class ArtifactError(RuntimeError):
    """A persistence artifact is missing, incomplete, corrupted, or
    incompatible with what the caller tried to load it into."""


def _split_arrays(obj, path: str = "") -> Tuple[object, dict]:
    """Mirror ``obj`` with every array replaced by an ``__array__`` ref;
    returns ``(json_mirror, {tree_path: ndarray})``."""
    if isinstance(obj, dict):
        mirror, arrays = {}, {}
        for k, v in obj.items():
            if not isinstance(k, str):
                raise ArtifactError(f"non-string dict key {k!r} at "
                                    f"{path or '/'} cannot be serialized")
            m, a = _split_arrays(v, f"{path}/{k}")
            mirror[k] = m
            arrays.update(a)
        return mirror, arrays
    if isinstance(obj, (list, tuple)):
        mirror, arrays = [], {}
        for i, v in enumerate(obj):
            m, a = _split_arrays(v, f"{path}/{i}")
            mirror.append(m)
            arrays.update(a)
        return mirror, arrays
    if isinstance(obj, np.generic):                 # numpy scalar -> python
        return obj.item(), {}
    if isinstance(obj, np.ndarray) or hasattr(obj, "__array_interface__") \
            or type(obj).__module__.startswith("jax"):
        return {"__array__": path}, {path: np.asarray(obj)}
    if isinstance(obj, _SCALARS):
        return obj, {}
    raise ArtifactError(f"unserializable value of type "
                        f"{type(obj).__name__} at {path or '/'}")


def _join_arrays(mirror, arrays: dict):
    if isinstance(mirror, dict):
        if set(mirror) == {"__array__"}:
            return np.asarray(arrays[mirror["__array__"]])
        return {k: _join_arrays(v, arrays) for k, v in mirror.items()}
    if isinstance(mirror, list):
        return [_join_arrays(v, arrays) for v in mirror]
    return mirror


def fingerprint_state(state: dict) -> str:
    """Canonical SHA-256 of a ``state_dict``: sorted-key JSON for the
    structure plus dtype/shape/bytes per array leaf.  Stable across a
    save→load round trip (tuples and lists hash identically)."""
    mirror, arrays = _split_arrays(state)
    h = hashlib.sha256()
    h.update(json.dumps(mirror, sort_keys=True,
                        separators=(",", ":")).encode())
    for key in sorted(arrays):
        a = np.ascontiguousarray(arrays[key])
        h.update(key.encode())
        h.update(str(a.dtype).encode())
        h.update(str(a.shape).encode())
        h.update(a.tobytes())
    return h.hexdigest()


def agent_fingerprint(agent) -> str:
    """Fingerprint of an agent's *current* deployable state."""
    return fingerprint_state(agent.state_dict())


# ---------------------------------------------------------------------------
# save / load
# ---------------------------------------------------------------------------

def save_agent(agent, directory: str) -> str:
    """Write ``agent.state_dict()`` as an atomic artifact directory;
    returns the state fingerprint recorded in the manifest."""
    state = agent.state_dict()
    if not isinstance(state, dict) or "name" not in state \
            or "version" not in state:
        raise ArtifactError("state_dict() must be a dict carrying 'name' "
                            "and 'version'")
    mirror, arrays = _split_arrays(state)
    fp = fingerprint_state(state)
    directory = str(directory).rstrip(os.sep)
    tmp = directory + f".tmp-{os.getpid()}"
    shutil.rmtree(tmp, ignore_errors=True)
    os.makedirs(tmp)
    try:
        with open(os.path.join(tmp, STATE_JSON), "w") as f:
            json.dump(mirror, f)
        np.savez(os.path.join(tmp, STATE_NPZ), **arrays)
        # manifest is written LAST: its presence marks the staged artifact
        # complete, so a directory without one is never restorable
        manifest = {"format": ARTIFACT_FORMAT, "agent": state["name"],
                    "version": state["version"], "fingerprint": fp,
                    "time": time.time()}
        with open(os.path.join(tmp, MANIFEST), "w") as f:
            json.dump(manifest, f, indent=1)
        # whole-directory swap: an existing (valid) artifact is moved
        # aside, not overwritten file-by-file — a crash at any point
        # leaves either the old or the new artifact restorable
        old = None
        if os.path.isdir(directory):
            old = directory + f".old-{os.getpid()}"
            shutil.rmtree(old, ignore_errors=True)
            os.replace(directory, old)
        os.replace(tmp, directory)
        if old is not None:
            shutil.rmtree(old, ignore_errors=True)
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
    return fp


def read_agent_state(directory: str) -> Tuple[dict, dict]:
    """Load and verify ``(state, manifest)`` from an artifact directory.

    Raises :class:`ArtifactError` when the directory is not a complete
    artifact (no manifest — e.g. an interrupted save) or when the
    recomputed fingerprint disagrees with the manifest (corruption)."""
    directory = str(directory)
    mpath = os.path.join(directory, MANIFEST)
    if not os.path.exists(mpath):
        raise ArtifactError(f"no restorable agent artifact at {directory!r} "
                            f"(manifest.json missing — incomplete save?)")
    with open(mpath) as f:
        manifest = json.load(f)
    if manifest.get("format") != ARTIFACT_FORMAT:
        raise ArtifactError(f"{directory!r} is not an agent artifact "
                            f"(format={manifest.get('format')!r})")
    with open(os.path.join(directory, STATE_JSON)) as f:
        mirror = json.load(f)
    with np.load(os.path.join(directory, STATE_NPZ),
                 allow_pickle=False) as npz:
        arrays = {k: npz[k] for k in npz.files}
    state = _join_arrays(mirror, arrays)
    fp = fingerprint_state(state)
    if fp != manifest.get("fingerprint"):
        raise ArtifactError(
            f"fingerprint mismatch for {directory!r}: manifest says "
            f"{manifest.get('fingerprint')!r} but the stored state hashes "
            f"to {fp!r} — the artifact is corrupted; refusing to load")
    return state, manifest


def load_agent(directory: str, agent=None, cfg=None, seed: int = 0,
               **agent_kwargs):
    """Restore an agent from an artifact directory.

    Pass ``agent=`` to load the state into an already-constructed agent
    (name/version are validated by its ``load_state``); otherwise the
    registry constructs one from the manifest's agent name with ``cfg`` /
    ``seed`` / extra kwargs — these must match the saving side for
    bit-exact behaviour (the facade records them; see
    ``NeuroVectorizer.load``)."""
    state, manifest = read_agent_state(directory)
    if agent is None:
        from repro.configs.neurovec import DEFAULT
        from repro.core.agents import make_agent
        agent = make_agent(manifest["agent"],
                           cfg if cfg is not None else DEFAULT,
                           seed=seed, **agent_kwargs)
    agent.load_state(state)
    return agent
