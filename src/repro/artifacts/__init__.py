"""``repro.artifacts`` — persistent tuning artifacts (PR 5).

Everything a tuning run produces that is worth keeping lives here, in two
layers mirroring what the paper amortizes:

* **agent checkpoints** (:mod:`repro.artifacts.agentio`) — a fitted
  agent's ``state_dict`` as an atomic, fingerprinted on-disk directory
  (``save_agent`` / ``load_agent``); the trained-once policy becomes the
  deployable artifact.
* **tuned programs** (:mod:`repro.artifacts.store`) —
  :class:`ProgramStore`, an append-only store of finished
  :class:`~repro.core.vectorizer.TileProgram`s keyed by (site set, agent
  state fingerprint, oracle/backend fingerprint), so a previously-seen
  tuning question is a lookup, not an inference pass.

Consumed by ``NeuroVectorizer.save/load`` + ``program_store=``,
``TuningService.open_session(agent_ckpt=..., program_store=...)`` and
``launch/serve.py --agent-ckpt --program-store``.
"""
from repro.artifacts.agentio import (ARTIFACT_FORMAT, ArtifactError,
                                     agent_fingerprint, fingerprint_state,
                                     load_agent, read_agent_state,
                                     save_agent)
from repro.artifacts.store import (ProgramStore, open_program_store,
                                   oracle_fingerprint, program_key,
                                   sites_fingerprint, tune_through_store)

__all__ = ["ArtifactError", "ARTIFACT_FORMAT", "save_agent", "load_agent",
           "read_agent_state", "agent_fingerprint", "fingerprint_state",
           "ProgramStore", "open_program_store", "program_key", "oracle_fingerprint",
           "sites_fingerprint", "tune_through_store"]
