"""``repro.api`` — the single public surface of the NeuroVectorizer
reproduction (paper Fig. 3/4: *end-to-end, code to vectorization*).

One facade drives the whole pipeline with interchangeable decision
methods behind the :class:`Agent` protocol and interchangeable reward
sources behind the :class:`Oracle` protocol::

    from repro.api import NeuroVectorizer

    nv = NeuroVectorizer(cfg, agent="ppo", lr=5e-4, seed=0)
    nv.fit(corpus_sites, total_steps=30_000)     # train vs the oracle
    prog = nv.tune(step_fn, abstract_args)       # extract -> act -> tiles
    print(nv.speedup(prog, sites))               # modelled speedup
    with nv.inject(prog):                        # tuned Pallas BlockSpecs
        step_fn(*real_args)

Swap ``agent="ppo"`` for any registry name (``dtree`` / ``nns`` /
``brute`` / ``random`` / ``polly`` / ``baseline``) and the rest of the
code does not change; swap the default cost-model oracle for
``oracle="measured"`` (or a hand-built :class:`MeasuredEnv`) and rewards
come from wall-clock timings of the compiled Pallas kernels instead of
the analytic model — same protocol, same facade::

    nv = NeuroVectorizer(cfg, agent="ppo", oracle="measured",
                         db_path="measure.jsonl",   # persistent timings
                         transport="pool", workers=4)   # N-worker pool

For many concurrent tuning sessions over one shared worker pool, move up
one altitude to :class:`repro.service.TuningService`.
"""
from __future__ import annotations

from typing import Optional, Sequence, Union

from repro.configs.neurovec import DEFAULT, NeuroVecConfig
from repro.core.agents import (AGENT_NAMES, BaselineHeuristicAgent,
                               BruteForceAgent, DecisionTreeAgent, NNSAgent,
                               PPOAgent, PollyAgent, RandomAgent,
                               brute_force_action, brute_force_costs,
                               brute_force_labels, default_embed_fn,
                               make_agent, n_evaluations, polly_action)
from repro.core.env import (ActionSpace, CostModelEnv, MeasuredEnv,
                            set_strict_actions)
from repro.core.extractor import extract_arch_sites, extract_sites
from repro.core.protocols import (Agent, AsyncOracle, MeasureTransport,
                                  Oracle)
from repro.core.vectorizer import (TileProgram, baseline_program, inject,
                                   program_speedup, tune, tune_step_fn)
from repro.measure import (TRANSPORT_NAMES, CachedMeasureFn,
                           InProcessTransport, MeasureDB, MeasureRunner,
                           TransportMeasureFn, WorkerPoolTransport,
                           make_measured_env, make_transport)
from repro.service import SessionHandle, TuningService

__all__ = [
    "NeuroVectorizer", "Agent", "Oracle", "AGENT_NAMES", "make_agent",
    "default_embed_fn",
    "NeuroVecConfig", "DEFAULT", "ActionSpace", "CostModelEnv",
    "MeasuredEnv", "set_strict_actions",
    "MeasureRunner", "MeasureDB", "CachedMeasureFn", "make_measured_env",
    "MeasureTransport", "AsyncOracle", "InProcessTransport",
    "WorkerPoolTransport", "TransportMeasureFn", "make_transport",
    "TRANSPORT_NAMES", "TuningService", "SessionHandle",
    "PPOAgent", "BruteForceAgent", "DecisionTreeAgent", "NNSAgent",
    "PollyAgent", "RandomAgent", "BaselineHeuristicAgent",
    "brute_force_action", "brute_force_labels", "brute_force_costs",
    "n_evaluations", "polly_action",
    "TileProgram", "baseline_program", "inject", "program_speedup",
    "tune", "tune_step_fn", "extract_sites", "extract_arch_sites",
]


class NeuroVectorizer:
    """The end-to-end facade: extract → fit → tune → inject.

    The reward source and its execution backend compose as a matrix —
    every cell speaks the same :class:`Oracle` protocol, so agents and
    the rest of the pipeline never branch on the choice:

    ==================  ======================  ===========================
    ``oracle=``         ``transport=``          rewards come from
    ==================  ======================  ===========================
    ``None`` / "model"  (must be unset)         the analytic cost model,
                                                ``CostModelEnv``
    ``"measured"``      ``None`` / "inproc"     wall-clock kernel timings
                                                in *this* process
    ``"measured"``      "pool", ``workers=N``   timings fanned out to N
                                                subprocess workers
                                                (``WorkerPoolTransport``)
    ``"measured"``      a ``MeasureTransport``  timings through your
                                                transport (borrowed — the
                                                facade won't close it)
    an ``Oracle``       (must be unset)         your oracle, verbatim
    ==================  ======================  ===========================

    Parameters
    ----------
    cfg:    the :class:`NeuroVecConfig` (action space, PPO and penalty
            hyperparameters).
    agent:  a registry name (``"ppo"``, ``"brute"``, ...) or an already
            constructed :class:`Agent`.  Extra ``agent_kwargs`` flow to
            ``make_agent`` (e.g. ``lr=``, ``mode=``, ``embed_fn=``).
    oracle: a row of the matrix above.  ``"measured"`` assembles
            :func:`repro.measure.make_measured_env` — real hardware on
            TPU/GPU, interpret-mode Pallas on CPU.
    transport: a column of the matrix above (``oracle="measured"`` only).
    workers: pool size for ``transport="pool"``.
    db_path: persistent timing-DB path for ``oracle="measured"``
            (repeat runs against the same path re-time nothing — under
            any transport).
    oracle_kwargs: extra :class:`repro.measure.MeasureRunner` options for
            ``oracle="measured"`` (``reps=``, ``warmup=``, ``max_dim=``,
            ``interpret=``...) — applied per worker under the pool.

    A facade that built a measured oracle owns its transport: call
    :meth:`close` (or use the facade as a context manager) to release
    pool workers and the DB file handle.  For many concurrent sessions
    over one shared pool, use :class:`repro.service.TuningService`.
    """

    def __init__(self, cfg: NeuroVecConfig = DEFAULT,
                 agent: Union[str, Agent] = "ppo",
                 oracle: Union[str, Oracle, None] = None, seed: int = 0,
                 db_path: Optional[str] = None,
                 oracle_kwargs: Optional[dict] = None,
                 transport: Union[str, MeasureTransport, None] = None,
                 workers: Optional[int] = None,
                 **agent_kwargs):
        self.cfg = cfg
        self._owns_oracle = False
        if oracle == "measured":
            self.oracle: Oracle = make_measured_env(
                cfg, db_path=db_path, seed=seed, transport=transport,
                workers=workers, **(oracle_kwargs or {}))
            # a borrowed MeasureTransport instance is not ours to close
            self._owns_oracle = transport is None or isinstance(transport,
                                                                str)
        else:
            if db_path is not None or oracle_kwargs or \
                    transport is not None or workers is not None:
                raise ValueError("db_path/oracle_kwargs/transport/workers "
                                 "apply only to oracle='measured'")
            if oracle is None or oracle == "model":
                self.oracle = CostModelEnv(cfg, seed=seed)
            elif isinstance(oracle, str):
                raise ValueError(f"unknown oracle {oracle!r}: "
                                 f"expected 'model' or 'measured'")
            else:
                self.oracle = oracle
        self.agent: Agent = (make_agent(agent, cfg, seed=seed,
                                        **agent_kwargs)
                             if isinstance(agent, str) else agent)

    # -- training ----------------------------------------------------------
    def fit(self, corpus_sites: Sequence, **fit_kwargs) -> "NeuroVectorizer":
        """Fit the agent against this facade's oracle (RL training, brute
        labelling, or a no-op for search-free methods).  Extra kwargs flow
        to the agent (e.g. ``total_steps=`` for ppo, ``labels=`` for
        nns/dtree)."""
        self.agent.fit(corpus_sites, self.oracle, **fit_kwargs)
        return self

    # -- tuning ------------------------------------------------------------
    def tune(self, step_fn, abstract_args: Sequence = ()) -> TileProgram:
        """Extract kernel sites from ``step_fn`` traced over
        ``abstract_args`` and tune them (greedy inference, paper §4.2)."""
        return self.tune_sites(extract_sites(step_fn, *abstract_args))

    def tune_sites(self, sites: Sequence) -> TileProgram:
        return tune(list(sites), self.agent, self.oracle.space)

    def tune_arch(self, arch: str, batch: int = 8,
                  seq: int = 2048) -> TileProgram:
        """Tune every site of one training step of a named architecture."""
        return self.tune_sites(extract_arch_sites(arch, batch=batch,
                                                  seq=seq))

    # -- deployment --------------------------------------------------------
    def inject(self, program: TileProgram, interpret: bool = False):
        """Context manager: run model code with the tuned tiles routed
        through the Pallas kernels (the pragma-injection analogue)."""
        return inject(program, interpret=interpret)

    def baseline(self, sites: Sequence) -> TileProgram:
        return baseline_program(list(sites))

    def speedup(self, program: TileProgram, sites: Sequence) -> float:
        """Aggregate speedup of ``program`` over the heuristic baseline,
        priced by this facade's oracle semantics."""
        return program_speedup(program, list(sites), env=self.oracle)

    # -- lifecycle ---------------------------------------------------------
    def close(self) -> None:
        """Release the measured oracle's transport (pool workers, DB file
        handle) when this facade built it.  No-op otherwise; idempotent."""
        if self._owns_oracle:
            self.oracle.measure_fn.transport.close()

    def __enter__(self) -> "NeuroVectorizer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
